"""Shared benchmark helpers: timing + the ``name,us_per_call,derived`` CSV
contract of ``benchmarks.run``."""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def record(name: str, us_per_call: float, derived: str):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def timed(name: str, fn: Callable, *, repeats: int = 1, warmup: int = 0,
          derived_fn=None):
    """Run ``fn`` ``repeats`` times; record mean wall time + derived info.
    ``warmup`` extra calls run first, outside the timed window — jit
    compiles, trace caches and allocator pools all land there instead of
    polluting the first timed repeat."""
    outs = []
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(repeats):
        outs.append(fn())
    dt = (time.perf_counter() - t0) / repeats
    derived = derived_fn(outs[-1]) if derived_fn else ""
    record(name, dt * 1e6, derived)
    return outs[-1]
