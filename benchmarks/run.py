"""Benchmark entry point: one section per paper table/figure plus the
roofline deliverable.  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--full]
"""

import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale axes (hours); default is CI-sized")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")

    print("# --- Fig 4: single-task DVFS optimum (S5.2) ---", flush=True)
    from benchmarks import single_task_dvfs
    single_task_dvfs.run(verbose=False)

    print("# --- Figs 5-8: offline scheduling (S5.3) ---", flush=True)
    from benchmarks import offline_scheduling
    offline_scheduling.main(["--full"] if args.full else [])

    print("# --- Fig 9, 12-13: theta sweeps (S5.3.3, S5.4.3) ---", flush=True)
    from benchmarks import theta_sweep
    theta_sweep.main(["--full"] if args.full else [])

    print("# --- Figs 10-11: online scheduling (S5.4) ---", flush=True)
    from benchmarks import online_scheduling
    online_scheduling.main(["--full"] if args.full else [])

    print("# --- S5 scenario grid (intervals x class mixes) ---", flush=True)
    from benchmarks import scenario_sweep
    scenario_sweep.run(utils=(0.2,), rhos=(2,), delta_scales=(1.0,),
                       verbose=False)

    print("# --- Phi cost (S2.1 low-overhead claim) ---", flush=True)
    from benchmarks import scheduler_throughput
    scheduler_throughput.run(verbose=False)

    print("# --- Solver throughput layer (dedup/cache + refined kernel) ---",
          flush=True)
    from benchmarks import solver_throughput
    solver_throughput.run(50000 if args.full else 10000, verbose=False)

    print("# --- Online scale (event-driven engine) ---", flush=True)
    from benchmarks import online_scale
    online_scale.run_one(100000 if args.full else 20000, "uniform",
                         verbose=False)

    print("# --- Pipelined online scheduling (prefetch + incremental "
          "pools) ---", flush=True)
    from benchmarks import pipeline
    pipeline.run_cell(100000 if args.full else 20000, "uniform",
                      reps=3 if args.full else 1, scalar=False,
                      verbose=False)

    print("# --- Offline scale (shared placement subsystem) ---", flush=True)
    from benchmarks import offline_scale
    offline_scale.run_one(100000 if args.full else 20000, "edl",
                          time_kernel=False, verbose=False)

    print("# --- Fault tolerance (failure rate x trace shape) ---",
          flush=True)
    from benchmarks import fault_tolerance
    fault_tolerance.sweep(20000 if args.full else 3000, verbose=False)

    if not args.skip_roofline:
        print("# --- Roofline (deliverable g; from dry-run JSONs) ---",
              flush=True)
        from benchmarks import roofline
        try:
            roofline.run(verbose=False)
        except Exception as e:  # dry-run not executed yet
            print(f"roofline/skipped,0,{type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
