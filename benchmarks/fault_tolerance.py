"""Fault tolerance of the online engine: energy overhead and violation
rate under server failure/recovery injection (``repro.core.faults``).

The harness sweeps **failure rate x trace shape** over one arrival trace:

* shapes — ``fraction`` (a fixed fraction of the fleet crashes once, no
  repair), ``mtbf`` (exponential per-server crash/repair alternation) and
  ``mtbf-norepair`` (crashed servers stay down);
* rates — multiples of a base failure intensity (the fraction of servers,
  or the inverse MTBF).

Per cell it reports the overhead of fault recovery against the
failure-free run of the same trace — ``e_total`` overhead (signed: a crash
can also *save* idle energy by retiring a server early) and the violation
rate — and asserts the scalar and vector placement paths stay bit-identical
under injection (the recovery path is shared, so this pins the engine-level
fault transitions too).

``--smoke`` is the CI guard: one 100k-task day with a pinned
1%-of-the-fleet failure trace must complete inside ``--budget`` seconds
with bit-equal scalar/vector energy, every task carrying exactly one live
record, and a re-run of the same seed producing the identical result
(deterministic replay).

    PYTHONPATH=src python -m benchmarks.fault_tolerance --tasks 20000
    PYTHONPATH=src python -m benchmarks.fault_tolerance --smoke
"""

from __future__ import annotations

import argparse
import time
from typing import Dict

import numpy as np

from benchmarks.common import record
from repro.core import faults, online, tasks

#: sweep axes (kept small: every cell runs scalar AND vector)
SHAPES = ("fraction", "mtbf", "mtbf-norepair")
RATES = (0.5, 1.0, 2.0)
BASE_FRACTION = 0.01        # of the estimated server fleet, per day
BASE_MTBF = 2000.0          # slots of mean up-time at rate 1.0
MTTR = 30.0                 # slots of mean repair time


def build_trace(shape: str, rate: float, n_servers: int, horizon: float,
                seed: int) -> faults.FaultTrace:
    if shape == "fraction":
        return faults.FaultTrace.fraction(n_servers,
                                          min(1.0, BASE_FRACTION * rate),
                                          horizon, seed=seed)
    mttr = None if shape == "mtbf-norepair" else MTTR
    return faults.FaultTrace.sample(n_servers, horizon,
                                    mtbf=BASE_MTBF / rate, mttr=mttr,
                                    seed=seed)


def run_cell(ts, cfgs, trace, l: int, theta: float, scalar: bool = True,
             baseline=None) -> Dict:
    """One (trace, scheduler) cell: vector run, optional scalar bit-identity
    check, overheads vs the failure-free baseline."""
    kw = dict(l=l, theta=theta, algorithm="edl", cfgs=cfgs, bound=False,
              faults=trace)
    t0 = time.perf_counter()
    r_vec = online.schedule_online(ts, placement="vector", **kw)
    t_vec = time.perf_counter() - t0
    out = {
        "vector_s": t_vec, "e_total": r_vec.e_total,
        "violations": r_vec.violations,
        "violation_rate": r_vec.violations / len(ts),
        "fault_stats": r_vec.fault_stats,
    }
    if baseline is not None:
        out["e_overhead_frac"] = r_vec.e_total / baseline.e_total - 1.0
        out["extra_violations"] = r_vec.violations - baseline.violations
    if scalar:
        r_sca = online.schedule_online(ts, placement="scalar", **kw)
        assert r_sca.e_total == r_vec.e_total, (
            f"scalar/vector diverged under faults: {r_sca.e_total!r} vs "
            f"{r_vec.e_total!r}")
        assert r_sca.violations == r_vec.violations
        assert r_sca.fault_stats == r_vec.fault_stats
    # exactly one live record per task, no matter how many crashes
    live = np.zeros(len(ts), dtype=np.int64)
    for a in r_vec.assignments:
        if not a.failed:
            live[a.task] += 1
    assert np.all(live == 1), "task lost or duplicated under fault recovery"
    return out


def sweep(n_tasks: int, l: int = 4, theta: float = 0.9, seed: int = 0,
          scalar: bool = True, verbose: bool = True) -> Dict:
    lib = tasks.app_library()
    ts = tasks.generate_trace(n_tasks, pattern="uniform",
                              horizon=tasks.DAY_SLOTS, seed=seed,
                              library=lib)
    mcs = online.machines.reference_classes()
    cfgs = online.online_configs(ts, mcs)
    n_servers = max(1, tasks.peak_pair_estimate(ts) // l)
    base = online.schedule_online(ts, l=l, theta=theta, algorithm="edl",
                                  cfgs=cfgs, bound=False)
    if verbose:
        print(f"failure-free: e_total={base.e_total:.3e} "
              f"violations={base.violations} fleet~{n_servers} servers",
              flush=True)
    out = {"n_tasks": len(ts), "n_servers_est": n_servers,
           "e_total_base": base.e_total, "violations_base": base.violations,
           "cells": {}}
    for shape in SHAPES:
        for rate in RATES:
            trace = build_trace(shape, rate, n_servers,
                                float(tasks.DAY_SLOTS), seed + 17)
            cell = run_cell(ts, cfgs, trace, l, theta, scalar=scalar,
                            baseline=base)
            out["cells"][(shape, rate)] = cell
            if verbose:
                st = cell["fault_stats"]
                print(f"{shape:13s} x{rate:3.1f}: failures={st['failures']:4d} "
                      f"orphans={st['orphans']:5d} degraded={st['degraded']:4d} "
                      f"e_overhead={cell['e_overhead_frac']:+7.3%} "
                      f"viol_rate={cell['violation_rate']:.4%}", flush=True)
            record(f"fault_tolerance/{shape}_x{rate}",
                   cell["vector_s"] / len(ts) * 1e6,
                   f"e_overhead={cell['e_overhead_frac']:+.3%}, "
                   f"{cell['violations']} violations")
    return out


def smoke(n_tasks: int, budget: float, l: int = 4, theta: float = 0.9,
          seed: int = 0) -> Dict:
    """The CI tripwire: a 100k-task day under a pinned 1%-of-fleet failure
    trace — budgeted wall clock, scalar/vector bit-identity, exactly one
    live record per task, deterministic replay."""
    lib = tasks.app_library()
    ts = tasks.generate_trace(n_tasks, pattern="uniform",
                              horizon=tasks.DAY_SLOTS, seed=seed,
                              library=lib)
    mcs = online.machines.reference_classes()
    cfgs = online.online_configs(ts, mcs)
    n_servers = max(1, tasks.peak_pair_estimate(ts) // l)
    trace = faults.FaultTrace.fraction(n_servers, BASE_FRACTION,
                                       float(tasks.DAY_SLOTS), seed=7)
    # warm the deferred-readjustment compile out of the timed run
    online.schedule_online(ts, l=l, theta=theta, algorithm="edl", cfgs=cfgs,
                           bound=False)
    t0 = time.perf_counter()
    cell = run_cell(ts, cfgs, trace, l, theta, scalar=True)
    t_all = time.perf_counter() - t0
    assert cell["fault_stats"]["failures"] > 0, "smoke trace injected nothing"
    assert cell["vector_s"] <= budget, (
        f"fault-injected run took {cell['vector_s']:.1f}s "
        f"(> {budget:.0f}s budget)")
    replay = run_cell(ts, cfgs, trace, l, theta, scalar=False)
    assert replay["e_total"] == cell["e_total"], "replay diverged"
    assert replay["fault_stats"] == cell["fault_stats"]
    print(f"smoke OK: {n_tasks} tasks, {cell['fault_stats']['failures']} "
          f"failures, {cell['violations']} violations, "
          f"vector={cell['vector_s']:.2f}s <= {budget:.0f}s, "
          f"scalar/vector bit-identical, replay bit-identical", flush=True)
    record(f"fault_tolerance/smoke_{n_tasks}",
           t_all / n_tasks * 1e6,
           f"{cell['fault_stats']['failures']} failures, "
           f"{cell['violations']} violations")
    return cell


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--tasks", type=int, default=20000)
    ap.add_argument("--l", type=int, default=4)
    ap.add_argument("--theta", type=float, default=0.9)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-scalar", action="store_true",
                    help="skip the scalar bit-identity runs (large sweeps)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: pinned 1%%-fleet trace on 100k tasks")
    ap.add_argument("--budget", type=float, default=240.0,
                    help="--smoke wall-clock cap for the vectorized run (s)")
    args = ap.parse_args(argv)
    if args.smoke:
        smoke(max(args.tasks, 100000), args.budget, l=args.l,
              theta=args.theta, seed=args.seed)
    else:
        sweep(args.tasks, l=args.l, theta=args.theta, seed=args.seed,
              scalar=not args.no_scalar)


if __name__ == "__main__":
    main()
