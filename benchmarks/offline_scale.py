"""Offline schedulers at scale: 1k-100k-task batches through the shared
placement subsystem (``core/placement.py``).

``schedule_offline`` is a thin driver over the same placement core the
online simulator uses — the offline batch is the degenerate "one group at
t=0" case.  This harness

* generates batches with exactly ``--tasks`` tasks
  (``repro.core.tasks.generate_offline_n``);
* times the Algorithm-1 solve twice — the jitted jnp solver and the Pallas
  kernel path — separately from the packing, by precomputing configs with
  ``scheduling.configure_all`` and injecting them via
  ``schedule_offline(cfgs=...)``;
* compares the vectorized placement path (``placement="vector"``, the
  default: batched worst-fit frontier, pooled probes, bulk fresh-pair
  opens) against the per-task scalar reference loop
  (``placement="scalar"``) — bit-identical by construction, asserted to
  1e-9 rel (it actually matches exactly);
* reports the §5 theoretical bound (``core/bounds.py``) next to every
  achieved energy, so each row shows achieved-vs-bound;
* emits a JSON + markdown report under ``--out`` for the full sweep
  (n × algorithm × class mix).

``--smoke`` is the CI guard: one 10k-task EDL batch must beat the scalar
loop by ``--min-speedup`` (default 2x, conservative for shared CI
hardware; quiet machines measure ~3x at 100k) inside a ``--budget``
wall-clock cap, with bit-equal energy.

    PYTHONPATH=src python -m benchmarks.offline_scale --tasks 10000 --smoke
    PYTHONPATH=src python -m benchmarks.offline_scale --full \\
        --out results/offline_scale
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional, Tuple

from benchmarks.common import record
from repro.core import bounds, machines, scheduling, tasks

ALGOS = ("edl", "edf-wf", "edf-bf", "lpt-ff")

#: class-mix name -> spec accepted by ``schedule_offline(classes=...)``
MIXES: Dict[str, Optional[Tuple[str, ...]]] = {
    "reference": None,
    "het2": ("gtx-1080ti", "tpu-v5e"),
}


def _solves(ts, mcs, time_kernel: bool):
    """Time the Algorithm-1 solve (jnp path, and optionally the Pallas
    kernel path) once for a (batch, mix); the configs feed every
    algorithm's packing run via ``schedule_offline(cfgs=...)``.

    ``dedup=False`` keeps the timings honest: they measure the solver
    itself, not hits on the process-wide solve cache (which
    ``benchmarks/solver_throughput.py`` measures separately).
    """
    t0 = time.perf_counter()
    cfgs = scheduling.configure_all(ts, True, mcs, dedup=False)
    t_solve = time.perf_counter() - t0
    t_solve_kernel = None
    if time_kernel:
        scheduling.configure_all(ts, True, mcs, use_kernel=True,
                                 dedup=False)  # warm
        t0 = time.perf_counter()
        scheduling.configure_all(ts, True, mcs, use_kernel=True, dedup=False)
        t_solve_kernel = time.perf_counter() - t0
    return cfgs, t_solve, t_solve_kernel


def run_one(n_tasks: int, algorithm: str = "edl", mix: str = "reference",
            l: int = 4, theta: float = 0.9, seed: int = 0,
            scalar: bool = True, time_kernel: bool = True,
            verbose: bool = True, _shared=None) -> Dict:
    """One batch end to end; returns timings, energies, bound and speedup.

    ``_shared`` (from :func:`sweep`) injects ``(ts, cfgs, t_solve,
    t_solve_kernel, bound)`` so the solve and bound — which depend only on
    the batch and the mix, not the algorithm — are computed once per
    (n, mix) cell.
    """
    classes = MIXES[mix]
    mcs = machines.resolve_classes(classes)
    if _shared is None:
        ts = tasks.generate_offline_n(n_tasks, seed=seed,
                                      library=tasks.app_library())
        cfgs, t_solve, t_solve_kernel = _solves(ts, mcs, time_kernel)
        b = bounds.theoretical_bound(ts, classes=mcs)
    else:
        ts, cfgs, t_solve, t_solve_kernel, b = _shared

    # ``bound=False``: the bound is computed once above; the timed runs
    # measure the packing hot path only.
    kw = dict(l=l, theta=theta, algorithm=algorithm, cfgs=cfgs,
              classes=classes, bound=False)
    # Warm the deferred-readjustment solver compile out of the timings so
    # the vector/scalar ratio is compile-free.
    scheduling.schedule_offline(ts, placement="vector", **kw)
    t0 = time.perf_counter()
    r_vec = scheduling.schedule_offline(ts, placement="vector", **kw)
    t_vec = time.perf_counter() - t0

    out = {
        "n_tasks": len(ts), "algorithm": algorithm, "mix": mix,
        "solve_s": t_solve, "solve_kernel_s": t_solve_kernel,
        "vector_s": t_vec, "vector_tasks_per_s": len(ts) / t_vec,
        "e_total": r_vec.e_total, "e_idle": r_vec.e_idle,
        "e_bound": b.e_bound, "savings_ceiling": b.savings_ceiling,
        "bound_gap": r_vec.e_total / b.e_bound - 1.0,
        "violations": r_vec.violations, "n_pairs": r_vec.n_pairs,
    }
    if scalar:
        t0 = time.perf_counter()
        r_sca = scheduling.schedule_offline(ts, placement="scalar", **kw)
        t_sca = time.perf_counter() - t0
        rel = abs(r_vec.e_total - r_sca.e_total) / max(abs(r_sca.e_total),
                                                       1e-12)
        out.update({"scalar_s": t_sca, "speedup": t_sca / t_vec,
                    "e_total_rel_err": rel})
        assert rel <= 1e-9, (
            f"vector/scalar e_total diverged: {r_vec.e_total!r} vs "
            f"{r_sca.e_total!r}")
    if verbose:
        line = (f"{algorithm:6s} {mix:9s} n={len(ts):7d} "
                f"solve={t_solve:5.2f}s vector={t_vec:5.2f}s "
                f"gap_vs_bound={out['bound_gap'] * 100:5.1f}%")
        if scalar:
            line += (f" scalar={out['scalar_s']:5.2f}s "
                     f"speedup={out['speedup']:4.1f}x "
                     f"rel_err={out['e_total_rel_err']:.1e}")
        print(line, flush=True)
    record(f"offline_scale/{algorithm}_{mix}_{len(ts)}",
           t_vec / len(ts) * 1e6,
           f"{len(ts) / t_vec:.0f} tasks/s, gap {out['bound_gap']:.3f}"
           + (f", {out['speedup']:.1f}x vs scalar" if scalar else ""))
    return out


def smoke(n_tasks: int, budget: float, min_speedup: float) -> Dict:
    """The CI tripwire: budgeted wall clock + speedup + bit-equal energy."""
    out = run_one(n_tasks, "edl", scalar=True, time_kernel=False)
    assert out["violations"] == 0, out
    assert out["vector_s"] <= budget, (
        f"vectorized {n_tasks}-task offline EDL took {out['vector_s']:.1f}s "
        f"(> {budget:.0f}s budget)")
    assert out["speedup"] >= min_speedup, (
        f"vectorized offline placement regressed: {out['speedup']:.1f}x < "
        f"{min_speedup:.1f}x over the scalar loop")
    assert out["bound_gap"] >= 0.0, out["bound_gap"]
    print(f"smoke OK: {out['vector_s']:.2f}s <= {budget:.0f}s, "
          f"{out['speedup']:.1f}x >= {min_speedup:.1f}x, "
          f"rel_err={out['e_total_rel_err']:.1e}, "
          f"gap_vs_bound={out['bound_gap'] * 100:.1f}%", flush=True)
    return out


def _write_report(rows: List[Dict], out_prefix: str):
    os.makedirs(os.path.dirname(out_prefix) or ".", exist_ok=True)
    with open(out_prefix + ".json", "w") as f:
        json.dump(rows, f, indent=2)
    cols = ("n_tasks", "algorithm", "mix", "solve_s", "solve_kernel_s",
            "scalar_s", "vector_s", "speedup", "e_total", "e_bound",
            "bound_gap", "violations")
    lines = ["# Offline placement at scale",
             "",
             "`e_bound` is the §5 theoretical lower bound "
             "(`core/bounds.py`); `bound_gap` = e_total / e_bound - 1.",
             "",
             "| " + " | ".join(cols) + " |",
             "|" + "---|" * len(cols)]
    for r in rows:
        cells = []
        for c in cols:
            v = r.get(c)
            if v is None:
                cells.append("-")
            elif isinstance(v, float):
                cells.append(f"{v:.4g}")
            else:
                cells.append(str(v))
        lines.append("| " + " | ".join(cells) + " |")
    with open(out_prefix + ".md", "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {out_prefix}.json and {out_prefix}.md", flush=True)


def sweep(ns, algorithms=ALGOS, mixes=tuple(MIXES), scalar: bool = True,
          time_kernel: bool = True, seed: int = 0,
          out: Optional[str] = None, verbose: bool = True) -> List[Dict]:
    lib = tasks.app_library()
    rows = []
    for n in ns:
        ts = tasks.generate_offline_n(int(n), seed=seed, library=lib)
        for mix in mixes:
            mcs = machines.resolve_classes(MIXES[mix])
            cfgs, t_solve, t_kernel = _solves(ts, mcs, time_kernel)
            b = bounds.theoretical_bound(ts, classes=mcs)
            shared = (ts, cfgs, t_solve, t_kernel, b)
            for alg in algorithms:
                rows.append(run_one(int(n), alg, mix, scalar=scalar,
                                    verbose=verbose, _shared=shared))
    if out:
        _write_report(rows, out)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--tasks", type=int, nargs="*", default=None,
                    help="batch sizes to sweep (default 1k 10k; --full adds "
                         "100k); with --smoke, the single smoke batch size "
                         "(default 10k)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale axes: adds the 100k-task batch")
    ap.add_argument("--algorithms", nargs="*", default=list(ALGOS),
                    choices=ALGOS)
    ap.add_argument("--mixes", nargs="*", default=list(MIXES),
                    choices=sorted(MIXES))
    ap.add_argument("--no-scalar", action="store_true",
                    help="skip the scalar reference run")
    ap.add_argument("--out", default="results/offline_scale",
                    help="JSON/markdown report path prefix")
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: budgeted wall clock + min speedup")
    ap.add_argument("--budget", type=float, default=60.0,
                    help="--smoke wall-clock cap for the vectorized run (s)")
    ap.add_argument("--min-speedup", type=float, default=2.0,
                    help="--smoke minimum vector/scalar speedup")
    args = ap.parse_args(argv)

    if args.smoke:
        smoke(args.tasks[0] if args.tasks else 10000, args.budget,
              args.min_speedup)
        return

    ns = list(args.tasks) if args.tasks else [1000, 10000]
    if args.full and 100000 not in ns:
        ns.append(100000)
    sweep(ns, tuple(args.algorithms), tuple(args.mixes),
          scalar=not args.no_scalar, out=args.out)


if __name__ == "__main__":
    main()
