"""Event-driven online engine at scale: 100k-1M-task horizons.

The online simulator advances arrival group by arrival group over the
``ClusterEngine.settle`` power-off primitive (exact DRS billing) and places
each group through the vectorized batch/pool path
(``online.schedule_online(placement="vector")``).  This harness

* generates traces with exactly ``--tasks`` tasks under the arrival
  patterns of ``repro.core.tasks.generate_trace`` (uniform / sparse /
  bursty / diurnal);
* times the Algorithm-1 solve (one batched dispatch, optionally through
  the Pallas kernel with ``--kernel``) separately from the simulation, by
  precomputing configs with ``online.online_configs`` and injecting them
  into both runs;
* compares the vectorized placement path against the per-task scalar
  reference loop (``placement="scalar"``) — the two are bit-identical by
  construction, and the harness asserts ``e_total`` matches to 1e-9 rel
  (it actually matches exactly).

``--smoke`` is the CI guard: one 100k-task uniform run must beat the
scalar loop by ``--min-speedup`` (default 3x, conservative for shared CI
hardware; quiet machines measure ~5x) inside a ``--budget`` wall-clock cap,
with bit-equal energy — so the vectorized placement path cannot silently
regress to the per-task Python loop.

    PYTHONPATH=src python -m benchmarks.online_scale --tasks 100000 --smoke
    PYTHONPATH=src python -m benchmarks.online_scale --tasks 1000000 \\
        --pattern diurnal --no-scalar
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

from benchmarks.common import record
from repro.core import bounds, cluster as cl, online, tasks


def run_one(n_tasks: int, pattern: str, l: int = 4, theta: float = 0.9,
            use_kernel: bool = False, horizon: Optional[int] = None,
            seed: int = 0, scalar: bool = True, verbose: bool = True) -> Dict:
    """One trace end to end; returns timings, energies and the speedup."""
    lib = tasks.app_library()
    horizon = horizon or tasks.DAY_SLOTS
    ts = tasks.generate_trace(n_tasks, pattern=pattern, horizon=horizon,
                              seed=seed, library=lib)
    mcs = online.machines.reference_classes()

    t0 = time.perf_counter()
    cfgs = online.online_configs(ts, mcs, use_kernel=use_kernel)
    t_solve = time.perf_counter() - t0

    b = bounds.theoretical_bound(ts, classes=mcs, l=l, rho=cl.RHO)

    # ``bound=False``: the bound is computed once above; the timed runs
    # measure the simulation hot path only.
    kw = dict(l=l, theta=theta, algorithm="edl", cfgs=cfgs,
              use_kernel=use_kernel, bound=False)
    # Warm the deferred-readjustment solver compile out of the timings so
    # the vector/scalar ratio (and the reported throughput) is
    # compile-free.  A smaller warmup would compile a different padded
    # shape and not help.
    online.schedule_online(ts, placement="vector", **kw)
    t0 = time.perf_counter()
    r_vec = online.schedule_online(ts, placement="vector", **kw)
    t_vec = time.perf_counter() - t0

    out = {
        "n_tasks": len(ts), "pattern": pattern, "solve_s": t_solve,
        "vector_s": t_vec, "vector_tasks_per_s": len(ts) / t_vec,
        "e_total": r_vec.e_total, "e_idle": r_vec.e_idle,
        "e_bound": b.e_bound, "bound_gap": r_vec.e_total / b.e_bound - 1.0,
        "violations": r_vec.violations, "n_pairs": r_vec.n_pairs,
    }
    if scalar:
        t0 = time.perf_counter()
        r_sca = online.schedule_online(ts, placement="scalar", **kw)
        t_sca = time.perf_counter() - t0
        rel = abs(r_vec.e_total - r_sca.e_total) / max(abs(r_sca.e_total),
                                                       1e-12)
        out.update({"scalar_s": t_sca, "speedup": t_sca / t_vec,
                    "e_total_rel_err": rel})
        assert rel <= 1e-9, (
            f"vector/scalar e_total diverged: {r_vec.e_total!r} vs "
            f"{r_sca.e_total!r}")
    if verbose:
        line = (f"{pattern:8s} n={len(ts):7d} solve={t_solve:6.2f}s "
                f"vector={t_vec:6.2f}s ({len(ts) / t_vec:9.0f} tasks/s)")
        if scalar:
            line += (f" scalar={out['scalar_s']:6.2f}s "
                     f"speedup={out['speedup']:4.1f}x "
                     f"rel_err={out['e_total_rel_err']:.1e}")
        print(line, flush=True)
    record(f"online_scale/{pattern}_{len(ts)}", t_vec / len(ts) * 1e6,
           f"{len(ts) / t_vec:.0f} tasks/s"
           + (f", {out['speedup']:.1f}x vs scalar" if scalar else ""))
    return out


def smoke(n_tasks: int, budget: float, min_speedup: float,
          use_kernel: bool) -> Dict:
    """The CI tripwire: budgeted wall clock + speedup + bit-equal energy."""
    out = run_one(n_tasks, "uniform", use_kernel=use_kernel, scalar=True)
    assert out["violations"] == 0, out
    assert out["vector_s"] <= budget, (
        f"vectorized 100k-task simulation took {out['vector_s']:.1f}s "
        f"(> {budget:.0f}s budget)")
    assert out["speedup"] >= min_speedup, (
        f"vectorized placement regressed: {out['speedup']:.1f}x < "
        f"{min_speedup:.1f}x over the scalar loop")
    print(f"smoke OK: {out['vector_s']:.2f}s <= {budget:.0f}s, "
          f"{out['speedup']:.1f}x >= {min_speedup:.1f}x, "
          f"rel_err={out['e_total_rel_err']:.1e}", flush=True)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--tasks", type=int, default=100000)
    ap.add_argument("--pattern", default="all",
                    choices=("all",) + tasks.TRACE_PATTERNS)
    ap.add_argument("--horizon", type=int, default=None,
                    help="slots (default: the 1440-slot day)")
    ap.add_argument("--kernel", action="store_true",
                    help="route the DVFS solves through the Pallas kernel")
    ap.add_argument("--no-scalar", action="store_true",
                    help="skip the scalar reference run (1M-task traces)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: budgeted wall clock + min speedup")
    ap.add_argument("--budget", type=float, default=120.0,
                    help="--smoke wall-clock cap for the vectorized run (s)")
    ap.add_argument("--min-speedup", type=float, default=3.0,
                    help="--smoke minimum vector/scalar speedup")
    args = ap.parse_args(argv)

    if args.smoke:
        smoke(args.tasks, args.budget, args.min_speedup, args.kernel)
        return

    patterns = tasks.TRACE_PATTERNS if args.pattern == "all" \
        else (args.pattern,)
    for pattern in patterns:
        run_one(args.tasks, pattern, use_kernel=args.kernel,
                horizon=args.horizon, scalar=not args.no_scalar)


if __name__ == "__main__":
    main()
