"""Paper §5.3.3 / Fig. 9 (offline) and §5.4.3 / Figs. 12-13 (online):
the θ-readjustment sweep.

θ < 1 trades runtime energy for idle energy; the paper's findings to
reproduce: (i) θ matters only for l > 1; (ii) larger l leans harder on the
readjustment; (iii) θ = 0.8 generally minimizes total energy (except l=1);
(iv) the online EDL conserves 30-33% total energy with a good θ.
"""

from __future__ import annotations

import argparse
from typing import Dict

import numpy as np

from benchmarks.common import record
from repro.core import cluster as cl, online, scheduling, solver_cache, tasks

THETAS = (0.8, 0.85, 0.9, 0.95, 1.0)


def _report_cache(side: str, base: Dict, verbose: bool) -> Dict:
    """Record the sweep's cross-cell solve reuse: every (l, θ) cell of one
    seed shares the same Algorithm-1 rows, so after the first cell the
    process-wide solve cache serves them all (θ only changes the deferred
    readjustment windows).  Counted as the lifetime-counter delta since
    ``base`` — ``schedule_online`` resets the per-run counters at every
    call, so those only cover the last cell."""
    now = solver_cache.GLOBAL_CACHE.stats()
    hits = now["hits_total"] - base["hits_total"]
    misses = now["misses_total"] - base["misses_total"]
    stats = {"hits": hits, "misses": misses,
             "hit_rate": hits / (hits + misses) if hits + misses else 0.0}
    record(f"theta/{side}_solve_cache", 0.0,
           f"hit_rate {stats['hit_rate']:.3f} ({stats['hits']} hits / "
           f"{stats['misses']} misses)")
    if verbose:
        print(f"{side} solve-cache cross-cell reuse: "
              f"{stats['hit_rate']:.1%} ({stats['hits']} hits, "
              f"{stats['misses']} misses)")
    return stats


def run_offline(groups=3, util=0.4, ls=(1, 4, 16), verbose=True) -> Dict:
    lib = tasks.app_library()
    cache_base = solver_cache.GLOBAL_CACHE.stats()
    out = {}
    for seed in range(groups):
        ts = tasks.generate_offline(util, seed=seed, library=lib)
        base = cl.baseline_energy(ts)
        for l in ls:
            for th in THETAS:
                # bound=False: e_bound is (task_set, classes)-invariant, so
                # re-solving it per swept (l, theta) point is pure overhead.
                r = scheduling.schedule_offline(ts, l=l, theta=th,
                                                algorithm="edl", bound=False)
                out.setdefault((l, th), []).append(1 - r.e_total / base)
    summary = {f"l{l}/theta{th}": float(np.mean(v))
               for (l, th), v in sorted(out.items())}
    if verbose:
        for k, v in summary.items():
            print(f"offline {k:18s} saving={v:+.4f}")
    for l in ls:
        best = max(THETAS, key=lambda th: summary[f"l{l}/theta{th}"])
        record(f"theta/offline_best_l{l}", 0.0, f"theta={best}")
    summary["solve_cache"] = _report_cache("offline", cache_base, verbose)
    return summary


def run_online(groups=2, u_off=0.1, u_on=0.4, horizon=400, ls=(1, 4, 16),
               verbose=True) -> Dict:
    lib = tasks.app_library()
    cache_base = solver_cache.GLOBAL_CACHE.stats()
    out = {}
    base_tot = {}
    for seed in range(groups):
        ts = tasks.generate_online(u_off, u_on, seed=seed, library=lib,
                                   horizon=horizon)
        for l in ls:
            rb = online.schedule_online(ts, l=l, theta=1.0, algorithm="edl",
                                        use_dvfs=False, bound=False)
            base_tot.setdefault(l, []).append(rb.e_total)
            for th in THETAS:
                r = online.schedule_online(ts, l=l, theta=th,
                                           algorithm="edl", use_dvfs=True,
                                           bound=False)
                out.setdefault((l, th), []).append(
                    (r.e_run, r.e_idle, r.e_overhead, r.e_total))
    summary = {}
    for (l, th), rows in sorted(out.items()):
        rows = np.asarray(rows)
        summary[f"l{l}/theta{th}"] = {
            "e_run": float(rows[:, 0].mean()),
            "e_idle": float(rows[:, 1].mean()),
            "e_overhead": float(rows[:, 2].mean()),
            "reduction_vs_baseline": float(
                1 - rows[:, 3].mean() / np.mean(base_tot[l])),
        }
        if verbose:
            s = summary[f"l{l}/theta{th}"]
            print(f"online l{l} theta{th}: run={s['e_run']:.3e} "
                  f"idle={s['e_idle']:.3e} total_reduction="
                  f"{s['reduction_vs_baseline']:+.4f}")
    for l in ls:
        reds = {th: summary[f"l{l}/theta{th}"]["reduction_vs_baseline"]
                for th in THETAS}
        best = max(reds, key=reds.get)
        record(f"theta/online_reduction_l{l}", 0.0,
               f"best_theta={best} reduction={reds[best]:.4f} "
               f"(paper 0.30-0.33)")
    summary["solve_cache"] = _report_cache("online", cache_base, verbose)
    return summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    if args.full:
        run_offline(groups=20, ls=(1, 2, 4, 8, 16))
        run_online(groups=5, u_off=0.4, u_on=1.6, horizon=1440,
                   ls=(1, 2, 4, 8, 16))
    else:
        run_offline()
        run_online()


if __name__ == "__main__":
    main()
