"""Pipelined online scheduling: async solve prefetch + incremental pools.

Benchmarks ``online.schedule_online(pipeline=True)`` against the
synchronous reference path (``pipeline=False``) on day-long traces.  The
two are bit-identical by construction (pinned by ``tests/test_pipeline.py``
and re-asserted here); the pipelined path wins by doing structurally less
work per arrival group:

* chunked per-arrival-group solve batches skip the serial path's
  sort-based ``np.unique`` pre-pass (the solve cache's probe already
  carries the cross-chunk dedup);
* the chunk prologue (EDF orders, per-class ``t_hat`` gathers) is hoisted
  into one vectorized ``PlacementContext.prepare_chunk`` pass;
* persistent candidate pools replace the per-group frontier rebuild with
  delta reconciliation (touched-pair merge, batched power-off deletion,
  fault-epoch invalidation).

Timing method: both modes are fully warmed (jit compiles), then timed
interleaved for ``--reps`` repeats with a cold solve cache and the GC
paused inside the window; the best (min) repeat per mode is compared —
single-core CI boxes jitter far more than the path difference.

``--smoke`` is the CI guard: the pipelined run must beat the synchronous
one by ``--min-speedup`` (default 1.5x) inside a ``--budget`` wall cap,
with bit-equal ``e_total`` and scalar-placement parity, and the cell
results land in ``BENCH_sched.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.pipeline --smoke
    PYTHONPATH=src python -m benchmarks.pipeline --tasks 1000000 \\
        --pattern diurnal --no-scalar
"""

from __future__ import annotations

import argparse
import gc
import json
import os
import time
from typing import Dict, Optional

from benchmarks.common import record
from repro.core import online, solver_cache, tasks

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_sched.json")


def _timed_run(ts, pipeline: bool, kw: dict) -> float:
    """One wall-clock sample: cold solve cache, warm jit, GC paused."""
    solver_cache.GLOBAL_CACHE.clear()
    gc.collect()
    gc.disable()
    try:
        t0 = time.perf_counter()
        online.schedule_online(ts, pipeline=pipeline, **kw)
        return time.perf_counter() - t0
    finally:
        gc.enable()


def run_cell(n_tasks: int, pattern: str, l: int = 4, theta: float = 0.9,
             use_kernel: bool = False, horizon: Optional[int] = None,
             seed: int = 0, reps: int = 3, scalar: bool = True,
             verbose: bool = True) -> Dict:
    """One trace: bit-identity checks + interleaved pipelined/sync timing."""
    horizon = horizon or tasks.DAY_SLOTS
    ts = tasks.generate_trace(n_tasks, pattern=pattern, horizon=horizon,
                              seed=seed)
    kw = dict(l=l, theta=theta, algorithm="edl", placement="vector",
              use_kernel=use_kernel, bound=False)

    # Warmup both modes (jit compiles for every padded chunk shape) — these
    # runs double as the bit-identity guard.
    r_pipe = online.schedule_online(ts, pipeline=True, **kw)
    r_sync = online.schedule_online(ts, pipeline=False, **kw)
    bit_identical = (
        r_pipe.e_total == r_sync.e_total
        and r_pipe.violations == r_sync.violations
        and len(r_pipe.assignments) == len(r_sync.assignments)
        and all(a == b for a, b in zip(r_pipe.assignments,
                                       r_sync.assignments)))
    assert bit_identical, (
        f"pipeline=True diverged from the synchronous path: "
        f"e_total {r_pipe.e_total!r} vs {r_sync.e_total!r}")

    scalar_parity = None
    if scalar:
        r_sca = online.schedule_online(ts, placement="scalar",
                                       **{k: v for k, v in kw.items()
                                          if k != "placement"})
        scalar_parity = r_pipe.e_total == r_sca.e_total
        assert scalar_parity, (
            f"vector/scalar e_total diverged: {r_pipe.e_total!r} vs "
            f"{r_sca.e_total!r}")

    t_pipe, t_sync = [], []
    for _ in range(reps):
        t_pipe.append(_timed_run(ts, True, kw))
        t_sync.append(_timed_run(ts, False, kw))
    best_pipe, best_sync = min(t_pipe), min(t_sync)
    speedup = best_sync / best_pipe

    out = {
        "workload": f"{pattern}-{len(ts)}",
        "n_tasks": len(ts), "pattern": pattern, "horizon": horizon,
        "path": "kernel" if use_kernel else "jnp",
        "pipelined_s": best_pipe, "sync_s": best_sync,
        "speedup": speedup,
        "tasks_per_s": len(ts) / best_pipe,
        "e_total": r_pipe.e_total, "violations": r_pipe.violations,
        "bit_identical": bit_identical, "scalar_parity": scalar_parity,
        "cache_stats": r_pipe.cache_stats,
    }
    if verbose:
        print(f"{pattern:8s} n={len(ts):7d} pipelined={best_pipe:6.2f}s "
              f"({len(ts) / best_pipe:9.0f} tasks/s) sync={best_sync:6.2f}s "
              f"speedup={speedup:4.2f}x bit_identical={bit_identical}"
              + (f" scalar_parity={scalar_parity}" if scalar else ""),
              flush=True)
    record(f"pipeline/{pattern}_{len(ts)}", best_pipe / len(ts) * 1e6,
           f"{len(ts) / best_pipe:.0f} tasks/s, {speedup:.2f}x vs sync")
    return out


def write_bench_json(cells, path: str = BENCH_JSON) -> None:
    """Mirror of ``BENCH_solver.json`` for the scheduling layer."""
    head = cells[0]
    payload = {
        "benchmark": "pipeline_scheduling",
        "cells": cells,
        "headline": {
            "pipeline_speedup": head["speedup"],
            "pipelined_tasks_per_s": head["tasks_per_s"],
            "e_total": head["e_total"],
            "bit_identical": all(c["bit_identical"] for c in cells),
        },
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {path}", flush=True)


def smoke(n_tasks: int, budget: float, min_speedup: float, use_kernel: bool,
          reps: int) -> Dict:
    """The CI tripwire: budgeted wall clock + pipeline speedup + bit-equal
    energy + scalar parity, recorded into ``BENCH_sched.json``."""
    out = run_cell(n_tasks, "uniform", use_kernel=use_kernel, reps=reps,
                   scalar=True)
    assert out["violations"] == 0, out
    if out["speedup"] < min_speedup:
        # Shared CI boxes jitter; one re-measure pools the samples before
        # declaring a regression (a real one fails both rounds).
        again = run_cell(n_tasks, "uniform", use_kernel=use_kernel,
                         reps=reps, scalar=False, verbose=False)
        out["pipelined_s"] = min(out["pipelined_s"], again["pipelined_s"])
        out["sync_s"] = min(out["sync_s"], again["sync_s"])
        out["speedup"] = out["sync_s"] / out["pipelined_s"]
        out["tasks_per_s"] = out["n_tasks"] / out["pipelined_s"]
    assert out["pipelined_s"] <= budget, (
        f"pipelined {n_tasks}-task simulation took {out['pipelined_s']:.1f}s "
        f"(> {budget:.0f}s budget)")
    assert out["speedup"] >= min_speedup, (
        f"pipelined path regressed: {out['speedup']:.2f}x < "
        f"{min_speedup:.1f}x over pipeline=False")
    write_bench_json([out])
    print(f"smoke OK: {out['pipelined_s']:.2f}s <= {budget:.0f}s, "
          f"{out['speedup']:.2f}x >= {min_speedup:.1f}x, bit-identical, "
          f"scalar parity", flush=True)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--tasks", type=int, default=100000)
    ap.add_argument("--pattern", default="uniform",
                    choices=tasks.TRACE_PATTERNS)
    ap.add_argument("--horizon", type=int, default=None,
                    help="slots (default: the 1440-slot day)")
    ap.add_argument("--reps", type=int, default=5,
                    help="interleaved timing repeats per mode")
    ap.add_argument("--kernel", action="store_true",
                    help="route the DVFS solves through the Pallas kernel")
    ap.add_argument("--no-scalar", action="store_true",
                    help="skip the scalar-placement parity run")
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: speedup + budget + bit-equality gates, "
                         "writes BENCH_sched.json")
    ap.add_argument("--budget", type=float, default=60.0,
                    help="--smoke: wall-clock cap for the pipelined run")
    ap.add_argument("--min-speedup", type=float, default=1.5,
                    help="--smoke: required pipelined/sync speedup")
    args = ap.parse_args(argv)

    if args.smoke:
        smoke(args.tasks, args.budget, args.min_speedup, args.kernel,
              args.reps)
        return
    run_cell(args.tasks, args.pattern, use_kernel=args.kernel,
             horizon=args.horizon, seed=0, reps=args.reps,
             scalar=not args.no_scalar)


if __name__ == "__main__":
    main()
