"""Paper §5.4 / Figs. 10-11: online energy decomposition, EDL vs
bin-packing, ±DVFS, across server widths.

CI default shrinks the day (horizon 400 slots, U_on 0.4); ``--full`` uses
the paper's 1440-slot day with U_off=0.4 / U_on=1.6.
"""

from __future__ import annotations

import argparse
from typing import Dict

import numpy as np

from benchmarks.common import record
from repro.core import online, tasks


def run(groups: int = 2, u_off: float = 0.1, u_on: float = 0.4,
        horizon: int = 400, ls=(1, 4, 16), theta: float = 0.9,
        verbose: bool = True, use_kernel: bool = False) -> Dict:
    lib = tasks.app_library()
    out: Dict[str, Dict] = {}
    for seed in range(groups):
        ts = tasks.generate_online(u_off, u_on, seed=seed, library=lib,
                                   horizon=horizon)
        for l in ls:
            for alg in ("edl", "bin"):
                for use_dvfs in (False, True):
                    th = theta if use_dvfs else 1.0
                    # bound=False: e_bound is (task_set)-invariant across
                    # the swept (l, alg, dvfs) axes.
                    r = online.schedule_online(ts, l=l, theta=th,
                                               algorithm=alg,
                                               use_dvfs=use_dvfs,
                                               use_kernel=use_kernel,
                                               bound=False)
                    key = f"l{l}/{alg}{'+dvfs' if use_dvfs else ''}"
                    d = out.setdefault(key, {"run": [], "idle": [],
                                             "ovh": [], "viol": 0})
                    d["run"].append(r.e_run)
                    d["idle"].append(r.e_idle)
                    d["ovh"].append(r.e_overhead)
                    d["viol"] += r.violations

    summary = {}
    for key, d in sorted(out.items()):
        summary[key] = {
            "e_run": float(np.mean(d["run"])),
            "e_idle": float(np.mean(d["idle"])),
            "e_overhead": float(np.mean(d["ovh"])),
            "violations": d["viol"],
        }
        if verbose:
            s = summary[key]
            tot = s["e_run"] + s["e_idle"] + s["e_overhead"]
            print(f"{key:16s} run={s['e_run']:.3e} idle={s['e_idle']:.3e} "
                  f"ovh={s['e_overhead']:.3e} total={tot:.3e} "
                  f"viol={s['violations']}")

    # paper §5.4.2: runtime energy saving ~34.7%, l-independent
    for l in ls:
        run_d = summary[f"l{l}/edl+dvfs"]["e_run"]
        run_n = summary[f"l{l}/edl"]["e_run"]
        record(f"online/run_saving_l{l}", 0.0,
               f"{1 - run_d / run_n:.4f} (paper ~0.347)")
    # bin-packing controls turn-on overhead better (paper Fig. 11)
    record("online/overhead_bin_vs_edl_l16", 0.0,
           f"{summary['l16/bin+dvfs']['e_overhead']:.3e} vs "
           f"{summary['l16/edl+dvfs']['e_overhead']:.3e}")
    return summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--kernel", action="store_true",
                    help="route the DVFS solves through the Pallas kernel")
    args = ap.parse_args(argv)
    if args.full:
        run(groups=10, u_off=0.4, u_on=1.6, horizon=1440,
            ls=(1, 2, 4, 8, 16), use_kernel=args.kernel)
    else:
        run(use_kernel=args.kernel)


if __name__ == "__main__":
    main()
