"""The paper's low-overhead claim (§2.1): Algorithm 1's per-task solve must
be cheap enough for instantaneous online decisions.  Measures tasks/second
for the production jnp solver and the Pallas kernel path, plus end-to-end
slots/second of the online simulator."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import record
from repro.core import online, single_task, tasks


def run(n_tasks: int = 4096, verbose: bool = True) -> dict:
    lib = tasks.app_library()
    ts = tasks.generate_offline(n_tasks / 2048.0, seed=0, library=lib)
    allowed = ts.deadline - ts.arrival

    # warmup compiles
    single_task.configure_tasks(ts.params, allowed)
    t0 = time.time()
    single_task.configure_tasks(ts.params, allowed)
    dt_jnp = time.time() - t0
    record("phi/jnp_solver", dt_jnp / len(ts) * 1e6,
           f"{len(ts)/dt_jnp:.0f} tasks/s")

    single_task.configure_tasks(ts.params, allowed, use_kernel=True)
    t0 = time.time()
    single_task.configure_tasks(ts.params, allowed, use_kernel=True)
    dt_k = time.time() - t0
    record("phi/pallas_kernel(interpret)", dt_k / len(ts) * 1e6,
           f"{len(ts)/dt_k:.0f} tasks/s")

    ts_on = tasks.generate_online(0.05, 0.2, seed=0, horizon=400)
    t0 = time.time()
    online.schedule_online(ts_on, l=4, theta=0.9, algorithm="edl")
    dt = time.time() - t0
    record("online/sim_throughput", dt / 400 * 1e6,
           f"{400/dt:.0f} slots/s, {len(ts_on)} tasks")
    return {"jnp_tasks_per_s": len(ts) / dt_jnp}


if __name__ == "__main__":
    run()
