"""The paper's low-overhead claim (§2.1): Algorithm 1's per-task solve must
be cheap enough for instantaneous online decisions.  Measures tasks/second
for the production jnp solver and the Pallas kernel path, plus end-to-end
slots/second of the online simulator — including the paper-scale 10k-task
day-long EDL simulation that the ClusterEngine refactor targets.
"""

from __future__ import annotations

import time

from benchmarks.common import record
from repro.core import online, single_task, tasks

# Wall-clock of the pre-engine (seed, commit 025555f) implementation on the
# 10k-task online EDL simulation below, measured on the reference container:
# per-slot solver dispatches, scalar theta-readjustment solves and python
# object-graph pair selection.  The ClusterEngine + batched-kernel path must
# beat it by >= 5x (it measures ~21x on the same machine).
SEED_10K_EDL_SECONDS = 36.0


def run(n_tasks: int = 4096, verbose: bool = True, full: bool = True) -> dict:
    lib = tasks.app_library()
    ts = tasks.generate_offline(n_tasks / 2048.0, seed=0, library=lib)
    allowed = ts.deadline - ts.arrival

    # warmup compiles.  dedup=False so the timed calls measure the solver,
    # not cache hits (benchmarks/solver_throughput.py measures the cache).
    single_task.configure_tasks(ts.params, allowed, dedup=False)
    t0 = time.perf_counter()
    single_task.configure_tasks(ts.params, allowed, dedup=False)
    dt_jnp = time.perf_counter() - t0
    record("phi/jnp_solver", dt_jnp / len(ts) * 1e6,
           f"{len(ts)/dt_jnp:.0f} tasks/s")

    single_task.configure_tasks(ts.params, allowed, use_kernel=True,
                                dedup=False)
    t0 = time.perf_counter()
    single_task.configure_tasks(ts.params, allowed, use_kernel=True,
                                dedup=False)
    dt_k = time.perf_counter() - t0
    record("phi/pallas_kernel(interpret)", dt_k / len(ts) * 1e6,
           f"{len(ts)/dt_k:.0f} tasks/s")

    # bound=False: this benchmark times the scheduling hot path (the seed
    # baseline below predates e_bound reporting).
    ts_on = tasks.generate_online(0.05, 0.2, seed=0, horizon=400)
    t0 = time.perf_counter()
    online.schedule_online(ts_on, l=4, theta=0.9, algorithm="edl",
                           bound=False)
    dt = time.perf_counter() - t0
    record("online/sim_throughput", dt / 400 * 1e6,
           f"{400/dt:.0f} slots/s, {len(ts_on)} tasks")

    out = {"jnp_tasks_per_s": len(ts) / dt_jnp}

    if full:
        # The acceptance-scale run: ~10k tasks over a 1440-slot day, EDL +
        # theta-readjustment, everything through the Pallas kernel (one
        # pallas_call for the horizon's Algorithm-1 solves, one for the
        # deferred readjustment batch).
        ts_10k = tasks.generate_online(0.4, 4.4, seed=0, library=lib,
                                       horizon=1440)
        t0 = time.perf_counter()
        r = online.schedule_online(ts_10k, l=4, theta=0.9, algorithm="edl",
                                   use_kernel=True, bound=False)
        dt10 = time.perf_counter() - t0
        speedup = SEED_10K_EDL_SECONDS / dt10
        record("online/10k_edl_kernel", dt10 / 1440 * 1e6,
               f"{len(ts_10k)/dt10:.0f} tasks/s, {speedup:.1f}x vs seed")
        out.update({"edl_10k_seconds": dt10, "edl_10k_speedup_vs_seed": speedup,
                    "edl_10k_e_total": r.e_total,
                    "edl_10k_violations": r.violations})
        if verbose:
            print(f"10k-task online EDL (use_kernel=True): {dt10:.2f}s "
                  f"({speedup:.1f}x vs seed {SEED_10K_EDL_SECONDS:.1f}s), "
                  f"e_total={r.e_total:.4e}, violations={r.violations}")
    return out


if __name__ == "__main__":
    run()
