"""The solver throughput layer end to end: rows/second through Algorithm 1
across {dense-unique, trace-duplicated} workloads x {kernel, jnp} solver
paths x {dedup on, off}, plus the kernel's refinement accuracy/time trade.

Two workload shapes bracket reality:

* **dense-unique** — ``tasks.generate_offline_n`` draws a continuous
  utilization per task, so every ``(params, allowed)`` row is unique: the
  dedup layer's worst case (pure overhead; the benchmark reports how
  small).
* **trace-duplicated** — a small base of unique tasks tiled into a long
  trace (recurring jobs, the paper's small-app-library setting): the dedup
  layer's home turf.  With a 2-class mix every task is solved once per
  class, so a 50k-task trace is a 100k-row solver workload.

For each cell the harness measures the direct solver (``dedup=False``),
the dedup layer on a **cold** cache (unique rows still hit the solver) and
on a **warm** cache (every row served from the process-wide LRU), and
asserts the dedup outputs are **bit-identical** to the direct path.

The refinement section rechecks the tentpole claim on the golden task set:
the hierarchical ``(64, 64)`` grid must beat the legacy flat-128-point
sweep (``grid=(128, 2)`` — same coarse resolution, degenerate refinement)
on max relative error vs the jnp oracle at equal-or-lower kernel time.

``--smoke`` is the CI guard (budget + dedup >= 2x on the duplicated trace
+ bit-equality + refinement wins); it also writes the JSON summary to
``BENCH_solver.json`` at the repo root so the perf trajectory is tracked
across PRs.

    PYTHONPATH=src python -m benchmarks.solver_throughput --smoke
    PYTHONPATH=src python -m benchmarks.solver_throughput \\
        --out results/solver_throughput
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional

import numpy as np

from benchmarks.common import record
from repro.core import dvfs, machines, solver_cache, tasks
from repro.core.scheduling import configure_all

#: class mix for the multi-class workloads: every task solved on both the
#: reference 1080Ti box and the v5e box in one stacked dispatch.
MIX = ("gtx-1080ti", "tpu-v5e")

#: unique base tasks behind the trace-duplicated workload (recurring jobs).
BASE_UNIQUE = 512


def _workload(kind: str, n_tasks: int, seed: int = 0):
    """A TaskSet of exactly ``n_tasks`` tasks: all-unique rows
    (``dense-unique``) or ``BASE_UNIQUE`` tasks tiled (``trace-duplicated``)."""
    lib = tasks.app_library()
    if kind == "dense-unique":
        return tasks.generate_offline_n(n_tasks, seed=seed, library=lib)
    base = tasks.generate_offline_n(min(BASE_UNIQUE, n_tasks), seed=seed,
                                    library=lib)
    reps = -(-n_tasks // len(base))
    return base.subset(np.tile(np.arange(len(base)), reps)[:n_tasks])


def _configs_equal(a, b) -> bool:
    """Bitwise TaskConfig equality across a per-class config list."""
    for ca, cb in zip(a, b):
        for fa, fb in zip(ca, cb):
            if isinstance(fa, int):
                if fa != fb:
                    return False
            elif not np.array_equal(np.asarray(fa), np.asarray(fb)):
                return False
    return True


def bench_cell(kind: str, use_kernel: bool, n_tasks: int,
               seed: int = 0, verbose: bool = True) -> Dict:
    """One (workload, solver-path) cell: direct vs dedup-cold vs dedup-warm
    rows/sec, with bit-equality asserted between all three."""
    ts = _workload(kind, n_tasks, seed)
    mcs = machines.resolve_classes(MIX)
    rows = len(ts) * len(mcs)
    path = "kernel" if use_kernel else "jnp"

    def run(dedup: bool):
        return configure_all(ts, True, mcs, use_kernel=use_kernel,
                             dedup=dedup)

    run(dedup=False)                       # compile warm-up, both paths
    run(dedup=True)
    t0 = time.perf_counter()
    ref = run(dedup=False)
    t_direct = time.perf_counter() - t0

    solver_cache.GLOBAL_CACHE.clear()      # cold: unique rows hit the solver
    solver_cache.GLOBAL_CACHE.reset_stats()
    t0 = time.perf_counter()
    cold = run(dedup=True)
    t_cold = time.perf_counter() - t0
    cold_stats = solver_cache.GLOBAL_CACHE.stats()

    t0 = time.perf_counter()                       # warm: every row is a cache hit
    warm = run(dedup=True)
    t_warm = time.perf_counter() - t0

    assert _configs_equal(ref, cold), (kind, path, "cold dedup diverged")
    assert _configs_equal(ref, warm), (kind, path, "warm dedup diverged")

    out = {
        "workload": kind, "path": path, "n_tasks": len(ts),
        "rows": rows, "unique_rows": cold_stats["misses"],
        "direct_s": t_direct, "direct_rows_per_s": rows / t_direct,
        "dedup_cold_s": t_cold, "dedup_cold_rows_per_s": rows / t_cold,
        "dedup_warm_s": t_warm, "dedup_warm_rows_per_s": rows / t_warm,
        "speedup_cold": t_direct / t_cold,
        "speedup_warm": t_direct / t_warm,
        "bit_identical": True,
    }
    if verbose:
        print(f"{kind:16s} {path:6s} rows={rows:7d} "
              f"uniq={out['unique_rows']:6d} direct={t_direct:6.2f}s "
              f"cold={t_cold:6.2f}s ({out['speedup_cold']:5.1f}x) "
              f"warm={t_warm:6.2f}s ({out['speedup_warm']:5.1f}x)",
              flush=True)
    record(f"solver_throughput/{kind}_{path}", t_direct / rows * 1e6,
           f"{rows / t_direct:.0f} rows/s direct, "
           f"{out['speedup_cold']:.1f}x dedup-cold, "
           f"{out['speedup_warm']:.1f}x dedup-warm")
    return out


def bench_refinement(seed: int = 9, verbose: bool = True) -> Dict:
    """Hierarchical (64, 64) grid vs the legacy flat-128 sweep on the golden
    task set: max rel energy error vs the jnp oracle, and kernel time."""
    from repro.kernels import ops, ref

    lib = tasks.generate_offline(0.08, seed=seed)
    allowed = np.asarray(lib.deadline - lib.arrival)
    tasks_mat = np.stack(
        [np.asarray(f, np.float32) for f in lib.params.astuple()]
        + [np.asarray(allowed, np.float32), np.zeros(len(lib), np.float32)],
        axis=1)
    expect = ref.dvfs_solve_ref(tasks_mat)
    keys = solver_cache.build_keys(
        lib.params.astuple(), allowed, False,
        np.asarray(dvfs.WIDE.bounds(), np.float32))

    out: Dict = {"n_golden": len(lib)}
    for label, grid in (("flat128", (128, 2)), ("hier64x64", (64, 64))):
        ops.dvfs_solve_matrix(keys, grid=grid)  # compile warm-up
        t0 = time.perf_counter()
        for _ in range(5):
            sol = ops.dvfs_solve_matrix(keys, grid=grid)
        t_k = (time.perf_counter() - t0) / 5
        rel = float(np.max(np.abs(sol[:, 5] - expect[:, 5]) / expect[:, 5]))
        out[f"{label}_max_rel_err"] = rel
        out[f"{label}_kernel_s"] = t_k
        if verbose:
            print(f"refinement {label:10s} grid={grid}: "
                  f"max_rel_err={rel:.2e} kernel={t_k * 1e3:.1f}ms",
                  flush=True)
    out["err_improvement"] = (out["flat128_max_rel_err"]
                              / max(out["hier64x64_max_rel_err"], 1e-300))
    record("solver_throughput/refinement",
           out["hier64x64_kernel_s"] * 1e6,
           f"err {out['hier64x64_max_rel_err']:.1e} vs flat128 "
           f"{out['flat128_max_rel_err']:.1e} "
           f"({out['err_improvement']:.0f}x tighter)")
    return out


def _write_report(rows: List[Dict], refinement: Dict, out_prefix: str):
    os.makedirs(os.path.dirname(out_prefix) or ".", exist_ok=True)
    payload = {"cells": rows, "refinement": refinement}
    with open(out_prefix + ".json", "w") as f:
        json.dump(payload, f, indent=2)
    cols = ("workload", "path", "rows", "unique_rows", "direct_rows_per_s",
            "dedup_cold_rows_per_s", "dedup_warm_rows_per_s", "speedup_cold",
            "speedup_warm", "bit_identical")
    lines = ["# Solver throughput layer",
             "",
             "rows = tasks x classes through Algorithm 1; `dedup` = the "
             "unique-row dedup + LRU solve cache (`core/solver_cache.py`), "
             "cold (empty cache) and warm (all rows cached).  Outputs are "
             "bit-identical across all columns.",
             "",
             "| " + " | ".join(cols) + " |",
             "|" + "---|" * len(cols)]
    for r in rows:
        cells = []
        for c in cols:
            v = r.get(c)
            cells.append(f"{v:.4g}" if isinstance(v, float) else str(v))
        lines.append("| " + " | ".join(cells) + " |")
    lines += ["",
              f"Refinement (golden set, n={refinement['n_golden']}): "
              f"hier (64,64) max rel err "
              f"{refinement['hier64x64_max_rel_err']:.2e} in "
              f"{refinement['hier64x64_kernel_s'] * 1e3:.1f} ms vs flat-128 "
              f"{refinement['flat128_max_rel_err']:.2e} in "
              f"{refinement['flat128_kernel_s'] * 1e3:.1f} ms "
              f"({refinement['err_improvement']:.0f}x tighter)."]
    with open(out_prefix + ".md", "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {out_prefix}.json and {out_prefix}.md", flush=True)


def _write_summary(rows: List[Dict], refinement: Dict, path: str):
    """The cross-PR tracking file (BENCH_solver.json)."""
    dup_kernel = next((r for r in rows
                       if r["workload"] == "trace-duplicated"
                       and r["path"] == "kernel"), None)
    summary = {
        "benchmark": "solver_throughput",
        "cells": rows,
        "refinement": refinement,
        "headline": {
            "duplicated_kernel_rows_per_s_direct":
                dup_kernel and dup_kernel["direct_rows_per_s"],
            "duplicated_kernel_speedup_cold":
                dup_kernel and dup_kernel["speedup_cold"],
            "duplicated_kernel_speedup_warm":
                dup_kernel and dup_kernel["speedup_warm"],
            "hier_max_rel_err": refinement["hier64x64_max_rel_err"],
            "flat128_max_rel_err": refinement["flat128_max_rel_err"],
        },
    }
    with open(path, "w") as f:
        json.dump(summary, f, indent=2)
    print(f"wrote {path}", flush=True)


def smoke(n_tasks: int, budget: float, min_speedup: float,
          summary: Optional[str]) -> Dict:
    """CI tripwire: on the trace-duplicated ``n_tasks`` x 2-class workload
    the dedup layer must beat the direct kernel path >= ``min_speedup``
    (cold cache) inside ``budget`` seconds, bit-identically; and the
    hierarchical kernel must beat the flat-128 grid on accuracy at
    equal-or-lower time."""
    t0 = time.perf_counter()
    cell = bench_cell("trace-duplicated", use_kernel=True, n_tasks=n_tasks)
    refinement = bench_refinement()
    wall = time.perf_counter() - t0
    assert cell["bit_identical"]
    assert cell["speedup_cold"] >= min_speedup, (
        f"dedup speedup regressed: {cell['speedup_cold']:.2f}x < "
        f"{min_speedup:.1f}x on the duplicated trace (cold cache)")
    assert wall <= budget, f"smoke took {wall:.1f}s (> {budget:.0f}s budget)"
    assert (refinement["hier64x64_max_rel_err"]
            < refinement["flat128_max_rel_err"]), refinement
    assert (refinement["hier64x64_kernel_s"]
            <= refinement["flat128_kernel_s"] * 1.10), (
        "refined kernel slower than the flat-128 sweep: "
        f"{refinement['hier64x64_kernel_s']:.3f}s vs "
        f"{refinement['flat128_kernel_s']:.3f}s")
    print(f"smoke OK: {cell['speedup_cold']:.1f}x >= {min_speedup:.1f}x "
          f"(warm {cell['speedup_warm']:.1f}x), wall {wall:.1f}s <= "
          f"{budget:.0f}s, err {refinement['hier64x64_max_rel_err']:.1e} < "
          f"{refinement['flat128_max_rel_err']:.1e}", flush=True)
    if summary:
        _write_summary([cell], refinement, summary)
    return cell


def run(n_tasks: int = 50000, out: Optional[str] = None,
        summary: Optional[str] = None, verbose: bool = True) -> List[Dict]:
    rows = [bench_cell(kind, uk, n_tasks, verbose=verbose)
            for kind in ("dense-unique", "trace-duplicated")
            for uk in (True, False)]
    refinement = bench_refinement(verbose=verbose)
    if out:
        _write_report(rows, refinement, out)
    if summary:
        _write_summary(rows, refinement, summary)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--tasks", type=int, default=50000,
                    help="tasks per workload (x2 classes = solver rows)")
    ap.add_argument("--out", default="results/solver_throughput",
                    help="JSON/markdown report path prefix")
    ap.add_argument("--summary", default=None,
                    help="also write the cross-PR summary JSON here "
                         "(CI uses BENCH_solver.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: budget + dedup speedup + bit-equality "
                         "+ refinement accuracy")
    ap.add_argument("--budget", type=float, default=300.0,
                    help="--smoke wall-clock cap (s)")
    ap.add_argument("--min-speedup", type=float, default=2.0,
                    help="--smoke minimum cold-cache dedup speedup on the "
                         "duplicated trace")
    args = ap.parse_args(argv)

    if args.smoke:
        smoke(args.tasks, args.budget, args.min_speedup,
              args.summary or "BENCH_solver.json")
        return
    run(args.tasks, out=args.out, summary=args.summary)


if __name__ == "__main__":
    main()
