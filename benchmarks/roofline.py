"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh)
cell from the dry-run JSONs.

    compute term    = HLO_FLOPs / (chips x 197 TFLOP/s)
    memory term     = HLO_bytes / (chips x 819 GB/s)
    collective term = collective_bytes / (chips x 50 GB/s/link)

``cost_analysis()``/HLO shapes on the partitioned module are per-device, so
the per-chip seconds drop out directly (chips cancel).  FLOPs/bytes use the
loop-body-corrected totals (see launch/dryrun.py — XLA counts scan bodies
once); the collective term uses the ring-model wire bytes per device.

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per step; the ratio
MODEL_FLOPS / HLO_FLOPs measures how much compiled compute is "useful"
(remat recompute makes it < 1 by design: fwd+remat+bwd ~ 4/3 overhead on
top of the 6ND convention's fwd+bwd).

Usage::

    PYTHONPATH=src python -m benchmarks.roofline --dir results/dryrun \
        [--mesh single] [--markdown]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from benchmarks.common import record
from repro.configs import registry

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link


def hbm_traffic(arch: str, shape: str, mesh: str, microbatches: int) -> float:
    """Analytic per-chip HBM traffic (bytes/step) for the memory term.

    XLA *CPU* ``bytes accessed`` counts every op's operands at CPU fusion
    granularity — ~10^3x the HBM traffic a TPU pass would see (verified:
    qwen2 train_4k reports 8e13 B/chip where weights+stash+optimizer round
    to ~4e10).  The memory roofline term therefore uses this explicit
    traffic model; the XLA number is kept as a diagnostic upper bound.

    Model (per chip): weight reads (bf16) x3 per microbatch for
    fwd/remat/bwd, f32 grad-accum read+write per microbatch, 7x f32
    optimizer traffic, remat stash write+read, K_ACT=6 residual-stream
    flows per layer per microbatch, chunked-CE logits write+read, and for
    decode the KV-cache/state read + weight read."""
    K_ACT = 6
    cfg = registry.get_config(arch)
    spec = registry.SHAPES[shape]
    chips = chips_of(mesh)
    dp = 32 if mesh == "multi" else 16
    ms = 16  # model shards
    p_local = cfg.param_count() / chips
    d = cfg.d_model
    v_local = cfg.padded_vocab / ms
    if spec.mode == "train":
        m = microbatches
        rows = max(1, spec.global_batch // m // dp)
        act = rows * spec.seq_len * d * 2
        t = (3 * m * p_local * 2                  # weights (bf16 cast reads)
             + 2 * m * p_local * 4                # grad accumulate r+w
             + 7 * p_local * 4                    # adam p/m/v read+write
             + 2 * cfg.n_layers * act * m         # stash write+read
             + K_ACT * cfg.n_layers * act * m     # residual-stream flows
             + 2 * m * rows * spec.seq_len * v_local * 4)   # CE logits
        return t
    if spec.mode == "prefill":
        rows = max(1, spec.global_batch // dp)
        act = rows * spec.seq_len * d * 2
        cache = (cfg.n_layers * rows * (spec.seq_len / ms)
                 * cfg.n_kv_heads * cfg.head_dim_ * 2 * 2)
        return (p_local * 2 + K_ACT * cfg.n_layers * act + cache
                + rows * v_local * 4)
    # decode
    rows = max(1, spec.global_batch // dp)
    if cfg.family == "ssm":
        cache = cfg.n_layers * rows * (
            cfg.n_ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4 * 2
            + (cfg.conv_width - 1) * (cfg.d_inner + 2 * cfg.ssm_state) * 2)
    elif cfg.family == "hybrid":
        w = min(spec.seq_len, cfg.local_window)
        n_attn = cfg.n_layers // 3
        cache = (n_attn * rows * (w / ms) * cfg.n_kv_heads
                 * cfg.head_dim_ * 2 * 2
                 + (cfg.n_layers - n_attn) * rows * cfg.rnn_width_ * 4 * 2)
    else:
        w = min(spec.seq_len, cfg.sliding_window or spec.seq_len)
        cache = (cfg.n_layers * rows * (w / ms) * cfg.n_kv_heads
                 * cfg.head_dim_ * 2 * 2)
    active_params = cfg.param_count(active_only=True) / chips
    return active_params * 2 + cache + rows * v_local * 4


def model_flops(arch: str, shape: str) -> float:
    cfg = registry.get_config(arch)
    spec = registry.SHAPES[shape]
    n_active = cfg.param_count(active_only=True)
    if spec.mode == "train":
        tokens = spec.global_batch * spec.seq_len
        return 6.0 * n_active * tokens
    if spec.mode == "prefill":
        tokens = spec.global_batch * spec.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * spec.global_batch  # decode: one token per row


def chips_of(mesh: str) -> int:
    return 512 if mesh == "multi" else 256


def analyze(rec: Dict) -> Optional[Dict]:
    if not rec.get("ok"):
        return None
    corr = rec.get("corrected")
    full = rec["full"]
    # The probe correction can go slightly negative when XLA dedups
    # collectives differently between the 1-unit and 2-unit probes
    # (CSE noise); clamp at the uncorrected full-program floor.
    flops = max(corr["flops"], full["cost"]["flops"]) if corr \
        else full["cost"]["flops"]
    xla_bytes = (max(corr["bytes_accessed"], 0.0) if corr
                 else full["cost"]["bytes_accessed"])
    hbytes = hbm_traffic(rec["arch"], rec["shape"], rec["mesh"],
                         rec.get("microbatches", 1))
    cbytes = (max(corr["collective_wire_bytes"],
                  full["collectives"]["ring_wire_bytes"]) if corr
              else full["collectives"]["ring_wire_bytes"])
    t_c = flops / PEAK_FLOPS
    t_m = hbytes / HBM_BW
    t_x = cbytes / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    step = max(t_c, t_m, t_x)
    mf = model_flops(rec["arch"], rec["shape"]) / chips_of(rec["mesh"])
    useful = mf / max(flops, 1e-30)
    # roofline fraction: useful work rate vs the peak the dominant
    # resource allows
    frac = (mf / PEAK_FLOPS) / max(step, 1e-30)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "mode": rec["mode"], "tag": rec.get("tag", "baseline"),
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "bottleneck": dom, "step_s": step,
        "model_flops_per_chip": mf, "hlo_flops": flops,
        "xla_bytes_diag": xla_bytes,
        "useful_ratio": useful, "roofline_frac": frac,
        "mem_live_gib": full["memory"]["live_bytes"] / 2**30,
        "napkin_gib": rec.get("hbm_napkin", {}).get("total", 0) / 2**30,
        "microbatches": rec.get("microbatches", 1),
    }


def load(dir_: str, mesh: Optional[str] = None,
         tag: str = "baseline") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        rec = json.load(open(path))
        if mesh and rec.get("mesh") != mesh:
            continue
        if tag and rec.get("tag", "baseline") != tag:
            continue
        row = analyze(rec)
        if row:
            rows.append(row)
    return rows


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def table(rows: List[Dict], markdown: bool = False) -> str:
    hdr = ["arch", "shape", "mesh", "compute", "memory", "collective",
           "bottleneck", "MF/HLO", "roofline%", "mem GiB", "mb"]
    lines = []
    if markdown:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    else:
        lines.append(",".join(hdr))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        cells = [r["arch"], r["shape"], r["mesh"], fmt_s(r["compute_s"]),
                 fmt_s(r["memory_s"]), fmt_s(r["collective_s"]),
                 r["bottleneck"], f"{r['useful_ratio']:.2f}",
                 f"{100*r['roofline_frac']:.1f}",
                 f"{r['mem_live_gib']:.1f}", str(r["microbatches"])]
        if markdown:
            lines.append("| " + " | ".join(cells) + " |")
        else:
            lines.append(",".join(cells))
    return "\n".join(lines)


def run(dir_: str = "results/dryrun", mesh: Optional[str] = "single",
        markdown: bool = False, tag: str = "baseline",
        verbose: bool = True) -> List[Dict]:
    rows = load(dir_, mesh, tag)
    if verbose:
        print(table(rows, markdown))
    if rows:
        worst = min(rows, key=lambda r: r["roofline_frac"])
        record("roofline/cells_analyzed", 0.0, str(len(rows)))
        record("roofline/worst_cell", 0.0,
               f"{worst['arch']}/{worst['shape']} "
               f"frac={worst['roofline_frac']:.3f}")
        for b in ("compute", "memory", "collective"):
            n = sum(1 for r in rows if r["bottleneck"] == b)
            record(f"roofline/bottleneck_{b}", 0.0, str(n))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "all"])
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args(argv)
    run(args.dir, None if args.mesh == "all" else args.mesh,
        args.markdown, args.tag)


if __name__ == "__main__":
    main()
