"""Scenario-sweep harness: the paper's §5 trends across every scenario axis.

Sweeps the grid

    U_J (task-set utilization) x rho (DRS idle threshold) x
    Delta (turn-on overhead scale) x scaling interval x machine-class mix

and emits a JSON + markdown report under ``--out``.  The two *interval
settings* bundle the paper's two calibrations (§5.2):

* ``wide``   — the analytic interval (:data:`repro.core.dvfs.WIDE`) with the
  published shrunk-static fit ranges: single-task saving anchor ~36.4%
  (Fig. 4);
* ``narrow`` — the realistic GTX-1080Ti interval
  (:data:`repro.core.dvfs.NARROW`) with the measured whole-system static
  share (``tasks.REALISTIC_P0``): anchor ~4.3%.

Each cell reports the offline EDL saving vs the no-DVFS baseline (Figs. 5-8
axis) and the online EDL total-energy reduction (Figs. 10-13 axis), per
class mix — the reference homogeneous mix plus heterogeneous mixes from the
:mod:`repro.core.machines` registry.  rho and Delta only act through the
online DRS, so they are swept on the online half of the grid only.

    PYTHONPATH=src python -m benchmarks.scenario_sweep [--full] [--kernel] \
        [--out results/scenario_sweep]

CI default is a minutes-sized grid (2 mixes x 2 intervals x 2 rho x 2
Delta); ``--full`` widens every axis toward the paper's scale.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Dict

import numpy as np

from benchmarks.common import record
from repro.core import (cluster as cl, dvfs, machines, online, scheduling,
                        single_task, solver_cache, tasks)

#: interval setting -> (ScalingInterval, app-library static-share range,
#: paper anchor for the mean single-task saving)
INTERVAL_SETTINGS = {
    "wide": (dvfs.WIDE, (0.20, 0.41), 0.364),
    "narrow": (dvfs.NARROW, tasks.REALISTIC_P0, 0.043),
}

DEFAULT_MIXES = (
    ("gtx-1080ti",),
    ("gtx-1080ti", "tpu-v5e"),
)
FULL_MIXES = DEFAULT_MIXES + (("gtx-1080ti", "tpu-v5e", "v100-sxm2"),)


def _scaled_mix(names, delta_scale: float):
    """The mix with every class's turn-on overhead scaled by ``delta_scale``
    (the Delta axis of the grid)."""
    mcs = machines.get_classes(names)
    if delta_scale == 1.0:
        return mcs
    return tuple(dataclasses.replace(mc, delta_on=mc.delta_on * delta_scale)
                 for mc in mcs)


def single_task_anchor(library, interval) -> float:
    """Mean unconstrained single-task saving on the reference class — the
    Fig. 4 number every scheduling trend hangs off."""
    sol = single_task.solve_unconstrained(library, interval)
    saving = 1.0 - np.asarray(sol.energy) / np.asarray(library.default_energy())
    return float(np.mean(saving))


def run(groups: int = 1, utils=(0.2, 0.4), rhos=(1, 2),
        delta_scales=(0.5, 1.0), intervals=("wide", "narrow"),
        mixes=DEFAULT_MIXES, theta: float = 0.9,
        u_off: float = 0.02, u_on: float = 0.05, horizon: int = 200,
        l: int = 2, use_kernel: bool = False, verbose: bool = True) -> Dict:
    report: Dict = {
        "meta": dict(groups=groups, utils=list(utils), rhos=list(rhos),
                     delta_scales=list(delta_scales),
                     intervals=list(intervals),
                     mixes=["+".join(m) for m in mixes], theta=theta,
                     u_off=u_off, u_on=u_on, horizon=horizon, l=l,
                     use_kernel=use_kernel),
        "anchors": {},
        "offline": [],
        "online": [],
    }
    # The rho x Delta (and seed-group) cells of one (interval, mix) re-solve
    # identical (params, allowed) rows; the process-wide solve cache serves
    # them after the first cell.  Snapshot the lifetime counters so the
    # hit-rate below is this sweep's own cross-cell reuse
    # (``schedule_online`` resets the per-run counters at every call).
    cache_base = solver_cache.GLOBAL_CACHE.stats()

    for iv_name in intervals:
        interval, p0_frac, paper_anchor = INTERVAL_SETTINGS[iv_name]
        lib = tasks.app_library(p0_frac=p0_frac)
        anchor = single_task_anchor(lib, interval)
        report["anchors"][iv_name] = {
            "single_task_saving": anchor, "paper": paper_anchor}
        if verbose:
            print(f"[{iv_name}] single-task anchor saving: {anchor:.3f} "
                  f"(paper ~{paper_anchor})")

        for mix in mixes:
            mix_name = "+".join(mix)
            mcs = machines.get_classes(mix)

            # ---- offline half: U_J axis (rho/Delta do not act offline).
            for u in utils:
                savings, viols, pairs = [], 0, []
                for seed in range(groups):
                    ts = tasks.generate_offline(u, seed=seed, library=lib)
                    base = cl.baseline_energy(ts)
                    # bound=False across the grid: e_bound only depends on
                    # (task_set, classes, interval), not the swept knobs.
                    r = scheduling.schedule_offline(
                        ts, l=l, theta=theta, algorithm="edl",
                        interval=interval, classes=mcs,
                        use_kernel=use_kernel, bound=False)
                    savings.append(1 - r.e_total / base)
                    viols += r.violations
                    pairs.append(r.n_pairs)
                row = dict(interval=iv_name, mix=mix_name, u=u,
                           saving=float(np.mean(savings)), violations=viols,
                           pairs=float(np.mean(pairs)))
                report["offline"].append(row)
                if verbose:
                    print(f"  offline {mix_name:28s} U={u:<4} "
                          f"saving={row['saving']:+.3f} viol={viols}")

            # ---- online half: rho x Delta axes.
            for rho in rhos:
                for ds in delta_scales:
                    mcs_d = _scaled_mix(mix, ds)
                    reds, viols = [], 0
                    for seed in range(groups):
                        ts = tasks.generate_online(u_off, u_on, seed=seed,
                                                   library=lib,
                                                   horizon=horizon)
                        rb = online.schedule_online(
                            ts, l=l, theta=1.0, algorithm="edl",
                            use_dvfs=False, rho=rho, classes=mcs_d,
                            bound=False)
                        rd = online.schedule_online(
                            ts, l=l, theta=theta, algorithm="edl",
                            use_dvfs=True, interval=interval, rho=rho,
                            classes=mcs_d, use_kernel=use_kernel,
                            bound=False)
                        reds.append(1 - rd.e_total / rb.e_total)
                        viols += rd.violations
                    row = dict(interval=iv_name, mix=mix_name, rho=rho,
                               delta_scale=ds,
                               reduction=float(np.mean(reds)),
                               violations=viols)
                    report["online"].append(row)
                    if verbose:
                        print(f"  online  {mix_name:28s} rho={rho} "
                              f"Deltax{ds:<4} reduction="
                              f"{row['reduction']:+.3f} viol={viols}")

    for iv_name in intervals:
        a = report["anchors"][iv_name]
        record(f"scenario/{iv_name}_anchor", 0.0,
               f"{a['single_task_saving']:.4f} (paper ~{a['paper']})")
    now = solver_cache.GLOBAL_CACHE.stats()
    hits = now["hits_total"] - cache_base["hits_total"]
    misses = now["misses_total"] - cache_base["misses_total"]
    stats = {"hits": hits, "misses": misses,
             "hit_rate": hits / (hits + misses) if hits + misses else 0.0}
    report["meta"]["solve_cache"] = stats
    record("scenario/solve_cache", 0.0,
           f"hit_rate {stats['hit_rate']:.3f} ({stats['hits']} hits / "
           f"{stats['misses']} misses)")
    if verbose:
        print(f"solve-cache cross-cell reuse: {stats['hit_rate']:.1%} "
              f"({stats['hits']} hits, {stats['misses']} misses)")
    return report


def to_markdown(report: Dict) -> str:
    """Render the sweep report as a standalone markdown document."""
    m = report["meta"]
    lines = [
        "# Scenario sweep report",
        "",
        f"Grid: U_J={m['utils']} x rho={m['rhos']} x "
        f"Delta-scale={m['delta_scales']} x intervals={m['intervals']} x "
        f"mixes={m['mixes']} (theta={m['theta']}, l={m['l']}, "
        f"{m['groups']} seed group(s), kernel={m['use_kernel']})",
        "",
        "## Single-task anchors (paper Fig. 4 / §5.2)",
        "",
        "| interval | mean saving | paper |",
        "|---|---|---|",
    ]
    if "solve_cache" in m:
        s = m["solve_cache"]
        lines[4:4] = [f"Solve-cache cross-cell reuse: {s['hit_rate']:.1%} "
                      f"({s['hits']} hits / {s['misses']} misses).", ""]
    for iv, a in report["anchors"].items():
        lines.append(f"| {iv} | {a['single_task_saving']:.1%} "
                     f"| ~{a['paper']:.1%} |")
    lines += [
        "",
        "## Offline EDL saving vs no-DVFS baseline (Figs. 5-8 axis)",
        "",
        "| interval | class mix | U_J | saving | violations |",
        "|---|---|---|---|---|",
    ]
    for r in report["offline"]:
        lines.append(f"| {r['interval']} | {r['mix']} | {r['u']} "
                     f"| {r['saving']:+.1%} | {r['violations']} |")
    lines += [
        "",
        "## Online EDL total-energy reduction (Figs. 10-13 axis)",
        "",
        "| interval | class mix | rho | Delta scale | reduction "
        "| violations |",
        "|---|---|---|---|---|---|",
    ]
    for r in report["online"]:
        lines.append(f"| {r['interval']} | {r['mix']} | {r['rho']} "
                     f"| x{r['delta_scale']} | {r['reduction']:+.1%} "
                     f"| {r['violations']} |")
    lines.append("")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--full", action="store_true",
                    help="paper-scale axes (slow); default is CI-sized")
    ap.add_argument("--kernel", action="store_true",
                    help="route every DVFS solve through the Pallas kernel")
    ap.add_argument("--theta", type=float, default=0.9)
    ap.add_argument("--out", default="results/scenario_sweep",
                    help="directory for scenario_sweep.{json,md}")
    args = ap.parse_args(argv)

    if args.full:
        report = run(groups=5, utils=(0.2, 0.4, 0.8, 1.6),
                     rhos=(1, 2, 4), delta_scales=(0.5, 1.0, 2.0),
                     mixes=FULL_MIXES, theta=args.theta,
                     u_off=0.4, u_on=1.6, horizon=1440,
                     use_kernel=args.kernel)
    else:
        report = run(theta=args.theta, use_kernel=args.kernel)

    os.makedirs(args.out, exist_ok=True)
    jpath = os.path.join(args.out, "scenario_sweep.json")
    mpath = os.path.join(args.out, "scenario_sweep.md")
    with open(jpath, "w") as f:
        json.dump(report, f, indent=2)
    with open(mpath, "w") as f:
        f.write(to_markdown(report))
    print(f"report: {jpath} + {mpath}")


if __name__ == "__main__":
    main()
