"""Paper §5.2 / Fig. 4: single-task DVFS optimum over the 20-app library.

Reports, per application: optimal (V, fc, fm) and the energy saving, for
both the wide (simulation) and narrow (measured GTX-1080Ti) scaling
intervals — plus the realistic-static-share variant that reproduces the
paper's ~4.3% narrow-interval measurement.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import record, timed
from repro.core import dvfs, single_task, tasks


def run(verbose: bool = True) -> dict:
    lib = tasks.app_library()

    def solve(interval):
        return single_task.solve_unconstrained(lib, interval)

    sol_w = timed("single_task/wide_solve_20apps", lambda: solve(dvfs.WIDE),
                  repeats=3)
    e_star = np.asarray(lib.default_energy())
    sav_w = 1 - np.asarray(sol_w.energy) / e_star

    sol_n = solve(dvfs.NARROW)
    sav_n = 1 - np.asarray(sol_n.energy) / e_star

    lib_r = tasks.app_library(p0_frac=tasks.REALISTIC_P0)
    sol_r = single_task.solve_unconstrained(lib_r, dvfs.NARROW)
    sav_r = 1 - np.asarray(sol_r.energy) / np.asarray(lib_r.default_energy())

    if verbose:
        print("app, delta, V*, fc*, fm*, saving_wide, saving_narrow")
        for i in range(20):
            print(f"{i:3d}, {float(np.asarray(lib.delta)[i]):.2f}, "
                  f"{float(np.asarray(sol_w.v)[i]):.3f}, "
                  f"{float(np.asarray(sol_w.fc)[i]):.3f}, "
                  f"{float(np.asarray(sol_w.fm)[i]):.3f}, "
                  f"{sav_w[i]:.3f}, {sav_n[i]:.3f}")
    out = {
        "mean_saving_wide": float(np.mean(sav_w)),          # paper: 0.364
        "mean_saving_narrow_fitlib": float(np.mean(sav_n)),
        "mean_saving_narrow_realistic": float(np.mean(sav_r)),  # paper: 0.043
        "core_voltage_near_floor": float(np.mean(
            np.asarray(sol_w.v) < 0.6)),  # paper: optima near lowest V
    }
    record("single_task/mean_saving_wide", 0.0,
           f"{out['mean_saving_wide']:.4f} (paper 0.364)")
    record("single_task/mean_saving_narrow_realistic", 0.0,
           f"{out['mean_saving_narrow_realistic']:.4f} (paper 0.043)")
    return out


if __name__ == "__main__":
    print(run())
