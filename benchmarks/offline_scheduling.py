"""Paper §5.3 / Figs. 5-8: offline scheduling energy across task-set
utilization, server width l, four algorithms, ±DVFS.

Defaults are CI-sized (3 groups per point, U_J up to 0.8); ``--full``
reproduces the paper's axes (100 groups, U_J up to 1.6) given the time.
"""

from __future__ import annotations

import argparse
from typing import Dict

import numpy as np

from benchmarks.common import record
from repro.core import cluster as cl, scheduling, tasks

ALGOS = ("edl", "edf-bf", "edf-wf", "lpt-ff")


def run(groups: int = 3, utils=(0.2, 0.4, 0.8), ls=(1, 4, 16),
        theta: float = 1.0, verbose: bool = True,
        use_kernel: bool = False) -> Dict:
    lib = tasks.app_library()
    out: Dict[str, Dict] = {}
    for u in utils:
        for seed in range(groups):
            ts = tasks.generate_offline(u, seed=seed, library=lib)
            base = cl.baseline_energy(ts)
            for l in ls:
                for alg in ALGOS:
                    for use_dvfs in (False, True):
                        # bound=False: e_bound is (task_set)-invariant
                        # across the swept (l, alg, dvfs) axes.
                        r = scheduling.schedule_offline(
                            ts, l=l, theta=theta, algorithm=alg,
                            use_dvfs=use_dvfs, use_kernel=use_kernel,
                            bound=False)
                        key = f"U{u}/l{l}/{alg}{'+dvfs' if use_dvfs else ''}"
                        d = out.setdefault(key, {
                            "e_total": [], "saving": [], "pairs": [],
                            "violations": 0})
                        d["e_total"].append(r.e_total)
                        d["saving"].append(1 - r.e_total / base)
                        d["pairs"].append(r.n_pairs)
                        d["violations"] += r.violations

    summary = {}
    for key, d in sorted(out.items()):
        summary[key] = {
            "e_total_mean": float(np.mean(d["e_total"])),
            "saving_mean": float(np.mean(d["saving"])),
            "pairs_mean": float(np.mean(d["pairs"])),
            "violations": d["violations"],
        }
        if verbose:
            s = summary[key]
            print(f"{key:30s} saving={s['saving_mean']:+.3f} "
                  f"pairs={s['pairs_mean']:7.1f} viol={s['violations']}")

    # headline rows (paper: ~33.5% at l=1 with DVFS)
    edl_l1 = [v["saving_mean"] for k, v in summary.items()
              if "/l1/edl+dvfs" in k]
    record("offline/edl_dvfs_l1_saving", 0.0,
           f"{float(np.mean(edl_l1)):.4f} (paper ~0.335)")
    # baseline energies algorithm-independent (paper Fig. 5a overlap):
    # compare the four algorithms at the SAME utilization.
    spreads = []
    for u in utils:
        base_e = [v["e_total_mean"] for k, v in summary.items()
                  if k.startswith(f"U{u}/l1/") and "+dvfs" not in k]
        if base_e:
            spreads.append(np.std(base_e) / np.mean(base_e))
    record("offline/baseline_overlap", 0.0,
           f"rel_spread={max(spreads):.2e}")
    return summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--theta", type=float, default=1.0)
    ap.add_argument("--kernel", action="store_true",
                    help="route Algorithm 1 through the Pallas kernel")
    args = ap.parse_args(argv)
    if args.full:
        run(groups=100, utils=(0.2, 0.4, 0.6, 0.8, 1.0, 1.2, 1.4, 1.6),
            ls=(1, 2, 4, 8, 16), theta=args.theta, use_kernel=args.kernel)
    else:
        run(theta=args.theta, use_kernel=args.kernel)


if __name__ == "__main__":
    main()
