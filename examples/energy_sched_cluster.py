"""Flagship example: the paper's technique as a first-class framework
feature — DVFS-aware, deadline-constrained scheduling of a DAY of LM
training/serving jobs on a TPU fleet.

The pipeline (DESIGN.md §2-3):

1. Each job is N steps of an (architecture x shape) cell; its DVFS model
   parameters are derived from the ROOFLINE ANALYSIS of the compiled
   dry-run (no profiling pass):
       delta := T_compute / (T_compute + T_memory)   (core-freq sensitivity)
       t0    >= collective share of the step          (freq-insensitive)
2. The resulting task set feeds the SAME online EDL θ-readjustment
   scheduler the paper evaluates on GPU benchmark traces.
3. Output: fleet energy saving vs the no-DVFS baseline, per-job settings.

Homogeneous fleet (the default)::

    PYTHONPATH=src python examples/energy_sched_cluster.py \
        [--dryrun-dir results/dryrun] [--jobs 400]

Heterogeneous fleet — schedule the same day across a machine-class mix
from the ``repro.core.machines`` registry; the scheduler solves each job's
DVFS optimum on every class and sends it to the min-energy feasible one
(per-class assignment counts are printed at the end)::

    PYTHONPATH=src python examples/energy_sched_cluster.py \
        --classes gtx-1080ti,tpu-v5e,v100-sxm2

Falls back to a representative synthetic roofline table if the dry-run
JSONs are absent.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import online, tasks
from repro.core.jobs import RooflineTerms, jobs_to_task_set, synth_job_stream

FALLBACK = {
    "qwen2-72b/train_4k": RooflineTerms("qwen2-72b", "train_4k",
                                        3.2, 1.1, 0.6),
    "qwen2-72b/decode_32k": RooflineTerms("qwen2-72b", "decode_32k",
                                          0.02, 0.35, 0.04),
    "mamba2-370m/train_4k": RooflineTerms("mamba2-370m", "train_4k",
                                          0.5, 0.4, 0.05),
    "qwen3-moe-30b-a3b/train_4k": RooflineTerms("qwen3-moe-30b-a3b",
                                                "train_4k", 0.9, 0.7, 0.5),
    "recurrentgemma-2b/long_500k": RooflineTerms("recurrentgemma-2b",
                                                 "long_500k", 0.01, 0.2,
                                                 0.01),
}


def load_roofline(dir_: str):
    try:
        from benchmarks.roofline import load
        rows = load(dir_, mesh="single")
    except Exception:
        rows = []
    if not rows:
        return FALLBACK
    return {f"{r['arch']}/{r['shape']}": RooflineTerms(
        r["arch"], r["shape"], r["compute_s"], r["memory_s"],
        r["collective_s"]) for r in rows}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--jobs", type=int, default=400)
    ap.add_argument("--l", type=int, default=4,
                    help="accelerator slices per power domain")
    ap.add_argument("--theta", type=float, default=0.9)
    ap.add_argument("--horizon", type=int, default=720)
    ap.add_argument("--classes", default=None,
                    help="comma-separated machine-class mix from the "
                         "repro.core.machines registry, e.g. "
                         "gtx-1080ti,tpu-v5e (default: homogeneous)")
    args = ap.parse_args()
    mix = args.classes.split(",") if args.classes else None

    terms = load_roofline(args.dryrun_dir)
    print(f"[fleet] roofline table: {len(terms)} cells "
          f"({'dry-run' if terms is not FALLBACK else 'fallback'})")
    jobs = synth_job_stream(terms, n_jobs=args.jobs, horizon=args.horizon,
                            seed=0)
    ts = jobs_to_task_set(jobs)
    deltas = np.asarray(ts.params.delta)
    print(f"[fleet] {len(ts)} jobs; delta range "
          f"[{deltas.min():.2f}, {deltas.max():.2f}] "
          f"(memory-bound decode ... compute-bound train)")

    if mix:
        print(f"[fleet] heterogeneous mix: {', '.join(mix)}")
    r_dvfs = online.schedule_online(ts, l=args.l, theta=args.theta,
                                    algorithm="edl", use_dvfs=True,
                                    classes=mix)
    r_base = online.schedule_online(ts, l=args.l, theta=1.0,
                                    algorithm="edl", use_dvfs=False,
                                    classes=mix)
    print(f"[fleet] no-DVFS  : E_run={r_base.e_run:.3e} "
          f"E_idle={r_base.e_idle:.3e} E_ovh={r_base.e_overhead:.3e} "
          f"(pairs={r_base.n_pairs})")
    print(f"[fleet] DVFS+EDL : E_run={r_dvfs.e_run:.3e} "
          f"E_idle={r_dvfs.e_idle:.3e} E_ovh={r_dvfs.e_overhead:.3e} "
          f"(pairs={r_dvfs.n_pairs}, violations={r_dvfs.violations})")
    print(f"[fleet] runtime-energy saving: "
          f"{1 - r_dvfs.e_run / r_base.e_run:.1%}")
    print(f"[fleet] total-energy saving:   "
          f"{1 - r_dvfs.e_total / r_base.e_total:.1%}")

    # per-kind settings summary: what the scheduler actually dialed in
    by_cell = {}
    for a in r_dvfs.assignments:
        j = jobs[a.task]
        by_cell.setdefault(f"{j.arch}/{j.shape}", []).append(
            (a.fc, a.fm, a.v))
    print("[fleet] mean chosen (fc, fm) per cell kind:")
    for cell, rows in sorted(by_cell.items()):
        rows = np.asarray(rows)
        print(f"    {cell:34s} fc={rows[:,0].mean():.2f} "
              f"fm={rows[:,1].mean():.2f} (n={len(rows)})")

    if mix:
        counts = np.bincount([a.class_id for a in r_dvfs.assignments],
                             minlength=len(mix))
        print("[fleet] jobs per machine class:")
        for name, cnt in zip(mix, counts):
            print(f"    {name:20s} {int(cnt)}")


if __name__ == "__main__":
    main()
