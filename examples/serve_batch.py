"""Batched serving example: prefill a batch of prompts, decode with the
slot server, report tokens/s (deliverable b, serving flavor).

    PYTHONPATH=src python examples/serve_batch.py --arch mamba2-370m
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--preset", "smoke",
                "--requests", str(args.requests), "--gen", str(args.gen)])


if __name__ == "__main__":
    main()
