"""Quickstart: the three public surfaces of the framework in ~60 lines.

1. The paper's core — optimal DVFS setting for one task, then an EDL
   θ-readjustment schedule for a small cluster batch.
2. The LM stack — one training step of an assigned architecture (reduced).
3. One decode step through the same model's serving path.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

# --- 1. the paper's core ----------------------------------------------------
from repro.core import cluster, scheduling, single_task, tasks
from repro.core.dvfs import DvfsParams

task = DvfsParams(p0=100.0, gamma=50.0, c=150.0, big_d=25.0, delta=0.5,
                  t0=5.0)
batched = DvfsParams(*(np.asarray([f]) for f in task.astuple()))
sol = single_task.solve_unconstrained(batched)
print(f"[dvfs] optimal setting: V={float(sol.v[0]):.3f} "
      f"fc={float(sol.fc[0]):.3f} fm={float(sol.fm[0]):.3f} -> "
      f"E={float(sol.energy[0]):.1f} J "
      f"(default {float(task.default_energy()):.1f} J)")

ts = tasks.generate_offline(0.05, seed=0)
r = scheduling.schedule_offline(ts, l=2, theta=0.9, algorithm="edl")
base = cluster.baseline_energy(ts)
print(f"[sched] {len(ts)} tasks -> {r.n_pairs} pairs / {r.n_servers} "
      f"servers, saving {1 - r.e_total / base:.1%} vs no-DVFS "
      f"(violations={r.violations})")

# --- 2. one training step ----------------------------------------------------
from repro.configs import get_config
from repro.data.pipeline import SyntheticLMData
from repro.models.model import Model
from repro.optim.adamw import AdamW
from repro.train.trainer import init_state, make_train_step

cfg = get_config("qwen3-moe-30b-a3b").reduced()
model = Model(cfg)
opt = AdamW(learning_rate=1e-3)
state = init_state(model, opt, jax.random.key(0))
step = make_train_step(model, opt, param_axes=model.param_axes())
data = SyntheticLMData.for_config(cfg, seq_len=64, global_batch=4)
state, metrics = step(state, {k: jnp.asarray(v)
                              for k, v in data.batch(0).items()})
print(f"[train] {cfg.name}: loss={float(metrics['loss']):.3f} "
      f"grad_norm={float(metrics['grad_norm']):.3f}")

# --- 3. one decode step --------------------------------------------------------
prompt = jnp.asarray(np.random.default_rng(0).integers(
    1, cfg.vocab_size, (2, 8)), jnp.int32)
logits, cache = model.prefill(state.params, {"tokens": prompt}, max_seq=32)
tok = jnp.argmax(logits, -1).astype(jnp.int32)
logits, cache = model.decode_step(state.params, cache, tok, jnp.asarray(8))
print(f"[serve] decoded 1 token/seq, logits shape={logits.shape}")
