"""End-to-end training driver: a ~100M-parameter model for a few hundred
steps with checkpointing, failure recovery and metrics (deliverable b).

    PYTHONPATH=src python examples/train_100m.py --steps 300

CPU-budget note: a full 300-step run at the default sizes is hours on this
single-core container; `--steps 30` demonstrates the same loop (loss on the
induction/copy task falls well below the unigram floor either way).
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-12b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()
    train_main(["--arch", args.arch, "--preset", "100m",
                "--steps", str(args.steps), "--batch", str(args.batch),
                "--seq", str(args.seq), "--lr", "3e-3",
                "--checkpoint-dir", "/tmp/repro_100m_ckpt",
                "--checkpoint-every", "50",
                "--metrics", "/tmp/repro_100m_metrics.jsonl"])


if __name__ == "__main__":
    main()
