"""Fault-tolerant training loop: checkpoint/restart, failure recovery,
straggler watchdog.

The loop is the part of the stack that must survive a 1000-node fleet:

* **Checkpoint/restart** — periodic async checkpoints; on (re)start the
  loop restores the latest complete checkpoint and resumes from its step;
  the data pipeline is keyed by step so the replayed stream is exact.
* **Failure recovery** — any exception from the step function (device
  loss, preemption; simulated in tests via ``failure_hook``) triggers
  restore-from-latest + retry, up to ``max_recoveries``.
* **Straggler watchdog** — an EWMA of step wall-time; steps slower than
  ``straggler_factor`` x EWMA are counted and surfaced in metrics.  On a
  real fleet this signal feeds the scheduler's DRS/hot-swap decision (the
  paper's θ-readjustment consumes exactly this kind of runtime signal).
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Callable, Dict, Optional

from repro.checkpoint.store import CheckpointStore


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep: int = 3
    straggler_factor: float = 3.0
    max_recoveries: int = 5
    log_every: int = 10
    metrics_path: Optional[str] = None


def run_loop(step_fn: Callable, state, data, cfg: LoopConfig, *,
             state_shardings=None,
             put_batch: Callable = None,
             failure_hook: Callable[[int], None] = None,
             log: Callable[[str], None] = print) -> Dict[str, Any]:
    """Run ``state = step_fn(state, batch)`` for ``cfg.total_steps``.

    ``data.batch(step)`` supplies batches; ``failure_hook(step)`` (tests)
    may raise to simulate node failure.  Returns the final state and
    summary stats."""
    store = (CheckpointStore(cfg.checkpoint_dir, cfg.keep)
             if cfg.checkpoint_dir else None)
    start = 0
    if store and store.latest_step() is not None:
        state = store.restore(state, shardings=state_shardings)
        start = int(store.latest_step()) + 1
        log(f"[loop] restored checkpoint, resuming at step {start}")

    ewma = None
    stragglers = 0
    recoveries = 0
    losses = []
    metrics_f = open(cfg.metrics_path, "a") if cfg.metrics_path else None

    step = start
    while step < cfg.total_steps:
        try:
            if failure_hook is not None:
                failure_hook(step)
            batch = data.batch(step)
            if put_batch is not None:
                batch = put_batch(batch)
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            if ewma is None:
                ewma = dt
            elif dt > cfg.straggler_factor * ewma and step > start + 2:
                stragglers += 1
                log(f"[loop] step {step}: straggler ({dt:.2f}s vs "
                    f"EWMA {ewma:.2f}s)")
            ewma = 0.9 * ewma + 0.1 * dt if ewma else dt
            losses.append(loss)
            if metrics_f:
                row = {"step": step, "loss": loss, "time_s": dt}
                row.update({k: float(v) for k, v in metrics.items()
                            if k != "loss"})
                metrics_f.write(json.dumps(row) + "\n")
                metrics_f.flush()
            if cfg.log_every and step % cfg.log_every == 0:
                log(f"[loop] step {step}: loss={loss:.4f} ({dt:.2f}s)")
            if store and cfg.checkpoint_every and \
                    step % cfg.checkpoint_every == 0 and step > start:
                store.save(step, state)
            step += 1
        except KeyboardInterrupt:
            raise
        except Exception as e:  # noqa: BLE001 — node-failure surface
            recoveries += 1
            if recoveries > cfg.max_recoveries or store is None:
                raise
            log(f"[loop] step {step}: FAILURE {type(e).__name__}: {e}; "
                f"restoring latest checkpoint "
                f"({recoveries}/{cfg.max_recoveries})")
            if store.latest_step() is not None:
                state = store.restore(state, shardings=state_shardings)
                step = int(store.latest_step()) + 1
            else:
                step = start  # nothing saved yet: restart from scratch

    if store:
        store.save(step - 1, state, blocking=True)
    if metrics_f:
        metrics_f.close()
    return {"state": state, "losses": losses, "stragglers": stragglers,
            "recoveries": recoveries, "final_step": step}
