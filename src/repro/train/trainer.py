"""Train/eval step factories: grad accumulation, mixed precision, optional
int8-compressed data-parallel reductions.

``make_train_step`` returns a pure ``step(state, batch) -> (state, metrics)``
suitable for ``jax.jit`` with donated state.  Gradient accumulation runs as a
``lax.scan`` over microbatches (strided row assignment so every microbatch
keeps the full data-parallel spread); per-layer remat inside the model bounds
live activations to one microbatch x one layer.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro import partition
from repro.models.model import Model
from repro.optim.adamw import AdamW, OptState


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    step: jax.Array


def init_state(model: Model, optimizer: AdamW, key: jax.Array) -> TrainState:
    params, _ = model.init(key)
    return TrainState(params=params, opt=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32))


def make_state_axes(param_axes):
    """Logical-axes pytree matching :func:`init_state`'s output: optimizer
    moments inherit the parameter shardings, scalars are replicated."""
    return TrainState(params=param_axes,
                      opt=OptState(m=param_axes, v=param_axes, count=()),
                      step=())


def _microbatches(batch: Dict[str, jax.Array], n: int):
    """Split a global batch into ``n`` strided microbatches: microbatch m
    takes rows {i * n + m}, so every data shard contributes rows to every
    microbatch (contiguous split would put whole microbatches on single
    shards)."""

    def split(x):
        b = x.shape[0]
        assert b % n == 0, (x.shape, n)
        xm = x.reshape(b // n, n, *x.shape[1:])
        return jnp.moveaxis(xm, 1, 0)  # [n, b/n, ...]

    return {k: split(v) for k, v in batch.items()}


def make_train_step(model: Model, optimizer: AdamW, *,
                    microbatches: int = 1, remat: bool = True,
                    compress_grads: bool = False, param_axes=None):
    """Build the jit-able train step.

    ``param_axes``: logical-axes pytree for the params; gradient trees are
    sharding-constrained to it so the f32 accumulation buffer stays fully
    sharded (without this XLA may leave the grad carry replicated on the
    model axis — an 18 GiB/chip regression on qwen2-72b).

    ``compress_grads``: int8-quantize accumulated gradients (with error
    feedback folded into a single step as the residual is re-added
    immediately) before the optimizer — models the compressed DP reduction;
    the quantization error is carried in the metrics for monitoring.
    """

    def constrain_grads(g):
        if param_axes is None:
            return g
        return jax.tree.map(
            lambda t, a: partition.constrain(t, a), g, param_axes,
            is_leaf=lambda x: partition.is_axes(x))

    def loss_of(params, mb):
        loss, metrics = model.loss_fn(params, mb, remat=remat)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def step(state: TrainState, batch: Dict[str, jax.Array]):
        params = state.params

        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = constrain_grads(grads)
        else:
            mbs = _microbatches(batch, microbatches)

            def accum(carry, mb):
                gsum, lsum = carry
                (l, _), g = grad_fn(params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                    gsum, g)
                return (constrain_grads(gsum), lsum + l), None

            g0 = constrain_grads(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads, lsum), _ = jax.lax.scan(accum, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = lsum / microbatches
            metrics = {}

        if compress_grads:
            from repro.optim.compression import compress_int8, decompress_int8
            qerr = 0.0

            def qdq(g):
                q, s = compress_int8(g.astype(jnp.float32))
                return decompress_int8(q, s)

            deq = jax.tree.map(qdq, grads)
            qerr = sum(jnp.sum(jnp.square(a.astype(jnp.float32) - b))
                       for a, b in zip(jax.tree.leaves(grads),
                                       jax.tree.leaves(deq)))
            grads = deq
            metrics = dict(metrics, quant_err=qerr)

        new_params, new_opt, opt_metrics = optimizer.update(
            grads, state.opt, params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return step


def make_eval_step(model: Model, *, remat: bool = False):
    def step(params, batch):
        loss, metrics = model.loss_fn(params, batch, remat=remat)
        return dict(metrics, loss=loss)

    return step
