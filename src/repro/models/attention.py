"""Attention: blockwise (flash-style) training/prefill path, GQA/SWA/bias
variants, and a sequence-sharded flash-decode for serving.

Training/prefill use a pure-JAX blockwise softmax-rescaling scan over KV
chunks: O(S * chunk) live memory instead of O(S^2), which is what makes the
32k-prefill cells compile inside per-chip HBM.  The same algorithm is the
oracle for the Pallas ``flash_attention`` kernel (``repro/kernels``).

Decode shards the KV cache over the *model* mesh axis on the sequence dim
(``cache_seq`` logical axis).  Each shard computes a local
(max, sum-exp, weighted-V) triple and the result is combined with
``psum``/``pmax`` inside ``shard_map`` - no kv-head divisibility constraint
(kv = 1..16 all work on a 16-wide model axis) and per-chip cache bytes are
bounded.  Cache insertion is ownership-masked ``dynamic_update_slice`` so no
collective touches the cache on the hot path.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import partition
from repro.models.config import ModelConfig
from repro.models.layers import COMPUTE_DTYPE, ParamBuilder, Params, apply_rope

NEG_INF = -1e30
DEFAULT_CHUNK = 1024

if hasattr(jax, "shard_map"):                      # jax >= 0.6
    def _shard_map(f, *, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:                                              # pinned 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

    def _shard_map(f, *, mesh, in_specs, out_specs):
        return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_rep=False)


def init_attention(b: ParamBuilder, cfg: ModelConfig, d_in: Optional[int] = None) -> Params:
    d = d_in or cfg.d_model
    p = {
        "wq": b.param("wq", (d, cfg.q_dim), ("embed", "heads")),
        "wk": b.param("wk", (d, cfg.kv_dim), ("embed", "kv")),
        "wv": b.param("wv", (d, cfg.kv_dim), ("embed", "kv")),
        "wo": b.param("wo", (cfg.q_dim, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = b.param("bq", (cfg.q_dim,), ("heads",), init="zeros")
        p["bk"] = b.param("bk", (cfg.kv_dim,), ("kv",), init="zeros")
        p["bv"] = b.param("bv", (cfg.kv_dim,), ("kv",), init="zeros")
    return p


def _project_qkv(params: Params, x: jax.Array, cfg: ModelConfig,
                 positions: Optional[jax.Array], rope: bool = True):
    B, S, _ = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = x @ partition.wcast(params["wq"], COMPUTE_DTYPE, ("embed", "heads"))
    k = x @ partition.wcast(params["wk"], COMPUTE_DTYPE, ("embed", "kv"))
    v = x @ partition.wcast(params["wv"], COMPUTE_DTYPE, ("embed", "kv"))
    if "bq" in params:
        q = q + params["bq"].astype(COMPUTE_DTYPE)
        k = k + params["bk"].astype(COMPUTE_DTYPE)
        v = v + params["bv"].astype(COMPUTE_DTYPE)
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, KV, dh)
    v = v.reshape(B, S, KV, dh)
    if rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _pick_chunk(s: int, chunk: int) -> Tuple[int, int]:
    """Pick a block size and (possibly padded) length for ``s``.

    Prefers the largest divisor of ``s`` in (chunk/2, chunk]; if none
    exists, keeps ``chunk`` and pads ``s`` up to a multiple (padded keys are
    masked, padded queries sliced away).  Never lets the block collapse to a
    tiny divisor — that would unroll O((s/c)^2) blocks at trace time."""
    if s <= chunk:
        return s, s
    for c in range(chunk, chunk // 2, -1):
        if s % c == 0:
            return c, s
    pad = -(-s // chunk) * chunk
    return chunk, pad


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool, window: Optional[int] = None,
                        chunk: int = DEFAULT_CHUNK,
                        bidirectional_prefix: int = 0) -> jax.Array:
    """Block attention with *static* block skipping.

    q: [B, Sq, H, dh]; k/v: [B, Sk, KV, dh]  (H = KV * group).
    Both q and kv are split into chunks; for each q chunk only the causally /
    window-wise reachable kv chunks are computed (running-max softmax
    rescaling combines them).  The loops are unrolled in Python with static
    chunk indices, so (a) fully-masked blocks cost **zero** HLO FLOPs - no 2x
    causal waste - and (b) ``cost_analysis()`` counts attention exactly (no
    while-loop undercount).  Live memory is O(Cq * Ck) per block.

    ``bidirectional_prefix``: positions < prefix attend bidirectionally (VLM
    image prefix; must fit the first chunk).  Returns [B, Sq, H, dh].
    """
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    g = H // KV
    cq, sq_pad = _pick_chunk(Sq, chunk)
    ck, sk_pad = _pick_chunk(Sk, chunk)
    if sq_pad != Sq:
        q = jnp.pad(q, ((0, 0), (0, sq_pad - Sq), (0, 0), (0, 0)))
    if sk_pad != Sk:
        k = jnp.pad(k, ((0, 0), (0, sk_pad - Sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad - Sk), (0, 0), (0, 0)))
    kv_limit = Sk if sk_pad != Sk else None   # mask padded keys
    nq, nk = sq_pad // cq, sk_pad // ck
    assert bidirectional_prefix <= cq or nq == 1, "prefix must fit one chunk"
    scale = dh ** -0.5
    qg = q.reshape(B, nq, cq, KV, g, dh).astype(COMPUTE_DTYPE)
    kc = k.reshape(B, nk, ck, KV, dh).astype(COMPUTE_DTYPE)
    vc = v.reshape(B, nk, ck, KV, dh).astype(COMPUTE_DTYPE)

    out_chunks = []
    for qi in range(nq):
        q_lo, q_hi = qi * cq, (qi + 1) * cq
        q_pos = jnp.arange(q_lo, q_hi)
        m = jnp.full((B, KV, g, cq), NEG_INF, jnp.float32)
        l = jnp.zeros((B, KV, g, cq), jnp.float32)
        o = jnp.zeros((B, KV, g, cq, dh), jnp.float32)
        for kj in range(nk):
            k_lo, k_hi = kj * ck, (kj + 1) * ck
            if causal and k_lo > q_hi - 1:
                continue  # strictly-upper block: statically skipped
            if window is not None and k_hi - 1 < q_lo - window + 1 \
                    and not (bidirectional_prefix and k_lo < bidirectional_prefix):
                continue  # outside the sliding window: statically skipped
            k_pos = jnp.arange(k_lo, k_hi)
            s = jnp.einsum("bqkgd,bckd->bkgqc", qg[:, qi], kc[:, kj],
                           preferred_element_type=jnp.float32) * scale
            mask = None
            if causal and k_hi > q_lo:  # diagonal-crossing block
                mask = q_pos[:, None] >= k_pos[None, :]
                if bidirectional_prefix:
                    bidir = (q_pos[:, None] < bidirectional_prefix) & \
                            (k_pos[None, :] < bidirectional_prefix)
                    mask = mask | bidir
            if window is not None and k_lo <= q_hi - window:
                wmask = q_pos[:, None] - k_pos[None, :] < window
                if bidirectional_prefix:
                    wmask = wmask | (k_pos[None, :] < bidirectional_prefix)
                mask = wmask if mask is None else (mask & wmask)
            if kv_limit is not None and k_hi > kv_limit:
                vmask = jnp.broadcast_to(k_pos[None, :] < kv_limit, (cq, ck))
                mask = vmask if mask is None else (mask & vmask)
            if mask is not None:
                s = jnp.where(mask[None, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(COMPUTE_DTYPE),
                            vc[:, kj], preferred_element_type=jnp.float32)
            o = o * corr[..., None] + pv
            m = m_new
        out_chunks.append(o / jnp.maximum(l, 1e-30)[..., None])
    out = jnp.stack(out_chunks, axis=1)  # [B, nq, KV, g, cq, dh]
    out = out.transpose(0, 1, 4, 2, 3, 5).reshape(B, sq_pad, H, dh)
    if sq_pad != Sq:
        out = out[:, :Sq]
    return out.astype(q.dtype)


def attention(params: Params, x: jax.Array, cfg: ModelConfig, *,
              positions: Optional[jax.Array] = None, causal: bool = True,
              window: Optional[int] = None, rope: bool = True,
              bidirectional_prefix: int = 0,
              kv_x: Optional[jax.Array] = None) -> jax.Array:
    """Full attention block (projections + blockwise core + output proj).

    ``kv_x`` switches to cross-attention (keys/values from the encoder)."""
    B, S, _ = x.shape
    if kv_x is None:
        q, k, v = _project_qkv(params, x, cfg, positions, rope)
    else:
        q, _, _ = _project_qkv(params, x, cfg, positions, rope=False)
        k, v = project_kv(params, kv_x, cfg)
        causal = False
    out = blockwise_attention(q, k, v, causal=causal, window=window,
                              bidirectional_prefix=bidirectional_prefix)
    out = partition.constrain(out.reshape(B, S, cfg.q_dim),
                              ("batch", "seq", "heads"))
    return out @ partition.wcast(params["wo"], COMPUTE_DTYPE,
                                 ("heads", "embed"))


def project_kv(params: Params, kv_x: jax.Array, cfg: ModelConfig):
    """Project keys/values (no rope) from encoder states: [B, Sk, KV, dh]."""
    B, Sk, _ = kv_x.shape
    k = (kv_x @ params["wk"].astype(COMPUTE_DTYPE))
    v = (kv_x @ params["wv"].astype(COMPUTE_DTYPE))
    if "bk" in params:
        k = k + params["bk"].astype(COMPUTE_DTYPE)
        v = v + params["bv"].astype(COMPUTE_DTYPE)
    return (k.reshape(B, Sk, cfg.n_kv_heads, cfg.head_dim_),
            v.reshape(B, Sk, cfg.n_kv_heads, cfg.head_dim_))


def attention_with_kv(params: Params, x: jax.Array, cfg: ModelConfig, *,
                      positions: Optional[jax.Array] = None,
                      causal: bool = True, window: Optional[int] = None,
                      rope: bool = True, bidirectional_prefix: int = 0):
    """Like :func:`attention` but also returns the (post-rope) K/V for the
    decode cache: (out [B, S, d], (k, v) each [B, S, KV, dh])."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, cfg, positions, rope)
    out = blockwise_attention(q, k, v, causal=causal, window=window,
                              bidirectional_prefix=bidirectional_prefix)
    out = partition.constrain(out.reshape(B, S, cfg.q_dim),
                              ("batch", "seq", "heads"))
    return out @ partition.wcast(params["wo"], COMPUTE_DTYPE,
                                 ("heads", "embed")), (k, v)


def pack_cache(k: jax.Array, v: jax.Array, window: int):
    """Lay prefill K/V [B, S, KV, dh] out as a ring cache of ``window`` slots.

    Slot convention is ``slot = pos % window`` (matching the decode insert),
    so for S >= window the last ``window`` tokens land rotated by S % window;
    for S < window tokens sit at slots [0, S) with zero padding above."""

    def one(c):
        B, S = c.shape[:2]
        if S >= window:
            tail = c[:, S - window:]
            return jnp.roll(tail, shift=S % window, axis=1)
        pad = [(0, 0)] * c.ndim
        pad[1] = (0, window - S)
        return jnp.pad(c, pad)

    return one(k), one(v)


def decode_attn(params: Params, x: jax.Array, cfg: ModelConfig,
                k_cache: jax.Array, v_cache: jax.Array, pos: jax.Array,
                window: int):
    """One-token self-attention against a ring cache.

    x: [B, d]; k/v_cache: [B, W, KV, dh]; pos: scalar (current position).
    Returns (out [B, d], new k_cache, new v_cache)."""
    B = x.shape[0]
    posb = jnp.broadcast_to(jnp.asarray(pos)[None, None], (B, 1))
    q, k, v = _project_qkv(params, x[:, None], cfg, posb, rope=True)
    k_cache = cache_insert(k_cache, k[:, 0], pos, ring=window)
    v_cache = cache_insert(v_cache, v[:, 0], pos, ring=window)
    eff_len = jnp.minimum(pos + 1, window)
    out = decode_attention_sharded(q[:, 0], k_cache, v_cache, eff_len)
    out = out.reshape(B, cfg.q_dim)
    return out @ params["wo"].astype(COMPUTE_DTYPE), k_cache, v_cache


def decode_cross_attn(params: Params, x: jax.Array, cfg: ModelConfig,
                      xk: jax.Array, xv: jax.Array) -> jax.Array:
    """One-token cross-attention over a fixed encoder cache.

    x: [B, d]; xk/xv: [B, F, KV, dh] (replicated over model axis)."""
    B = x.shape[0]
    q, _, _ = _project_qkv(params, x[:, None], cfg, None, rope=False)
    q = q[:, 0]                                        # [B, H, dh]
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    qg = q.reshape(B, KV, H // KV, dh)
    s = jnp.einsum("bkgd,bfkd->bkgf", qg.astype(COMPUTE_DTYPE),
                   xk.astype(COMPUTE_DTYPE),
                   preferred_element_type=jnp.float32) * (dh ** -0.5)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgf,bfkd->bkgd", p.astype(COMPUTE_DTYPE),
                   xv.astype(COMPUTE_DTYPE), preferred_element_type=jnp.float32)
    out = o.reshape(B, cfg.q_dim).astype(x.dtype)
    return out @ params["wo"].astype(COMPUTE_DTYPE)


# ---------------------------------------------------------------------------
# Decode: sequence-sharded KV cache (flash-decode).
# ---------------------------------------------------------------------------


def _local_decode(q, k, v, cache_len, shard_idx, n_shards, s_local, window):
    """One shard's decode-attention partial: returns (o, l, m) un-normalized.

    q: [B, H, dh] local; k/v: [B, s_local, KV, dh] local slice of the cache.
    Positions covered: [shard_idx * s_local, ...).
    """
    B, H, dh = q.shape
    KV = k.shape[2]
    g = H // KV
    qg = q.reshape(B, KV, g, dh)
    pos = shard_idx * s_local + jnp.arange(s_local)
    valid = pos < cache_len
    if window is not None:
        valid = valid & (pos >= cache_len - window)
    s = jnp.einsum("bkgd,bckd->bkgc", qg.astype(COMPUTE_DTYPE),
                   k.astype(COMPUTE_DTYPE),
                   preferred_element_type=jnp.float32) * (dh ** -0.5)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                          # [B, KV, g]
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[None, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgc,bckd->bkgd", p.astype(COMPUTE_DTYPE),
                   v.astype(COMPUTE_DTYPE), preferred_element_type=jnp.float32)
    return o, l, m


def decode_attention_sharded(q: jax.Array, k_cache: jax.Array,
                             v_cache: jax.Array, cache_len: jax.Array,
                             window: Optional[int] = None) -> jax.Array:
    """Flash-decode over a seq-sharded cache.  q: [B, H, dh];
    k/v_cache: [B, S, KV, dh] sharded on S over the model axis."""
    rules = partition.current_rules()
    axis = rules.axis("cache_seq") if rules is not None else None
    if axis is None:
        o, l, m = _local_decode(q, k_cache, v_cache, cache_len, 0,
                                1, k_cache.shape[1], window)
        out = o / jnp.maximum(l, 1e-30)[..., None]
        B, H, dh = q.shape
        return out.reshape(B, H, dh).astype(q.dtype)

    mesh = rules.mesh
    n_shards = mesh.shape[axis]
    S = k_cache.shape[1]
    s_local = S // n_shards
    batch = rules.axis("batch")
    qspec = P(batch, None, None)
    cspec = P(batch, axis, None, None)

    def body(q, k, v, cache_len):
        idx = jax.lax.axis_index(axis)
        o, l, m = _local_decode(q, k, v, cache_len, idx, n_shards,
                                s_local, window)
        m_glob = jax.lax.pmax(m, axis)
        corr = jnp.exp(m - m_glob)
        l_glob = jax.lax.psum(l * corr, axis)
        o_glob = jax.lax.psum(o * corr[..., None], axis)
        out = o_glob / jnp.maximum(l_glob, 1e-30)[..., None]
        B, KV, g, dh = out.shape
        return out.reshape(B, KV * g, dh).astype(q.dtype)

    return _shard_map(
        body, mesh=mesh, in_specs=(qspec, cspec, cspec, P()),
        out_specs=qspec,
    )(q, k_cache, v_cache, cache_len)


def cache_insert(cache: jax.Array, new: jax.Array, pos: jax.Array,
                 ring: Optional[int] = None) -> jax.Array:
    """Insert one token's K or V at position ``pos`` (mod ring size if a
    sliding-window ring buffer).  cache: [B, S, KV, dh]; new: [B, KV, dh].

    With a seq-sharded cache the insert runs inside shard_map: the owning
    shard does a local dynamic_update_slice, the rest keep their slice."""
    S = cache.shape[1]
    tgt = pos % ring if ring is not None else pos
    rules = partition.current_rules()
    axis = rules.axis("cache_seq") if rules is not None else None

    def local_insert(c, n, owner_base, s_local):
        rel = tgt - owner_base
        owns = (rel >= 0) & (rel < s_local)
        rel_c = jnp.clip(rel, 0, s_local - 1)
        upd = jax.lax.dynamic_update_slice(
            c, n[:, None].astype(c.dtype), (0, rel_c, 0, 0))
        return jnp.where(owns, upd, c)

    if axis is None:
        return local_insert(cache, new, 0, S)

    mesh = rules.mesh
    s_local = S // mesh.shape[axis]
    batch = rules.axis("batch")
    cspec = P(batch, axis, None, None)
    nspec = P(batch, None, None)

    def body(c, n):
        base = jax.lax.axis_index(axis) * s_local
        return local_insert(c, n, base, s_local)

    return _shard_map(body, mesh=mesh, in_specs=(cspec, nspec),
                      out_specs=cspec)(cache, new)


def init_decode_cache(cfg: ModelConfig, n_layers: int, batch: int,
                      max_seq: int, window: Optional[int] = None):
    """Zeroed stacked KV cache [L, B, W, KV, dh] (+ axes tuple)."""
    W = min(max_seq, window) if window else max_seq
    shape = (n_layers, batch, W, cfg.n_kv_heads, cfg.head_dim_)
    axes = ("layers", "batch", "cache_seq", None, None)
    return (jnp.zeros(shape, COMPUTE_DTYPE), jnp.zeros(shape, COMPUTE_DTYPE)), axes
