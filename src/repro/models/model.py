"""Unified model: one class covering every assigned architecture family.

``Model(cfg)`` exposes four entry points, all pure functions of a params
pytree (so every one of them is ``jax.eval_shape``-able for the dry-run):

* ``init(key) -> (params, axes)`` — axes is a matching pytree of
  logical-axis tuples consumed by :mod:`repro.partition`.
* ``loss_fn(params, batch) -> (loss, metrics)`` — next-token CE (chunked
  vocab-parallel-friendly), plus MoE aux losses where applicable.
* ``prefill(params, batch) -> (last_logits, cache)`` — processes a prompt
  and builds the decode cache.
* ``decode_step(params, cache, token, pos) -> (logits, cache)`` — one new
  token against the cache; caches are O(seq) KV for attention families and
  O(1) recurrent state for SSM/hybrid families.

Layer stacks run as ``lax.scan`` over stacked weights (a single HLO while
body regardless of depth — this is what keeps 66 dry-run compiles
tractable), with optional per-layer ``jax.checkpoint`` for training.
Hybrid (RecurrentGemma) stacks scan over complete pattern *units*
(rec, rec, attn) and unroll the remainder.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import partition
from repro.models import (attention as attn_lib, moe as moe_lib,
                          rglru as rglru_lib, ssm as ssm_lib)
from repro.models.config import ModelConfig
from repro.models.layers import (COMPUTE_DTYPE, ParamBuilder, Params,
                                 embed_lookup, init_mlp, layer_norm, mlp,
                                 rms_norm, sinusoidal_positions)

CE_CHUNK = 512  # sequence chunk for the checkpointed cross-entropy


@jax.custom_jvp
def _barrier(x):
    """``optimization_barrier`` with an identity differentiation rule.

    The pinned jax (0.4.x) defines no JVP/transpose for
    ``optimization_barrier_p``, so putting the raw primitive inside a
    ``jax.checkpoint``-ed scan body breaks ``jax.grad``.  The barrier is
    semantically the identity — it only fences XLA scheduling/convert
    motion — so the tangent passes straight through (and the barrier is
    NOT applied to the tangent: fencing the primal stash is what matters).
    """
    return jax.lax.optimization_barrier(x)


@_barrier.defjvp
def _barrier_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return jax.lax.optimization_barrier(x), t


def _is_axes(x) -> bool:
    return partition.is_axes(x)


def _prefix_layers(axes):
    return jax.tree.map(lambda a: ("layers",) + a, axes, is_leaf=_is_axes)


def _built(build_fn, key, *args):
    b = ParamBuilder(key)
    params = build_fn(b, *args)
    return params, {k: v for k, v in b.axes.items() if k in params}


def stack_layers(key: jax.Array, n: int, build_fn):
    """Stack ``n`` layers built by ``build_fn(key) -> (params, axes)``."""
    _, axes = build_fn(jax.random.key(0))  # structure + axes side-channel
    params = jax.vmap(lambda k: build_fn(k)[0])(jax.random.split(key, n))
    return params, _prefix_layers(axes)


# ---------------------------------------------------------------------------
# Norm helpers (rms for LM families, layernorm for whisper).
# ---------------------------------------------------------------------------


def _init_norm(b: ParamBuilder, d: int, kind: str, name: str) -> Params:
    if kind == "rms":
        return {"scale": b.param(f"{name}_s", (d,), ("embed",), init="zeros")}
    return {"scale": b.param(f"{name}_s", (d,), ("embed",), init="ones"),
            "bias": b.param(f"{name}_b", (d,), ("embed",), init="zeros")}


def _norm(p: Params, x: jax.Array, kind: str, eps: float) -> jax.Array:
    if kind == "rms":
        return rms_norm(x, p["scale"], eps)
    return layer_norm(x, p["scale"], p["bias"], eps)


def _norm_axes(kind: str) -> Dict[str, tuple]:
    if kind == "rms":
        return {"scale": ("embed",)}
    return {"scale": ("embed",), "bias": ("embed",)}


# ---------------------------------------------------------------------------
# Per-family layer builders: build(key) -> (params, axes).
# ---------------------------------------------------------------------------


def _build_attn_mlp_layer(key, cfg: ModelConfig, norm_kind: str,
                          use_moe: bool = False):
    b = ParamBuilder(key)
    attn_p, attn_a = _built(attn_lib.init_attention, b.next_key(), cfg)
    if use_moe:
        mlp_p, mlp_a = _built(moe_lib.init_moe, b.next_key(), cfg)
    else:
        mlp_p, mlp_a = _built(init_mlp, b.next_key(), cfg.d_model, cfg.d_ff,
                              cfg.mlp_type)
    nb = ParamBuilder(b.next_key())
    params = {
        "ln1": _init_norm(nb, cfg.d_model, norm_kind, "ln1"),
        "attn": attn_p,
        "ln2": _init_norm(nb, cfg.d_model, norm_kind, "ln2"),
        "mlp": mlp_p,
    }
    axes = {
        "ln1": _norm_axes(norm_kind), "attn": attn_a,
        "ln2": _norm_axes(norm_kind), "mlp": mlp_a,
    }
    return params, axes


def _build_ssm_layer(key, cfg: ModelConfig):
    b = ParamBuilder(key)
    mix_p, mix_a = _built(ssm_lib.init_mamba2, b.next_key(), cfg)
    nb = ParamBuilder(b.next_key())
    return ({"ln": _init_norm(nb, cfg.d_model, "rms", "ln"), "mixer": mix_p},
            {"ln": _norm_axes("rms"), "mixer": mix_a})


def _build_hybrid_layer(key, cfg: ModelConfig, kind: str):
    b = ParamBuilder(key)
    if kind == "rec":
        blk_p, blk_a = _built(rglru_lib.init_rglru_block, b.next_key(), cfg)
    else:
        blk_p, blk_a = _built(attn_lib.init_attention, b.next_key(), cfg)
    mlp_p, mlp_a = _built(init_mlp, b.next_key(), cfg.d_model, cfg.d_ff,
                          cfg.mlp_type)
    nb = ParamBuilder(b.next_key())
    return ({"ln1": _init_norm(nb, cfg.d_model, "rms", "ln1"), "block": blk_p,
             "ln2": _init_norm(nb, cfg.d_model, "rms", "ln2"), "mlp": mlp_p},
            {"ln1": _norm_axes("rms"), "block": blk_a,
             "ln2": _norm_axes("rms"), "mlp": mlp_a})


def _build_decoder_xattn_layer(key, cfg: ModelConfig):
    """Whisper decoder layer: self-attn + cross-attn + mlp, layernorm."""
    b = ParamBuilder(key)
    self_p, self_a = _built(attn_lib.init_attention, b.next_key(), cfg)
    cross_p, cross_a = _built(attn_lib.init_attention, b.next_key(), cfg)
    mlp_p, mlp_a = _built(init_mlp, b.next_key(), cfg.d_model, cfg.d_ff,
                          cfg.mlp_type)
    nb = ParamBuilder(b.next_key())
    return ({"ln1": _init_norm(nb, cfg.d_model, "ln", "ln1"), "self": self_p,
             "ln2": _init_norm(nb, cfg.d_model, "ln", "ln2"), "cross": cross_p,
             "ln3": _init_norm(nb, cfg.d_model, "ln", "ln3"), "mlp": mlp_p},
            {"ln1": _norm_axes("ln"), "self": self_a,
             "ln2": _norm_axes("ln"), "cross": cross_a,
             "ln3": _norm_axes("ln"), "mlp": mlp_a})


# ---------------------------------------------------------------------------
# Chunked cross-entropy (keeps [B, S, V] logits out of live memory).
# ---------------------------------------------------------------------------


def chunked_cross_entropy(x: jax.Array, head: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None,
                          chunk: int = CE_CHUNK,
                          unroll: bool = False,
                          valid_vocab: Optional[int] = None) -> jax.Array:
    """Mean next-token CE; computes logits per sequence-chunk inside a
    checkpointed scan so only one chunk's [B, c, V] is ever live."""
    B, S, d = x.shape
    c = min(chunk, S)
    while S % c:
        c //= 2
    n = S // c
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    mask = jnp.broadcast_to(mask, (B, S))
    xc = x.reshape(B, n, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, c).transpose(1, 0, 2)
    mc = mask.astype(jnp.float32).reshape(B, n, c).transpose(1, 0, 2)

    head = partition.constrain(head.astype(COMPUTE_DTYPE), (None, "vocab"))

    @jax.checkpoint
    def body(carry, inp):
        xi, li, mi = inp
        logits = (xi @ head).astype(jnp.float32)
        logits = partition.constrain(logits, ("batch", None, "vocab"))
        if valid_vocab is not None and valid_vocab < logits.shape[-1]:
            pad = jnp.arange(logits.shape[-1]) >= valid_vocab
            logits = jnp.where(pad, -1e30, logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        nll_sum = jnp.sum((lse - gold) * mi)
        tot, cnt = carry
        return (tot + nll_sum, cnt + jnp.sum(mi)), None

    if unroll:
        carry = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
        for i in range(n):
            carry, _ = body(carry, (xc[i], lc[i], mc[i]))
        tot, cnt = carry
    else:
        (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


# §Perf H1 (EXPERIMENTS.md): the checkpointed chunk body above used to
# re-gather the FSDP-sharded head EVERY chunk in f32 (16 x 128 MiB
# all-gathers per microbatch on stablelm-12b).  The fix is the single
# bf16 (None, "vocab") constrain before the scan: the partitioner gathers
# one bf16 copy that the chunk scan reuses (jax.checkpoint saves
# scan-invariant inputs; no per-chunk re-gather).


# ---------------------------------------------------------------------------
# The unified model.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    # Unrolled layer loops (python loop over the stacked weights instead of
    # lax.scan).  Production keeps scan (compact HLO); the roofline probes
    # unroll so ``cost_analysis`` counts every layer exactly.
    unroll: bool = False
    _paxes: Any = dataclasses.field(default=None, repr=False, compare=False)

    def param_axes(self):
        """Full logical-axes pytree (computed without allocating params)."""
        if self._paxes is None:
            box = {}

            def f():
                p, a = self.init(jax.random.key(0))
                box["a"] = a
                return p

            jax.eval_shape(f)
            self._paxes = box["a"]
        return self._paxes

    def _sliced_axes(self, key: str):
        """Per-layer axes for one stacked group ('layers'/'enc_layers'):
        the leading 'layers' entry stripped from every leaf."""
        ax = self.param_axes()[key]
        return jax.tree.map(lambda a: a[1:], ax, is_leaf=partition.is_axes)

    def _constrain_layer(self, p, key: str = "layers"):
        """Constrain a sliced layer's params inside the scan body.  The
        transpose of with_sharding_constraint is the same constraint, so
        this forces the per-layer weight *cotangents* back to the fully
        sharded layout before they are stacked into the backward scan's
        carry — without it the grad stash is only model-sharded
        (~18 GiB/chip on qwen2-72b instead of ~1.1 GiB)."""
        if partition.current_rules() is None:
            return p
        return jax.tree.map(lambda t, a: partition.constrain(t, a),
                            p, self._sliced_axes(key))

    def _scan(self, body, carry, xs):
        if not self.unroll:
            return jax.lax.scan(body, carry, xs)
        n = jax.tree.leaves(xs)[0].shape[0]
        ys = []
        for i in range(n):
            carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
            ys.append(y)
        if ys and ys[0] is not None:
            ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
        else:
            ys = None
        return carry, ys

    # ----- construction -----------------------------------------------------
    @property
    def norm_kind(self) -> str:
        return "ln" if self.cfg.family == "encdec" else "rms"

    def init(self, key: jax.Array) -> Tuple[Params, Any]:
        cfg = self.cfg
        b = ParamBuilder(key)
        params: Dict[str, Any] = {}
        axes: Dict[str, Any] = {}

        # Vocab padded to a multiple of 256 for even TP sharding; logits
        # above cfg.vocab_size are masked to -inf everywhere they surface.
        params["embed"] = b.param("embed", (cfg.padded_vocab, cfg.d_model),
                                  ("vocab", "embed"), scale=0.02)
        axes["embed"] = ("vocab", "embed")
        if not cfg.tie_embeddings:
            params["head"] = b.param("head", (cfg.d_model, cfg.padded_vocab),
                                     ("embed", "vocab"), scale=0.02)
            axes["head"] = ("embed", "vocab")

        nb = ParamBuilder(b.next_key())
        params["final_norm"] = _init_norm(nb, cfg.d_model, self.norm_kind, "fn")
        axes["final_norm"] = _norm_axes(self.norm_kind)

        fam = cfg.family
        if fam in ("dense", "vlm", "moe"):
            build = functools.partial(_build_attn_mlp_layer, cfg=cfg,
                                      norm_kind="rms", use_moe=(fam == "moe"))
            params["layers"], axes["layers"] = stack_layers(
                b.next_key(), cfg.n_layers, build)
        elif fam == "ssm":
            params["layers"], axes["layers"] = stack_layers(
                b.next_key(), cfg.n_layers,
                functools.partial(_build_ssm_layer, cfg=cfg))
        elif fam == "hybrid":
            pattern = cfg.block_pattern or ("attn",)
            n_units, rem = divmod(cfg.n_layers, len(pattern))

            def build_unit(k):
                ps, as_ = [], []
                for i, kind in enumerate(pattern):
                    p, a = _build_hybrid_layer(jax.random.fold_in(k, i), cfg, kind)
                    ps.append(p)
                    as_.append(a)
                return tuple(ps), tuple(as_)

            params["layers"], axes["layers"] = stack_layers(
                b.next_key(), n_units, build_unit)
            rem_p, rem_a = [], []
            for i in range(rem):
                p, a = _build_hybrid_layer(b.next_key(), cfg, pattern[i])
                rem_p.append(p)
                rem_a.append(a)
            if rem_p:  # omit when empty: keeps params/axes trees congruent
                params["rem_layers"] = tuple(rem_p)
                axes["rem_layers"] = tuple(rem_a)
        elif fam == "encdec":
            params["enc_layers"], axes["enc_layers"] = stack_layers(
                b.next_key(), cfg.n_enc_layers,
                functools.partial(_build_attn_mlp_layer, cfg=cfg,
                                  norm_kind="ln"))
            enb = ParamBuilder(b.next_key())
            params["enc_norm"] = _init_norm(enb, cfg.d_model, "ln", "en")
            axes["enc_norm"] = _norm_axes("ln")
            params["layers"], axes["layers"] = stack_layers(
                b.next_key(), cfg.n_layers,
                functools.partial(_build_decoder_xattn_layer, cfg=cfg))
        else:
            raise ValueError(fam)
        return params, axes

    # ----- layer application -------------------------------------------------
    def _attn_mlp_layer(self, p: Params, x: jax.Array, positions, *,
                        causal=True, window=None, prefix=0, kv_x=None,
                        aux_carry=None, rope=True):
        cfg = self.cfg
        h = _norm(p["ln1"], x, "rms" if self.norm_kind == "rms" else "ln",
                  cfg.norm_eps)
        out = attn_lib.attention(p["attn"], h, cfg, positions=positions,
                                 causal=causal, window=window, rope=rope,
                                 bidirectional_prefix=prefix, kv_x=kv_x)
        # §Perf H6: barrier keeps the TP partial-sum all-reduce in bf16
        # (the downstream norm's f32 convert otherwise hoists before it).
        x = x + _barrier(out)
        h = _norm(p["ln2"], x, "rms" if self.norm_kind == "rms" else "ln",
                  cfg.norm_eps)
        if cfg.family == "moe":
            y, aux = moe_lib.moe_mlp(p["mlp"], h, cfg)
            x = x + _barrier(y)
            if aux_carry is not None:
                aux_carry = aux_carry + aux
        else:
            x = x + _barrier(mlp(p["mlp"], h,
                                                     cfg.mlp_type))
        x = partition.constrain(x, ("batch", "seq", "act_embed"))
        return x, aux_carry

    def _hybrid_layer(self, p: Params, x, positions, kind: str):
        cfg = self.cfg
        h = _norm(p["ln1"], x, "rms", cfg.norm_eps)
        if kind == "rec":
            x = x + rglru_lib.recurrent_block(p["block"], h, cfg)
        else:
            x = x + attn_lib.attention(p["block"], h, cfg, positions=positions,
                                       causal=True, window=cfg.local_window)
        h = _norm(p["ln2"], x, "rms", cfg.norm_eps)
        x = x + mlp(p["mlp"], h, cfg.mlp_type)
        return partition.constrain(x, ("batch", "seq", "act_embed"))

    # ----- forward (training) -------------------------------------------------
    def forward(self, params: Params, batch: Dict[str, jax.Array], *,
                remat: bool = True) -> Tuple[jax.Array, jax.Array]:
        """Returns (pre-head hidden states [B, S, d], aux loss scalar)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = embed_lookup(params["embed"], tokens)
        if cfg.family == "vlm":
            pe = batch["patch_embeds"].astype(x.dtype)
            x = jnp.concatenate([pe, x[:, cfg.n_patches:]], axis=1)
        positions = jnp.arange(S)[None, :]
        aux0 = jnp.zeros((), jnp.float32)

        fam = cfg.family
        if fam in ("dense", "vlm", "moe"):
            prefix = cfg.n_patches if fam == "vlm" else 0

            def body(carry, p):
                # optimization_barrier: stops XLA convert-motion from
                # stashing the remat carry as f32 (2x stash memory).
                x, aux = _barrier(carry)
                p = self._constrain_layer(p)
                x, aux = self._attn_mlp_layer(p, x, positions,
                                              window=cfg.sliding_window,
                                              prefix=prefix, aux_carry=aux)
                return (x, aux), None

            body_fn = jax.checkpoint(body) if remat else body
            (x, aux), _ = self._scan(body_fn, (x, aux0), params["layers"])
        elif fam == "ssm":
            def body(x, p):
                x = _barrier(x)
                p = self._constrain_layer(p)
                h = _norm(p["ln"], x, "rms", cfg.norm_eps)
                x = x + ssm_lib.mamba2_block(p["mixer"], h, cfg)
                return partition.constrain(x, ("batch", "seq", "act_embed")), None

            body_fn = jax.checkpoint(body) if remat else body
            x, _ = self._scan(body_fn, x, params["layers"])
            aux = aux0
        elif fam == "hybrid":
            pattern = cfg.block_pattern

            def unit_body(x, unit):
                x = _barrier(x)
                unit = self._constrain_layer(unit)
                for i, kind in enumerate(pattern):
                    x = self._hybrid_layer(unit[i], x, positions, kind)
                return x, None

            body_fn = jax.checkpoint(unit_body) if remat else unit_body
            x, _ = self._scan(body_fn, x, params["layers"])
            for i, p in enumerate(params.get("rem_layers", ())):
                x = self._hybrid_layer(p, x, positions, pattern[i])
            aux = aux0
        elif fam == "encdec":
            enc = self._encode(params, batch["frames"], remat=remat)

            def body(x, p):
                x = _barrier(x)
                p = self._constrain_layer(p)
                x = self._decoder_layer(p, x, positions, enc)
                return x, None

            body_fn = jax.checkpoint(body) if remat else body
            x, _ = self._scan(body_fn, x, params["layers"])
            aux = aux0
        else:
            raise ValueError(fam)

        x = _norm(params["final_norm"], x, self.norm_kind, cfg.norm_eps)
        return x, aux

    def _encode(self, params: Params, frames: jax.Array, *,
                remat: bool = True) -> jax.Array:
        """Whisper encoder over precomputed frame embeddings (stub frontend)."""
        cfg = self.cfg
        F = frames.shape[1]
        pos_table = jnp.asarray(sinusoidal_positions(F, cfg.d_model))
        x = frames.astype(COMPUTE_DTYPE) + pos_table.astype(COMPUTE_DTYPE)
        x = partition.constrain(x, ("batch", "seq", "act_embed"))

        def body(x, p):
            x = _barrier(x)
            p = self._constrain_layer(p, "enc_layers")
            x, _ = self._attn_mlp_layer(p, x, None, causal=False, rope=False)
            return x, None

        body_fn = jax.checkpoint(body) if remat else body
        x, _ = self._scan(body_fn, x, params["enc_layers"])
        return _norm(params["enc_norm"], x, "ln", cfg.norm_eps)

    def _decoder_layer(self, p: Params, x, positions, enc):
        cfg = self.cfg
        h = _norm(p["ln1"], x, "ln", cfg.norm_eps)
        x = x + attn_lib.attention(p["self"], h, cfg, positions=positions,
                                   causal=True)
        h = _norm(p["ln2"], x, "ln", cfg.norm_eps)
        x = x + attn_lib.attention(p["cross"], h, cfg, kv_x=enc, rope=False)
        h = _norm(p["ln3"], x, "ln", cfg.norm_eps)
        x = x + mlp(p["mlp"], h, cfg.mlp_type)
        return partition.constrain(x, ("batch", "seq", "act_embed"))

    # ----- loss ----------------------------------------------------------------
    def head_matrix(self, params: Params) -> jax.Array:
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["head"]

    def _mask_pad_logits(self, logits: jax.Array) -> jax.Array:
        v = self.cfg.vocab_size
        if logits.shape[-1] == v:
            return logits
        return jnp.where(jnp.arange(logits.shape[-1]) >= v, -1e30, logits)

    def loss_fn(self, params: Params, batch: Dict[str, jax.Array], *,
                remat: bool = True) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        x, aux = self.forward(params, batch, remat=remat)
        labels = batch["labels"]
        mask = batch.get("mask")
        if cfg.family == "vlm":
            pmask = (jnp.arange(labels.shape[1]) >= cfg.n_patches)[None, :]
            mask = pmask if mask is None else (mask * pmask)
        ce = chunked_cross_entropy(x, self.head_matrix(params), labels, mask,
                                   unroll=self.unroll,
                                   valid_vocab=cfg.vocab_size)
        loss = ce + 1e-2 * aux
        return loss, {"ce": ce, "aux": aux}

    # ----- decode cache ----------------------------------------------------------
    def cache_window(self, max_seq: int) -> int:
        cfg = self.cfg
        if cfg.sliding_window:
            return min(max_seq, cfg.sliding_window)
        return max_seq

    def init_cache(self, batch: int, max_seq: int):
        """Zeroed decode cache + matching logical-axes pytree."""
        cfg = self.cfg
        fam = cfg.family
        kv_axes = ("layers", "batch", "cache_seq", None, None)

        def kv(n_layers, window):
            shape = (n_layers, batch, window, cfg.n_kv_heads, cfg.head_dim_)
            return (jnp.zeros(shape, COMPUTE_DTYPE),
                    jnp.zeros(shape, COMPUTE_DTYPE))

        if fam in ("dense", "vlm", "moe"):
            W = self.cache_window(max_seq)
            k, v = kv(cfg.n_layers, W)
            return ({"k": k, "v": v}, {"k": kv_axes, "v": kv_axes})
        if fam == "ssm":
            (conv, ssm_st), (ca, sa) = ssm_lib.init_mamba2_state(cfg, batch)
            L = cfg.n_layers
            return ({"conv": jnp.broadcast_to(conv, (L,) + conv.shape),
                     "ssm": jnp.broadcast_to(ssm_st, (L,) + ssm_st.shape)},
                    {"conv": ("layers",) + ca, "ssm": ("layers",) + sa})
        if fam == "hybrid":
            pattern = cfg.block_pattern
            n_units, rem = divmod(cfg.n_layers, len(pattern))
            W = min(max_seq, cfg.local_window)
            (conv, h), (ca, ha) = rglru_lib.init_rglru_state(cfg, batch)

            def unit_cache(n):
                c, a = [], []
                for kind in pattern:
                    if kind == "rec":
                        c.append({"conv": jnp.broadcast_to(conv, (n,) + conv.shape),
                                  "h": jnp.broadcast_to(h, (n,) + h.shape)})
                        a.append({"conv": ("layers",) + ca, "h": ("layers",) + ha})
                    else:
                        kk, vv = kv(n, W)
                        c.append({"k": kk, "v": vv})
                        a.append({"k": kv_axes, "v": kv_axes})
                return tuple(c), tuple(a)

            cache, axes = unit_cache(n_units)
            rem_c, rem_a = [], []
            for i in range(rem):
                if pattern[i] == "rec":
                    rem_c.append({"conv": conv, "h": h})
                    rem_a.append({"conv": ca, "h": ha})
                else:
                    kk, vv = kv(1, W)
                    rem_c.append({"k": kk[0], "v": vv[0]})
                    rem_a.append({"k": kv_axes[1:], "v": kv_axes[1:]})
            return ({"units": cache, "rem": tuple(rem_c)},
                    {"units": axes, "rem": tuple(rem_a)})
        if fam == "encdec":
            k, v = kv(cfg.n_layers, max_seq)
            xshape = (cfg.n_layers, batch, cfg.n_frames, cfg.n_kv_heads,
                      cfg.head_dim_)
            return ({"k": k, "v": v,
                     "xk": jnp.zeros(xshape, COMPUTE_DTYPE),
                     "xv": jnp.zeros(xshape, COMPUTE_DTYPE)},
                    {"k": kv_axes, "v": kv_axes,
                     "xk": ("layers", "batch", None, None, None),
                     "xv": ("layers", "batch", None, None, None)})
        raise ValueError(fam)

    # ----- prefill -----------------------------------------------------------
    def prefill(self, params: Params, batch: Dict[str, jax.Array],
                max_seq: int):
        """Process a prompt, return (last-token logits [B, V], cache)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = embed_lookup(params["embed"], tokens)
        if cfg.family == "vlm":
            pe = batch["patch_embeds"].astype(x.dtype)
            x = jnp.concatenate([pe, x[:, cfg.n_patches:]], axis=1)
        positions = jnp.arange(S)[None, :]
        fam = cfg.family

        if fam in ("dense", "vlm", "moe"):
            W = self.cache_window(max_seq)
            prefix = cfg.n_patches if fam == "vlm" else 0

            def body(x, p):
                h = _norm(p["ln1"], x, "rms", cfg.norm_eps)
                out, (k, v) = attn_lib.attention_with_kv(
                    p["attn"], h, cfg, positions=positions,
                    window=cfg.sliding_window, bidirectional_prefix=prefix)
                x = x + out
                h = _norm(p["ln2"], x, "rms", cfg.norm_eps)
                if fam == "moe":
                    y, _ = moe_lib.moe_mlp(p["mlp"], h, cfg)
                    x = x + y
                else:
                    x = x + mlp(p["mlp"], h, cfg.mlp_type)
                x = partition.constrain(x, ("batch", "seq", "act_embed"))
                return x, attn_lib.pack_cache(k, v, W)

            x, kvs = self._scan(body, x, params["layers"])
            cache = {"k": kvs[0], "v": kvs[1]}
        elif fam == "ssm":
            def body(x, p):
                h = _norm(p["ln"], x, "rms", cfg.norm_eps)
                out, st = ssm_lib.mamba2_block(p["mixer"], h, cfg,
                                               return_state=True)
                x = partition.constrain(x + out, ("batch", "seq", "act_embed"))
                return x, st

            x, (convs, ssms) = self._scan(body, x, params["layers"])
            cache = {"conv": convs, "ssm": ssms}
        elif fam == "hybrid":
            pattern = cfg.block_pattern
            W = min(max_seq, cfg.local_window)

            def apply_layer(p, x, kind):
                h = _norm(p["ln1"], x, "rms", cfg.norm_eps)
                if kind == "rec":
                    out, st = rglru_lib.recurrent_block(p["block"], h, cfg,
                                                        return_state=True)
                    st = {"conv": st[0], "h": st[1]}
                else:
                    out, (k, v) = attn_lib.attention_with_kv(
                        p["block"], h, cfg, positions=positions,
                        window=cfg.local_window)
                    k, v = attn_lib.pack_cache(k, v, W)
                    st = {"k": k, "v": v}
                x = x + out
                h = _norm(p["ln2"], x, "rms", cfg.norm_eps)
                x = x + mlp(p["mlp"], h, cfg.mlp_type)
                return partition.constrain(x, ("batch", "seq", "act_embed")), st

            def unit_body(x, unit):
                sts = []
                for i, kind in enumerate(pattern):
                    x, st = apply_layer(unit[i], x, kind)
                    sts.append(st)
                return x, tuple(sts)

            x, unit_caches = self._scan(unit_body, x, params["layers"])
            rem_caches = []
            for i, p in enumerate(params.get("rem_layers", ())):
                x, st = apply_layer(p, x, pattern[i])
                rem_caches.append(st)
            cache = {"units": unit_caches, "rem": tuple(rem_caches)}
        elif fam == "encdec":
            enc = self._encode(params, batch["frames"], remat=False)

            def body(x, p):
                h = _norm(p["ln1"], x, "ln", cfg.norm_eps)
                out, (k, v) = attn_lib.attention_with_kv(
                    p["self"], h, cfg, positions=positions)
                x = x + out
                h = _norm(p["ln2"], x, "ln", cfg.norm_eps)
                xk, xv = attn_lib.project_kv(p["cross"], enc, cfg)
                x = x + attn_lib.attention(p["cross"], h, cfg, kv_x=enc,
                                           rope=False)
                h = _norm(p["ln3"], x, "ln", cfg.norm_eps)
                x = x + mlp(p["mlp"], h, cfg.mlp_type)
                x = partition.constrain(x, ("batch", "seq", "act_embed"))
                k, v = attn_lib.pack_cache(k, v, max_seq)
                return x, (k, v, xk, xv)

            x, (ks, vs, xks, xvs) = self._scan(body, x, params["layers"])
            cache = {"k": ks, "v": vs, "xk": xks, "xv": xvs}
        else:
            raise ValueError(fam)

        x = _norm(params["final_norm"], x, self.norm_kind, cfg.norm_eps)
        logits = (x[:, -1] @ self.head_matrix(params).astype(COMPUTE_DTYPE))
        return self._mask_pad_logits(logits.astype(jnp.float32)), cache

    # ----- decode -------------------------------------------------------------
    def decode_step(self, params: Params, cache, token: jax.Array,
                    pos: jax.Array):
        """One token.  token: [B] int32; pos: scalar int32 (current length).

        Returns (logits [B, V], new cache)."""
        cfg = self.cfg
        B = token.shape[0]
        x = embed_lookup(params["embed"], token[:, None])[:, 0]   # [B, d]
        fam = cfg.family

        if fam in ("dense", "vlm", "moe"):
            W = cache["k"].shape[2]

            def body(x, layer):
                # barrier: keeps per-layer weight/cache casts inside the
                # loop (CPU hoists them into whole-stack f32 copies).
                p, k, v = _barrier(layer)
                h = _norm(p["ln1"], x[:, None], "rms", cfg.norm_eps)[:, 0]
                out, k, v = attn_lib.decode_attn(p["attn"], h, cfg, k, v, pos, W)
                x = x + out
                h = _norm(p["ln2"], x[:, None], "rms", cfg.norm_eps)
                if fam == "moe":
                    y, _ = moe_lib.moe_mlp(p["mlp"], h, cfg)
                else:
                    y = mlp(p["mlp"], h, cfg.mlp_type)
                return x + y[:, 0], (k, v)

            x, (ks, vs) = self._scan(body, x,
                                       (params["layers"], cache["k"],
                                        cache["v"]))
            new_cache = {"k": ks, "v": vs}
        elif fam == "ssm":
            def body(x, layer):
                p, conv, ssm_st = _barrier(layer)
                h = _norm(p["ln"], x[:, None], "rms", cfg.norm_eps)[:, 0]
                out, (conv, ssm_st) = ssm_lib.mamba2_decode(
                    p["mixer"], h, cfg, (conv, ssm_st))
                return x + out, (conv, ssm_st)

            x, (convs, ssms) = self._scan(
                body, x, (params["layers"], cache["conv"], cache["ssm"]))
            new_cache = {"conv": convs, "ssm": ssms}
        elif fam == "hybrid":
            pattern = cfg.block_pattern

            def apply_layer(p, x, kind, st):
                h = _norm(p["ln1"], x[:, None], "rms", cfg.norm_eps)[:, 0]
                if kind == "rec":
                    out, (conv, hst) = rglru_lib.recurrent_block_decode(
                        p["block"], h, cfg, (st["conv"], st["h"]))
                    st = {"conv": conv, "h": hst}
                else:
                    W = st["k"].shape[1]
                    out, k, v = attn_lib.decode_attn(p["block"], h, cfg,
                                                     st["k"], st["v"], pos, W)
                    st = {"k": k, "v": v}
                x = x + out
                h = _norm(p["ln2"], x[:, None], "rms", cfg.norm_eps)
                x = x + mlp(p["mlp"], h, cfg.mlp_type)[:, 0]
                return x, st

            def unit_body(x, unit):
                ps, sts = _barrier(unit)
                new = []
                for i, kind in enumerate(pattern):
                    x, st = apply_layer(ps[i], x, kind, sts[i])
                    new.append(st)
                return x, tuple(new)

            x, units = self._scan(unit_body, x,
                                    (params["layers"], cache["units"]))
            rem = []
            for i, p in enumerate(params.get("rem_layers", ())):
                x, st = apply_layer(p, x, pattern[i], cache["rem"][i])
                rem.append(st)
            new_cache = {"units": units, "rem": tuple(rem)}
        elif fam == "encdec":
            W = cache["k"].shape[2]

            def body(x, layer):
                p, k, v, xk, xv = _barrier(layer)
                h = _norm(p["ln1"], x[:, None], "ln", cfg.norm_eps)[:, 0]
                out, k, v = attn_lib.decode_attn(p["self"], h, cfg, k, v, pos, W)
                x = x + out
                h = _norm(p["ln2"], x[:, None], "ln", cfg.norm_eps)[:, 0]
                out = attn_lib.decode_cross_attn(p["cross"], h, cfg, xk, xv)
                x = x + out
                h = _norm(p["ln3"], x[:, None], "ln", cfg.norm_eps)
                x = x + mlp(p["mlp"], h, cfg.mlp_type)[:, 0]
                return x, (k, v)

            x, (ks, vs) = self._scan(
                body, x, (params["layers"], cache["k"], cache["v"],
                          cache["xk"], cache["xv"]))
            new_cache = {"k": ks, "v": vs, "xk": cache["xk"],
                         "xv": cache["xv"]}
        else:
            raise ValueError(fam)

        x = _norm(params["final_norm"], x[:, None], self.norm_kind,
                  cfg.norm_eps)[:, 0]
        logits = (x @ self.head_matrix(params).astype(COMPUTE_DTYPE))
        logits = partition.constrain(logits.astype(jnp.float32),
                                     ("batch", "vocab"))
        return self._mask_pad_logits(logits), new_cache
