"""Unified model configuration covering all assigned architecture families.

One dataclass describes dense / MoE / SSM / hybrid / enc-dec / VLM stacks;
family-specific fields are simply unused elsewhere.  Exact assigned configs
live in ``repro/configs/<arch>.py``; reduced same-family configs for smoke
tests come from :meth:`ModelConfig.reduced`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // n_heads

    # attention
    qkv_bias: bool = False
    sliding_window: Optional[int] = None   # SWA window (h2o-danube)
    rope_theta: float = 10_000.0

    # mlp
    mlp_type: str = "swiglu"         # swiglu | squared_relu | gelu

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4

    # hybrid (recurrentgemma): block pattern, local-attention window
    rnn_width: Optional[int] = None
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    local_window: int = 2048

    # enc-dec (whisper): encoder stack + stubbed frontend length
    n_enc_layers: int = 0
    n_frames: int = 1500             # precomputed frame embeddings (stub)

    # VLM: stubbed patch-embedding prefix length
    n_patches: int = 0

    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # ---- derived ----------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding table and
        LM head shard evenly over a 16-wide TP axis (Megatron-style vocab
        padding; logits above ``vocab_size`` are masked to -inf)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim_

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim_

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def rnn_width_(self) -> int:
        return self.rnn_width if self.rnn_width else self.d_model

    def block_types(self) -> Tuple[str, ...]:
        """Per-layer block kinds for hybrid stacks (pattern, truncated)."""
        if not self.block_pattern:
            return tuple(["attn"] * self.n_layers)
        reps = -(-self.n_layers // len(self.block_pattern))
        return tuple((self.block_pattern * reps)[: self.n_layers])

    # ---- parameter counting (for 6ND MODEL_FLOPS and napkin math) ---------
    def param_count(self, active_only: bool = False) -> int:
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        kinds = self.block_types()
        for kind in kinds if self.family == "hybrid" else ["x"] * self.n_layers:
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.family == "hybrid":
                r = self.rnn_width_
                blk = (2 * d * r + r * d + 3 * r * r + r) if kind == "rec" else attn
                mlp = 3 * d * ff if self.mlp_type in ("swiglu", "gelu") else 2 * d * ff
                per_layer += blk + mlp
                continue
            if self.family == "ssm":
                di, n, h = self.d_inner, self.ssm_state, self.n_ssm_heads
                per_layer += (d * (2 * di + 2 * n + h) + di * d
                              + self.conv_width * (di + 2 * n))
                continue
            mlp_mult = 3 if self.mlp_type == "swiglu" else 2
            if self.n_experts:
                e = self.top_k if active_only else self.n_experts
                mlp = e * mlp_mult * d * ff + d * self.n_experts
            else:
                mlp = mlp_mult * d * ff
            per_layer += attn + mlp
        enc = 0
        if self.n_enc_layers:
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            mlp = (3 if self.mlp_type == "swiglu" else 2) * d * ff
            enc = self.n_enc_layers * (attn + mlp)
            # decoder cross-attention
            per_layer_cross = attn
            enc += self.n_layers * per_layer_cross
        return emb + per_layer + enc

    # ---- smoke-test reduction ---------------------------------------------
    def reduced(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        pattern = self.block_pattern
        n_layers = max(2, len(pattern)) if pattern else 2
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=16,
            d_ff=96 if not self.n_experts else 32,
            vocab_size=256,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16,
            ssm_chunk=16,
            rnn_width=64 if self.rnn_width else None,
            local_window=32,
            sliding_window=32 if self.sliding_window else None,
            n_enc_layers=2 if self.n_enc_layers else 0,
            n_frames=24 if self.n_enc_layers else 1500,
            n_patches=8 if self.n_patches else 0,
        )
