"""Mamba2 SSD (state-space duality) blocks: chunked train/prefill scan and
O(1)-state decode (arXiv:2405.21060).

The SSD layer computes, per head h with state size N and head dim P::

    s_t = exp(dt_t * A_h) * s_{t-1} + dt_t * B_t x_t^T      (s in R^{P x N})
    y_t = C_t s_t + D_h x_t

Training/prefill uses the chunked dual form: split the sequence into chunks
of Q tokens; inside a chunk the contribution is a masked "attention"
``(C B^T ⊙ L)`` with the decay matrix ``L[i,j] = exp(cum_i - cum_j)``;
across chunks a short ``lax.scan`` carries the [H, P, N] chunk states.  The
intra-chunk einsums are MXU-shaped (Q x Q x N / Q x N x P) — they are the
Pallas ``ssd_scan`` kernel's oracle (``repro/kernels/ref.py``).

Decode carries ``(conv_state, ssm_state)`` per layer — constant memory in
sequence length, which is what makes the ``long_500k`` cell runnable.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import partition
from repro.models.config import ModelConfig
from repro.models.layers import COMPUTE_DTYPE, ParamBuilder, Params, rms_norm


def init_mamba2(b: ParamBuilder, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.n_ssm_heads
    conv_dim = di + 2 * n  # conv over (x, B, C)
    return {
        # in_proj packs (z, x, B, C, dt)
        "in_proj": b.param("in_proj", (d, 2 * di + 2 * n + h),
                           ("embed", "inner"), scale=0.02),
        "conv_w": b.param("conv_w", (cfg.conv_width, conv_dim),
                          (None, "inner"), scale=0.02),
        "conv_b": b.param("conv_b", (conv_dim,), ("inner",), init="zeros"),
        "a_log": b.param("a_log", (h,), (None,), init="uniform", scale=1.0),
        "d_skip": b.param("d_skip", (h,), (None,), init="ones"),
        "dt_bias": b.param("dt_bias", (h,), (None,), init="zeros"),
        "norm": b.param("norm", (di,), ("inner",), init="zeros"),
        "out_proj": b.param("out_proj", (di, d), ("inner", "embed"), scale=0.02),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv.  x: [B, S, Cdim]; w: [W, Cdim].

    ``state``: [B, W-1, Cdim] trailing context (decode); None => zero-pad."""
    W = w.shape[0]
    if state is None:
        x_pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        x_pad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(x_pad[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(x.dtype)


def segsum(dA: jax.Array) -> jax.Array:
    """Lower-triangular pairwise decay exponents.

    dA: [..., Q] -> L_exp [..., Q, Q] with L_exp[i, j] = sum_{j < m <= i} dA_m
    for i >= j, -inf above the diagonal."""
    q = dA.shape[-1]
    cum = jnp.cumsum(dA, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b_in: jax.Array,
                c_in: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: [B, S, H, P]; dt: [B, S, H] (already softplus'd);
    a: [H] (negative); b_in/c_in: [B, S, N] (single group, broadcast over H).
    Returns (y [B, S, H, P], final_state [B, H, P, N])."""
    B, S, H, P = x.shape
    N = b_in.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    NC = S // Q

    cd = x.dtype  # matmul dtype follows the activations (bf16 in prod)
    dA = (dt * a).astype(jnp.float32)                          # [B, S, H]
    xd = (x * dt[..., None]).astype(cd)                        # dt-weighted input

    xc = xd.reshape(B, NC, Q, H, P)
    dAc = dA.reshape(B, NC, Q, H)
    bc = b_in.reshape(B, NC, Q, N).astype(cd)
    cc = c_in.reshape(B, NC, Q, N).astype(cd)

    # --- intra-chunk (diagonal blocks): (C B^T ⊙ L) X
    L = jnp.exp(segsum(dAc.transpose(0, 1, 3, 2)))             # [B,NC,H,Q,Q]
    scores = jnp.einsum("bcqn,bckn->bcqk", cc, bc,
                        preferred_element_type=jnp.float32)    # [B,NC,Q,Q]
    m = scores[:, :, None, :, :] * L                           # [B,NC,H,Q,Q]
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", m.astype(cd), xc,
                        preferred_element_type=jnp.float32)

    # --- chunk states: S_c_local = sum_k exp(cum_last - cum_k) B_k xd_k^T
    cum = jnp.cumsum(dAc, axis=2)                              # [B,NC,Q,H]
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)            # [B,NC,Q,H]
    states = jnp.einsum("bckn,bckh,bckhp->bchpn", bc,
                        decay_states.astype(cd), xc,
                        preferred_element_type=jnp.float32)    # [B,NC,H,P,N]

    # --- inter-chunk recurrence.
    chunk_decay = jnp.exp(cum[:, :, -1, :])                    # [B,NC,H]
    s0 = (jnp.zeros((B, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit the state *entering* the chunk

    final, prev_states = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)         # [B,NC,H,P,N]

    # --- state -> output within each chunk.
    state_decay = jnp.exp(cum)                                 # [B,NC,Q,H]
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", cc,
                       prev_states.astype(cd),
                       state_decay.astype(cd),
                       preferred_element_type=jnp.float32)

    y = (y_diag + y_off).reshape(B, S, H, P)
    return y.astype(x.dtype), final


def ssd_reference(x, dt, a, b_in, c_in) -> Tuple[jax.Array, jax.Array]:
    """Token-by-token recurrence oracle (tests): O(S) sequential scan."""
    B, S, H, P = x.shape
    N = b_in.shape[-1]

    def step(s, inp):
        xt, dtt, bt, ct = inp  # [B,H,P], [B,H], [B,N], [B,N]
        decay = jnp.exp(dtt * a)[..., None, None]              # [B,H,1,1]
        s = s * decay + jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], bt)
        y = jnp.einsum("bhpn,bn->bhp", s, ct)
        return s, y

    s0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (x.astype(jnp.float32).transpose(1, 0, 2, 3),
          dt.astype(jnp.float32).transpose(1, 0, 2),
          b_in.astype(jnp.float32).transpose(1, 0, 2),
          c_in.astype(jnp.float32).transpose(1, 0, 2))
    final, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), final


def mamba2_block(params: Params, x: jax.Array, cfg: ModelConfig, *,
                 state: Optional[Tuple[jax.Array, jax.Array]] = None,
                 return_state: bool = False):
    """Full mamba2 block.  x: [B, S, d].

    ``state``: (conv_state [B, W-1, conv_dim], ssm_state [B, H, P, N]) for
    decode continuation.  Returns y or (y, new_state)."""
    B, S, d = x.shape
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    zxbcdt = x @ partition.wcast(params["in_proj"], COMPUTE_DTYPE,
                                 ("embed", "inner"))
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)

    conv_state, ssm_state = state if state is not None else (None, None)
    new_conv = None
    if return_state:
        W = cfg.conv_width
        hist = xbc if conv_state is None else jnp.concatenate(
            [conv_state.astype(xbc.dtype), xbc], axis=1)
        new_conv = hist[:, -(W - 1):, :]
        if hist.shape[1] < W - 1:  # left-pad short prefills
            new_conv = jnp.pad(hist, ((0, 0), (W - 1 - hist.shape[1], 0), (0, 0)))
    xbc = _causal_conv(xbc, params["conv_w"].astype(COMPUTE_DTYPE),
                       params["conv_b"].astype(COMPUTE_DTYPE), conv_state)

    xs, b_in, c_in = jnp.split(xbc, [di, di + n], axis=-1)
    xs = partition.constrain(xs, ("batch", "seq", "inner"))
    xs = xs.reshape(B, S, h, p)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))

    y, final_state = ssd_chunked(xs, dt, a, b_in, c_in, cfg.ssm_chunk,
                                 init_state=ssm_state)
    y = y + xs.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)[:, None]
    y = y.reshape(B, S, di).astype(COMPUTE_DTYPE)

    # gated RMSNorm then out projection
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(COMPUTE_DTYPE),
                 params["norm"], cfg.norm_eps)
    out = y @ partition.wcast(params["out_proj"], COMPUTE_DTYPE,
                              ("inner", "embed"))
    if return_state:
        return out, (new_conv.astype(COMPUTE_DTYPE), final_state)
    return out


def mamba2_decode(params: Params, x: jax.Array, cfg: ModelConfig,
                  state: Tuple[jax.Array, jax.Array]):
    """Single-token decode.  x: [B, d]; state as in :func:`mamba2_block`.

    Fully recurrent: O(1) in the sequence length."""
    B, d = x.shape
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    conv_state, ssm_state = state
    zxbcdt = x @ params["in_proj"].astype(COMPUTE_DTYPE)
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)

    # conv ring update
    hist = jnp.concatenate([conv_state.astype(xbc.dtype), xbc[:, None, :]], 1)
    new_conv = hist[:, 1:, :]
    w = params["conv_w"].astype(COMPUTE_DTYPE)
    conv_out = jnp.sum(hist * w[None], axis=1) + params["conv_b"].astype(COMPUTE_DTYPE)
    xbc = jax.nn.silu(conv_out.astype(jnp.float32)).astype(COMPUTE_DTYPE)

    xs, b_in, c_in = jnp.split(xbc, [di, di + n], axis=-1)
    xs = xs.reshape(B, h, p)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))     # [B, h]

    decay = jnp.exp(dt * a)[..., None, None]                          # [B,h,1,1]
    upd = jnp.einsum("bhp,bn->bhpn", xs.astype(jnp.float32) * dt[..., None],
                     b_in.astype(jnp.float32))
    new_ssm = ssm_state * decay + upd
    y = jnp.einsum("bhpn,bn->bhp", new_ssm, c_in.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)[:, None]
    y = y.reshape(B, di).astype(COMPUTE_DTYPE)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(COMPUTE_DTYPE),
                 params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"].astype(COMPUTE_DTYPE)
    return out, (new_conv, new_ssm)


def init_mamba2_state(cfg: ModelConfig, batch: int):
    """Zeroed decode state (+ logical axes)."""
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    conv = jnp.zeros((batch, cfg.conv_width - 1, conv_dim), COMPUTE_DTYPE)
    ssm = jnp.zeros((batch, cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                    jnp.float32)
    axes = (("batch", None, "inner"), ("batch", None, None, None))
    return (conv, ssm), axes
