"""Shared building blocks: parameter builder, norms, RoPE, MLPs.

Parameters are plain nested dicts of ``jnp`` arrays; :class:`ParamBuilder`
creates them *and* records a parallel tree of logical-axes tuples, so the
launcher can derive shardings without a second source of truth.  All forward
code is pure functions over the params dict - vmappable, scannable, and
`jax.eval_shape`-able (the dry-run never allocates real parameters).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import partition

Params = Dict[str, Any]

PARAM_DTYPE = jnp.float32     # master weights
COMPUTE_DTYPE = jnp.bfloat16  # activations / matmul inputs


class ParamBuilder:
    """Creates parameters and records their logical axes.

    >>> b = ParamBuilder(jax.random.key(0))
    >>> w = b.param("w", (64, 128), ("embed", "ff"))
    >>> b.axes["w"] == ("embed", "ff")
    """

    def __init__(self, key: jax.Array, prefix: str = ""):
        self._key = key
        self.prefix = prefix
        self.axes: Dict[str, Any] = {}

    def next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(self, name: str, shape: Tuple[int, ...], axes: Tuple,
              init: str = "normal", scale: float = 0.02,
              dtype=PARAM_DTYPE) -> jax.Array:
        assert len(shape) == len(axes), (name, shape, axes)
        self.axes[name] = tuple(axes)
        if init == "normal":
            return (jax.random.normal(self.next_key(), shape, dtype) * scale)
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if init == "uniform":  # U(scale_lo, scale_hi) packed into `scale`
            return jax.random.uniform(self.next_key(), shape, dtype,
                                      minval=0.0, maxval=scale)
        raise ValueError(init)

    def child(self, name: str) -> "ParamBuilder":
        sub = ParamBuilder(self.next_key())
        sub._parent, sub._name = self, name  # type: ignore[attr-defined]
        return sub

    def adopt(self, name: str, sub: "ParamBuilder", params: Params) -> Params:
        self.axes[name] = sub.axes
        return params


def init_stacked(key: jax.Array, n: int, fn):
    """Initialize ``n`` identical layers stacked on a leading axis via vmap.

    ``fn(builder) -> params``; returns ``(params, axes)`` where every array
    gains a leading "layers" axis and every axes tuple a leading "layers"
    entry.  The stacked layout is what lets the model run the layer stack as
    one ``lax.scan`` - a single HLO while-body regardless of depth.
    """
    probe = ParamBuilder(jax.random.key(0))
    fn(probe)  # record axes once

    def one(k):
        return fn(ParamBuilder(k))

    params = jax.vmap(one)(jax.random.split(key, n))
    axes = jax.tree.map(lambda a: ("layers",) + a, probe.axes,
                        is_leaf=lambda x: isinstance(x, tuple))
    return params, axes


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embedding.
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # [head_dim // 2]


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> np.ndarray:
    """Fixed sinusoidal table (whisper frontend positions)."""
    pos = np.arange(n)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    angle = pos / np.power(10_000.0, dim / d)
    out = np.zeros((n, d), np.float32)
    out[:, 0::2] = np.sin(angle)
    out[:, 1::2] = np.cos(angle)
    return out


# ---------------------------------------------------------------------------
# MLPs.
# ---------------------------------------------------------------------------


def init_mlp(b: ParamBuilder, d: int, ff: int, mlp_type: str) -> Params:
    if mlp_type in ("swiglu", "geglu"):
        return {
            "wi": b.param("wi", (d, 2 * ff), ("embed", "ff"), scale=0.02),
            "wo": b.param("wo", (ff, d), ("ff", "embed"), scale=0.02),
        }
    if mlp_type in ("squared_relu", "gelu"):
        return {
            "wi": b.param("wi", (d, ff), ("embed", "ff"), scale=0.02),
            "wo": b.param("wo", (ff, d), ("ff", "embed"), scale=0.02),
        }
    raise ValueError(mlp_type)


def mlp(params: Params, x: jax.Array, mlp_type: str) -> jax.Array:
    wi = partition.wcast(params["wi"], COMPUTE_DTYPE, ("embed", "ff"))
    wo = partition.wcast(params["wo"], COMPUTE_DTYPE, ("ff", "embed"))
    h = x @ wi
    if mlp_type in ("swiglu", "geglu"):
        gate, up = jnp.split(h, 2, axis=-1)
        act = jax.nn.silu if mlp_type == "swiglu" else jax.nn.gelu
        h = act(gate.astype(jnp.float32)).astype(COMPUTE_DTYPE) * up
    elif mlp_type == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    elif mlp_type == "gelu":
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(COMPUTE_DTYPE)
    h = partition.constrain(h, ("batch", "seq", "ff"))
    return h @ wo


# ---------------------------------------------------------------------------
# Embedding / unembedding with vocab-parallel cross-entropy.
# ---------------------------------------------------------------------------


def embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    out = jnp.take(table.astype(COMPUTE_DTYPE), tokens, axis=0)
    return partition.constrain(out, ("batch", "seq", "act_embed"))


def unembed(x: jax.Array, head: jax.Array) -> jax.Array:
    """Logits in f32; vocab dim carries the "vocab" logical axis (TP)."""
    logits = x @ head.astype(COMPUTE_DTYPE)
    logits = partition.constrain(logits.astype(jnp.float32),
                                 ("batch", "seq", "vocab"))
    return logits


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token CE over valid positions.  ``logits`` may be sharded on
    the vocab dim - the log-softmax reductions stay in the global view so the
    partitioner inserts the (small) cross-shard reductions."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
