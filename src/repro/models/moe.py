"""Mixture-of-Experts layer: top-k router with capacity-based einsum dispatch.

The dispatch follows the GShard/Mesh-TF formulation, which the XLA SPMD
partitioner handles robustly: tokens are flattened and re-grouped into
``[G, T, d]`` groups (``G`` inherits the batch sharding), the router picks
``top_k`` experts per token, and two one-hot tensors ``dispatch``/``combine``
of shape ``[G, T, E, C]`` route tokens into per-expert buffers
``[E, G, C, d]`` (``E`` sharded over the model axis => expert parallelism;
the G<->E resharding lowers to an all-to-all-like collective schedule).

The one-hot dispatch is O(T * E * C) = O(k * cf * T^2) per group, so the
group size ``T`` bounds the routing overhead; with the default T=256 the
dispatch einsums cost <10% of the expert FLOPs for both assigned MoE archs.
A shard_map all-to-all dispatch (no one-hot) is the §Perf iteration.

Aux losses: the standard load-balance loss (Shazeer/Switch ``E * sum f_e p_e``)
and router z-loss, returned for the trainer to weigh in.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro import partition
from repro.models.config import ModelConfig
from repro.models.layers import COMPUTE_DTYPE, ParamBuilder, Params

DEFAULT_GROUP = 256


def init_moe(b: ParamBuilder, cfg: ModelConfig) -> Params:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    mult = 2 if cfg.mlp_type in ("swiglu", "geglu") else 1
    # Expert dim carries the model axis (EP); the per-expert ff dim must NOT
    # also map to "model", hence the separate "expert_ff" logical axis.
    return {
        "router": b.param("router", (d, E), ("embed", "expert"), scale=0.02),
        "wi": b.param("wi", (E, d, mult * ff), ("expert", "embed", "expert_ff"),
                      scale=0.02),
        "wo": b.param("wo", (E, ff, d), ("expert", "expert_ff", "embed"),
                      scale=0.02),
    }


def _group(n_tokens: int, group: int) -> int:
    """Largest group size <= ``group`` dividing ``n_tokens``."""
    t = min(group, n_tokens)
    while n_tokens % t:
        t -= 1
    return t


def _capacity(t: int, k: int, n_experts: int, cf: float) -> int:
    return max(1, int(-(-(k * t * cf) // n_experts)))  # ceil


def moe_mlp(params: Params, x: jax.Array, cfg: ModelConfig, *,
            group: int = DEFAULT_GROUP) -> Tuple[jax.Array, jax.Array]:
    """Apply the MoE MLP.  x: [B, S, d] -> ([B, S, d], aux_loss scalar)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    N = B * S
    T = _group(N, group)
    G = N // T
    C = _capacity(T, k, E, cfg.capacity_factor)

    xg = x.reshape(G, T, d)
    xg = partition.constrain(xg, ("batch", None, "act_embed"))

    # --- Router (f32 for numerics).
    logits = (xg.astype(jnp.float32)
              @ params["router"].astype(jnp.float32))          # [G, T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                        # [G, T, k]
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    # --- Aux losses.
    # load balance: E * sum_e (fraction routed to e) * (mean prob of e)
    sel1 = jax.nn.one_hot(eidx[..., 0], E, dtype=jnp.float32)   # top-1 fraction
    load = jnp.mean(sel1, axis=(0, 1))
    importance = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(load * importance)
    zloss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = aux + 1e-3 * zloss

    # --- Position-in-expert (capacity cut-off), priority = (t, k) order.
    sel = jax.nn.one_hot(eidx, E, dtype=jnp.int32)              # [G, T, k, E]
    flatsel = sel.reshape(G, T * k, E)
    pos = jnp.cumsum(flatsel, axis=1) - flatsel                 # tokens ahead
    pos = jnp.sum(pos.reshape(G, T, k, E) * sel, axis=-1)       # [G, T, k]
    keep = pos < C

    # --- dispatch / combine one-hots, built per-k to bound transients.
    flat_idx = eidx * C + jnp.minimum(pos, C - 1)               # [G, T, k]
    dispatch = jnp.zeros((G, T, E * C), COMPUTE_DTYPE)
    combine = jnp.zeros((G, T, E * C), jnp.float32)
    for i in range(k):
        hot = jax.nn.one_hot(flat_idx[..., i], E * C, dtype=jnp.float32)
        hot = hot * keep[..., i, None]
        dispatch = dispatch + hot.astype(COMPUTE_DTYPE)
        combine = combine + hot * gate[..., i, None]
    dispatch = dispatch.reshape(G, T, E, C)
    combine = combine.reshape(G, T, E, C)

    # --- Expert buffers: [E, G, C, d]; E carries the "expert" (model) axis.
    expert_in = jnp.einsum("gtec,gtd->egcd", dispatch,
                           xg.astype(COMPUTE_DTYPE),
                           preferred_element_type=COMPUTE_DTYPE)
    expert_in = partition.constrain(expert_in, ("expert", "batch", None, None))

    wi = partition.wcast(params["wi"], COMPUTE_DTYPE,
                         ("expert", "embed", "expert_ff"))
    wo = partition.wcast(params["wo"], COMPUTE_DTYPE,
                         ("expert", "expert_ff", "embed"))
    h = jnp.einsum("egcd,edf->egcf", expert_in, wi,
                   preferred_element_type=COMPUTE_DTYPE)
    if cfg.mlp_type in ("swiglu", "geglu"):
        g_, u_ = jnp.split(h, 2, axis=-1)
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
        h = act(g_.astype(jnp.float32)).astype(COMPUTE_DTYPE) * u_
    elif cfg.mlp_type == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(COMPUTE_DTYPE)
    h = partition.constrain(h, ("expert", "batch", None, "expert_ff"))
    expert_out = jnp.einsum("egcf,efd->egcd", h, wo,
                            preferred_element_type=COMPUTE_DTYPE)

    y = jnp.einsum("gtec,egcd->gtd", combine.astype(COMPUTE_DTYPE), expert_out,
                   preferred_element_type=COMPUTE_DTYPE)
    y = partition.constrain(y, ("batch", None, "act_embed"))
    return y.reshape(B, S, d), aux


def moe_mlp_dense_ref(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Oracle: route every token through its top-k experts densely (no
    capacity drop).  Used by tests to bound the capacity-dispatch error."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(B * S, d).astype(jnp.float32)
    logits = xf @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)
    wi = params["wi"].astype(jnp.float32)
    wo = params["wo"].astype(jnp.float32)

    def expert_fn(e, t):
        h = t @ wi[e]
        if cfg.mlp_type in ("swiglu", "geglu"):
            g_, u_ = jnp.split(h, 2, axis=-1)
            act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
            h = act(g_) * u_
        elif cfg.mlp_type == "squared_relu":
            h = jnp.square(jax.nn.relu(h))
        else:
            h = jax.nn.gelu(h)
        return h @ wo[e]

    out = jnp.zeros_like(xf)
    for i in range(k):
        per_tok = jax.vmap(expert_fn)(eidx[:, i], xf[:, None, :])[:, 0]
        out = out + gate[:, i, None] * per_tok
    return out.reshape(B, S, d).astype(x.dtype)
