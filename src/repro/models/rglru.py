"""RG-LRU recurrent blocks (RecurrentGemma / Griffin, arXiv:2402.19427).

The recurrence is a gated diagonal linear RNN::

    r_t = sigmoid(W_a x_t + b_a)           (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)           (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t) (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t^2) ⊙ (i_t ⊙ x_t)

Being *linear* in ``h``, it maps onto ``jax.lax.associative_scan`` — the
parallel-prefix formulation is what keeps the 500k-token hybrid cell
sub-quadratic.  Decode is a single fused elementwise update (O(1) state).

The full recurrent block (as in Griffin) is two branches: a GeLU gate
branch, and a (linear -> causal conv1d -> RG-LRU) branch, merged
multiplicatively and projected back to ``d_model``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import partition
from repro.models.config import ModelConfig
from repro.models.layers import COMPUTE_DTYPE, ParamBuilder, Params

C_FACTOR = 8.0


def init_rglru_block(b: ParamBuilder, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    r = cfg.rnn_width_
    return {
        "w_gate": b.param("w_gate", (d, r), ("embed", "inner"), scale=0.02),
        "w_in": b.param("w_in", (d, r), ("embed", "inner"), scale=0.02),
        "conv_w": b.param("conv_w", (cfg.conv_width, r), (None, "inner"),
                          scale=0.02),
        "conv_b": b.param("conv_b", (r,), ("inner",), init="zeros"),
        # RG-LRU gates (first dim replicated: both dims on the model axis
        # would double-assign the mesh axis)
        "wa": b.param("wa", (r, r), (None, "inner"), scale=0.02),
        "ba": b.param("ba", (r,), ("inner",), init="zeros"),
        "wx": b.param("wx", (r, r), (None, "inner"), scale=0.02),
        "bx": b.param("bx", (r,), ("inner",), init="zeros"),
        "lam": b.param("lam", (r,), ("inner",), init="uniform", scale=1.0),
        "w_out": b.param("w_out", (r, d), ("inner", "embed"), scale=0.02),
    }


def _gates(params: Params, x: jax.Array):
    """(a_t, beta_t * i_t ⊙ x_t) for the linear recurrence, in f32."""
    xf = x.astype(jnp.float32)
    r_gate = jax.nn.sigmoid(xf @ params["wa"].astype(jnp.float32)
                            + params["ba"].astype(jnp.float32))
    i_gate = jax.nn.sigmoid(xf @ params["wx"].astype(jnp.float32)
                            + params["bx"].astype(jnp.float32))
    log_a = -C_FACTOR * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r_gate
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    return a, beta * i_gate * xf


def rglru_scan(params: Params, x: jax.Array,
               h0: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Run the RG-LRU over a sequence with a parallel prefix scan.

    x: [B, S, r] -> (h [B, S, r], h_last [B, r])."""
    a, b_term = _gates(params, x)

    if h0 is not None:
        # Fold the initial state in as a virtual step 0 with a=1 gain.
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        b_term = jnp.concatenate([h0.astype(jnp.float32)[:, None], b_term], 1)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b_term), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    return h.astype(x.dtype), h[:, -1]


def rglru_step(params: Params, x: jax.Array, h_prev: jax.Array) -> jax.Array:
    """One decode step.  x: [B, r]; h_prev: [B, r] -> h [B, r]."""
    a, b_term = _gates(params, x[:, None, :])
    return (a[:, 0] * h_prev.astype(jnp.float32) + b_term[:, 0])


def _causal_conv(x: jax.Array, w: jax.Array, bias: jax.Array,
                 state: Optional[jax.Array] = None) -> jax.Array:
    W = w.shape[0]
    if state is None:
        x_pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        x_pad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(x_pad[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    return out + bias


def recurrent_block(params: Params, x: jax.Array, cfg: ModelConfig, *,
                    state: Optional[Tuple[jax.Array, jax.Array]] = None,
                    return_state: bool = False):
    """Griffin recurrent block.  x: [B, S, d].

    ``state``: (conv_state [B, W-1, r], h [B, r])."""
    conv_state, h0 = state if state is not None else (None, None)
    gate = jax.nn.gelu((x @ partition.wcast(params["w_gate"], COMPUTE_DTYPE,
                                            ("embed", "inner")))
                       .astype(jnp.float32)).astype(COMPUTE_DTYPE)
    u = x @ partition.wcast(params["w_in"], COMPUTE_DTYPE,
                            ("embed", "inner"))
    u = partition.constrain(u, ("batch", "seq", "inner"))

    new_conv = None
    if return_state:
        W = cfg.conv_width
        hist = u if conv_state is None else jnp.concatenate(
            [conv_state.astype(u.dtype), u], axis=1)
        if hist.shape[1] < W - 1:
            hist = jnp.pad(hist, ((0, 0), (W - 1 - hist.shape[1], 0), (0, 0)))
        new_conv = hist[:, -(W - 1):, :]
    u = _causal_conv(u, params["conv_w"].astype(COMPUTE_DTYPE),
                     params["conv_b"].astype(COMPUTE_DTYPE), conv_state)

    h, h_last = rglru_scan(params, u, h0)
    y = (h * gate) @ partition.wcast(params["w_out"], COMPUTE_DTYPE,
                                     ("inner", "embed"))
    if return_state:
        return y, (new_conv.astype(COMPUTE_DTYPE), h_last)
    return y


def recurrent_block_decode(params: Params, x: jax.Array, cfg: ModelConfig,
                           state: Tuple[jax.Array, jax.Array]):
    """One-token decode.  x: [B, d] -> (y [B, d], new state)."""
    conv_state, h_prev = state
    gate = jax.nn.gelu((x @ params["w_gate"].astype(COMPUTE_DTYPE))
                       .astype(jnp.float32)).astype(COMPUTE_DTYPE)
    u = x @ params["w_in"].astype(COMPUTE_DTYPE)
    hist = jnp.concatenate([conv_state.astype(u.dtype), u[:, None, :]], 1)
    new_conv = hist[:, 1:, :]
    w = params["conv_w"].astype(COMPUTE_DTYPE)
    u = jnp.sum(hist * w[None], axis=1) + params["conv_b"].astype(COMPUTE_DTYPE)
    h = rglru_step(params, u, h_prev)
    y = (h.astype(COMPUTE_DTYPE) * gate) @ params["w_out"].astype(COMPUTE_DTYPE)
    return y, (new_conv, h)


def init_rglru_state(cfg: ModelConfig, batch: int):
    r = cfg.rnn_width_
    conv = jnp.zeros((batch, cfg.conv_width - 1, r), COMPUTE_DTYPE)
    h = jnp.zeros((batch, r), jnp.float32)
    axes = (("batch", None, "inner"), ("batch", "inner"))
    return (conv, h), axes


def rglru_reference(params: Params, x: jax.Array,
                    h0: Optional[jax.Array] = None) -> jax.Array:
    """Sequential-scan oracle for :func:`rglru_scan` (tests)."""
    a, b_term = _gates(params, x)
    B, S, r = x.shape
    h = jnp.zeros((B, r), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    out = []
    for t in range(S):
        h = a[:, t] * h + b_term[:, t]
        out.append(h)
    return jnp.stack(out, axis=1).astype(x.dtype)
