"""The paper's contribution: DVFS power/performance models, the single-task
optimum, and the EDL theta-readjustment schedulers (offline + online),
plus the accelerator-job adapter that feeds roofline-derived LM jobs into
the same algorithms."""

from repro.core import cluster, dvfs, jobs, online, scheduling, single_task, tasks
from repro.core.dvfs import DvfsParams, ScalingInterval, NARROW, WIDE
from repro.core.online import schedule_online
from repro.core.scheduling import schedule_offline
from repro.core.single_task import configure_tasks, solve_unconstrained, solve_with_deadline
from repro.core.tasks import TaskSet, app_library, generate_offline, generate_online

__all__ = [
    "DvfsParams", "ScalingInterval", "NARROW", "WIDE", "TaskSet",
    "app_library", "generate_offline", "generate_online",
    "configure_tasks", "solve_unconstrained", "solve_with_deadline",
    "schedule_offline", "schedule_online",
    "cluster", "dvfs", "jobs", "online", "scheduling", "single_task", "tasks",
]
