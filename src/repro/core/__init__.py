"""The paper's contribution: DVFS power/performance models, the single-task
optimum, and the EDL theta-readjustment schedulers (offline + online) over a
heterogeneous cluster of machine classes, plus the accelerator-job adapter
that feeds roofline-derived LM jobs into the same algorithms.

Architecture (top to bottom)::

    policies            scheduling.schedule_offline / online.schedule_online
                        (Algorithms 1-6: ordering, arrival grouping, result
                        assembly incl. the bounds.theoretical_bound e_bound
                        column; two thin drivers over one placement core)
        |
    placement           placement.PlacementContext - THE pair-selection
                        subsystem (per-class compact pools, batched
                        worst-fit frontier + theta-rows, pooled first/best
                        fit, per-task reference loop; offline == the
                        degenerate one-group-at-t=0 case)
        |
    machine classes     machines.MachineClass / REGISTRY - per-class task
                        constants + scaling box; configure_classes runs
                        Algorithm 1 on every class (one reference class ==
                        the homogeneous paper setup, bit-for-bit)
        |
    ClusterEngine       engine.ClusterEngine - ONE vectorized pair/server
                        state machine (numpy struct-of-arrays with a per-pair
                        class_id column, DRS sweeps, class-restricted
                        worst/best/first-fit selectors, per-class Eq. 6/7
                        finalizer)
        |
    DVFS solvers        single_task.configure_tasks / readjust_batch
                        (Algorithm 1; batched, padded to pow-2 shapes)
        |
    solve dedup/cache   solver_cache.solve_rows - unique-row dedup + the
                        process-wide LRU solve cache (bit-transparent;
                        dedup=True default on every solver entry point);
                        kernels/ops.dvfs_solve_matrix shards miss batches
                        across local devices
        |
    Pallas kernel       kernels/dvfs_opt.dvfs_solve_kernel - the use_kernel
                        fast path: one [n, 16] task matrix per dispatch
                        (per-row interval bounds -> all classes in one call),
                        hierarchical G0 -> G1 frequency sweeps in VMEM
                        (incl. the theta-readjustment case)

See docs/ARCHITECTURE.md for the full picture and docs/EQUATIONS.md for the
equation/algorithm -> code map.
"""

from repro.core import (bounds, cluster, dvfs, engine, jobs, machines,
                        online, placement, scheduling, single_task,
                        solver_cache, tasks)
from repro.core.bounds import theoretical_bound
from repro.core.dvfs import DvfsParams, ScalingInterval, NARROW, WIDE
from repro.core.engine import ClusterEngine
from repro.core.machines import REGISTRY, MachineClass
from repro.core.online import schedule_online
from repro.core.scheduling import schedule_offline
from repro.core.single_task import configure_tasks, solve_unconstrained, solve_with_deadline
from repro.core.tasks import TaskSet, app_library, generate_offline, generate_online

__all__ = [
    "DvfsParams", "ScalingInterval", "NARROW", "WIDE", "TaskSet",
    "ClusterEngine", "MachineClass", "REGISTRY",
    "app_library", "generate_offline", "generate_online",
    "configure_tasks", "solve_unconstrained", "solve_with_deadline",
    "schedule_offline", "schedule_online", "theoretical_bound",
    "bounds", "cluster", "dvfs", "engine", "jobs", "machines", "online",
    "placement", "scheduling", "single_task", "solver_cache", "tasks",
]
