"""Offline scheduling: the EDL theta-readjustment algorithm and baselines
(paper S4.2.1, Algorithms 1-3; baselines of S5.3).

All offline algorithms share the same three-phase structure:

1. **Algorithm 1** - per-task optimal DVFS configuration (deadline-aware);
   deadline-prior tasks get the boundary solution, energy-prior tasks get the
   unconstrained optimum.
2. **Task packing** - deadline-prior tasks are pinned to fresh pairs first
   (they must start at t=0), then the energy-prior tasks are placed in EDF
   order by the policy-specific rule:

   * ``edl``    - shortest-processing-time pair (worst fit) **with
     theta-readjustment**: if the task does not fit at its optimal length, its
     execution is allowed to shrink to ``max(theta * t_hat, t_min)`` by
     re-solving the DVFS setting with the remaining window as deadline
     (Algorithm 2, lines 16-19).
   * ``edf-wf`` - worst fit (min mu), no readjustment;
   * ``edf-bf`` - best fit (max mu among fitting pairs), no readjustment;
   * ``lpt-ff`` - longest-processing-time order, first fit, no readjustment.

3. **Algorithm 3** - pairs are sorted by finish time and grouped into servers
   of ``l``; idle energy is ``P_idle * sum_j sum_k (F_j - tau_kj)`` (Eq. 6).
"""

from __future__ import annotations

import heapq
from typing import Optional

import numpy as np

from repro.core import cluster as cl
from repro.core import dvfs, single_task
from repro.core.dvfs import DvfsParams, ScalingInterval
from repro.core.single_task import TaskConfig
from repro.core.tasks import TaskSet

_EPS = 1e-9


def default_config(task_set: TaskSet) -> TaskConfig:
    """A no-DVFS configuration: every task runs at (1, 1, 1)."""
    n = len(task_set)
    t_star = task_set.t_star
    p_star = task_set.p_star
    allowed = task_set.deadline - task_set.arrival
    ones = np.ones(n)
    return TaskConfig(
        v=ones.copy(), fc=ones.copy(), fm=ones.copy(),
        t_hat=t_star.copy(), p_hat=p_star.copy(), e_hat=(p_star * t_star),
        t_min=t_star.copy(),  # no scaling => no shrink room
        deadline_prior=(t_star > allowed + _EPS),
        feasible=(t_star <= allowed + _EPS),
        n_deadline_prior=int(np.sum(t_star > allowed + _EPS)),
    )


def configure(task_set: TaskSet, use_dvfs: bool,
              interval: ScalingInterval = dvfs.WIDE,
              use_kernel: bool = False) -> TaskConfig:
    """Algorithm 1 over a task set (or the no-DVFS default configuration)."""
    if not use_dvfs:
        return default_config(task_set)
    allowed = task_set.deadline - task_set.arrival
    return single_task.configure_tasks(task_set.params, allowed, interval,
                                       use_kernel=use_kernel)


def _assignment(task: int, pair: int, start: float, cfg: TaskConfig,
                override=None, readjusted=False) -> cl.Assignment:
    if override is None:
        v, fc, fm, t, p, e = (cfg.v[task], cfg.fc[task], cfg.fm[task],
                              cfg.t_hat[task], cfg.p_hat[task], cfg.e_hat[task])
    else:
        v, fc, fm, t, p, e = override
    return cl.Assignment(task=task, pair=pair, start=float(start),
                         finish=float(start + t), v=float(v), fc=float(fc),
                         fm=float(fm), power=float(p), energy=float(e),
                         readjusted=readjusted)


def schedule_offline(task_set: TaskSet, l: int = 1, theta: float = 1.0,
                     algorithm: str = "edl", use_dvfs: bool = True,
                     interval: ScalingInterval = dvfs.WIDE,
                     p_idle: float = cl.P_IDLE,
                     cfg: Optional[TaskConfig] = None,
                     use_kernel: bool = False) -> cl.ScheduleResult:
    """Run one offline scheduling algorithm end to end (Algorithms 1+2+3)."""
    algorithm = algorithm.lower()
    if algorithm not in ("edl", "edf-wf", "edf-bf", "lpt-ff"):
        raise ValueError(f"unknown offline algorithm {algorithm!r}")
    if cfg is None:
        cfg = configure(task_set, use_dvfs, interval, use_kernel=use_kernel)

    n = len(task_set)
    deadline = np.asarray(task_set.deadline, dtype=np.float64)
    assignments: list[cl.Assignment] = []
    violations = int(np.sum(~cfg.feasible))

    pair_mu: list[float] = []       # finish time per pair, indexed by pair id

    # --- Phase 2a: deadline-prior tasks, each started at t=0 on a fresh pair.
    dp_idx = np.nonzero(cfg.deadline_prior)[0]
    for t_idx in dp_idx[np.argsort(deadline[dp_idx], kind="stable")]:
        pid = len(pair_mu)
        pair_mu.append(float(cfg.t_hat[t_idx]))
        assignments.append(_assignment(int(t_idx), pid, 0.0, cfg))

    # --- Phase 2b: energy-prior tasks by the policy rule.
    ep_idx = np.nonzero(~cfg.deadline_prior)[0]
    if algorithm == "lpt-ff":
        order = ep_idx[np.argsort(-cfg.t_hat[ep_idx], kind="stable")]
    else:
        order = ep_idx[np.argsort(deadline[ep_idx], kind="stable")]

    if algorithm in ("edl", "edf-wf"):
        # Maintain a min-heap over pair finish times (SPT / worst fit).
        heap = [(mu, pid) for pid, mu in enumerate(pair_mu)]
        heapq.heapify(heap)
        for t_idx in order:
            t_idx = int(t_idx)
            d = deadline[t_idx]
            t_hat = float(cfg.t_hat[t_idx])
            if heap:
                mu_spt, pid = heap[0]
            else:
                mu_spt, pid = np.inf, -1
            if pid >= 0 and d - mu_spt >= t_hat - _EPS:
                heapq.heapreplace(heap, (mu_spt + t_hat, pid))
                pair_mu[pid] = mu_spt + t_hat
                assignments.append(_assignment(t_idx, pid, mu_spt, cfg))
                continue
            if algorithm == "edl" and pid >= 0:
                t_theta = max(theta * t_hat, float(cfg.t_min[t_idx]))
                window = d - mu_spt
                if window >= t_theta - _EPS:
                    # theta-readjustment: re-solve with the window as deadline.
                    override = single_task.readjust(
                        task_set.params[t_idx], float(window), interval)
                    heapq.heapreplace(heap, (mu_spt + override[3], pid))
                    pair_mu[pid] = mu_spt + override[3]
                    assignments.append(_assignment(t_idx, pid, mu_spt, cfg,
                                                   override, readjusted=True))
                    continue
            pid = len(pair_mu)
            pair_mu.append(t_hat)
            heapq.heappush(heap, (t_hat, pid))
            assignments.append(_assignment(t_idx, pid, 0.0, cfg))
    else:
        # edf-bf (tightest fitting pair) and lpt-ff (first fitting pair):
        # linear scans; pair counts stay in the low thousands.
        mus = np.asarray(pair_mu, dtype=np.float64)
        for t_idx in order:
            t_idx = int(t_idx)
            d = deadline[t_idx]
            t_hat = float(cfg.t_hat[t_idx])
            fits = np.nonzero(d - mus >= t_hat - _EPS)[0]
            if fits.size:
                pid = int(fits[np.argmax(mus[fits])]) if algorithm == "edf-bf" \
                    else int(fits[0])
                start = float(mus[pid])
                mus[pid] += t_hat
            else:
                pid = mus.shape[0]
                mus = np.append(mus, t_hat)
                start = 0.0
            assignments.append(_assignment(t_idx, pid, start, cfg))
        pair_mu = mus.tolist()

    # --- Phase 3: Algorithm 3 server grouping + Eq. (6) energies.
    e_run = float(sum(a.energy for a in assignments))
    busy_end = np.asarray(pair_mu, dtype=np.float64)
    e_idle, n_servers = cl.offline_idle_energy(busy_end, l, p_idle) \
        if busy_end.size else (0.0, 0)
    for a in assignments:
        if a.finish > deadline[a.task] + 1e-6:
            violations += 1
    return cl.ScheduleResult(
        algorithm=f"{algorithm}{'+dvfs' if use_dvfs else ''}",
        e_run=e_run, e_idle=e_idle, e_overhead=0.0,
        n_pairs=len(pair_mu), n_servers=n_servers, violations=violations,
        assignments=assignments,
        makespan=float(busy_end.max()) if busy_end.size else 0.0,
        feasible_pairs=len(pair_mu) <= 2048,
    )
