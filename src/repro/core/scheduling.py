"""Offline scheduling: the EDL theta-readjustment algorithm and baselines
(paper S4.2.1, Algorithms 1-3; baselines of S5.3).

All offline algorithms share the same three-phase structure:

1. **Algorithm 1** - per-task optimal DVFS configuration (deadline-aware),
   one batched solve for the whole task set (the Pallas kernel with
   ``use_kernel=True``); deadline-prior tasks get the boundary solution,
   energy-prior tasks get the unconstrained optimum.
2. **Task packing** - deadline-prior tasks are pinned to fresh pairs first
   (they must start at t=0), then the energy-prior tasks are placed in EDF
   order by the policy-specific rule, each a vectorized selector on the
   :class:`~repro.core.engine.ClusterEngine` pair arrays:

   * ``edl``    - shortest-processing-time pair (worst fit) **with
     theta-readjustment**: if the task does not fit at its optimal length, its
     execution is allowed to shrink to ``max(theta * t_hat, t_min)`` by
     re-solving the DVFS setting with the remaining window as deadline
     (Algorithm 2, lines 16-19).  The re-solves only pin the finish time to
     the window during packing; the actual DVFS settings/energies are
     batch-solved afterwards in ONE dispatch (`single_task.readjust_batch`).
   * ``edf-wf`` - worst fit (min mu), no readjustment;
   * ``edf-bf`` - best fit (max mu among fitting pairs), no readjustment;
   * ``lpt-ff`` - longest-processing-time order, first fit, no readjustment.

3. **Algorithm 3** - the engine finalizer groups pairs into virtual servers
   of ``l``; idle energy is ``P_idle * sum_j sum_k (F_j - tau_kj)`` (Eq. 6).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core import cluster as cl
from repro.core import dvfs, single_task
from repro.core.dvfs import ScalingInterval
from repro.core.engine import ClusterEngine
from repro.core.single_task import TaskConfig
from repro.core.tasks import TaskSet

_EPS = 1e-9


def default_config(task_set: TaskSet) -> TaskConfig:
    """A no-DVFS configuration: every task runs at (1, 1, 1)."""
    n = len(task_set)
    t_star = task_set.t_star
    p_star = task_set.p_star
    allowed = task_set.deadline - task_set.arrival
    ones = np.ones(n)
    return TaskConfig(
        v=ones.copy(), fc=ones.copy(), fm=ones.copy(),
        t_hat=t_star.copy(), p_hat=p_star.copy(), e_hat=(p_star * t_star),
        t_min=t_star.copy(),  # no scaling => no shrink room
        deadline_prior=(t_star > allowed + _EPS),
        feasible=(t_star <= allowed + _EPS),
        n_deadline_prior=int(np.sum(t_star > allowed + _EPS)),
    )


def configure(task_set: TaskSet, use_dvfs: bool,
              interval: ScalingInterval = dvfs.WIDE,
              use_kernel: bool = False) -> TaskConfig:
    """Algorithm 1 over a task set (or the no-DVFS default configuration)."""
    if not use_dvfs:
        return default_config(task_set)
    allowed = task_set.deadline - task_set.arrival
    return single_task.configure_tasks(task_set.params, allowed, interval,
                                       use_kernel=use_kernel)


def make_assignment(task: int, pair: int, start: float, cfg: TaskConfig,
                    duration: Optional[float] = None,
                    readjusted: bool = False) -> cl.Assignment:
    """An assignment at the task's configured setting; a readjusted one gets
    its finish pinned to ``start + duration`` and its DVFS fields filled in
    later by :func:`fill_readjusted`."""
    t = cfg.t_hat[task] if duration is None else duration
    return cl.Assignment(task=task, pair=pair, start=float(start),
                         finish=float(start + t), v=float(cfg.v[task]),
                         fc=float(cfg.fc[task]), fm=float(cfg.fm[task]),
                         power=float(cfg.p_hat[task]),
                         energy=float(cfg.e_hat[task]), readjusted=readjusted)


def fill_readjusted(assignments: List[cl.Assignment],
                    pending: List[Tuple[int, int, float]],
                    task_set: TaskSet, interval: ScalingInterval,
                    use_kernel: bool):
    """Solve every deferred theta-readjustment in ONE batched dispatch and
    write the DVFS settings/energies back into the assignment list.

    ``pending`` rows are ``(assignment_index, task_index, window)``.  The
    schedule itself never depends on these solves — a readjusted task always
    occupies exactly its window — so they are batched after packing: one
    ``pallas_call`` (or one jitted boundary solve) instead of one scalar
    dispatch per readjusted task.
    """
    if not pending:
        return
    rows = np.asarray([t for _, t, _ in pending], dtype=np.int64)
    windows = np.asarray([w for _, _, w in pending], dtype=np.float64)
    v, fc, fm, t, p, e = single_task.readjust_batch(
        task_set.params[rows], windows, interval, use_kernel=use_kernel)
    for k, (ai, _, _) in enumerate(pending):
        a = assignments[ai]
        assignments[ai] = dataclasses.replace(
            a, v=float(v[k]), fc=float(fc[k]), fm=float(fm[k]),
            power=float(p[k]), energy=float(e[k]))


def count_violations(assignments: List[cl.Assignment], deadline: np.ndarray,
                     feasible: np.ndarray) -> int:
    """Each violated task counts exactly once: infeasible at configuration
    time (cannot meet its deadline at max speed) OR finished past its
    deadline — never both."""
    violated = ~np.asarray(feasible, dtype=bool)
    for a in assignments:
        if a.finish > deadline[a.task] + 1e-6:
            violated[a.task] = True
    return int(np.sum(violated))


def schedule_offline(task_set: TaskSet, l: int = 1, theta: float = 1.0,
                     algorithm: str = "edl", use_dvfs: bool = True,
                     interval: ScalingInterval = dvfs.WIDE,
                     p_idle: float = cl.P_IDLE,
                     cfg: Optional[TaskConfig] = None,
                     use_kernel: bool = False) -> cl.ScheduleResult:
    """Run one offline scheduling algorithm end to end (Algorithms 1+2+3)."""
    algorithm = algorithm.lower()
    if algorithm not in ("edl", "edf-wf", "edf-bf", "lpt-ff"):
        raise ValueError(f"unknown offline algorithm {algorithm!r}")
    if cfg is None:
        cfg = configure(task_set, use_dvfs, interval, use_kernel=use_kernel)

    deadline = np.asarray(task_set.deadline, dtype=np.float64)
    assignments: List[cl.Assignment] = []
    pending: List[Tuple[int, int, float]] = []
    eng = ClusterEngine(l, servers=False, p_idle=p_idle)

    # --- Phase 2a: deadline-prior tasks, each started at t=0 on a fresh pair.
    dp_idx = np.nonzero(cfg.deadline_prior)[0]
    for t_idx in dp_idx[np.argsort(deadline[dp_idx], kind="stable")]:
        t_idx = int(t_idx)
        pid = eng.open_pair()
        eng.assign(pid, 0.0, float(cfg.t_hat[t_idx]))
        assignments.append(make_assignment(t_idx, pid, 0.0, cfg))

    # --- Phase 2b: energy-prior tasks by the policy rule.
    ep_idx = np.nonzero(~cfg.deadline_prior)[0]
    if algorithm == "lpt-ff":
        order = ep_idx[np.argsort(-cfg.t_hat[ep_idx], kind="stable")]
    else:
        order = ep_idx[np.argsort(deadline[ep_idx], kind="stable")]

    for t_idx in order:
        t_idx = int(t_idx)
        d = deadline[t_idx]
        t_hat = float(cfg.t_hat[t_idx])

        if algorithm in ("edl", "edf-wf"):
            pid = eng.worst_fit()
            mu = float(eng.mu[pid]) if pid >= 0 else np.inf
            if pid >= 0 and d - mu >= t_hat - _EPS:
                eng.assign(pid, mu, t_hat)
                assignments.append(make_assignment(t_idx, pid, mu, cfg))
                continue
            if algorithm == "edl" and pid >= 0:
                t_theta = max(theta * t_hat, float(cfg.t_min[t_idx]))
                window = d - mu
                if window >= t_theta - _EPS:
                    # theta-readjustment: the task shrinks to exactly the
                    # remaining window; its DVFS setting is batch-solved
                    # after packing (fill_readjusted).
                    eng.assign(pid, mu, window)
                    pending.append((len(assignments), t_idx, window))
                    assignments.append(make_assignment(t_idx, pid, mu, cfg,
                                                   duration=window,
                                                   readjusted=True))
                    continue
        else:
            pid = eng.best_fit(0.0, d, t_hat) if algorithm == "edf-bf" \
                else eng.first_fit(0.0, d, t_hat)
            if pid >= 0:
                start = float(eng.mu[pid])
                eng.assign(pid, start, t_hat)
                assignments.append(make_assignment(t_idx, pid, start, cfg))
                continue
        pid = eng.open_pair()
        eng.assign(pid, 0.0, t_hat)
        assignments.append(make_assignment(t_idx, pid, 0.0, cfg))

    # --- Deferred theta-readjustment solves: one batched dispatch.
    fill_readjusted(assignments, pending, task_set, interval, use_kernel)

    # --- Phase 3: Algorithm 3 server grouping + Eq. (6) energies.
    e_run = float(sum(a.energy for a in assignments))
    e_idle, e_overhead, n_servers = eng.finalize()
    violations = count_violations(assignments, deadline, cfg.feasible)
    return cl.ScheduleResult(
        algorithm=f"{algorithm}{'+dvfs' if use_dvfs else ''}",
        e_run=e_run, e_idle=e_idle, e_overhead=e_overhead,
        n_pairs=eng.n_pairs, n_servers=n_servers, violations=violations,
        assignments=assignments,
        makespan=float(eng.mu.max()) if eng.n_pairs else 0.0,
        feasible_pairs=eng.feasible_pairs,
    )
