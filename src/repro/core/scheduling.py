"""Offline scheduling: the EDL theta-readjustment algorithm and baselines
(paper S4.2.1, Algorithms 1-3; baselines of S5.3).

All offline algorithms share the same three-phase structure:

1. **Algorithm 1** - per-task optimal DVFS configuration (deadline-aware),
   one batched solve for the whole task set (the Pallas kernel with
   ``use_kernel=True``); deadline-prior tasks get the boundary solution,
   energy-prior tasks get the unconstrained optimum.  With heterogeneous
   machine classes (``classes=...``) the solve runs once per task **per
   class** — a single widened kernel dispatch — and each task's classes are
   ranked min-energy-feasible first (:func:`repro.core.machines.class_order`).
2. **Task packing** - deadline-prior tasks are pinned to fresh pairs first
   (they must start at t=0), then the energy-prior tasks are placed in EDF
   order by the policy-specific rule, each a path of the shared placement
   subsystem (:mod:`repro.core.placement`) over the
   :class:`~repro.core.engine.ClusterEngine` pair arrays, applied to each
   candidate class in preference order:

   * ``edl``    - shortest-processing-time pair (worst fit) **with
     theta-readjustment**: if the task does not fit at its optimal length, its
     execution is allowed to shrink to ``max(theta * t_hat, t_min)`` by
     re-solving the DVFS setting with the remaining window as deadline
     (Algorithm 2, lines 16-19).  The re-solves only pin the finish time to
     the window during packing; the actual DVFS settings/energies are
     batch-solved afterwards (`single_task.readjust_batch`, one dispatch per
     class present).
   * ``edf-wf`` - worst fit (min mu), no readjustment;
   * ``edf-bf`` - best fit (max mu among fitting pairs), no readjustment;
   * ``lpt-ff`` - longest-processing-time order, first fit, no readjustment.

   A task no class can host lands on a fresh pair of its primary
   (min-energy feasible) class.

   The offline batch is the placement subsystem's degenerate "one group at
   ``t = 0``" case: ``placement="vector"`` (default) runs the batched
   worst-fit frontier / pooled probes of
   :class:`~repro.core.placement.PlacementContext`,
   ``placement="scalar"`` the per-task reference loop over the engine
   selectors — bit-identical by construction
   (``tests/test_placement.py`` pins all four policies).

3. **Algorithm 3** - the engine finalizer groups pairs into virtual servers
   of ``l`` per class; idle energy is ``P_idle * sum_j sum_k (F_j - tau_kj)``
   (Eq. 6) with the class's own ``P_idle``.

Every result also reports ``e_bound``, the §5 analytical lower bound on
its energy (:func:`repro.core.bounds.theoretical_bound`), so achieved
savings can be read against the paper's ~36% ceiling.

See docs/EQUATIONS.md for the full equation/algorithm -> code map.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core import bounds, cluster as cl, dvfs, machines, single_task
from repro.core.dvfs import ScalingInterval
from repro.core.engine import ClusterEngine
from repro.core.machines import MachineClass, resolve_classes
from repro.core.placement import (OFFLINE_RULES, PendingRow, PlacementContext,
                                  make_assignment)
from repro.core.single_task import TaskConfig
from repro.core.tasks import TaskSet

_EPS = 1e-9


def default_config(task_set: TaskSet) -> TaskConfig:
    """A no-DVFS configuration: every task runs at (1, 1, 1) (the shared
    :func:`repro.core.single_task.no_dvfs_config` on the reference fit)."""
    return single_task.no_dvfs_config(task_set.params,
                                      task_set.deadline - task_set.arrival)


def configure(task_set: TaskSet, use_dvfs: bool,
              interval: ScalingInterval = dvfs.WIDE,
              use_kernel: bool = False, dedup: bool = True) -> TaskConfig:
    """Algorithm 1 over a task set (or the no-DVFS default configuration)."""
    if not use_dvfs:
        return default_config(task_set)
    allowed = task_set.deadline - task_set.arrival
    return single_task.configure_tasks(task_set.params, allowed, interval,
                                       use_kernel=use_kernel, dedup=dedup)


def configure_all(task_set: TaskSet, use_dvfs: bool,
                  mcs: Sequence[MachineClass],
                  interval: ScalingInterval = dvfs.WIDE,
                  use_kernel: bool = False, dedup: bool = True) -> List[TaskConfig]:
    """Algorithm 1 on every class (offline windows ``d - a``)."""
    if not use_dvfs:
        return machines.default_configs(task_set, mcs)
    allowed = task_set.deadline - task_set.arrival
    return machines.configure_classes(task_set.params, allowed, mcs,
                                      interval, use_kernel=use_kernel,
                                      dedup=dedup)


def fill_readjusted(assignments: List[cl.Assignment],
                    pending: List[PendingRow],
                    task_set: TaskSet, interval: ScalingInterval,
                    use_kernel: bool, mcs: Sequence[MachineClass],
                    dedup: bool = True):
    """Solve every deferred theta-readjustment in one batched dispatch per
    class present and write the DVFS settings/energies back into the
    assignment list.

    ``pending`` rows are ``(assignment_index, task_index, window, class_id)``.
    The schedule itself never depends on these solves — a readjusted task
    always occupies exactly its window — so they are batched after packing:
    one ``pallas_call`` (or one jitted boundary solve) per class instead of
    one scalar dispatch per readjusted task.
    """
    if not pending:
        return
    rows = np.asarray([t for _, t, _, _ in pending], dtype=np.int64)
    windows = np.asarray([w for _, _, w, _ in pending], dtype=np.float64)
    cids = np.asarray([c for _, _, _, c in pending], dtype=np.int64)
    v, fc, fm, t, p, e = machines.readjust_classes(
        task_set.params, rows, windows, cids, mcs, interval, use_kernel,
        dedup=dedup)
    for k, (ai, _, _, _) in enumerate(pending):
        a = assignments[ai]
        assignments[ai] = dataclasses.replace(
            a, v=float(v[k]), fc=float(fc[k]), fm=float(fm[k]),
            power=float(p[k]), energy=float(e[k]))


def count_violations(assignments: List[cl.Assignment], deadline: np.ndarray,
                     feasible: np.ndarray) -> int:
    """Each violated task counts exactly once: infeasible at configuration
    time (cannot meet its deadline at max speed) OR finished past its
    deadline — never both.  Records truncated by a server failure are
    skipped: the task is judged by its re-placed record (every task keeps
    exactly one live record under fault injection)."""
    violated = ~np.asarray(feasible, dtype=bool)
    if assignments:
        t = np.fromiter((a.task for a in assignments if not a.failed),
                        np.int64)
        f = np.fromiter((a.finish for a in assignments if not a.failed),
                        np.float64)
        violated[t[f > deadline[t] + 1e-6]] = True
    return int(np.sum(violated))


def chosen_feasibility(cfgs: Sequence[TaskConfig],
                       assignments: List[cl.Assignment],
                       n_tasks: int) -> np.ndarray:
    """Per-task feasibility on the class each task actually ran on (for a
    task re-placed after a server failure: the class of its live record —
    failed records are skipped)."""
    feas = np.ones(n_tasks, dtype=bool)
    if not assignments:
        return feas
    t = np.fromiter((a.task for a in assignments if not a.failed), np.int64)
    if len(cfgs) == 1:
        feas[t] = np.asarray(cfgs[0].feasible, bool)[t]
        return feas
    cid = np.fromiter((a.class_id for a in assignments if not a.failed),
                      np.int64)
    for c in np.unique(cid):
        tc = t[cid == c]
        feas[tc] = np.asarray(cfgs[int(c)].feasible, bool)[tc]
    return feas


def schedule_offline(task_set: TaskSet, l: int = 1, theta: float = 1.0,
                     algorithm: str = "edl", use_dvfs: bool = True,
                     interval: ScalingInterval = dvfs.WIDE,
                     p_idle: float = cl.P_IDLE,
                     cfg: Optional[TaskConfig] = None,
                     use_kernel: bool = False,
                     classes=None, placement: str = "vector",
                     cfgs: Optional[List[TaskConfig]] = None,
                     bound: bool = True,
                     dedup: bool = True) -> cl.ScheduleResult:
    """Run one offline scheduling algorithm end to end (Algorithms 1+2+3).

    ``classes`` selects the machine-class mix: ``None`` is the homogeneous
    paper setup (one reference class — identical to the pre-heterogeneity
    code path), otherwise a sequence of registry names and/or
    :class:`~repro.core.machines.MachineClass` instances.  ``cfg`` (a
    precomputed single-class Algorithm-1 output) is only valid for the
    homogeneous case; ``cfgs`` injects the full per-class
    :func:`configure_all` output (must match ``task_set``/``classes``/
    ``use_dvfs``/``interval``).  ``placement`` picks the batched array path
    (``"vector"``, default) or the per-task reference loop (``"scalar"``);
    both produce bit-identical schedules.  ``bound=False`` skips the
    ``e_bound`` solve (benchmarks timing the packing hot path).
    ``dedup=False`` opts every DVFS solve out of the unique-row dedup +
    solve cache (the default routes them through it, bit-identically).
    """
    algorithm = algorithm.lower()
    if algorithm not in OFFLINE_RULES:
        raise ValueError(f"unknown offline algorithm {algorithm!r}")
    if placement not in ("vector", "scalar"):
        raise ValueError(f"unknown placement mode {placement!r}")
    mcs = resolve_classes(classes, p_idle=p_idle)
    if cfg is not None:
        if len(mcs) > 1:
            raise ValueError("cfg= is only supported for a single class")
        cfgs = [cfg]
    elif cfgs is None:
        cfgs = configure_all(task_set, use_dvfs, mcs, interval,
                             use_kernel=use_kernel, dedup=dedup)
    elif len(cfgs) != len(mcs):
        raise ValueError("cfgs= needs one TaskConfig per machine class")

    n = len(task_set)
    deadline = np.asarray(task_set.deadline, dtype=np.float64)
    order_cls = machines.class_order(cfgs)          # [C, n]
    primary = order_cls[0]
    assignments: List[cl.Assignment] = []
    pending: List[PendingRow] = []
    eng = ClusterEngine(l, servers=False, classes=mcs)
    ctx = PlacementContext(eng, cfgs, deadline, theta=theta,
                           readjust=(algorithm == "edl"),
                           assignments=assignments, pending=pending,
                           order_cls=order_cls)

    # --- Phase 2a: tasks that are deadline-prior on their primary class,
    # each started at t=0 on a fresh pair of that class.
    dp_primary = np.take_along_axis(
        np.stack([np.asarray(c.deadline_prior, bool) for c in cfgs]),
        primary[None], axis=0)[0]
    dp_idx = np.nonzero(dp_primary)[0]
    dp_order = dp_idx[np.argsort(deadline[dp_idx], kind="stable")]
    if placement == "vector":
        ctx.pin_fresh(dp_order)
    else:
        for t_idx in dp_order:
            t_idx = int(t_idx)
            c = int(primary[t_idx])
            pid = eng.open_pair(class_id=c)
            eng.assign(pid, 0.0, float(cfgs[c].t_hat[t_idx]))
            assignments.append(make_assignment(t_idx, pid, 0.0, cfgs[c],
                                               class_id=c))

    # --- Phase 2b: energy-prior tasks by the policy rule, trying classes in
    # min-energy-feasible-first order — ONE group at t=0 through the shared
    # placement subsystem.
    ep_idx = np.nonzero(~dp_primary)[0]
    if algorithm == "lpt-ff":
        t_hat_primary = np.take_along_axis(
            np.stack([np.asarray(c.t_hat) for c in cfgs]),
            primary[None], axis=0)[0]
        order = ep_idx[np.argsort(-t_hat_primary[ep_idx], kind="stable")]
    else:
        order = ep_idx[np.argsort(deadline[ep_idx], kind="stable")]

    rule = OFFLINE_RULES[algorithm]
    pos = np.arange(order.shape[0])
    if placement == "vector":
        if rule == "wf":
            ctx.place_group_vector(order, pos, 0.0)
        else:
            ctx.place_group_select(order, pos, 0.0, rule)
    else:
        ctx.place_group_scalar(order, pos, 0.0, rule)

    # --- Deferred theta-readjustment solves: one batched dispatch per class.
    fill_readjusted(assignments, pending, task_set, interval, use_kernel, mcs,
                    dedup=dedup)

    # --- Phase 3: Algorithm 3 server grouping + Eq. (6) energies per class.
    e_run = float(sum(a.energy for a in assignments))
    e_idle, e_overhead, n_servers = eng.finalize()
    violations = count_violations(
        assignments, deadline, chosen_feasibility(cfgs, assignments, n))
    e_bound = bounds.theoretical_bound(
        task_set, interval=interval, classes=mcs,
        dedup=dedup).e_bound if bound else 0.0
    return cl.ScheduleResult(
        algorithm=f"{algorithm}{'+dvfs' if use_dvfs else ''}",
        e_run=e_run, e_idle=e_idle, e_overhead=e_overhead,
        n_pairs=eng.n_pairs, n_servers=n_servers, violations=violations,
        assignments=assignments,
        makespan=float(eng.mu.max()) if eng.n_pairs else 0.0,
        feasible_pairs=eng.feasible_pairs, e_bound=e_bound,
    )
