"""GPU/accelerator DVFS power, performance and energy models (paper Eq. 1-4).

The paper models a DVFS-scalable accelerator with three normalized knobs:

  * ``V``  - core voltage,
  * ``fc`` - core frequency, upper-bounded by the sublinear voltage curve
             ``fc <= g1(V) = sqrt((V - 0.5) / 2) + 0.5``,
  * ``fm`` - memory frequency (memory *voltage* scaling is dropped: it has a
             narrow range and negligible energy impact, paper S3.1.1).

Runtime power (Eq. 1)::

    P(V, fc, fm) = P0 + gamma * fm + c * V^2 * fc

Execution time (Eq. 2) - the *nonlinear* accelerator-specific relation::

    t(fc, fm) = D * (delta / fc + (1 - delta) / fm) + t0

Energy (Eq. 3/4)::

    E = P * t

All functions are written with ``jax.numpy`` so they can be vmapped/jitted
and reused verbatim by the Pallas kernel oracle; they accept plain floats and
numpy arrays as well (jnp broadcasts).

See docs/EQUATIONS.md for the full equation/algorithm -> code map, and
:mod:`repro.core.machines` for how these model constants are re-fitted per
heterogeneous machine class.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Array = Any


# ---------------------------------------------------------------------------
# Voltage/frequency curve.
# ---------------------------------------------------------------------------

# g1(V) = sqrt((V - A) / B) + C, fitted on the paper's Pascal platform with
# A = 0.5, B = 2.0, C = 0.5 (S5.1.1).
G1_A = 0.5
G1_B = 2.0
G1_C = 0.5


def g1(v: Array) -> Array:
    """Maximum core frequency allowed at core voltage ``v`` (sublinear)."""
    v = jnp.asarray(v)
    return jnp.sqrt(jnp.maximum(v - G1_A, 0.0) / G1_B) + G1_C


def g1_float(v: float) -> float:
    """Pure-python g1 for static (non-traced) uses such as interval bounds."""
    import math

    return math.sqrt(max(v - G1_A, 0.0) / G1_B) + G1_C


def g1_inv(fc: Array) -> Array:
    """Minimum core voltage able to sustain core frequency ``fc``."""
    fc = jnp.asarray(fc)
    return G1_B * jnp.square(jnp.maximum(fc - G1_C, 0.0)) + G1_A


# ---------------------------------------------------------------------------
# Scaling intervals (paper S5.1.1).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScalingInterval:
    """Normalized DVFS box ``V in [v_min, v_max], fm in [fm_min, fm_max],
    fc in [fc_min, g1(V)]``."""

    v_min: float
    v_max: float
    fc_min: float
    fm_min: float
    fm_max: float

    @property
    def fc_max(self) -> float:
        return g1_float(self.v_max)

    def bounds(self) -> tuple:
        """``(v_min, v_max, fc_min, fm_min, fm_max)`` — the per-row interval
        columns (``layout.BOUNDS_SLICE``, width ``layout.N_BOUNDS``) of the
        widened ``[n, NCOL]`` kernel task matrix (see
        :mod:`repro.kernels.layout`; not imported here — this module sits
        below the kernel package in the layer DAG)."""
        return (self.v_min, self.v_max, self.fc_min, self.fm_min, self.fm_max)

    def clamp(self, v: Array, fc: Array, fm: Array):
        v = jnp.clip(v, self.v_min, self.v_max)
        fc = jnp.clip(fc, self.fc_min, g1(v))
        fm = jnp.clip(fm, self.fm_min, self.fm_max)
        return v, fc, fm


# The *analytical* ("Wide") interval used for the simulations: the paper argues
# for studying the potential of DVFS with fc_max = g1(1.2) ~= 1.0916.
WIDE = ScalingInterval(v_min=0.5, v_max=1.2, fc_min=0.5, fm_min=0.5, fm_max=1.2)

# The realistic ("Narrow") GTX-1080Ti interval.
NARROW = ScalingInterval(v_min=0.8, v_max=1.24, fc_min=0.89, fm_min=0.8, fm_max=1.1)

# Default (normalized) operating point: V = fc = fm = 1.
DEFAULT_SETTING = (1.0, 1.0, 1.0)


# ---------------------------------------------------------------------------
# Task DVFS parameters.
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DvfsParams:
    """Per-task model constants. Every field may be a scalar or an array of
    shape ``[n]`` (a batch of tasks).

    ``p0``    - frequency-independent power (static + host share), Watts.
    ``gamma`` - memory-frequency power sensitivity, Watts per normalized fm.
    ``c``     - core dynamic-power coefficient (``c * V^2 * fc``), Watts.
    ``big_d`` - frequency-sensitive execution-time component ``D``, seconds.
    ``delta`` - core-frequency sensitivity in ``[0, 1]``.
    ``t0``    - frequency-insensitive execution-time component, seconds.
    """

    p0: Array
    gamma: Array
    c: Array
    big_d: Array
    delta: Array
    t0: Array

    def astuple(self):
        return (self.p0, self.gamma, self.c, self.big_d, self.delta, self.t0)

    @property
    def n(self) -> int:
        return int(np.shape(np.asarray(self.p0))[0]) if np.ndim(self.p0) else 1

    def default_power(self) -> Array:
        """P* = P(1, 1, 1)."""
        return power(self, 1.0, 1.0, 1.0)

    def default_time(self) -> Array:
        """t* = t(1, 1) = D + t0."""
        return self.big_d + self.t0

    def default_energy(self) -> Array:
        return self.default_power() * self.default_time()

    def __getitem__(self, idx) -> "DvfsParams":
        return DvfsParams(*(np.asarray(f)[idx] for f in self.astuple()))

    @staticmethod
    def stack(items) -> "DvfsParams":
        cols = list(zip(*(it.astuple() for it in items)))
        return DvfsParams(*(np.asarray(col, dtype=np.float64) for col in cols))


def power(params: DvfsParams, v: Array, fc: Array, fm: Array) -> Array:
    """Runtime power, Eq. (1)."""
    return params.p0 + params.gamma * fm + params.c * jnp.square(v) * fc


def exec_time(params: DvfsParams, fc: Array, fm: Array) -> Array:
    """Execution time, Eq. (2)."""
    return params.big_d * (params.delta / fc + (1.0 - params.delta) / fm) + params.t0


def energy(params: DvfsParams, v: Array, fc: Array, fm: Array) -> Array:
    """Task energy, Eq. (4): E = P * t."""
    return power(params, v, fc, fm) * exec_time(params, fc, fm)


def min_time(params: DvfsParams, interval: ScalingInterval) -> Array:
    """The fastest achievable execution time inside the scaling box."""
    return exec_time(params, interval.fc_max, interval.fm_max)


def optimal_fm(params: DvfsParams, v: Array, fc: Array, interval: ScalingInterval) -> Array:
    """Closed-form optimal memory frequency for fixed (V, fc), paper S4.1.

    f_xi = sqrt((P0 + c V^2 fc) * D (1-delta) / (gamma * (t0 + D delta / fc))),
    clamped to [fm_min, fm_max].  gamma == 0 or delta == 1 degenerate to
    fm_min (memory frequency does not help time, only costs power).
    """
    num = (params.p0 + params.c * jnp.square(v) * fc) * params.big_d * (1.0 - params.delta)
    den = params.gamma * (params.t0 + params.big_d * params.delta / fc)
    f_xi = jnp.sqrt(num / jnp.maximum(den, 1e-30))
    # gamma==0: power is flat in fm while time decreases => fm_max optimal.
    f_xi = jnp.where(params.gamma <= 0.0, interval.fm_max, f_xi)
    return jnp.clip(f_xi, interval.fm_min, interval.fm_max)


# ---------------------------------------------------------------------------
# TPU adaptation constants (DESIGN.md S3).
#
# The scheduler's task abstraction is hardware-agnostic; these constants give
# the fleet simulation a v5e-class flavour when scheduling LM jobs whose delta
# comes from the roofline analysis.  Normalized exactly like the GPU numbers.
# They back the ``tpu-v5e`` machine class in :mod:`repro.core.machines`,
# which makes them a first-class pair class in the heterogeneous engine.
# ---------------------------------------------------------------------------

# Normalized DVFS box of the v5e-class part: a narrower voltage range than
# the analytic GPU interval (server silicon is binned tighter) with HBM
# frequency scaling down to 0.6 of nominal.
TPU_V5E_INTERVAL = ScalingInterval(v_min=0.7, v_max=1.1, fc_min=0.6,
                                   fm_min=0.6, fm_max=1.05)

TPU_V5E_CHIP = dict(
    # Peak board power envelope per chip (W), static + host share, HBM share,
    # and core dynamic share at the default operating point.
    p_peak=200.0,
    p0_frac=0.30,     # host/static/interconnect share
    gamma_frac=0.15,  # HBM-frequency-proportional share
    # remainder is c * V^2 * fc at (1,1,1)
    p_idle=37.0,      # idle pair power (kept identical to the paper's setup)
    delta_on=90.0,    # turn on/off energy overhead (J), paper S5.1.2
)


def tpu_task_params(duration_s: float, delta: float, t0_frac: float = 0.1,
                    chip: dict = TPU_V5E_CHIP) -> DvfsParams:
    """Build paper-model parameters for an accelerator job.

    ``duration_s`` - default execution time t* at the (1,1,1) operating point.
    ``delta``      - compute-boundness from the roofline analysis
                     (T_compute / (T_compute + T_memory)).
    ``t0_frac``    - fraction of t* that does not scale with frequency
                     (data pipeline, host gaps).
    """
    p_peak = chip["p_peak"]
    p0 = p_peak * chip["p0_frac"]
    gamma = p_peak * chip["gamma_frac"]
    c = p_peak - p0 - gamma
    t0 = duration_s * t0_frac
    big_d = duration_s - t0
    return DvfsParams(p0=p0, gamma=gamma, c=c, big_d=big_d, delta=float(delta), t0=t0)
