"""Heterogeneous machine classes: the per-pair-class layer of the cluster.

The paper's premise is a *heterogeneous* CPU-GPU cluster — pairs whose
accelerators have different power/frequency curves.  A
:class:`MachineClass` captures one device class as a transform of the
canonical GTX-1080Ti-fit task parameters (:mod:`repro.core.tasks`) plus its
own DVFS scaling box:

* ``speed``        — relative throughput: both time components (``D``,
                     ``t0``) are divided by it;
* ``power_scale``  — power envelope relative to the reference part;
* ``p0_frac`` / ``gamma_frac`` — optional re-split of the scaled default
                     power ``P*`` into static / memory / core shares (the
                     way :func:`repro.core.dvfs.tpu_task_params` derives a
                     chip's split from its envelope);
* ``interval``     — the class's own :class:`~repro.core.dvfs.ScalingInterval`
                     (``None`` = follow the run-level interval, the
                     reference-class behaviour);
* ``p_idle`` / ``delta_on`` — per-class idle power and turn-on overhead
                     used by the :class:`~repro.core.engine.ClusterEngine`
                     finalizers (Eq. 6/7 per class).

The **reference class** (``gtx-1080ti``) is the identity transform: with a
single reference class every scheduler degenerates bit-for-bit to the
homogeneous code path (pinned by ``tests/test_machines.py`` against the
``tests/test_engine.py`` goldens).

:func:`configure_classes` runs Algorithm 1 for every task **on every
class**: with ``use_kernel=True`` all ``C x n`` solves go through ONE
widened ``[C*n, 16]`` Pallas dispatch whose rows carry their own interval
bounds (``layout.BOUNDS_SLICE``, see :mod:`repro.kernels.layout`); otherwise
one jitted batched solve per class.  The schedulers then pick, per task, the
min-energy *feasible* class first and fall back through the remaining
classes in ascending energy order (see docs/EQUATIONS.md for the
equation/algorithm map).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import cluster as cl, dvfs, single_task
from repro.core.dvfs import DvfsParams, ScalingInterval
from repro.core.single_task import TaskConfig
from repro.kernels import layout

_EPS = 1e-9
INFEASIBLE_PENALTY = 1e30  # pushes infeasible classes behind feasible ones


@dataclasses.dataclass(frozen=True)
class MachineClass:
    """One accelerator pair class: a parameter transform + a DVFS box."""

    name: str
    interval: Optional[ScalingInterval] = None  # None -> run-level interval
    speed: float = 1.0
    power_scale: float = 1.0
    p0_frac: Optional[float] = None
    gamma_frac: Optional[float] = None
    p_idle: float = cl.P_IDLE
    delta_on: float = cl.DELTA_ON

    @property
    def is_reference(self) -> bool:
        """True if :meth:`adapt` is the identity transform."""
        return (self.speed == 1.0 and self.power_scale == 1.0
                and self.p0_frac is None and self.gamma_frac is None)

    def effective_interval(self, default: ScalingInterval) -> ScalingInterval:
        return self.interval if self.interval is not None else default

    def adapt(self, params: DvfsParams) -> DvfsParams:
        """Class-specific task constants from the reference (1080Ti) fit.

        The identity class returns values bit-identical to its input
        (``x * 1.0`` and ``x / 1.0`` are exact in IEEE-754), which is what
        lets a single-reference-class run reproduce the homogeneous goldens
        exactly.
        """
        p0, gamma, c, big_d, delta, t0 = (
            np.asarray(f, np.float64) for f in params.astuple())
        if self.p0_frac is not None or self.gamma_frac is not None:
            if self.p0_frac is None or self.gamma_frac is None:
                raise ValueError(f"{self.name}: p0_frac and gamma_frac must "
                                 "be set together")
            p_star = (p0 + gamma + c) * self.power_scale
            p0 = p_star * self.p0_frac
            gamma = p_star * self.gamma_frac
            c = p_star - p0 - gamma
        else:
            p0 = p0 * self.power_scale
            gamma = gamma * self.power_scale
            c = c * self.power_scale
        return DvfsParams(p0=p0, gamma=gamma, c=c, big_d=big_d / self.speed,
                          delta=delta, t0=t0 / self.speed)


# ---------------------------------------------------------------------------
# Registry (the class mixes the scenario sweep iterates over).
# ---------------------------------------------------------------------------

#: Reference power envelope (W): mid of the paper's fitted P* range
#: [175, 206] for the GTX-1080Ti library — the denominator every other
#: class's ``power_scale`` is expressed against.
REF_P_PEAK = 190.0

#: The canonical class: the GTX-1080Ti the paper's 20-app library was fitted
#: on.  Identity transform; its interval follows the run-level choice
#: (WIDE analytic / NARROW realistic).
GTX_1080TI = MachineClass("gtx-1080ti")

#: The v5e-class accelerator from the chip envelope constants in
#: :mod:`repro.core.dvfs`: ~200 W peak split 30/15/55 static/HBM/core,
#: ~35% faster per task than the reference part, with its own tighter box.
TPU_V5E = MachineClass(
    "tpu-v5e",
    interval=dvfs.TPU_V5E_INTERVAL,
    speed=1.35,
    power_scale=dvfs.TPU_V5E_CHIP["p_peak"] / REF_P_PEAK,
    p0_frac=dvfs.TPU_V5E_CHIP["p0_frac"],
    gamma_frac=dvfs.TPU_V5E_CHIP["gamma_frac"],
    p_idle=dvfs.TPU_V5E_CHIP["p_idle"],
    delta_on=dvfs.TPU_V5E_CHIP["delta_on"],
)

#: A Volta-class datacenter GPU: ~250 W envelope, ~1.5x the reference
#: throughput, a slightly wider voltage floor than the 1080Ti's NARROW box
#: (fit ranges in the style of the paper's published table).
V100_SXM2 = MachineClass(
    "v100-sxm2",
    interval=ScalingInterval(v_min=0.75, v_max=1.2, fc_min=0.55,
                             fm_min=0.65, fm_max=1.1),
    speed=1.5,
    power_scale=250.0 / REF_P_PEAK,
    p0_frac=0.35,
    gamma_frac=0.18,
    p_idle=45.0,
    delta_on=110.0,
)

REGISTRY = {c.name: c for c in (GTX_1080TI, TPU_V5E, V100_SXM2)}

ClassSpec = Union[str, MachineClass]


def get_classes(names: Sequence[ClassSpec]) -> Tuple[MachineClass, ...]:
    """Resolve a class mix: registry names and/or MachineClass instances."""
    out = []
    for item in names:
        if isinstance(item, MachineClass):
            out.append(item)
        elif item in REGISTRY:
            out.append(REGISTRY[item])
        else:
            raise KeyError(f"unknown machine class {item!r}; registry has "
                           f"{sorted(REGISTRY)}")
    if not out:
        raise ValueError("a class mix needs at least one machine class")
    return tuple(out)


def reference_classes(p_idle: float = cl.P_IDLE,
                      delta_on: float = cl.DELTA_ON) -> Tuple[MachineClass, ...]:
    """The homogeneous degenerate case: one identity class with the
    engine-scalar idle/overhead constants."""
    return (MachineClass("default", p_idle=p_idle, delta_on=delta_on),)


def resolve_classes(classes, p_idle: float = cl.P_IDLE,
                    delta_on: float = cl.DELTA_ON) -> Tuple[MachineClass, ...]:
    """Class-mix argument -> MachineClass tuple: ``None`` is the homogeneous
    default (one identity class with the given scalar constants), anything
    else a sequence of registry names and/or instances.  The ONE resolver
    shared by both schedulers and :mod:`repro.core.bounds`."""
    if classes is None:
        return reference_classes(p_idle=p_idle, delta_on=delta_on)
    return get_classes(classes)


# ---------------------------------------------------------------------------
# Algorithm 1 across classes.
# ---------------------------------------------------------------------------


def configure_classes(params: DvfsParams, allowed: np.ndarray,
                      classes: Sequence[MachineClass],
                      interval: ScalingInterval = dvfs.WIDE,
                      use_kernel: bool = False,
                      dedup: bool = True) -> List[TaskConfig]:
    """Algorithm 1 for every task on every class: ``C`` TaskConfigs of ``n``.

    ``use_kernel=True`` fuses all ``C x n`` solves into ONE widened Pallas
    dispatch — the class blocks are stacked into a ``[C*n, 16]`` task matrix
    whose rows carry their class's interval bounds.  The jnp path runs one
    batched ``configure_tasks`` per class (each interval compiles once).
    ``dedup=True`` (default) routes either path through the unique-row
    dedup + process-wide solve cache (bit-identical; see
    :mod:`repro.core.solver_cache`).
    """
    allowed = np.asarray(allowed, dtype=np.float64)
    if not use_kernel:
        return [single_task.configure_tasks(
                    mc.adapt(params), allowed, mc.effective_interval(interval),
                    use_kernel=False, dedup=dedup)
                for mc in classes]

    from repro.kernels import ops as kernel_ops

    n = allowed.shape[0]
    adapted = [mc.adapt(params) for mc in classes]
    ivs = [mc.effective_interval(interval) for mc in classes]
    big = DvfsParams(*(np.concatenate([np.asarray(f, np.float64)
                                       for f in cols])
                       for cols in zip(*(a.astuple() for a in adapted))))
    allowed_rep = np.tile(allowed, len(classes))
    interval_rows = np.concatenate(
        [np.broadcast_to(np.asarray(iv.bounds(), np.float64),
                         (n, layout.N_BOUNDS))
         for iv in ivs], axis=0)
    big, allowed_rep, interval_rows, _ = single_task.pad_pow2(
        big, allowed_rep, interval_rows)
    sol = kernel_ops.dvfs_solve(big, allowed_rep, interval,
                                interval_rows=interval_rows, dedup=dedup)
    cfgs: List[TaskConfig] = []
    for c, (a, iv) in enumerate(zip(adapted, ivs)):
        sol_c = type(sol)(*(np.asarray(f)[c * n: (c + 1) * n] for f in sol))
        cfgs.append(single_task.config_from_solution(sol_c, a, allowed, iv))
    return cfgs


class ClassSolves:
    """In-flight Algorithm-1 solves for one chunk of tasks on every class.

    Wraps either one :class:`~repro.core.solver_cache.AsyncSolve` per class
    (jnp path) or a single stacked-dispatch handle (kernel path);
    :meth:`result` blocks and returns the per-class ``[k, 8]`` solution
    rows — the same bits the synchronous :func:`configure_classes` would
    have produced for those rows.
    """

    __slots__ = ("_handles", "_stacked", "_n")

    def __init__(self, handles=None, stacked=None, n: int = 0):
        self._handles = handles
        self._stacked = stacked
        self._n = n

    def result(self) -> List[np.ndarray]:
        if self._stacked is not None:
            rows = self._stacked.result()
            n = self._n
            return [rows[c * n:(c + 1) * n]
                    for c in range(rows.shape[0] // n)]
        return [h.result() for h in self._handles]


def configure_classes_async(params: DvfsParams, allowed: np.ndarray,
                            classes: Sequence[MachineClass],
                            interval: ScalingInterval = dvfs.WIDE,
                            use_kernel: bool = False,
                            dedup: bool = True) -> ClassSolves:
    """Dispatch Algorithm 1 for a *chunk* of tasks on every class without
    blocking — the prefetch half of the pipelined online scheduler.

    Mirrors :func:`configure_classes` batch shape for batch shape: the
    kernel path stacks the class blocks (with per-row interval bounds)
    into ONE dispatch, the jnp path issues one per-class solve.  Rows are
    keyed and cached exactly like the synchronous path (same tags), so the
    values that come back are bit-identical and the cache composes across
    pipelined and monolithic runs.
    """
    allowed = np.asarray(allowed, dtype=np.float64)
    if not use_kernel:
        return ClassSolves(handles=[
            single_task.solve_rows_async(
                mc.adapt(params), allowed, mc.effective_interval(interval),
                boundary=False, use_kernel=False, dedup=dedup)
            for mc in classes])

    from repro.core import solver_cache
    from repro.kernels import ops as kernel_ops
    from repro.kernels.dvfs_opt import DEFAULT_GRID

    n = allowed.shape[0]
    adapted = [mc.adapt(params) for mc in classes]
    ivs = [mc.effective_interval(interval) for mc in classes]
    big = DvfsParams(*(np.concatenate([np.asarray(f, np.float64)
                                       for f in cols])
                       for cols in zip(*(a.astuple() for a in adapted))))
    interval_rows = np.concatenate(
        [np.broadcast_to(np.asarray(iv.bounds(), np.float64),
                         (n, layout.N_BOUNDS))
         for iv in ivs], axis=0)
    keys = solver_cache.build_keys(big.astuple(), np.tile(allowed, len(ivs)),
                                   False, interval_rows)
    handle = solver_cache.solve_rows_async(
        keys, lambda km: kernel_ops.dvfs_solve_matrix(km, block=False),
        tag=f"k{int(DEFAULT_GRID[0])}x{int(DEFAULT_GRID[1])}",
        cache=solver_cache.GLOBAL_CACHE if dedup else None, unique=False)
    return ClassSolves(stacked=handle, n=n)


def default_configs(task_set, classes: Sequence[MachineClass],
                    allowed=None) -> List[TaskConfig]:
    """The no-DVFS configuration per class: every task at (1, 1, 1) with the
    class-adapted constants — one :func:`repro.core.single_task.no_dvfs_config`
    per class (the same implementation ``scheduling.default_config`` wraps,
    so the homogeneous and heterogeneous fallbacks cannot drift).
    ``allowed`` overrides the per-task window (the online scheduler passes
    the slot-aligned ``d - ceil(a)``); default is the offline ``d - a``."""
    if allowed is None:
        allowed = np.asarray(task_set.deadline - task_set.arrival, np.float64)
    return [single_task.no_dvfs_config(mc.adapt(task_set.params), allowed)
            for mc in classes]


def class_order(cfgs: Sequence[TaskConfig]) -> np.ndarray:
    """Per-task class preference, shape ``[C, n]``: feasible classes in
    ascending optimized energy first, then infeasible ones by energy.
    ``class_order(cfgs)[0]`` is each task's *primary* class."""
    e = np.stack([np.asarray(c.e_hat, np.float64) for c in cfgs])
    feas = np.stack([np.asarray(c.feasible, bool) for c in cfgs])
    key = np.where(feas, e, e + INFEASIBLE_PENALTY)
    return np.argsort(key, axis=0, kind="stable")


def readjust_classes(params: DvfsParams, rows: np.ndarray, windows: np.ndarray,
                     class_ids: np.ndarray, classes: Sequence[MachineClass],
                     interval: ScalingInterval, use_kernel: bool,
                     dedup: bool = True):
    """Batched θ-readjustment across classes: one deadline-boundary dispatch
    per class present in ``class_ids`` (≤ C dispatches per run).

    Returns ``(v, fc, fm, t, p, e)`` arrays aligned with ``rows``.
    """
    n = rows.shape[0]
    v, fc, fm, t, p, e = (np.zeros(n) for _ in range(6))
    for cid in np.unique(class_ids):
        mc = classes[int(cid)]
        m = class_ids == cid
        sub = mc.adapt(params[rows[m]])
        out = single_task.readjust_batch(sub, windows[m],
                                         mc.effective_interval(interval),
                                         use_kernel=use_kernel, dedup=dedup)
        for dst, src in zip((v, fc, fm, t, p, e), out):
            dst[m] = src
    return v, fc, fm, t, p, e
