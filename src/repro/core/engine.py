"""Vectorized cluster state machine shared by every scheduler (§3.1.2, §4.2).

``ClusterEngine`` is the single source of truth for cluster state: pair
finish times (``mu``) and cumulative busy time are flat numpy arrays, and
server DRS bookkeeping (on/off, powered-on duration, turn-on counts) is a
parallel set of arrays with pairs laid out contiguously per server
(``server j`` owns pairs ``[j*l, (j+1)*l)``).  The offline (Algorithms 1-3)
and online (Algorithms 4-6) schedulers in :mod:`repro.core.scheduling` and
:mod:`repro.core.online` are thin policy layers over this engine: they pick
pairs via the vectorized ``worst_fit`` / ``best_fit`` / ``first_fit``
selectors and never touch the arrays directly.

Two operating modes share the arrays and the Eq. (7) finalizer:

* ``servers=False`` (offline): pairs are opened on demand with no live
  server bookkeeping; :meth:`finalize` runs Algorithm 3 — sort pairs by
  finish time, group ``l`` consecutive pairs into a *virtual* server whose
  powered-on span is its longest pair — and then evaluates the same
  Eq. (7) sum with ``omega = 0``, which is exactly Eq. (6).
* ``servers=True`` (online): pairs come in server granules of ``l``; the
  DRS sweep powers a server off once all of its pairs have been idle for
  ``rho`` slots, and every power-on adds ``l`` to the turn-on count
  ``omega``.  :meth:`finalize` powers off the stragglers and returns

      E_idle     = P_idle * (sum_j on_time_j * l - sum_k busy_k)
      E_overhead = Delta * omega.
"""

from __future__ import annotations

import numpy as np

from repro.core import cluster as cl

_EPS = 1e-9


class ClusterEngine:
    """Struct-of-arrays pair/server state with vectorized policy selectors."""

    def __init__(self, l: int, *, servers: bool = True, rho: int = cl.RHO,
                 p_idle: float = cl.P_IDLE, delta_on: float = cl.DELTA_ON,
                 max_pairs: int = cl.MAX_PAIRS):
        self.l = int(l)
        self.server_mode = bool(servers)
        self.rho = rho
        self.p_idle = p_idle
        self.delta_on = delta_on
        self.max_pairs = max_pairs
        self.n_pairs = 0
        self.n_servers = 0
        cap_p, cap_s = 64, 16
        self._mu = np.zeros(cap_p)
        self._busy = np.zeros(cap_p)
        self._on = np.zeros(cap_s, dtype=bool)
        self._on_since = np.zeros(cap_s)
        self._on_time = np.zeros(cap_s)
        self._turn_ons = np.zeros(cap_s, dtype=np.int64)

    # -- array views ---------------------------------------------------------
    @property
    def mu(self) -> np.ndarray:
        """Finish time of the last task per pair, shape ``[n_pairs]``."""
        return self._mu[: self.n_pairs]

    @property
    def busy(self) -> np.ndarray:
        """Cumulative busy duration per pair, shape ``[n_pairs]``."""
        return self._busy[: self.n_pairs]

    @property
    def feasible_pairs(self) -> bool:
        return self.n_pairs <= self.max_pairs

    def n_on_servers(self) -> int:
        return int(np.count_nonzero(self._on[: self.n_servers]))

    # -- growth --------------------------------------------------------------
    def _grow_pairs(self, extra: int):
        need = self.n_pairs + extra
        if need <= self._mu.shape[0]:
            return
        cap = max(need, 2 * self._mu.shape[0])
        self._mu = np.concatenate([self._mu, np.zeros(cap - self._mu.shape[0])])
        self._busy = np.concatenate([self._busy,
                                     np.zeros(cap - self._busy.shape[0])])

    def _grow_servers(self, extra: int):
        need = self.n_servers + extra
        if need <= self._on.shape[0]:
            return
        cap = max(need, 2 * self._on.shape[0])
        pad = cap - self._on.shape[0]
        self._on = np.concatenate([self._on, np.zeros(pad, dtype=bool)])
        self._on_since = np.concatenate([self._on_since, np.zeros(pad)])
        self._on_time = np.concatenate([self._on_time, np.zeros(pad)])
        self._turn_ons = np.concatenate([self._turn_ons,
                                         np.zeros(pad, dtype=np.int64)])

    # -- transitions ---------------------------------------------------------
    def open_pair(self, mu0: float = 0.0) -> int:
        """A fresh standalone pair (offline mode: no server bookkeeping)."""
        assert not self.server_mode
        self._grow_pairs(1)
        pid = self.n_pairs
        self._mu[pid] = mu0
        self._busy[pid] = 0.0
        self.n_pairs += 1
        return pid

    def new_server(self, t: float) -> int:
        """Build and power on a server of ``l`` fresh pairs; returns its id."""
        assert self.server_mode
        self._grow_servers(1)
        self._grow_pairs(self.l)
        sid = self.n_servers
        self._on[sid] = True
        self._on_since[sid] = t
        self._turn_ons[sid] = self.l
        lo = self.n_pairs
        self._mu[lo: lo + self.l] = t   # a fresh pair is free *now*
        self._busy[lo: lo + self.l] = 0.0
        self.n_servers += 1
        self.n_pairs += self.l
        return sid

    def wake_server(self, sid: int, t: float):
        self._on[sid] = True
        self._on_since[sid] = t
        self._turn_ons[sid] += self.l
        self._mu[sid * self.l: (sid + 1) * self.l] = t

    def acquire_pair(self, t: float) -> int:
        """A fresh pair: prefer re-powering an off server over building one."""
        off = np.flatnonzero(~self._on[: self.n_servers])
        if off.size:
            sid = int(off[0])
            self.wake_server(sid, t)
        else:
            sid = self.new_server(t)
        return sid * self.l

    def assign(self, pid: int, start: float, duration: float):
        self._mu[pid] = start + duration
        self._busy[pid] += duration

    def drs_sweep(self, t: float):
        """Power off every server whose pairs have all been idle >= rho."""
        ns = self.n_servers
        if not ns:
            return
        mu_srv = self._mu[: ns * self.l].reshape(ns, self.l).max(axis=1)
        on = self._on[: ns]
        off = on & (t - mu_srv >= self.rho - _EPS)
        if off.any():
            self._on_time[: ns][off] += t - self._on_since[: ns][off]
            self._on[: ns][off] = False

    # -- pair selection (the policy rules' vectorized primitives) ------------
    def eligible_mask(self):
        """Mask of assignable pairs (``None`` == all): every pair offline,
        only pairs of powered-on servers online."""
        if not self.server_mode:
            return None
        return np.repeat(self._on[: self.n_servers], self.l)

    def worst_fit(self) -> int:
        """The pair with the smallest mu (SPT; ties -> smallest id), or -1."""
        if self.n_pairs == 0:
            return -1
        mu = self.mu
        mask = self.eligible_mask()
        if mask is None:
            return int(np.argmin(mu))
        if not mask.any():
            return -1
        return int(np.argmin(np.where(mask, mu, np.inf)))

    def _fits(self, t_now: float, deadline: float, t_hat: float):
        mu = self.mu
        fit = deadline - np.maximum(t_now, mu) >= t_hat - _EPS
        mask = self.eligible_mask()
        return fit if mask is None else (fit & mask)

    def best_fit(self, t_now: float, deadline: float, t_hat: float) -> int:
        """The *fitting* pair with the largest mu (tightest fit), or -1."""
        if self.n_pairs == 0:
            return -1
        fit = self._fits(t_now, deadline, t_hat)
        if not fit.any():
            return -1
        return int(np.argmax(np.where(fit, self.mu, -np.inf)))

    def first_fit(self, t_now: float, deadline: float, t_hat: float) -> int:
        """The lowest-id fitting pair, or -1."""
        if self.n_pairs == 0:
            return -1
        fit = self._fits(t_now, deadline, t_hat)
        if not fit.any():
            return -1
        return int(np.argmax(fit))

    # -- Eq. (7) finalizer ---------------------------------------------------
    def _energy(self):
        ns = self.n_servers
        e_idle = self.p_idle * (float(self._on_time[:ns].sum()) * self.l
                                - float(self.busy.sum()))
        e_overhead = self.delta_on * float(self._turn_ons[:ns].sum())
        return e_idle, e_overhead

    def finalize(self):
        """Close the books: returns ``(e_idle, e_overhead, n_servers)``.

        Online mode powers off the remaining servers ``rho`` slots after
        their last pair frees up; offline mode first runs Algorithm 3 to
        group the standalone pairs into virtual servers (powered on for
        exactly their longest pair's span).  Both then evaluate the same
        Eq. (7) idle/overhead sums over the server arrays.
        """
        if self.server_mode:
            ns = self.n_servers
            if ns:
                mu_srv = self._mu[: ns * self.l].reshape(ns, self.l).max(axis=1)
                on = self._on[: ns]
                self._on_time[: ns][on] += (mu_srv[on] + self.rho
                                            - self._on_since[: ns][on])
                self._on[: ns] = False
        elif self.n_pairs:
            # Algorithm 3: each virtual server is powered on for exactly its
            # longest pair's span.
            spans = cl.server_spans(self.mu, self.l)
            ns = spans.shape[0]
            self._grow_servers(ns)
            self._on_time[:ns] = spans
            self._turn_ons[:ns] = 0
            self._on[:ns] = False
            self.n_servers = ns
        e_idle, e_overhead = self._energy()
        return e_idle, e_overhead, self.n_servers
