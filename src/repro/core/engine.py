"""Vectorized cluster state machine shared by every scheduler (§3.1.2, §4.2).

``ClusterEngine`` is the single source of truth for cluster state: pair
finish times (``mu``), cumulative busy time and the pair's *machine class*
are flat numpy arrays, and server DRS bookkeeping (on/off, powered-on
duration, turn-on counts, server class) is a parallel set of arrays with
pairs laid out contiguously per server (``server j`` owns pairs
``[j*l, (j+1)*l)``).  Servers are class-homogeneous: every pair of a server
shares its ``class_id``, so the DRS sweep and the Eq. (7) sums naturally
operate per class.  The offline (Algorithms 1-3) and online (Algorithms
4-6) schedulers in :mod:`repro.core.scheduling` and :mod:`repro.core.online`
are thin policy layers over this engine: they pick pairs via the vectorized
``worst_fit`` / ``best_fit`` / ``first_fit`` selectors (optionally
restricted to one class) and never touch the arrays directly.

Heterogeneity: pass ``classes`` (a sequence of
:class:`repro.core.machines.MachineClass`, or any objects with ``p_idle``
and ``delta_on`` attributes) and open pairs/servers with a ``class_id``.
With the default single class the engine reduces exactly to the homogeneous
paper setup (scalar ``p_idle``/``delta_on``).

Two operating modes share the arrays and the Eq. (7) finalizer:

* ``servers=False`` (offline): pairs are opened on demand with no live
  server bookkeeping; :meth:`finalize` runs Algorithm 3 — per class, sort
  pairs by finish time, group ``l`` consecutive pairs into a *virtual*
  server whose powered-on span is its longest pair — and then evaluates the
  same Eq. (7) sum with ``omega = 0``, which is exactly Eq. (6).
* ``servers=True`` (online): pairs come in server granules of ``l``; DRS
  power-off is an *event*: a server goes off exactly ``rho`` slots after
  its last pair frees up, and :meth:`settle` books every such event at
  its exact time ``mu_srv + rho`` no matter how far past it the
  simulation has advanced (arrival slots may be arbitrarily sparse).
  Every power-on adds ``l`` to the turn-on count ``omega``.
  :meth:`finalize` settles the stragglers through the same primitive and
  returns (per class ``k``)

      E_idle     = sum_k P_idle[k] * (sum_j on_time_jk * l - sum busy_k)
      E_overhead = sum_k Delta[k] * omega_k.

See docs/EQUATIONS.md for the full equation/algorithm -> code map.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core import cluster as cl

_EPS = 1e-9


class _DefaultClass:
    """Scalar-parameter stand-in when no machine classes are given."""

    __slots__ = ("name", "p_idle", "delta_on")

    def __init__(self, p_idle: float, delta_on: float):
        self.name = "default"
        self.p_idle = p_idle
        self.delta_on = delta_on


class ClusterEngine:
    """Struct-of-arrays pair/server state with vectorized policy selectors."""

    def __init__(self, l: int, *, servers: bool = True, rho: int = cl.RHO,
                 p_idle: float = cl.P_IDLE, delta_on: float = cl.DELTA_ON,
                 max_pairs: int = cl.MAX_PAIRS, classes: Sequence = None):
        self.l = int(l)
        self.server_mode = bool(servers)
        self.rho = rho
        self.classes = tuple(classes) if classes is not None \
            else (_DefaultClass(p_idle, delta_on),)
        self.max_pairs = max_pairs
        self.n_pairs = 0
        self.n_servers = 0
        cap_p, cap_s = 64, 16
        self._mu = np.zeros(cap_p)
        self._busy = np.zeros(cap_p)
        self._cls = np.zeros(cap_p, dtype=np.int64)
        self._on = np.zeros(cap_s, dtype=bool)
        self._on_since = np.zeros(cap_s)
        self._on_time = np.zeros(cap_s)
        self._turn_ons = np.zeros(cap_s, dtype=np.int64)
        self._srv_cls = np.zeros(cap_s, dtype=np.int64)
        # Server-level finish time max_k mu_{server pairs} maintained
        # incrementally (mu only ever moves forward), so settle() never
        # re-reduces the pair columns.
        self._mu_srv = np.zeros(cap_s)
        # Fault state (repro.core.faults): failed pairs are ineligible, and
        # a server with a failed pair is withheld from the wake pool until
        # revived.  _any_failed gates every fast-path check so the
        # failure-free masks stay bit-identical to the pre-fault engine.
        self._pair_failed = np.zeros(cap_p, dtype=bool)
        self._srv_failed = np.zeros(cap_s, dtype=bool)
        self._any_failed = False
        # Dirty-pair tracking for incremental placement pools: with
        # ``track_offs`` on, settle() logs every server it powers off (the
        # pool owner deletes just those pair blocks instead of rebuilding),
        # and ``pool_epoch`` bumps on any fault transition — the coarse
        # invalidate-everything signal for prefetched pool state.
        self.track_offs = False
        self._off_log: list = []
        self.pool_epoch = 0

    # Back-compat scalar views (meaningful for the single-class engine).
    @property
    def p_idle(self) -> float:
        return self.classes[0].p_idle

    @property
    def delta_on(self) -> float:
        return self.classes[0].delta_on

    # -- array views ---------------------------------------------------------
    @property
    def mu(self) -> np.ndarray:
        """Finish time of the last task per pair, shape ``[n_pairs]``."""
        return self._mu[: self.n_pairs]

    @property
    def busy(self) -> np.ndarray:
        """Cumulative busy duration per pair, shape ``[n_pairs]``."""
        return self._busy[: self.n_pairs]

    @property
    def pair_class(self) -> np.ndarray:
        """Machine-class id per pair, shape ``[n_pairs]``."""
        return self._cls[: self.n_pairs]

    @property
    def feasible_pairs(self) -> bool:
        return self.n_pairs <= self.max_pairs

    def n_on_servers(self) -> int:
        return int(np.count_nonzero(self._on[: self.n_servers]))

    def server_class(self, sid: int) -> int:
        """Machine-class id of one server."""
        return int(self._srv_cls[sid])

    # -- growth --------------------------------------------------------------
    def _grow_pairs(self, extra: int):
        need = self.n_pairs + extra
        if need <= self._mu.shape[0]:
            return
        cap = max(need, 2 * self._mu.shape[0])
        pad = cap - self._mu.shape[0]
        self._mu = np.concatenate([self._mu, np.zeros(pad)])
        self._busy = np.concatenate([self._busy, np.zeros(pad)])
        self._cls = np.concatenate([self._cls, np.zeros(pad, dtype=np.int64)])
        self._pair_failed = np.concatenate(
            [self._pair_failed, np.zeros(pad, dtype=bool)])

    def _grow_servers(self, extra: int):
        need = self.n_servers + extra
        if need <= self._on.shape[0]:
            return
        cap = max(need, 2 * self._on.shape[0])
        pad = cap - self._on.shape[0]
        self._on = np.concatenate([self._on, np.zeros(pad, dtype=bool)])
        self._on_since = np.concatenate([self._on_since, np.zeros(pad)])
        self._on_time = np.concatenate([self._on_time, np.zeros(pad)])
        self._turn_ons = np.concatenate([self._turn_ons,
                                         np.zeros(pad, dtype=np.int64)])
        self._srv_cls = np.concatenate([self._srv_cls,
                                        np.zeros(pad, dtype=np.int64)])
        self._mu_srv = np.concatenate([self._mu_srv, np.zeros(pad)])
        self._srv_failed = np.concatenate(
            [self._srv_failed, np.zeros(pad, dtype=bool)])

    # -- transitions ---------------------------------------------------------
    def open_pair(self, mu0: float = 0.0, class_id: int = 0) -> int:
        """A fresh standalone pair (offline mode: no server bookkeeping)."""
        assert not self.server_mode
        self._grow_pairs(1)
        pid = self.n_pairs
        self._mu[pid] = mu0
        self._busy[pid] = 0.0
        self._cls[pid] = class_id
        self.n_pairs += 1
        return pid

    def open_pairs(self, class_ids: np.ndarray) -> int:
        """Bulk :meth:`open_pair`: one fresh standalone pair per entry of
        ``class_ids`` (offline mode), all free at ``mu0 = 0``.  Returns the
        first new pair id; the block is contiguous and id-ascending — the
        bulk primitive behind the offline deadline-prior pinning phase."""
        assert not self.server_mode
        k = int(np.shape(class_ids)[0])
        self._grow_pairs(k)
        base = self.n_pairs
        self._mu[base: base + k] = 0.0
        self._busy[base: base + k] = 0.0
        self._cls[base: base + k] = class_ids
        self.n_pairs += k
        return base

    def new_server(self, t: float, class_id: int = 0) -> int:
        """Build and power on a server of ``l`` fresh pairs; returns its id."""
        assert self.server_mode
        self._grow_servers(1)
        self._grow_pairs(self.l)
        sid = self.n_servers
        self._on[sid] = True
        self._on_since[sid] = t
        self._turn_ons[sid] = self.l
        self._srv_cls[sid] = class_id
        self._mu_srv[sid] = t
        lo = self.n_pairs
        self._mu[lo: lo + self.l] = t   # a fresh pair is free *now*
        self._busy[lo: lo + self.l] = 0.0
        self._cls[lo: lo + self.l] = class_id
        self.n_servers += 1
        self.n_pairs += self.l
        return sid

    def wake_server(self, sid: int, t: float):
        self._on[sid] = True
        self._on_since[sid] = t
        self._turn_ons[sid] += self.l
        self._mu[sid * self.l: (sid + 1) * self.l] = t
        self._mu_srv[sid] = t

    def acquire_pair(self, t: float, class_id: int = 0) -> int:
        """A fresh pair of ``class_id``: prefer re-powering an off server of
        that class over building a new one."""
        avail = ~self._on[: self.n_servers] \
            & (self._srv_cls[: self.n_servers] == class_id)
        if self._any_failed:
            avail &= ~self._srv_failed[: self.n_servers]
        off = np.flatnonzero(avail)
        if off.size:
            sid = int(off[0])
            self.wake_server(sid, t)
        else:
            sid = self.new_server(t, class_id)
        return sid * self.l

    def assign(self, pid: int, start: float, duration: float):
        end = start + duration
        self._mu[pid] = end
        self._busy[pid] += duration
        if self.server_mode:
            sid = pid // self.l
            if end > self._mu_srv[sid]:
                self._mu_srv[sid] = end

    def book_assignments(self, pids: np.ndarray, starts: np.ndarray,
                         durations: np.ndarray):
        """Busy-time and server-finish bookkeeping for a whole batch of
        assignments (duplicate pids allowed, in chronological order) whose
        pair ``mu`` column is written separately via :meth:`sync_mu` — the
        group-commit half of the vectorized placement path."""
        np.add.at(self._busy, pids, durations)
        if self.server_mode:
            np.maximum.at(self._mu_srv, pids // self.l, starts + durations)

    def sync_mu(self, pids: np.ndarray, mus: np.ndarray):
        """Write a block of pair finish times (the other group-commit half;
        values must be the result of chronologically applied assignments)."""
        self._mu[pids] = mus

    def settle(self, t: float = np.inf):
        """Advance the engine to time ``t``, booking every DRS power-off
        *event* that occurred on the way — exactly.

        A server's power-off event fires ``rho`` slots after its last pair
        frees up, i.e. at ``mu_srv + rho``.  Every ON server whose event
        time is ``<= t`` is powered off with an on-span of exactly
        ``mu_srv + rho - on_since`` — independent of how far past the event
        the simulation has advanced, so sparse arrival slots never inflate
        ``E_idle``.  ``settle()`` with no argument books all outstanding
        events (the online :meth:`finalize`).
        """
        ns = self.n_servers
        if not ns:
            return
        mu_srv = self._mu_srv[: ns]
        on = self._on[: ns]
        off = on & (mu_srv + self.rho <= t + _EPS)
        if off.any():
            self._on_time[: ns][off] += (mu_srv[off] + self.rho
                                         - self._on_since[: ns][off])
            self._on[: ns][off] = False
            if self.track_offs:
                self._off_log.extend(np.flatnonzero(off).tolist())

    def drain_offs(self) -> list:
        """Return (and clear) the server ids powered off since the last
        drain.  Only populated with ``track_offs`` set."""
        out = self._off_log
        self._off_log = []
        return out

    # Back-compat name: the sweep is now the exact event-settling primitive
    # (the old sweep booked ``t - on_since`` at whatever slot it happened to
    # run, overcharging E_idle by the full arrival gap past ``mu + rho``).
    drs_sweep = settle

    # -- fault transitions (repro.core.faults) -------------------------------
    @property
    def pair_failed(self) -> np.ndarray:
        """Failed-pair mask, shape ``[n_pairs]``."""
        return self._pair_failed[: self.n_pairs]

    def fail_pairs(self, t: float, pids, busy_rollback=None) -> np.ndarray:
        """Crash the given pairs at time ``t``: energy settles EXACTLY at
        the failure instant — never past it.

        Callers must :meth:`settle` to ``t`` first, so every ON server has
        its power-off event strictly after ``t`` and the crash books the
        powered-on span ``t - on_since`` with no double counting.  Per
        failed pair the engine (a) truncates its finish time to ``t`` (an
        in-flight task dies at the crash), (b) subtracts ``busy_rollback``
        (the caller-computed booked-busy portion past ``t``; the
        :class:`repro.core.faults.FaultInjector` derives it from the
        orphaned assignment records), and (c) marks the pair ineligible.
        A server whose pairs have ALL failed while powered on is a hard
        crash: its on-span is booked up to ``t`` (no ``rho`` power-off
        tail — the machine lost power, it did not drain) and it leaves the
        wake pool until :meth:`revive_pairs`.  Already-failed pairs are
        no-ops.  Returns the pair ids actually transitioned.
        """
        assert self.server_mode
        pids = np.asarray(pids, dtype=np.int64)
        if busy_rollback is not None:
            rb = np.asarray(busy_rollback, dtype=np.float64)
        fresh_m = ~self._pair_failed[pids]
        fresh = pids[fresh_m]
        if fresh.size == 0:
            return fresh
        self.pool_epoch += 1
        self._pair_failed[fresh] = True
        self._any_failed = True
        if busy_rollback is not None:
            np.subtract.at(self._busy, fresh, rb[fresh_m])
        self._mu[fresh] = np.minimum(self._mu[fresh], t)
        for sid in np.unique(fresh // self.l).tolist():
            lo = sid * self.l
            hi = lo + self.l
            # mu only ever moved *down* here: re-reduce this server's block.
            self._mu_srv[sid] = self._mu[lo:hi].max()
            self._srv_failed[sid] = True
            if self._on[sid] and self._pair_failed[lo:hi].all():
                self._on_time[sid] += t - self._on_since[sid]
                self._on[sid] = False
        return fresh

    def revive_pairs(self, t: float, pids) -> np.ndarray:
        """Repair the given pairs at time ``t`` (the inverse transition).

        A revived pair on a still-powered server becomes assignable from
        ``t`` (its ``mu`` is floored to ``t``); a revived pair on an OFF
        server costs nothing now — the server merely rejoins the wake pool
        (once none of its pairs is failed) and a later
        :meth:`acquire_pair` powers it on through the normal DRS event.
        Pairs that are not failed are no-ops.  Returns the pair ids
        actually transitioned.
        """
        assert self.server_mode
        pids = np.asarray(pids, dtype=np.int64)
        sel = pids[self._pair_failed[pids]]
        if sel.size == 0:
            return sel
        self.pool_epoch += 1
        self._pair_failed[sel] = False
        for sid in np.unique(sel // self.l).tolist():
            lo = sid * self.l
            hi = lo + self.l
            if not self._pair_failed[lo:hi].any():
                self._srv_failed[sid] = False
            if self._on[sid]:
                blk = sel[(sel >= lo) & (sel < hi)]
                self._mu[blk] = np.maximum(self._mu[blk], t)
                if self._mu_srv[sid] < t:
                    self._mu_srv[sid] = t
        self._any_failed = bool(self._pair_failed[: self.n_pairs].any())
        return sel

    # -- pair selection (the policy rules' vectorized primitives) ------------
    def on_pair_mask(self) -> np.ndarray:
        """Mask of pairs whose server is powered on, shape ``[n_pairs]``."""
        return np.repeat(self._on[: self.n_servers], self.l)

    def eligible_mask(self, class_id: Optional[int] = None):
        """Mask of assignable pairs (``None`` == all): every pair offline,
        only pairs of powered-on servers online, never a failed pair;
        restricted to one machine class when ``class_id`` is given."""
        mask = None
        if self.server_mode:
            mask = np.repeat(self._on[: self.n_servers], self.l)
            if self._any_failed:
                mask = mask & ~self._pair_failed[: self.n_pairs]
        if class_id is not None and len(self.classes) > 1:
            cmask = self._cls[: self.n_pairs] == class_id
            mask = cmask if mask is None else (mask & cmask)
        return mask

    def pool_ids(self, class_id: Optional[int] = None) -> np.ndarray:
        """Ascending ids of the currently assignable pairs — the compact-pool
        snapshot primitive of :mod:`repro.core.placement`: every pair
        offline, pairs of powered-on servers online, optionally restricted
        to one machine class."""
        mask = self.eligible_mask(class_id)
        if mask is None:
            return np.arange(self.n_pairs, dtype=np.int64)
        return np.flatnonzero(mask)

    def worst_fit(self, class_id: Optional[int] = None) -> int:
        """The pair with the smallest mu (SPT; ties -> smallest id), or -1."""
        if self.n_pairs == 0:
            return -1
        mu = self.mu
        mask = self.eligible_mask(class_id)
        if mask is None:
            return int(np.argmin(mu))
        if not mask.any():
            return -1
        return int(np.argmin(np.where(mask, mu, np.inf)))

    def _fits(self, t_now: float, deadline: float, t_hat: float,
              class_id: Optional[int] = None):
        mu = self.mu
        fit = deadline - np.maximum(t_now, mu) >= t_hat - _EPS
        mask = self.eligible_mask(class_id)
        return fit if mask is None else (fit & mask)

    def best_fit(self, t_now: float, deadline: float, t_hat: float,
                 class_id: Optional[int] = None) -> int:
        """The *fitting* pair with the largest mu (tightest fit), or -1."""
        if self.n_pairs == 0:
            return -1
        fit = self._fits(t_now, deadline, t_hat, class_id)
        if not fit.any():
            return -1
        return int(np.argmax(np.where(fit, self.mu, -np.inf)))

    def first_fit(self, t_now: float, deadline: float, t_hat: float,
                  class_id: Optional[int] = None) -> int:
        """The lowest-id fitting pair, or -1."""
        if self.n_pairs == 0:
            return -1
        fit = self._fits(t_now, deadline, t_hat, class_id)
        if not fit.any():
            return -1
        return int(np.argmax(fit))

    # -- Eq. (7) finalizer ---------------------------------------------------
    def _energy(self):
        ns = self.n_servers
        srv_cls = self._srv_cls[:ns]
        pair_cls = self._cls[: self.n_pairs]
        e_idle = 0.0
        e_overhead = 0.0
        for k, mc in enumerate(self.classes):
            sm = srv_cls == k
            pm = pair_cls == k
            e_idle += mc.p_idle * (float(self._on_time[:ns][sm].sum()) * self.l
                                   - float(self.busy[pm].sum()))
            e_overhead += mc.delta_on * float(self._turn_ons[:ns][sm].sum())
        return e_idle, e_overhead

    def finalize(self):
        """Close the books: returns ``(e_idle, e_overhead, n_servers)``.

        Online mode settles every outstanding power-off event — the same
        :meth:`settle` primitive the simulation loop advances with, so a
        server powered off mid-run and one powered off here book the
        identical ``mu_srv + rho - on_since`` span; offline mode first runs
        Algorithm 3 per class to group the standalone pairs into
        (class-homogeneous) virtual servers, powered on for exactly their
        longest pair's span.  Both then evaluate the same Eq. (7)
        idle/overhead sums over the server arrays with per-class
        ``p_idle``/``delta_on``.
        """
        if self.server_mode:
            self.settle()
        elif self.n_pairs:
            # Algorithm 3 per class: each virtual server is powered on for
            # exactly its longest pair's span (servers never mix classes).
            pair_cls = self._cls[: self.n_pairs]
            spans, span_cls = [], []
            for k in range(len(self.classes)):
                mu_k = self.mu[pair_cls == k]
                if mu_k.size:
                    s = cl.server_spans(mu_k, self.l)
                    spans.append(s)
                    span_cls.append(np.full(s.shape[0], k, dtype=np.int64))
            spans = np.concatenate(spans) if spans else np.zeros(0)
            ns = spans.shape[0]
            self._grow_servers(ns)
            self._on_time[:ns] = spans
            self._turn_ons[:ns] = 0
            self._on[:ns] = False
            self._srv_cls[:ns] = np.concatenate(span_cls) if span_cls \
                else np.zeros(0, dtype=np.int64)
            self.n_servers = ns
        e_idle, e_overhead = self._energy()
        return e_idle, e_overhead, self.n_servers
