"""Accelerator-job adapter: LM training/serving jobs as paper-model tasks.

This is the hardware adaptation of the paper's central abstraction
(DESIGN.md S3): the schedulable unit becomes a non-preemptive *LM job* (train
N steps of an architecture x shape cell, or serve a request batch) running on
one accelerator slice, and the job's DVFS model parameters are **derived from
the roofline analysis of the compiled dry-run** instead of a profiling pass:

* ``delta`` (core-frequency sensitivity) := T_compute / (T_compute + T_memory)
  - a compute-bound cell (dense 4k training) is core-voltage sensitive, a
  memory-bound cell (32k decode) is HBM-frequency sensitive;
* ``t*`` (default duration) := steps x max(roofline terms) at the default
  operating point, plus a frequency-insensitive ``t0`` share (host input
  pipeline, collective latency floor);
* the power split ``(P0, gamma, c)`` comes from the chip envelope
  (:data:`repro.core.dvfs.TPU_V5E_CHIP`).

The resulting :class:`repro.core.tasks.TaskSet` is scheduled by the *same*
EDL theta-readjustment algorithms as the paper's GPU tasks - the scheduler
is architecture-agnostic; only the fitted constants differ.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from repro.core import dvfs
from repro.core.dvfs import DvfsParams, TPU_V5E_CHIP
from repro.core.tasks import TaskSet


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """Per-step roofline terms (seconds) of one compiled (arch x shape) cell."""

    arch: str
    shape: str
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def step_time(self) -> float:
        """Roofline step-time estimate: the max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def delta(self) -> float:
        """Compute-boundness, the paper's core-frequency sensitivity."""
        denom = self.compute_s + self.memory_s
        return float(self.compute_s / denom) if denom > 0 else 0.5

    @property
    def bottleneck(self) -> str:
        terms = dict(compute=self.compute_s, memory=self.memory_s,
                     collective=self.collective_s)
        return max(terms, key=terms.get)


@dataclasses.dataclass(frozen=True)
class AcceleratorJob:
    """A non-preemptive accelerator job: N steps of one (arch x shape) cell."""

    arch: str
    shape: str
    steps: int
    arrival: float            # slot units
    deadline_slack: float     # deadline = arrival + slack * t_star
    terms: RooflineTerms
    t0_frac: float = 0.10     # host/io share that does not scale with DVFS

    @property
    def t_star(self) -> float:
        return self.steps * self.terms.step_time  # seconds

    def to_params(self, chip: dict = TPU_V5E_CHIP) -> DvfsParams:
        """Paper-model constants for this job.

        The collective share of the step joins ``t0`` (ICI frequency is not a
        DVFS knob on the modeled part), so a collective-bound job is correctly
        seen by the scheduler as nearly frequency-insensitive.
        """
        step = self.terms.step_time
        coll_frac = self.terms.collective_s / step if step > 0 else 0.0
        t0_frac = min(0.95, max(self.t0_frac, coll_frac))
        return dvfs.tpu_task_params(self.t_star, self.terms.delta,
                                    t0_frac=t0_frac, chip=chip)


def jobs_to_task_set(jobs: Sequence[AcceleratorJob],
                     chip: dict = TPU_V5E_CHIP) -> TaskSet:
    """Convert accelerator jobs into a schedulable :class:`TaskSet`."""
    params = DvfsParams.stack([j.to_params(chip) for j in jobs])
    arrival = np.asarray([j.arrival for j in jobs], dtype=np.float64)
    t_star = np.asarray(params.default_time())
    deadline = arrival + np.asarray([j.deadline_slack for j in jobs]) * t_star
    # Utilization bookkeeping mirrors the paper's generator: u = t*/(d - a).
    util = t_star / np.maximum(deadline - arrival, 1e-9)
    return TaskSet(arrival=arrival, deadline=deadline, params=params,
                   utilization=util)


def synth_job_stream(terms_table: Dict[str, RooflineTerms], n_jobs: int,
                     horizon: int = 1440, seed: int = 0,
                     steps_range=(50, 500),
                     slack_range=(1.1, 3.0)) -> List[AcceleratorJob]:
    """A day of mixed training/serving jobs drawn from a roofline table.

    ``terms_table`` maps "arch/shape" cell names to their measured roofline
    terms (produced by ``benchmarks/roofline.py``); arrivals are uniform over
    the horizon with an offline batch at slot 0.
    """
    rng = np.random.default_rng(seed)
    cells = sorted(terms_table)
    out: List[AcceleratorJob] = []
    for i in range(n_jobs):
        cell = cells[int(rng.integers(len(cells)))]
        arch, shape = cell.split("/", 1)
        arrival = 0.0 if i < max(1, n_jobs // 8) else float(rng.integers(1, horizon))
        out.append(AcceleratorJob(
            arch=arch, shape=shape,
            steps=int(rng.integers(*steps_range)),
            arrival=arrival,
            deadline_slack=float(rng.uniform(*slack_range)),
            terms=terms_table[cell]))
    return sorted(out, key=lambda j: j.arrival)
