"""Online scheduling: EDL theta-readjustment + DRS, and the bin-packing
baseline (paper S4.2.2, Algorithms 4-6), as an event-driven simulation.

Time is divided into unit slots (one minute in the paper's day-long
simulation).  The system starts with an offline batch at ``T = 0``; online
tasks arrive at slots ``T >= 1`` (a fractional arrival is rounded *up* to
the next slot boundary — a task can never start, or have its DVFS window
measured, before it actually arrives).  The simulator advances arrival
group by arrival group; for each group at slot ``T`` it

1. *settles to T* - :meth:`~repro.core.engine.ClusterEngine.settle` books
   every DRS power-off *event* that occurred since the previous group at
   its exact time: a server goes off ``rho`` slots after its last pair
   frees up and is billed ``mu + rho - on_since`` of powered-on span, no
   matter how sparse the arrival slots are.  (Power-on is already an
   event - it happens exactly when a task acquires a fresh pair - so with
   this step every on/off transition is billed at its event time and the
   per-slot sweep of Algorithm 4 is recovered exactly, without iterating
   arrival-free slots.)
2. *assigns the group's tasks* (Algorithm 5) - per-task optimal DVFS
   configuration first (deadline-aware, on every machine class), then EDF
   order; each task tries its classes min-energy-feasible first and goes
   to the ON pair of that class with the shortest processing time if it
   fits, else a theta-readjustment shrinks its execution window, else the
   next class; a task no class can host powers on a fresh server of its
   primary class.

The bin-packing baseline (Algorithm 6) replaces the pair-selection rule with
worst-fit on utilization for the offline batch and first-fit for online
arrivals, with no readjustment - the heuristic used by Liu et al. [41].

This module is a thin *driver*: every pair-selection path — the per-class
compact pools, the batched EDF-prefix placement with θ-readjustment rows,
the pooled first-fit probes, the lazy-heap scalar finish and the per-task
reference loop — lives in the shared placement subsystem
(:class:`repro.core.placement.PlacementContext`), which also serves the
offline batch scheduler.  ``placement="vector"`` (default) runs the
batched paths, ``placement="scalar"`` the reference loop; both are
bit-identical (``tests/test_event_engine.py`` pins this on a mixed-class
horizon, ``benchmarks/online_scale.py`` guards the speedup).

Cluster state lives in :class:`~repro.core.engine.ClusterEngine` (the same
vectorized pair/server arrays the offline scheduler packs into, including
the per-pair ``class_id`` column), and the per-task DVFS solves are
batched: a task's slot-relative window ``d - ceil(a)`` is known before the
simulation starts, so Algorithm 1 runs ONCE for the whole horizon and every
class (one widened ``pallas_call`` with ``use_kernel=True``), and the
theta-readjustment re-solves — whose windows only pin finish times, never
the packing decisions — are deferred and batch-solved per class at the end
(``single_task.readjust_batch``).

Energy accounting follows Eq. (7) with per-class constants:

    E_total = E_run + E_idle + E_overhead
            = sum_i P_i (mu_i - kappa_i)
              + sum_k P_idle[k] * idle periods of class k
              + sum_k Delta[k] * (class-k pair turn-ons)

and every result reports ``e_bound``, the §5 analytical lower bound
(:func:`repro.core.bounds.theoretical_bound` with the DRS floors).

**Pipelined execution** (``pipeline=True``, the default): the driver cuts
the arrival groups into ~:data:`PIPELINE_CHUNK_TASKS`-task chunks and
double-buffers the DVFS solves against the host placement — chunk ``k+1``'s
Algorithm-1 batch is dispatched (JAX async dispatch; the host never blocks
on dispatch) before the host places chunk ``k``, and the deferred
θ-readjustment boundary re-solves join the next in-flight batch at each
chunk boundary instead of forcing a run-end sync.  The vector placement
path additionally keeps its per-class candidate pools alive across arrival
groups (``PlacementContext(incremental=True)``) with delta reconciliation.
Both halves are bit-identical to the synchronous path by construction: the
f32 key matrix IS the solver input and every solver is row-independent, so
chunked solves return the same bits as one monolithic batch, and the
persistent pools are pinned against the per-group rebuild by the frontier
invariant (see :mod:`repro.core.placement`).  ``pipeline=False`` runs the
reference path unchanged.  See docs/ARCHITECTURE.md (pipelined online
scheduling) for the dataflow diagram and the invalidation rules.

See docs/EQUATIONS.md for the full equation/algorithm -> code map.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core import bounds, cluster as cl, dvfs, machines, single_task
from repro.core.dvfs import ScalingInterval
from repro.core.engine import ClusterEngine
from repro.core.faults import FaultInjector, FaultTrace, make_degrade
from repro.core.placement import PendingRow, PlacementContext
from repro.core.scheduling import (chosen_feasibility, count_violations,
                                   fill_readjusted)
from repro.core.single_task import TaskConfig
from repro.core.tasks import TaskSet
from repro.kernels import layout


def arrival_slots(task_set: TaskSet) -> np.ndarray:
    """Each task's arrival slot: ``ceil(a)`` — the first slot boundary at or
    after the arrival.  A task with a fractional arrival must wait for the
    next slot; grouping by ``floor`` would let it start before it arrives
    and grant it a too-wide DVFS window."""
    return np.ceil(np.asarray(task_set.arrival, dtype=np.float64))


def _slot_groups(task_set: TaskSet):
    """Group task indices by arrival slot (``ceil(a)``), ascending (one
    argsort-split instead of one full scan per populated slot)."""
    slots = arrival_slots(task_set).astype(np.int64)
    order = np.argsort(slots, kind="stable")
    uniq, first = np.unique(slots[order], return_index=True)
    bounds_ = np.append(first, order.size)
    return [(int(s), order[a:b])
            for s, a, b in zip(uniq, bounds_[:-1], bounds_[1:])]


def online_configs(task_set: TaskSet, mcs, use_dvfs: bool = True,
                   interval: ScalingInterval = dvfs.WIDE,
                   use_kernel: bool = False,
                   dedup: bool = True) -> List[TaskConfig]:
    """Algorithm 1 (Alg 5, lines 1-4) for the WHOLE horizon and EVERY class
    in one batch: the per-task window ``d - ceil(a)`` is fixed by the
    arrival slot, so nothing forces a per-slot solve.  With
    ``use_kernel=True`` this is a single widened pallas_call covering all
    classes.  Exposed so benchmarks can time the solve and the simulation
    separately (pass the result back through ``schedule_online(cfgs=...)``).
    """
    deadline = np.asarray(task_set.deadline, dtype=np.float64)
    allowed = deadline - arrival_slots(task_set)
    if use_dvfs:
        return machines.configure_classes(task_set.params, allowed, mcs,
                                          interval, use_kernel=use_kernel,
                                          dedup=dedup)
    return machines.default_configs(task_set, mcs, allowed=allowed)


# The pipelined driver below runs with a solve batch in flight.  Host<->
# device sync points are confined to methods whose name ends in ``_sync``;
# the ``async-protocol`` lint family derives the in-flight window from the
# dispatch sites by dataflow and flags any other blocking call
# (np.asarray / jax.device_get / .block_until_ready) inside it, plus
# dropped/double-consumed AsyncSolve handles and reads of the full-horizon
# views before the sync point.

#: Target chunk size (tasks) for the pipelined driver: whole arrival groups
#: are accumulated until the count reaches this.  Large enough that one
#: batched solve amortizes its dispatch and per-chunk host bookkeeping
#: (eager op dispatch overhead is per chunk, not per row), small enough
#: that a 1M-task horizon still pipelines ~30 chunks deep.
PIPELINE_CHUNK_TASKS = 32768


def _chunk_groups(groups, target: int):
    """Cut the (slot, idx) arrival groups into consecutive runs of >=
    ``target`` tasks (always whole groups; the tail run may be smaller)."""
    chunks, cur, count = [], [], 0
    for g in groups:
        cur.append(g)
        count += g[1].size
        if count >= target:
            chunks.append(cur)
            cur, count = [], 0
    if cur:
        chunks.append(cur)
    return chunks


class _PipelineState:
    """The config-prefetch half of the pipelined driver.

    Owns the full-horizon per-class config arrays the rest of the run reads
    (:class:`~repro.core.single_task.TaskConfig` views created once, so the
    :class:`~repro.core.placement.PlacementContext` holds live aliases), the
    class-preference matrix, and the per-class ``t_min`` floors computed
    once up front (``dvfs.min_time`` is elementwise, so whole-horizon floors
    sliced per chunk are bitwise equal to per-call floors).

    :meth:`dispatch` sends one chunk's Algorithm-1 batch through
    :func:`repro.core.machines.configure_classes_async` (same keys, tags and
    batch shapes as the synchronous :func:`online_configs`, so the solve
    cache composes across both paths); :meth:`consume_sync` — the ONE sync
    point — blocks on the in-flight rows and scatters the assembled config
    columns into the horizon arrays.
    """

    def __init__(self, task_set: TaskSet, mcs, interval: ScalingInterval,
                 allowed: np.ndarray, use_kernel: bool, dedup: bool):
        self.mcs = mcs
        self.interval = interval
        self.use_kernel = use_kernel
        self.dedup = dedup
        self.params = task_set.params
        # Setup-time host-array normalization — no solve is in flight yet
        # (astype(copy=False) is a no-op view on the float64 input).
        self.allowed = allowed.astype(np.float64, copy=False)
        n = self.allowed.shape[0]
        self.adapted = [mc.adapt(self.params) for mc in mcs]
        self.ivs = [mc.effective_interval(interval) for mc in mcs]
        self.tmin = self._floors_sync()
        # Full-horizon config columns, filled chunk by chunk.  f64 storage:
        # every consumer (precompute casts, make_assignment floats, list
        # mirrors) upcasts the solver's f32 values anyway, and f32 -> f64 is
        # exact, so the scattered values read back bit-identically.
        self.cfgs = [TaskConfig(
            v=np.zeros(n), fc=np.zeros(n), fm=np.zeros(n),
            t_hat=np.zeros(n), p_hat=np.zeros(n), e_hat=np.zeros(n),
            t_min=np.zeros(n), deadline_prior=np.zeros(n, dtype=bool),
            feasible=np.zeros(n, dtype=bool), n_deadline_prior=0)
            for _ in mcs]
        self.order_cls = np.zeros((len(mcs), n), dtype=np.int64)

    def _floors_sync(self) -> list:
        """Whole-horizon ``t_min`` per class, one blocking solve at setup
        (before anything is in flight)."""
        return [np.asarray(dvfs.min_time(a, iv), np.float64)
                for a, iv in zip(self.adapted, self.ivs)]

    def dispatch(self, idx: np.ndarray):
        """Send one chunk's all-classes solve; returns the in-flight handle
        (``machines.ClassSolves``).  ``adapt`` is elementwise, so adapting
        the chunk subset equals slicing the adapted horizon, bitwise."""
        return machines.configure_classes_async(
            self.params[idx], self.allowed[idx], self.mcs, self.interval,
            use_kernel=self.use_kernel, dedup=self.dedup)

    def consume_sync(self, handle, idx: np.ndarray):
        """Block on one chunk's rows and scatter the assembled configs into
        the horizon arrays (+ the chunk's class-preference columns —
        ``argsort(axis=0)`` is per-column independent, so chunk columns
        equal the monolithic ``machines.class_order`` sliced)."""
        from repro.core import solver_cache

        allowed = self.allowed[idx]
        for c, rows in enumerate(handle.result()):
            sol = solver_cache.rows_to_solution(rows)
            cfg = single_task.config_from_solution(
                sol, self.adapted[c], allowed, self.ivs[c],
                tmin=self.tmin[c][idx])
            dst = self.cfgs[c]
            dst.v[idx] = cfg.v
            dst.fc[idx] = cfg.fc
            dst.fm[idx] = cfg.fm
            dst.t_hat[idx] = cfg.t_hat
            dst.p_hat[idx] = cfg.p_hat
            dst.e_hat[idx] = cfg.e_hat
            dst.t_min[idx] = cfg.t_min
            dst.deadline_prior[idx] = cfg.deadline_prior
            dst.feasible[idx] = cfg.feasible
        if len(self.mcs) > 1:
            e = np.stack([c.e_hat[idx] for c in self.cfgs])
            feas = np.stack([c.feasible[idx] for c in self.cfgs])
            key = np.where(feas, e, e + machines.INFEASIBLE_PENALTY)
            self.order_cls[:, idx] = np.argsort(key, axis=0, kind="stable")


class _ReadjustPrefetch:
    """The θ-readjustment half of the pipeline: at every chunk boundary the
    rows queued since the last boundary are dispatched per class
    (deadline-boundary solves, same keys/tags as
    :func:`repro.core.single_task.readjust_batch`), joining the in-flight
    work instead of the run-end batch; :meth:`flush_sync` materializes every
    batch and writes the records back exactly like
    :func:`repro.core.scheduling.fill_readjusted`.

    A readjusted window only pins the task's finish time — never the
    packing — and the solve values depend only on (task params, window,
    class), all fixed at queue time, so host-state changes (placements,
    power-offs, fault injection) between dispatch and flush cannot change
    the values.  Pair failures only *invalidate pools* (epoch bump), never
    prefetched solves.
    """

    def __init__(self, task_set: TaskSet, mcs, interval: ScalingInterval,
                 use_kernel: bool, dedup: bool):
        self.params = task_set.params
        self.mcs = mcs
        self.interval = interval
        self.use_kernel = use_kernel
        self.dedup = dedup
        self.sent = 0
        self.batches: list = []   # (assignment idx, windows, AsyncSolve)

    def dispatch(self, pending: List[PendingRow]):
        """Send every pending row queued since the last call, one boundary
        batch per class present."""
        new = pending[self.sent:]
        if not new:
            return
        self.sent = len(pending)
        k = len(new)
        ai = np.fromiter((r[0] for r in new), np.int64, k)
        rows = np.fromiter((r[1] for r in new), np.int64, k)
        windows = np.fromiter((r[2] for r in new), np.float64, k)
        cids = np.fromiter((r[3] for r in new), np.int64, k)
        for cid in np.unique(cids):
            mc = self.mcs[int(cid)]
            m = cids == cid
            handle = single_task.solve_rows_async(
                mc.adapt(self.params[rows[m]]), windows[m],
                mc.effective_interval(self.interval), boundary=True,
                use_kernel=self.use_kernel, dedup=self.dedup)
            self.batches.append((ai[m], windows[m], handle))

    def flush_sync(self, assignments: List[cl.Assignment],
                   pending: List[PendingRow]):
        """Dispatch the tail rows, block on every batch and write the DVFS
        fields back (the pipelined :func:`fill_readjusted`)."""
        self.dispatch(pending)
        for ai, windows, handle in self.batches:
            rows = handle.result()
            v = rows[:, layout.SOL_V].astype(np.float64)
            fc = rows[:, layout.SOL_FC].astype(np.float64)
            fm = rows[:, layout.SOL_FM].astype(np.float64)
            t = rows[:, layout.SOL_T].astype(np.float64)
            p = rows[:, layout.SOL_P].astype(np.float64)
            feas = rows[:, layout.SOL_FEASIBLE] > 0.5
            t = np.where(feas, np.minimum(t, windows), t)  # snap f32 residual
            e = p * t
            for j, a_i in enumerate(ai.tolist()):
                a = assignments[a_i]
                assignments[a_i] = dataclasses.replace(
                    a, v=float(v[j]), fc=float(fc[j]), fm=float(fm[j]),
                    power=float(p[j]), energy=float(e[j]))
        self.batches = []


def _chunk_span(ch):
    """One chunk's task index set: a contiguous ``slice`` when the indices
    form an unbroken run (always, for the slot-sorted traces
    ``tasks.generate_trace`` emits — then every per-chunk gather is a
    view), the concatenated index array otherwise."""
    cat = np.concatenate([idx for _, idx in ch])
    lo, hi = int(cat[0]), int(cat[-1]) + 1
    if hi - lo == cat.shape[0] and np.array_equal(
            cat, np.arange(lo, hi, dtype=cat.dtype)):
        return slice(lo, hi)
    return cat


def _drive_pipelined(groups, state: Optional[_PipelineState],
                     readj: _ReadjustPrefetch, ctx: PlacementContext,
                     pending: List[PendingRow], place_group, vector: bool,
                     prep: bool = False):
    """The double-buffered driver loop: with chunk ``k``'s configs landed,
    dispatch chunk ``k+1``'s solve and the readjustment rows queued so far,
    THEN place chunk ``k`` — the device computes ahead while the host
    packs.  ``state is None`` (configs injected / DVFS off) degenerates to
    chunked placement with the readjustment prefetch only.  ``prep``
    (worst-fit vector placement only) additionally hoists each group's
    placement prologue into one vectorized
    :meth:`~repro.core.placement.PlacementContext.prepare_chunk` pass."""
    chunks = _chunk_groups(groups, PIPELINE_CHUNK_TASKS)
    spans = [_chunk_span(ch) for ch in chunks]
    handle = state.dispatch(spans[0]) if state is not None and chunks else None
    for j, ch in enumerate(chunks):
        if state is not None:
            nxt = state.dispatch(spans[j + 1]) if j + 1 < len(chunks) else None
            state.consume_sync(handle, spans[j])
            if vector:
                ctx.update_tasks(spans[j])
            handle = nxt
        readj.dispatch(pending)
        if prep:
            for (slot, idx), pr in zip(ch, ctx.prepare_chunk(ch)):
                place_group(slot, idx, pr)
        else:
            for slot, idx in ch:
                place_group(slot, idx)


def schedule_online(task_set: TaskSet, l: int = 1, theta: float = 1.0,
                    algorithm: str = "edl", use_dvfs: bool = True,
                    interval: ScalingInterval = dvfs.WIDE,
                    rho: int = cl.RHO, p_idle: float = cl.P_IDLE,
                    delta_on: float = cl.DELTA_ON,
                    use_kernel: bool = False,
                    classes=None, placement: str = "vector",
                    cfgs: Optional[List[TaskConfig]] = None,
                    bound: bool = True,
                    dedup: bool = True,
                    faults: Optional[FaultTrace] = None,
                    pipeline: bool = True) -> cl.ScheduleResult:
    """Run the online simulation end to end (Algorithms 4-6).

    ``algorithm`` is ``"edl"`` (Algorithm 5, SPT + theta-readjustment) or
    ``"bin"`` (Algorithm 6, worst-fit utilization for the offline batch then
    first-fit online).  ``classes`` selects the machine-class mix (``None``
    = the homogeneous paper setup with the scalar ``p_idle``/``delta_on``;
    with a mix, idle power and turn-on overhead come from each class).
    ``placement`` picks the group-batched array path (``"vector"``, default)
    or the per-task reference loop (``"scalar"``); both produce bit-identical
    schedules.  ``cfgs`` injects precomputed :func:`online_configs` output
    (must match ``task_set``/``classes``/``use_dvfs``/``interval``).
    ``bound=False`` skips the ``e_bound`` solve (benchmarks timing the
    simulation hot path).  ``dedup=False`` opts every DVFS solve out of the
    unique-row dedup + solve cache (the default routes them through it,
    bit-identically).

    ``faults`` injects a :class:`repro.core.faults.FaultTrace`: every
    fail/revive event with ``t <= slot`` is applied — energy settled at the
    exact event time — before the slot's arrival group is placed, orphaned
    tasks re-enter placement with shrunken DVFS windows, and the result
    carries ``fault_stats``.  ``faults=None`` (default) leaves every
    failure check disengaged, bit-identical to the pre-fault behaviour.

    ``pipeline=True`` (default) overlaps the DVFS solve batches with the
    host placement (async chunked config prefetch + deferred readjustment
    batches joining the in-flight work + persistent candidate pools on the
    vector path) — bit-identical to ``pipeline=False``, the synchronous
    reference path (pinned by ``tests/test_pipeline.py``).
    """
    algorithm = algorithm.lower()
    if algorithm not in ("edl", "bin"):
        raise ValueError(f"unknown online algorithm {algorithm!r}")
    if placement not in ("vector", "scalar"):
        raise ValueError(f"unknown placement mode {placement!r}")
    mcs = machines.resolve_classes(classes, p_idle=p_idle, delta_on=delta_on)

    n = len(task_set)
    deadline = np.asarray(task_set.deadline, dtype=np.float64)

    from repro.core import solver_cache
    if dedup:
        # Per-run counters (reported as ``result.cache_stats``); the cached
        # rows themselves persist across runs.
        solver_cache.GLOBAL_CACHE.reset_stats()

    groups = _slot_groups(task_set)

    prefetch = pipeline and cfgs is None and use_dvfs and n > 0
    state: Optional[_PipelineState] = None
    if prefetch:
        allowed = deadline - arrival_slots(task_set)
        state = _PipelineState(task_set, mcs, interval, allowed,
                               use_kernel, dedup)
        cfgs = state.cfgs               # live views, filled chunk by chunk
        order_cls = state.order_cls
    else:
        if cfgs is None:
            cfgs = online_configs(task_set, mcs, use_dvfs=use_dvfs,
                                  interval=interval, use_kernel=use_kernel,
                                  dedup=dedup)
        order_cls = machines.class_order(cfgs)      # [C, n]

    eng = ClusterEngine(l, servers=True, rho=rho, classes=mcs)
    assignments: List[cl.Assignment] = []
    pending: List[PendingRow] = []
    ctx = PlacementContext(eng, cfgs, deadline, theta=theta,
                           readjust=(algorithm == "edl"),
                           assignments=assignments, pending=pending,
                           order_cls=order_cls,
                           incremental=(pipeline and placement == "vector"))

    injector = None
    if faults is not None:
        injector = FaultInjector(
            eng, ctx, faults, rule=("wf" if algorithm == "edl" else "ff"),
            degrade=make_degrade(task_set, mcs, interval, use_dvfs))

    def place_group(slot: int, idx: np.ndarray, prep=None):
        t_now = float(slot)
        if injector is not None:
            # Apply every failure/recovery event up to this slot, each
            # settled at its exact time, BEFORE placing the slot's arrivals.
            injector.advance(t_now)
        eng.settle(t_now)

        # EDF order — precomputed chunk-wide when ``prep`` is injected.
        order = None if prep is not None \
            else np.argsort(deadline[idx], kind="stable")

        base = len(assignments)
        if algorithm == "bin" and slot == 0:
            # Algorithm 6 offline phase: worst-fit on task utilization.
            ctx.binpack_offline_util(idx, order, t_now)
        elif placement == "vector":
            if algorithm == "bin":
                ctx.place_group_select(idx, order, t_now, "ff")
            else:
                ctx.place_group_vector(idx, order, t_now, prep=prep)
        else:
            ctx.place_group_scalar(idx, order, t_now,
                                   "wf" if algorithm == "edl" else "ff")
        if injector is not None:
            injector.register(base)

    if pipeline:
        readj = _ReadjustPrefetch(task_set, mcs, interval, use_kernel, dedup)
        _drive_pipelined(groups, state, readj, ctx, pending, place_group,
                         vector=(placement == "vector"),
                         prep=(placement == "vector" and algorithm == "edl"))
        if injector is not None:
            injector.advance(np.inf)   # events after the last arrival slot
        # Materialize the in-flight readjustment batches + the tail rows.
        readj.flush_sync(assignments, pending)
    else:
        for slot, idx in groups:
            place_group(slot, idx)
        if injector is not None:
            injector.advance(np.inf)   # events after the last arrival slot
        # Deferred theta-readjustment solves: one batched dispatch per class.
        fill_readjusted(assignments, pending, task_set, interval, use_kernel,
                        mcs, dedup=dedup)
    if injector is not None:
        injector.finalize_records()    # re-price truncated records

    # Per-run solve-cache counters: the config + readjustment solves (the
    # e_bound solve below is not part of the scheduling hot path).
    cache_stats = solver_cache.GLOBAL_CACHE.stats() if dedup else None

    e_idle, e_overhead, n_servers = eng.finalize()
    e_run = float(sum(a.energy for a in assignments))
    violations = count_violations(
        assignments, deadline, chosen_feasibility(cfgs, assignments, n))
    mk = max((a.finish for a in assignments), default=0.0)
    e_bound = bounds.theoretical_bound(
        task_set, interval=interval, classes=mcs, l=l,
        rho=rho, dedup=dedup).e_bound if bound else 0.0
    return cl.ScheduleResult(
        algorithm=f"online-{algorithm}{'+dvfs' if use_dvfs else ''}",
        e_run=e_run, e_idle=e_idle, e_overhead=e_overhead,
        n_pairs=eng.n_pairs, n_servers=n_servers,
        violations=violations, assignments=assignments, makespan=mk,
        feasible_pairs=eng.feasible_pairs, e_bound=e_bound,
        fault_stats=dict(injector.stats) if injector is not None else None,
        cache_stats=cache_stats,
    )
