"""Online scheduling: EDL theta-readjustment + DRS, and the bin-packing
baseline (paper S4.2.2, Algorithms 4-6), as an event-driven simulation.

Time is divided into unit slots (one minute in the paper's day-long
simulation).  The system starts with an offline batch at ``T = 0``; online
tasks arrive at slots ``T >= 1`` (a fractional arrival is rounded *up* to
the next slot boundary — a task can never start, or have its DVFS window
measured, before it actually arrives).  The simulator advances arrival
group by arrival group; for each group at slot ``T`` it

1. *settles to T* - :meth:`~repro.core.engine.ClusterEngine.settle` books
   every DRS power-off *event* that occurred since the previous group at
   its exact time: a server goes off ``rho`` slots after its last pair
   frees up and is billed ``mu + rho - on_since`` of powered-on span, no
   matter how sparse the arrival slots are.  (Power-on is already an
   event - it happens exactly when a task acquires a fresh pair - so with
   this step every on/off transition is billed at its event time and the
   per-slot sweep of Algorithm 4 is recovered exactly, without iterating
   arrival-free slots.)
2. *assigns the group's tasks* (Algorithm 5) - per-task optimal DVFS
   configuration first (deadline-aware, on every machine class), then EDF
   order; each task tries its classes min-energy-feasible first and goes
   to the ON pair of that class with the shortest processing time if it
   fits, else a theta-readjustment shrinks its execution window, else the
   next class; a task no class can host powers on a fresh server of its
   primary class.

The bin-packing baseline (Algorithm 6) replaces the pair-selection rule with
worst-fit on utilization for the offline batch and first-fit for online
arrivals, with no readjustment - the heuristic used by Liu et al. [41].

Placement is vectorized (``placement="vector"``, the default): each arrival
group's EDF-ordered class-preference probes are batched into array ops over
the engine's ``mu``/``class_id`` columns - the group's tasks are matched
against the k smallest-``mu`` eligible pairs of their primary class in one
shot, with a proven-equivalence prefix check (fits at the optimal length,
and no assigned pair re-enters the worst-fit frontier) - and only the tail
past the first collision (theta-readjustment, class fallback, fresh-server
power-on, or a worst-fit tie) goes through the scalar per-task loop.  Both
paths are bit-identical by construction (``tests/test_event_engine.py``
pins this on a mixed-class horizon); ``placement="scalar"`` keeps the pure
per-task reference loop for tests and benchmarks
(``benchmarks/online_scale.py`` guards the speedup).

Cluster state lives in :class:`~repro.core.engine.ClusterEngine` (the same
vectorized pair/server arrays the offline scheduler packs into, including
the per-pair ``class_id`` column), and the per-task DVFS solves are
batched: a task's slot-relative window ``d - ceil(a)`` is known before the
simulation starts, so Algorithm 1 runs ONCE for the whole horizon and every
class (one widened ``pallas_call`` with ``use_kernel=True``), and the
theta-readjustment re-solves — whose windows only pin finish times, never
the packing decisions — are deferred and batch-solved per class at the end
(``single_task.readjust_batch``).

Energy accounting follows Eq. (7) with per-class constants:

    E_total = E_run + E_idle + E_overhead
            = sum_i P_i (mu_i - kappa_i)
              + sum_k P_idle[k] * idle periods of class k
              + sum_k Delta[k] * (class-k pair turn-ons)

See docs/EQUATIONS.md for the full equation/algorithm -> code map.
"""

from __future__ import annotations

import heapq
from typing import List, Optional

import numpy as np

from repro.core import cluster as cl
from repro.core import dvfs, machines
from repro.core.dvfs import ScalingInterval
from repro.core.engine import ClusterEngine
from repro.core.scheduling import (PendingRow, chosen_feasibility,
                                   count_violations, fill_readjusted,
                                   make_assignment)
from repro.core.single_task import TaskConfig
from repro.core.tasks import TaskSet

_EPS = 1e-9


def arrival_slots(task_set: TaskSet) -> np.ndarray:
    """Each task's arrival slot: ``ceil(a)`` — the first slot boundary at or
    after the arrival.  A task with a fractional arrival must wait for the
    next slot; grouping by ``floor`` would let it start before it arrives
    and grant it a too-wide DVFS window."""
    return np.ceil(np.asarray(task_set.arrival, dtype=np.float64))


def _slot_groups(task_set: TaskSet):
    """Group task indices by arrival slot (``ceil(a)``), ascending (one
    argsort-split instead of one full scan per populated slot)."""
    slots = arrival_slots(task_set).astype(np.int64)
    order = np.argsort(slots, kind="stable")
    uniq, first = np.unique(slots[order], return_index=True)
    bounds = np.append(first, order.size)
    return [(int(s), order[a:b])
            for s, a, b in zip(uniq, bounds[:-1], bounds[1:])]


def online_configs(task_set: TaskSet, mcs, use_dvfs: bool = True,
                   interval: ScalingInterval = dvfs.WIDE,
                   use_kernel: bool = False) -> List[TaskConfig]:
    """Algorithm 1 (Alg 5, lines 1-4) for the WHOLE horizon and EVERY class
    in one batch: the per-task window ``d - ceil(a)`` is fixed by the
    arrival slot, so nothing forces a per-slot solve.  With
    ``use_kernel=True`` this is a single widened pallas_call covering all
    classes.  Exposed so benchmarks can time the solve and the simulation
    separately (pass the result back through ``schedule_online(cfgs=...)``).
    """
    deadline = np.asarray(task_set.deadline, dtype=np.float64)
    allowed = deadline - arrival_slots(task_set)
    if use_dvfs:
        return machines.configure_classes(task_set.params, allowed, mcs,
                                          interval, use_kernel=use_kernel)
    return machines.default_configs(task_set, mcs, allowed=allowed)


def schedule_online(task_set: TaskSet, l: int = 1, theta: float = 1.0,
                    algorithm: str = "edl", use_dvfs: bool = True,
                    interval: ScalingInterval = dvfs.WIDE,
                    rho: int = cl.RHO, p_idle: float = cl.P_IDLE,
                    delta_on: float = cl.DELTA_ON,
                    use_kernel: bool = False,
                    classes=None, placement: str = "vector",
                    cfgs: Optional[List[TaskConfig]] = None) -> cl.ScheduleResult:
    """Run the online simulation end to end (Algorithms 4-6).

    ``algorithm`` is ``"edl"`` (Algorithm 5, SPT + theta-readjustment) or
    ``"bin"`` (Algorithm 6, worst-fit utilization for the offline batch then
    first-fit online).  ``classes`` selects the machine-class mix (``None``
    = the homogeneous paper setup with the scalar ``p_idle``/``delta_on``;
    with a mix, idle power and turn-on overhead come from each class).
    ``placement`` picks the group-batched array path (``"vector"``, default)
    or the per-task reference loop (``"scalar"``); both produce bit-identical
    schedules.  ``cfgs`` injects precomputed :func:`online_configs` output
    (must match ``task_set``/``classes``/``use_dvfs``/``interval``).
    """
    algorithm = algorithm.lower()
    if algorithm not in ("edl", "bin"):
        raise ValueError(f"unknown online algorithm {algorithm!r}")
    if placement not in ("vector", "scalar"):
        raise ValueError(f"unknown placement mode {placement!r}")
    mcs = machines.reference_classes(p_idle=p_idle, delta_on=delta_on) \
        if classes is None else machines.get_classes(classes)

    n = len(task_set)
    deadline = np.asarray(task_set.deadline, dtype=np.float64)

    if cfgs is None:
        cfgs = online_configs(task_set, mcs, use_dvfs=use_dvfs,
                              interval=interval, use_kernel=use_kernel)
    order_cls = machines.class_order(cfgs)          # [C, n]
    primary = order_cls[0]
    pre = _edl_precompute(cfgs, order_cls) \
        if placement == "vector" and algorithm == "edl" else None

    eng = ClusterEngine(l, servers=True, rho=rho, classes=mcs)
    assignments: List[cl.Assignment] = []
    pending: List[PendingRow] = []

    for slot, idx in _slot_groups(task_set):
        t_now = float(slot)
        eng.settle(t_now)

        order = np.argsort(deadline[idx], kind="stable")  # EDF

        if algorithm == "bin" and slot == 0:
            # Algorithm 6 offline phase: worst-fit on task utilization.
            _binpack_offline(eng, deadline, idx, order, cfgs, order_cls,
                             primary, t_now, assignments)
            continue

        if placement == "vector":
            if algorithm == "bin":
                _bin_place_group_vector(eng, idx, order, deadline, cfgs,
                                        order_cls, primary, t_now,
                                        assignments)
            else:
                _edl_place_group_vector(eng, idx, order, deadline, cfgs,
                                        order_cls, primary, t_now, theta,
                                        assignments, pending, pre)
        else:
            _place_group_scalar(eng, idx, order, deadline, cfgs, order_cls,
                                primary, t_now, theta, algorithm,
                                assignments, pending)

    # Deferred theta-readjustment solves: one batched dispatch per class.
    fill_readjusted(assignments, pending, task_set, interval, use_kernel, mcs)

    e_idle, e_overhead, n_servers = eng.finalize()
    e_run = float(sum(a.energy for a in assignments))
    violations = count_violations(
        assignments, deadline, chosen_feasibility(cfgs, assignments, n))
    mk = max((a.finish for a in assignments), default=0.0)
    return cl.ScheduleResult(
        algorithm=f"online-{algorithm}{'+dvfs' if use_dvfs else ''}",
        e_run=e_run, e_idle=e_idle, e_overhead=e_overhead,
        n_pairs=eng.n_pairs, n_servers=n_servers,
        violations=violations, assignments=assignments, makespan=mk,
        feasible_pairs=eng.feasible_pairs,
    )


def _edl_precompute(cfgs: List[TaskConfig], order_cls: np.ndarray) -> dict:
    """Per-run lookups for the vectorized EDL path: config columns as numpy
    arrays (batch gathers) and as plain lists (the scalar-finish loop reads
    per-task floats ~20x faster off a list than off a numpy scalar)."""
    t_hat = [np.asarray(c.t_hat) for c in cfgs]
    t_min = [np.asarray(c.t_min) for c in cfgs]
    return {
        "t_hat": t_hat,
        "t_min": t_min,
        "t_hat_l": [a.tolist() for a in t_hat],
        "t_min_l": [a.tolist() for a in t_min],
        "order_cols": order_cls.T.tolist() if len(cfgs) > 1 else None,
        # record columns [v, fc, fm, p_hat, e_hat] stacked per class: one
        # fancy-index gathers a whole group's records
        "cols": [np.stack([np.asarray(c.v, np.float64),
                           np.asarray(c.fc, np.float64),
                           np.asarray(c.fm, np.float64),
                           np.asarray(c.p_hat, np.float64),
                           np.asarray(c.e_hat, np.float64)]) for c in cfgs],
    }


def _edl_place_group_vector(eng: ClusterEngine, idx, order,
                            deadline: np.ndarray, cfgs: List[TaskConfig],
                            order_cls: np.ndarray, primary: np.ndarray,
                            t_now: float, theta: float,
                            assignments: List[cl.Assignment],
                            pending: List[PendingRow], pre: dict):
    """Vectorized Algorithm-5 placement for one arrival group.

    Worst-fit (SPT) placement is a sequential min-extraction process, but
    it batches exactly under a frontier invariant: in EDF order, the
    group's class-``c`` tasks land on the smallest-``mu`` eligible pairs of
    class ``c`` — *provided* each task fits (at its optimal length, or via
    a theta-readjustment window, whose pair ``mu`` is pinned to the task's
    deadline) and no already-assigned pair's new ``mu`` drops back to (or
    ties) the worst-fit frontier.  Both conditions are array ops over
    per-class *compact pools* of the engine's ``mu``/``class_id`` columns:
    a pool is the pair-id-ascending snapshot of the ON pairs of one class,
    its candidate stream is the ``(mu, pair id)``-sorted frontier computed
    once per group (stale entries drop out by exact ``mu`` comparison, a
    power-on appends its fresh pairs), and ``min_new`` tracks the smallest
    already-assigned finish time so a frontier re-entry is detected across
    batch rounds.

    The placement loop alternates: batch the longest provable EDF prefix,
    then place the single violating task through the scalar rule — class
    fallback, readjustment that does not batch, fresh-server power-on, an
    exact ``mu`` tie — and resume batching while a round nets enough tasks
    to pay for itself; otherwise (power-on ramp, saturated frontier) the
    rest of the group runs the same scalar rule as a tight loop over the
    pools.  All pair-state writes are deferred to one engine commit
    (:meth:`~repro.core.engine.ClusterEngine.book_assignments` +
    :meth:`~repro.core.engine.ClusterEngine.sync_mu`) and the group's
    assignment records are gathered from the config columns in one shot.
    Bit-identical to :func:`_place_group_scalar` by construction.
    """
    k = order.shape[0]
    if k == 0:
        return
    gidx = np.asarray(idx)[order]                 # [k] task ids, EDF order
    prim = primary[gidx]                          # [k] primary class per task
    d = deadline[gidx]
    multi = len(eng.classes) > 1
    on_pairs = eng.on_pair_mask()
    t_hat_cls = pre["t_hat"]
    t_min_cls = pre["t_min"]

    # Per-class pool state: [ids, mus, n] (capacity-grown append arrays),
    # candidate stream [positions, recorded mus], fresh power-on positions,
    # and the min already-assigned finish time (frontier re-entry guard).
    pools = {}
    cands = {}
    fresh = {}
    min_new = {}

    def pool(c: int):
        """Compact (pair-id ascending) snapshot of the ON pairs of class c,
        kept in sync for the rest of the group (the engine itself is only
        written at the group commit)."""
        st = pools.get(c)
        if st is None:
            # on_pairs is the group-start snapshot: pairs acquired later in
            # the group are appended/inserted explicitly, so the stale
            # (shorter) mask only needs a size guard here.
            ids = np.flatnonzero(
                on_pairs & (eng.pair_class[: on_pairs.size] == c)) if multi \
                else np.flatnonzero(on_pairs)
            st = pools[c] = [ids, eng.mu[ids].astype(np.float64, copy=True),
                             ids.size]
            min_new[c] = np.inf
        return st

    def candidates(c: int, need: int):
        """Up to ``need`` live frontier entries of class c as (positions,
        recorded mus), ordered by ``(mu, pair id)``."""
        ids, mus, n = pool(c)
        st = cands.get(c)
        if st is None:
            kc = min(need, n)
            m_live = mus[:n]
            if kc and kc < n:
                part = np.argpartition(m_live, kc - 1)[:kc]
                cp = np.flatnonzero(m_live <= m_live[part].max())
                cp = cp[np.lexsort((cp, m_live[cp]))][:kc]
            else:
                cp = np.argsort(m_live, kind="stable")
            st = cands[c] = [cp, m_live[cp].copy()]
        cp, cm = st
        alive = pools[c][1][cp] == cm             # assigned entries drop out
        if not alive.all():
            cp, cm = cp[alive], cm[alive]
            cands[c] = [cp, cm]
        fr = fresh.get(c)
        if fr:
            fa = np.sort(np.asarray(fr, dtype=np.int64))
            fa = fa[pools[c][1][fa] == t_now]     # consumed fresh drop out
            if fa.size:
                allp = np.concatenate([cp, fa])
                allm = np.concatenate([cm, np.full(fa.size, t_now)])
                o = np.lexsort((allp, allm))      # position order == id order
                return allp[o][:need], allm[o][:need]
        return cp[:need], cm[:need]

    # Per-group record columns, filled by the batch rounds and the scalar
    # violators; records and engine state are committed once at the end.
    t_hat = np.empty(k)
    uniq_prim = np.unique(prim)
    for c in uniq_prim:
        m = prim == c
        t_hat[m] = t_hat_cls[int(c)][gidx[m]]
    pid_col = np.empty(k, dtype=np.int64)
    start_col = np.empty(k)
    dur_col = t_hat.copy()
    cls_col = prim.astype(np.int64, copy=True)
    readj_col = np.zeros(k, dtype=bool)
    base = len(assignments)

    valid = np.empty(k, dtype=bool)
    pos_sel = np.empty(k, dtype=np.int64)

    def batch_round(pos0: int) -> int:
        """Batch the longest provable EDF prefix of tasks[pos0:]; returns
        the number of positions consumed."""
        valid[pos0:] = False
        if order_cols is None:                    # single class: no split
            by_class = ((0, np.arange(pos0, k)),)
        else:
            sub = prim[pos0:]
            by_class = tuple((int(c), pos0 + np.flatnonzero(sub == c))
                             for c in np.unique(sub))
        for c, tm in by_class:
            cp, cm = candidates(int(c), tm.size)
            kc = cp.size
            if not kc:
                continue
            w = t_hat[tm[:kc]]
            start = np.maximum(t_now, cm)
            window = d[tm[:kc]] - start
            fit = window >= w - _EPS              # fits at optimal length
            if theta < 1.0:
                # Algorithm 5's theta-readjustment batches under the same
                # frontier check: the task occupies exactly its window, so
                # its pair's new mu is pinned to the task's deadline.
                t_min_c = t_min_cls[int(c)][gidx[tm[:kc]]]
                readj = ~fit & (window >= np.maximum(theta * w, t_min_c)
                                - _EPS)
            else:
                readj = np.zeros(kc, dtype=bool)
            dur = np.where(fit, w, window)
            ok = fit | readj
            # no-collision: every already-assigned pair's new mu (previous
            # rounds and this one) stays strictly above the next candidate
            # (ties -> scalar fallback).
            pm = np.minimum.accumulate(start + dur)
            ok &= np.concatenate(([min_new[int(c)]],
                                  np.minimum(pm[:-1], min_new[int(c)]))) > cm
            nvalid = kc if ok.all() else int(np.argmin(ok))
            if nvalid:
                sel = tm[:nvalid]
                valid[sel] = True
                pos_sel[sel] = cp[:nvalid]
                start_col[sel] = start[:nvalid]
                dur_col[sel] = dur[:nvalid]
                readj_col[sel] = readj[:nvalid]
        cut = k if valid[pos0:].all() else pos0 + int(np.argmin(valid[pos0:]))
        if cut == pos0:
            return 0
        if order_cols is None:
            by_class = ((0, np.arange(pos0, cut)),)
        else:
            sub = prim[pos0:cut]
            by_class = tuple((int(c), pos0 + np.flatnonzero(sub == c))
                             for c in np.unique(sub))
        for c, m in by_class:
            ids, mus, _ = pools[int(c)]
            pos = pos_sel[m]
            new_mu = start_col[m] + dur_col[m]
            mus[pos] = new_mu
            pid_col[m] = ids[pos]
            min_new[int(c)] = min(min_new[int(c)], float(new_mu.min()))
        for i in np.flatnonzero(readj_col[pos0:cut]).tolist():
            i += pos0
            pending.append((base + i, int(gidx[i]), float(dur_col[i]),
                            int(prim[i])))
        return cut - pos0

    def acquire(i: int, g: int, c: int):
        """Fresh-server fallback: power on (live engine event), splice the
        ``l`` new pairs into the class pool, assign the first one."""
        pid = eng.acquire_pair(t_now, class_id=c)
        st = pool(c)
        ids, mus, n = st
        pos = int(np.searchsorted(ids[:n], pid))
        if pos == n:
            if n + eng.l > ids.shape[0]:          # grow capacity, amortized
                grow = max(n + eng.l, 2 * ids.shape[0])
                st[0] = ids = np.concatenate(
                    [ids, np.empty(grow - ids.shape[0], dtype=np.int64)])
                st[1] = mus = np.concatenate(
                    [mus, np.empty(grow - mus.shape[0])])
        else:
            # waking a lower-id server inserts mid-pool: shift the stored
            # candidate/fresh positions past the insertion point.
            st[0] = ids = np.insert(ids[:n], pos,
                                    np.zeros(eng.l, dtype=np.int64))
            st[1] = mus = np.insert(mus[:n], pos, np.zeros(eng.l))
            if c in cands:
                cp, cm = cands[c]
                cands[c] = [np.where(cp >= pos, cp + eng.l, cp), cm]
            if fresh.get(c):
                fresh[c] = [p + eng.l if p >= pos else p for p in fresh[c]]
        ids[pos: pos + eng.l] = pid + np.arange(eng.l)
        mus[pos: pos + eng.l] = t_now
        st[2] = n + eng.l
        th = pre["t_hat_l"][c][g]
        mus[pos] = t_now + th                     # a fresh pair is free *now*
        if min_new[c] > t_now + th:
            min_new[c] = t_now + th
        fresh.setdefault(c, []).extend(range(pos + 1, pos + eng.l))
        pid_col[i], start_col[i], dur_col[i], cls_col[i] = pid, t_now, th, c
        return pos, pos != n

    t_hat_l = pre["t_hat_l"]
    t_min_l = pre["t_min_l"]
    order_cols = pre["order_cols"]
    readjust_on = theta < 1.0

    def place_one(i: int):
        """The scalar Algorithm-5 rule for one violating task, over the
        same pools (argmin over a pool's contiguous mu column is worst-fit
        with the identical lowest-pair-id tie-break)."""
        g = int(gidx[i])
        dd = d[i]
        readj_col[i] = False      # may hold a stale beyond-cut batch verdict
        for c in (order_cols[g] if order_cols is not None else (0,)):
            ids, mus, n = pool(c)
            if not n:
                continue
            j = int(mus[:n].argmin())
            mu_j = mus[j]
            start = t_now if mu_j < t_now else float(mu_j)
            th = t_hat_l[c][g]
            if dd - start >= th - _EPS:
                mus[j] = start + th
                if min_new[c] > start + th:
                    min_new[c] = start + th
                pid_col[i], start_col[i], dur_col[i], cls_col[i] = \
                    ids[j], start, th, c
                return
            elif readjust_on:
                t_theta = theta * th
                t_mn = t_min_l[c][g]
                if t_theta < t_mn:
                    t_theta = t_mn
                window = dd - start
                if window >= t_theta - _EPS:
                    mus[j] = start + window
                    if min_new[c] > start + window:
                        min_new[c] = start + window
                    pending.append((base + i, g, window, c))
                    pid_col[i], start_col[i], dur_col[i], cls_col[i] = \
                        ids[j], start, window, c
                    readj_col[i] = True
                    return
        acquire(i, g, int(prim[i]))

    def finish_scalar(i0: int):
        """The scalar rule for the rest of the group as a tight loop over a
        lazy frontier heap: alive candidate-stream originals, pairs already
        assigned this group, and outstanding fresh pairs, keyed ``(mu, pair
        id)`` — exactly argmin's lowest-pair-id tie-break.  Entries go stale
        by exact ``mu`` comparison; when the original stream runs dry while
        uncovered pool entries exist, the loop degrades to plain argmin
        over the pool.  Per-task reads come off plain python lists and the
        record columns are written back in bulk.  Multi-class groups fall
        back to the per-task rule, which also handles class fallback."""
        if order_cols is not None:
            for j in range(i0, k):
                place_one(j)
            return
        gl = gidx.tolist()
        dl = d.tolist()
        th_l = t_hat_l[0]
        tm_l = t_min_l[0]
        pid_l, st_l, du_l, rj_l = [], [], [], []
        ids, mus, n = pool(0)
        cp, cm = candidates(0, k - i0)
        heap = [(m, int(ids[p]), int(p), True)
                for m, p in zip(cm.tolist(), cp.tolist())]
        alive_orig = len(heap)
        statics = alive_orig < n                  # uncovered pool entries?
        if i0:
            tpos = np.unique(np.searchsorted(ids[:n], pid_col[:i0]))
            heap += [(float(mus[p]), int(ids[p]), int(p), False)
                     for p in tpos.tolist()]
        for p in fresh.get(0, ()):
            if mus[p] == t_now:
                heap.append((t_now, int(ids[p]), int(p), False))
        heapq.heapify(heap)
        heap_ok = True
        for j in range(i0, k):
            g = gl[j]
            dd = dl[j]
            top = None
            if heap_ok:
                while heap:
                    e = heap[0]
                    if mus[e[2]] == e[0]:
                        top = e
                        break
                    heapq.heappop(heap)
                    if e[3]:
                        alive_orig -= 1
                if top is None or (statics and alive_orig == 0):
                    heap_ok = False
                    top = None
            if not heap_ok and n:
                p = int(mus[:n].argmin())
                top = (float(mus[p]), int(ids[p]), p, False)
            if top is not None:
                mu_p, pid, p = top[0], top[1], top[2]
                start = t_now if mu_p < t_now else mu_p
                th = th_l[g]
                if dd - start >= th - _EPS:
                    if heap_ok:
                        heapq.heappop(heap)
                        if top[3]:
                            alive_orig -= 1
                        heapq.heappush(heap, (start + th, pid, p, False))
                    mus[p] = start + th
                    pid_l.append(pid)
                    st_l.append(start)
                    du_l.append(th)
                    rj_l.append(False)
                    continue
                if readjust_on:
                    t_theta = theta * th
                    t_mn = tm_l[g]
                    if t_theta < t_mn:
                        t_theta = t_mn
                    window = dd - start
                    if window >= t_theta - _EPS:
                        if heap_ok:
                            heapq.heappop(heap)
                            if top[3]:
                                alive_orig -= 1
                            heapq.heappush(heap,
                                           (start + window, pid, p, False))
                        mus[p] = start + window
                        pending.append((base + j, g, window, 0))
                        pid_l.append(pid)
                        st_l.append(start)
                        du_l.append(window)
                        rj_l.append(True)
                        continue
            pos, mid = acquire(j, g, 0)
            ids, mus, n = pools[0]
            if heap_ok:
                if mid:
                    # positions past the insertion point shifted by l
                    heap = [(m_, pi_, p_ + eng.l if p_ >= pos else p_, o_)
                            for m_, pi_, p_, o_ in heap]
                npid = int(ids[pos])
                heapq.heappush(heap, (float(mus[pos]), npid, pos, False))
                for jj in range(1, eng.l):
                    heapq.heappush(heap, (t_now, npid + jj, pos + jj, False))
            pid_l.append(pid_col[j])
            st_l.append(t_now)
            du_l.append(dur_col[j])
            rj_l.append(False)
        pid_col[i0:] = pid_l
        start_col[i0:] = st_l
        dur_col[i0:] = du_l
        readj_col[i0:] = rj_l

    # Alternate batch rounds with single scalar violators while batching
    # pays for itself; a round that nets only a few tasks (power-on ramp,
    # saturated frontier) costs more than the scalar rule, so finish the
    # group scalar from there.
    i = 0
    while i < k:
        consumed = batch_round(i)
        i += consumed
        if i >= k:
            break
        place_one(i)
        i += 1
        if consumed < 8:
            finish_scalar(i)
            break

    # ---- commit the group to the engine in one shot ------------------------
    # (power-ons already wrote their pairs live; only assigned pairs moved,
    # and for a pair assigned twice the chronologically last finish wins.)
    eng.book_assignments(pid_col, start_col, dur_col)
    _, last = np.unique(pid_col[::-1], return_index=True)
    last = k - 1 - last
    eng.sync_mu(pid_col[last], start_col[last] + dur_col[last])

    # ---- gather the group's assignment records in EDF order ----------------
    if order_cols is None:
        mat = pre["cols"][0][:, gidx]
    else:
        mat = np.empty((5, k))
        for c in np.unique(cls_col):
            m = cls_col == c
            mat[:, m] = pre["cols"][int(c)][:, gidx[m]]
    v_l, fc_l, fm_l, p_l, e_l = mat.tolist()
    finish = start_col + dur_col
    assignments.extend(map(
        cl.Assignment, gidx.tolist(), pid_col.tolist(), start_col.tolist(),
        finish.tolist(), v_l, fc_l, fm_l, p_l, e_l, readj_col.tolist(),
        cls_col.tolist()))


def _bin_place_group_vector(eng: ClusterEngine, idx, order,
                            deadline: np.ndarray, cfgs: List[TaskConfig],
                            order_cls: np.ndarray, primary: np.ndarray,
                            t_now: float,
                            assignments: List[cl.Assignment]):
    """Vectorized Algorithm-6 online placement for one arrival group.

    First-fit probes become array ops over per-class *compact pools* —
    snapshots of the eligible (ON, class-``c``) pairs in ascending pair-id
    order, so ``argmax(fit)`` is exactly the scalar ``first_fit`` tie-break
    — instead of rebuilding the full eligibility mask per probe.  Pools are
    kept in sync with the engine within the group (assignments update the
    pool ``mu``; a fresh-server power-on inserts its ``l`` pairs at their
    sorted position).  Bit-identical to the scalar loop by construction.
    """
    mu_all = eng._mu
    cls_all = eng._cls
    on_pairs = eng.on_pair_mask()
    pools = {}

    def pool(c: int):
        if c not in pools:
            if len(eng.classes) > 1:
                ids = np.flatnonzero(on_pairs & (cls_all[: on_pairs.size] == c))
            else:
                ids = np.flatnonzero(on_pairs)
            pools[c] = [ids, mu_all[ids].copy()]
        return pools[c]

    for r in order:
        gidx = int(idx[int(r)])
        d = deadline[gidx]
        placed = False
        for c in order_cls[:, gidx]:
            c = int(c)
            cfg_c = cfgs[c]
            t_hat = float(cfg_c.t_hat[gidx])
            ids, mus = pool(c)
            if not ids.size:
                continue
            starts = np.maximum(t_now, mus)
            fit = d - starts >= t_hat - _EPS
            if not fit.any():
                continue
            j = int(np.argmax(fit))
            pid = int(ids[j])
            start = float(starts[j])
            eng.assign(pid, start, t_hat)
            mus[j] = start + t_hat
            assignments.append(make_assignment(gidx, pid, start, cfg_c,
                                               class_id=c))
            placed = True
            break
        if not placed:
            c = int(primary[gidx])
            cfg_c = cfgs[c]
            pid = eng.acquire_pair(t_now, class_id=c)
            ids, mus = pool(c)
            pos = int(np.searchsorted(ids, pid))
            new_ids = pid + np.arange(eng.l)
            pools[c] = [np.insert(ids, pos, new_ids),
                        np.insert(mus, pos, np.full(eng.l, t_now))]
            ids, mus = pools[c]
            start = max(t_now, float(eng.mu[pid]))
            eng.assign(pid, start, float(cfg_c.t_hat[gidx]))
            mus[pos] = start + float(cfg_c.t_hat[gidx])
            assignments.append(make_assignment(gidx, pid, start, cfg_c,
                                               class_id=c))


def _place_group_scalar(eng: ClusterEngine, idx, order, deadline: np.ndarray,
                        cfgs: List[TaskConfig], order_cls: np.ndarray,
                        primary: np.ndarray, t_now: float, theta: float,
                        algorithm: str,
                        assignments: List[cl.Assignment],
                        pending: List[PendingRow]):
    """The per-task reference loop (Algorithm 5 EDL / Algorithm 6 online
    first-fit): class preference order, engine selectors, θ-readjustment and
    fresh-server fallback.  Also serves as the tail of the vectorized path
    after its first collision."""
    for r in order:
        gidx = int(idx[int(r)])
        d = deadline[gidx]

        placed = False
        for c in order_cls[:, gidx]:
            c = int(c)
            cfg_c = cfgs[c]
            t_hat = float(cfg_c.t_hat[gidx])
            if algorithm == "edl":
                pid = eng.worst_fit(class_id=c)  # SPT: ON pair free first
                if pid < 0:
                    continue
                start = max(t_now, float(eng.mu[pid]))
                if d - start >= t_hat - _EPS:
                    eng.assign(pid, start, t_hat)
                    assignments.append(make_assignment(
                        gidx, pid, start, cfg_c, class_id=c))
                    placed = True
                    break
                elif theta < 1.0:
                    t_theta = max(theta * t_hat, float(cfg_c.t_min[gidx]))
                    window = d - start
                    if window >= t_theta - _EPS:
                        eng.assign(pid, start, window)
                        pending.append((len(assignments), gidx, window, c))
                        assignments.append(make_assignment(
                            gidx, pid, start, cfg_c, duration=window,
                            readjusted=True, class_id=c))
                        placed = True
                        break
            else:  # bin: first-fit in pair-id order
                pid = eng.first_fit(t_now, d, t_hat, class_id=c)
                if pid >= 0:
                    start = max(t_now, float(eng.mu[pid]))
                    eng.assign(pid, start, t_hat)
                    assignments.append(make_assignment(
                        gidx, pid, start, cfg_c, class_id=c))
                    placed = True
                    break
        if not placed:
            c = int(primary[gidx])
            cfg_c = cfgs[c]
            pid = eng.acquire_pair(t_now, class_id=c)
            start = max(t_now, float(eng.mu[pid]))
            eng.assign(pid, start, float(cfg_c.t_hat[gidx]))
            assignments.append(make_assignment(gidx, pid, start, cfg_c,
                                               class_id=c))


def _binpack_offline(eng: ClusterEngine, deadline: np.ndarray, idx, order,
                     cfgs: List[TaskConfig], order_cls: np.ndarray,
                     primary: np.ndarray, t_now: float,
                     assignments: List[cl.Assignment]):
    """Algorithm 6, lines 1-7: worst-fit on utilization, cap at 1.0.

    The *optimal task utilization* is ``u_hat = t_hat / (d - a)``; the
    worst-fit heuristic sends each task to the pair with the lowest current
    utilization (among pairs of the candidate class), opening a new pair of
    the task's primary class when no candidate fits.
    """
    util = np.zeros(0)

    def grow():
        nonlocal util
        if util.shape[0] < eng.n_pairs:
            util = np.concatenate([util,
                                   np.zeros(eng.n_pairs - util.shape[0])])

    for r in order:
        gidx = int(idx[int(r)])
        d = deadline[gidx]
        grow()
        placed = False
        for c in order_cls[:, gidx]:
            c = int(c)
            cfg_c = cfgs[c]
            t_hat = float(cfg_c.t_hat[gidx])
            u_hat = t_hat / max(d - t_now, _EPS)
            on = eng.eligible_mask(class_id=c)
            if on is None:
                on = np.ones(eng.n_pairs, dtype=bool)
            if not on.any():
                continue
            pid = int(np.argmin(np.where(on, util[: eng.n_pairs], np.inf)))
            start = max(t_now, float(eng.mu[pid]))
            if util[pid] + u_hat > 1.0 + _EPS or d - start < t_hat - _EPS:
                continue
            eng.assign(pid, start, t_hat)
            util[pid] += u_hat
            assignments.append(make_assignment(gidx, pid, start, cfg_c,
                                               class_id=c))
            placed = True
            break
        if not placed:
            c = int(primary[gidx])
            cfg_c = cfgs[c]
            t_hat = float(cfg_c.t_hat[gidx])
            u_hat = t_hat / max(d - t_now, _EPS)
            pid = eng.acquire_pair(t_now, class_id=c)
            grow()
            start = max(t_now, float(eng.mu[pid]))
            eng.assign(pid, start, t_hat)
            util[pid] += u_hat
            assignments.append(make_assignment(gidx, pid, start, cfg_c,
                                               class_id=c))
