"""Online scheduling: EDL theta-readjustment + DRS, and the bin-packing
baseline (paper S4.2.2, Algorithms 4-6), as an event-driven simulation.

Time is divided into unit slots (one minute in the paper's day-long
simulation).  The system starts with an offline batch at ``T = 0``; online
tasks arrive at slots ``T >= 1`` (a fractional arrival is rounded *up* to
the next slot boundary — a task can never start, or have its DVFS window
measured, before it actually arrives).  The simulator advances arrival
group by arrival group; for each group at slot ``T`` it

1. *settles to T* - :meth:`~repro.core.engine.ClusterEngine.settle` books
   every DRS power-off *event* that occurred since the previous group at
   its exact time: a server goes off ``rho`` slots after its last pair
   frees up and is billed ``mu + rho - on_since`` of powered-on span, no
   matter how sparse the arrival slots are.  (Power-on is already an
   event - it happens exactly when a task acquires a fresh pair - so with
   this step every on/off transition is billed at its event time and the
   per-slot sweep of Algorithm 4 is recovered exactly, without iterating
   arrival-free slots.)
2. *assigns the group's tasks* (Algorithm 5) - per-task optimal DVFS
   configuration first (deadline-aware, on every machine class), then EDF
   order; each task tries its classes min-energy-feasible first and goes
   to the ON pair of that class with the shortest processing time if it
   fits, else a theta-readjustment shrinks its execution window, else the
   next class; a task no class can host powers on a fresh server of its
   primary class.

The bin-packing baseline (Algorithm 6) replaces the pair-selection rule with
worst-fit on utilization for the offline batch and first-fit for online
arrivals, with no readjustment - the heuristic used by Liu et al. [41].

This module is a thin *driver*: every pair-selection path — the per-class
compact pools, the batched EDF-prefix placement with θ-readjustment rows,
the pooled first-fit probes, the lazy-heap scalar finish and the per-task
reference loop — lives in the shared placement subsystem
(:class:`repro.core.placement.PlacementContext`), which also serves the
offline batch scheduler.  ``placement="vector"`` (default) runs the
batched paths, ``placement="scalar"`` the reference loop; both are
bit-identical (``tests/test_event_engine.py`` pins this on a mixed-class
horizon, ``benchmarks/online_scale.py`` guards the speedup).

Cluster state lives in :class:`~repro.core.engine.ClusterEngine` (the same
vectorized pair/server arrays the offline scheduler packs into, including
the per-pair ``class_id`` column), and the per-task DVFS solves are
batched: a task's slot-relative window ``d - ceil(a)`` is known before the
simulation starts, so Algorithm 1 runs ONCE for the whole horizon and every
class (one widened ``pallas_call`` with ``use_kernel=True``), and the
theta-readjustment re-solves — whose windows only pin finish times, never
the packing decisions — are deferred and batch-solved per class at the end
(``single_task.readjust_batch``).

Energy accounting follows Eq. (7) with per-class constants:

    E_total = E_run + E_idle + E_overhead
            = sum_i P_i (mu_i - kappa_i)
              + sum_k P_idle[k] * idle periods of class k
              + sum_k Delta[k] * (class-k pair turn-ons)

and every result reports ``e_bound``, the §5 analytical lower bound
(:func:`repro.core.bounds.theoretical_bound` with the DRS floors).

See docs/EQUATIONS.md for the full equation/algorithm -> code map.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core import bounds, cluster as cl, dvfs, machines
from repro.core.dvfs import ScalingInterval
from repro.core.engine import ClusterEngine
from repro.core.faults import FaultInjector, FaultTrace, make_degrade
from repro.core.placement import PendingRow, PlacementContext
from repro.core.scheduling import (chosen_feasibility, count_violations,
                                   fill_readjusted)
from repro.core.single_task import TaskConfig
from repro.core.tasks import TaskSet


def arrival_slots(task_set: TaskSet) -> np.ndarray:
    """Each task's arrival slot: ``ceil(a)`` — the first slot boundary at or
    after the arrival.  A task with a fractional arrival must wait for the
    next slot; grouping by ``floor`` would let it start before it arrives
    and grant it a too-wide DVFS window."""
    return np.ceil(np.asarray(task_set.arrival, dtype=np.float64))


def _slot_groups(task_set: TaskSet):
    """Group task indices by arrival slot (``ceil(a)``), ascending (one
    argsort-split instead of one full scan per populated slot)."""
    slots = arrival_slots(task_set).astype(np.int64)
    order = np.argsort(slots, kind="stable")
    uniq, first = np.unique(slots[order], return_index=True)
    bounds_ = np.append(first, order.size)
    return [(int(s), order[a:b])
            for s, a, b in zip(uniq, bounds_[:-1], bounds_[1:])]


def online_configs(task_set: TaskSet, mcs, use_dvfs: bool = True,
                   interval: ScalingInterval = dvfs.WIDE,
                   use_kernel: bool = False,
                   dedup: bool = True) -> List[TaskConfig]:
    """Algorithm 1 (Alg 5, lines 1-4) for the WHOLE horizon and EVERY class
    in one batch: the per-task window ``d - ceil(a)`` is fixed by the
    arrival slot, so nothing forces a per-slot solve.  With
    ``use_kernel=True`` this is a single widened pallas_call covering all
    classes.  Exposed so benchmarks can time the solve and the simulation
    separately (pass the result back through ``schedule_online(cfgs=...)``).
    """
    deadline = np.asarray(task_set.deadline, dtype=np.float64)
    allowed = deadline - arrival_slots(task_set)
    if use_dvfs:
        return machines.configure_classes(task_set.params, allowed, mcs,
                                          interval, use_kernel=use_kernel,
                                          dedup=dedup)
    return machines.default_configs(task_set, mcs, allowed=allowed)


def schedule_online(task_set: TaskSet, l: int = 1, theta: float = 1.0,
                    algorithm: str = "edl", use_dvfs: bool = True,
                    interval: ScalingInterval = dvfs.WIDE,
                    rho: int = cl.RHO, p_idle: float = cl.P_IDLE,
                    delta_on: float = cl.DELTA_ON,
                    use_kernel: bool = False,
                    classes=None, placement: str = "vector",
                    cfgs: Optional[List[TaskConfig]] = None,
                    bound: bool = True,
                    dedup: bool = True,
                    faults: Optional[FaultTrace] = None) -> cl.ScheduleResult:
    """Run the online simulation end to end (Algorithms 4-6).

    ``algorithm`` is ``"edl"`` (Algorithm 5, SPT + theta-readjustment) or
    ``"bin"`` (Algorithm 6, worst-fit utilization for the offline batch then
    first-fit online).  ``classes`` selects the machine-class mix (``None``
    = the homogeneous paper setup with the scalar ``p_idle``/``delta_on``;
    with a mix, idle power and turn-on overhead come from each class).
    ``placement`` picks the group-batched array path (``"vector"``, default)
    or the per-task reference loop (``"scalar"``); both produce bit-identical
    schedules.  ``cfgs`` injects precomputed :func:`online_configs` output
    (must match ``task_set``/``classes``/``use_dvfs``/``interval``).
    ``bound=False`` skips the ``e_bound`` solve (benchmarks timing the
    simulation hot path).  ``dedup=False`` opts every DVFS solve out of the
    unique-row dedup + solve cache (the default routes them through it,
    bit-identically).

    ``faults`` injects a :class:`repro.core.faults.FaultTrace`: every
    fail/revive event with ``t <= slot`` is applied — energy settled at the
    exact event time — before the slot's arrival group is placed, orphaned
    tasks re-enter placement with shrunken DVFS windows, and the result
    carries ``fault_stats``.  ``faults=None`` (default) leaves every
    failure check disengaged, bit-identical to the pre-fault behaviour.
    """
    algorithm = algorithm.lower()
    if algorithm not in ("edl", "bin"):
        raise ValueError(f"unknown online algorithm {algorithm!r}")
    if placement not in ("vector", "scalar"):
        raise ValueError(f"unknown placement mode {placement!r}")
    mcs = machines.resolve_classes(classes, p_idle=p_idle, delta_on=delta_on)

    n = len(task_set)
    deadline = np.asarray(task_set.deadline, dtype=np.float64)

    if cfgs is None:
        cfgs = online_configs(task_set, mcs, use_dvfs=use_dvfs,
                              interval=interval, use_kernel=use_kernel,
                              dedup=dedup)
    order_cls = machines.class_order(cfgs)          # [C, n]

    eng = ClusterEngine(l, servers=True, rho=rho, classes=mcs)
    assignments: List[cl.Assignment] = []
    pending: List[PendingRow] = []
    ctx = PlacementContext(eng, cfgs, deadline, theta=theta,
                           readjust=(algorithm == "edl"),
                           assignments=assignments, pending=pending,
                           order_cls=order_cls)

    injector = None
    if faults is not None:
        injector = FaultInjector(
            eng, ctx, faults, rule=("wf" if algorithm == "edl" else "ff"),
            degrade=make_degrade(task_set, mcs, interval, use_dvfs))

    for slot, idx in _slot_groups(task_set):
        t_now = float(slot)
        if injector is not None:
            # Apply every failure/recovery event up to this slot, each
            # settled at its exact time, BEFORE placing the slot's arrivals.
            injector.advance(t_now)
        eng.settle(t_now)

        order = np.argsort(deadline[idx], kind="stable")  # EDF

        base = len(assignments)
        if algorithm == "bin" and slot == 0:
            # Algorithm 6 offline phase: worst-fit on task utilization.
            ctx.binpack_offline_util(idx, order, t_now)
        elif placement == "vector":
            if algorithm == "bin":
                ctx.place_group_select(idx, order, t_now, "ff")
            else:
                ctx.place_group_vector(idx, order, t_now)
        else:
            ctx.place_group_scalar(idx, order, t_now,
                                   "wf" if algorithm == "edl" else "ff")
        if injector is not None:
            injector.register(base)

    if injector is not None:
        injector.advance(np.inf)       # events after the last arrival slot

    # Deferred theta-readjustment solves: one batched dispatch per class.
    fill_readjusted(assignments, pending, task_set, interval, use_kernel, mcs,
                    dedup=dedup)
    if injector is not None:
        injector.finalize_records()    # re-price truncated records

    e_idle, e_overhead, n_servers = eng.finalize()
    e_run = float(sum(a.energy for a in assignments))
    violations = count_violations(
        assignments, deadline, chosen_feasibility(cfgs, assignments, n))
    mk = max((a.finish for a in assignments), default=0.0)
    e_bound = bounds.theoretical_bound(
        task_set, interval=interval, classes=mcs, l=l,
        rho=rho, dedup=dedup).e_bound if bound else 0.0
    return cl.ScheduleResult(
        algorithm=f"online-{algorithm}{'+dvfs' if use_dvfs else ''}",
        e_run=e_run, e_idle=e_idle, e_overhead=e_overhead,
        n_pairs=eng.n_pairs, n_servers=n_servers,
        violations=violations, assignments=assignments, makespan=mk,
        feasible_pairs=eng.feasible_pairs, e_bound=e_bound,
        fault_stats=dict(injector.stats) if injector is not None else None,
    )
