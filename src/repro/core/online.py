"""Online scheduling: EDL theta-readjustment + DRS, and the bin-packing
baseline (paper S4.2.2, Algorithms 4-6).

Time is divided into unit slots (one minute in the paper's day-long
simulation).  The system starts with an offline batch at ``T = 0``; online
tasks arrive at slots ``T >= 1``.  Each slot the simulator

1. *processes leaving tasks* - pairs whose last task finished become idle;
2. *turns servers off* (DRS) - a server is powered off once **all** of its
   pairs have been idle for at least ``rho`` slots, paying no further idle
   power but incurring a per-class ``Delta``-per-pair overhead on the next
   power-on (servers are class-homogeneous, so the sweep operates per
   class by construction);
3. *assigns newly arrived tasks* (Algorithm 5) - per-task optimal DVFS
   configuration first (deadline-aware, on every machine class), then EDF
   order; each task tries its classes min-energy-feasible first and goes
   to the ON pair of that class with the shortest processing time if it
   fits, else a theta-readjustment shrinks its execution window, else the
   next class; a task no class can host powers on a fresh server of its
   primary class.

The bin-packing baseline (Algorithm 6) replaces the pair-selection rule with
worst-fit on utilization for the offline batch and first-fit for online
arrivals, with no readjustment - the heuristic used by Liu et al. [41].

Cluster state lives in :class:`~repro.core.engine.ClusterEngine` (the same
vectorized pair/server arrays the offline scheduler packs into, including
the per-pair ``class_id`` column), and the per-task DVFS solves are
batched: a task's slot-relative window ``d - floor(a)`` is known before the
simulation starts, so Algorithm 1 runs ONCE for the whole horizon and every
class (one widened ``pallas_call`` with ``use_kernel=True``), and the
theta-readjustment re-solves — whose windows only pin finish times, never
the packing decisions — are deferred and batch-solved per class at the end
(``single_task.readjust_batch``).

Energy accounting follows Eq. (7) with per-class constants:

    E_total = E_run + E_idle + E_overhead
            = sum_i P_i (mu_i - kappa_i)
              + sum_k P_idle[k] * idle periods of class k
              + sum_k Delta[k] * (class-k pair turn-ons)

See docs/EQUATIONS.md for the full equation/algorithm -> code map.
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core import cluster as cl
from repro.core import dvfs, machines
from repro.core.dvfs import ScalingInterval
from repro.core.engine import ClusterEngine
from repro.core.scheduling import (PendingRow, chosen_feasibility,
                                   count_violations, fill_readjusted,
                                   make_assignment)
from repro.core.single_task import TaskConfig
from repro.core.tasks import TaskSet

_EPS = 1e-9


def _slot_groups(task_set: TaskSet):
    """Group task indices by integer arrival slot, ascending."""
    arrival = np.asarray(task_set.arrival)
    slots = np.unique(arrival.astype(np.int64))
    return [(int(s), np.nonzero(arrival.astype(np.int64) == s)[0]) for s in slots]


def schedule_online(task_set: TaskSet, l: int = 1, theta: float = 1.0,
                    algorithm: str = "edl", use_dvfs: bool = True,
                    interval: ScalingInterval = dvfs.WIDE,
                    rho: int = cl.RHO, p_idle: float = cl.P_IDLE,
                    delta_on: float = cl.DELTA_ON,
                    use_kernel: bool = False,
                    classes=None) -> cl.ScheduleResult:
    """Run the online simulation end to end (Algorithms 4-6).

    ``algorithm`` is ``"edl"`` (Algorithm 5, SPT + theta-readjustment) or
    ``"bin"`` (Algorithm 6, worst-fit utilization for the offline batch then
    first-fit online).  ``classes`` selects the machine-class mix (``None``
    = the homogeneous paper setup with the scalar ``p_idle``/``delta_on``;
    with a mix, idle power and turn-on overhead come from each class).
    """
    algorithm = algorithm.lower()
    if algorithm not in ("edl", "bin"):
        raise ValueError(f"unknown online algorithm {algorithm!r}")
    mcs = machines.reference_classes(p_idle=p_idle, delta_on=delta_on) \
        if classes is None else machines.get_classes(classes)

    n = len(task_set)
    deadline = np.asarray(task_set.deadline, dtype=np.float64)
    arrival = np.asarray(task_set.arrival, dtype=np.float64)

    # Algorithm 1 (Alg 5, lines 1-4) for the WHOLE horizon and EVERY class
    # in one batch: the per-task window d - T is fixed by the arrival slot,
    # so nothing forces a per-slot solve.  With use_kernel=True this is a
    # single widened pallas_call covering all classes.
    if use_dvfs:
        allowed = deadline - arrival.astype(np.int64).astype(np.float64)
        cfgs = machines.configure_classes(task_set.params, allowed, mcs,
                                          interval, use_kernel=use_kernel)
    else:
        cfgs = machines.default_configs(task_set, mcs)
    order_cls = machines.class_order(cfgs)          # [C, n]
    primary = order_cls[0]

    eng = ClusterEngine(l, servers=True, rho=rho, classes=mcs)
    assignments: List[cl.Assignment] = []
    pending: List[PendingRow] = []

    for slot, idx in _slot_groups(task_set):
        t_now = float(slot)
        eng.drs_sweep(t_now)

        order = np.argsort(deadline[idx], kind="stable")  # EDF

        if algorithm == "bin" and slot == 0:
            # Algorithm 6 offline phase: worst-fit on task utilization.
            _binpack_offline(eng, deadline, idx, order, cfgs, order_cls,
                             primary, t_now, assignments)
            continue

        for r in order:
            gidx = int(idx[int(r)])
            d = deadline[gidx]

            placed = False
            for c in order_cls[:, gidx]:
                c = int(c)
                cfg_c = cfgs[c]
                t_hat = float(cfg_c.t_hat[gidx])
                if algorithm == "edl":
                    pid = eng.worst_fit(class_id=c)  # SPT: ON pair free first
                    if pid < 0:
                        continue
                    start = max(t_now, float(eng.mu[pid]))
                    if d - start >= t_hat - _EPS:
                        eng.assign(pid, start, t_hat)
                        assignments.append(make_assignment(
                            gidx, pid, start, cfg_c, class_id=c))
                        placed = True
                        break
                    elif theta < 1.0:
                        t_theta = max(theta * t_hat, float(cfg_c.t_min[gidx]))
                        window = d - start
                        if window >= t_theta - _EPS:
                            eng.assign(pid, start, window)
                            pending.append((len(assignments), gidx, window, c))
                            assignments.append(make_assignment(
                                gidx, pid, start, cfg_c, duration=window,
                                readjusted=True, class_id=c))
                            placed = True
                            break
                else:  # bin: first-fit in pair-id order
                    pid = eng.first_fit(t_now, d, t_hat, class_id=c)
                    if pid >= 0:
                        start = max(t_now, float(eng.mu[pid]))
                        eng.assign(pid, start, t_hat)
                        assignments.append(make_assignment(
                            gidx, pid, start, cfg_c, class_id=c))
                        placed = True
                        break
            if not placed:
                c = int(primary[gidx])
                cfg_c = cfgs[c]
                pid = eng.acquire_pair(t_now, class_id=c)
                start = max(t_now, float(eng.mu[pid]))
                eng.assign(pid, start, float(cfg_c.t_hat[gidx]))
                assignments.append(make_assignment(gidx, pid, start, cfg_c,
                                                   class_id=c))

    # Deferred theta-readjustment solves: one batched dispatch per class.
    fill_readjusted(assignments, pending, task_set, interval, use_kernel, mcs)

    e_idle, e_overhead, n_servers = eng.finalize()
    e_run = float(sum(a.energy for a in assignments))
    violations = count_violations(
        assignments, deadline, chosen_feasibility(cfgs, assignments, n))
    mk = max((a.finish for a in assignments), default=0.0)
    return cl.ScheduleResult(
        algorithm=f"online-{algorithm}{'+dvfs' if use_dvfs else ''}",
        e_run=e_run, e_idle=e_idle, e_overhead=e_overhead,
        n_pairs=eng.n_pairs, n_servers=n_servers,
        violations=violations, assignments=assignments, makespan=mk,
        feasible_pairs=eng.feasible_pairs,
    )


def _binpack_offline(eng: ClusterEngine, deadline: np.ndarray, idx, order,
                     cfgs: List[TaskConfig], order_cls: np.ndarray,
                     primary: np.ndarray, t_now: float,
                     assignments: List[cl.Assignment]):
    """Algorithm 6, lines 1-7: worst-fit on utilization, cap at 1.0.

    The *optimal task utilization* is ``u_hat = t_hat / (d - a)``; the
    worst-fit heuristic sends each task to the pair with the lowest current
    utilization (among pairs of the candidate class), opening a new pair of
    the task's primary class when no candidate fits.
    """
    util = np.zeros(0)

    def grow():
        nonlocal util
        if util.shape[0] < eng.n_pairs:
            util = np.concatenate([util,
                                   np.zeros(eng.n_pairs - util.shape[0])])

    for r in order:
        gidx = int(idx[int(r)])
        d = deadline[gidx]
        grow()
        placed = False
        for c in order_cls[:, gidx]:
            c = int(c)
            cfg_c = cfgs[c]
            t_hat = float(cfg_c.t_hat[gidx])
            u_hat = t_hat / max(d - t_now, _EPS)
            on = eng.eligible_mask(class_id=c)
            if on is None:
                on = np.ones(eng.n_pairs, dtype=bool)
            if not on.any():
                continue
            pid = int(np.argmin(np.where(on, util[: eng.n_pairs], np.inf)))
            start = max(t_now, float(eng.mu[pid]))
            if util[pid] + u_hat > 1.0 + _EPS or d - start < t_hat - _EPS:
                continue
            eng.assign(pid, start, t_hat)
            util[pid] += u_hat
            assignments.append(make_assignment(gidx, pid, start, cfg_c,
                                               class_id=c))
            placed = True
            break
        if not placed:
            c = int(primary[gidx])
            cfg_c = cfgs[c]
            t_hat = float(cfg_c.t_hat[gidx])
            u_hat = t_hat / max(d - t_now, _EPS)
            pid = eng.acquire_pair(t_now, class_id=c)
            grow()
            start = max(t_now, float(eng.mu[pid]))
            eng.assign(pid, start, t_hat)
            util[pid] += u_hat
            assignments.append(make_assignment(gidx, pid, start, cfg_c,
                                               class_id=c))
