"""Online scheduling: EDL theta-readjustment + DRS, and the bin-packing
baseline (paper S4.2.2, Algorithms 4-6).

Time is divided into unit slots (one minute in the paper's day-long
simulation).  The system starts with an offline batch at ``T = 0``; online
tasks arrive at slots ``T >= 1``.  Each slot the simulator

1. *processes leaving tasks* - pairs whose last task finished become idle;
2. *turns servers off* (DRS) - a server is powered off once **all** of its
   pairs have been idle for at least ``rho`` slots, paying no further idle
   power but incurring a ``Delta``-per-pair overhead on the next power-on;
3. *assigns newly arrived tasks* (Algorithm 5) - per-task optimal DVFS
   configuration first (deadline-aware), then EDF order; each task goes to
   the ON pair with the shortest processing time if it fits, else a
   theta-readjustment shrinks its execution window, else a fresh server is
   powered on.

The bin-packing baseline (Algorithm 6) replaces the pair-selection rule with
worst-fit on utilization for the offline batch and first-fit for online
arrivals, with no readjustment - the heuristic used by Liu et al. [41].

Cluster state lives in :class:`~repro.core.engine.ClusterEngine` (the same
vectorized pair/server arrays the offline scheduler packs into), and the
per-task DVFS solves are batched: a task's slot-relative window
``d - floor(a)`` is known before the simulation starts, so Algorithm 1 runs
ONCE for the whole horizon (one ``pallas_call`` with ``use_kernel=True``),
and the theta-readjustment re-solves — whose windows only pin finish times,
never the packing decisions — are deferred and batch-solved in one more
dispatch at the end (``single_task.readjust_batch``).

Energy accounting follows Eq. (7):

    E_total = E_run + E_idle + E_overhead
            = sum_i P_i (mu_i - kappa_i) + P_idle * sum idle periods
              + Delta * (number of pair turn-ons)
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core import cluster as cl
from repro.core import dvfs, single_task
from repro.core.dvfs import ScalingInterval
from repro.core.engine import ClusterEngine
from repro.core.scheduling import (count_violations, default_config,
                                   fill_readjusted, make_assignment)
from repro.core.single_task import TaskConfig
from repro.core.tasks import TaskSet

_EPS = 1e-9


def _slot_groups(task_set: TaskSet):
    """Group task indices by integer arrival slot, ascending."""
    arrival = np.asarray(task_set.arrival)
    slots = np.unique(arrival.astype(np.int64))
    return [(int(s), np.nonzero(arrival.astype(np.int64) == s)[0]) for s in slots]


def schedule_online(task_set: TaskSet, l: int = 1, theta: float = 1.0,
                    algorithm: str = "edl", use_dvfs: bool = True,
                    interval: ScalingInterval = dvfs.WIDE,
                    rho: int = cl.RHO, p_idle: float = cl.P_IDLE,
                    delta_on: float = cl.DELTA_ON,
                    use_kernel: bool = False) -> cl.ScheduleResult:
    """Run the online simulation end to end (Algorithms 4-6).

    ``algorithm`` is ``"edl"`` (Algorithm 5, SPT + theta-readjustment) or
    ``"bin"`` (Algorithm 6, worst-fit utilization for the offline batch then
    first-fit online).
    """
    algorithm = algorithm.lower()
    if algorithm not in ("edl", "bin"):
        raise ValueError(f"unknown online algorithm {algorithm!r}")

    deadline = np.asarray(task_set.deadline, dtype=np.float64)
    arrival = np.asarray(task_set.arrival, dtype=np.float64)

    # Algorithm 1 (Alg 5, lines 1-4) for the WHOLE horizon in one batch: the
    # per-task window d - T is fixed by the arrival slot, so nothing forces a
    # per-slot solve.  With use_kernel=True this is a single pallas_call.
    if use_dvfs:
        allowed = deadline - arrival.astype(np.int64).astype(np.float64)
        cfg = single_task.configure_tasks(task_set.params, allowed, interval,
                                          use_kernel=use_kernel)
    else:
        cfg = default_config(task_set)

    eng = ClusterEngine(l, servers=True, rho=rho, p_idle=p_idle,
                        delta_on=delta_on)
    assignments: List[cl.Assignment] = []
    pending: List[Tuple[int, int, float]] = []

    for slot, idx in _slot_groups(task_set):
        t_now = float(slot)
        eng.drs_sweep(t_now)

        order = np.argsort(deadline[idx], kind="stable")  # EDF

        if algorithm == "bin" and slot == 0:
            # Algorithm 6 offline phase: worst-fit on task utilization.
            _binpack_offline(eng, deadline, idx, order, cfg, t_now,
                             assignments)
            continue

        for r in order:
            gidx = int(idx[int(r)])
            d = deadline[gidx]
            t_hat = float(cfg.t_hat[gidx])

            placed = False
            if algorithm == "edl":
                pid = eng.worst_fit()   # SPT: the ON pair free the earliest
                if pid >= 0:
                    start = max(t_now, float(eng.mu[pid]))
                    if d - start >= t_hat - _EPS:
                        eng.assign(pid, start, t_hat)
                        assignments.append(make_assignment(gidx, pid, start, cfg))
                        placed = True
                    elif theta < 1.0:
                        t_theta = max(theta * t_hat, float(cfg.t_min[gidx]))
                        window = d - start
                        if window >= t_theta - _EPS:
                            eng.assign(pid, start, window)
                            pending.append((len(assignments), gidx, window))
                            assignments.append(make_assignment(
                                gidx, pid, start, cfg, duration=window,
                                readjusted=True))
                            placed = True
            else:  # bin: first-fit in pair-id order
                pid = eng.first_fit(t_now, d, t_hat)
                if pid >= 0:
                    start = max(t_now, float(eng.mu[pid]))
                    eng.assign(pid, start, t_hat)
                    assignments.append(make_assignment(gidx, pid, start, cfg))
                    placed = True
            if not placed:
                pid = eng.acquire_pair(t_now)
                start = max(t_now, float(eng.mu[pid]))
                eng.assign(pid, start, t_hat)
                assignments.append(make_assignment(gidx, pid, start, cfg))

    # Deferred theta-readjustment solves: one batched dispatch for the run.
    fill_readjusted(assignments, pending, task_set, interval, use_kernel)

    e_idle, e_overhead, n_servers = eng.finalize()
    e_run = float(sum(a.energy for a in assignments))
    violations = count_violations(assignments, deadline, cfg.feasible)
    mk = max((a.finish for a in assignments), default=0.0)
    return cl.ScheduleResult(
        algorithm=f"online-{algorithm}{'+dvfs' if use_dvfs else ''}",
        e_run=e_run, e_idle=e_idle, e_overhead=e_overhead,
        n_pairs=eng.n_pairs, n_servers=n_servers,
        violations=violations, assignments=assignments, makespan=mk,
        feasible_pairs=eng.feasible_pairs,
    )


def _binpack_offline(eng: ClusterEngine, deadline: np.ndarray, idx, order,
                     cfg: TaskConfig, t_now: float,
                     assignments: List[cl.Assignment]):
    """Algorithm 6, lines 1-7: worst-fit on utilization, cap at 1.0.

    The *optimal task utilization* is ``u_hat = t_hat / (d - a)``; the
    worst-fit heuristic sends each task to the pair with the lowest current
    utilization, opening a new pair when the best candidate would exceed 1.
    """
    util = np.zeros(0)
    for r in order:
        gidx = int(idx[int(r)])
        d = deadline[gidx]
        t_hat = float(cfg.t_hat[gidx])
        u_hat = t_hat / max(d - t_now, _EPS)
        if util.shape[0] < eng.n_pairs:
            util = np.concatenate([util,
                                   np.zeros(eng.n_pairs - util.shape[0])])
        pid = -1
        on = eng.eligible_mask()
        if on is not None and on.any():
            pid = int(np.argmin(np.where(on, util[: eng.n_pairs], np.inf)))
            start = max(t_now, float(eng.mu[pid]))
            if util[pid] + u_hat > 1.0 + _EPS or d - start < t_hat - _EPS:
                pid = -1
        if pid < 0:
            pid = eng.acquire_pair(t_now)
            if util.shape[0] < eng.n_pairs:
                util = np.concatenate(
                    [util, np.zeros(eng.n_pairs - util.shape[0])])
        start = max(t_now, float(eng.mu[pid]))
        eng.assign(pid, start, t_hat)
        util[pid] += u_hat
        assignments.append(make_assignment(gidx, pid, start, cfg))
