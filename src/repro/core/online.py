"""Online scheduling: EDL theta-readjustment + DRS, and the bin-packing
baseline (paper S4.2.2, Algorithms 4-6).

Time is divided into unit slots (one minute in the paper's day-long
simulation).  The system starts with an offline batch at ``T = 0``; online
tasks arrive at slots ``T >= 1``.  Each slot the simulator

1. *processes leaving tasks* - pairs whose last task finished become idle;
2. *turns servers off* (DRS) - a server is powered off once **all** of its
   pairs have been idle for at least ``rho`` slots, paying no further idle
   power but incurring a ``Delta``-per-pair overhead on the next power-on;
3. *assigns newly arrived tasks* (Algorithm 5) - per-task optimal DVFS
   configuration first (deadline-aware), then EDF order; each task goes to
   the ON pair with the shortest processing time if it fits, else a
   theta-readjustment shrinks its execution window, else a fresh server is
   powered on.

The bin-packing baseline (Algorithm 6) replaces the pair-selection rule with
worst-fit on utilization for the offline batch and first-fit for online
arrivals, with no readjustment - the heuristic used by Liu et al. [41].

Energy accounting follows Eq. (7):

    E_total = E_run + E_idle + E_overhead
            = sum_i P_i (mu_i - kappa_i) + P_idle * sum idle periods
              + Delta * (number of pair turn-ons)
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core import cluster as cl
from repro.core import dvfs, single_task
from repro.core.dvfs import ScalingInterval
from repro.core.single_task import TaskConfig
from repro.core.tasks import TaskSet

_EPS = 1e-9


@dataclasses.dataclass
class _PairState:
    idx: int
    server: int
    mu: float = 0.0       # finish time of the last assigned task
    busy: float = 0.0     # cumulative busy duration


@dataclasses.dataclass
class _ServerState:
    idx: int
    pairs: List[int]
    on: bool = False
    on_since: float = 0.0
    on_time: float = 0.0
    turn_ons: int = 0     # counted in pair units (omega)

    def power_on(self, t: float):
        self.on = True
        self.on_since = t
        self.turn_ons += len(self.pairs)

    def power_off(self, t: float):
        self.on = False
        self.on_time += t - self.on_since


class OnlineCluster:
    """Slot-driven cluster simulator shared by EDL and bin-packing."""

    def __init__(self, l: int, rho: int = cl.RHO, p_idle: float = cl.P_IDLE,
                 delta_on: float = cl.DELTA_ON, max_pairs: int = 2048):
        self.l = l
        self.rho = rho
        self.p_idle = p_idle
        self.delta_on = delta_on
        self.max_pairs = max_pairs
        self.pairs: List[_PairState] = []
        self.servers: List[_ServerState] = []

    # -- state interrogation ------------------------------------------------
    def on_pair_ids(self) -> List[int]:
        out: List[int] = []
        for srv in self.servers:
            if srv.on:
                out.extend(srv.pairs)
        return out

    @property
    def n_pairs(self) -> int:
        return len(self.pairs)

    def n_on_servers(self) -> int:
        return sum(1 for s in self.servers if s.on)

    # -- transitions ---------------------------------------------------------
    def new_server(self, t: float) -> _ServerState:
        sid = len(self.servers)
        pair_ids = []
        for _ in range(self.l):
            pid = len(self.pairs)
            self.pairs.append(_PairState(idx=pid, server=sid, mu=t))
            pair_ids.append(pid)
        srv = _ServerState(idx=sid, pairs=pair_ids)
        srv.power_on(t)
        self.servers.append(srv)
        return srv

    def wake_server(self, srv: _ServerState, t: float):
        srv.power_on(t)
        for pid in srv.pairs:
            self.pairs[pid].mu = t  # an awakened pair is free *now*

    def acquire_pair(self, t: float) -> _PairState:
        """A fresh pair: prefer re-powering an off server over building one."""
        for srv in self.servers:
            if not srv.on:
                self.wake_server(srv, t)
                return self.pairs[srv.pairs[0]]
        return self.pairs[self.new_server(t).pairs[0]]

    def drs_sweep(self, t: float):
        """Turn off every server whose pairs have all been idle >= rho."""
        for srv in self.servers:
            if not srv.on:
                continue
            mu_max = max(self.pairs[p].mu for p in srv.pairs)
            if t - mu_max >= self.rho - _EPS:
                srv.power_off(t)

    def assign(self, pair: _PairState, start: float, duration: float):
        pair.mu = start + duration
        pair.busy += duration

    # -- energy --------------------------------------------------------------
    def finalize(self):
        """Power off remaining servers and return (E_idle, E_overhead)."""
        for srv in self.servers:
            if srv.on:
                mu_max = max(self.pairs[p].mu for p in srv.pairs)
                srv.power_off(mu_max + self.rho)
        e_idle = 0.0
        omega = 0
        for srv in self.servers:
            omega += srv.turn_ons
            busy = sum(self.pairs[p].busy for p in srv.pairs)
            e_idle += srv.on_time * self.l - busy
        return self.p_idle * e_idle, self.delta_on * omega


def _slot_groups(task_set: TaskSet):
    """Group task indices by integer arrival slot, ascending."""
    arrival = np.asarray(task_set.arrival)
    slots = np.unique(arrival.astype(np.int64))
    return [(int(s), np.nonzero(arrival.astype(np.int64) == s)[0]) for s in slots]


def schedule_online(task_set: TaskSet, l: int = 1, theta: float = 1.0,
                    algorithm: str = "edl", use_dvfs: bool = True,
                    interval: ScalingInterval = dvfs.WIDE,
                    rho: int = cl.RHO, p_idle: float = cl.P_IDLE,
                    delta_on: float = cl.DELTA_ON,
                    use_kernel: bool = False) -> cl.ScheduleResult:
    """Run the online simulation end to end (Algorithms 4-6).

    ``algorithm`` is ``"edl"`` (Algorithm 5, SPT + theta-readjustment) or
    ``"bin"`` (Algorithm 6, worst-fit utilization for the offline batch then
    first-fit online).
    """
    algorithm = algorithm.lower()
    if algorithm not in ("edl", "bin"):
        raise ValueError(f"unknown online algorithm {algorithm!r}")

    deadline = np.asarray(task_set.deadline, dtype=np.float64)
    arrival = np.asarray(task_set.arrival, dtype=np.float64)
    clu = OnlineCluster(l, rho=rho, p_idle=p_idle, delta_on=delta_on)
    assignments: List[cl.Assignment] = []
    violations = 0

    import heapq

    for slot, idx in _slot_groups(task_set):
        t_now = float(slot)
        clu.drs_sweep(t_now)

        # Phase 1 (Alg 5, lines 1-4): per-task optimal configuration.
        sub = task_set.subset(idx)
        if use_dvfs:
            cfg = single_task.configure_tasks(
                sub.params, deadline[idx] - t_now, interval, use_kernel=use_kernel)
        else:
            from repro.core.scheduling import default_config
            cfg = default_config(sub)
        violations += int(np.sum(~cfg.feasible))

        order = np.argsort(deadline[idx], kind="stable")  # EDF

        if algorithm == "bin" and slot == 0:
            # Algorithm 6 offline phase: worst-fit on task utilization.
            _binpack_offline(clu, task_set, idx, order, cfg, t_now, assignments)
            continue

        for r in order:
            r = int(r)
            gidx = int(idx[r])
            d = deadline[gidx]
            t_hat = float(cfg.t_hat[r])

            on_ids = clu.on_pair_ids()
            placed = False
            if on_ids:
                if algorithm == "edl":
                    cand = [min(on_ids, key=lambda p: (clu.pairs[p].mu, p))]
                else:  # bin: first-fit in pair-id order
                    cand = sorted(on_ids)
                for pid in cand:
                    pair = clu.pairs[pid]
                    start = max(t_now, pair.mu)
                    if d - start >= t_hat - _EPS:
                        clu.assign(pair, start, t_hat)
                        assignments.append(_mk(gidx, pid, start, cfg, r))
                        placed = True
                        break
                if not placed and algorithm == "edl" and theta < 1.0:
                    pid = cand[0]
                    pair = clu.pairs[pid]
                    start = max(t_now, pair.mu)
                    t_theta = max(theta * t_hat, float(cfg.t_min[r]))
                    window = d - start
                    if window >= t_theta - _EPS:
                        ov = single_task.readjust(task_set.params[gidx],
                                                  float(window), interval)
                        clu.assign(pair, start, ov[3])
                        assignments.append(cl.Assignment(
                            task=gidx, pair=pid, start=float(start),
                            finish=float(start + ov[3]), v=ov[0], fc=ov[1],
                            fm=ov[2], power=ov[4], energy=ov[5],
                            readjusted=True))
                        placed = True
            if not placed:
                pair = clu.acquire_pair(t_now)
                start = max(t_now, pair.mu)
                clu.assign(pair, start, t_hat)
                assignments.append(_mk(gidx, pair.idx, start, cfg, r))

    e_idle, e_overhead = clu.finalize()
    e_run = float(sum(a.energy for a in assignments))
    for a in assignments:
        if a.finish > deadline[a.task] + 1e-6:
            violations += 1
    mk = max((a.finish for a in assignments), default=0.0)
    return cl.ScheduleResult(
        algorithm=f"online-{algorithm}{'+dvfs' if use_dvfs else ''}",
        e_run=e_run, e_idle=e_idle, e_overhead=e_overhead,
        n_pairs=clu.n_pairs, n_servers=len(clu.servers),
        violations=violations, assignments=assignments, makespan=mk,
        feasible_pairs=clu.n_pairs <= clu.max_pairs,
    )


def _mk(task: int, pid: int, start: float, cfg: TaskConfig, row: int) -> cl.Assignment:
    return cl.Assignment(
        task=task, pair=pid, start=float(start),
        finish=float(start + cfg.t_hat[row]), v=float(cfg.v[row]),
        fc=float(cfg.fc[row]), fm=float(cfg.fm[row]),
        power=float(cfg.p_hat[row]), energy=float(cfg.e_hat[row]))


def _binpack_offline(clu: OnlineCluster, task_set: TaskSet, idx, order,
                     cfg: TaskConfig, t_now: float,
                     assignments: List[cl.Assignment]):
    """Algorithm 6, lines 1-7: worst-fit on utilization, cap at 1.0.

    The *optimal task utilization* is ``u_hat = t_hat / (d - a)``; the
    worst-fit heuristic sends each task to the pair with the lowest current
    utilization, opening a new pair when the best candidate would exceed 1.
    """
    deadline = np.asarray(task_set.deadline, dtype=np.float64)
    pair_util: dict[int, float] = {}
    for r in order:
        r = int(r)
        gidx = int(idx[r])
        t_hat = float(cfg.t_hat[r])
        u_hat = t_hat / max(deadline[gidx] - t_now, _EPS)
        on_ids = clu.on_pair_ids()
        best: Optional[int] = None
        if on_ids:
            best = min(on_ids, key=lambda p: (pair_util.get(p, 0.0), p))
            pair = clu.pairs[best]
            start = max(t_now, pair.mu)
            if (pair_util.get(best, 0.0) + u_hat > 1.0 + _EPS or
                    deadline[gidx] - start < t_hat - _EPS):
                best = None
        if best is None:
            pair = clu.acquire_pair(t_now)
            best = pair.idx
        pair = clu.pairs[best]
        start = max(t_now, pair.mu)
        clu.assign(pair, start, t_hat)
        pair_util[best] = pair_util.get(best, 0.0) + u_hat
        assignments.append(_mk(gidx, best, start, cfg, r))
