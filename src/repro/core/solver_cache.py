"""Unique-row dedup + persistent LRU cache for the DVFS solvers.

Every scheduler path funnels through one solver shape: a batch of rows
``(params, allowed, readjust, interval bounds)`` mapped independently to an
8-tuple solution ``(v, fc, fm, t, p, e, deadline_prior, feasible)``.  Two
structural facts make that batch massively redundant:

* traces are drawn from a small application library (the paper's benchmark
  apps; ``tasks.generate_trace`` patterns), so recurring jobs produce
  *duplicate rows* inside one call;
* sweep benchmarks re-solve the *same* rows cell after cell (θ-sweep cells
  share the task set; ``theoretical_bound`` is recomputed per scenario
  knob), so whole calls repeat *across* invocations.

This module removes both: :func:`solve_rows` quantizes the rows to the
solver's own f32 precision, keeps only ``np.unique`` rows, serves
previously-solved rows from a process-wide LRU (:data:`GLOBAL_CACHE`),
dispatches the solver on the residual misses only, and scatters the
solutions back via the unique-inverse.

**Bit-equality contract.**  The f32 key IS the solver input: every solver
(jnp and kernel) casts its params/allowed to f32 before computing, and all
of them are row-independent (elementwise math + per-row argmin), so a row's
solution does not depend on which other rows share the batch.  Dedup +
scatter therefore returns *bit-identical* solutions to the direct solve —
``tests/test_solver_cache.py`` pins this property end-to-end through both
schedulers.

Keys are ``[n, 13]`` f32 rows — exactly columns 0-12 of the Pallas task
matrix (:mod:`repro.kernels.dvfs_opt`):

    (p0, γ, c, D, δ, t0, allowed, readjust,
     v_min, v_max, fc_min, fm_min, fm_max)

Cache entries are namespaced by a solver ``tag`` ("k64x64" for the kernel
at that refinement grid, "jnp-dl"/"jnp-bd"/"jnp-unc" for the jnp
deadline/boundary/unconstrained solvers), so numerically-different solvers
never serve each other's rows.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Optional, Sequence

import numpy as np

from repro.kernels import layout
from repro.kernels.layout import DvfsSolution, KEY_COLS, SOL_COLS

#: Pad the miss batch so the jitted solvers compile a bounded set of
#: shapes, not one per unique-row count: powers of two (>= 8) up to
#: _PAD_BLOCK, multiples of _PAD_BLOCK above it.  Capping the pow-2
#: rounding matters for the chunked online pipeline — a stream of ~4k-row
#: chunks would otherwise pad each one to 8192 and nearly double the
#: device work.
_MIN_PAD = 8
_PAD_BLOCK = 1024


class SolveCache:
    """LRU map from ``(tag, row-bytes)`` to an 8-float solution row.

    Sized in *rows*; the default :data:`GLOBAL_CACHE` keeps 2^18 rows
    (~25 MB of keys+values), far above any single sweep's working set.
    ``hits``/``misses`` accumulate across calls — sweep benchmarks report
    them as the cross-cell reuse rate.
    """

    def __init__(self, maxsize: int = 1 << 18):
        if maxsize < 1:
            raise ValueError("maxsize must be >= 1")
        self.maxsize = int(maxsize)
        self._rows: "OrderedDict[tuple, np.ndarray]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # Lifetime counters: same increments, never cleared by
        # ``reset_stats`` — ``schedule_online`` resets the per-run counters
        # at every call, so cross-run consumers (sweep benchmarks) diff
        # these instead.
        self.hits_total = 0
        self.misses_total = 0
        self.evictions_total = 0

    def __len__(self) -> int:
        return len(self._rows)

    def get(self, tag: str, key: bytes) -> Optional[np.ndarray]:
        row = self._rows.get((tag, key))
        if row is None:
            self.misses += 1
            self.misses_total += 1
            return None
        self._rows.move_to_end((tag, key))  # refresh LRU position
        self.hits += 1
        self.hits_total += 1
        return row

    def put(self, tag: str, key: bytes, value: np.ndarray) -> None:
        k = (tag, key)
        self._rows[k] = value
        self._rows.move_to_end(k)
        while len(self._rows) > self.maxsize:
            self._rows.popitem(last=False)
            self.evictions += 1
            self.evictions_total += 1

    def get_many(self, tag: str, keys: np.ndarray,
                 out: np.ndarray) -> tuple:
        """Batch :meth:`get` over the rows of a contiguous ``[m, k]`` key
        matrix: hits are written into ``out`` (same row index) and counted;
        returns ``(miss_idx, miss_keys)`` — the miss row indices and their
        ready-made ``(tag, row-bytes)`` dict keys, which :meth:`put_keys`
        inserts without re-serializing.  One ``tobytes`` of the whole
        matrix + constant-stride slicing beats a per-row ``ndarray.tobytes``
        by ~4x on the 100k-row batches the online pipeline feeds through."""
        rows = self._rows
        get = rows.get
        move = rows.move_to_end
        stride = keys.shape[1] * keys.itemsize
        buf = keys.tobytes()
        miss: list = []
        miss_keys: list = []
        append = miss.append
        append_key = miss_keys.append
        hits = 0
        for i in range(keys.shape[0]):
            k = (tag, buf[i * stride:(i + 1) * stride])
            row = get(k)
            if row is None:
                append(i)
                append_key(k)
            else:
                move(k)
                out[i] = row
                hits += 1
        self.hits += hits
        self.hits_total += hits
        self.misses += len(miss)
        self.misses_total += len(miss)
        return miss, miss_keys

    def put_keys(self, keys: list, values: list) -> None:
        """Batch :meth:`put` under pre-built ``(tag, row-bytes)`` keys (the
        ``miss_keys`` of a :meth:`get_many` call).  Rows are assumed new,
        so the C-level ``dict.update`` lands them at the LRU tail exactly
        like :meth:`put` would."""
        rows = self._rows
        rows.update(zip(keys, values))
        if len(rows) > self.maxsize:
            pop = rows.popitem
            while len(rows) > self.maxsize:
                pop(last=False)
                self.evictions += 1
                self.evictions_total += 1

    def clear(self) -> None:
        self._rows.clear()

    def reset_stats(self) -> None:
        """Zero the per-run counters (``hits``/``misses``/``evictions``);
        the ``*_total`` lifetime counters keep accumulating."""
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {"rows": len(self), "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "hit_rate": self.hit_rate,
                "hits_total": self.hits_total,
                "misses_total": self.misses_total,
                "evictions_total": self.evictions_total}


#: The process-wide cache every ``dedup=True`` solver call shares.
GLOBAL_CACHE = SolveCache()


def build_keys(param_cols: Sequence[np.ndarray], allowed: np.ndarray,
               readjust: bool, bounds: np.ndarray) -> np.ndarray:
    """Assemble the ``[n, 13]`` f32 key matrix (= kernel columns 0-12).

    ``param_cols`` are the six ``DvfsParams`` columns; ``bounds`` is either
    a 5-vector (one interval for all rows) or an ``[n, 5]`` per-row matrix.
    """
    cols = [np.asarray(c, np.float32) for c in param_cols]
    n = cols[0].shape[0]
    flag = np.full(n, 1.0 if readjust else 0.0, np.float32)
    bounds = np.asarray(bounds, np.float32)
    if bounds.ndim == 1:
        bounds = np.broadcast_to(bounds, (n, layout.N_BOUNDS))
    keys = np.concatenate(
        [np.stack(cols + [np.asarray(allowed, np.float32), flag], axis=1),
         bounds], axis=1)
    assert keys.shape == (n, KEY_COLS)
    return np.ascontiguousarray(keys, np.float32)


def _pad_rows(mat: np.ndarray) -> np.ndarray:
    """Pad the row count up to the solver shape grid — the next power of
    two (>= _MIN_PAD) below _PAD_BLOCK, the next _PAD_BLOCK multiple above
    it — replicating the last row, which is safe because every solver is
    row-independent."""
    k = mat.shape[0]
    if k <= _PAD_BLOCK:
        k_pad = max(_MIN_PAD, 1 << (k - 1).bit_length())
    else:
        k_pad = -(-k // _PAD_BLOCK) * _PAD_BLOCK
    if k_pad == k:
        return mat
    return np.concatenate(
        [mat, np.broadcast_to(mat[-1], (k_pad - k, mat.shape[1]))], axis=0)


def _materialize(pending) -> np.ndarray:
    """Resolve an in-flight solver result to a host f32 matrix.  Accepts a
    zero-arg callable (deferred multi-device gather), a JAX device array
    (blocks until the dispatched computation lands), or a plain ndarray."""
    while callable(pending):
        pending = pending()
    return np.asarray(pending, np.float32)


class AsyncSolve:
    """Handle for a dispatched-but-not-consumed dedup solve.

    Created by :func:`solve_rows_async` after the host-side work (unique,
    cache probe, dispatch of the misses) is done; the device computation —
    if any — runs concurrently with whatever the host does next.

    :meth:`result` is the single sync point: it blocks on the device
    values, validates the shape, feeds the cache and scatters through the
    unique-inverse.  It is memoized, so calling it twice is free.

    State changes on the host between dispatch and consumption (placement,
    server power-off, fault injection) cannot change the values: the key
    matrix was snapshotted at dispatch time and every solver is
    row-independent, so the rows solve to the same bits no matter when —
    or beside what — they are computed.
    """

    __slots__ = ("_inverse", "_out", "_miss", "_miss_keys", "_pending",
                 "_cache", "_result")

    def __init__(self, inverse, out, miss, miss_keys, pending, cache):
        self._inverse = inverse
        self._out = out
        self._miss = miss
        self._miss_keys = miss_keys
        self._pending = pending
        self._cache = cache
        self._result: Optional[np.ndarray] = None

    @property
    def in_flight(self) -> bool:
        """True until :meth:`result` has materialized the solve."""
        return self._result is None

    @property
    def n_missing(self) -> int:
        """Unique rows actually dispatched (cache misses)."""
        return len(self._miss)

    def result(self) -> np.ndarray:
        """Block on the dispatched solve and return ``[n, 8]`` f32 rows."""
        if self._result is None:
            miss = self._miss
            if miss:
                solved = _materialize(self._pending)[:len(miss)]
                if solved.shape != (len(miss), SOL_COLS):
                    raise ValueError(
                        f"solver_fn returned {solved.shape}, expected "
                        f"{(len(miss), SOL_COLS)}")
                solved = np.ascontiguousarray(solved)
                if len(miss) == self._out.shape[0]:
                    self._out = solved
                else:
                    self._out[miss] = solved
                if self._cache is not None:
                    self._cache.put_keys(self._miss_keys, list(solved))
            self._pending = None
            self._miss_keys = None
            self._result = self._out if self._inverse is None \
                else self._out[self._inverse]
        return self._result


def solve_rows_async(keys: np.ndarray,
                     solver_fn: Callable[[np.ndarray], np.ndarray], *,
                     tag: str,
                     cache: Optional[SolveCache] = GLOBAL_CACHE,
                     unique: bool = True) -> AsyncSolve:
    """Non-blocking :func:`solve_rows`: dedup + cache probe + dispatch now,
    materialize later.

    ``solver_fn`` maps a ``[m, 13]`` f32 key matrix (possibly pad-row extended)
    to ``[m, 8]`` solution rows; it may return a plain ndarray, a JAX
    device array (the async-dispatch fast path), or a zero-arg callable
    that yields either when invoked (the sharded multi-device gather).
    The returned :class:`AsyncSolve` resolves to the same bits
    :func:`solve_rows` would return — call ``.result()`` at the pipeline's
    sync point.

    ``unique=False`` skips the sort-based ``np.unique`` pass and relies on
    the cache probe alone: intra-batch duplicate rows are each solved (to
    the same bits — solvers are row-independent) and each counted as a
    miss.  The pipelined online scheduler uses this: its chunks are nearly
    duplicate-free (distinct per-task deadlines), so the O(n log n) sort
    costs far more than the duplicate solves it saves, while *cross*-chunk
    repeats still hit the cache.  Values are bit-identical either way.
    """
    keys = np.ascontiguousarray(np.asarray(keys, np.float32))
    if keys.ndim != 2 or keys.shape[1] != KEY_COLS:
        raise ValueError(f"keys must be [n, {KEY_COLS}], got {keys.shape}")
    if unique:
        uniq, inverse = np.unique(keys, axis=0, return_inverse=True)
        inverse = np.asarray(inverse).reshape(-1)  # numpy 2.x shape compat
    else:
        uniq, inverse = keys, None
    m = uniq.shape[0]
    out = np.empty((m, SOL_COLS), np.float32)
    if cache is not None:
        miss, miss_keys = cache.get_many(tag, uniq, out)
    else:
        miss, miss_keys = list(range(m)), None
    sub = uniq if len(miss) == m else uniq[miss]
    pending = solver_fn(_pad_rows(sub)) if miss else None
    return AsyncSolve(inverse, out, miss, miss_keys, pending, cache)


def solve_rows(keys: np.ndarray,
               solver_fn: Callable[[np.ndarray], np.ndarray], *,
               tag: str,
               cache: Optional[SolveCache] = GLOBAL_CACHE) -> np.ndarray:
    """Dedup + cache + scatter around a row-independent solver.

    ``solver_fn`` maps a ``[m, 13]`` f32 key matrix (possibly pad-row extended)
    to ``[m, 8]`` solution rows.  Returns the ``[n, 8]`` f32 solutions for
    all input rows; rows equal as f32 vectors share one solve, and rows
    seen by a previous call (same ``tag``) are served from ``cache``
    without touching the solver at all.  ``cache=None`` dedups within the
    call but persists nothing.

    This is the blocking wrapper over :func:`solve_rows_async` — dispatch
    and consume back to back.
    """
    return solve_rows_async(keys, solver_fn, tag=tag, cache=cache).result()


def solution_to_rows(sol) -> np.ndarray:
    """Pack a ``DvfsSolution`` (8 same-length arrays) into ``[n, 8]`` f32 —
    the cache's value layout (bool columns stored as 0.0/1.0)."""
    return np.stack([np.asarray(f, np.float32) for f in sol], axis=1)


def rows_to_solution(rows: np.ndarray) -> DvfsSolution:
    """Inverse of :func:`solution_to_rows`."""
    return DvfsSolution(
        v=rows[:, layout.SOL_V], fc=rows[:, layout.SOL_FC],
        fm=rows[:, layout.SOL_FM], time=rows[:, layout.SOL_T],
        power=rows[:, layout.SOL_P], energy=rows[:, layout.SOL_E],
        deadline_prior=rows[:, layout.SOL_DP] > 0.5,
        feasible=rows[:, layout.SOL_FEASIBLE] > 0.5)
