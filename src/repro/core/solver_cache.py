"""Unique-row dedup + persistent LRU cache for the DVFS solvers.

Every scheduler path funnels through one solver shape: a batch of rows
``(params, allowed, readjust, interval bounds)`` mapped independently to an
8-tuple solution ``(v, fc, fm, t, p, e, deadline_prior, feasible)``.  Two
structural facts make that batch massively redundant:

* traces are drawn from a small application library (the paper's benchmark
  apps; ``tasks.generate_trace`` patterns), so recurring jobs produce
  *duplicate rows* inside one call;
* sweep benchmarks re-solve the *same* rows cell after cell (θ-sweep cells
  share the task set; ``theoretical_bound`` is recomputed per scenario
  knob), so whole calls repeat *across* invocations.

This module removes both: :func:`solve_rows` quantizes the rows to the
solver's own f32 precision, keeps only ``np.unique`` rows, serves
previously-solved rows from a process-wide LRU (:data:`GLOBAL_CACHE`),
dispatches the solver on the residual misses only, and scatters the
solutions back via the unique-inverse.

**Bit-equality contract.**  The f32 key IS the solver input: every solver
(jnp and kernel) casts its params/allowed to f32 before computing, and all
of them are row-independent (elementwise math + per-row argmin), so a row's
solution does not depend on which other rows share the batch.  Dedup +
scatter therefore returns *bit-identical* solutions to the direct solve —
``tests/test_solver_cache.py`` pins this property end-to-end through both
schedulers.

Keys are ``[n, 13]`` f32 rows — exactly columns 0-12 of the Pallas task
matrix (:mod:`repro.kernels.dvfs_opt`):

    (p0, γ, c, D, δ, t0, allowed, readjust,
     v_min, v_max, fc_min, fm_min, fm_max)

Cache entries are namespaced by a solver ``tag`` ("k64x64" for the kernel
at that refinement grid, "jnp-dl"/"jnp-bd"/"jnp-unc" for the jnp
deadline/boundary/unconstrained solvers), so numerically-different solvers
never serve each other's rows.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Optional, Sequence

import numpy as np

from repro.kernels import layout
from repro.kernels.layout import DvfsSolution, KEY_COLS, SOL_COLS

#: Pad the miss batch to a power of two (>= 8) so the jitted solvers
#: compile O(log n) distinct shapes, not one per unique-row count.
_MIN_PAD = 8


class SolveCache:
    """LRU map from ``(tag, row-bytes)`` to an 8-float solution row.

    Sized in *rows*; the default :data:`GLOBAL_CACHE` keeps 2^18 rows
    (~25 MB of keys+values), far above any single sweep's working set.
    ``hits``/``misses`` accumulate across calls — sweep benchmarks report
    them as the cross-cell reuse rate.
    """

    def __init__(self, maxsize: int = 1 << 18):
        if maxsize < 1:
            raise ValueError("maxsize must be >= 1")
        self.maxsize = int(maxsize)
        self._rows: "OrderedDict[tuple, np.ndarray]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._rows)

    def get(self, tag: str, key: bytes) -> Optional[np.ndarray]:
        row = self._rows.get((tag, key))
        if row is None:
            self.misses += 1
            return None
        self._rows.move_to_end((tag, key))  # refresh LRU position
        self.hits += 1
        return row

    def put(self, tag: str, key: bytes, value: np.ndarray) -> None:
        k = (tag, key)
        self._rows[k] = value
        self._rows.move_to_end(k)
        while len(self._rows) > self.maxsize:
            self._rows.popitem(last=False)

    def clear(self) -> None:
        self._rows.clear()

    def reset_stats(self) -> None:
        self.hits = 0
        self.misses = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {"rows": len(self), "hits": self.hits, "misses": self.misses,
                "hit_rate": self.hit_rate}


#: The process-wide cache every ``dedup=True`` solver call shares.
GLOBAL_CACHE = SolveCache()


def build_keys(param_cols: Sequence[np.ndarray], allowed: np.ndarray,
               readjust: bool, bounds: np.ndarray) -> np.ndarray:
    """Assemble the ``[n, 13]`` f32 key matrix (= kernel columns 0-12).

    ``param_cols`` are the six ``DvfsParams`` columns; ``bounds`` is either
    a 5-vector (one interval for all rows) or an ``[n, 5]`` per-row matrix.
    """
    cols = [np.asarray(c, np.float32) for c in param_cols]
    n = cols[0].shape[0]
    flag = np.full(n, 1.0 if readjust else 0.0, np.float32)
    bounds = np.asarray(bounds, np.float32)
    if bounds.ndim == 1:
        bounds = np.broadcast_to(bounds, (n, layout.N_BOUNDS))
    keys = np.concatenate(
        [np.stack(cols + [np.asarray(allowed, np.float32), flag], axis=1),
         bounds], axis=1)
    assert keys.shape == (n, KEY_COLS)
    return np.ascontiguousarray(keys, np.float32)


def _pad_pow2_rows(mat: np.ndarray) -> np.ndarray:
    """Pad to the next pow-2 row count (>= _MIN_PAD), replicating the last
    row — safe because every solver is row-independent."""
    k = mat.shape[0]
    k_pad = max(_MIN_PAD, 1 << (k - 1).bit_length())
    if k_pad == k:
        return mat
    return np.concatenate(
        [mat, np.broadcast_to(mat[-1], (k_pad - k, mat.shape[1]))], axis=0)


def solve_rows(keys: np.ndarray,
               solver_fn: Callable[[np.ndarray], np.ndarray], *,
               tag: str,
               cache: Optional[SolveCache] = GLOBAL_CACHE) -> np.ndarray:
    """Dedup + cache + scatter around a row-independent solver.

    ``solver_fn`` maps a ``[m, 13]`` f32 key matrix (possibly pow-2 padded)
    to ``[m, 8]`` solution rows.  Returns the ``[n, 8]`` f32 solutions for
    all input rows; rows equal as f32 vectors share one solve, and rows
    seen by a previous call (same ``tag``) are served from ``cache``
    without touching the solver at all.  ``cache=None`` dedups within the
    call but persists nothing.
    """
    keys = np.ascontiguousarray(np.asarray(keys, np.float32))
    if keys.ndim != 2 or keys.shape[1] != KEY_COLS:
        raise ValueError(f"keys must be [n, {KEY_COLS}], got {keys.shape}")
    uniq, inverse = np.unique(keys, axis=0, return_inverse=True)
    inverse = np.asarray(inverse).reshape(-1)  # numpy 2.x shape compat
    m = uniq.shape[0]
    out = np.empty((m, SOL_COLS), np.float32)
    if cache is not None:
        miss = []
        for i in range(m):
            row = cache.get(tag, uniq[i].tobytes())
            if row is None:
                miss.append(i)
            else:
                out[i] = row
    else:
        miss = list(range(m))
    if miss:
        miss_keys = uniq[miss]
        solved = np.asarray(solver_fn(_pad_pow2_rows(miss_keys)),
                            np.float32)[:len(miss)]
        if solved.shape != (len(miss), SOL_COLS):
            raise ValueError(f"solver_fn returned {solved.shape}, expected "
                             f"{(len(miss), SOL_COLS)}")
        out[miss] = solved
        if cache is not None:
            for j, i in enumerate(miss):
                cache.put(tag, uniq[i].tobytes(), solved[j].copy())
    return out[inverse]


def solution_to_rows(sol) -> np.ndarray:
    """Pack a ``DvfsSolution`` (8 same-length arrays) into ``[n, 8]`` f32 —
    the cache's value layout (bool columns stored as 0.0/1.0)."""
    return np.stack([np.asarray(f, np.float32) for f in sol], axis=1)


def rows_to_solution(rows: np.ndarray) -> DvfsSolution:
    """Inverse of :func:`solution_to_rows`."""
    return DvfsSolution(
        v=rows[:, layout.SOL_V], fc=rows[:, layout.SOL_FC],
        fm=rows[:, layout.SOL_FM], time=rows[:, layout.SOL_T],
        power=rows[:, layout.SOL_P], energy=rows[:, layout.SOL_E],
        deadline_prior=rows[:, layout.SOL_DP] > 0.5,
        feasible=rows[:, layout.SOL_FEASIBLE] > 0.5)
