"""Analytical energy bounds (paper §5): the savings ceiling every
scheduling result is measured against.

The paper's headline claim is *comparable energy savings to the
theoretical upper bound*: with the wide (analytic) GPU scaling interval at
most ~36% of energy can be saved, and the schedulers record 33-35%.  The
bound is the energy no schedule can beat:

* **run floor** — every task at its *unconstrained* optimum (Algorithm 1
  with the deadline dropped) on its cheapest machine class.  Any feasible
  setting of any class costs at least this much, deadline-constrained or
  θ-readjusted settings strictly more.
* **exact-fit idle floor** — a packing in which every pair of every
  (virtual) server stays busy until the server's span ends leaves zero
  idle energy, so the offline (Eq. 6) floor is 0.  Online (Eq. 7) the DRS
  rule itself puts a floor under the books: at least one server must power
  on (``Δ`` per pair of turn-on overhead) and each of its ``l`` pairs
  idles exactly ``ρ`` slots between its last finish and the power-off
  event, whatever the schedule does.

``savings_ceiling`` relates the bound to the paper's no-DVFS ``l = 1``
baseline (:func:`repro.core.cluster.baseline_energy`); on the synthesized
20-app library it reproduces the §5 wide-interval ~36% anchor
(``tests/test_placement.py`` pins it).  Both schedulers report
``ScheduleResult.e_bound`` from here so every benchmark row shows
achieved-vs-bound.

See docs/EQUATIONS.md for the equation/algorithm -> code map.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import cluster as cl, dvfs, machines, single_task
from repro.core.dvfs import ScalingInterval
from repro.kernels import layout


@dataclasses.dataclass(frozen=True)
class EnergyBound:
    """The §5 lower bound on schedule energy (= upper bound on savings)."""

    e_run: float        # sum of per-task unconstrained optima, cheapest class
    e_idle: float       # exact-fit idle floor (0 offline; P_idle*rho*l online)
    e_overhead: float   # DRS turn-on floor (0 offline; Delta*l online)
    e_baseline: float   # no-DVFS l=1 reference, sum_i P*_i t*_i

    @property
    def e_bound(self) -> float:
        """The energy no schedule of this task set can beat."""
        return self.e_run + self.e_idle + self.e_overhead

    @property
    def savings_ceiling(self) -> float:
        """Max achievable saving vs the no-DVFS baseline (paper: ~0.36 on
        the wide interval, where the schedulers record 0.33-0.35)."""
        if self.e_baseline <= 0.0:
            return 0.0
        return 1.0 - self.e_bound / self.e_baseline


def unconstrained_energies(params, classes, interval: ScalingInterval,
                           n: int, dedup: bool = True) -> np.ndarray:
    """Per-task unconstrained-optimum energy on each class, shape ``[C, n]``
    (``params`` may be pow-2 padded past ``n``; one jitted batched solve
    per class).

    ``dedup=True`` (default) keys each row as ``(params, allowed=+inf)`` in
    the process-wide solve cache, so re-evaluating the bound across sweep
    cells (every scenario knob calls :func:`theoretical_bound` on the same
    task set) never re-solves a row.
    """
    from repro.core import solver_cache

    out = np.empty((len(classes), n))
    for k, mc in enumerate(classes):
        adapted = mc.adapt(params)
        iv = mc.effective_interval(interval)
        if dedup:
            n_rows = np.shape(np.asarray(adapted.p0))[0]
            keys = solver_cache.build_keys(
                adapted.astuple(), np.full(n_rows, np.inf, np.float32),
                False, np.asarray(iv.bounds(), np.float32))

            def solve(km: np.ndarray, _iv=iv) -> np.ndarray:
                p = dvfs.DvfsParams(
                    *(km[:, i] for i in range(layout.N_PARAMS)))
                return solver_cache.solution_to_rows(
                    single_task.solve_unconstrained(p, _iv))

            rows = solver_cache.solve_rows(keys, solve, tag="jnp-unc")
            out[k] = np.asarray(rows[:, layout.SOL_E], np.float64)[:n]
        else:
            sol = single_task.solve_unconstrained(adapted, iv)
            out[k] = np.asarray(sol.energy, np.float64)[:n]
    return out


def theoretical_bound(task_set, interval: ScalingInterval = dvfs.WIDE,
                      classes=None, p_idle: float = cl.P_IDLE,
                      delta_on: float = cl.DELTA_ON, l: int = 1,
                      rho: int = 0, dedup: bool = True) -> EnergyBound:
    """The paper's §5 analytical bound for a task set.

    ``classes`` is any class-mix spec (``None`` = the homogeneous reference
    setup with the scalar ``p_idle``/``delta_on``).  ``rho > 0`` adds the
    online DRS floors (at least one power-on of ``l`` pairs, each idling
    exactly ``rho`` before the off event); the offline bound leaves them at
    the exact-fit 0.  The floors use the cheapest class's constants so the
    bound stays valid for any class mix.
    """
    mcs = machines.resolve_classes(classes, p_idle=p_idle, delta_on=delta_on)
    n = len(task_set)
    e_baseline = cl.baseline_energy(task_set)
    if n == 0:
        return EnergyBound(0.0, 0.0, 0.0, e_baseline)
    params, _, _, _ = single_task.pad_pow2(task_set.params, np.zeros(n))
    e_run = float(np.min(
        unconstrained_energies(params, mcs, interval, n, dedup=dedup),
        axis=0).sum())
    if rho > 0:
        e_idle = min(mc.p_idle for mc in mcs) * rho * l
        e_overhead = min(mc.delta_on for mc in mcs) * l
    else:
        e_idle = e_overhead = 0.0
    return EnergyBound(e_run, e_idle, e_overhead, e_baseline)
