"""Cluster energy accounting and schedule result types (paper S3.1.2, Eq. 6-7).

A cluster has ``m`` servers of ``l`` CPU-GPU pairs each (we model the
homogeneous case the paper simulates: every server has the same ``l``, the
total pair budget is 2048).  A pair is *busy* while it executes a task, *idle*
while its server is on but it has no task, and consumes nothing while its
server is off.  Turning a server on costs ``Delta`` per pair; a server is
turned off once all of its pairs have been idle for at least ``rho`` slots
(dynamic resource sleep).

Energy decomposition (Eq. 7)::

    E_total = E_run + E_idle + E_overhead
    E_run      = sum_i P_i * (mu_i - kappa_i)
    E_idle     = P_idle * sum_{pairs} eta_kj
    E_overhead = omega * Delta

The offline objective (Eq. 6) is the special case with no overhead term and
servers that run from t=0 until their longest pair finishes (Algorithm 3
groups pairs into servers after the mapping is fixed).

The live cluster *state* (pair finish times, server on/off DRS bookkeeping,
per-pair machine class) lives in :class:`repro.core.engine.ClusterEngine` —
the single vectorized state machine shared by the offline and online
schedulers.  See docs/EQUATIONS.md for the equation/algorithm -> code map.
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

P_IDLE = 37.0        # W, idle pair power (24 W CPU + 13 W GPU), S5.1.2
DELTA_ON = 90.0      # J, per-pair turn on/off overhead, S5.1.2
RHO = 2              # slots; floor(DELTA_ON / P_IDLE), S5.1.2
MAX_PAIRS = 2048     # cluster-wide pair budget, S5.1.2


@dataclasses.dataclass(slots=True)
class Assignment:
    """One scheduled task: where, when, and at which DVFS setting.

    ``slots=True``: online horizons carry one record per task (100k+), so
    construction cost and footprint matter."""

    task: int
    pair: int
    start: float
    finish: float
    v: float
    fc: float
    fm: float
    power: float
    energy: float
    readjusted: bool = False
    class_id: int = 0   # machine class of the hosting pair (heterogeneity)
    #: the hosting pair crashed before ``finish``: the record is truncated
    #: (or tombstoned, finish == start) at the failure instant, its energy
    #: re-priced to the span actually run, and the task re-placed as a new
    #: record (repro.core.faults).  Violation accounting skips failed rows.
    failed: bool = False


@dataclasses.dataclass
class ScheduleResult:
    """Outcome of a scheduling run (energies in Joule-equivalent W x time)."""

    algorithm: str
    e_run: float
    e_idle: float
    e_overhead: float
    n_pairs: int
    n_servers: int
    violations: int
    assignments: List[Assignment]
    makespan: float = 0.0
    feasible_pairs: bool = True
    #: the §5 analytical lower bound on e_total for this task set
    #: (repro.core.bounds.theoretical_bound); 0.0 when not computed.
    e_bound: float = 0.0
    #: fault-injection counters (repro.core.faults.FaultInjector.stats);
    #: None for a failure-free run.
    fault_stats: dict = None
    #: solve-cache counters (solver_cache.GLOBAL_CACHE.stats, reset at the
    #: start of each ``schedule_online(dedup=True)`` call so the numbers
    #: are per-run); None when the run bypassed the cache.
    cache_stats: dict = None

    @property
    def e_total(self) -> float:
        return self.e_run + self.e_idle + self.e_overhead

    @property
    def bound_gap(self) -> float:
        """Achieved-vs-bound: ``e_total / e_bound - 1`` (0 == optimal)."""
        return self.e_total / self.e_bound - 1.0 if self.e_bound > 0 else 0.0

    def summary(self) -> dict:
        return dict(algorithm=self.algorithm, e_run=self.e_run, e_idle=self.e_idle,
                    e_overhead=self.e_overhead, e_total=self.e_total,
                    e_bound=self.e_bound,
                    n_pairs=self.n_pairs, n_servers=self.n_servers,
                    violations=self.violations, makespan=self.makespan)


def offline_idle_energy(pair_busy_end: np.ndarray, l: int, p_idle: float = P_IDLE):
    """Algorithm 3: group pairs into servers, return (E_idle, n_servers).

    Pairs are sorted by their finish time (mu) in descending order and packed
    into servers of ``l`` consecutive pairs; each server's span F_j is the
    longest pair in its group, and every other pair idles for F_j - tau_kj.
    Eq. (6) sums over ALL l pair slots of a powered server — unoccupied
    slots on a partially-filled server idle for the whole span F_j (this is
    what makes the paper's Table-3 example favor θ=0.9 over θ=1).  Sorting
    by finish time minimizes the summed idle gap for a fixed group size.
    """
    f_j = server_spans(pair_busy_end, l)
    e_idle = float(f_j.sum()) * l - float(np.sum(pair_busy_end))
    return p_idle * e_idle, int(f_j.shape[0])


def server_spans(pair_busy_end: np.ndarray, l: int) -> np.ndarray:
    """Algorithm 3 grouping: per-virtual-server span ``F_j``, one entry per
    server of ``l`` pairs (pairs sorted by finish time descending; a group's
    span is its longest pair).  Shared by :func:`offline_idle_energy` and
    the engine's offline finalizer."""
    mu = np.sort(np.asarray(pair_busy_end, dtype=np.float64))[::-1]
    n = mu.shape[0]
    if n == 0:
        return np.zeros(0)
    n_servers = -(-n // l)
    padded = np.concatenate([mu, np.zeros(n_servers * l - n)])
    # Not a solver-matrix read: column 0 of the [n_servers, l] span grouping
    # (descending sort puts each server's longest pair first).  The repo's
    # one live suppression — the unused-suppression meta-check proves it
    # still filters a real matrix-schema finding on every lint run.
    return padded.reshape(n_servers, l)[:, 0]  # lint: disable=matrix-schema


def baseline_energy(task_set) -> float:
    """The paper's reference point: no DVFS, l=1 (no idle energy) -- the energy
    of running every task at the default setting, sum_i P*_i t*_i."""
    return float(np.sum(task_set.p_star * task_set.t_star))
