"""Cluster state and energy accounting (paper S3.1.2, Eq. 6-7).

A cluster has ``m`` servers of ``l`` CPU-GPU pairs each (we model the
homogeneous case the paper simulates: every server has the same ``l``, the
total pair budget is 2048).  A pair is *busy* while it executes a task, *idle*
while its server is on but it has no task, and consumes nothing while its
server is off.  Turning a server on costs ``Delta`` per pair; a server is
turned off once all of its pairs have been idle for at least ``rho`` slots
(dynamic resource sleep).

Energy decomposition (Eq. 7)::

    E_total = E_run + E_idle + E_overhead
    E_run      = sum_i P_i * (mu_i - kappa_i)
    E_idle     = P_idle * sum_{pairs} eta_kj
    E_overhead = omega * Delta

The offline objective (Eq. 6) is the special case with no overhead term and
servers that run from t=0 until their longest pair finishes (Algorithm 3
groups pairs into servers after the mapping is fixed).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

P_IDLE = 37.0        # W, idle pair power (24 W CPU + 13 W GPU), S5.1.2
DELTA_ON = 90.0      # J, per-pair turn on/off overhead, S5.1.2
RHO = 2              # slots; floor(DELTA_ON / P_IDLE), S5.1.2


@dataclasses.dataclass
class Assignment:
    """One scheduled task: where, when, and at which DVFS setting."""

    task: int
    pair: int
    start: float
    finish: float
    v: float
    fc: float
    fm: float
    power: float
    energy: float
    readjusted: bool = False


@dataclasses.dataclass
class Pair:
    """A CPU-GPU pair's running schedule."""

    idx: int
    server: int = -1
    mu: float = 0.0          # finish time of the last scheduled task
    busy: float = 0.0        # cumulative busy time
    tasks: List[int] = dataclasses.field(default_factory=list)

    def add(self, task: int, start: float, duration: float):
        self.tasks.append(task)
        self.mu = start + duration
        self.busy += duration


@dataclasses.dataclass
class Server:
    """A server hosting ``l`` pairs, with DRS on/off bookkeeping."""

    idx: int
    pairs: List[int]
    on: bool = False
    on_since: float = 0.0
    on_time: float = 0.0     # cumulative powered-on duration
    turn_ons: int = 0        # omega contribution counts pairs, not servers

    def power_on(self, t: float, pair_count: int):
        assert not self.on
        self.on = True
        self.on_since = t
        self.turn_ons += pair_count

    def power_off(self, t: float):
        assert self.on
        self.on = False
        self.on_time += t - self.on_since


@dataclasses.dataclass
class ScheduleResult:
    """Outcome of a scheduling run (energies in Joule-equivalent W x time)."""

    algorithm: str
    e_run: float
    e_idle: float
    e_overhead: float
    n_pairs: int
    n_servers: int
    violations: int
    assignments: List[Assignment]
    makespan: float = 0.0
    feasible_pairs: bool = True

    @property
    def e_total(self) -> float:
        return self.e_run + self.e_idle + self.e_overhead

    def summary(self) -> dict:
        return dict(algorithm=self.algorithm, e_run=self.e_run, e_idle=self.e_idle,
                    e_overhead=self.e_overhead, e_total=self.e_total,
                    n_pairs=self.n_pairs, n_servers=self.n_servers,
                    violations=self.violations, makespan=self.makespan)


def offline_idle_energy(pair_busy_end: np.ndarray, l: int, p_idle: float = P_IDLE):
    """Algorithm 3: group pairs into servers, return (E_idle, n_servers).

    Pairs are sorted by their finish time (mu) in descending order and packed
    into servers of ``l`` consecutive pairs; each server's span F_j is the
    longest pair in its group, and every other pair idles for F_j - tau_kj.
    Eq. (6) sums over ALL l pair slots of a powered server — unoccupied
    slots on a partially-filled server idle for the whole span F_j (this is
    what makes the paper's Table-3 example favor θ=0.9 over θ=1).  Sorting
    by finish time minimizes the summed idle gap for a fixed group size.
    """
    mu = np.sort(np.asarray(pair_busy_end))[::-1]
    n = mu.shape[0]
    e_idle = 0.0
    n_servers = 0
    for j in range(0, n, l):
        group = mu[j:j + l]
        f_j = group[0]
        e_idle += float(np.sum(f_j - group)) + (l - group.shape[0]) * f_j
        n_servers += 1
    return p_idle * e_idle, n_servers


def baseline_energy(task_set) -> float:
    """The paper's reference point: no DVFS, l=1 (no idle energy) -- the energy
    of running every task at the default setting, sum_i P*_i t*_i."""
    return float(np.sum(task_set.p_star * task_set.t_star))


class PairPool:
    """Allocates pairs on demand and tracks the server <-> pair mapping for the
    online simulator.  Servers are created lazily, ``l`` pairs each."""

    def __init__(self, l: int, max_pairs: int = 2048):
        self.l = l
        self.max_pairs = max_pairs
        self.pairs: List[Pair] = []
        self.servers: List[Server] = []

    def new_server(self, t: float) -> Server:
        sid = len(self.servers)
        pair_ids = []
        for _ in range(self.l):
            pid = len(self.pairs)
            self.pairs.append(Pair(idx=pid, server=sid))
            pair_ids.append(pid)
        srv = Server(idx=sid, pairs=pair_ids)
        srv.power_on(t, self.l)
        self.servers.append(srv)
        return srv

    @property
    def n_pairs(self) -> int:
        return len(self.pairs)

    def feasible(self) -> bool:
        return self.n_pairs <= self.max_pairs

    def on_pairs(self) -> List[Pair]:
        out = []
        for srv in self.servers:
            if srv.on:
                out.extend(self.pairs[p] for p in srv.pairs)
        return out

    def finalize(self, t_end: float):
        """Power everything off and return (E_idle, E_overhead, on_servers_max)."""
        for srv in self.servers:
            if srv.on:
                srv.power_off(t_end)
        e_idle = 0.0
        omega = 0
        for srv in self.servers:
            omega += srv.turn_ons
            busy = sum(self.pairs[p].busy for p in srv.pairs)
            e_idle += srv.on_time * self.l - busy
        return P_IDLE * e_idle, DELTA_ON * omega
