"""Server failure/recovery injection for the online event-driven stack.

The paper's online algorithms (Algorithms 4-6) assume every acquired server
survives until its DRS power-off event.  Real clusters lose nodes mid-job,
so this module adds failure semantics on top of the
:class:`~repro.core.engine.ClusterEngine` without disturbing the
failure-free paths (every fault check in the engine is gated on a flag
that stays False until the first failure, so fault-free runs remain
bit-identical to the pre-fault goldens):

* :class:`FaultTrace` — a deterministic, state-independent list of
  :class:`FaultEvent` (server crash / recovery), built from an explicit
  event list (:meth:`FaultTrace.from_events`), exponential MTBF/MTTR
  alternation per server slot (:meth:`FaultTrace.sample`; pass an array
  ``mtbf`` for per-class rates), or a fixed fraction of servers
  (:meth:`FaultTrace.fraction`).  Traces name *server slots*: an event for
  a server the run never builds is counted and skipped, so one trace can
  replay against schedulers that open different fleet sizes.
* :class:`FaultInjector` — the runtime half, driven by
  :func:`repro.core.online.schedule_online` between arrival groups.  At a
  crash it settles engine energy exactly at the failure instant
  (:meth:`~repro.core.engine.ClusterEngine.fail_pairs` books idle/compute
  up to ``t``, never past it), truncates the orphaned in-flight records
  (energy up to ``t`` is *wasted* but still billed — the machine did burn
  it), tombstones queued-but-unstarted records, and re-enters the orphan
  tasks into placement with shrunken DVFS windows
  (:meth:`~repro.core.placement.PlacementContext.place_orphans`, whose
  re-solves ride the same deferred ``readjust_batch`` dispatch as the
  θ-readjustments).  When no pair can meet a deadline the documented
  graceful-degradation policy books the task at max speed and lets the
  violation be counted — a failure trace can never crash a run.

Event ordering is deterministic: events sort by ``(t, kind, server)`` with
failures before recoveries at equal times; the simulation applies every
event with ``t <= slot`` before placing the slot's arrival group.

See docs/ARCHITECTURE.md (fault-injection layer) and docs/TESTING.md for
the failure-trace regression workflow.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

_EPS = 1e-9

#: sort rank per event kind: failures apply before recoveries at equal t
_KIND_RANK = {"fail": 0, "revive": 1}


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One server transition: the server crashes or comes back at ``t``."""

    t: float
    server: int
    kind: str  # "fail" | "revive"

    def __post_init__(self):
        if self.kind not in _KIND_RANK:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.t < 0.0:
            raise ValueError(f"fault time must be >= 0, got {self.t}")
        if self.server < 0:
            raise ValueError(f"server id must be >= 0, got {self.server}")


def _sort(events) -> Tuple[FaultEvent, ...]:
    return tuple(sorted(events,
                        key=lambda e: (e.t, _KIND_RANK[e.kind], e.server)))


@dataclasses.dataclass(frozen=True)
class FaultTrace:
    """A deterministic failure trace: time-sorted server fail/revive events.

    Traces are generated up front from a seed or an explicit list — they
    never depend on simulation state, so the same trace replays
    bit-identically against the scalar and vector placement paths (and
    against different schedulers, where events naming never-built servers
    are skipped)."""

    events: Tuple[FaultEvent, ...]

    def __len__(self) -> int:
        return len(self.events)

    @property
    def n_failures(self) -> int:
        return sum(1 for e in self.events if e.kind == "fail")

    @classmethod
    def from_events(cls, events: Sequence) -> "FaultTrace":
        """Build from FaultEvents or ``(t, server, kind)`` tuples; order
        does not matter (events are sorted deterministically)."""
        evs = [e if isinstance(e, FaultEvent) else FaultEvent(float(e[0]),
                                                              int(e[1]), e[2])
               for e in events]
        return cls(_sort(evs))

    @classmethod
    def sample(cls, n_servers: int, horizon: float, mtbf,
               mttr: Optional[float] = None, seed: int = 0) -> "FaultTrace":
        """Exponential MTBF/MTTR alternation per server slot.

        ``mtbf`` is the mean up-time before a crash — a scalar, or an array
        of length ``n_servers`` for per-slot rates (the per-*class* support:
        build the array from the server classes of a failure-free run, or
        from any class layout you want to model).  ``mttr`` is the mean
        repair time; ``None`` means crashed servers never come back.
        """
        rng = np.random.default_rng(seed)
        mtbf = np.broadcast_to(np.asarray(mtbf, np.float64), (int(n_servers),))
        if np.any(mtbf <= 0.0):
            raise ValueError("mtbf must be positive")
        events: List[FaultEvent] = []
        for sid in range(int(n_servers)):
            t = float(rng.exponential(mtbf[sid]))
            while t < horizon:
                events.append(FaultEvent(t, sid, "fail"))
                if mttr is None:
                    break
                t += max(float(rng.exponential(mttr)), 1e-3)
                if t >= horizon:
                    break
                events.append(FaultEvent(t, sid, "revive"))
                t += float(rng.exponential(mtbf[sid]))
        return cls(_sort(events))

    @classmethod
    def fraction(cls, n_servers: int, frac: float, horizon: float,
                 seed: int = 0,
                 repair: Optional[float] = None) -> "FaultTrace":
        """Crash a fixed fraction of the first ``n_servers`` server slots,
        each once, at a uniform random time in ``(0, horizon)``; with
        ``repair`` each comes back that many slots later.  The pinned-trace
        shape of the CI fault-tolerance smoke ("1% of pairs fail")."""
        n_servers = int(n_servers)
        k = min(n_servers, max(1, int(round(frac * n_servers))))
        rng = np.random.default_rng(seed)
        sids = rng.choice(n_servers, size=k, replace=False)
        times = rng.uniform(_EPS, horizon, size=k)
        events = [FaultEvent(float(t), int(s), "fail")
                  for t, s in zip(times, sids)]
        if repair is not None:
            events += [FaultEvent(float(t + repair), int(s), "revive")
                       for t, s in zip(times, sids)]
        return cls(_sort(events))


class FaultInjector:
    """Replays a :class:`FaultTrace` against a live online run.

    Owned by :func:`repro.core.online.schedule_online`; the loop calls
    :meth:`advance` before each arrival group (applying every event up to
    the slot), :meth:`register` after each placement (tracking which
    assignment records live on which pair), and :meth:`finalize_records`
    once after the deferred readjust solves (re-pricing truncated records
    from their *final* power — a truncated θ-readjusted record only knows
    its power after the batch solve).

    ``stats`` counts ``failures`` / ``revivals`` applied, ``skipped``
    events (server never built, or already in the target state),
    ``orphans`` (records cut by a crash), ``restarted`` re-placements and
    ``degraded`` graceful-degradation bookings.
    """

    def __init__(self, eng, ctx, trace: FaultTrace, rule: str,
                 degrade: Optional[Callable] = None):
        self.eng = eng
        self.ctx = ctx
        self.events = list(trace.events)
        self.pos = 0
        self.rule = rule
        self.degrade = degrade
        self.pair_tasks: Dict[int, List[int]] = {}
        self.truncated: List[int] = []
        self.stats = {"failures": 0, "revivals": 0, "skipped": 0,
                      "orphans": 0, "restarted": 0, "degraded": 0}

    # -- tracking ------------------------------------------------------------
    def register(self, base: int):
        """Track ``assignments[base:]`` (one placement's records) by pair."""
        asn = self.ctx.assignments
        for i in range(base, len(asn)):
            self.pair_tasks.setdefault(asn[i].pair, []).append(i)

    # -- replay --------------------------------------------------------------
    def advance(self, t: float):
        """Apply every event with ``e.t <= t``, each at its exact time:
        settle the engine to ``e.t`` first, so a crash books energy at the
        failure instant and never past it."""
        while self.pos < len(self.events) \
                and self.events[self.pos].t <= t + _EPS:
            e = self.events[self.pos]
            self.pos += 1
            if e.server >= self.eng.n_servers:
                self.stats["skipped"] += 1
                continue
            self.eng.settle(e.t)
            if e.kind == "fail":
                self._fail(e)
            else:
                self._revive(e)

    def _fail(self, e: FaultEvent):
        l = self.eng.l
        lo = e.server * l
        pids = np.arange(lo, lo + l, dtype=np.int64)
        asn = self.ctx.assignments
        rollback = np.zeros(l)
        orphans: List[int] = []
        for j, pid in enumerate(pids.tolist()):
            rows = self.pair_tasks.get(pid)
            if not rows:
                continue
            for ai in rows:
                a = asn[ai]
                if a.failed or a.finish <= e.t + _EPS:
                    continue          # already truncated, or completed by t
                if a.start < e.t - _EPS:
                    # in-flight: the task dies mid-run; energy up to the
                    # crash is wasted but billed (the machine burned it)
                    rollback[j] += a.finish - e.t
                    asn[ai] = dataclasses.replace(a, finish=e.t, failed=True)
                else:
                    # queued but unstarted: tombstone (records are
                    # index-addressed by the pending readjust rows, so
                    # they are never removed, only zero-spanned)
                    rollback[j] += a.finish - a.start
                    asn[ai] = dataclasses.replace(a, finish=a.start,
                                                  failed=True)
                self.truncated.append(ai)
                orphans.append(a.task)
            rows.clear()              # pair is down: nothing left to track
        failed = self.eng.fail_pairs(e.t, pids, busy_rollback=rollback)
        if failed.size == 0:
            self.stats["skipped"] += 1
            return
        self.stats["failures"] += 1
        self.stats["orphans"] += len(orphans)
        if orphans:
            base = len(asn)
            restarted, degraded = self.ctx.place_orphans(
                np.asarray(orphans, dtype=np.int64), e.t, self.rule,
                degrade=self.degrade)
            self.stats["restarted"] += restarted
            self.stats["degraded"] += degraded
            self.register(base)

    def _revive(self, e: FaultEvent):
        l = self.eng.l
        lo = e.server * l
        revived = self.eng.revive_pairs(
            e.t, np.arange(lo, lo + l, dtype=np.int64))
        if revived.size:
            self.stats["revivals"] += 1
        else:
            self.stats["skipped"] += 1

    # -- post-pass -----------------------------------------------------------
    def finalize_records(self):
        """Re-price every truncated record as ``power * (finish - start)``.

        Runs AFTER :func:`repro.core.scheduling.fill_readjusted`: a
        truncated θ-readjusted record gets its power from the deferred
        boundary solve, and the batch writer prices the full window — this
        pass rewrites the energy to the span the pair actually ran
        (tombstones price to exactly 0)."""
        asn = self.ctx.assignments
        for ai in self.truncated:
            a = asn[ai]
            asn[ai] = dataclasses.replace(
                a, energy=a.power * (a.finish - a.start))


def make_degrade(task_set, mcs, interval, use_dvfs: bool) -> Callable:
    """The graceful-degradation setting: ``degrade(task, class) ->
    (v, fc, fm, t, p)`` at the class's maximum speed (``t`` equals the
    class ``t_min`` bitwise — both are :func:`repro.core.dvfs.min_time` on
    the adapted params), or the ``(1, 1, 1)`` default when DVFS is off.
    Lazy per class: fault recovery is a rare path."""
    from repro.core import single_task

    cache: Dict[int, tuple] = {}

    def degrade(g: int, c: int):
        if c not in cache:
            params_c = mcs[c].adapt(task_set.params)
            if use_dvfs:
                iv = mcs[c].effective_interval(interval)
                cache[c] = single_task.max_speed_setting(params_c, iv)
            else:
                t = np.asarray(params_c.default_time(), np.float64)
                p = np.asarray(params_c.default_power(), np.float64)
                ones = np.ones_like(t)
                cache[c] = (ones, ones, ones, t, p)
        v, fc, fm, t, p = cache[c]
        return (float(v[g]), float(fc[g]), float(fm[g]), float(t[g]),
                float(p[g]))

    return degrade
