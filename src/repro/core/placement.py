"""The shared placement subsystem: pair selection over the ClusterEngine
columns for BOTH schedulers (paper §4.2 — the packing half of Algorithms
2, 5 and 6).

The offline batch packer (:func:`repro.core.scheduling.schedule_offline`)
and the online arrival-group simulator
(:func:`repro.core.online.schedule_online`) run the *same* placement rules:
order the tasks, try each task's machine classes min-energy-feasible first,
pick a pair of the class by the policy rule (worst fit / best fit / first
fit, with the EDL θ-readjustment shrinking a non-fitting task's window),
and fall back to a fresh pair of the task's primary class.  This module
owns that machinery once, parameterized by a :class:`PlacementContext`:

* **offline** is the degenerate "one group at ``t = 0``" case — the engine
  runs ``servers=False``, a fresh pair is a single standalone
  :meth:`~repro.core.engine.ClusterEngine.open_pair`, and every pair is
  always eligible;
* **online** places one arrival group per call at its slot time — the
  engine runs ``servers=True``, a fresh pair is a DRS power-on of ``l``
  pairs (:meth:`~repro.core.engine.ClusterEngine.acquire_pair`), and only
  pairs of powered-on servers are eligible.

Three placement paths per context, all bit-identical by construction:

* :meth:`PlacementContext.place_group_vector` — the batched worst-fit/SPT
  path (Algorithm 2/5 EDL and the plain worst-fit policy).  Worst-fit is a
  sequential min-extraction process, but it batches exactly under a
  frontier invariant: in task order, the group's class-``c`` tasks land on
  the smallest-``mu`` eligible pairs of class ``c`` *provided* each task
  fits (at its optimal length, or via a θ-readjustment window, whose pair
  ``mu`` is pinned to the task's deadline) and no already-assigned pair's
  new ``mu`` drops back to (or ties) the worst-fit frontier.  Both
  conditions are array ops over per-class *compact pools*
  (:class:`_GroupPools`) of the engine's ``mu``/``class_id`` columns; the
  batch rounds alternate with the scalar rule per collision, and a lazy
  frontier heap finishes the group when batching stops paying for itself.
* :meth:`PlacementContext.place_group_select` — the pooled first-fit
  (``"ff"``) / best-fit (``"bf"``) path (offline ``lpt-ff``/``edf-bf`` and
  the online Algorithm-6 first-fit), per-task probes vectorized over the
  class pools.
* :meth:`PlacementContext.place_group_scalar` — the per-task reference
  loop over the engine's own ``worst_fit``/``best_fit``/``first_fit``
  selectors; the bit-identity oracle the two paths above are pinned
  against (``tests/test_placement.py`` offline,
  ``tests/test_event_engine.py`` online).

The vectorized paths defer every engine write to one group commit
(:meth:`~repro.core.engine.ClusterEngine.book_assignments` +
:meth:`~repro.core.engine.ClusterEngine.sync_mu`) and gather the group's
assignment records from the config columns in one shot; only fresh-server
power-ons touch the engine live (they are DRS events).  θ-readjustment
rows are *not* solved here — a readjusted task occupies exactly its
window, so the rows are queued as :data:`PendingRow` and batch-priced
after packing (:func:`repro.core.scheduling.fill_readjusted`).

See docs/ARCHITECTURE.md (placement subsystem layer) and docs/EQUATIONS.md
for the full equation/algorithm -> code map.
"""

from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import cluster as cl, machines
from repro.core.engine import ClusterEngine
from repro.core.single_task import TaskConfig

_EPS = 1e-9

# Persistent candidate streams are built/kept this many times deeper than
# the current group's ask: deep enough that consecutive groups rarely
# exhaust the carried frontier (a rebuild is an argpartition over the whole
# pool), shallow enough that the per-group touched-merge stays O(stream).
# Result-neutral: streams are a coverage window over the same (mu, pair id)
# order, and every consumer re-slices ``[:need]``.
_STREAM_OVERSHOOT = 8

#: pending θ-readjustment row: (assignment_index, task_index, window, class_id)
PendingRow = Tuple[int, int, float, int]

#: offline algorithm name -> pair-selection rule
OFFLINE_RULES = {"edl": "wf", "edf-wf": "wf", "edf-bf": "bf", "lpt-ff": "ff"}


def make_assignment(task: int, pair: int, start: float, cfg: TaskConfig,
                    duration: Optional[float] = None,
                    readjusted: bool = False, class_id: int = 0) -> cl.Assignment:
    """An assignment at the task's configured setting; a readjusted one gets
    its finish pinned to ``start + duration`` and its DVFS fields filled in
    later by :func:`repro.core.scheduling.fill_readjusted`."""
    t = cfg.t_hat[task] if duration is None else duration
    return cl.Assignment(task=task, pair=pair, start=float(start),
                         finish=float(start + t), v=float(cfg.v[task]),
                         fc=float(cfg.fc[task]), fm=float(cfg.fm[task]),
                         power=float(cfg.p_hat[task]),
                         energy=float(cfg.e_hat[task]), readjusted=readjusted,
                         class_id=class_id)


def precompute(cfgs: Sequence[TaskConfig], order_cls: np.ndarray) -> dict:
    """Per-run lookups for the vectorized paths: config columns as numpy
    arrays (batch gathers) and as plain lists (the scalar-finish loop reads
    per-task floats ~20x faster off a list than off a numpy scalar)."""
    t_hat = [np.asarray(c.t_hat) for c in cfgs]
    t_min = [np.asarray(c.t_min) for c in cfgs]
    return {
        "t_hat": t_hat,
        "t_min": t_min,
        "t_hat_l": [a.tolist() for a in t_hat],
        "t_min_l": [a.tolist() for a in t_min],
        "order_cols": order_cls.T.tolist() if len(cfgs) > 1 else None,
        # record columns [v, fc, fm, p_hat, e_hat] stacked per class: one
        # fancy-index gathers a whole group's records
        "cols": [np.stack([np.asarray(c.v, np.float64),
                           np.asarray(c.fc, np.float64),
                           np.asarray(c.fm, np.float64),
                           np.asarray(c.p_hat, np.float64),
                           np.asarray(c.e_hat, np.float64)]) for c in cfgs],
    }


class _GroupPools:
    """Per-class compact pools for one placement call — or, in persistent
    mode, carried across every call of a run.

    A pool is the pair-id-ascending snapshot of the eligible pairs of one
    class, kept in sync for the rest of the call while the engine itself is
    only written at the group commit.  Its candidate stream is the
    ``(mu, pair id)``-sorted frontier computed once per call (stale entries
    drop out by exact ``mu`` comparison, a power-on appends its fresh
    pairs), and ``min_new`` tracks the smallest already-assigned finish
    time so a frontier re-entry is detected across batch rounds.

    **Persistent mode** (``PlacementContext(incremental=True)``, the
    pipelined online path): pools and candidate streams survive from one
    arrival group to the next under three delta rules instead of the
    per-group full rebuild —

    * *touched re-entry*: every pair whose ``mu`` moved (assignment, fresh
      power-on splice) is recorded by **pair id** (ids are stable under the
      position shifts that splices/deletions cause); at the next group
      the touched set is merged back into the stream at its current ``mu``.
      ``thresh`` records the stream's ``(mu, pair id)`` coverage bound from
      build time: every pool entry outside the stream compares strictly
      greater, assignments only *raise* ``mu``, so merged entries above the
      bound can be dropped and the stream stays the true global frontier.
    * *power-off deletion*: servers the engine's DRS settle powered off
      (``ClusterEngine.drain_offs``) have their contiguous pair block cut
      out of the pool; stream positions shift left.
    * *epoch invalidation*: any fault transition (``fail_pairs`` /
      ``revive_pairs`` bump ``ClusterEngine.pool_epoch``) mutates pairs
      behind the pool's back — eligibility masks, mu truncations, orphan
      re-placements — so everything is dropped and lazily rebuilt from the
      live engine.  Failures are rare events; correctness over cleverness.
    """

    __slots__ = ("ctx", "eng", "t_now", "grain", "t_hat_l", "pools", "cands",
                 "fresh", "min_new", "pid_col", "start_col", "dur_col",
                 "cls_col", "persistent", "touched", "thresh", "needs_merge",
                 "epoch")

    def __init__(self, ctx: "PlacementContext", t_now: float,
                 pid_col: np.ndarray, start_col: np.ndarray,
                 dur_col: np.ndarray, cls_col: np.ndarray):
        self.ctx = ctx
        self.eng = ctx.eng
        self.t_now = t_now
        self.grain = ctx.grain
        self.t_hat_l = ctx.pre["t_hat_l"]
        self.pools: Dict[int, list] = {}
        self.cands: Dict[int, list] = {}
        self.fresh: Dict[int, list] = {}
        self.min_new: Dict[int, float] = {}
        self.pid_col = pid_col
        self.start_col = start_col
        self.dur_col = dur_col
        self.cls_col = cls_col
        self.persistent = False
        self.touched: Dict[int, list] = {}
        self.thresh: Dict[int, Optional[tuple]] = {}
        self.needs_merge: set = set()
        self.epoch = 0

    def begin_group(self, t_now: float, pid_col: np.ndarray,
                    start_col: np.ndarray, dur_col: np.ndarray,
                    cls_col: np.ndarray):
        """Rebind the per-group output columns and reconcile the carried
        pool state with everything the engine did since the last group."""
        self.t_now = t_now
        self.pid_col = pid_col
        self.start_col = start_col
        self.dur_col = dur_col
        self.cls_col = cls_col
        eng = self.eng
        if eng.pool_epoch != self.epoch:
            self.epoch = eng.pool_epoch
            self.pools.clear()
            self.cands.clear()
            self.fresh.clear()
            self.min_new.clear()
            self.touched.clear()
            self.thresh.clear()
            self.needs_merge.clear()
            eng.drain_offs()
            return
        # Unconsumed fresh splices may sit below any stream bound: convert
        # them (by id) into touched entries for the merge.  Must happen
        # BEFORE power-off deletions shift pool positions.
        for c, fl in self.fresh.items():
            if fl:
                ids = self.pools[c][0]
                self.touched.setdefault(c, []).append(
                    ids[np.asarray(fl, dtype=np.int64)])
                self.fresh[c] = []
        offs = eng.drain_offs()
        if offs:
            self.apply_offs(offs)
        for c in self.min_new:
            self.min_new[c] = np.inf
        self.needs_merge = set(self.cands)
        for c in self.touched:
            if c not in self.cands:
                # No stream to reconcile against; a later build is full.
                self.touched[c] = []

    def apply_offs(self, sids):
        """Cut the powered-off servers' contiguous pair blocks out of their
        class pools (and shift/drop stream positions accordingly).  A
        powered-on server always has its whole ``grain`` block in the pool,
        so the whole batch is one keep-mask compaction per class — a
        per-``sid`` slice shift is O(offs * pool) and collapses on diurnal
        traces, where a falling edge powers off thousands of servers at
        once.  Order-preserving, so it commutes with the loop form."""
        grain = self.grain
        eng = self.eng
        multi = len(eng.classes) > 1
        if multi:
            by_class: Dict[int, list] = {}
            for sid in sids:
                by_class.setdefault(eng.server_class(sid), []).append(sid)
        else:
            by_class = {0: list(sids)}
        for c, csids in by_class.items():
            st = self.pools.get(c)
            if st is None:
                continue
            ids, mus, n = st
            live = ids[:n]
            lo_id = np.asarray(sorted(csids), dtype=np.int64) * grain
            lo = np.searchsorted(live, lo_id)
            hi = np.searchsorted(live, lo_id + grain)
            if not np.any(hi > lo):
                continue
            # Deleted-coverage mask over positions via a range-diff sweep.
            diff = np.zeros(n + 1, dtype=np.int64)
            np.add.at(diff, lo, 1)
            np.add.at(diff, hi, -1)
            dead = np.cumsum(diff[:n]) > 0
            keep = ~dead
            m = int(keep.sum())
            if m == n:
                continue
            shift = np.cumsum(dead) - dead    # deleted positions before p
            ids[:m] = live[keep]
            mus[:m] = mus[:n][keep]
            st[2] = m
            cst = self.cands.get(c)
            if cst is not None:
                cp, cm = cst
                km = keep[cp]
                if not km.all():
                    cp, cm = cp[km], cm[km]
                self.cands[c] = [cp - shift[cp], cm]

    def _merge_carry(self, c: int):
        """Fold the touched pair ids back into class ``c``'s carried stream
        at their current ``mu`` (dropping entries beyond the coverage
        bound and pairs that left the pool), keeping ``(mu, pair id)``
        order — position order == id order inside a pool."""
        ids, mus, n = self.pools[c]
        cp, cm = self.cands[c]
        alive = mus[cp] == cm
        if not alive.all():
            cp, cm = cp[alive], cm[alive]
        tl = self.touched.get(c)
        if tl:
            tids = np.unique(np.concatenate(
                [np.atleast_1d(np.asarray(x, dtype=np.int64)) for x in tl]))
            self.touched[c] = []
            pos = np.searchsorted(ids[:n], tids)
            ok = pos < n
            pos = np.where(ok, pos, 0)
            ok &= ids[pos] == tids
            pos = pos[ok]
            if pos.size:
                tmu = mus[pos]
                th = self.thresh.get(c)
                if th is not None:
                    t_mu, t_pid = th
                    keep = (tmu < t_mu) | ((tmu == t_mu)
                                           & (ids[pos] <= t_pid))
                    pos, tmu = pos[keep], tmu[keep]
                if pos.size:
                    allp = np.concatenate([cp, pos])
                    allm = np.concatenate([cm, tmu])
                    o = np.lexsort((allp, allm))
                    cp, cm = allp[o], allm[o]
        st = self.cands[c] = [cp, cm]
        return st

    def pool(self, c: int):
        """Compact (pair-id ascending) snapshot of the eligible pairs of
        class c as ``[ids, mus, n]`` (capacity-grown append arrays).  Built
        lazily; pairs acquired later in the call are spliced in by
        :meth:`acquire`, which always creates the pool first — so the lazy
        snapshot can never miss a same-class pair."""
        st = self.pools.get(c)
        if st is None:
            ids = self.eng.pool_ids(c)
            st = self.pools[c] = [ids,
                                  self.eng.mu[ids].astype(np.float64,
                                                          copy=True),
                                  ids.size]
            self.min_new[c] = np.inf
        return st

    def candidates(self, c: int, need: int):
        """Up to ``need`` live frontier entries of class c as (positions,
        recorded mus), ordered by ``(mu, pair id)``."""
        ids, mus, n = self.pool(c)
        st = self.cands.get(c)
        clean = False   # stream (re)built/merged this call -> fully alive
        if st is not None and c in self.needs_merge:
            self.needs_merge.discard(c)
            st = self._merge_carry(c)
            clean = True
            if st[0].size < need and self.thresh.get(c) is not None:
                # Carried stream exhausted below the ask while entries past
                # its coverage bound exist: refresh with a full build.
                st = None
                del self.cands[c]
            elif st[0].size > max(_STREAM_OVERSHOOT * need, 64):
                # Keep the carried stream bounded: drop the sorted tail and
                # *tighten* the coverage bound to the last kept entry (the
                # dropped entries all compare greater, and any future mu
                # move re-enters through the touched set) — without this a
                # full-coverage stream (thresh None) re-absorbs every
                # touched pair forever and the per-group merge degenerates
                # into maintaining a whole sorted pool.
                keep = max(_STREAM_OVERSHOOT * need, 64)
                cp, cm = st[0][:keep], st[1][:keep]
                self.thresh[c] = (float(cm[-1]), int(ids[cp[-1]]))
                st = self.cands[c] = [cp, cm]
        if st is None:
            # Persistent mode overshoots the ask: a stream of exactly
            # ``need`` entries is fully consumed by its own group, which
            # would force a rebuild every group and make the carry pure
            # overhead.  The extra entries are the same frontier, just
            # deeper — the return below still slices [:need].
            kc = min(max(_STREAM_OVERSHOOT * need, 64)
                     if self.persistent else need, n)
            m_live = mus[:n]
            if kc and kc < n:
                part = np.argpartition(m_live, kc - 1)[:kc]
                cp = np.flatnonzero(m_live <= m_live[part].max())
                cp = cp[np.lexsort((cp, m_live[cp]))][:kc]
            else:
                cp = np.argsort(m_live, kind="stable")
            st = self.cands[c] = [cp, m_live[cp].copy()]
            clean = True
            if self.persistent:
                self.thresh[c] = None if cp.size >= n else \
                    (float(st[1][-1]), int(ids[cp[-1]]))
                self.touched[c] = []
                self.fresh.pop(c, None)
        cp, cm = st
        if not clean:
            alive = self.pools[c][1][cp] == cm    # assigned entries drop out
            if not alive.all():
                cp, cm = cp[alive], cm[alive]
                self.cands[c] = [cp, cm]
        fr = self.fresh.get(c)
        if fr:
            fa = np.sort(np.asarray(fr, dtype=np.int64))
            fa = fa[self.pools[c][1][fa] == self.t_now]  # consumed drop out
            if fa.size:
                allp = np.concatenate([cp, fa])
                allm = np.concatenate([cm, np.full(fa.size, self.t_now)])
                o = np.lexsort((allp, allm))      # position order == id order
                return allp[o][:need], allm[o][:need]
        return cp[:need], cm[:need]

    def acquire(self, i: int, g: int, c: int):
        """Fresh-pair fallback: open a fresh pair of class ``c`` (offline a
        standalone pair, online a DRS power-on of ``grain = l`` pairs — a
        live engine event), splice the new pairs into the class pool, and
        assign the first one to task ``g`` at position ``i``."""
        t_now = self.t_now
        grain = self.grain
        eng = self.eng
        # Snapshot the pool BEFORE firing the engine event: pool_ids reads
        # live engine state, so a pool built after the power-on would
        # already contain the fresh pairs the splice below adds.
        st = self.pools.get(c)
        if st is None:
            st = self.pool(c)
        pid = eng.acquire_pair(t_now, class_id=c) if eng.server_mode \
            else eng.open_pair(class_id=c)
        ids, mus, n = st
        if n == 0 or pid > ids[n - 1]:            # append (always offline)
            pos = n
            if n + grain > ids.shape[0]:          # grow capacity, amortized
                grow = max(n + grain, 2 * ids.shape[0])
                st[0] = ids = np.concatenate(
                    [ids, np.empty(grow - ids.shape[0], dtype=np.int64)])
                st[1] = mus = np.concatenate(
                    [mus, np.empty(grow - mus.shape[0])])
        else:
            # waking a lower-id server inserts mid-pool: shift the stored
            # candidate/fresh positions past the insertion point.
            pos = int(np.searchsorted(ids[:n], pid))
            st[0] = ids = np.insert(ids[:n], pos,
                                    np.zeros(grain, dtype=np.int64))
            st[1] = mus = np.insert(mus[:n], pos, np.zeros(grain))
            if c in self.cands:
                cp, cm = self.cands[c]
                self.cands[c] = [np.where(cp >= pos, cp + grain, cp), cm]
            if self.fresh.get(c):
                self.fresh[c] = [p + grain if p >= pos else p
                                 for p in self.fresh[c]]
        th = self.t_hat_l[c][g]
        if grain == 1:                            # offline: one standalone pair
            ids[pos] = pid
        else:
            ids[pos: pos + grain] = pid + np.arange(grain)
            mus[pos + 1: pos + grain] = t_now
            self.fresh.setdefault(c, []).extend(range(pos + 1, pos + grain))
        st[2] = n + grain
        mus[pos] = t_now + th                     # a fresh pair is free *now*
        if self.persistent:
            self.touched.setdefault(c, []).append(pid)
        if self.min_new[c] > t_now + th:
            self.min_new[c] = t_now + th
        self.pid_col[i] = pid
        self.start_col[i] = t_now
        self.dur_col[i] = th
        self.cls_col[i] = c
        return pos, pos != n


class PlacementContext:
    """One scheduler run's placement state: the engine handle, the per-class
    Algorithm-1 configs and their precomputed column lookups, the policy
    knobs (θ, readjustment on/off) and the output sinks (the assignment
    list and the pending θ-readjustment rows).

    ``readjust`` enables the EDL θ-readjustment on the worst-fit rule; it
    only takes effect for ``theta < 1`` (at ``θ = 1`` the readjustment
    window ``max(θ·t_hat, t_min)`` equals ``t_hat`` and can never admit a
    task the plain fit test rejected).  The fresh-pair granularity follows
    the engine mode: a standalone pair offline, a server of ``l`` pairs
    online.
    """

    def __init__(self, eng: ClusterEngine, cfgs: Sequence[TaskConfig],
                 deadline: np.ndarray, *, theta: float = 1.0,
                 readjust: bool = False,
                 assignments: Optional[List[cl.Assignment]] = None,
                 pending: Optional[List[PendingRow]] = None,
                 order_cls: Optional[np.ndarray] = None,
                 incremental: bool = False):
        self.eng = eng
        self.cfgs = list(cfgs)
        self.deadline = np.asarray(deadline, dtype=np.float64)
        self.theta = float(theta)
        self.readjust = bool(readjust) and self.theta < 1.0
        self.assignments = assignments if assignments is not None else []
        self.pending = pending if pending is not None else []
        self.order_cls = order_cls if order_cls is not None \
            else machines.class_order(self.cfgs)
        self.primary = self.order_cls[0]
        self.grain = eng.l if eng.server_mode else 1
        self._pre = None
        # Incremental mode (the pipelined online scheduler): pools and
        # candidate streams persist across groups with delta reconciliation
        # instead of a per-group rebuild; the engine logs power-offs for the
        # deletion deltas.
        self.incremental = bool(incremental)
        self._gp: Optional[_GroupPools] = None
        if self.incremental:
            eng.track_offs = True

    @property
    def pre(self) -> dict:
        """The :func:`precompute` column lookups, built on first use (the
        scalar reference path never touches them)."""
        if self._pre is None:
            self._pre = precompute(self.cfgs, self.order_cls)
        return self._pre

    def update_tasks(self, idx):
        """Refresh the :attr:`pre` lookups for the tasks in ``idx`` (an
        index array, or a contiguous ``slice`` — what the pipelined driver
        passes for slot-sorted traces) after their config columns were
        filled in place (the pipelined config prefetch consumes Algorithm-1
        solutions chunk by chunk).  The numpy entries of ``pre`` alias the
        config arrays, so only the derived list mirrors and the stacked
        record columns need resyncing — all mutated in place so aliases
        held by a persistent pool stay live."""
        if self._pre is None:
            # First chunk: the plain build snapshots current (chunk-filled)
            # values; unfilled tasks hold garbage until their own refresh.
            self._pre = precompute(self.cfgs, self.order_cls)
            return
        pre = self._pre
        for c, cfg in enumerate(self.cfgs):
            pre["cols"][c][:, idx] = np.stack(
                [np.asarray(cfg.v, np.float64)[idx],
                 np.asarray(cfg.fc, np.float64)[idx],
                 np.asarray(cfg.fm, np.float64)[idx],
                 np.asarray(cfg.p_hat, np.float64)[idx],
                 np.asarray(cfg.e_hat, np.float64)[idx]])
            th = np.asarray(cfg.t_hat)[idx].tolist()
            tm = np.asarray(cfg.t_min)[idx].tolist()
            th_l = pre["t_hat_l"][c]
            tm_l = pre["t_min_l"][c]
            if isinstance(idx, slice):
                th_l[idx] = th
                tm_l[idx] = tm
            else:
                for j, i in enumerate(idx.tolist()):
                    th_l[i] = th[j]
                    tm_l[i] = tm[j]
        if pre["order_cols"] is not None:
            oc = self.order_cls[:, idx].T.tolist()
            order_cols = pre["order_cols"]
            if isinstance(idx, slice):
                order_cols[idx] = oc
            else:
                for j, i in enumerate(idx.tolist()):
                    order_cols[i] = oc[j]

    def _group_pools(self, t_now: float, pid_col: np.ndarray,
                     start_col: np.ndarray, dur_col: np.ndarray,
                     cls_col: np.ndarray) -> _GroupPools:
        """The per-call pool state: a throwaway instance normally, the
        carried one (delta-reconciled) in incremental mode."""
        if not self.incremental:
            return _GroupPools(self, t_now, pid_col, start_col, dur_col,
                               cls_col)
        gp = self._gp
        if gp is None:
            gp = self._gp = _GroupPools(self, t_now, pid_col, start_col,
                                        dur_col, cls_col)
            gp.persistent = True
            gp.epoch = self.eng.pool_epoch
            self.eng.drain_offs()   # nothing existed to reconcile yet
        else:
            gp.begin_group(t_now, pid_col, start_col, dur_col, cls_col)
        return gp

    def acquire_fresh(self, t_now: float, class_id: int) -> int:
        """A fresh pair of ``class_id`` through the engine-mode-appropriate
        primitive: offline a standalone pair, online a DRS power-on."""
        if self.eng.server_mode:
            return self.eng.acquire_pair(t_now, class_id=class_id)
        return self.eng.open_pair(class_id=class_id)

    # -- group commit --------------------------------------------------------

    def _commit_group(self, gidx: np.ndarray, pid_col: np.ndarray,
                      start_col: np.ndarray, dur_col: np.ndarray,
                      readj_col: np.ndarray, cls_col: np.ndarray):
        """Commit one placed group to the engine in one shot (power-ons
        already wrote their pairs live; only assigned pairs moved, and for
        a pair assigned twice the chronologically last finish wins), then
        gather the group's assignment records."""
        k = gidx.shape[0]
        self.eng.book_assignments(pid_col, start_col, dur_col)
        _, last = np.unique(pid_col[::-1], return_index=True)
        last = k - 1 - last
        self.eng.sync_mu(pid_col[last], start_col[last] + dur_col[last])
        self._gather(gidx, pid_col, start_col, dur_col, readj_col, cls_col)

    def _gather(self, gidx: np.ndarray, pid_col: np.ndarray,
                start_col: np.ndarray, dur_col: np.ndarray,
                readj_col: np.ndarray, cls_col: np.ndarray):
        """Bulk-build the group's assignment records from the config
        columns (one fancy-index per class present)."""
        pre = self.pre
        k = gidx.shape[0]
        if len(self.cfgs) == 1:
            mat = pre["cols"][0][:, gidx]
        else:
            mat = np.empty((5, k))
            for c in np.unique(cls_col):
                m = cls_col == c
                mat[:, m] = pre["cols"][int(c)][:, gidx[m]]
        v_l, fc_l, fm_l, p_l, e_l = mat.tolist()
        finish = start_col + dur_col
        self.assignments.extend(map(
            cl.Assignment, gidx.tolist(), pid_col.tolist(),
            start_col.tolist(), finish.tolist(), v_l, fc_l, fm_l, p_l, e_l,
            readj_col.tolist(), cls_col.tolist()))

    # -- placement paths -----------------------------------------------------

    def pin_fresh(self, tids: np.ndarray):
        """Each task on its OWN fresh pair of its primary class at ``t = 0``
        (the offline deadline-prior phase: these tasks must start
        immediately), opened and committed in bulk."""
        tids = np.asarray(tids, dtype=np.int64)
        k = tids.shape[0]
        if k == 0:
            return
        cls = self.primary[tids].astype(np.int64, copy=True)
        t_hat = np.empty(k)
        for c in np.unique(cls):
            m = cls == c
            t_hat[m] = self.pre["t_hat"][int(c)][tids[m]]
        base = self.eng.open_pairs(cls)
        pids = base + np.arange(k, dtype=np.int64)
        starts = np.zeros(k)
        self.eng.book_assignments(pids, starts, t_hat)
        self.eng.sync_mu(pids, t_hat)
        self._gather(tids, pids, starts, t_hat, np.zeros(k, dtype=bool), cls)

    def prepare_chunk(self, groups):
        """Hoist the per-group prologue of :meth:`place_group_vector` for a
        run of arrival groups (the pipelined driver's chunk): ONE stable
        lexsort replaces each group's stable deadline argsort (equal
        permutations — the group id is the primary key and lexsort keeps
        arrival order on deadline ties, exactly like the per-group
        ``kind="stable"`` argsort), and the task-column gathers vectorize
        across the whole chunk.  Returns one ``(gidx, prim, d, t_hat)``
        tuple per group, each bit-identical to the inline prologue."""
        sizes = [idx.shape[0] for _, idx in groups]
        cat = np.concatenate([idx for _, idx in groups])
        gid = np.repeat(np.arange(len(sizes)), sizes)
        d_cat = self.deadline[cat]
        order = np.lexsort((d_cat, gid))
        gidx = cat[order]
        d_s = d_cat[order]
        prim = self.primary[gidx]
        pre = self.pre
        if len(self.cfgs) == 1:
            t_hat = pre["t_hat"][0][gidx]
        else:
            t_hat = np.empty(gidx.shape[0])
            for c in np.unique(prim):
                m = prim == c
                t_hat[m] = pre["t_hat"][int(c)][gidx[m]]
        out = []
        off = 0
        for s in sizes:
            sl = slice(off, off + s)
            out.append((gidx[sl], prim[sl], d_s[sl], t_hat[sl]))
            off += s
        return out

    def place_group_vector(self, idx, order, t_now: float, prep=None):
        """Batched worst-fit/SPT (+ θ-readjustment) placement for one
        ordered group — Algorithm 2/5's pair rule.

        The placement loop alternates: batch the longest provable prefix
        (see the frontier invariant in the module docstring), then place
        the single violating task through the scalar rule — class fallback,
        readjustment that does not batch, fresh-pair power-on, an exact
        ``mu`` tie — and resume batching while a round nets enough tasks to
        pay for itself; otherwise (power-on ramp, saturated frontier) the
        rest of the group runs the same scalar rule as a tight loop over
        the pools with a lazy frontier heap.  Bit-identical to
        :meth:`place_group_scalar` (rule ``"wf"``) by construction.

        ``prep`` injects the group's :meth:`prepare_chunk` tuple; ``idx``
        and ``order`` are ignored then (the tuple already IS the ordered
        group).
        """
        if prep is not None:
            gidx, prim, d, t_hat = prep
            k = gidx.shape[0]
            if k == 0:
                return
            pre = self.pre
        else:
            k = order.shape[0]
            if k == 0:
                return
            pre = self.pre
            gidx = np.asarray(idx)[order]         # [k] task ids, batch order
            prim = self.primary[gidx]             # [k] primary class per task
            d = self.deadline[gidx]
        theta = self.theta
        readjust_on = self.readjust
        pending = self.pending
        t_hat_cls = pre["t_hat"]
        t_min_cls = pre["t_min"]
        t_hat_l = pre["t_hat_l"]
        t_min_l = pre["t_min_l"]
        order_cols = pre["order_cols"]
        grain = self.grain

        # Per-group record columns, filled by the batch rounds and the
        # scalar violators; records and engine state are committed once at
        # the end.
        if prep is None:
            t_hat = np.empty(k)
            for c in np.unique(prim):
                m = prim == c
                t_hat[m] = t_hat_cls[int(c)][gidx[m]]
        pid_col = np.empty(k, dtype=np.int64)
        start_col = np.empty(k)
        dur_col = t_hat.copy()
        cls_col = prim.astype(np.int64, copy=True)
        readj_col = np.zeros(k, dtype=bool)
        base = len(self.assignments)

        gp = self._group_pools(t_now, pid_col, start_col, dur_col, cls_col)
        pool = gp.pool
        candidates = gp.candidates
        pools = gp.pools
        fresh = gp.fresh
        min_new = gp.min_new
        persistent = gp.persistent
        touched = gp.touched

        valid = np.empty(k, dtype=bool)
        pos_sel = np.empty(k, dtype=np.int64)

        def batch_round(pos0: int) -> int:
            """Batch the longest provable prefix of tasks[pos0:]; returns
            the number of positions consumed."""
            valid[pos0:] = False
            if order_cols is None:                # single class: no split
                by_class = ((0, np.arange(pos0, k)),)
            else:
                sub = prim[pos0:]
                by_class = tuple((int(c), pos0 + np.flatnonzero(sub == c))
                                 for c in np.unique(sub))
            for c, tm in by_class:
                cp, cm = candidates(int(c), tm.size)
                kc = cp.size
                if not kc:
                    continue
                w = t_hat[tm[:kc]]
                start = np.maximum(t_now, cm)
                window = d[tm[:kc]] - start
                fit = window >= w - _EPS          # fits at optimal length
                if readjust_on:
                    # The θ-readjustment batches under the same frontier
                    # check: the task occupies exactly its window, so its
                    # pair's new mu is pinned to the task's deadline.
                    t_min_c = t_min_cls[int(c)][gidx[tm[:kc]]]
                    readj = ~fit & (window >= np.maximum(theta * w, t_min_c)
                                    - _EPS)
                else:
                    readj = np.zeros(kc, dtype=bool)
                dur = np.where(fit, w, window)
                ok = fit | readj
                # no-collision: every already-assigned pair's new mu
                # (previous rounds and this one) stays strictly above the
                # next candidate (ties -> scalar fallback).
                pm = np.minimum.accumulate(start + dur)
                ok &= np.concatenate(([min_new[int(c)]],
                                      np.minimum(pm[:-1],
                                                 min_new[int(c)]))) > cm
                nvalid = kc if ok.all() else int(np.argmin(ok))
                if nvalid:
                    sel = tm[:nvalid]
                    valid[sel] = True
                    pos_sel[sel] = cp[:nvalid]
                    start_col[sel] = start[:nvalid]
                    dur_col[sel] = dur[:nvalid]
                    readj_col[sel] = readj[:nvalid]
            cut = k if valid[pos0:].all() \
                else pos0 + int(np.argmin(valid[pos0:]))
            if cut == pos0:
                return 0
            if order_cols is None:
                by_class = ((0, np.arange(pos0, cut)),)
            else:
                sub = prim[pos0:cut]
                by_class = tuple((int(c), pos0 + np.flatnonzero(sub == c))
                                 for c in np.unique(sub))
            for c, m in by_class:
                ids, mus, _ = pools[int(c)]
                pos = pos_sel[m]
                new_mu = start_col[m] + dur_col[m]
                mus[pos] = new_mu
                pid_col[m] = ids[pos]
                if persistent:
                    touched.setdefault(int(c), []).append(pid_col[m].copy())
                min_new[int(c)] = min(min_new[int(c)], float(new_mu.min()))
            for i in np.flatnonzero(readj_col[pos0:cut]).tolist():
                i += pos0
                pending.append((base + i, int(gidx[i]), float(dur_col[i]),
                                int(prim[i])))
            return cut - pos0

        def place_one(i: int):
            """The scalar rule for one violating task, over the same pools
            (argmin over a pool's contiguous mu column is worst-fit with
            the identical lowest-pair-id tie-break)."""
            g = int(gidx[i])
            dd = d[i]
            readj_col[i] = False  # may hold a stale beyond-cut batch verdict
            for c in (order_cols[g] if order_cols is not None else (0,)):
                ids, mus, n = pool(c)
                if not n:
                    continue
                j = int(mus[:n].argmin())
                mu_j = mus[j]
                start = t_now if mu_j < t_now else float(mu_j)
                th = t_hat_l[c][g]
                if dd - start >= th - _EPS:
                    mus[j] = start + th
                    if persistent:
                        touched.setdefault(c, []).append(int(ids[j]))
                    if min_new[c] > start + th:
                        min_new[c] = start + th
                    pid_col[i], start_col[i], dur_col[i], cls_col[i] = \
                        ids[j], start, th, c
                    return
                elif readjust_on:
                    t_theta = theta * th
                    t_mn = t_min_l[c][g]
                    if t_theta < t_mn:
                        t_theta = t_mn
                    window = dd - start
                    if window >= t_theta - _EPS:
                        mus[j] = start + window
                        if persistent:
                            touched.setdefault(c, []).append(int(ids[j]))
                        if min_new[c] > start + window:
                            min_new[c] = start + window
                        pending.append((base + i, g, window, c))
                        pid_col[i], start_col[i], dur_col[i], cls_col[i] = \
                            ids[j], start, window, c
                        readj_col[i] = True
                        return
            gp.acquire(i, g, int(prim[i]))

        def finish_scalar(i0: int):
            """The scalar rule for the rest of the group as a tight loop
            over a lazy frontier heap: alive candidate-stream originals,
            pairs already assigned this group, and outstanding fresh pairs,
            keyed ``(mu, pair id)`` — exactly argmin's lowest-pair-id
            tie-break.  Entries go stale by exact ``mu`` comparison; when
            the original stream runs dry while uncovered pool entries
            exist, the loop degrades to plain argmin over the pool.
            Per-task reads come off plain python lists and the record
            columns are written back in bulk.  Multi-class groups fall back
            to the per-task rule, which also handles class fallback."""
            if order_cols is not None:
                for j in range(i0, k):
                    place_one(j)
                return
            gl = gidx.tolist()
            dl = d.tolist()
            th_l = t_hat_l[0]
            tm_l = t_min_l[0]
            pid_l, st_l, du_l, rj_l = [], [], [], []
            ids, mus, n = pool(0)
            cp, cm = candidates(0, k - i0)
            heap = [(m, int(ids[p]), int(p), True)
                    for m, p in zip(cm.tolist(), cp.tolist())]
            alive_orig = len(heap)
            statics = alive_orig < n              # uncovered pool entries?
            if i0:
                tpos = np.unique(np.searchsorted(ids[:n], pid_col[:i0]))
                heap += [(float(mus[p]), int(ids[p]), int(p), False)
                         for p in tpos.tolist()]
            for p in fresh.get(0, ()):
                if mus[p] == t_now:
                    heap.append((t_now, int(ids[p]), int(p), False))
            heapq.heapify(heap)
            heap_ok = True
            for j in range(i0, k):
                g = gl[j]
                dd = dl[j]
                top = None
                if heap_ok:
                    while heap:
                        e = heap[0]
                        if mus[e[2]] == e[0]:
                            top = e
                            break
                        heapq.heappop(heap)
                        if e[3]:
                            alive_orig -= 1
                    if top is None or (statics and alive_orig == 0):
                        heap_ok = False
                        top = None
                if not heap_ok and n:
                    p = int(mus[:n].argmin())
                    top = (float(mus[p]), int(ids[p]), p, False)
                if top is not None:
                    mu_p, pid, p = top[0], top[1], top[2]
                    start = t_now if mu_p < t_now else mu_p
                    th = th_l[g]
                    if dd - start >= th - _EPS:
                        if heap_ok:
                            heapq.heappop(heap)
                            if top[3]:
                                alive_orig -= 1
                            heapq.heappush(heap, (start + th, pid, p, False))
                        mus[p] = start + th
                        pid_l.append(pid)
                        st_l.append(start)
                        du_l.append(th)
                        rj_l.append(False)
                        continue
                    if readjust_on:
                        t_theta = theta * th
                        t_mn = tm_l[g]
                        if t_theta < t_mn:
                            t_theta = t_mn
                        window = dd - start
                        if window >= t_theta - _EPS:
                            if heap_ok:
                                heapq.heappop(heap)
                                if top[3]:
                                    alive_orig -= 1
                                heapq.heappush(heap,
                                               (start + window, pid, p,
                                                False))
                            mus[p] = start + window
                            pending.append((base + j, g, window, 0))
                            pid_l.append(pid)
                            st_l.append(start)
                            du_l.append(window)
                            rj_l.append(True)
                            continue
                pos, mid = gp.acquire(j, g, 0)
                ids, mus, n = pools[0]
                if heap_ok:
                    if mid:
                        # positions past the insertion point shifted
                        heap = [(m_, pi_, p_ + grain if p_ >= pos else p_,
                                 o_) for m_, pi_, p_, o_ in heap]
                    npid = int(ids[pos])
                    heapq.heappush(heap, (float(mus[pos]), npid, pos, False))
                    for jj in range(1, grain):
                        heapq.heappush(heap,
                                       (t_now, npid + jj, pos + jj, False))
                pid_l.append(pid_col[j])
                st_l.append(t_now)
                du_l.append(dur_col[j])
                rj_l.append(False)
            pid_col[i0:] = pid_l
            start_col[i0:] = st_l
            dur_col[i0:] = du_l
            readj_col[i0:] = rj_l
            if persistent and pid_l:
                touched.setdefault(0, []).append(
                    np.asarray(pid_l, dtype=np.int64))

        def finish_offline(i0: int):
            """The offline (single-class, ``grain == 1``) specialization of
            :func:`finish_scalar`: the scalar worst-fit rule as a frontier
            heap over plain python floats.

            With no power-on granule and no eligibility churn the WHOLE
            pool fits in the heap (so no lazy-staleness or argmin-degrade
            machinery is needed — a ``(mu, pair id)`` heap top IS argmin's
            lowest-pair-id tie-break, and every mutation is a
            ``heapreplace`` of the top), and fresh pairs are deferred to
            ONE bulk :meth:`~repro.core.engine.ClusterEngine.open_pairs` —
            offline pair ids are sequential, so they are known without
            touching the engine inside the loop.  Bit-identical to the
            scalar rule by construction: the list mirrors hold the exact
            float64 values of the pool columns."""
            eng = self.eng
            ids_a, mus_a, n = pool(0)
            gl = gidx.tolist()
            dl = d.tolist()
            th_l = t_hat_l[0]
            tm_l = t_min_l[0]
            pid_l, st_l, du_l, rj_l = [], [], [], []
            heap = list(zip(mus_a[:n].tolist(), ids_a[:n].tolist()))
            heapq.heapify(heap)
            heappush = heapq.heappush
            heapreplace = heapq.heapreplace
            pid_next = eng.n_pairs
            n_fresh = 0
            for j in range(i0, k):
                g = gl[j]
                dd = dl[j]
                if heap:
                    mu_p, pid = heap[0]
                    start = t_now if mu_p < t_now else mu_p
                    th = th_l[g]
                    if dd - start >= th - _EPS:
                        heapreplace(heap, (start + th, pid))
                        pid_l.append(pid)
                        st_l.append(start)
                        du_l.append(th)
                        rj_l.append(False)
                        continue
                    if readjust_on:
                        t_theta = theta * th
                        t_mn = tm_l[g]
                        if t_theta < t_mn:
                            t_theta = t_mn
                        window = dd - start
                        if window >= t_theta - _EPS:
                            heapreplace(heap, (start + window, pid))
                            pending.append((base + j, g, window, 0))
                            pid_l.append(pid)
                            st_l.append(start)
                            du_l.append(window)
                            rj_l.append(True)
                            continue
                # fresh standalone pair: id known in advance (sequential),
                # opened in bulk after the loop; class 0 == the primary
                # cls_col already holds
                pid = pid_next + n_fresh
                n_fresh += 1
                th = th_l[g]
                heappush(heap, (t_now + th, pid))
                pid_l.append(pid)
                st_l.append(t_now)
                du_l.append(th)
                rj_l.append(False)
            if n_fresh:
                eng.open_pairs(np.zeros(n_fresh, dtype=np.int64))
            pid_col[i0:] = pid_l
            start_col[i0:] = st_l
            dur_col[i0:] = du_l
            readj_col[i0:] = rj_l

        # Alternate batch rounds with single scalar violators while batching
        # pays for itself; a round that nets only a few tasks (power-on
        # ramp, saturated frontier) costs more than the scalar rule, so
        # finish the group scalar from there.
        finish = finish_offline if (grain == 1 and not self.eng.server_mode
                                    and order_cols is None) else finish_scalar
        i = 0
        while i < k:
            consumed = batch_round(i)
            i += consumed
            if i >= k:
                break
            place_one(i)
            i += 1
            if consumed < 8:
                if i < k:
                    finish(i)
                break

        self._commit_group(gidx, pid_col, start_col, dur_col, readj_col,
                           cls_col)

    def place_group_select(self, idx, order, t_now: float, rule: str):
        """Pooled first-fit (``"ff"``) / best-fit (``"bf"``) placement for
        one ordered group (offline ``lpt-ff``/``edf-bf``, online
        Algorithm-6 first-fit).

        The per-task probes become array ops over the per-class compact
        pools — id-ascending, so ``argmax(fit)`` is exactly the scalar
        ``first_fit`` tie-break and ``argmax`` over the fit-masked ``mu``
        column is exactly ``best_fit`` — with the engine written once at
        the group commit.  Bit-identical to :meth:`place_group_scalar` by
        construction.
        """
        k = order.shape[0]
        if k == 0:
            return
        pre = self.pre
        gidx = np.asarray(idx)[order]
        gl = gidx.tolist()
        dl = self.deadline[gidx].tolist()
        prim = self.primary[gidx]
        t_hat_l = pre["t_hat_l"]
        order_cols = pre["order_cols"]
        best = rule == "bf"

        pid_col = np.empty(k, dtype=np.int64)
        start_col = np.empty(k)
        dur_col = np.empty(k)
        cls_col = np.empty(k, dtype=np.int64)
        gp = self._group_pools(t_now, pid_col, start_col, dur_col, cls_col)
        pool = gp.pool
        persistent = gp.persistent
        touched = gp.touched

        for i in range(k):
            g = gl[i]
            dd = dl[i]
            placed = False
            for c in (order_cols[g] if order_cols is not None else (0,)):
                ids, mus, n = pool(c)
                if not n:
                    continue
                th = t_hat_l[c][g]
                m = mus[:n]
                starts = np.maximum(t_now, m)
                fit = dd - starts >= th - _EPS
                if best:
                    if not fit.any():
                        continue
                    j = int(np.argmax(np.where(fit, m, -np.inf)))
                else:
                    j = int(np.argmax(fit))
                    if not fit[j]:
                        continue
                start = float(starts[j])
                mus[j] = start + th
                if persistent:
                    touched.setdefault(c, []).append(int(ids[j]))
                pid_col[i] = ids[j]
                start_col[i] = start
                dur_col[i] = th
                cls_col[i] = c
                placed = True
                break
            if not placed:
                gp.acquire(i, g, int(prim[i]))
        self._commit_group(gidx, pid_col, start_col, dur_col,
                           np.zeros(k, dtype=bool), cls_col)

    def place_group_scalar(self, idx, order, t_now: float, rule: str):
        """The per-task reference loop over the engine's own selectors:
        class preference order, worst fit (``"wf"``, with θ-readjustment
        when the context enables it) / best fit (``"bf"``) / first fit
        (``"ff"``), and the fresh-pair fallback.  The bit-identity oracle
        for the vectorized paths."""
        eng = self.eng
        cfgs = self.cfgs
        deadline = self.deadline
        order_cls = self.order_cls
        theta = self.theta
        readjust_on = self.readjust
        assignments = self.assignments
        pending = self.pending
        for r in order:
            gidx = int(idx[int(r)])
            d = deadline[gidx]

            placed = False
            for c in order_cls[:, gidx]:
                c = int(c)
                cfg_c = cfgs[c]
                t_hat = float(cfg_c.t_hat[gidx])
                if rule == "wf":
                    pid = eng.worst_fit(class_id=c)  # SPT: pair free first
                    if pid < 0:
                        continue
                    start = max(t_now, float(eng.mu[pid]))
                    if d - start >= t_hat - _EPS:
                        eng.assign(pid, start, t_hat)
                        assignments.append(make_assignment(
                            gidx, pid, start, cfg_c, class_id=c))
                        placed = True
                        break
                    elif readjust_on:
                        t_theta = max(theta * t_hat,
                                      float(cfg_c.t_min[gidx]))
                        window = d - start
                        if window >= t_theta - _EPS:
                            eng.assign(pid, start, window)
                            pending.append((len(assignments), gidx, window,
                                            c))
                            assignments.append(make_assignment(
                                gidx, pid, start, cfg_c, duration=window,
                                readjusted=True, class_id=c))
                            placed = True
                            break
                else:
                    pid = eng.best_fit(t_now, d, t_hat, class_id=c) \
                        if rule == "bf" \
                        else eng.first_fit(t_now, d, t_hat, class_id=c)
                    if pid >= 0:
                        start = max(t_now, float(eng.mu[pid]))
                        eng.assign(pid, start, t_hat)
                        assignments.append(make_assignment(
                            gidx, pid, start, cfg_c, class_id=c))
                        placed = True
                        break
            if not placed:
                c = int(self.primary[gidx])
                cfg_c = cfgs[c]
                pid = self.acquire_fresh(t_now, c)
                start = max(t_now, float(eng.mu[pid]))
                eng.assign(pid, start, float(cfg_c.t_hat[gidx]))
                assignments.append(make_assignment(gidx, pid, start, cfg_c,
                                                   class_id=c))

    def place_orphans(self, tids: np.ndarray, t_now: float, rule: str,
                      degrade=None) -> Tuple[int, int]:
        """Deadline-aware re-placement of tasks orphaned by a pair failure
        (the fault-recovery half of :mod:`repro.core.faults`).

        One scalar loop shared verbatim by the scalar and vector placement
        modes — failures are rare events, so bit-identity between the modes
        under injection comes for free instead of by a second batched
        implementation.  Policy, in EDF order per orphan:

        * try the classes in the task's preference order with the normal
          pair rule (``"wf"`` worst fit for EDL, ``"ff"`` first fit for the
          bin baseline); a fit at the optimal length is placed like any
          arrival;
        * EDL only: when the worst-fit pair cannot host the optimal length,
          shrink to the remaining window ``d - start`` down to the class's
          ``t_min`` floor and queue the boundary re-solve on the shared
          deferred ``readjust_batch`` dispatch.  θ is deliberately ignored
          here — recovery prefers a deadline met at higher speed over a
          counted violation;
        * otherwise fall back to a fresh pair of the primary class; if even
          a fresh pair cannot meet the deadline, the *graceful degradation*
          step books the task anyway — at the ``degrade`` callback's
          max-speed setting (EDL) or the configured setting (bin) — so the
          miss is counted as a violation and a failure trace can never
          crash a run.

        Returns ``(n_restarted, n_degraded)``."""
        tids = np.asarray(tids, dtype=np.int64)
        if tids.size == 0:
            return 0, 0
        eng = self.eng
        cfgs = self.cfgs
        deadline = self.deadline
        assignments = self.assignments
        pending = self.pending
        n_degraded = 0
        order = np.argsort(deadline[tids], kind="stable")     # EDF
        for g in tids[order].tolist():
            d = float(deadline[g])
            placed = False
            for c in self.order_cls[:, g]:
                c = int(c)
                cfg_c = cfgs[c]
                t_hat = float(cfg_c.t_hat[g])
                if rule == "wf":
                    pid = eng.worst_fit(class_id=c)
                    if pid < 0:
                        continue
                    start = max(t_now, float(eng.mu[pid]))
                    window = d - start
                    if window >= t_hat - _EPS:
                        eng.assign(pid, start, t_hat)
                        assignments.append(make_assignment(
                            g, pid, start, cfg_c, class_id=c))
                        placed = True
                        break
                    if window >= float(cfg_c.t_min[g]) - _EPS:
                        eng.assign(pid, start, window)
                        pending.append((len(assignments), g, window, c))
                        assignments.append(make_assignment(
                            g, pid, start, cfg_c, duration=window,
                            readjusted=True, class_id=c))
                        placed = True
                        break
                else:
                    pid = eng.first_fit(t_now, d, t_hat, class_id=c)
                    if pid >= 0:
                        start = max(t_now, float(eng.mu[pid]))
                        eng.assign(pid, start, t_hat)
                        assignments.append(make_assignment(
                            g, pid, start, cfg_c, class_id=c))
                        placed = True
                        break
            if placed:
                continue
            c = int(self.primary[g])
            cfg_c = cfgs[c]
            t_hat = float(cfg_c.t_hat[g])
            pid = self.acquire_fresh(t_now, c)
            start = max(t_now, float(eng.mu[pid]))            # == t_now
            window = d - start
            if window < t_hat - _EPS:
                if rule == "wf" and window >= float(cfg_c.t_min[g]) - _EPS:
                    eng.assign(pid, start, window)
                    pending.append((len(assignments), g, window, c))
                    assignments.append(make_assignment(
                        g, pid, start, cfg_c, duration=window,
                        readjusted=True, class_id=c))
                    continue
                n_degraded += 1
                if rule == "wf" and degrade is not None:
                    v, fc, fm, t_run, p = degrade(g, c)
                    eng.assign(pid, start, t_run)
                    assignments.append(cl.Assignment(
                        task=g, pair=pid, start=start, finish=start + t_run,
                        v=v, fc=fc, fm=fm, power=p, energy=p * t_run,
                        class_id=c))
                    continue
            eng.assign(pid, start, t_hat)
            assignments.append(make_assignment(g, pid, start, cfg_c,
                                               class_id=c))
        return int(tids.size), n_degraded

    def binpack_offline_util(self, idx, order, t_now: float):
        """Algorithm 6, lines 1-7 (the online baseline's offline phase):
        worst-fit on task *utilization*, cap at 1.0.

        The *optimal task utilization* is ``u_hat = t_hat / (d - a)``; the
        worst-fit heuristic sends each task to the pair with the lowest
        current utilization (among pairs of the candidate class), opening a
        fresh pair of the task's primary class when no candidate fits.
        """
        eng = self.eng
        cfgs = self.cfgs
        deadline = self.deadline
        util = np.zeros(0)

        def grow():
            nonlocal util
            if util.shape[0] < eng.n_pairs:
                util = np.concatenate(
                    [util, np.zeros(eng.n_pairs - util.shape[0])])

        for r in order:
            gidx = int(idx[int(r)])
            d = deadline[gidx]
            grow()
            placed = False
            for c in self.order_cls[:, gidx]:
                c = int(c)
                cfg_c = cfgs[c]
                t_hat = float(cfg_c.t_hat[gidx])
                u_hat = t_hat / max(d - t_now, _EPS)
                on = eng.eligible_mask(class_id=c)
                if on is None:
                    on = np.ones(eng.n_pairs, dtype=bool)
                if not on.any():
                    continue
                pid = int(np.argmin(np.where(on, util[: eng.n_pairs],
                                             np.inf)))
                start = max(t_now, float(eng.mu[pid]))
                if util[pid] + u_hat > 1.0 + _EPS or d - start < t_hat - _EPS:
                    continue
                eng.assign(pid, start, t_hat)
                util[pid] += u_hat
                self.assignments.append(make_assignment(gidx, pid, start,
                                                        cfg_c, class_id=c))
                placed = True
                break
            if not placed:
                c = int(self.primary[gidx])
                cfg_c = cfgs[c]
                t_hat = float(cfg_c.t_hat[gidx])
                u_hat = t_hat / max(d - t_now, _EPS)
                pid = self.acquire_fresh(t_now, c)
                grow()
                start = max(t_now, float(eng.mu[pid]))
                eng.assign(pid, start, t_hat)
                util[pid] += u_hat
                self.assignments.append(make_assignment(gidx, pid, start,
                                                        cfg_c, class_id=c))
