"""Task sets and the benchmark-derived application library (paper S5.1.3).

The paper fits its 20-application library from real GTX-1080Ti power/runtime
measurements; the published fitting ranges are

    P*      in [175, 206] W          (default runtime power)
    gamma/P* in [0.1, 0.2]
    P0/P*   in [0.20, 0.41]
    delta   in [0.07, 0.91]
    D       in [1.66, 7.61] s
    t0      in [0.1, 0.95] s

We synthesize a 20-app library inside exactly those ranges (fixed seed), then
generate task sets the way S5.1.3 prescribes: pick an app uniformly, scale its
time components by an integer in [10, 50], draw the task utilization
``u ~ U(0, 1)`` and set the deadline ``d = a + t*/u``.  Offline sets fill a
target *task-set utilization* ``U_J`` (normalized to 1024 CPU-GPU pairs);
online sets additionally spread arrivals over the 1440 one-minute slots of a
day with a Poisson profile.

The library is the *reference-class* (``gtx-1080ti``) fit: heterogeneous
machine classes in :mod:`repro.core.machines` derive their own constants
from it via :meth:`~repro.core.machines.MachineClass.adapt`.  See
docs/EQUATIONS.md for the equation/algorithm -> code map.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dvfs import DvfsParams

UTILIZATION_BASE = 1024  # U_J is normalized to this many pairs (S5.1.3)
REALISTIC_P0 = (0.68, 0.88)  # measured whole-system static share (S5.2):
#   calibrated so the narrow-interval library saving lands at the paper's
#   measured ~4.3% (we get 4.7%); the published fit ranges [0.20, 0.41]
#   are the shrunk-static simulation setting that yields the 36.4% anchor.
MAX_PAIRS = 2048         # cluster-wide pair budget (S5.1.2)
DAY_SLOTS = 1440         # one-minute slots in a day
SCALE_LO, SCALE_HI = 10, 50


@dataclasses.dataclass(frozen=True)
class TaskSet:
    """A batch of independent, non-preemptive tasks (struct-of-arrays)."""

    arrival: np.ndarray    # a_i
    deadline: np.ndarray   # d_i (absolute)
    params: DvfsParams     # per-task model constants (arrays)
    utilization: np.ndarray  # u_i used by the generator / bin-packing

    def __len__(self) -> int:
        return int(self.arrival.shape[0])

    @property
    def t_star(self) -> np.ndarray:
        return np.asarray(self.params.default_time())

    @property
    def p_star(self) -> np.ndarray:
        return np.asarray(self.params.default_power())

    @property
    def total_utilization(self) -> float:
        return float(self.utilization.sum()) / UTILIZATION_BASE

    def subset(self, idx) -> "TaskSet":
        return TaskSet(self.arrival[idx], self.deadline[idx], self.params[idx],
                       self.utilization[idx])

    def concat(self, other: "TaskSet") -> "TaskSet":
        return TaskSet(
            np.concatenate([self.arrival, other.arrival]),
            np.concatenate([self.deadline, other.deadline]),
            DvfsParams(*(np.concatenate([a, b]) for a, b in
                         zip(self.params.astuple(), other.params.astuple()))),
            np.concatenate([self.utilization, other.utilization]),
        )


def app_library(n_apps: int = 20, seed: int = 11,
                p0_frac=(0.20, 0.41)) -> DvfsParams:
    """Synthesize the 20-application library inside the paper's fit ranges.

    The default seed is calibrated so the library's mean wide-interval
    single-task energy saving is 36.4% - the paper's own Fig. 4 anchor -
    making all downstream scheduling numbers directly comparable.

    ``p0_frac``: static-power share range.  The default is the paper's
    published fit range used for the (shrunk-static) simulations; pass
    ``REALISTIC_P0`` to model the measured whole-system static share that
    produces the paper's ~4.3% *narrow-interval* saving (§5.2).
    """
    rng = np.random.default_rng(seed)
    p_star = rng.uniform(175.0, 206.0, n_apps)
    gamma = p_star * rng.uniform(0.10, 0.20, n_apps)
    p0 = p_star * rng.uniform(*p0_frac, n_apps)
    c = p_star - gamma - p0
    # Spread delta across the full measured range, ends included, so the
    # library contains both strongly compute-bound and memory-bound apps.
    delta = np.linspace(0.07, 0.91, n_apps)
    rng.shuffle(delta)
    big_d = rng.uniform(1.66, 7.61, n_apps)
    t0 = rng.uniform(0.10, 0.95, n_apps)
    return DvfsParams(p0=p0, gamma=gamma, c=c, big_d=big_d, delta=delta, t0=t0)


def _draw_tasks(rng: np.random.Generator, library: DvfsParams, target_util: float):
    """Draw tasks until the cumulative utilization hits ``target_util*1024``."""
    lib = [library[i] for i in range(np.asarray(library.p0).shape[0])]
    target = target_util * UTILIZATION_BASE
    rows, us = [], []
    total = 0.0
    while total < target:
        app = lib[int(rng.integers(len(lib)))]
        k = int(rng.integers(SCALE_LO, SCALE_HI + 1))
        u = float(rng.uniform(0.0, 1.0))
        u = min(max(u, 1e-3), 1.0)
        if total + u > target:      # trim the last task to land exactly on U_J
            u = target - total
            if u < 1e-3:
                break
        rows.append(DvfsParams(app.p0, app.gamma, app.c,
                               app.big_d * k, app.delta, app.t0 * k))
        us.append(u)
        total += u
    params = DvfsParams.stack(rows)
    return params, np.asarray(us, dtype=np.float64)


def generate_offline(target_util: float, seed: int = 0,
                     library: DvfsParams | None = None) -> TaskSet:
    """An offline batch: every task arrives at T = 0 (S5.1.3)."""
    rng = np.random.default_rng(seed)
    library = library if library is not None else app_library()
    params, u = _draw_tasks(rng, library, target_util)
    t_star = np.asarray(params.default_time())
    arrival = np.zeros_like(u)
    deadline = arrival + t_star / u
    return TaskSet(arrival, deadline, params, u)


def _draw_n(rng: np.random.Generator, library: DvfsParams, n: int):
    """Draw exactly ``n`` tasks (app, scale, utilization) the §5.1.3 way —
    vectorized, since a 1M-task trace is a realistic request."""
    p0, gamma, c, big_d, delta, t0 = (np.asarray(f, np.float64)
                                      for f in library.astuple())
    app = rng.integers(p0.shape[0], size=n)
    k = rng.integers(SCALE_LO, SCALE_HI + 1, size=n).astype(np.float64)
    u = np.clip(rng.uniform(0.0, 1.0, n), 1e-3, 1.0)
    params = DvfsParams(p0=p0[app], gamma=gamma[app], c=c[app],
                        big_d=big_d[app] * k, delta=delta[app],
                        t0=t0[app] * k)
    return params, u


def generate_offline_n(n_tasks: int, seed: int = 0,
                       library: DvfsParams | None = None) -> TaskSet:
    """A count-driven offline batch: exactly ``n_tasks`` tasks drawn the
    §5.1.3 way (vectorized), every one arriving at ``T = 0``.

    Complements :func:`generate_offline` (which targets a *utilization*)
    for scale benchmarks that need exactly ``n`` tasks
    (``benchmarks/offline_scale.py``).
    """
    rng = np.random.default_rng(seed)
    library = library if library is not None else app_library()
    params, u = _draw_n(rng, library, int(n_tasks))
    t_star = np.asarray(params.default_time())
    arrival = np.zeros(int(n_tasks))
    deadline = arrival + t_star / u
    return TaskSet(arrival, deadline, params, u)


TRACE_PATTERNS = ("uniform", "sparse", "bursty", "diurnal")


def generate_trace(n_tasks: int, pattern: str = "uniform",
                   horizon: int = DAY_SLOTS, seed: int = 0,
                   library: DvfsParams | None = None) -> TaskSet:
    """A task-count-driven online trace with a named arrival pattern.

    Complements :func:`generate_online` (which targets a *utilization*) for
    scale benchmarks that need exactly ``n_tasks`` tasks:

    * ``uniform`` — every slot equally likely;
    * ``sparse``  — arrivals only on every 32nd slot (arrival gaps far
      beyond ``rho``, the regime that exposes DRS power-off accounting);
    * ``bursty``  — a handful of random slots carry everything;
    * ``diurnal`` — a day-shaped sinusoidal rate (§5.1.3's day profile).
    """
    if pattern not in TRACE_PATTERNS:
        raise ValueError(f"unknown arrival pattern {pattern!r}; "
                         f"choose from {TRACE_PATTERNS}")
    rng = np.random.default_rng(seed)
    library = library if library is not None else app_library()
    params, u = _draw_n(rng, library, int(n_tasks))

    slots = np.arange(1, horizon + 1, dtype=np.int64)
    if pattern == "uniform":
        p = np.ones(horizon)
    elif pattern == "sparse":
        p = (slots % 32 == 1).astype(np.float64)
    elif pattern == "bursty":
        n_bursts = max(1, min(horizon, n_tasks // 512 + 1))
        p = np.zeros(horizon)
        p[rng.choice(horizon, size=n_bursts, replace=False)] = 1.0
    else:  # diurnal
        p = 1.0 + np.sin(2.0 * np.pi * slots / horizon - 0.5 * np.pi)
        p += 1e-3
    counts = rng.multinomial(n_tasks, p / p.sum())
    arrival = np.repeat(slots.astype(np.float64), counts)
    t_star = np.asarray(params.default_time())
    deadline = arrival + t_star / u
    return TaskSet(arrival, deadline, params, u)


def generate_online(offline_util: float = 0.4, online_util: float = 1.6,
                    seed: int = 0, library: DvfsParams | None = None,
                    horizon: int = DAY_SLOTS) -> TaskSet:
    """The online workload: an initial batch at T=0 plus Poisson arrivals.

    ``n(T)`` for T in [1, horizon] is Poisson and refined so that the online
    tasks sum exactly to ``online_util`` (S5.1.3; U_OFF=0.4, U_ON=1.6).
    """
    rng = np.random.default_rng(seed)
    library = library if library is not None else app_library()
    off = generate_offline(offline_util, seed=int(rng.integers(2**31)), library=library)

    params, u = _draw_tasks(rng, library, online_util)
    n_on = u.shape[0]
    lam = n_on / horizon
    counts = rng.poisson(lam, horizon)
    # Refine the profile until it carries exactly n_on tasks.
    diff = int(counts.sum()) - n_on
    while diff != 0:
        slot = int(rng.integers(horizon))
        if diff > 0 and counts[slot] > 0:
            counts[slot] -= 1
            diff -= 1
        elif diff < 0:
            counts[slot] += 1
            diff += 1
    arrival = np.repeat(np.arange(1, horizon + 1, dtype=np.float64), counts)
    t_star = np.asarray(params.default_time())
    deadline = arrival + t_star / u
    online = TaskSet(arrival, deadline, params, u)
    return off.concat(online)


def peak_pair_estimate(task_set: TaskSet) -> int:
    """Upper estimate of concurrently busy pairs: each task on its own pair
    from its arrival slot until the later of its deadline and
    ``ceil(a) + t*``, peak of the running sum.

    A sizing heuristic, not a schedule: packing shares pairs and DRS holds
    servers ``rho`` slots past their last task, so the real fleet is
    usually smaller but the same order of magnitude.  Used to size
    :class:`repro.core.faults.FaultTrace` server ranges (``peak / l``)
    without running a failure-free schedule first."""
    if len(task_set) == 0:
        return 0
    start = np.ceil(np.asarray(task_set.arrival, np.float64))
    end = np.maximum(np.asarray(task_set.deadline, np.float64),
                     start + task_set.t_star)
    ts = np.concatenate([start, end])
    delta = np.concatenate([np.ones(start.shape[0]),
                            -np.ones(end.shape[0])])
    order = np.lexsort((-delta, ts))       # at ties, starts count first
    return int(np.cumsum(delta[order]).max())
