"""Single-task DVFS optimization (paper S4.1, Algorithm 1).

Two sub-problems, both reduced to a 1-D minimization:

* **Unconstrained** ``argmin E(V, fc, fm)``: the paper's Theorem 1 shows
  ``dE/dV > 0`` everywhere, so the optimum has the *minimum voltage that
  sustains the chosen core frequency*, ``V = max(v_min, g1^{-1}(fc))``; and for
  fixed ``(V, fc)`` the optimal memory frequency has the closed form
  :func:`repro.core.dvfs.optimal_fm`.  That leaves a single decision variable
  ``fc in [fc_min, g1(v_max)]`` which we minimize with a coarse grid followed
  by golden-section refinement (the energy curve is unimodal on the analytic
  interval where P is strictly convex; the grid stage guards against the
  clamped-fm kinks).

* **Deadline-constrained** (deadline-prior tasks, ``t_hat > d - a``): the
  optimum sits on the time boundary ``t(fc, fm) = allowed``.  Parametrizing by
  ``fm``, the required core frequency is
  ``fc_req(fm) = D delta / (allowed - t0 - D (1 - delta) / fm)`` and
  ``V = max(v_min, g1^{-1}(fc))``; again a 1-D search over ``fm``.

Everything is vectorized over a batch of tasks and jit-compatible; it is both
the production solver and the oracle for the ``dvfs_opt`` Pallas kernel.
Heterogeneous machine classes run this same solver once per class —
:func:`repro.core.machines.configure_classes` stacks the class blocks into
one widened kernel dispatch.  See docs/EQUATIONS.md for the
equation/algorithm -> code map.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dvfs
from repro.core.dvfs import DvfsParams, ScalingInterval
from repro.kernels import layout
from repro.kernels.layout import DvfsSolution  # noqa: F401  (re-export)

INV_PHI = 0.6180339887498949  # 1/golden ratio
GRID_POINTS = 65
GOLDEN_ITERS = 40


# ---------------------------------------------------------------------------
# Unconstrained optimum.
# ---------------------------------------------------------------------------


def _energy_of_fc(params: DvfsParams, fc, interval: ScalingInterval):
    """Energy along the optimal-V / optimal-fm manifold, as a function of fc."""
    v = jnp.maximum(interval.v_min, dvfs.g1_inv(fc))
    fm = dvfs.optimal_fm(params, v, fc, interval)
    return dvfs.energy(params, v, fc, fm), (v, fm)


def _golden_minimize(fn, lo, hi, iters: int = GOLDEN_ITERS):
    """Vectorized golden-section minimization of ``fn`` over ``[lo, hi]``."""

    def body(state, _):
        lo, hi = state
        d = (hi - lo) * INV_PHI
        x1 = hi - d
        x2 = lo + d
        f1 = fn(x1)
        f2 = fn(x2)
        shrink_right = f1 < f2  # minimum is in [lo, x2]
        new_lo = jnp.where(shrink_right, lo, x1)
        new_hi = jnp.where(shrink_right, x2, hi)
        return (new_lo, new_hi), None

    (lo, hi), _ = jax.lax.scan(body, (lo, hi), None, length=iters)
    return 0.5 * (lo + hi)


def _grid_then_golden(fn, lo, hi, n_grid: int = GRID_POINTS):
    """Coarse grid scan to bracket the global minimum, then golden refine.

    ``lo``/``hi`` may be per-task arrays. Returns the argmin x (same shape).
    """
    ts = jnp.linspace(0.0, 1.0, n_grid)

    def eval_at(frac):
        return fn(lo + (hi - lo) * frac)

    vals = jax.vmap(eval_at)(ts)  # [n_grid, batch...]
    best = jnp.argmin(vals, axis=0)
    step = 1.0 / (n_grid - 1)
    frac_lo = jnp.clip(best * step - step, 0.0, 1.0)
    frac_hi = jnp.clip(best * step + step, 0.0, 1.0)
    x = _golden_minimize(lambda f: fn(lo + (hi - lo) * f), frac_lo, frac_hi)
    return lo + (hi - lo) * x


@partial(jax.jit, static_argnames=("interval",))
def solve_unconstrained(params: DvfsParams, interval: ScalingInterval = dvfs.WIDE) -> DvfsSolution:
    """argmin_{V, fc, fm} E for each task, ignoring deadlines (paper Eq. 9)."""
    params = DvfsParams(*(jnp.asarray(f, jnp.float32) for f in params.astuple()))

    def efc(fc):
        return _energy_of_fc(params, fc, interval)[0]

    lo = jnp.full_like(params.big_d, interval.fc_min)
    hi = jnp.full_like(params.big_d, interval.fc_max)
    fc = _grid_then_golden(efc, lo, hi)
    e, (v, fm) = _energy_of_fc(params, fc, interval)
    t = dvfs.exec_time(params, fc, fm)
    p = dvfs.power(params, v, fc, fm)
    true_ = jnp.ones_like(e, dtype=bool)
    return DvfsSolution(v, fc, fm, t, p, e, ~true_, true_)


# ---------------------------------------------------------------------------
# Deadline-constrained optimum.
# ---------------------------------------------------------------------------


def _deadline_energy_of_fm(params: DvfsParams, fm, allowed, interval: ScalingInterval):
    """Energy on the ``t = allowed`` boundary parametrized by fm.

    Infeasible fm (required fc above fc_max, or non-positive time budget for
    the core component) get +inf energy.
    """
    slack = allowed - params.t0 - params.big_d * (1.0 - params.delta) / fm
    fc_req = params.big_d * params.delta / jnp.maximum(slack, 1e-30)
    # delta == 0: any fc meets the deadline; run the core floor.
    fc_req = jnp.where(params.delta <= 0.0, interval.fc_min, fc_req)
    infeasible = (slack <= 0.0) & (params.delta > 0.0)
    fc = jnp.clip(fc_req, interval.fc_min, interval.fc_max)
    v = jnp.maximum(interval.v_min, dvfs.g1_inv(fc))
    t = dvfs.exec_time(params, fc, fm)
    e = dvfs.power(params, v, fc, fm) * t
    e = jnp.where(infeasible | (fc_req > interval.fc_max + 1e-6), jnp.inf, e)
    return e, (v, fc)


def _boundary_optimum(params: DvfsParams, allowed, interval: ScalingInterval):
    """The deadline-boundary optimum ``(v, fc, fm)``: 1-D search over fm on
    the ``t(fc, fm) = allowed`` manifold (params/allowed already f32)."""

    def efm(fm):
        return _deadline_energy_of_fm(params, fm, allowed, interval)[0]

    lo = jnp.full_like(params.big_d, interval.fm_min)
    hi = jnp.full_like(params.big_d, interval.fm_max)
    fm = _grid_then_golden(efm, lo, hi)
    _, (v, fc) = _deadline_energy_of_fm(params, fm, allowed, interval)
    return v, fc, fm


@partial(jax.jit, static_argnames=("interval",))
def solve_with_deadline(params: DvfsParams, allowed,
                        interval: ScalingInterval = dvfs.WIDE) -> DvfsSolution:
    """Optimal setting subject to ``t <= allowed`` (Algorithm 1 body).

    Tasks whose unconstrained optimum already fits (``t_hat <= allowed``) keep
    it (energy-prior); the rest are re-solved on the deadline boundary
    (deadline-prior).  Tasks that cannot meet the deadline even at maximum
    frequencies are flagged infeasible and returned at max speed.
    """
    params = DvfsParams(*(jnp.asarray(f, jnp.float32) for f in params.astuple()))
    allowed = jnp.asarray(allowed, jnp.float32)
    unc = solve_unconstrained(params, interval)
    energy_prior = unc.time <= allowed + 1e-6

    v, fc, fm = _boundary_optimum(params, allowed, interval)

    # Infeasible deadline => max speed, still report honestly.
    tmin = dvfs.min_time(params, interval)
    feasible = allowed >= tmin - 1e-6
    vmax = jnp.full_like(v, interval.v_max)
    fcmax = jnp.full_like(fc, interval.fc_max)
    fmmax = jnp.full_like(fm, interval.fm_max)

    def pick(con_val, unc_val, max_val):
        x = jnp.where(energy_prior, unc_val, con_val)
        return jnp.where(feasible, x, max_val)

    v = pick(v, unc.v, vmax)
    fc = pick(fc, unc.fc, fcmax)
    fm = pick(fm, unc.fm, fmmax)
    t = dvfs.exec_time(params, fc, fm)
    p = dvfs.power(params, v, fc, fm)
    e = p * t
    return DvfsSolution(v, fc, fm, t, p, e, ~energy_prior, feasible)


@partial(jax.jit, static_argnames=("interval",))
def solve_on_boundary(params: DvfsParams, allowed,
                      interval: ScalingInterval = dvfs.WIDE) -> DvfsSolution:
    """The deadline-boundary solve used by theta-readjustment.

    A readjustment shrinks a task's window *below* its optimal execution
    time, so the constrained optimum sits on the ``t = allowed`` boundary by
    construction — no unconstrained solve or energy-prior comparison is
    needed.  Windows below ``t_min`` fall back to max speed (infeasible).
    """
    params = DvfsParams(*(jnp.asarray(f, jnp.float32) for f in params.astuple()))
    allowed = jnp.asarray(allowed, jnp.float32)
    v, fc, fm = _boundary_optimum(params, allowed, interval)

    tmin = dvfs.min_time(params, interval)
    feasible = allowed >= tmin - 1e-6
    v = jnp.where(feasible, v, interval.v_max)
    fc = jnp.where(feasible, fc, interval.fc_max)
    fm = jnp.where(feasible, fm, interval.fm_max)
    t = dvfs.exec_time(params, fc, fm)
    p = dvfs.power(params, v, fc, fm)
    dp = jnp.ones_like(feasible)
    return DvfsSolution(v, fc, fm, t, p, p * t, dp, feasible)


# ---------------------------------------------------------------------------
# Algorithm 1: voltage/frequency configuration for a task set.
# ---------------------------------------------------------------------------


class TaskConfig(NamedTuple):
    """Numpy view of Algorithm 1's output, consumed by the schedulers."""

    v: np.ndarray
    fc: np.ndarray
    fm: np.ndarray
    t_hat: np.ndarray          # optimized execution time (paper's t-hat / t-hat')
    p_hat: np.ndarray
    e_hat: np.ndarray
    t_min: np.ndarray          # fastest achievable time (theta floor)
    deadline_prior: np.ndarray
    feasible: np.ndarray
    n_deadline_prior: int


def pad_pow2(params: DvfsParams, allowed, extra_rows: np.ndarray = None):
    """Pad a batch to the next power of two (>= 8) by replicating the last
    task, so the jitted solvers compile O(log n) distinct shapes over a
    day-long online simulation instead of one per slot population.

    ``extra_rows`` (``[n, k]``, e.g. per-row interval bounds) is padded the
    same way; returns ``(params, allowed, extra_rows, n)``.
    """
    n = int(np.shape(np.asarray(params.p0))[0])
    n_pad = max(8, 1 << (n - 1).bit_length())
    if n_pad != n:
        pad = n_pad - n
        params = DvfsParams(*(np.concatenate(
            [np.asarray(f, np.float64), np.full(pad, np.asarray(f)[-1])])
            for f in params.astuple()))
        allowed = np.concatenate(
            [np.asarray(allowed, np.float64),
             np.full(pad, np.asarray(allowed)[-1])])
        if extra_rows is not None:
            extra_rows = np.concatenate(
                [extra_rows,
                 np.broadcast_to(extra_rows[-1], (pad, extra_rows.shape[1]))],
                axis=0)
    return params, allowed, extra_rows, n


def config_from_solution(sol: DvfsSolution, params: DvfsParams, allowed,
                         interval: ScalingInterval,
                         tmin: np.ndarray = None) -> TaskConfig:
    """TaskConfig assembly shared by :func:`configure_tasks` and the
    heterogeneous class path (``machines.configure_classes``): the t_min
    floor plus snapping the deadline-boundary f32 residual to ``allowed``
    so downstream deadline checks are exact.

    ``tmin`` short-circuits the :func:`repro.core.dvfs.min_time` call when
    the caller already holds it — the pipelined online path computes the
    whole horizon's floors once up front and passes per-chunk slices
    (``min_time`` is elementwise, so slices are bitwise equal)."""
    sol = DvfsSolution(*(np.asarray(f) for f in sol))
    if tmin is None:
        tmin = np.asarray(dvfs.min_time(params, interval))
    allowed_arr = np.broadcast_to(np.asarray(allowed, np.float64),
                                  sol.time.shape)
    t_hat = np.where(sol.deadline_prior & sol.feasible,
                     np.minimum(sol.time, allowed_arr), sol.time)
    return TaskConfig(
        v=sol.v, fc=sol.fc, fm=sol.fm,
        t_hat=t_hat, p_hat=sol.power, e_hat=sol.power * t_hat,
        t_min=np.broadcast_to(tmin, sol.time.shape).copy(),
        deadline_prior=sol.deadline_prior, feasible=sol.feasible,
        n_deadline_prior=int(np.sum(sol.deadline_prior)),
    )


def no_dvfs_config(params: DvfsParams, allowed) -> TaskConfig:
    """The no-DVFS configuration: every task runs at ``(1, 1, 1)``.

    The ONE implementation behind both ``scheduling.default_config``
    (homogeneous) and ``machines.default_configs`` (per adapted class), so
    the ``(1, 1, 1)`` fallback cannot drift between the two paths.  With no
    scaling there is no shrink room: ``t_min == t_hat == t*``.
    """
    allowed = np.asarray(allowed, dtype=np.float64)
    t_star = np.asarray(params.default_time())
    p_star = np.asarray(params.default_power())
    ones = np.ones(t_star.shape[0])
    deadline_prior = t_star > allowed + 1e-9
    return TaskConfig(
        v=ones.copy(), fc=ones.copy(), fm=ones.copy(),
        t_hat=t_star.copy(), p_hat=p_star.copy(), e_hat=(p_star * t_star),
        t_min=t_star.copy(),
        deadline_prior=deadline_prior,
        feasible=~deadline_prior,
        n_deadline_prior=int(np.sum(deadline_prior)),
    )


def max_speed_setting(params: DvfsParams,
                      interval: ScalingInterval = dvfs.WIDE):
    """Every task at the interval's maximum speed: ``(v_max, fc_max,
    fm_max)``, with ``t`` equal to the class ``t_min`` bitwise (both are
    :func:`repro.core.dvfs.min_time` on the same params/interval).

    The graceful-degradation setting of the fault-recovery policy
    (:meth:`repro.core.placement.PlacementContext.place_orphans`): a task
    re-placed after a server failure that cannot meet its deadline on any
    pair runs flat out, and the remaining miss is counted as a violation.
    Returns numpy arrays ``(v, fc, fm, t, p)``.
    """
    t = np.asarray(dvfs.min_time(params, interval), np.float64)
    p = np.asarray(dvfs.power(params, interval.v_max, interval.fc_max,
                              interval.fm_max), np.float64)
    n = t.shape[0]
    return (np.full(n, interval.v_max), np.full(n, interval.fc_max),
            np.full(n, interval.fm_max), t, np.broadcast_to(p, (n,)))


def _dedup_solve(params: DvfsParams, allowed, interval: ScalingInterval,
                 boundary: bool) -> DvfsSolution:
    """Route a batched jnp solve through the unique-row dedup + process-wide
    LRU cache (:mod:`repro.core.solver_cache`).

    Bit-identical to the direct solve: the f32 key matrix IS the solver
    input (both solvers cast to f32 before computing) and every solver is
    row-independent, so deduped rows scatter back to exactly the values a
    full-batch solve would produce.
    """
    from repro.core import solver_cache

    keys = solver_cache.build_keys(
        params.astuple(), allowed, boundary,
        np.asarray(interval.bounds(), np.float32))
    solver = solve_on_boundary if boundary else solve_with_deadline

    def solve(km: np.ndarray) -> np.ndarray:
        p = DvfsParams(*(km[:, i] for i in range(layout.N_PARAMS)))
        return solver_cache.solution_to_rows(
            solver(p, km[:, layout.ALLOWED], interval))

    rows = solver_cache.solve_rows(keys, solve,
                                   tag="jnp-bd" if boundary else "jnp-dl")
    return solver_cache.rows_to_solution(rows)


def solve_rows_async(params: DvfsParams, allowed,
                     interval: ScalingInterval, *, boundary: bool,
                     use_kernel: bool = False, dedup: bool = True):
    """Dispatch one solve batch without blocking — the pipelined online
    scheduler's per-chunk entry point.

    Builds the f32 key matrix, probes the cache, and dispatches only the
    misses; returns a :class:`repro.core.solver_cache.AsyncSolve` whose
    ``.result()`` is bit-identical to the synchronous
    :func:`configure_tasks` / :func:`readjust_batch` solves (same tags, so
    the cache composes across both paths).  The jnp path keeps the result
    on device by stacking the solution columns eagerly (dispatch, not
    compute); the kernel path defers via ``dvfs_solve_matrix(block=False)``.

    Chunks skip the sort-based intra-batch unique pass
    (``solve_rows_async(unique=False)``): online chunks are nearly
    duplicate-free, so the cache probe alone carries the dedup and
    cross-chunk repeats still hit.
    """
    from repro.core import solver_cache

    keys = solver_cache.build_keys(
        params.astuple(), allowed, boundary,
        np.asarray(interval.bounds(), np.float32))
    cache = solver_cache.GLOBAL_CACHE if dedup else None
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        from repro.kernels.dvfs_opt import DEFAULT_GRID

        tag = f"k{int(DEFAULT_GRID[0])}x{int(DEFAULT_GRID[1])}"

        def solve(km: np.ndarray):
            return kernel_ops.dvfs_solve_matrix(km, block=False)

    else:
        tag = "jnp-bd" if boundary else "jnp-dl"
        solver = solve_on_boundary if boundary else solve_with_deadline

        def solve(km: np.ndarray):
            p = DvfsParams(*(km[:, i] for i in range(layout.N_PARAMS)))
            sol = solver(p, km[:, layout.ALLOWED], interval)
            # Device-side stack: pure data movement (bitwise equal to the
            # host-side ``solution_to_rows``), so the host never waits here.
            return jnp.stack(
                [jnp.asarray(f, jnp.float32) for f in sol], axis=1)

    return solver_cache.solve_rows_async(keys, solve, tag=tag, cache=cache,
                                         unique=False)


def configure_tasks(params: DvfsParams, allowed, interval: ScalingInterval = dvfs.WIDE,
                    use_kernel: bool = False, dedup: bool = True) -> TaskConfig:
    """Algorithm 1: per-task optimal DVFS settings for a whole task set.

    ``allowed`` is ``d - a`` per task.  With ``use_kernel=True`` the batched
    Pallas kernel (interpret mode on CPU) computes the whole solve.
    ``dedup=True`` (default) solves only unique ``(params, allowed)`` rows
    and serves repeats — within this call or from any previous one — out of
    the process-wide solve cache, bit-identically.
    """
    params, allowed, _, n = pad_pow2(params, allowed)
    if use_kernel:
        from repro.kernels import ops as kernel_ops

        sol = kernel_ops.dvfs_solve(params, np.asarray(allowed), interval,
                                    dedup=dedup)
    elif dedup:
        sol = _dedup_solve(params, allowed, interval, boundary=False)
    else:
        sol = solve_with_deadline(params, allowed, interval)
    if np.shape(np.asarray(params.p0))[0] != n:
        sol = DvfsSolution(*(np.asarray(f)[:n] for f in sol))
        params = params[:n]
        allowed = np.asarray(allowed)[:n]
    return config_from_solution(sol, params, allowed, interval)


def readjust_batch(params: DvfsParams, windows, interval: ScalingInterval = dvfs.WIDE,
                   use_kernel: bool = False, dedup: bool = True):
    """Batched theta-readjustment: re-solve ``n`` tasks with shrunken time
    budgets in ONE solver dispatch (Algorithm 2 lines 16-19 / Algorithm 5).

    A readjusted window sits below the task's optimal execution time by
    construction, so every row takes the deadline-boundary branch; with
    ``use_kernel=True`` the whole batch goes through the Pallas kernel's
    readjust sweep in a single ``pallas_call``.  Returns numpy arrays
    ``(v, fc, fm, t, p, e)`` with ``t`` snapped to the window where feasible
    (so scheduler mu updates land exactly on the deadline).
    """
    windows = np.asarray(windows, dtype=np.float64)
    params, padded, _, n = pad_pow2(params, windows)
    if use_kernel:
        from repro.kernels import ops as kernel_ops

        sol = kernel_ops.dvfs_solve(params, np.asarray(padded), interval,
                                    readjust=True, dedup=dedup)
    elif dedup:
        sol = _dedup_solve(params, padded, interval, boundary=True)
    else:
        sol = solve_on_boundary(params, padded, interval)
    v, fc, fm, t, p = (np.asarray(f, np.float64)[:n]
                       for f in (sol.v, sol.fc, sol.fm, sol.time, sol.power))
    feas = np.asarray(sol.feasible)[:n]
    t = np.where(feas, np.minimum(t, windows), t)  # snap the f32 residual
    return v, fc, fm, t, p, p * t


def readjust(params: DvfsParams, new_allowed: float,
             interval: ScalingInterval = dvfs.WIDE):
    """theta-readjustment: re-solve one task with a shrunken time budget.

    Returns ``(v, fc, fm, t, p, e)`` as python floats.  Thin scalar wrapper
    over :func:`readjust_batch`: ``new_allowed`` must sit below the task's
    unconstrained optimal time (the readjustment regime) — the boundary
    solution is returned unconditionally, so a window wide enough for the
    interior optimum would come back pessimally stretched to fill it.
    """
    batched = DvfsParams(*(np.asarray([f], dtype=np.float64) for f in params.astuple()))
    out = readjust_batch(batched, np.asarray([float(new_allowed)]), interval)
    return tuple(float(np.asarray(f)[0]) for f in out)


def brute_force_optimum(params: DvfsParams, allowed: float | None = None,
                        interval: ScalingInterval = dvfs.WIDE, n: int = 160):
    """Dense-grid reference optimum (tests only; O(n^3) with feasibility mask)."""
    vs = np.linspace(interval.v_min, interval.v_max, n)
    fms = np.linspace(interval.fm_min, interval.fm_max, n)
    best = (np.inf, None)
    for v in vs:
        fc_hi = float(dvfs.g1(v))
        fcs = np.linspace(interval.fc_min, fc_hi, n)
        fcs = fcs[fcs <= fc_hi + 1e-9]
        for fc in fcs:
            t = np.asarray(dvfs.exec_time(params, fc, fms))
            p = np.asarray(dvfs.power(params, v, fc, fms))
            e = p * t
            if allowed is not None:
                e = np.where(t <= allowed + 1e-9, e, np.inf)
            i = int(np.argmin(e))
            if e[i] < best[0]:
                best = (float(e[i]), (float(v), float(fc), float(fms[i]), float(t[i])))
    return best
