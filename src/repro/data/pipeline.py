"""Deterministic sharded synthetic data pipeline.

Every batch is a pure function of ``(seed, step, row)`` — restarts replay
the exact same stream regardless of how many steps were lost, and any data
shard can be regenerated independently on its host (the multi-host story:
each host materializes only the rows its data shard owns).

Two task distributions:

* ``mode="zipf"``  — Zipf-distributed tokens (realistic marginals),
* ``mode="copy"``  — the second half of each row repeats the first half
  (induction-head task; needs hundreds of steps to click),
* ``mode="succ"``  — noisy successor chains (x_{t+1} = x_t + 1 mod V with
  5% noise): learnable by the embedding/head alone, so loss falls well
  below the unigram floor within tens of CPU steps — the fast-feedback
  signal for the examples and trainer tests.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np


@dataclasses.dataclass
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mode: str = "copy"          # "copy" | "zipf"
    zipf_a: float = 1.2
    # modality extras (stub frontends)
    n_patches: int = 0
    n_frames: int = 0
    d_model: int = 0

    def _rows(self, step: int, lo: int, hi: int) -> np.ndarray:
        out = np.empty((hi - lo, self.seq_len + 1), np.int32)
        for i, row in enumerate(range(lo, hi)):
            rng = np.random.default_rng(
                np.uint64(self.seed) * np.uint64(1_000_003)
                + np.uint64(step) * np.uint64(65_537) + np.uint64(row))
            if self.mode == "zipf":
                toks = rng.zipf(self.zipf_a, self.seq_len + 1)
                out[i] = np.minimum(toks, self.vocab_size - 1)
            elif self.mode == "succ":
                start = rng.integers(0, self.vocab_size)
                seq = (start + np.arange(self.seq_len + 1)) % self.vocab_size
                noise = rng.random(self.seq_len + 1) < 0.05
                seq = np.where(noise, rng.integers(
                    0, self.vocab_size, self.seq_len + 1), seq)
                out[i] = seq
            else:
                half = (self.seq_len + 1 + 1) // 2
                first = rng.integers(1, self.vocab_size,
                                     half).astype(np.int32)
                row_t = np.concatenate([first, first])[: self.seq_len + 1]
                out[i] = row_t
        return out

    def batch(self, step: int, shard: int = 0, n_shards: int = 1
              ) -> Dict[str, np.ndarray]:
        """The (full or per-shard) batch for ``step``.

        ``shard``/``n_shards`` select a contiguous row range — the rows a
        data shard owns; the same (step, row) always yields the same data.
        """
        assert self.global_batch % n_shards == 0
        rows = self.global_batch // n_shards
        lo = shard * rows
        seqs = self._rows(step, lo, lo + rows)
        out = {"tokens": seqs[:, :-1].copy(), "labels": seqs[:, 1:].copy()}
        if self.n_patches:
            rng = np.random.default_rng(np.uint64(self.seed + 7) +
                                        np.uint64(step))
            out["patch_embeds"] = rng.standard_normal(
                (rows, self.n_patches, self.d_model)).astype(np.float32) * 0.02
        if self.n_frames:
            rng = np.random.default_rng(np.uint64(self.seed + 13) +
                                        np.uint64(step))
            out["frames"] = rng.standard_normal(
                (rows, self.n_frames, self.d_model)).astype(np.float32) * 0.02
        return out

    @staticmethod
    def for_config(cfg, seq_len: int, global_batch: int, seed: int = 0,
                   mode: str = "copy") -> "SyntheticLMData":
        return SyntheticLMData(
            vocab_size=cfg.vocab_size, seq_len=seq_len,
            global_batch=global_batch, seed=seed, mode=mode,
            n_patches=cfg.n_patches if cfg.family == "vlm" else 0,
            n_frames=cfg.n_frames if cfg.family == "encdec" else 0,
            d_model=cfg.d_model)
