"""Int8 gradient compression with error feedback (distributed-optimization
trick for the DP gradient reduction).

Per-tensor symmetric int8 quantization; the quantization residual is kept
in an error-feedback accumulator and added back before the next step's
quantization, which provably preserves SGD convergence.  Used by the
trainer's optional ``compress_grads`` path: gradients are quantized
*before* the data-parallel reduction (4x fewer bytes on the wire) and
dequantized after.  Off by default; measured in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: Any  # error-feedback accumulator, same tree as grads (f32)


def init_compression(grads_like) -> CompressionState:
    return CompressionState(error=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8.  Returns (q int8, scale f32 scalar)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, state: CompressionState):
    """Quantize a gradient tree with error feedback.

    Returns (quantized tree of (q, scale), new state).  The caller reduces
    the quantized payload (psum of int32-accumulated int8 values or
    all-gather of q) and calls :func:`decompress_tree`."""
    compensated = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                               grads, state.error)
    qs = jax.tree.map(compress_int8, compensated,
                      is_leaf=lambda x: isinstance(x, jax.Array))
    deq = jax.tree.map(lambda qs_: decompress_int8(*qs_), qs,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda c, d: c - d, compensated, deq)
    return qs, deq, CompressionState(error=new_err)
