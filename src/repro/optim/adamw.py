"""AdamW with global-norm clipping and a warmup+cosine schedule.

Self-contained (no optax in this container).  Optimizer states are f32 and
inherit the parameter shardings — with the fsdp rule table every moment
tensor is fully sharded over both mesh axes (ZeRO-style), so optimizer
memory is ``2 * params / n_chips`` per chip.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array  # int32 step counter


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    """lr(step): linear warmup then cosine decay to ``min_frac * base_lr``."""

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Any = 3e-4      # float or callable(step) -> lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> OptState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(m=zeros,
                        v=jax.tree.map(jnp.copy, zeros),
                        count=jnp.zeros((), jnp.int32))

    def update(self, grads, state: OptState, params):
        """Returns (new_params, new_state, metrics)."""
        count = state.count + 1
        gnorm = global_norm(grads)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        lr = (self.learning_rate(count) if callable(self.learning_rate)
              else jnp.asarray(self.learning_rate, jnp.float32))

        c = count.astype(jnp.float32)
        bc1 = 1.0 - self.b1 ** c
        bc2 = 1.0 - self.b2 ** c

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            step = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            return m, v, (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        flat_p = treedef.flatten_up_to(params)
        new = [upd(g, m, v, p) for g, m, v, p in
               zip(flat_g, flat_m, flat_v, flat_p)]
        new_m = treedef.unflatten([x[0] for x in new])
        new_v = treedef.unflatten([x[1] for x in new])
        new_p = treedef.unflatten([x[2] for x in new])
        return new_p, OptState(new_m, new_v, count), {
            "grad_norm": gnorm, "lr": lr}
