from repro.optim.adamw import AdamW, OptState, cosine_schedule
from repro.optim.compression import (compress_int8, decompress_int8,
                                     CompressionState)

__all__ = ["AdamW", "OptState", "cosine_schedule", "compress_int8",
           "decompress_int8", "CompressionState"]
