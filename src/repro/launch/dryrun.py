import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against ShapeDtypeStruct inputs (no allocation), and capture

* ``compiled.memory_analysis()``  — proves the cell fits per-device HBM,
* ``compiled.cost_analysis()``    — per-device HLO FLOPs / bytes,
* collective bytes parsed from the partitioned HLO (``compiled.as_text()``),

into one JSON per cell under ``--out``.  ``benchmarks/roofline.py`` turns
these into the three-term roofline table.

Loop-body correction: XLA cost analysis counts a ``lax.scan`` (while) body
ONCE regardless of trip count (verified empirically), so each cell also
compiles two small *probe* programs — the same step on a 1-unit and a
2-unit model with the layer loop UNROLLED.  ``B = cost(2u) - cost(u)`` is
the exact per-unit cost and ``F = cost(u) - B`` the layer-independent part;
the corrected totals are ``M * (F + L_units * B)`` (M = gradient-
accumulation microbatches; the optimizer mis-scaling this introduces is
< 1e-5 of step FLOPs, noted in EXPERIMENTS.md).

Run::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
        --shape train_4k --mesh single --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out results/dryrun
"""

import argparse
import dataclasses
import json
import math
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import partition
from repro.configs import registry
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train.trainer import init_state, make_state_axes, make_train_step

HBM_BYTES = 16 * 2**30          # v5e-class: 16 GiB per chip
ACT_BUDGET = 6 * 2**30          # live-activation napkin budget for microbatching


# ---------------------------------------------------------------------------
# Microbatch policy (grad accumulation keeps live activations under budget).
# ---------------------------------------------------------------------------


def dp_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def choose_microbatches(cfg, spec, mesh) -> int:
    if spec.mode != "train":
        return 1
    dp = dp_size(mesh)
    B, S = spec.global_batch, spec.seq_len
    d_eff = max(cfg.d_model, cfg.d_inner if cfg.family == "ssm" else 0,
                cfg.rnn_width_ if cfg.family == "hybrid" else 0)
    # Per-layer live bytes per sequence row under per-layer remat: the saved
    # residual plus scan carries; alpha=2 safety.
    per_row_layer = S * d_eff * 2 * 2
    m = 1
    while True:
        rows_per_chip = max(1, (B // m) // dp)
        live = cfg.n_layers * rows_per_chip * per_row_layer
        if live <= ACT_BUDGET or (B // (2 * m)) % dp != 0 or B // (2 * m) < dp:
            return m
        m *= 2


# ---------------------------------------------------------------------------
# Collective parsing (ring model).
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*(?P<shape>[^=]*?)\s+(?P<op>all-reduce-start|all-gather-start|"
    r"all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute-start|"
    r"collective-permute)\(")
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred|"
                       r"c64|c128)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{\{")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Per-device collective byte accounting from partitioned HLO.

    Returns operand-byte sums per op kind (the prompt's prescription) and a
    ring-model wire-bytes estimate per device."""
    per_op: Dict[str, float] = {}
    wire = 0.0
    operand = 0.0
    count = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        op = m.group("op").replace("-start", "")
        result_bytes = _shape_bytes(m.group("shape"))
        if result_bytes == 0:
            continue
        gi = _GROUPS_IOTA_RE.search(line)
        if gi:
            gsize = int(gi.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            gsize = len(gl.group(1).split(",")) if gl else 1
        n = max(gsize, 1)
        if op == "all-reduce":
            op_bytes = result_bytes
            w = 2.0 * result_bytes * (n - 1) / n
        elif op == "all-gather":
            op_bytes = result_bytes / n          # operand is the local shard
            w = result_bytes * (n - 1) / n
        elif op == "reduce-scatter":
            op_bytes = result_bytes * n          # operand is the full tensor
            w = result_bytes * (n - 1)
        elif op == "all-to-all":
            op_bytes = result_bytes
            w = result_bytes * (n - 1) / n
        else:  # collective-permute
            op_bytes = result_bytes
            w = float(result_bytes)
        per_op[op] = per_op.get(op, 0.0) + op_bytes
        wire += w
        operand += op_bytes
        count += 1
    return {"per_op_operand_bytes": per_op, "operand_bytes": operand,
            "ring_wire_bytes": wire, "n_collectives": count}


# ---------------------------------------------------------------------------
# Cell construction.
# ---------------------------------------------------------------------------


def _probe_cfg(cfg, units: int):
    """A config with ``units`` pattern units of layers (for probes)."""
    if cfg.family == "hybrid":
        n = units * len(cfg.block_pattern)
    else:
        n = units
    kw = dict(n_layers=n)
    if cfg.family == "encdec":
        kw["n_enc_layers"] = units
    return dataclasses.replace(cfg, **kw)


def n_units(cfg) -> float:
    if cfg.family == "hybrid":
        return cfg.n_layers / len(cfg.block_pattern)
    return float(cfg.n_layers)


def _capture_axes(fn):
    """Run ``fn`` (returning (arrays, axes)) under eval_shape; capture axes."""
    box = {}

    def inner(*a):
        out, axes = fn(*a)
        box["axes"] = axes
        return out

    shapes = jax.eval_shape(inner)
    return shapes, box["axes"]


def build_cell(arch: str, shape: str, mesh, *, cfg=None, unroll=False,
               microbatches: Optional[int] = None, rules_kind="fsdp",
               remat=True, extra_rules: Optional[dict] = None,
               batch_rows: Optional[int] = None):
    """Returns (fn, arg_shapes tuple, in_shardings tuple, donate_argnums).

    ``batch_rows`` overrides the global batch (roofline probes run the step
    on exactly one microbatch so the M x (F + L x B) correction scales both
    activation and per-microbatch gradient collectives correctly)."""
    spec = registry.SHAPES[shape]
    cfg = cfg or registry.get_config(arch)
    model = Model(cfg, unroll=unroll)
    rows = batch_rows or spec.global_batch
    if rules_kind == "fsdp":
        rules = partition.fsdp_rules(mesh, rows)
    elif rules_kind == "serve":
        rules = partition.serve_rules(mesh, rows)
    else:
        rules = partition.replicated_rules(mesh, rows)
    if extra_rules:
        rules = partition.Rules(mesh=mesh, table={**rules.table, **extra_rules})

    mb = microbatches if microbatches is not None else \
        choose_microbatches(cfg, spec, mesh)

    inputs = registry.input_specs(arch, shape)
    in_axes = registry.input_logical_axes(arch, shape)
    if batch_rows is not None:
        inputs = {k: jax.ShapeDtypeStruct((rows,) + v.shape[1:], v.dtype)
                  for k, v in inputs.items()}
    batch_sh = {k: rules.sharding(in_axes[k]) for k in inputs}

    params_shapes, param_axes = _capture_axes(
        lambda: model.init(jax.random.key(0)))

    if spec.mode == "train":
        opt = AdamW(learning_rate=cosine_schedule(3e-4, 100, 10_000))
        step = make_train_step(model, opt, microbatches=mb, remat=remat,
                               param_axes=param_axes)
        state_shapes = jax.eval_shape(
            lambda: init_state(model, opt, jax.random.key(0)))
        state_axes = make_state_axes(param_axes)
        state_sh = jax.tree.map(lambda a: rules.sharding(a), state_axes,
                                is_leaf=_is_axes_leaf)
        fn = step
        args = (state_shapes, inputs)
        shardings = (state_sh, batch_sh)
        donate = (0,)
    elif spec.mode == "prefill":
        def fn(params, batch):
            return model.prefill(params, batch, max_seq=spec.seq_len)

        params_sh = jax.tree.map(lambda a: rules.sharding(a), param_axes,
                                 is_leaf=_is_axes_leaf)
        args = (params_shapes, inputs)
        shardings = (params_sh, batch_sh)
        donate = ()
    else:  # decode
        cache_shapes, cache_axes = _capture_axes(
            lambda: model.init_cache(rows, spec.seq_len))
        if rules_kind == "serve":
            # serving stores weights in bf16 (no optimizer on this path)
            params_shapes = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(
                    x.shape, jnp.bfloat16 if x.dtype == jnp.float32
                    else x.dtype), params_shapes)
        params_sh = jax.tree.map(lambda a: rules.sharding(a), param_axes,
                                 is_leaf=_is_axes_leaf)
        cache_sh = jax.tree.map(lambda a: rules.sharding(a), cache_axes,
                                is_leaf=_is_axes_leaf)

        def fn(params, cache, token, pos):
            return model.decode_step(params, cache, token, pos)

        args = (params_shapes, cache_shapes, inputs["token"],
                jax.ShapeDtypeStruct((), jnp.int32))
        shardings = (params_sh, cache_sh, batch_sh["token"],
                     rules.sharding(()))
        donate = (1,)
    return fn, args, shardings, donate, rules, mb


def _is_axes_leaf(x) -> bool:
    return partition.is_axes(x)


# ---------------------------------------------------------------------------
# Lower + compile + capture.
# ---------------------------------------------------------------------------


def compile_cell(arch: str, shape: str, mesh, **kw):
    fn, args, shardings, donate, rules, mb = build_cell(arch, shape, mesh,
                                                        **kw)
    t0 = time.time()
    with partition.use_rules(rules), mesh:
        jitted = jax.jit(fn, in_shardings=shardings,
                         donate_argnums=donate or None)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_total = time.time() - t0
    return compiled, dict(lower_s=round(t_lower, 2),
                          compile_s=round(t_total - t_lower, 2),
                          microbatches=mb)


def capture(compiled) -> Dict[str, Any]:
    ma = compiled.memory_analysis()
    mem = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        mem[f] = int(getattr(ma, f, 0) or 0)
    # Live-bytes estimate: donated outputs alias arguments.
    mem["live_bytes"] = (mem["argument_size_in_bytes"]
                         + mem["temp_size_in_bytes"]
                         + max(0, mem["output_size_in_bytes"]
                               - mem["alias_size_in_bytes"]))
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):         # pre-0.5 jax: one dict per device
        ca = ca[0] if ca else {}
    cost = {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
    text = compiled.as_text()
    coll = parse_collectives(text)
    return {"memory": mem, "cost": cost, "collectives": coll,
            "hlo_chars": len(text)}


def hbm_napkin(cfg, spec, mesh, mb: int) -> Dict[str, float]:
    """Analytic per-chip HBM budget (bytes) for the TPU target.

    The CPU backend's ``temp_size`` includes an f32 round-trip of the remat
    stash introduced by CPU fusion of dynamic-update-slice (verified on
    qwen2-72b: the while carry itself is bf16); the napkin is the
    TPU-expected budget and both are reported."""
    chips = math.prod(mesh.shape.values())
    dp = dp_size(mesh)
    params = cfg.param_count()
    p_bytes = params * 4 / chips              # f32 master, fully sharded
    opt_bytes = 2 * p_bytes                   # adam m, v
    grad_bytes = params * 4 / chips
    out = {"params": p_bytes, "opt": opt_bytes}
    if spec.mode == "train":
        rows = max(1, (spec.global_batch // mb) // dp)
        d_eff = max(cfg.d_model, cfg.d_inner if cfg.family == "ssm" else 0,
                    cfg.rnn_width_ if cfg.family == "hybrid" else 0)
        stash = cfg.n_layers * rows * spec.seq_len * cfg.d_model * 2
        out.update(grads=grad_bytes, remat_stash=stash,
                   layer_transient=rows * spec.seq_len * d_eff * 2 * 8)
    elif spec.mode == "decode":
        rows = max(1, spec.global_batch // dp)
        model_shards = mesh.shape.get("model", 1)
        if cfg.family == "ssm":
            cache = cfg.n_layers * rows * (
                cfg.n_ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
                + (cfg.conv_width - 1) * (cfg.d_inner + 2 * cfg.ssm_state) * 2)
        else:
            w = min(spec.seq_len, cfg.sliding_window or spec.seq_len)
            cache = (cfg.n_layers * rows * (w / model_shards)
                     * cfg.n_kv_heads * cfg.head_dim_ * 2 * 2)
        out["kv_cache"] = cache
    else:  # prefill
        rows = max(1, spec.global_batch // dp)
        out["activations"] = rows * spec.seq_len * cfg.d_model * 2 * 8
        model_shards = mesh.shape.get("model", 1)
        out["kv_cache_out"] = (cfg.n_layers * rows
                               * (spec.seq_len / model_shards)
                               * cfg.n_kv_heads * cfg.head_dim_ * 2 * 2)
    out["total"] = float(sum(out.values()))
    return out


def run_cell(arch: str, shape: str, mesh_kind: str, *, probes=True,
             out_dir: Optional[str] = None, microbatches=None,
             rules_kind="fsdp", tag="baseline", extra_rules=None,
             remat=True) -> Dict[str, Any]:
    spec = registry.SHAPES[shape]
    cfg = registry.get_config(arch)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec: Dict[str, Any] = dict(arch=arch, shape=shape, mesh=mesh_kind,
                               mode=spec.mode, tag=tag, ok=False)
    try:
        compiled, meta = compile_cell(arch, shape, mesh,
                                      microbatches=microbatches,
                                      rules_kind=rules_kind,
                                      extra_rules=extra_rules, remat=remat)
        rec.update(meta)
        rec["full"] = capture(compiled)
        rec["hbm_napkin"] = hbm_napkin(cfg, spec, mesh, rec["microbatches"])
        del compiled
        rec["ok"] = True

        if probes:
            pr = {}
            mb_real = rec.get("microbatches", 1)
            rows = spec.global_batch // mb_real
            for units in (1, 2):
                pcfg = _probe_cfg(cfg, units)
                # Probe = one microbatch of the real step, layers unrolled.
                c, _ = compile_cell(arch, shape, mesh, cfg=pcfg, unroll=True,
                                    microbatches=1, batch_rows=rows,
                                    rules_kind=rules_kind,
                                    extra_rules=extra_rules, remat=remat)
                pr[f"u{units}"] = capture(c)
                del c
            rec["probes"] = pr
            rec["corrected"] = correct(rec, cfg)
    except Exception as e:  # noqa: BLE001 — record the failure verbatim
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}__{tag}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def correct(rec: Dict[str, Any], cfg) -> Dict[str, Any]:
    """Loop-body-corrected totals: M * (F + L_units * B) per metric."""
    u1, u2 = rec["probes"]["u1"], rec["probes"]["u2"]
    L = n_units(cfg)
    M = rec.get("microbatches", 1)
    out = {}
    for key, get in (
            ("flops", lambda c: c["cost"]["flops"]),
            ("bytes_accessed", lambda c: c["cost"]["bytes_accessed"]),
            ("collective_operand_bytes",
             lambda c: c["collectives"]["operand_bytes"]),
            ("collective_wire_bytes",
             lambda c: c["collectives"]["ring_wire_bytes"])):
        b = get(u2) - get(u1)
        f = get(u1) - b
        out[key] = M * (f + L * b)
        out[key + "_per_unit"] = b
        out[key + "_fixed"] = f
    return out


# ---------------------------------------------------------------------------
# CLI.
# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--rules", default="fsdp")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--remat", default="on", choices=["on", "off"])
    args = ap.parse_args()

    if args.list:
        for a, s in registry.list_cells():
            print(f"{a:24s} {s}")
        return

    cells = registry.list_cells() if args.all else [(args.arch, args.shape)]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for arch, shape in cells:
        reason = registry.cell_skip_reason(arch, shape)
        if reason:
            print(f"SKIP {arch}/{shape}: {reason}")
            continue
        for mk in meshes:
            t0 = time.time()
            rec = run_cell(arch, shape, mk, probes=not args.no_probes,
                           out_dir=args.out, microbatches=args.microbatches,
                           rules_kind=args.rules, tag=args.tag,
                           remat=(args.remat == "on"))
            status = "OK " if rec["ok"] else "FAIL"
            dt = time.time() - t0
            if rec["ok"]:
                mem = rec["full"]["memory"]
                per_dev = mem["live_bytes"] / 2**30
                print(f"{status} {arch}/{shape}/{mk} mb={rec['microbatches']} "
                      f"mem/dev={per_dev:.2f}GiB "
                      f"flops={rec['full']['cost']['flops']:.3g} "
                      f"coll={rec['full']['collectives']['n_collectives']} "
                      f"({dt:.0f}s)", flush=True)
            else:
                print(f"{status} {arch}/{shape}/{mk}: {rec['error']} "
                      f"({dt:.0f}s)", flush=True)


if __name__ == "__main__":
    main()
