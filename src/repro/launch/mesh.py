"""Production mesh builders.

All builders are FUNCTIONS (not module-level constants) so importing this
module never touches jax device state — smoke tests keep seeing 1 CPU
device; only the dry-run process forces 512 host devices.
"""

from __future__ import annotations

import math

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5 exposes explicit axis types; older versions have none.
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _mesh(shape, axes):
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devs)} — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_"
            "count=512 before importing jax")
    kwargs = {}
    if AxisType is not None:
        kwargs["axis_types"] = (AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, devices=devs[:n], **kwargs)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """A small mesh over whatever devices exist (tests / examples)."""
    return _mesh((data, model), ("data", "model"))
