"""Launchers: production mesh builders, the multi-pod dry-run, and the
train/serve drivers."""
