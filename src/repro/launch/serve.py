"""Serving launcher: batched prefill + decode with a continuous-batching
style slot scheduler.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m \
        --preset smoke --requests 8 --gen 32

The server keeps a fixed batch of decode slots; finished requests free
their slot and the next queued request is prefilled into it.  On the
production mesh the decode step is the same ``Model.decode_step`` the
dry-run compiles (seq-sharded KV caches over the model axis).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro import partition
from repro.configs import registry
from repro.launch.mesh import make_host_mesh
from repro.launch.train import preset_config
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S0] int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    """Single-model batch server (greedy decoding)."""

    def __init__(self, model: Model, params, batch_slots: int,
                 max_seq: int):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self._decode = jax.jit(model.decode_step, donate_argnums=1)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_seq=max_seq))

    def run(self, requests: List[Request]) -> dict:
        """Static batch: prefill all (padded to one length), decode until
        every request hits its token budget."""
        model, cfg = self.model, self.model.cfg
        B = len(requests)
        s0 = max(len(r.prompt) for r in requests)
        toks = np.zeros((B, s0), np.int32)
        for i, r in enumerate(requests):
            toks[i, s0 - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((B, cfg.n_frames, cfg.d_model),
                                        jnp.bfloat16)
        t0 = time.time()
        logits, cache = self._prefill(self.params, batch)
        prefill_s = time.time() - t0
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        max_new = max(r.max_new for r in requests)
        t0 = time.time()
        for t in range(max_new):
            for i, r in enumerate(requests):
                if t < r.max_new:
                    r.out.append(int(nxt[i]))
            pos = jnp.asarray(s0 + t, jnp.int32)
            logits, cache = self._decode(self.params, cache, nxt, pos)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        decode_s = time.time() - t0
        new_tokens = sum(len(r.out) for r in requests)
        return {"prefill_s": prefill_s, "decode_s": decode_s,
                "new_tokens": new_tokens,
                "tok_per_s": new_tokens / max(decode_s, 1e-9)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m",
                    choices=list(registry.ARCHS))
    ap.add_argument("--preset", default="smoke",
                    choices=["smoke", "100m", "full"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = preset_config(args.arch, args.preset)
    model = Model(cfg)
    mesh = make_host_mesh(data=1, model=len(jax.devices()))
    rules = partition.fsdp_rules(mesh, args.requests)
    rng = np.random.default_rng(args.seed)
    with partition.use_rules(rules), mesh:
        params, _ = model.init(jax.random.key(args.seed))
        srv = Server(model, params, args.requests,
                     max_seq=args.prompt_len + args.gen + 8)
        reqs = [Request(rid=i,
                        prompt=rng.integers(1, cfg.vocab_size,
                                            args.prompt_len).astype(np.int32),
                        max_new=args.gen)
                for i in range(args.requests)]
        stats = srv.run(reqs)
    print(json.dumps({"arch": cfg.name, **{k: (round(v, 4) if
          isinstance(v, float) else v) for k, v in stats.items()}}))
    return stats


if __name__ == "__main__":
    main()
