"""Training launcher.

Runs a real training job on the host devices (examples / CI) with the same
stack the dry-run lowers for the production meshes: Model + AdamW +
grad-accumulation train step + fault-tolerant loop + sharded checkpoints.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-370m \
        --preset smoke --steps 50 --batch 8 --seq 128

``--preset full`` uses the assigned config verbatim (for TPU fleets);
``--preset smoke`` reduces it to CPU scale; ``--preset 100m`` targets a
~100M-parameter same-family config (examples/train_100m.py).
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import numpy as np

from repro import partition
from repro.configs import registry
from repro.data.pipeline import SyntheticLMData
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train.loop import LoopConfig, run_loop
from repro.train.trainer import init_state, make_train_step


def preset_config(arch: str, preset: str):
    cfg = registry.get_config(arch)
    if preset == "full":
        return cfg
    if preset == "smoke":
        return cfg.reduced()
    if preset == "100m":
        # ~100M params, same family: scale width/depth down.
        return dataclasses.replace(
            cfg.reduced(), name=cfg.name + "-100m",
            n_layers=max(4, min(cfg.n_layers, 8)),
            d_model=512, n_heads=8, n_kv_heads=min(cfg.n_kv_heads, 4),
            head_dim=64, d_ff=1408 if not cfg.n_experts else 512,
            vocab_size=32_000,
            ssm_state=64 if cfg.ssm_state else 0,
            rnn_width=512 if cfg.rnn_width else None)
    raise ValueError(preset)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m",
                    choices=list(registry.ARCHS))
    ap.add_argument("--preset", default="smoke",
                    choices=["smoke", "100m", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--data", default="succ", choices=["succ", "copy", "zipf"])
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args(argv)

    cfg = preset_config(args.arch, args.preset)
    model = Model(cfg)
    mesh = make_host_mesh(data=len(jax.devices()), model=1)
    rules = partition.fsdp_rules(mesh, args.batch)

    opt = AdamW(learning_rate=cosine_schedule(args.lr, 20, args.steps))
    data = SyntheticLMData.for_config(cfg, args.seq, args.batch,
                                      seed=args.seed, mode=args.data)

    with partition.use_rules(rules), mesh:
        state = init_state(model, opt, jax.random.key(args.seed))
        step = jax.jit(make_train_step(
            model, opt, microbatches=args.microbatches,
            param_axes=model.param_axes(),
            compress_grads=args.compress_grads), donate_argnums=0)

        out = run_loop(step, state, data, LoopConfig(
            total_steps=args.steps,
            checkpoint_every=args.checkpoint_every,
            checkpoint_dir=args.checkpoint_dir,
            metrics_path=args.metrics))
    losses = out["losses"]
    print(json.dumps({
        "arch": cfg.name, "steps": out["final_step"],
        "first_loss": losses[0] if losses else None,
        "last_loss": float(np.mean(losses[-5:])) if losses else None,
        "stragglers": out["stragglers"], "recoveries": out["recoveries"],
    }))
    return out


if __name__ == "__main__":
    main()
