"""Pallas TPU kernels for the framework's compute hot-spots.

* ``dvfs_opt``        — batched single-task DVFS optimum (the scheduler's
                        per-slot Phi solve; the paper's own hot loop),
* ``flash_attention`` — blockwise attention (prefill/training),
* ``ssd_scan``        — Mamba2 SSD chunked scan.

``ops`` holds the jit'd public wrappers (interpret=True on CPU); ``ref``
holds the pure-jnp oracles used by tests/test_kernels.py.
``default_interpret`` is the one interpret-mode policy every kernel call
site shares (CPU containers interpret, TPU hosts compile).
"""

from repro.kernels import ops, ref
from repro.kernels.ops import default_interpret

__all__ = ["ops", "ref", "default_interpret"]
