"""Pallas TPU kernels for the framework's compute hot-spots.

* ``dvfs_opt``        — batched single-task DVFS optimum (the scheduler's
                        per-slot Phi solve; the paper's own hot loop),
* ``flash_attention`` — blockwise attention (prefill/training),
* ``ssd_scan``        — Mamba2 SSD chunked scan.

``ops`` holds the jit'd public wrappers (interpret=True on CPU); ``ref``
holds the pure-jnp oracles used by tests/test_kernels.py.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
