"""Pallas TPU flash attention (prefill/training hot-spot).

Canonical TPU tiling: grid ``(B, H, n_q_blocks, n_kv_blocks)`` with the
minor-most (kv) axis executed sequentially per core so the running-softmax
state lives in VMEM scratch across kv steps:

* q block   ``(1, 1, bq, dh)``  — revisited for every kv step,
* k/v block ``(1, 1, bk, dh)``  — GQA maps q-head h to kv-head ``h // g``
  in the BlockSpec index map (no materialized head broadcast),
* scratch   ``m, l [bq]``, ``acc [bq, dh]`` (f32).

Matmul dims are MXU-aligned (bq = bk = 128 defaults, dh padded to 128 by
the wrapper in ``ops.py``).  Causal masking is done per-block; fully-masked
blocks short-circuit with ``pl.when`` so they cost no MXU work.

Validated in ``interpret=True`` mode against ``ref.attention_ref`` over
shape/dtype sweeps (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, causal: bool, window: Optional[int],
            scale: float, n_kv: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = iq * bq
    k_lo = ik * bk
    # Static-shape block skip test must be dynamic (program ids are traced):
    # a block is live unless causal-above-diagonal or outside the window.
    live = jnp.bool_(True)
    if causal:
        live = jnp.logical_and(live, k_lo <= q_lo + bq - 1)
    if window is not None:
        live = jnp.logical_and(live, k_lo + bk - 1 >= q_lo - window + 1)

    @pl.when(live)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # [bq, dh]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, dh]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        if window is not None:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: [B, H, Sq, dh]; k/v: [B, KV, Sk, dh] -> [B, H, Sq, dh].

    ``dh`` should be 128-aligned for the MXU (the ops.py wrapper pads)."""
    B, H, Sq, dh = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    g = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    scale = dh ** -0.5

    kernel = functools.partial(_kernel, bq=bq, bk=bk, causal=causal,
                               window=window, scale=scale, n_kv=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
