"""Pallas TPU kernel for the batched single-task DVFS optimum (paper §4.1).

This is the scheduler's own hot-spot Φ: at every online time slot the
cluster solves ``argmin E(V, fc, fm)`` for every newly-arrived task
(Algorithm 1/5) — thousands of independent 2-variable minimizations, and
with heterogeneous machine classes one such solve per task **per class**.
The kernel evaluates the energy surface for a block of tasks over a dense
frequency grid entirely in VMEM and reduces the argmin, fusing what would
otherwise be a dozen HBM round-trips per task into one.

Layout: tasks are a [n, 16] f32 matrix
    (p0, γ, c, D, δ, t0, allowed, readjust,
     v_min, v_max, fc_min, fm_min, fm_max, pad, pad, pad);
block = (BT=128 tasks, G=128 grid points) — an (8,128)-aligned VPU tile.
Columns 8-12 carry the row's own :class:`ScalingInterval` bounds, which is
what lets one ``pallas_call`` solve a class-stacked ``[C*n, 16]`` matrix
where every class block has a different DVFS box (see
``repro.core.machines.configure_classes``).  The legacy ``[n, 8]`` layout
(homogeneous interval) is widened on entry from the static ``interval``
argument.

Two grid sweeps per task block, matching the paper's case split:

* unconstrained: fc-grid over [fc_min, g1(v_max)]; V = max(v_min, g1⁻¹(fc));
  fm = closed-form optimum clamped to the box (paper §4.1);
* deadline boundary: fm-grid; fc from t(fc, fm) = allowed (§4.1 deadline-
  prior case); +inf energy where infeasible.

The winner per task replicates exactly the decision rule of
``repro.core.single_task.solve_with_deadline`` (the pure-jnp oracle in
``ref.py``) up to grid resolution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.dvfs import G1_A, G1_B, G1_C, ScalingInterval, WIDE

BT = 128   # tasks per block
G = 128    # grid points per sweep
NCOL = 16  # task-matrix columns (6 params, allowed, readjust, 5 bounds, pad)
INF = 1e30


def _g1(v):
    return jnp.sqrt(jnp.maximum(v - G1_A, 0.0) / G1_B) + G1_C


def _g1_inv(fc):
    return G1_B * jnp.square(jnp.maximum(fc - G1_C, 0.0)) + G1_A


def _kernel(tasks_ref, out_ref):
    t = tasks_ref[...].astype(jnp.float32)               # [BT, 16]
    p0, gamma, cc = t[:, 0:1], t[:, 1:2], t[:, 2:3]
    dd, delta, t0 = t[:, 3:4], t[:, 4:5], t[:, 5:6]
    allowed = t[:, 6:7]
    readjust = t[:, 7] > 0.5   # theta-readjustment rows: boundary binds
    # Per-row scaling-interval bounds (columns 8-12), shape [BT, 1].
    v_min, v_max = t[:, 8:9], t[:, 9:10]
    fc_min, fm_min, fm_max = t[:, 10:11], t[:, 11:12], t[:, 12:13]

    frac = jax.lax.broadcasted_iota(jnp.float32, (BT, G), 1) / (G - 1)

    def energy_at(v, fc, fm):
        pw = p0 + gamma * fm + cc * jnp.square(v) * fc
        tt = dd * (delta / fc + (1.0 - delta) / fm) + t0
        return pw * tt, pw, tt

    # ---- sweep 1: unconstrained, fc grid on [fc_min, g1(v_max)].
    fc_max = _g1(v_max)                                  # [BT, 1]
    fc = fc_min + (fc_max - fc_min) * frac               # [BT, G]
    v = jnp.maximum(v_min, _g1_inv(fc))
    # closed-form fm (paper §4.1), clamped; gamma == 0 -> fm_max.
    num = (p0 + cc * jnp.square(v) * fc) * dd * (1.0 - delta)
    den = gamma * (t0 + dd * delta / fc)
    fm = jnp.sqrt(num / jnp.maximum(den, 1e-30))
    fm = jnp.where(gamma <= 0.0, fm_max, fm)
    fm = jnp.clip(fm, fm_min, fm_max)
    e_u, _, t_u = energy_at(v, fc, fm)
    iu = jnp.argmin(e_u, axis=1)                          # [BT]
    rows = jnp.arange(BT)
    fc_u = fc[rows, iu]
    v_u = v[rows, iu]
    fm_u = fm[rows, iu]
    t_un = t_u[rows, iu]

    # ---- sweep 2: deadline boundary t(fc, fm) = allowed, fm grid.
    fm2 = fm_min + (fm_max - fm_min) * frac
    slack = allowed - t0 - dd * (1.0 - delta) / fm2
    fc_req = dd * delta / jnp.maximum(slack, 1e-30)
    fc_req = jnp.where(delta <= 0.0, fc_min, fc_req)
    bad = (slack <= 0.0) & (delta > 0.0)
    fc2 = jnp.clip(fc_req, fc_min, fc_max)
    v2 = jnp.maximum(v_min, _g1_inv(fc2))
    e_d, _, t_d = energy_at(v2, fc2, fm2)
    e_d = jnp.where(bad | (fc_req > fc_max + 1e-6), INF, e_d)
    idx = jnp.argmin(e_d, axis=1)
    fc_d = fc2[rows, idx]
    v_d = v2[rows, idx]
    fm_d = fm2[rows, idx]

    # ---- decision rule (== solve_with_deadline / solve_on_boundary):
    # energy-prior if the unconstrained optimum meets the deadline;
    # readjust rows shrank their window below the optimum, so the boundary
    # binds by construction; infeasible (deadline < t_min) -> max speed.
    energy_prior = (t_un <= allowed[:, 0] + 1e-6) & ~readjust
    t_min = (dd * (delta / fc_max + (1.0 - delta) / fm_max) + t0)[:, 0]
    feasible = allowed[:, 0] >= t_min - 1e-6
    v_mx = v_max[:, 0]
    fc_mx = fc_max[:, 0]
    fm_mx = fm_max[:, 0]

    def pick(unc, con, mx):
        x = jnp.where(energy_prior, unc, con)
        return jnp.where(feasible, x, mx)

    vf = pick(v_u, v_d, v_mx)
    fcf = pick(fc_u, fc_d, fc_mx)
    fmf = pick(fm_u, fm_d, fm_mx)
    pw = (p0[:, 0] + gamma[:, 0] * fmf + cc[:, 0] * jnp.square(vf) * fcf)
    tt = dd[:, 0] * (delta[:, 0] / fcf + (1.0 - delta[:, 0]) / fmf) + t0[:, 0]
    tt = jnp.where(feasible & ~energy_prior, jnp.minimum(tt, allowed[:, 0]), tt)

    out = jnp.stack([vf, fcf, fmf, tt, pw, pw * tt,
                     (~energy_prior).astype(jnp.float32),
                     feasible.astype(jnp.float32)], axis=1)   # [BT, 8]
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interval", "interpret"))
def dvfs_solve_kernel(tasks: jax.Array, *, interval: ScalingInterval = WIDE,
                      interpret: bool = False) -> jax.Array:
    """tasks: [n, 8] or [n, 16] f32 (see module docstring) ->
    [n, 8] (v, fc, fm, t, p, e, deadline_prior, feasible).

    An 8-column matrix is widened with the static ``interval``'s bounds
    (the homogeneous legacy layout); a 16-column matrix carries per-row
    bounds and ignores ``interval``.
    """
    n = tasks.shape[0]
    if tasks.shape[1] == 8:
        bounds = jnp.broadcast_to(
            jnp.asarray(interval.bounds(), tasks.dtype), (n, 5))
        pad = jnp.zeros((n, NCOL - 8 - 5), tasks.dtype)
        tasks = jnp.concatenate([tasks, bounds, pad], axis=1)
    elif tasks.shape[1] != NCOL:
        raise ValueError(f"task matrix must have 8 or {NCOL} columns, "
                         f"got {tasks.shape[1]}")
    n_pad = -(-n // BT) * BT
    if n_pad != n:
        pad = jnp.ones((n_pad - n, NCOL), tasks.dtype)  # benign dummy tasks
        tasks = jnp.concatenate([tasks, pad], axis=0)
    out = pl.pallas_call(
        _kernel,
        grid=(n_pad // BT,),
        in_specs=[pl.BlockSpec((BT, NCOL), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BT, 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 8), jnp.float32),
        interpret=interpret,
    )(tasks.astype(jnp.float32))
    return out[:n]
