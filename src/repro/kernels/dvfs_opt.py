"""Pallas TPU kernel for the batched single-task DVFS optimum (paper §4.1).

This is the scheduler's own hot-spot Φ: at every online time slot the
cluster solves ``argmin E(V, fc, fm)`` for every newly-arrived task
(Algorithm 1/5) — thousands of independent 2-variable minimizations, and
with heterogeneous machine classes one such solve per task **per class**.
The kernel evaluates the energy surface for a block of tasks over a
hierarchically refined frequency grid entirely in VMEM and reduces the
argmin, fusing what would otherwise be a dozen HBM round-trips per task
into one.

Layout: tasks are a [n, NCOL=16] f32 matrix whose columns are declared
once in :mod:`repro.kernels.layout`
    (P0, GAMMA, C_COEF, BIG_D, DELTA, T0, ALLOWED, READJUST,
     V_MIN, V_MAX, FC_MIN, FM_MIN, FM_MAX, pad, pad, pad);
block = BT=128 tasks per VPU tile row.
The ``BOUNDS_SLICE`` columns carry the row's own :class:`ScalingInterval`
bounds, which is what lets one ``pallas_call`` solve a class-stacked
``[C*n, 16]`` matrix where every class block has a different DVFS box (see
``repro.core.machines.configure_classes``).  The legacy
``[n, LEGACY_NCOL=8]`` layout (homogeneous interval) is widened on entry
from the static ``interval`` argument.

Each of the two 1-D sweeps is **hierarchical** (``grid=(G0, G1)`` static
args, default ``(64, 64)``): a coarse pass over ``G0`` equispaced points
brackets the argmin, then a fine pass re-sweeps ``G1`` points inside the
``±1``-coarse-step bracket — ~``G0·G1/2`` effective resolution for
``G0+G1`` evaluations, i.e. the same evaluation budget as the old flat
128-point sweep but ~16x the resolution.  The fine winner is guarded
against the coarse winner (finer grids can never *increase* the energy),
mirroring the coarse-grid-then-golden-refinement structure of the
production jnp solver (``single_task._grid_then_golden``, the ``ref.py``
oracle).

The two sweeps match the paper's case split:

* unconstrained: fc-grid over [fc_min, g1(v_max)]; V = max(v_min, g1⁻¹(fc));
  fm = closed-form optimum clamped to the box (paper §4.1);
* deadline boundary: fm-grid; fc from t(fc, fm) = allowed (§4.1 deadline-
  prior case); +inf energy where infeasible.

The winner per task replicates exactly the decision rule of
``repro.core.single_task.solve_with_deadline`` (the pure-jnp oracle in
``ref.py``) up to grid resolution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.dvfs import G1_A, G1_B, G1_C, ScalingInterval, WIDE
from repro.kernels.layout import (ALLOWED, BIG_D, C_COEF, DELTA, FC_MIN,
                                  FM_MAX, FM_MIN, GAMMA, KEY_COLS,
                                  LEGACY_NCOL, N_BOUNDS, NCOL, P0, READJUST,
                                  SOL_COLS, T0, V_MAX, V_MIN, col)

BT = 128   # tasks per block
DEFAULT_GRID = (64, 64)  # (coarse, fine) sweep points; ~16x the old flat-128
INF = 1e30

#: A benign, fully-feasible pad task: reference-ish constants on the WIDE
#: box with a huge deadline window, so pad rows always take the smooth
#: energy-prior branch.  (The old ``jnp.ones`` pad encoded the degenerate
#: box v_min=v_max=fc_min=fm_min=fm_max=1, which pushed every pad row
#: through the INF-masked deadline-boundary sweep.)
PAD_ROW = np.asarray(
    [[1.0, 1.0, 1.0, 1.0, 0.5, 0.1, 1e6, 0.0, *WIDE.bounds(), 0.0, 0.0, 0.0]],
    np.float32)
assert PAD_ROW.shape == (1, NCOL)


def _g1(v):
    return jnp.sqrt(jnp.maximum(v - G1_A, 0.0) / G1_B) + G1_C


def _g1_inv(fc):
    return G1_B * jnp.square(jnp.maximum(fc - G1_C, 0.0)) + G1_A


def _hier_argmin(efn, rows, g0: int, g1: int):
    """Coarse-then-fine argmin of ``efn`` over the unit interval.

    ``efn`` maps a fraction array ``[BT, k]`` to energies ``[BT, k]``.
    Sweeps ``g0`` coarse points, brackets the winner one coarse step to
    each side, re-sweeps ``g1`` fine points inside the bracket, and
    returns the per-row winning fraction ``[BT]`` — guarded so the fine
    winner is never worse than the coarse one (refinement is monotone).
    """
    f0 = jax.lax.broadcasted_iota(jnp.float32, (BT, g0), 1) / (g0 - 1)
    e0 = efn(f0)
    i0 = jnp.argmin(e0, axis=1)
    e0_best = e0[rows, i0]
    f0_best = f0[rows, i0]
    step = 1.0 / (g0 - 1)
    f_lo = jnp.clip((i0.astype(jnp.float32) - 1.0) * step, 0.0, 1.0)
    f_hi = jnp.clip((i0.astype(jnp.float32) + 1.0) * step, 0.0, 1.0)
    frac = jax.lax.broadcasted_iota(jnp.float32, (BT, g1), 1) / (g1 - 1)
    f1 = f_lo[:, None] + (f_hi - f_lo)[:, None] * frac
    e1 = efn(f1)
    i1 = jnp.argmin(e1, axis=1)
    e1_best = e1[rows, i1]
    f1_best = f1[rows, i1]
    return jnp.where(e1_best <= e0_best, f1_best, f0_best)


def _sq(x):
    """``[BT, 1] -> [BT]`` squeeze (a shape op, not a schema column read)."""
    return jnp.squeeze(x, axis=1)


def _kernel(tasks_ref, out_ref, *, g0: int, g1: int):
    t = tasks_ref[...].astype(jnp.float32)               # [BT, NCOL]
    p0, gamma, cc = t[:, col(P0)], t[:, col(GAMMA)], t[:, col(C_COEF)]
    dd, delta, t0 = t[:, col(BIG_D)], t[:, col(DELTA)], t[:, col(T0)]
    allowed = t[:, col(ALLOWED)]
    readjust = t[:, READJUST] > 0.5  # theta-readjustment rows: boundary binds
    # Per-row scaling-interval bounds, shape [BT, 1].
    v_min, v_max = t[:, col(V_MIN)], t[:, col(V_MAX)]
    fc_min, fm_min, fm_max = (t[:, col(FC_MIN)], t[:, col(FM_MIN)],
                              t[:, col(FM_MAX)])
    rows = jnp.arange(BT)

    def energy_at(v, fc, fm):
        pw = p0 + gamma * fm + cc * jnp.square(v) * fc
        tt = dd * (delta / fc + (1.0 - delta) / fm) + t0
        return pw * tt, pw, tt

    # ---- sweep 1: unconstrained, fc grid on [fc_min, g1(v_max)].
    fc_max = _g1(v_max)                                  # [BT, 1]

    def unc_at(frac):
        """frac [BT, k] -> (energy, (v, fc, fm, t)) on the optimal-V /
        closed-form-fm manifold (paper §4.1)."""
        fc = fc_min + (fc_max - fc_min) * frac           # [BT, k]
        v = jnp.maximum(v_min, _g1_inv(fc))
        # closed-form fm (paper §4.1), clamped; gamma == 0 -> fm_max.
        num = (p0 + cc * jnp.square(v) * fc) * dd * (1.0 - delta)
        den = gamma * (t0 + dd * delta / fc)
        fm = jnp.sqrt(num / jnp.maximum(den, 1e-30))
        fm = jnp.where(gamma <= 0.0, fm_max, fm)
        fm = jnp.clip(fm, fm_min, fm_max)
        e, _, tt = energy_at(v, fc, fm)
        return e, (v, fc, fm, tt)

    fu = _hier_argmin(lambda f: unc_at(f)[0], rows, g0, g1)
    _, (v_1, fc_1, fm_1, t_1) = unc_at(fu[:, None])      # [BT, 1] at winner
    v_u, fc_u, fm_u, t_un = _sq(v_1), _sq(fc_1), _sq(fm_1), _sq(t_1)

    # ---- sweep 2: deadline boundary t(fc, fm) = allowed, fm grid.
    def bnd_at(frac):
        """frac [BT, k] -> (energy, (v, fc, fm)) on the t = allowed
        manifold; infeasible points get +INF."""
        fm2 = fm_min + (fm_max - fm_min) * frac
        slack = allowed - t0 - dd * (1.0 - delta) / fm2
        fc_req = dd * delta / jnp.maximum(slack, 1e-30)
        fc_req = jnp.where(delta <= 0.0, fc_min, fc_req)
        bad = (slack <= 0.0) & (delta > 0.0)
        fc2 = jnp.clip(fc_req, fc_min, fc_max)
        v2 = jnp.maximum(v_min, _g1_inv(fc2))
        e, _, _ = energy_at(v2, fc2, fm2)
        e = jnp.where(bad | (fc_req > fc_max + 1e-6), INF, e)
        return e, (v2, fc2, fm2)

    fb = _hier_argmin(lambda f: bnd_at(f)[0], rows, g0, g1)
    _, (v_2, fc_2, fm_2) = bnd_at(fb[:, None])
    v_d, fc_d, fm_d = _sq(v_2), _sq(fc_2), _sq(fm_2)

    # ---- decision rule (== solve_with_deadline / solve_on_boundary):
    # energy-prior if the unconstrained optimum meets the deadline;
    # readjust rows shrank their window below the optimum, so the boundary
    # binds by construction; infeasible (deadline < t_min) -> max speed.
    allowed1 = _sq(allowed)
    energy_prior = (t_un <= allowed1 + 1e-6) & ~readjust
    t_min = _sq(dd * (delta / fc_max + (1.0 - delta) / fm_max) + t0)
    feasible = allowed1 >= t_min - 1e-6
    v_mx = _sq(v_max)
    fc_mx = _sq(fc_max)
    fm_mx = _sq(fm_max)

    def pick(unc, con, mx):
        x = jnp.where(energy_prior, unc, con)
        return jnp.where(feasible, x, mx)

    vf = pick(v_u, v_d, v_mx)
    fcf = pick(fc_u, fc_d, fc_mx)
    fmf = pick(fm_u, fm_d, fm_mx)
    pw = _sq(p0) + _sq(gamma) * fmf + _sq(cc) * jnp.square(vf) * fcf
    tt = _sq(dd) * (_sq(delta) / fcf + (1.0 - _sq(delta)) / fmf) + _sq(t0)
    tt = jnp.where(feasible & ~energy_prior, jnp.minimum(tt, allowed1), tt)

    # [BT, SOL_COLS] in layout.SOL_* column order.
    out = jnp.stack([vf, fcf, fmf, tt, pw, pw * tt,
                     (~energy_prior).astype(jnp.float32),
                     feasible.astype(jnp.float32)], axis=1)
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interval", "grid", "interpret"))
def dvfs_solve_kernel(tasks: jax.Array, *, interval: ScalingInterval = WIDE,
                      grid: tuple = DEFAULT_GRID,
                      interpret: bool = False) -> jax.Array:
    """tasks: [n, 8] or [n, 16] f32 (see module docstring) ->
    [n, 8] (v, fc, fm, t, p, e, deadline_prior, feasible).

    An 8-column matrix is widened with the static ``interval``'s bounds
    (the homogeneous legacy layout); a 16-column matrix carries per-row
    bounds and ignores ``interval``.  ``grid=(G0, G1)`` sets the coarse /
    fine sweep sizes of the hierarchical refinement (both >= 2); the
    effective resolution of each 1-D sweep is ~``G0*G1/2`` points for
    ``G0 + G1`` evaluations.
    """
    g0, g1 = int(grid[0]), int(grid[1])
    if g0 < 2 or g1 < 2:
        raise ValueError(f"grid sizes must be >= 2, got {grid}")
    n = tasks.shape[0]
    if tasks.shape[1] == LEGACY_NCOL:
        bounds = jnp.broadcast_to(
            jnp.asarray(interval.bounds(), tasks.dtype), (n, N_BOUNDS))
        pad = jnp.zeros((n, NCOL - KEY_COLS), tasks.dtype)
        tasks = jnp.concatenate([tasks, bounds, pad], axis=1)
    elif tasks.shape[1] != NCOL:
        raise ValueError(f"task matrix must have {LEGACY_NCOL} or {NCOL} "
                         f"columns, got {tasks.shape[1]}")
    n_pad = -(-n // BT) * BT
    if n_pad != n:
        pad = jnp.broadcast_to(jnp.asarray(PAD_ROW, tasks.dtype),
                               (n_pad - n, NCOL))
        tasks = jnp.concatenate([tasks, pad], axis=0)
    out = pl.pallas_call(
        functools.partial(_kernel, g0=g0, g1=g1),
        grid=(n_pad // BT,),
        in_specs=[pl.BlockSpec((BT, NCOL), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BT, SOL_COLS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, SOL_COLS), jnp.float32),
        interpret=interpret,
    )(tasks.astype(jnp.float32))
    return out[:n]
