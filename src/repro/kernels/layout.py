"""THE declared column schema of the DVFS solver matrices.

Three hand-synchronized matrix layouts flow through the solver stack —
the ``[n, NCOL]`` Pallas *task* matrix (:mod:`repro.kernels.dvfs_opt`),
the ``[n, KEY_COLS]`` solver-cache *key* matrix
(:mod:`repro.core.solver_cache`, = task columns ``0..KEY_COLS-1``) and
the ``[n, SOL_COLS]`` *solution* matrix every solver returns.  This
module is the single place their column meanings are declared; every
other module indexes them through these names, and the repo lint
(``python -m tools.lint``, rule family ``matrix-schema``) flags raw
integer column indices anywhere else so the three layouts cannot drift
apart silently.

Imports nothing (stdlib ``typing`` only), so any layer — kernels, the
solver cache, the core solvers, tools — can depend on it without cycles.

Task matrix (one row per task; f32)::

    col   0..5   P0, GAMMA, C_COEF, BIG_D, DELTA, T0   DvfsParams columns
    col   6      ALLOWED                               time budget d - a
    col   7      READJUST                              >0.5: boundary binds
    col   8..12  V_MIN, V_MAX, FC_MIN, FM_MIN, FM_MAX  per-row interval box
    col  13..15  padding to NCOL (VPU lane alignment)

Columns ``0..KEY_COLS-1`` ARE the solver-cache key: the f32 row is the
entire solver input, which is what makes unique-row dedup bit-transparent.

Solution matrix (one row per task; f32, bools stored as 0.0/1.0)::

    col   0..7   SOL_V, SOL_FC, SOL_FM, SOL_T, SOL_P, SOL_E,
                 SOL_DP (deadline_prior), SOL_FEASIBLE
"""

from __future__ import annotations

from typing import Any, NamedTuple

# --- task / key matrix columns -------------------------------------------
P0, GAMMA, C_COEF, BIG_D, DELTA, T0, ALLOWED, READJUST = range(8)
V_MIN, V_MAX, FC_MIN, FM_MIN, FM_MAX = range(8, 13)

N_PARAMS = 6        #: DvfsParams columns (P0..T0)
N_BOUNDS = 5        #: ScalingInterval.bounds() columns (V_MIN..FM_MAX)
LEGACY_NCOL = 8     #: the homogeneous [n, 8] layout: params+allowed+readjust
KEY_COLS = 13       #: solver-cache key width = params+allowed+readjust+bounds
NCOL = 16           #: Pallas task-matrix width (KEY_COLS + 3 pad columns)

PARAMS_SLICE = slice(0, N_PARAMS)         #: the DvfsParams columns
BOUNDS_SLICE = slice(V_MIN, KEY_COLS)     #: the per-row interval columns

# --- solution matrix columns ---------------------------------------------
SOL_V, SOL_FC, SOL_FM, SOL_T, SOL_P, SOL_E, SOL_DP, SOL_FEASIBLE = range(8)
SOL_COLS = 8        #: solution width (= the DvfsSolution fields, in order)

# Width asserts tying the three layouts together: the key matrix is a
# prefix of the task matrix, and both derive from the same column names.
assert N_PARAMS + 2 == READJUST + 1 == LEGACY_NCOL
assert LEGACY_NCOL + N_BOUNDS == FM_MAX + 1 == KEY_COLS
assert KEY_COLS <= NCOL
assert SOL_FEASIBLE + 1 == SOL_COLS


def col(i: int) -> slice:
    """Width-1 column slice ``[i, i+1)`` — a keepdims column read."""
    return slice(i, i + 1)


class DvfsSolution(NamedTuple):
    """Optimal DVFS setting for a (batch of) task(s) — the record form of
    the solution matrix, fields in ``SOL_*`` column order.

    Declared here (not in :mod:`repro.core.single_task`, which re-exports
    it) so the solver-throughput layer and the kernel wrappers can name
    the solution type without importing up-layer.
    """

    v: Any
    fc: Any
    fm: Any
    time: Any
    power: Any
    energy: Any
    deadline_prior: Any  # bool: was the deadline binding?
    feasible: Any        # bool: can the deadline be met at all?


assert len(DvfsSolution._fields) == SOL_COLS
