"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import single_task
from repro.core.dvfs import DvfsParams, ScalingInterval, WIDE
from repro.kernels import layout as L


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  window: Optional[int] = None) -> jax.Array:
    """Dense softmax attention.  q: [B, H, S, dh]; k/v: [B, KV, Sk, dh]."""
    B, H, Sq, dh = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    g = H // KV
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= (qp - kp) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
            c: jax.Array) -> jax.Array:
    """Sequential SSD recurrence (no D-skip), matching ssd_scan's contract."""
    from repro.models.ssm import ssd_reference
    y, _ = ssd_reference(x, dt, a, b, c)
    return y.astype(x.dtype)


def dvfs_solve_ref(tasks: np.ndarray,
                   interval: ScalingInterval = WIDE) -> np.ndarray:
    """Oracle for dvfs_opt: the production grid+golden solver.

    Column 7 > 0.5 flags a theta-readjustment row: those take the forced
    deadline-boundary solve (``solve_on_boundary``), matching the kernel's
    readjust sweep.

    A widened ``[n, 16]`` matrix (``layout.BOUNDS_SLICE`` = per-row
    interval bounds, the heterogeneous-class layout) is solved by grouping
    rows that share a scaling box and running the production solver once
    per group — exactly the semantics of the kernel's per-row bounds."""
    if tasks.shape[1] >= L.KEY_COLS:
        bounds = np.asarray(tasks[:, L.BOUNDS_SLICE], np.float32)
        out = np.zeros((tasks.shape[0], L.SOL_COLS), np.float32)
        for row in np.unique(bounds, axis=0):
            m = np.all(bounds == row, axis=1)
            iv = ScalingInterval(*(float(x) for x in row))
            out[m] = dvfs_solve_ref(tasks[m, :L.LEGACY_NCOL], iv)
        return out
    params = DvfsParams(p0=tasks[:, L.P0], gamma=tasks[:, L.GAMMA],
                        c=tasks[:, L.C_COEF], big_d=tasks[:, L.BIG_D],
                        delta=tasks[:, L.DELTA], t0=tasks[:, L.T0])
    allowed = tasks[:, L.ALLOWED]
    sol = single_task.solve_with_deadline(params, allowed, interval)
    readj = tasks[:, L.READJUST] > 0.5
    if np.any(readj):
        bnd = single_task.solve_on_boundary(params, allowed, interval)
        sol = type(sol)(*(jnp.where(readj, b, s) for s, b in zip(sol, bnd)))
    t = np.asarray(sol.time)
    dp = np.asarray(sol.deadline_prior)
    feas = np.asarray(sol.feasible)
    t = np.where(dp & feas, np.minimum(t, allowed), t)
    p = np.asarray(sol.power)
    return np.stack([np.asarray(sol.v), np.asarray(sol.fc),
                     np.asarray(sol.fm), t, p, p * t,
                     dp.astype(np.float32), feas.astype(np.float32)], axis=1)
