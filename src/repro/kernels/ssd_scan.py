"""Pallas TPU kernel for the Mamba2 SSD chunked scan (train/prefill
hot-spot of the attention-free cells).

Grid ``(B, H, n_chunks)`` with the chunk axis minor-most (sequential per
core) so the inter-chunk state ``[P, N]`` lives in VMEM scratch across
chunk steps — the HBM<->VMEM traffic per chunk is exactly one read of
(x, dt, B, C) and one write of y; the state never leaves VMEM.

Per chunk (Q tokens, all f32 in VMEM):

    scores = C B^T ⊙ L           (L = exp(segsum(dt*a)), lower-tri)
    y_diag = scores @ (dt*x)
    y_off  = (C @ state) ⊙ exp(cum)
    state  = decay * state + (B ⊙ w)^T @ (dt*x)

MXU shapes: [Q, N] x [N, Q] and [Q, Q] x [Q, P] with Q = 128/256 and
N = 128, P = 64..128 — all 128-aligned on the lane dim.

Oracle: ``repro.models.ssm.ssd_chunked`` / ``ssd_reference``
(tests/test_kernels.py sweeps shapes and dtypes in interpret mode).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
            q: int, p: int, n: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)              # [Q, P]
    dt = dt_ref[0, 0, 0].astype(jnp.float32)            # [Q]
    a = a_ref[0]                                        # scalar (per head)
    bm = b_ref[0, 0].astype(jnp.float32)                # [Q, N]
    cm = c_ref[0, 0].astype(jnp.float32)                # [Q, N]

    dA = dt * a                                         # [Q]
    cum = jnp.cumsum(dA)                                # [Q]
    xd = x * dt[:, None]                                # dt-weighted input

    # Intra-chunk: (C B^T ⊙ L) xd, L[i,j] = exp(cum_i - cum_j) for i >= j.
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Q,Q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    ldec = jnp.where(ii >= jj, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    y = jax.lax.dot_general(scores * ldec, xd, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # [Q,P]

    # Off-chunk: contribution of the carried state.
    cs = jax.lax.dot_general(cm, state_ref[...], (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)      # [Q,P]
    y = y + cs * jnp.exp(cum)[:, None]

    # State update: state' = exp(cum_last) * state + sum_k w_k B_k xd_k^T.
    w = jnp.exp(cum[-1] - cum)                          # [Q]
    upd = jax.lax.dot_general(xd, bm * w[:, None], (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)     # [P,N]
    state_ref[...] = state_ref[...] * jnp.exp(cum[-1]) + upd

    y_ref[0, 0, 0, :, :] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, *, chunk: int = 128,
             interpret: bool = False) -> jax.Array:
    """x: [B, S, H, P]; dt: [B, S, H] (softplus'd); a: [H] (negative);
    b/c: [B, S, N].  Returns y [B, S, H, P] (without the D skip term)."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    q = min(chunk, S)
    assert S % q == 0, (S, q)
    nc = S // q

    xk = x.transpose(0, 2, 1, 3).reshape(B, H, nc, q, P)
    dtk = dt.transpose(0, 2, 1).reshape(B, H, nc, q)
    bk = b.reshape(B, nc, q, N)
    ck = c.reshape(B, nc, q, N)

    kernel = functools.partial(_kernel, q=q, p=P, n=N, n_chunks=nc)
    y = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, P), lambda bb, h, cc: (bb, h, cc, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda bb, h, cc: (bb, h, cc, 0)),
            pl.BlockSpec((1,), lambda bb, h, cc: (h,)),
            pl.BlockSpec((1, 1, q, N), lambda bb, h, cc: (bb, cc, 0, 0)),
            pl.BlockSpec((1, 1, q, N), lambda bb, h, cc: (bb, cc, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, q, P),
                               lambda bb, h, cc: (bb, h, cc, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nc, q, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xk, dtk, a.astype(jnp.float32), bk, ck)
    return y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
