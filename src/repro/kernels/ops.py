"""Jit'd public wrappers for the Pallas kernels.

On the CPU container every kernel runs in ``interpret=True`` mode (the
kernel body executes as JAX ops — bit-identical control flow to the TPU
lowering); on a real TPU backend the same calls compile to Mosaic.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dvfs import DvfsParams, ScalingInterval, WIDE
from repro.core.single_task import DvfsSolution
from repro.kernels.dvfs_opt import dvfs_solve_kernel
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.ssd_scan import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_head_dim(x: jax.Array, to: int = 128) -> jax.Array:
    dh = x.shape[-1]
    if dh % to == 0:
        return x
    pad = -(-dh // to) * to - dh
    cfgpad = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, cfgpad)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    bq: int = 128, bk: int = 128) -> jax.Array:
    """MXU-padded flash attention.  q: [B, H, S, dh]; k/v: [B, KV, Sk, dh].

    Pads dh to a multiple of 128 (scores are unaffected because padded
    columns are zero in both q and k; v padding is sliced off)."""
    dh = q.shape[-1]
    qp, kp, vp = (_pad_head_dim(t) for t in (q, k, v))
    # scale uses the REAL dh: compensate the kernel's padded-dh scale.
    fix = (qp.shape[-1] / dh) ** 0.5
    out = _flash(qp * fix, kp, vp, causal=causal, window=window, bq=bq,
                 bk=bk, interpret=_interpret())
    return out[..., :dh]


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, chunk: int = 128) -> jax.Array:
    """SSD chunked scan (no D-skip).  See kernels/ssd_scan.py."""
    return _ssd(x, dt, a, b, c, chunk=chunk, interpret=_interpret())


def dvfs_solve(params: DvfsParams, allowed: np.ndarray,
               interval: ScalingInterval = WIDE,
               readjust: bool = False,
               interval_rows: Optional[np.ndarray] = None) -> DvfsSolution:
    """Batched single-task DVFS optimum via the Pallas kernel.

    Drop-in for ``single_task.solve_with_deadline`` (same DvfsSolution
    contract; used by ``configure_tasks(use_kernel=True)``).  With
    ``readjust=True`` every row is flagged as a theta-readjustment (column
    7 of the task matrix): the kernel then takes the deadline-boundary
    sweep unconditionally — the drop-in for ``single_task.solve_on_boundary``
    used by ``readjust_batch(use_kernel=True)``.

    ``interval_rows`` (``[n, 5]``: v_min, v_max, fc_min, fm_min, fm_max)
    gives every row its own scaling box — the heterogeneous-class path
    (``machines.configure_classes``) stacks one class block per interval
    and solves them all in this one dispatch.  When omitted, the static
    ``interval`` applies to every row."""
    cols = [np.asarray(f, np.float32) for f in params.astuple()]
    n = cols[0].shape[0]
    flag = np.ones(n, np.float32) if readjust else np.zeros(n, np.float32)
    cols = cols + [np.asarray(allowed, np.float32), flag]
    if interval_rows is not None:
        bounds = np.asarray(interval_rows, np.float32)
        if bounds.shape != (n, 5):
            raise ValueError(f"interval_rows must be [n, 5], got {bounds.shape}")
        tasks = np.concatenate(
            [np.stack(cols, axis=1), bounds, np.zeros((n, 3), np.float32)],
            axis=1)
    else:
        tasks = np.stack(cols, axis=1)
    out = np.asarray(dvfs_solve_kernel(jnp.asarray(tasks), interval=interval,
                                       interpret=_interpret()))
    return DvfsSolution(v=out[:, 0], fc=out[:, 1], fm=out[:, 2],
                        time=out[:, 3], power=out[:, 4], energy=out[:, 5],
                        deadline_prior=out[:, 6] > 0.5,
                        feasible=out[:, 7] > 0.5)
