"""Jit'd public wrappers for the Pallas kernels.

On the CPU container every kernel runs in ``interpret=True`` mode (the
kernel body executes as JAX ops — bit-identical control flow to the TPU
lowering); on a real TPU backend the same calls compile to Mosaic.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import solver_cache
from repro.core.dvfs import DvfsParams, ScalingInterval, WIDE
from repro.kernels import layout
from repro.kernels.dvfs_opt import BT, DEFAULT_GRID, PAD_ROW, dvfs_solve_kernel
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.layout import DvfsSolution
from repro.kernels.ssd_scan import ssd_scan as _ssd

#: Below this row count a multi-device split costs more in transfer/dispatch
#: than it saves in compute.
SHARD_MIN_ROWS = 4096


def default_interpret() -> bool:
    """THE ``interpret=`` policy for every kernel call site: run the Pallas
    bodies as JAX ops unless a real TPU backend is attached, so CI, laptops,
    and TPU hosts all exercise the same code path without per-caller flags."""
    return jax.default_backend() != "tpu"


_interpret = default_interpret  # back-compat alias for older call sites


def _pad_head_dim(x: jax.Array, to: int = 128) -> jax.Array:
    dh = x.shape[-1]
    if dh % to == 0:
        return x
    pad = -(-dh // to) * to - dh
    cfgpad = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, cfgpad)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    bq: int = 128, bk: int = 128) -> jax.Array:
    """MXU-padded flash attention.  q: [B, H, S, dh]; k/v: [B, KV, Sk, dh].

    Pads dh to a multiple of 128 (scores are unaffected because padded
    columns are zero in both q and k; v padding is sliced off)."""
    dh = q.shape[-1]
    qp, kp, vp = (_pad_head_dim(t) for t in (q, k, v))
    # scale uses the REAL dh: compensate the kernel's padded-dh scale.
    fix = (qp.shape[-1] / dh) ** 0.5
    out = _flash(qp * fix, kp, vp, causal=causal, window=window, bq=bq,
                 bk=bk, interpret=_interpret())
    return out[..., :dh]


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, chunk: int = 128) -> jax.Array:
    """SSD chunked scan (no D-skip).  See kernels/ssd_scan.py."""
    return _ssd(x, dt, a, b, c, chunk=chunk, interpret=_interpret())


def dvfs_solve_matrix(mat: np.ndarray, *, grid: tuple = DEFAULT_GRID,
                      interpret: Optional[bool] = None,
                      shard: bool = True, block: bool = True):
    """Dispatch a ``[m, 16]`` (or ``[m, 13]`` key-layout) task matrix to the
    Pallas solver, sharded across local devices when it pays off.

    The matrix is padded to a whole number of kernel blocks with benign
    rows, split into equal per-device chunks (all chunks one compiled
    shape), dispatched asynchronously to each device, and re-concatenated —
    per-row results are bitwise identical to the single-device path because
    the solver is row-independent.  Falls back to one local dispatch when
    there is a single device or the batch is under ``SHARD_MIN_ROWS``.
    Returns the ``[m, 8]`` solution matrix as numpy.

    ``block=False`` is the pipelined-scheduler entry point: the kernel is
    dispatched but the host does NOT wait for it — the return value is the
    in-flight device array (single device) or a zero-arg callable that
    gathers the per-device parts when invoked.  Either form is what
    ``solver_cache._materialize`` consumes at the pipeline's sync point.
    """
    if interpret is None:
        interpret = default_interpret()
    mat = np.asarray(mat, np.float32)
    if mat.shape[1] == layout.KEY_COLS:  # widen key layout -> NCOL
        mat = np.concatenate(
            [mat, np.zeros((mat.shape[0], layout.NCOL - layout.KEY_COLS),
                           np.float32)], axis=1)
    m = mat.shape[0]
    devs = jax.local_devices()
    nd = 1
    if shard and len(devs) > 1 and m >= SHARD_MIN_ROWS:
        nd = 1 << (len(devs).bit_length() - 1)   # pow-2 device count
        while nd > 1 and -(-m // nd) < BT:
            nd //= 2
    if nd == 1:
        fut = dvfs_solve_kernel(jnp.asarray(mat), grid=grid,
                                interpret=interpret)
        return np.asarray(fut) if block else fut
    per_dev = -(-m // nd)
    chunk = -(-per_dev // BT) * BT  # whole kernel blocks per device
    if nd * chunk != m:
        pad = np.broadcast_to(PAD_ROW, (nd * chunk - m, layout.NCOL))
        mat = np.concatenate([mat, pad], axis=0)
    parts = [dvfs_solve_kernel(
                 jax.device_put(jnp.asarray(mat[i * chunk:(i + 1) * chunk]),
                                devs[i]),
                 grid=grid, interpret=interpret)
             for i in range(nd)]  # dispatches are async; concat blocks

    def gather() -> np.ndarray:
        return np.concatenate([np.asarray(p) for p in parts], axis=0)[:m]

    return gather() if block else gather


def dvfs_solve(params: DvfsParams, allowed: np.ndarray,
               interval: ScalingInterval = WIDE,
               readjust: bool = False,
               interval_rows: Optional[np.ndarray] = None,
               dedup: bool = True,
               grid: tuple = DEFAULT_GRID,
               cache: Optional["solver_cache.SolveCache"] = None) -> DvfsSolution:
    """Batched single-task DVFS optimum via the Pallas kernel.

    Drop-in for ``single_task.solve_with_deadline`` (same DvfsSolution
    contract; used by ``configure_tasks(use_kernel=True)``).  With
    ``readjust=True`` every row is flagged as a theta-readjustment (column
    7 of the task matrix): the kernel then takes the deadline-boundary
    sweep unconditionally — the drop-in for ``single_task.solve_on_boundary``
    used by ``readjust_batch(use_kernel=True)``.

    ``interval_rows`` (``[n, 5]``: v_min, v_max, fc_min, fm_min, fm_max)
    gives every row its own scaling box — the heterogeneous-class path
    (``machines.configure_classes``) stacks one class block per interval
    and solves them all in this one dispatch.  When omitted, the static
    ``interval`` applies to every row.

    ``dedup=True`` routes the matrix through the unique-row dedup +
    process-wide LRU solve cache (:mod:`repro.core.solver_cache`) — bit
    identical output, only previously-unseen rows touch the kernel.
    ``grid`` sets the kernel's hierarchical (coarse, fine) sweep sizes;
    ``cache=None`` means the global cache when deduping.
    """
    cols = [np.asarray(f, np.float32) for f in params.astuple()]
    n = cols[0].shape[0]
    if interval_rows is not None:
        bounds = np.asarray(interval_rows, np.float32)
        if bounds.shape != (n, layout.N_BOUNDS):
            raise ValueError(f"interval_rows must be [n, {layout.N_BOUNDS}], "
                             f"got {bounds.shape}")
    else:
        bounds = np.asarray(interval.bounds(), np.float32)
    keys = solver_cache.build_keys(cols, allowed, readjust, bounds)

    def solve(km: np.ndarray) -> np.ndarray:
        return dvfs_solve_matrix(km, grid=grid)

    if dedup:
        tag = f"k{int(grid[0])}x{int(grid[1])}"
        out = solver_cache.solve_rows(
            keys, solve, tag=tag,
            cache=solver_cache.GLOBAL_CACHE if cache is None else cache)
    else:
        out = solve(keys)
    return solver_cache.rows_to_solution(out)
