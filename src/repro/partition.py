"""Logical-axis partitioning.

Model code annotates every parameter and key activation with *logical* axis
names ("embed", "heads", "ff", "vocab", "batch", ...).  The launcher binds a
:class:`Rules` context that maps logical names onto physical mesh axes; with
no context bound (unit tests, single-device smoke runs) every annotation is a
no-op.  This keeps the model definitions mesh-agnostic while letting the
dry-run and the trainer express DP/FSDP/TP/EP/SP sharding as data, not code.

Default rule tables:

* ``fsdp``  - parameter ``embed`` dims shard over the data axis (ZeRO-3
  style; XLA inserts the per-layer all-gathers), ``heads``/``ff``/``vocab``/
  ``expert``/``inner`` shard over the model axis (Megatron TP / EP), decode
  caches shard their sequence dim over the model axis (flash-decode SP).
* ``replicated`` - parameters replicated, only batch sharded (pure DP).
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any, Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar("partition_rules",
                                                         default=None)


@dataclasses.dataclass(frozen=True)
class Rules:
    """A binding of logical axis names to mesh axes for one mesh."""

    mesh: Mesh
    table: Mapping[str, MeshAxes]

    def axis(self, name: Optional[str]) -> MeshAxes:
        if name is None:
            return None
        return self.table.get(name)

    def spec(self, axes: Sequence[Optional[str]]) -> P:
        return P(*(self.axis(a) for a in axes))

    def sharding(self, axes: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes))


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    token = _ACTIVE.set(rules)
    try:
        yield rules
    finally:
        _ACTIVE.reset(token)


def current_rules() -> Optional[Rules]:
    return _ACTIVE.get()


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """``with_sharding_constraint`` by logical axes; no-op without rules."""
    rules = current_rules()
    if rules is None:
        return x
    if x.ndim != len(axes):
        raise ValueError(f"rank {x.ndim} tensor annotated with {axes}")
    return jax.lax.with_sharding_constraint(x, rules.sharding(axes))


def wcast(x: jax.Array, dtype, axes: Sequence[Optional[str]]) -> jax.Array:
    """Cast a weight to the compute dtype AND pin the cast to the sharded
    layout (§Perf H5): the identity constraint materializes the bf16 copy
    *before* any partitioner-inserted all-gather, halving FSDP weight-
    gather bytes (XLA otherwise gathers f32 and converts after)."""
    return constrain(x.astype(dtype), axes)


# ---------------------------------------------------------------------------
# Standard rule tables.
# ---------------------------------------------------------------------------


def batch_axes_for(mesh: Mesh, global_batch: int) -> MeshAxes:
    """The largest prefix of the mesh's batch axes that divides the batch.

    ``long_500k`` runs at global batch 1 - its batch stays replicated; every
    other assigned shape divides the full ("pod", "data") product.
    """
    candidates = [a for a in ("pod", "data") if a in mesh.axis_names]
    chosen = []
    size = 1
    for a in candidates:
        nxt = size * mesh.shape[a]
        if global_batch % nxt == 0:
            chosen.append(a)
            size = nxt
        else:
            break
    if not chosen:
        return None
    return tuple(chosen) if len(chosen) > 1 else chosen[0]


def fsdp_rules(mesh: Mesh, global_batch: int, *,
               shard_cache_seq: bool = True) -> Rules:
    """The production table: DP/FSDP over data (and pod), TP/EP/SP over model."""
    batch = batch_axes_for(mesh, global_batch)
    table = {
        # activations
        "batch": batch,
        "seq": None,
        "act_embed": None,
        "cache_seq": "model" if shard_cache_seq else None,
        # parameters
        "embed": "data",
        "heads": "model",   # fused q-heads dim (H * head_dim)
        "kv": None,         # kv-heads replicated across model (GQA kv < 16)
        "ff": "model",
        "vocab": "model",
        "expert": "model",     # MoE expert dim (EP)
        "expert_ff": None,     # per-expert ff (expert dim already on model)
        "inner": "model",      # SSM / RG-LRU inner width
        "layers": None,
    }
    return Rules(mesh=mesh, table=table)


def replicated_rules(mesh: Mesh, global_batch: int) -> Rules:
    """Pure data parallelism: parameters replicated, batch sharded."""
    batch = batch_axes_for(mesh, global_batch)
    table = {k: None for k in fsdp_rules(mesh, global_batch).table}
    table["batch"] = batch
    return Rules(mesh=mesh, table=table)


def serve_rules(mesh: Mesh, global_batch: int) -> Rules:
    """Serving table (§Perf H3): weights TP-only — the ``embed`` dim is
    replicated across data instead of FSDP-sharded, so the decode step
    issues NO per-layer weight all-gathers (weights are resident, read
    once from HBM).  Pairs with bf16 parameter storage: a 72B model is
    9 GB/chip over a 16-wide model axis — resident beside the KV cache."""
    rules = fsdp_rules(mesh, global_batch)
    table = dict(rules.table)
    table["embed"] = None
    # kv projections shard over model as a tensor dim (kv_dim = KV * dh is
    # 16-divisible for every assigned arch) — replicating them costs 5.4 GiB
    # on qwen2-72b in serve mode.
    table["kv"] = "model"
    return Rules(mesh=mesh, table=table)


def is_axes(x: Any) -> bool:
    """True for a logical-axes tuple leaf: a plain tuple of str/None entries
    (empty tuple = scalar).  NamedTuples (TrainState etc.) are containers."""
    return (isinstance(x, tuple) and not hasattr(x, "_fields")
            and all(a is None or isinstance(a, str) for a in x))


def param_shardings(rules: Optional[Rules], axes_tree: Any):
    """Map a tree of logical-axes tuples to NamedShardings (or None)."""
    if rules is None:
        return jax.tree.map(lambda _: None, axes_tree, is_leaf=is_axes)
    return jax.tree.map(lambda axes: rules.sharding(axes), axes_tree,
                        is_leaf=is_axes)
