"""Sharded checkpointing with async save, atomic publish, and elastic
restore.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json          # tree structure, shapes, dtypes, step
        leaf_00000.npy ...     # one .npy per leaf (host-gathered)
    <dir>/step_000123.tmp/     # staging; renamed atomically when complete

Design points for the 1000-node story:

* **Async**: ``save()`` snapshots device arrays to host (blocking only on
  the device->host copy) and writes files on a background thread — the
  train loop loses one d2h copy, not the filesystem latency.
* **Atomic**: writers stage into ``.tmp`` and ``os.rename`` at the end, so
  a node failure mid-save never corrupts the latest checkpoint;
  ``latest_step()`` only ever sees complete directories.
* **Elastic restore**: ``restore(like, shardings=...)`` re-shards every
  leaf onto an arbitrary *new* mesh via ``jax.device_put`` — restarting on
  a different pod count is a restore-time decision, not a save-time one.
* **Retention**: ``keep`` most recent checkpoints are retained.

On a real multi-host cluster each host would write only the shards it
owns (the manifest already records per-leaf shapes); the single-host
container writes fully-gathered leaves, which keeps restore trivially
correct for any target topology.
"""

from __future__ import annotations

import concurrent.futures
import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[concurrent.futures.Future] = None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = False):
        """Checkpoint ``tree`` (any pytree of arrays) at ``step``."""
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(x) for x in leaves]   # d2h snapshot (blocking)
        self.wait()                               # one in-flight save max

        def write():
            tmp = os.path.join(self.dir, f"step_{step:06d}.tmp")
            final = os.path.join(self.dir, f"step_{step:06d}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            for i, arr in enumerate(host):
                np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
            manifest = {
                "step": step,
                "treedef": str(treedef),
                "n_leaves": len(host),
                "shapes": [list(a.shape) for a in host],
                "dtypes": [str(a.dtype) for a in host],
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()
            return final

        self._pending = self._pool.submit(write)
        if blocking:
            self.wait()

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:06d}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Load a checkpoint into the structure of ``like``.

        ``shardings``: optional pytree of (Named)Shardings — pass the NEW
        mesh's shardings to restore elastically onto a different topology.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step_{step:06d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_like, treedef = jax.tree.flatten(like)
        assert manifest["n_leaves"] == len(leaves_like), \
            (manifest["n_leaves"], len(leaves_like))
        host = [np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
                for i in range(manifest["n_leaves"])]
        if shardings is not None:
            sh_leaves = treedef.flatten_up_to(shardings)
            out = [jax.device_put(a, s) if s is not None else jax.device_put(a)
                   for a, s in zip(host, sh_leaves)]
        else:
            out = [jax.device_put(a) for a in host]
        return treedef.unflatten(out)
