"""Architecture registry, input shapes, and dry-run cell enumeration."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

ARCHS = (
    "mamba2-370m",
    "stablelm-12b",
    "h2o-danube-1.8b",
    "qwen2-72b",
    "nemotron-4-15b",
    "internvl2-2b",
    "moonshot-v1-16b-a3b",
    "qwen3-moe-30b-a3b",
    "whisper-base",
    "recurrentgemma-2b",
)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# Archs whose attention is sub-quadratic / O(1)-state at decode; only these
# run the 524k-context cell (the assignment's prescribed skip for pure
# full-attention archs).
LONG_CONTEXT_OK = {"mamba2-370m", "recurrentgemma-2b", "h2o-danube-1.8b"}

_MOD = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
        for a in ARCHS}
_CACHE: Dict[str, ModelConfig] = {}


def get_config(arch: str) -> ModelConfig:
    if arch not in _CACHE:
        if arch not in _MOD:
            raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
        _CACHE[arch] = importlib.import_module(_MOD[arch]).CONFIG
    return _CACHE[arch]


def cell_skip_reason(arch: str, shape: str) -> Optional[str]:
    """None if the (arch x shape) cell runs; else the reason it is skipped."""
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
        return ("full quadratic attention at 524k tokens / batch 1: "
                "unshardable batch, quadratic score matrix (DESIGN.md skip)")
    return None


def list_cells(include_skipped: bool = False):
    out = []
    for a in ARCHS:
        for s in SHAPES:
            reason = cell_skip_reason(a, s)
            if reason is None or include_skipped:
                out.append((a, s))
    return out


CELLS = list_cells()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(arch: str, shape: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the model inputs of one cell.

    * train:   {tokens, labels [B, S] int32} (+ modality extras)
    * prefill: {tokens [B, S] int32} (+ extras)
    * decode:  {token [B] int32, pos scalar} — the cache spec comes from
      ``Model.init_cache`` via ``jax.eval_shape`` in the dry-run.
    """
    cfg = get_config(arch)
    spec = SHAPES[shape]
    B, S = spec.global_batch, spec.seq_len
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if spec.mode == "train":
        out["tokens"] = _sds((B, S), jnp.int32)
        out["labels"] = _sds((B, S), jnp.int32)
    elif spec.mode == "prefill":
        out["tokens"] = _sds((B, S), jnp.int32)
    else:  # decode
        out["token"] = _sds((B,), jnp.int32)
    if spec.mode in ("train", "prefill"):
        if cfg.family == "vlm":
            out["patch_embeds"] = _sds((B, cfg.n_patches, cfg.d_model),
                                       jnp.bfloat16)
        if cfg.family == "encdec":
            out["frames"] = _sds((B, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    return out


def input_logical_axes(arch: str, shape: str) -> Dict[str, tuple]:
    """Logical axes for each input (for in_shardings in the dry-run)."""
    cfg = get_config(arch)
    spec = SHAPES[shape]
    out: Dict[str, tuple] = {}
    if spec.mode == "train":
        out = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    elif spec.mode == "prefill":
        out = {"tokens": ("batch", "seq")}
    else:
        out = {"token": ("batch",)}
    if spec.mode in ("train", "prefill"):
        if cfg.family == "vlm":
            out["patch_embeds"] = ("batch", None, "act_embed")
        if cfg.family == "encdec":
            out["frames"] = ("batch", None, "act_embed")
    return out
