"""Assigned architecture configs (exact figures from the assignment) and the
input-shape registry.

``get_config(arch_id)`` returns the full :class:`ModelConfig`;
``input_specs(arch, shape, mode)`` returns ShapeDtypeStruct stand-ins for
every model input (weak-type-correct, shardable, zero allocation) — the
dry-run lowers against these.
"""

from repro.configs.registry import (ARCHS, SHAPES, CELLS, cell_skip_reason,
                                    get_config, input_specs, list_cells)

__all__ = ["ARCHS", "SHAPES", "CELLS", "cell_skip_reason", "get_config",
           "input_specs", "list_cells"]
