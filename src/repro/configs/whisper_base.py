"""whisper-base — encoder-decoder, conv frontend stubbed [arXiv:2212.04356].

6 encoder + 6 decoder layers, d_model=512, 8 heads (kv=8, head_dim=64),
d_ff=2048, vocab=51865; GELU MLP, LayerNorm, sinusoidal positions.  The
conv1d audio frontend is a STUB: ``input_specs()`` supplies 1500 precomputed
frame embeddings.  The assigned 32k decode shape far exceeds the real
model's 448-token context; we honor the assigned shape (DESIGN.md note).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51_865,
    mlp_type="gelu",
    n_enc_layers=6,
    n_frames=1500,
)
