"""stablelm-12b — dense GQA decoder [hf:stabilityai/stablelm-2-12b].

40L, d_model=5120, 32 heads (GQA kv=8, head_dim=160), d_ff=13824,
vocab=100352; SwiGLU; per-head qk handled by standard RoPE.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13_824,
    vocab_size=100_352,
    mlp_type="swiglu",
    rope_theta=10_000.0,
)
