"""qwen3-moe-30b-a3b — 128-expert top-8 MoE [hf:Qwen/Qwen3-30B-A3B].

48L, d_model=2048, 32 heads (GQA kv=4, head_dim=128 explicit), expert
d_ff=768, vocab=151936; 128 experts, top-8.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151_936,
    mlp_type="swiglu",
    n_experts=128,
    top_k=8,
    capacity_factor=1.25,
    rope_theta=1_000_000.0,
)
