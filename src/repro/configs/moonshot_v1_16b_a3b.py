"""moonshot-v1-16b-a3b — Moonlight-style MoE [hf:moonshotai/Moonlight-16B-A3B].

48L, d_model=2048, 16 heads (kv=16, head_dim=128), expert d_ff=1408,
vocab=163840; 64 experts, top-6 routing (capacity-based EP dispatch; the
checkpoint's 2 shared experts are out of the assigned figure set and
omitted — noted in DESIGN.md).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163_840,
    mlp_type="swiglu",
    n_experts=64,
    top_k=6,
    capacity_factor=1.25,
    rope_theta=50_000.0,
)
