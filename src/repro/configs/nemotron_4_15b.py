"""nemotron-4-15b — dense GQA with squared-ReLU MLP [arXiv:2402.16819].

32L, d_model=6144, 48 heads (GQA kv=8, head_dim=128), d_ff=24576,
vocab=256000; non-gated squared-ReLU MLP (2 weight matrices).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24_576,
    vocab_size=256_000,
    mlp_type="squared_relu",
    rope_theta=10_000.0,
)
