"""internvl2-2b — InternViT + InternLM2-1.8B backbone [arXiv:2404.16821].

Assignment specifies the transformer BACKBONE only: 24L, d_model=2048,
16 heads (GQA kv=8), d_ff=8192, vocab=92553.  The ViT frontend is a STUB:
``input_specs()`` provides 256 precomputed patch embeddings (448px / 14
patch / 0.5 pixel-shuffle) that overwrite the first positions; labels are
masked there and the image prefix attends bidirectionally.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92_553,
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
    n_patches=256,
)
