"""qwen2-72b — flagship dense GQA decoder with QKV bias [arXiv:2407.10671].

80L, d_model=8192, 64 heads (GQA kv=8, head_dim=128), d_ff=29568,
vocab=152064; SwiGLU; rope theta 1e6.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29_568,
    vocab_size=152_064,
    mlp_type="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
)
