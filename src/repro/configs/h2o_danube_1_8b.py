"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818].

24L, d_model=2560, 32 heads (GQA kv=8, head_dim=80), d_ff=6912, vocab=32000;
SWA window 4096 (mistral-style) => ring-buffer KV cache, sub-quadratic
long-context decode (runs the 524k cell).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6_912,
    vocab_size=32_000,
    mlp_type="swiglu",
    sliding_window=4_096,
    rope_theta=10_000.0,
)
