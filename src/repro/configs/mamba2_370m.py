"""mamba2-370m — SSD (state-space duality), attention-free [arXiv:2405.21060].

48L, d_model=1024, ssm_state=128, vocab=50280; expand=2 => d_inner=2048,
head_dim=64 => 32 SSD heads; conv width 4; tied embeddings (mamba2 default).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=16,        # unused (attention-free)
    n_kv_heads=16,     # unused
    d_ff=0,            # attention-free: no MLP stack
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    conv_width=4,
    tie_embeddings=True,
)
