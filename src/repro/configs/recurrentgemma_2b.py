"""recurrentgemma-2b — RG-LRU + local attention, 1:2 pattern
[arXiv:2402.19427].

26L, d_model=2560, 10 heads (MQA kv=1, head_dim=256), d_ff=7680 (GeGLU),
vocab=256000; lru_width=2560, conv width 4, local window 2048; block
pattern (rec, rec, attn) => 8 full units + 2 remainder rec layers; tied
embeddings (gemma family).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    mlp_type="geglu",
    rnn_width=2560,
    block_pattern=("rec", "rec", "attn"),
    local_window=2048,
    conv_width=4,
    tie_embeddings=True,
)
