"""Property-based tests over randomized task sets.

``hypothesis`` is not installed in this container, so properties run over
seeded random sweeps (20 draws each) — same invariants, deterministic CI.
"""

import numpy as np
import pytest

from repro.core import dvfs, online, scheduling, single_task, tasks
from repro.core.dvfs import DvfsParams, WIDE

SEEDS = range(20)


def random_params(rng) -> DvfsParams:
    p_star = rng.uniform(120, 260)
    gamma = p_star * rng.uniform(0.05, 0.25)
    p0 = p_star * rng.uniform(0.1, 0.5)
    return DvfsParams(p0=p0, gamma=gamma, c=p_star - gamma - p0,
                      big_d=rng.uniform(1.0, 50.0),
                      delta=rng.uniform(0.0, 1.0),
                      t0=rng.uniform(0.05, 5.0))


@pytest.mark.parametrize("seed", SEEDS)
def test_solution_always_inside_box_and_saves_energy(seed):
    rng = np.random.default_rng(seed)
    p = random_params(rng)
    b = DvfsParams(*(np.asarray([f]) for f in p.astuple()))
    sol = single_task.solve_unconstrained(b)
    v = float(np.asarray(sol.v)[0])
    fc = float(np.asarray(sol.fc)[0])
    fm = float(np.asarray(sol.fm)[0])
    assert WIDE.v_min - 1e-5 <= v <= WIDE.v_max + 1e-5
    assert WIDE.fc_min - 1e-5 <= fc <= dvfs.g1_float(v) + 1e-4
    assert WIDE.fm_min - 1e-5 <= fm <= WIDE.fm_max + 1e-5
    # never worse than running at the default setting
    assert float(np.asarray(sol.energy)[0]) <= \
        float(np.asarray(p.default_energy())) * (1 + 1e-6)


@pytest.mark.parametrize("seed", SEEDS)
def test_deadline_solution_meets_deadline_iff_feasible(seed):
    rng = np.random.default_rng(100 + seed)
    p = random_params(rng)
    b = DvfsParams(*(np.asarray([f]) for f in p.astuple()))
    tmin = float(dvfs.min_time(p, WIDE))
    tstar = float(p.default_time())
    allowed = rng.uniform(0.5 * tmin, 2.0 * tstar)
    sol = single_task.solve_with_deadline(b, np.asarray([allowed]))
    feas = bool(np.asarray(sol.feasible)[0])
    t = float(np.asarray(sol.time)[0])
    assert feas == (allowed >= tmin - 1e-5)
    if feas:
        assert t <= allowed * (1 + 1e-4)


@pytest.mark.parametrize("seed", range(6))
def test_offline_schedule_invariants(seed):
    rng = np.random.default_rng(200 + seed)
    util = float(rng.uniform(0.02, 0.15))
    l = int(rng.choice([1, 2, 4, 8]))
    theta = float(rng.choice([0.8, 0.9, 1.0]))
    ts = tasks.generate_offline(util, seed=seed)
    r = scheduling.schedule_offline(ts, l=l, theta=theta, algorithm="edl")
    # every task assigned exactly once
    assert sorted(a.task for a in r.assignments) == list(range(len(ts)))
    assert r.violations == 0
    # pairs never overlap
    by_pair = {}
    for a in r.assignments:
        by_pair.setdefault(a.pair, []).append((a.start, a.finish))
    for spans in by_pair.values():
        spans.sort()
        for (s1, f1), (s2, f2) in zip(spans, spans[1:]):
            assert s2 >= f1 - 1e-6
    # energy accounting
    assert r.e_total == pytest.approx(r.e_run + r.e_idle + r.e_overhead)


@pytest.mark.parametrize("seed", range(4))
def test_online_offline_consistency_at_t0(seed):
    """An online run whose tasks ALL arrive at T=0 must match the offline
    scheduler's runtime energy (same Algorithm 1 optima)."""
    ts = tasks.generate_offline(0.05, seed=300 + seed)
    r_off = scheduling.schedule_offline(ts, l=1, theta=1.0, algorithm="edl")
    r_on = online.schedule_online(ts, l=1, theta=1.0, algorithm="edl")
    assert r_on.e_run == pytest.approx(r_off.e_run, rel=1e-6)


@pytest.mark.parametrize("seed", range(10))
def test_kernel_solver_agrees_with_reference(seed):
    from repro.kernels import ops, ref
    rng = np.random.default_rng(400 + seed)
    rows = [random_params(rng) for _ in range(32)]
    params = DvfsParams.stack(rows)
    tstar = np.asarray(params.default_time())
    allowed = tstar * rng.uniform(0.6, 2.0, 32)
    sol = ops.dvfs_solve(params, allowed)
    tasks_mat = np.stack([np.asarray(f, np.float32)
                          for f in params.astuple()]
                         + [allowed.astype(np.float32),
                            np.zeros(32, np.float32)], axis=1)
    expect = ref.dvfs_solve_ref(tasks_mat)
    rel = np.abs(sol.energy - expect[:, 5]) / np.maximum(expect[:, 5], 1e-9)
    assert float(np.median(rel)) < 2e-3
    assert float(np.mean(sol.deadline_prior == (expect[:, 6] > 0.5))) >= 0.9
