"""Pipelined online scheduling (async solve prefetch + incremental pools).

The contract under test is ``schedule_online(pipeline=True)`` — the
default — being *bit-identical* to the synchronous reference path while
overlapping device solves with host placement:

* bit-identity grid over {edl, bin} x {vector, scalar} x theta x class
  mixes, plus the kernel / dedup-off / injected-config variants;
* the same identity under a pinned fault trace (epoch invalidation is on
  the hot path there);
* unit tests for the persistent-pool delta rules (epoch invalidation,
  batched power-off compaction) and the :class:`AsyncSolve` handle
  (``unique=False`` probe-side dedup, memoized result, pad grid);
* the solve-cache counter semantics that back ``result.cache_stats`` and
  ``BENCH_sched.json`` (per-run reset vs lifetime totals, duplicated-trace
  hit pinning).
"""

import inspect
import types

import numpy as np
import pytest

from repro.core import online, placement, solver_cache, tasks
from repro.core.faults import FaultTrace
from repro.core.solver_cache import (KEY_COLS, SOL_COLS, SolveCache,
                                     _pad_rows, solve_rows_async)

MIX = ("gtx-1080ti", "tpu-v5e")


def trace(n=500, pattern="uniform", horizon=60, seed=0):
    return tasks.generate_trace(n, pattern=pattern, horizon=horizon,
                                seed=seed)


def assert_same_schedule(r0, r1, fault_stats=False):
    assert r1.e_total == r0.e_total
    assert r1.violations == r0.violations
    assert r1.assignments == r0.assignments
    if fault_stats:
        assert r1.fault_stats == r0.fault_stats


# ---------------------------------------------------------------------------
# Bit-identity with the synchronous path (the tentpole contract).
# ---------------------------------------------------------------------------


def test_pipeline_is_the_default():
    sig = inspect.signature(online.schedule_online)
    assert sig.parameters["pipeline"].default is True


@pytest.mark.parametrize("classes", [None, MIX])
@pytest.mark.parametrize("theta", [1.0, 0.7])
@pytest.mark.parametrize("mode", ["vector", "scalar"])
@pytest.mark.parametrize("alg", ["edl", "bin"])
def test_pipeline_bit_identical(alg, mode, theta, classes):
    ts = trace(seed=3)
    kw = dict(l=2, theta=theta, algorithm=alg, placement=mode,
              classes=classes, bound=False)
    r0 = online.schedule_online(ts, pipeline=False, **kw)
    r1 = online.schedule_online(ts, pipeline=True, **kw)
    assert_same_schedule(r0, r1)


def test_pipeline_bit_identical_small_chunks(monkeypatch):
    """Force many chunk boundaries (the prefetch double-buffer actually
    cycles) on a small trace; still bit-identical."""
    monkeypatch.setattr(online, "PIPELINE_CHUNK_TASKS", 64)
    ts = trace(seed=4, pattern="bursty")
    kw = dict(l=2, theta=0.9, algorithm="edl", bound=False)
    r0 = online.schedule_online(ts, pipeline=False, **kw)
    r1 = online.schedule_online(ts, pipeline=True, **kw)
    assert_same_schedule(r0, r1)


@pytest.mark.parametrize("mode", ["vector", "scalar"])
@pytest.mark.parametrize("alg", ["edl", "bin"])
def test_pipeline_bit_identical_under_faults(alg, mode):
    """Fault transitions bump the pool epoch mid-run; the pipelined path
    must invalidate its carried state and stay bit-identical."""
    ts = trace(seed=5, pattern="bursty")
    tr = FaultTrace.sample(16, 60.0, mtbf=25.0, mttr=5.0, seed=2)
    kw = dict(l=2, theta=0.9, algorithm=alg, placement=mode, faults=tr,
              bound=False)
    r0 = online.schedule_online(ts, pipeline=False, **kw)
    r1 = online.schedule_online(ts, pipeline=True, **kw)
    assert r1.fault_stats["failures"] > 0   # the trace actually engaged
    assert_same_schedule(r0, r1, fault_stats=True)


def test_pipeline_bit_identical_kernel_path():
    ts = trace(n=300, seed=7)
    kw = dict(l=2, theta=0.9, use_kernel=True, bound=False)
    r0 = online.schedule_online(ts, pipeline=False, **kw)
    r1 = online.schedule_online(ts, pipeline=True, **kw)
    assert_same_schedule(r0, r1)


def test_pipeline_bit_identical_dedup_off():
    ts = trace(n=300, seed=8)
    kw = dict(l=2, theta=0.9, dedup=False, bound=False)
    r0 = online.schedule_online(ts, pipeline=False, **kw)
    r1 = online.schedule_online(ts, pipeline=True, **kw)
    assert r1.cache_stats is None
    assert_same_schedule(r0, r1)


def test_pipeline_injected_cfgs_bit_identical():
    """With precomputed configs there is nothing to prefetch; the driver
    degenerates to chunked placement + readjustment prefetch only."""
    ts = trace(n=300, seed=9)
    mcs = online.machines.reference_classes()
    cfgs = online.online_configs(ts, mcs)
    kw = dict(l=2, theta=0.9, cfgs=cfgs, bound=False)
    r0 = online.schedule_online(ts, pipeline=False, **kw)
    r1 = online.schedule_online(ts, pipeline=True, **kw)
    assert_same_schedule(r0, r1)


# ---------------------------------------------------------------------------
# Persistent-pool delta rules (unit level).
# ---------------------------------------------------------------------------


class _StubEngine:
    """Just enough of ClusterEngine for _GroupPools' reconciliation path."""

    def __init__(self):
        self.pool_epoch = 0
        self.classes = [None]           # single class: no server_class calls
        self.drains = 0

    def drain_offs(self):
        self.drains += 1
        return []


def _stub_pools(grain=2):
    eng = _StubEngine()
    ctx = types.SimpleNamespace(eng=eng, grain=grain,
                                pre={"t_hat_l": None})
    gp = placement._GroupPools(ctx, 0.0, None, None, None, None)
    gp.persistent = True
    return eng, gp


def test_epoch_bump_invalidates_carried_pools():
    """A fault transition (pool_epoch bump) drops every carried pool and
    stream — the next group rebuilds lazily from the live engine."""
    eng, gp = _stub_pools()
    gp.pools[0] = [np.arange(6, dtype=np.int64), np.zeros(6), 6]
    gp.cands[0] = [np.arange(3), np.zeros(3)]
    gp.min_new[0] = 1.0
    gp.thresh[0] = (0.0, 0)
    gp.needs_merge = {0}
    eng.pool_epoch += 1
    gp.begin_group(1.0, None, None, None, None)
    assert gp.epoch == eng.pool_epoch
    assert not gp.pools and not gp.cands and not gp.min_new
    assert not gp.thresh and not gp.needs_merge
    assert eng.drains == 1              # queued power-offs still consumed


def test_same_epoch_keeps_carried_pools():
    eng, gp = _stub_pools()
    ids = np.arange(6, dtype=np.int64)
    gp.pools[0] = [ids, np.zeros(6), 6]
    gp.begin_group(1.0, None, None, None, None)
    assert gp.pools[0][0] is ids        # untouched carry


def test_power_off_deletion_compacts_pool_and_stream():
    """Batched power-off: one keep-mask compaction per class; surviving
    stream entries shift left by the deletions before them."""
    eng, gp = _stub_pools(grain=2)      # pair id = 2 * server + k
    ids = np.arange(8, dtype=np.int64)  # servers 0..3, fully pooled
    mus = np.arange(8, dtype=np.float64)
    gp.pools[0] = [ids.copy(), mus.copy(), 8]
    gp.cands[0] = [np.array([1, 3, 6]), mus[[1, 3, 6]].copy()]
    gp.apply_offs([1])                  # cuts pair ids 2 and 3
    ids2, mus2, n2 = gp.pools[0]
    assert n2 == 6
    assert list(ids2[:n2]) == [0, 1, 4, 5, 6, 7]
    assert list(mus2[:n2]) == [0.0, 1.0, 4.0, 5.0, 6.0, 7.0]
    cp, cm = gp.cands[0]
    assert list(cp) == [1, 4]           # id 3 dropped; id 6 shifted by 2
    assert list(cm) == [1.0, 6.0]
    assert list(ids2[cp]) == [1, 6]     # positions still point at their ids


def test_power_off_of_unpooled_server_is_a_noop():
    eng, gp = _stub_pools(grain=2)
    ids = np.arange(4, dtype=np.int64)  # servers 0..1 only
    gp.pools[0] = [ids.copy(), np.zeros(4), 4]
    gp.apply_offs([3])                  # server 3 never entered the pool
    assert gp.pools[0][2] == 4
    assert list(gp.pools[0][0]) == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# AsyncSolve handle and the pad grid.
# ---------------------------------------------------------------------------


def _toy_solver(calls):
    def fn(km):
        calls.append(km.shape[0])
        out = np.zeros((km.shape[0], SOL_COLS), np.float32)
        out[:, 0] = km[:, 0] * 2.0
        out[:, 1] = km[:, 1] + 1.0
        return out
    return fn


def _dup_keys(n_unique, n_total, seed=0):
    rng = np.random.default_rng(seed)
    uniq = rng.random((n_unique, KEY_COLS)).astype(np.float32)
    return uniq[rng.integers(0, n_unique, size=n_total)]


def test_async_solve_unique_false_matches_unique_true():
    """The pipelined chunks skip the sort-based np.unique pass
    (``unique=False``) and lean on the cache probe; results are identical
    and only the dispatched row count differs."""
    keys = _dup_keys(40, 130, seed=1)
    c_t, c_f = SolveCache(), SolveCache()
    calls_t, calls_f = [], []
    h_t = solve_rows_async(keys, _toy_solver(calls_t), tag="t", cache=c_t,
                           unique=True)
    h_f = solve_rows_async(keys, _toy_solver(calls_f), tag="t", cache=c_f,
                           unique=False)
    assert h_t.in_flight and h_f.in_flight
    assert h_t.n_missing <= 40 < h_f.n_missing == 130
    r_t, r_f = h_t.result(), h_f.result()
    assert not h_t.in_flight and not h_f.in_flight
    assert r_t.shape == r_f.shape == (130, SOL_COLS)
    assert np.array_equal(r_t, r_f)
    assert h_t.result() is r_t          # memoized


def test_async_solve_feeds_the_cache():
    keys = _dup_keys(24, 90, seed=2)
    cache = SolveCache()
    calls = []
    first = solve_rows_async(keys, _toy_solver(calls), tag="t", cache=cache,
                             unique=False).result()
    again = solve_rows_async(keys, _toy_solver(calls), tag="t", cache=cache,
                             unique=False)
    assert again.n_missing == 0         # fully served from the cache
    assert np.array_equal(again.result(), first)
    assert len(calls) == 1              # the solver ran exactly once


@pytest.mark.parametrize("k,expect", [
    (1, 8), (5, 8), (8, 8), (9, 16), (600, 1024), (1024, 1024),
    (1025, 2048), (2049, 3072)])
def test_pad_rows_shape_grid(k, expect):
    """Powers of two (>= 8) up to 1024, 1024-multiples above — so jit
    compiles a bounded family of solver shapes."""
    m = np.arange(k * 2, dtype=np.float32).reshape(k, 2)
    p = _pad_rows(m)
    assert p.shape == (expect, 2)
    assert np.array_equal(p[:k], m)
    if expect > k:
        assert np.array_equal(
            p[k:], np.broadcast_to(m[-1], (expect - k, 2)))


# ---------------------------------------------------------------------------
# Cache counters: per-run reset vs lifetime totals, hit pinning.
# ---------------------------------------------------------------------------


def test_reset_stats_preserves_lifetime_totals():
    c = SolveCache(maxsize=8)
    keys = _dup_keys(2, 2, seed=3)
    out = np.zeros((2, SOL_COLS), np.float32)
    miss, miss_keys = c.get_many("t", keys, out)
    assert (c.misses, c.misses_total) == (2, 2)
    c.put_keys(miss_keys, [np.zeros(SOL_COLS, np.float32)] * 2)
    c.get_many("t", keys, out)
    assert (c.hits, c.hits_total) == (2, 2)
    c.reset_stats()
    assert (c.hits, c.misses) == (0, 0)
    assert (c.hits_total, c.misses_total) == (2, 2)
    s = c.stats()
    assert s["hits"] == 0 and s["hits_total"] == 2


def test_eviction_counters_per_run_and_lifetime():
    c = SolveCache(maxsize=2)
    keys = _dup_keys(3, 3, seed=4)
    out = np.zeros((3, SOL_COLS), np.float32)
    _, miss_keys = c.get_many("t", keys, out)
    c.put_keys(miss_keys, [np.zeros(SOL_COLS, np.float32)] * 3)
    assert (c.evictions, c.evictions_total) == (1, 1)
    c.reset_stats()
    assert c.evictions == 0 and c.evictions_total == 1


def test_schedule_online_resets_per_run_counters():
    """Every dedup run reports its OWN counters in ``cache_stats`` — the
    cached rows persist, so a warm rerun is pure hits."""
    ts = trace(n=300, seed=10)
    solver_cache.GLOBAL_CACHE.clear()
    s1 = online.schedule_online(ts, l=2, theta=0.9,
                                bound=False).cache_stats
    s2 = online.schedule_online(ts, l=2, theta=0.9,
                                bound=False).cache_stats
    assert s1["misses"] > 0
    assert s2["misses"] == 0
    assert s2["hits"] == s1["hits"] + s1["misses"]
    assert s2["hits_total"] >= s2["hits"] + s1["hits"]


def test_duplicated_trace_cache_hits_pinned(monkeypatch):
    """A trace whose second epoch replays the first (same params, same
    DVFS windows, shifted arrivals) must be answered from the cache once
    chunk boundaries separate the epochs — and the counters are
    deterministic run to run."""
    monkeypatch.setattr(online, "PIPELINE_CHUNK_TASKS", 64)
    ts = trace(n=300, horizon=40, seed=11)
    shifted = tasks.TaskSet(ts.arrival + 40.0, ts.deadline + 40.0,
                            ts.params, ts.utilization)
    dup = ts.concat(shifted)
    kw = dict(l=2, theta=0.9, bound=False)
    solver_cache.GLOBAL_CACHE.clear()
    s1 = online.schedule_online(dup, **kw).cache_stats
    # Every second-epoch Algorithm-1 row re-probes a first-epoch key.
    assert s1["hits"] >= len(ts)
    # Cold-cache per-run counters are pinned: an identical rerun
    # reproduces them exactly (the *_total fields keep accumulating
    # across runs by design, so compare the per-run view only).
    solver_cache.GLOBAL_CACHE.clear()
    s2 = online.schedule_online(dup, **kw).cache_stats
    per_run = ("rows", "hits", "misses", "evictions", "hit_rate")
    assert {k: s2[k] for k in per_run} == {k: s1[k] for k in per_run}
