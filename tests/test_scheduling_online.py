"""Online EDL + DRS and the bin-packing baseline (paper §4.2.2, Alg 4-6)."""

import numpy as np
import pytest

from repro.core import cluster as cl, online, tasks


def small_online(seed=0):
    return tasks.generate_online(offline_util=0.02, online_util=0.05,
                                 seed=seed, horizon=200)


@pytest.mark.parametrize("alg", ["edl", "bin"])
def test_online_no_violations(alg):
    ts = small_online(1)
    r = online.schedule_online(ts, l=2, theta=0.9, algorithm=alg)
    assert r.violations == 0
    for a in r.assignments:
        assert a.finish <= ts.deadline[a.task] + 1e-6
        assert a.start >= ts.arrival[a.task] - 1e-6  # no time travel


def test_online_energy_decomposition():
    ts = small_online(2)
    r = online.schedule_online(ts, l=4, theta=0.9, algorithm="edl")
    assert r.e_total == pytest.approx(r.e_run + r.e_idle + r.e_overhead)
    assert r.e_run == pytest.approx(sum(a.energy for a in r.assignments))
    assert r.e_overhead >= 0 and r.e_idle >= 0
    # overhead is a multiple of the per-pair turn-on cost
    assert r.e_overhead % cl.DELTA_ON == pytest.approx(0.0, abs=1e-9)


def test_online_every_task_scheduled_once():
    ts = small_online(3)
    r = online.schedule_online(ts, l=2, algorithm="edl")
    seen = sorted(a.task for a in r.assignments)
    assert seen == list(range(len(ts)))


def test_online_pairs_never_overlap():
    ts = small_online(4)
    r = online.schedule_online(ts, l=2, algorithm="edl")
    by_pair = {}
    for a in r.assignments:
        by_pair.setdefault(a.pair, []).append((a.start, a.finish))
    for spans in by_pair.values():
        spans.sort()
        for (s1, f1), (s2, f2) in zip(spans, spans[1:]):
            assert s2 >= f1 - 1e-6


def test_online_dvfs_saves_runtime_energy():
    """§5.4.2: GPU DVFS cuts ~1/3 of online runtime energy."""
    ts = small_online(5)
    r_d = online.schedule_online(ts, l=1, algorithm="edl", use_dvfs=True)
    r_n = online.schedule_online(ts, l=1, algorithm="edl", use_dvfs=False)
    assert r_d.violations == 0 and r_n.violations == 0
    saving = 1 - r_d.e_run / r_n.e_run
    assert 0.25 < saving < 0.40, saving


def test_drs_turns_servers_off():
    """With sparse arrivals the DRS sweep must power servers off between
    bursts (bounded idle energy)."""
    ts = small_online(6)
    r = online.schedule_online(ts, l=1, algorithm="edl")
    # idle upper bound: every pair idles at most ~rho per busy interval +
    # the final rho tail; a gross violation means DRS never fired.
    n_tasks = len(ts)
    bound = cl.P_IDLE * (cl.RHO + 1) * (n_tasks + r.n_pairs) * 2
    assert r.e_idle <= bound


def test_theta_readjustment_reduces_total_energy_online():
    tot1, tot09 = [], []
    for seed in range(3):
        ts = small_online(10 + seed)
        r1 = online.schedule_online(ts, l=16, theta=1.0, algorithm="edl")
        r09 = online.schedule_online(ts, l=16, theta=0.9, algorithm="edl")
        tot1.append(r1.e_total)
        tot09.append(r09.e_total)
    assert np.mean(tot09) <= np.mean(tot1) * 1.005
