"""repro-lint (tools/lint): pass/fail fixtures per rule family, the
suppression mechanism, the layer-DAG data, and a self-check that the repo
itself lints clean.

Every rule family gets at least one fixture that MUST fail and one that
MUST pass, so a rule that silently stops firing (or starts flagging idiom
the repo depends on) breaks this gate, not a future refactor.
"""

import json
import os
import subprocess
import sys

import pytest

from tools.lint import layer_dag, lint_source

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def findings(src, module, select=None):
    return lint_source(src, module=module, select=select)


def rules_of(fs):
    return {f.rule for f in fs}


# ---------------------------------------------------------------------------
# layer-contract
# ---------------------------------------------------------------------------


def test_layer_contract_flags_up_layer_import():
    fs = findings("from repro.core import scheduling\n",
                  module="repro.kernels.dvfs_opt")
    assert rules_of(fs) == {"layer-contract"}
    assert "UP-layer" in fs[0].message


def test_layer_contract_flags_lazy_up_layer_import():
    src = ("def f():\n"
           "    from repro.core.placement import PlacementContext\n")
    fs = findings(src, module="repro.core.engine")
    assert rules_of(fs) == {"layer-contract"}


def test_layer_contract_allows_same_and_down_layer():
    src = ("from repro.core import bounds\n"           # same layer
           "from repro.core.engine import ClusterEngine\n"  # deeper
           "from repro.core.dvfs import DvfsParams\n"  # shared leaf
           "from repro.kernels import layout\n")       # shared leaf
    assert findings(src, module="repro.core.scheduling") == []


def test_layer_contract_flags_out_of_dag_module():
    fs = findings("from repro.models.ssm import ssd_reference\n",
                  module="repro.core.engine")
    assert rules_of(fs) == {"layer-contract"}
    assert "outside the scheduler-stack DAG" in fs[0].message


def test_layer_contract_allows_documented_extra_edge():
    # kernels/ref.py -> models/ssm.py is a documented EXTRA_EDGES entry.
    assert findings("from repro.models.ssm import ssd_reference\n",
                    module="repro.kernels.ref") == []


def test_layer_contract_shared_leaf_imports_only_shared():
    assert findings("from repro.core.dvfs import DvfsParams\n",
                    module="repro.core.tasks") == []
    fs = findings("from repro.core import engine\n",
                  module="repro.core.tasks")
    assert rules_of(fs) == {"layer-contract"}
    assert "shared leaf" in fs[0].message


def test_layer_contract_flags_private_name_import():
    fs = findings("from repro.kernels.dvfs_opt import _PAD_ROW\n",
                  module="repro.core.solver_cache")
    assert any("private name" in f.message for f in fs)


def test_layer_dag_matches_repo_modules():
    """Every ranked/shared module in the DAG data actually exists."""
    for mod in list(layer_dag.RANK) + sorted(layer_dag.SHARED):
        rel = mod.replace(".", "/") + ".py"
        assert os.path.exists(os.path.join(REPO, "src", rel)), mod


# ---------------------------------------------------------------------------
# matrix-schema
# ---------------------------------------------------------------------------


def test_matrix_schema_flags_raw_column_index():
    fs = findings("e = rows[:, 5]\n", module="repro.core.bounds")
    assert rules_of(fs) == {"matrix-schema"}


def test_matrix_schema_flags_raw_column_slice():
    fs = findings("b = tasks[:, 8:13]\n", module="repro.kernels.ref")
    assert rules_of(fs) == {"matrix-schema"}


def test_matrix_schema_allows_named_columns_and_variables():
    src = ("from repro.kernels import layout\n"
           "e = rows[:, layout.SOL_E]\n"
           "p = km[:, i]\n"
           "x = t[:, None]\n"
           "w = mat.shape[1]\n")
    assert findings(src, module="repro.core.solver_cache",
                    select=["matrix-schema"]) == []


def test_matrix_schema_out_of_scope_module_not_flagged():
    # models code indexes its own tensors freely.
    assert findings("y = x[:, 0]\n", module="repro.models.model") == []


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------


def test_determinism_flags_legacy_global_rng():
    fs = findings("import numpy as np\nx = np.random.rand(4)\n",
                  module="repro.core.tasks")
    assert rules_of(fs) == {"determinism"}


def test_determinism_flags_unseeded_default_rng():
    fs = findings("import numpy as np\nr = np.random.default_rng()\n",
                  module="repro.core.faults")
    assert rules_of(fs) == {"determinism"}
    assert "without a seed" in fs[0].message


def test_determinism_allows_seeded_generator():
    assert findings("import numpy as np\nr = np.random.default_rng(7)\n",
                    module="repro.core.faults") == []


def test_determinism_flags_stdlib_random():
    fs = findings("import random\nx = random.random()\n",
                  module="repro.core.jobs")
    assert rules_of(fs) == {"determinism"}


def test_determinism_flags_wall_clock_in_core():
    fs = findings("import time\nt = time.time()\n",
                  module="repro.core.engine")
    assert rules_of(fs) == {"determinism"}


def test_determinism_wall_clock_ok_outside_core_and_kernels():
    # launch/train instrumentation may read the clock.
    assert findings("import time\nt = time.time()\n",
                    module="repro.launch.run") == []


def test_determinism_flags_mutable_default_in_core():
    fs = findings("def f(x=[]):\n    return x\n",
                  module="repro.core.placement")
    assert rules_of(fs) == {"determinism"}


def test_determinism_flags_traced_float_and_if_in_kernel_body():
    src = ("def _kernel(t_ref, o_ref):\n"
           "    t = t_ref[...]\n"
           "    a = t * 2.0\n"
           "    if a.sum() > 0:\n"
           "        pass\n"
           "    y = float(a)\n"
           "    z = a.item()\n")
    fs = findings(src, module="repro.kernels.dvfs_opt",
                  select=["determinism"])
    msgs = " | ".join(f.message for f in fs)
    assert "control flow on a traced value" in msgs
    assert "float() on a traced value" in msgs
    assert ".item() on a traced value" in msgs


def test_determinism_static_conditional_in_kernel_body_ok():
    src = ("def _kernel(t_ref, o_ref, *, causal=True):\n"
           "    t = t_ref[...]\n"
           "    if causal:\n"
           "        t = t + 1.0\n"
           "    o_ref[...] = t\n")
    assert findings(src, module="repro.kernels.flash_attention",
                    select=["determinism"]) == []


PREFETCH_SRC = ("import numpy as np\n"
                "# lint: prefetch-region-begin\n"
                "{body}"
                "# lint: prefetch-region-end\n")


def test_determinism_flags_blocking_asarray_in_prefetch_region():
    src = PREFETCH_SRC.format(body=(
        "def consume(handle):\n"
        "    return np.asarray(handle)\n"))
    fs = findings(src, module="repro.core.online", select=["determinism"])
    assert rules_of(fs) == {"determinism"}
    assert "prefetch region" in fs[0].message


def test_determinism_flags_block_until_ready_in_prefetch_region():
    src = PREFETCH_SRC.format(body=(
        "def drain(rows):\n"
        "    rows.block_until_ready()\n"))
    fs = findings(src, module="repro.core.online", select=["determinism"])
    assert rules_of(fs) == {"determinism"}
    assert "block_until_ready" in fs[0].message


def test_determinism_flags_device_get_in_prefetch_region():
    src = PREFETCH_SRC.format(body=(
        "import jax\n"
        "def peek(x):\n"
        "    return jax.device_get(x)\n"))
    fs = findings(src, module="repro.core.online", select=["determinism"])
    assert rules_of(fs) == {"determinism"}


def test_determinism_sync_suffixed_method_may_block_in_region():
    src = PREFETCH_SRC.format(body=(
        "def consume_sync(handle):\n"
        "    return np.asarray(handle)\n"))
    assert findings(src, module="repro.core.online",
                    select=["determinism"]) == []


def test_determinism_blocking_call_outside_region_ok():
    src = ("import numpy as np\n"
           "def f(x):\n"
           "    return np.asarray(x)\n")
    assert findings(src, module="repro.core.online",
                    select=["determinism"]) == []


# ---------------------------------------------------------------------------
# dtype-discipline
# ---------------------------------------------------------------------------


def test_dtype_flags_dtypeless_constructor_in_kernels():
    fs = findings("import jax.numpy as jnp\nz = jnp.zeros((4, 4))\n",
                  module="repro.kernels.ops")
    assert rules_of(fs) == {"dtype-discipline"}


def test_dtype_flags_float64_in_kernels():
    fs = findings("import jax.numpy as jnp\n"
                  "z = jnp.zeros((4,), jnp.float64)\n",
                  module="repro.kernels.dvfs_opt")
    assert rules_of(fs) == {"dtype-discipline"}


def test_dtype_allows_explicit_f32_and_like_constructors():
    src = ("import jax.numpy as jnp\n"
           "a = jnp.zeros((4,), jnp.float32)\n"
           "b = jnp.full((4,), 0.5, dtype=jnp.float32)\n"
           "c = jnp.zeros_like(a)\n"
           "d = jnp.asarray(a)\n")
    assert findings(src, module="repro.kernels.ops",
                    select=["dtype-discipline"]) == []


def test_dtype_out_of_scope_in_core():
    assert findings("import numpy as np\nz = np.zeros((4, 4))\n",
                    module="repro.core.engine") == []


# ---------------------------------------------------------------------------
# suppressions and runner
# ---------------------------------------------------------------------------


def test_inline_suppression_silences_named_rule_only():
    line = "e = rows[:, 5]  # lint: disable=matrix-schema\n"
    assert findings(line, module="repro.core.bounds") == []
    # A different rule name does NOT suppress it.
    other = "e = rows[:, 5]  # lint: disable=determinism\n"
    assert rules_of(findings(other, module="repro.core.bounds")) == \
        {"matrix-schema"}


def test_suppression_disable_all():
    line = "e = rows[:, 5]  # lint: disable=all\n"
    assert findings(line, module="repro.core.bounds") == []


def test_select_limits_rule_families():
    src = "import numpy as np\nx = np.random.rand(4)\ne = rows[:, 5]\n"
    only_schema = findings(src, module="repro.core.bounds",
                           select=["matrix-schema"])
    assert rules_of(only_schema) == {"matrix-schema"}


def test_syntax_error_reported_as_parse_finding():
    fs = lint_source("def broken(:\n", path="x.py")
    assert fs and fs[0].rule == "parse"


@pytest.mark.parametrize("extra", [[], ["--json"]])
def test_repo_lints_clean_via_module_runner(extra):
    """`python -m tools.lint` exits 0 on the repo (the CI gate)."""
    proc = subprocess.run(
        [sys.executable, "-m", "tools.lint", *extra],
        cwd=REPO, capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    if extra:
        assert json.loads(proc.stdout) == []


def test_runner_rejects_unknown_rule():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.lint", "--select", "no-such-rule"],
        cwd=REPO, capture_output=True, text=True, timeout=60)
    assert proc.returncode == 2
    assert "unknown rule" in proc.stderr


def test_runner_lists_rules():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.lint", "--list-rules"],
        cwd=REPO, capture_output=True, text=True, timeout=60)
    assert proc.returncode == 0
    listed = set(proc.stdout.split())
    assert listed == {"layer-contract", "matrix-schema", "determinism",
                      "dtype-discipline"}
