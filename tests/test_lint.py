"""repro-lint (tools/lint): pass/fail fixtures per rule family, the
suppression mechanism, the layer-DAG data, and a self-check that the repo
itself lints clean.

Every rule family — including the flow-sensitive tier (pallas-hazard,
async-protocol, shape-flow) — gets at least one fixture that MUST fail and
one that MUST pass, so a rule that silently stops firing (or starts
flagging idiom the repo depends on) breaks this gate, not a future
refactor.  The differential mutation corpus (tools/lint/selfcheck.py) is
parametrized in at the bottom: every seeded bug in a copy of the real
sources must be caught by the expected rule.
"""

import json
import os
import subprocess
import sys

import pytest

from tools.lint import layer_dag, lint_source, selfcheck

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def findings(src, module, select=None):
    return lint_source(src, module=module, select=select)


def rules_of(fs):
    return {f.rule for f in fs}


# ---------------------------------------------------------------------------
# layer-contract
# ---------------------------------------------------------------------------


def test_layer_contract_flags_up_layer_import():
    fs = findings("from repro.core import scheduling\n",
                  module="repro.kernels.dvfs_opt")
    assert rules_of(fs) == {"layer-contract"}
    assert "UP-layer" in fs[0].message


def test_layer_contract_flags_lazy_up_layer_import():
    src = ("def f():\n"
           "    from repro.core.placement import PlacementContext\n")
    fs = findings(src, module="repro.core.engine")
    assert rules_of(fs) == {"layer-contract"}


def test_layer_contract_allows_same_and_down_layer():
    src = ("from repro.core import bounds\n"           # same layer
           "from repro.core.engine import ClusterEngine\n"  # deeper
           "from repro.core.dvfs import DvfsParams\n"  # shared leaf
           "from repro.kernels import layout\n")       # shared leaf
    assert findings(src, module="repro.core.scheduling") == []


def test_layer_contract_flags_out_of_dag_module():
    fs = findings("from repro.models.ssm import ssd_reference\n",
                  module="repro.core.engine")
    assert rules_of(fs) == {"layer-contract"}
    assert "outside the scheduler-stack DAG" in fs[0].message


def test_layer_contract_allows_documented_extra_edge():
    # kernels/ref.py -> models/ssm.py is a documented EXTRA_EDGES entry.
    assert findings("from repro.models.ssm import ssd_reference\n",
                    module="repro.kernels.ref") == []


def test_layer_contract_shared_leaf_imports_only_shared():
    assert findings("from repro.core.dvfs import DvfsParams\n",
                    module="repro.core.tasks") == []
    fs = findings("from repro.core import engine\n",
                  module="repro.core.tasks")
    assert rules_of(fs) == {"layer-contract"}
    assert "shared leaf" in fs[0].message


def test_layer_contract_flags_private_name_import():
    fs = findings("from repro.kernels.dvfs_opt import _PAD_ROW\n",
                  module="repro.core.solver_cache")
    assert any("private name" in f.message for f in fs)


def test_layer_dag_matches_repo_modules():
    """Every ranked/shared module in the DAG data actually exists."""
    for mod in list(layer_dag.RANK) + sorted(layer_dag.SHARED):
        rel = mod.replace(".", "/") + ".py"
        assert os.path.exists(os.path.join(REPO, "src", rel)), mod


# ---------------------------------------------------------------------------
# matrix-schema
# ---------------------------------------------------------------------------


def test_matrix_schema_flags_raw_column_index():
    fs = findings("e = rows[:, 5]\n", module="repro.core.bounds")
    assert rules_of(fs) == {"matrix-schema"}


def test_matrix_schema_flags_raw_column_slice():
    fs = findings("b = tasks[:, 8:13]\n", module="repro.kernels.ref")
    assert rules_of(fs) == {"matrix-schema"}


def test_matrix_schema_allows_named_columns_and_variables():
    src = ("from repro.kernels import layout\n"
           "e = rows[:, layout.SOL_E]\n"
           "p = km[:, i]\n"
           "x = t[:, None]\n"
           "w = mat.shape[1]\n")
    assert findings(src, module="repro.core.solver_cache",
                    select=["matrix-schema"]) == []


def test_matrix_schema_out_of_scope_module_not_flagged():
    # models code indexes its own tensors freely.
    assert findings("y = x[:, 0]\n", module="repro.models.model") == []


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------


def test_determinism_flags_legacy_global_rng():
    fs = findings("import numpy as np\nx = np.random.rand(4)\n",
                  module="repro.core.tasks")
    assert rules_of(fs) == {"determinism"}


def test_determinism_flags_unseeded_default_rng():
    fs = findings("import numpy as np\nr = np.random.default_rng()\n",
                  module="repro.core.faults")
    assert rules_of(fs) == {"determinism"}
    assert "without a seed" in fs[0].message


def test_determinism_allows_seeded_generator():
    assert findings("import numpy as np\nr = np.random.default_rng(7)\n",
                    module="repro.core.faults") == []


def test_determinism_flags_stdlib_random():
    fs = findings("import random\nx = random.random()\n",
                  module="repro.core.jobs")
    assert rules_of(fs) == {"determinism"}


def test_determinism_flags_wall_clock_in_core():
    fs = findings("import time\nt = time.time()\n",
                  module="repro.core.engine")
    assert rules_of(fs) == {"determinism"}


def test_determinism_wall_clock_ok_outside_core_and_kernels():
    # launch/train instrumentation may read the clock.
    assert findings("import time\nt = time.time()\n",
                    module="repro.launch.run") == []


def test_determinism_flags_mutable_default_in_core():
    fs = findings("def f(x=[]):\n    return x\n",
                  module="repro.core.placement")
    assert rules_of(fs) == {"determinism"}


def test_determinism_flags_traced_float_and_if_in_kernel_body():
    src = ("def _kernel(t_ref, o_ref):\n"
           "    t = t_ref[...]\n"
           "    a = t * 2.0\n"
           "    if a.sum() > 0:\n"
           "        pass\n"
           "    y = float(a)\n"
           "    z = a.item()\n")
    fs = findings(src, module="repro.kernels.dvfs_opt",
                  select=["determinism"])
    msgs = " | ".join(f.message for f in fs)
    assert "control flow on a traced value" in msgs
    assert "float() on a traced value" in msgs
    assert ".item() on a traced value" in msgs


def test_determinism_static_conditional_in_kernel_body_ok():
    src = ("def _kernel(t_ref, o_ref, *, causal=True):\n"
           "    t = t_ref[...]\n"
           "    if causal:\n"
           "        t = t + 1.0\n"
           "    o_ref[...] = t\n")
    assert findings(src, module="repro.kernels.flash_attention",
                    select=["determinism"]) == []


# ---------------------------------------------------------------------------
# pallas-hazard (flow-sensitive)
# ---------------------------------------------------------------------------


def _pallas_module(kernel: str) -> str:
    """A kernel body plus the pallas_call site that classifies its refs:
    one input ref of width NCOL, one output ref of width SOL_COLS."""
    return (
        "import functools\n"
        "from jax.experimental import pallas as pl\n"
        "from repro.kernels.layout import (\n"
        "    NCOL, SOL_COLS, ALLOWED, FM_MAX, PARAMS_SLICE, col)\n"
        + kernel +
        "def run(tasks):\n"
        "    return pl.pallas_call(\n"
        "        functools.partial(_kernel),\n"
        "        in_specs=[pl.BlockSpec((8, NCOL), lambda i: (i, 0))],\n"
        "        out_specs=pl.BlockSpec((8, SOL_COLS), lambda i: (i, 0)),\n"
        "    )(tasks)\n")


def hazards(kernel):
    return findings(_pallas_module(kernel), module="repro.kernels.dvfs_opt",
                    select=["pallas-hazard"])


def test_pallas_hazard_flags_read_after_write():
    fs = hazards("def _kernel(tasks_ref, out_ref):\n"
                 "    out_ref[...] = tasks_ref[...] * 2.0\n"
                 "    y = out_ref[...] + 1.0\n"
                 "    out_ref[...] = y\n")
    assert rules_of(fs) == {"pallas-hazard"}
    assert any("read-after-write" in f.message for f in fs)


def test_pallas_hazard_flags_store_to_input_ref():
    fs = hazards("def _kernel(tasks_ref, out_ref):\n"
                 "    t = tasks_ref[...]\n"
                 "    out_ref[...] = t\n"
                 "    tasks_ref[...] = t * 2.0\n")
    assert rules_of(fs) == {"pallas-hazard"}
    assert any("store to input ref tasks_ref" in f.message for f in fs)


def test_pallas_hazard_flags_partial_write_after_read():
    fs = hazards("def _kernel(tasks_ref, out_ref):\n"
                 "    acc = out_ref[...]\n"
                 "    out_ref[:, col(0)] = acc[:, col(0)] * 2.0\n")
    assert any("write-after-read" in f.message for f in fs)


def test_pallas_hazard_flags_group_cross_and_oob_columns():
    fs = hazards("def _kernel(tasks_ref, out_ref):\n"
                 "    t = tasks_ref[...]\n"
                 "    bad = t[:, ALLOWED:FM_MAX]\n"
                 "    oob = t[:, NCOL]\n"
                 "    out_ref[...] = t * 0.0\n")
    msgs = " | ".join(f.message for f in fs)
    assert "crosses a layout.py column-group boundary" in msgs
    assert "out of bounds" in msgs


def test_pallas_hazard_clean_kernel_idiom_passes():
    # Full-ref load with .astype, whole-group column reads, same-statement
    # RMW on the output ref: the idiom every shipped kernel uses.
    fs = hazards("def _kernel(tasks_ref, out_ref):\n"
                 "    t = tasks_ref[...].astype(out_ref.dtype)\n"
                 "    p = t[:, PARAMS_SLICE]\n"
                 "    a = t[:, col(ALLOWED)]\n"
                 "    out_ref[...] = out_ref[...] * 0.0 + 1.0\n")
    assert fs == []


def test_pallas_hazard_barrier_clears_hazard_state():
    fs = hazards("def _kernel(tasks_ref, out_ref):\n"
                 "    out_ref[...] = tasks_ref[...] * 2.0\n"
                 "    pl.debug_barrier()\n"
                 "    y = out_ref[...] + 1.0\n"
                 "    out_ref[...] = y\n")
    assert fs == []


# ---------------------------------------------------------------------------
# async-protocol (flow-sensitive; retires the prefetch-region markers)
# ---------------------------------------------------------------------------


def protocol(src):
    return findings(src, module="repro.core.online",
                    select=["async-protocol"])


def test_async_protocol_flags_dropped_handle():
    fs = protocol("def fetch(keys, solve):\n"
                  "    handle = solve_rows_async(keys, solve)\n"
                  "    return None\n")
    assert rules_of(fs) == {"async-protocol"}
    assert "never reaches result()" in fs[0].message


def test_async_protocol_flags_rebind_of_live_handle():
    fs = protocol("def fetch(keys, more, solve):\n"
                  "    handle = solve_rows_async(keys, solve)\n"
                  "    handle = solve_rows_async(more, solve)\n"
                  "    return handle.result()\n")
    assert any("rebound while it may still hold a live" in f.message
               for f in fs)


def test_async_protocol_flags_double_consume():
    fs = protocol("def fetch(keys, solve):\n"
                  "    handle = solve_rows_async(keys, solve)\n"
                  "    first = handle.result()\n"
                  "    return handle.result()\n")
    assert any("already be consumed" in f.message for f in fs)


def test_async_protocol_consume_and_escape_pass():
    src = ("def fetch(keys, solve):\n"
           "    handle = solve_rows_async(keys, solve)\n"
           "    return handle.result()\n"
           "def hand_off(keys, solve, batches):\n"
           "    handle = solve_rows_async(keys, solve)\n"
           "    batches.append((keys, handle))\n"
           "def conditional(keys, solve, want):\n"
           "    handle = solve_rows_async(keys, solve) if want else None\n"
           "    if handle is not None:\n"
           "        consume_sync(handle)\n")
    assert protocol(src) == []


def test_async_protocol_flags_blocking_call_in_window():
    fs = protocol("import numpy as np\n"
                  "def drive(state, chunks):\n"
                  "    for span in chunks:\n"
                  "        state.dispatch(span)\n"
                  "    rows = np.asarray(chunks)\n"
                  "    return rows\n")
    assert rules_of(fs) == {"async-protocol"}
    assert "blocks on device results" in fs[0].message


def test_async_protocol_blocking_before_dispatch_and_in_sync_fn_pass():
    src = ("import numpy as np\n"
           "def drive(state, chunks):\n"
           "    arr = np.asarray(chunks)\n"
           "    state.dispatch(arr)\n"
           "def consume_sync(state, handle):\n"
           "    state.dispatch(handle)\n"
           "    return np.asarray(handle)\n")
    assert protocol(src) == []


def test_async_protocol_flags_view_read_before_sync():
    fs = protocol("def drive(state, ctx, spans):\n"
                  "    handle = state.dispatch(spans[0])\n"
                  "    ctx.update_tasks(spans[0])\n"
                  "    state.consume_sync(handle, spans[0])\n")
    assert any("full-horizon view" in f.message for f in fs)


def test_async_protocol_view_read_after_sync_passes():
    src = ("def drive(state, ctx, spans):\n"
           "    handle = state.dispatch(spans[0])\n"
           "    state.consume_sync(handle, spans[0])\n"
           "    ctx.update_tasks(spans[0])\n")
    assert protocol(src) == []


def test_async_protocol_flags_retired_prefetch_marker():
    fs = protocol("# lint: prefetch-region-begin\nx = 1\n")
    assert rules_of(fs) == {"async-protocol"}
    assert "retired prefetch-region marker" in fs[0].message


def test_async_protocol_out_of_scope_module_silent():
    src = ("def fetch(keys, solve):\n"
           "    handle = solve_rows_async(keys, solve)\n"
           "    return None\n")
    assert findings(src, module="repro.core.engine",
                    select=["async-protocol"]) == []


# ---------------------------------------------------------------------------
# shape-flow (flow-sensitive)
# ---------------------------------------------------------------------------


def shapes(src):
    return findings(
        "from repro.core import solver_cache\n"
        "from repro.kernels import layout\n" + src,
        module="repro.core.solver_cache", select=["shape-flow"])


def test_shape_flow_flags_truncated_key_matrix():
    fs = shapes("def f(params, allowed, boundary, bounds, solve):\n"
                "    keys = solver_cache.build_keys(\n"
                "        params, allowed, boundary, bounds)\n"
                "    return solver_cache.solve_rows_async(\n"
                "        keys[:, layout.PARAMS_SLICE], solve)\n")
    assert rules_of(fs) == {"shape-flow"}
    assert "key-matrix contract" in fs[0].message
    assert "[n, 6]" in fs[0].message


def test_shape_flow_flags_float64_key_matrix():
    fs = shapes("import numpy as np\n"
                "def f(keys, solve):\n"
                "    k64 = np.asarray(keys, np.float64)\n"
                "    return solve_rows(k64, solve)\n")
    assert any("float32" in f.message for f in fs)


def test_shape_flow_flags_key_width_into_kernel_entry():
    fs = shapes("def g(params, allowed, boundary, bounds, kernel_ops):\n"
                "    keys = solver_cache.build_keys(\n"
                "        params, allowed, boundary, bounds)\n"
                "    return kernel_ops.dvfs_solve_kernel(keys)\n")
    assert any("dvfs_solve_kernel()" in f.message for f in fs)


def test_shape_flow_correct_and_unknown_widths_pass():
    fs = shapes("def f(params, allowed, boundary, bounds, solve):\n"
                "    keys = solver_cache.build_keys(\n"
                "        params, allowed, boundary, bounds)\n"
                "    return solver_cache.solve_rows_async(keys, solve)\n"
                "def passthrough(keys, solve):\n"
                "    return solver_cache.solve_rows(keys, solve)\n")
    assert fs == []


def test_shape_flow_branch_join_degrades_to_unknown():
    # Different widths on the two arms: the join loses the fact, and the
    # rule stays silent rather than guessing.
    fs = shapes("def f(params, allowed, boundary, bounds, solve, legacy):\n"
                "    keys = solver_cache.build_keys(\n"
                "        params, allowed, boundary, bounds)\n"
                "    if legacy:\n"
                "        keys = keys[:, layout.PARAMS_SLICE]\n"
                "    return solver_cache.solve_rows_async(keys, solve)\n")
    assert fs == []


# ---------------------------------------------------------------------------
# dtype-discipline
# ---------------------------------------------------------------------------


def test_dtype_flags_dtypeless_constructor_in_kernels():
    fs = findings("import jax.numpy as jnp\nz = jnp.zeros((4, 4))\n",
                  module="repro.kernels.ops")
    assert rules_of(fs) == {"dtype-discipline"}


def test_dtype_flags_float64_in_kernels():
    fs = findings("import jax.numpy as jnp\n"
                  "z = jnp.zeros((4,), jnp.float64)\n",
                  module="repro.kernels.dvfs_opt")
    assert rules_of(fs) == {"dtype-discipline"}


def test_dtype_allows_explicit_f32_and_like_constructors():
    src = ("import jax.numpy as jnp\n"
           "a = jnp.zeros((4,), jnp.float32)\n"
           "b = jnp.full((4,), 0.5, dtype=jnp.float32)\n"
           "c = jnp.zeros_like(a)\n"
           "d = jnp.asarray(a)\n")
    assert findings(src, module="repro.kernels.ops",
                    select=["dtype-discipline"]) == []


def test_dtype_out_of_scope_in_core():
    assert findings("import numpy as np\nz = np.zeros((4, 4))\n",
                    module="repro.core.engine") == []


# ---------------------------------------------------------------------------
# suppressions and runner
# ---------------------------------------------------------------------------


def test_inline_suppression_silences_named_rule_only():
    line = "e = rows[:, 5]  # lint: disable=matrix-schema\n"
    assert findings(line, module="repro.core.bounds") == []
    # A different rule name does NOT suppress it — and is itself flagged
    # as a stale suppression.
    other = "e = rows[:, 5]  # lint: disable=determinism\n"
    assert rules_of(findings(other, module="repro.core.bounds")) == \
        {"matrix-schema", "unused-suppression"}


def test_unused_suppression_flagged():
    src = "x = 1  # lint: disable=matrix-schema\n"
    fs = findings(src, module="repro.core.bounds")
    assert rules_of(fs) == {"unused-suppression"}
    assert "does not suppress any finding" in fs[0].message


def test_typod_rule_name_in_suppression_flagged():
    src = "e = rows[:, 5]  # lint: disable=matrx-schema\n"
    fs = findings(src, module="repro.core.bounds")
    assert rules_of(fs) == {"matrix-schema", "unused-suppression"}


def test_unused_suppression_meta_check_skipped_under_select():
    # --select runs a subset of families, so a suppression for an
    # unselected rule cannot be proven stale.
    src = "x = 1  # lint: disable=matrix-schema\n"
    assert findings(src, module="repro.core.bounds",
                    select=["matrix-schema"]) == []


def test_suppression_mention_in_docstring_not_parsed():
    src = ('"""prose mentioning # lint: disable=matrix-schema only."""\n'
           "x = 1\n")
    assert findings(src, module="repro.core.bounds") == []


def test_suppression_disable_all():
    line = "e = rows[:, 5]  # lint: disable=all\n"
    assert findings(line, module="repro.core.bounds") == []


def test_select_limits_rule_families():
    src = "import numpy as np\nx = np.random.rand(4)\ne = rows[:, 5]\n"
    only_schema = findings(src, module="repro.core.bounds",
                           select=["matrix-schema"])
    assert rules_of(only_schema) == {"matrix-schema"}


def test_syntax_error_reported_as_parse_finding():
    fs = lint_source("def broken(:\n", path="x.py")
    assert fs and fs[0].rule == "parse"


@pytest.mark.parametrize("extra", [[], ["--json"]])
def test_repo_lints_clean_via_module_runner(extra):
    """`python -m tools.lint` exits 0 on the repo (the CI gate)."""
    proc = subprocess.run(
        [sys.executable, "-m", "tools.lint", *extra],
        cwd=REPO, capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    if extra:
        assert json.loads(proc.stdout) == []


def test_runner_rejects_unknown_rule():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.lint", "--select", "no-such-rule"],
        cwd=REPO, capture_output=True, text=True, timeout=60)
    assert proc.returncode == 2
    assert "unknown rule" in proc.stderr


def test_runner_lists_rules():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.lint", "--list-rules"],
        cwd=REPO, capture_output=True, text=True, timeout=60)
    assert proc.returncode == 0
    listed = set(proc.stdout.split())
    assert listed == {"layer-contract", "matrix-schema", "determinism",
                      "dtype-discipline", "pallas-hazard", "async-protocol",
                      "shape-flow", "unused-suppression"}


def test_runner_selects_flow_families_json():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.lint", "--select",
         "pallas-hazard,async-protocol,shape-flow", "--json"],
        cwd=REPO, capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert json.loads(proc.stdout) == []


def test_no_prefetch_region_markers_survive():
    """The comment markers are retired; the guarantee is derived by the
    async-protocol dataflow (fixtures above)."""
    for dirpath, _dirs, files in os.walk(os.path.join(REPO, "src",
                                                      "repro")):
        for fname in files:
            if not fname.endswith(".py"):
                continue
            text = open(os.path.join(dirpath, fname)).read()
            assert "prefetch-region-begin" not in text, fname
            assert "prefetch-region-end" not in text, fname


# ---------------------------------------------------------------------------
# differential mutation corpus (tools/lint/selfcheck.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mutation", selfcheck.MUTATIONS,
                         ids=lambda m: m.name)
def test_selfcheck_mutation_caught(mutation):
    """Each seeded bug in a copy of the real sources is (a) absent from
    the pristine file and (b) caught by exactly the expected rule."""
    assert selfcheck.baseline_clean(mutation), \
        f"pristine {mutation.path} already matches {mutation.expect!r}"
    caught, all_findings = selfcheck.run_one(mutation)
    assert caught, ("mutation not caught; findings: "
                    + "; ".join(f.render() for f in all_findings))
