"""Offline EDL θ-readjustment scheduling (paper §4.2.1, Algorithms 2-3)."""

import numpy as np
import pytest

from repro.core import cluster as cl, scheduling, tasks
from repro.core.dvfs import DvfsParams
from repro.core.tasks import TaskSet


def paper_table3_task_set() -> TaskSet:
    """The worked example of §4.2 (Table 3): five tasks with
    P = 100 + 50 fm + 150 V^2 fc (gamma=0 in the example's energy math),
    t = 25 (delta/fc + (1-delta)/fm) + 5."""
    deltas = [0.0, 1.0, 0.5, 0.8, 0.2]
    deadlines = [50.0, 36.0, 60.0, 100.0, 300.0]
    rows = [DvfsParams(p0=100.0, gamma=0.0, c=200.0, big_d=25.0,
                       delta=d, t0=5.0) for d in deltas]
    params = DvfsParams.stack(rows)
    arrival = np.zeros(5)
    return TaskSet(arrival=arrival, deadline=np.asarray(deadlines),
                   params=params, utilization=np.full(5, 0.5))


def test_table3_deadline_prior_classification():
    ts = paper_table3_task_set()
    cfg = scheduling.configure(ts, use_dvfs=True)
    # J2 (delta=1.0, d=36) is the deadline-prior one in the paper's example
    assert bool(cfg.deadline_prior[1])
    assert cfg.n_deadline_prior == 1
    # its execution time is pinned to the deadline
    assert cfg.t_hat[1] == pytest.approx(36.0, abs=1e-3)


def test_table3_theta_readjustment_packs_two_pairs():
    """§4.2 worked example: θ=0.9 packs five tasks onto 2 pairs; θ=1 needs 3."""
    ts = paper_table3_task_set()
    r_tight = scheduling.schedule_offline(ts, l=2, theta=0.9, algorithm="edl")
    r_loose = scheduling.schedule_offline(ts, l=2, theta=1.0, algorithm="edl")
    assert r_tight.violations == 0 and r_loose.violations == 0
    assert r_tight.n_pairs == 2
    assert r_loose.n_pairs == 3
    assert r_tight.e_total < r_loose.e_total


@pytest.mark.parametrize("alg", ["edl", "edf-wf", "edf-bf", "lpt-ff"])
def test_no_deadline_violations(alg):
    ts = tasks.generate_offline(0.1, seed=3)
    r = scheduling.schedule_offline(ts, l=2, theta=0.9, algorithm=alg)
    assert r.violations == 0
    deadline = ts.deadline
    for a in r.assignments:
        assert a.finish <= deadline[a.task] + 1e-6


def test_energy_accounting_identity():
    """E_run equals the sum of assignment energies; E_idle matches a direct
    recomputation from pair busy intervals (Eq. 6)."""
    ts = tasks.generate_offline(0.08, seed=11)
    r = scheduling.schedule_offline(ts, l=4, theta=0.9, algorithm="edl")
    assert r.e_run == pytest.approx(sum(a.energy for a in r.assignments))
    # recompute idle energy via Algorithm 3 from the assignment list
    mu = {}
    for a in r.assignments:
        mu[a.pair] = max(mu.get(a.pair, 0.0), a.finish)
    e_idle, n_srv = cl.offline_idle_energy(np.asarray(list(mu.values())), 4)
    assert r.e_idle == pytest.approx(e_idle)
    assert r.n_servers == n_srv


def test_dvfs_saves_vs_baseline():
    """Offline DVFS saving close to the paper's ~33.5% at l=1 (§5.3.2)."""
    lib = tasks.app_library()
    savings = []
    for seed in range(3):
        ts = tasks.generate_offline(0.3, seed=seed, library=lib)
        base = cl.baseline_energy(ts)
        r = scheduling.schedule_offline(ts, l=1, algorithm="edl",
                                        use_dvfs=True)
        savings.append(1 - r.e_total / base)
    s = float(np.mean(savings))
    assert 0.30 <= s <= 0.365, s


def test_no_dvfs_baseline_energy_algorithm_independent():
    ts = tasks.generate_offline(0.15, seed=5)
    runs = [scheduling.schedule_offline(ts, l=1, algorithm=a, use_dvfs=False)
            for a in ("edl", "edf-bf", "edf-wf", "lpt-ff")]
    e = [r.e_run for r in runs]
    assert max(e) - min(e) < 1e-6 * max(e)


def test_theta_packs_fewer_pairs_large_l():
    """The θ-readjustment's direct mechanism (Alg 2 lines 16-19): allowing
    up to (1-θ) shrink packs tasks onto strictly fewer pairs.  The *total*
    energy effect is calibration-sensitive offline (paper Fig 9 deltas are
    1-3%; see EXPERIMENTS.md); the robust assertions are the pair count and
    a bounded energy change."""
    lib = tasks.app_library()
    pairs_t1, pairs_t08, tot_t1, tot_t08 = [], [], [], []
    for seed in range(3):
        ts = tasks.generate_offline(0.25, seed=seed, library=lib)
        r1 = scheduling.schedule_offline(ts, l=16, theta=1.0, algorithm="edl")
        r08 = scheduling.schedule_offline(ts, l=16, theta=0.8,
                                          algorithm="edl")
        assert r1.violations == 0 and r08.violations == 0
        pairs_t1.append(r1.n_pairs)
        pairs_t08.append(r08.n_pairs)
        tot_t1.append(r1.e_total)
        tot_t08.append(r08.e_total)
    assert np.mean(pairs_t08) < np.mean(pairs_t1)
    assert np.mean(tot_t08) <= np.mean(tot_t1) * 1.02


def test_pair_feasibility_flag():
    ts = tasks.generate_offline(0.1, seed=2)
    r = scheduling.schedule_offline(ts, l=1, algorithm="edl")
    assert r.feasible_pairs == (r.n_pairs <= 2048)
