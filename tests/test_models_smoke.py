"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs (assignment
requirement f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.model import Model
from repro.optim.adamw import AdamW
from repro.train.trainer import init_state, make_train_step


def make_batch(cfg, rng, B=2, S=32):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.ones((B, cfg.n_patches, cfg.d_model),
                                         jnp.bfloat16) * 0.02
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((B, cfg.n_frames, cfg.d_model),
                                   jnp.bfloat16) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params, axes = model.init(jax.random.key(0))
    batch = make_batch(cfg, rng)
    x, aux = model.forward(params, batch, remat=False)
    B, S = batch["tokens"].shape
    assert x.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
    loss, metrics = model.loss_fn(params, batch)
    assert np.isfinite(float(loss))
    # axes tree mirrors params tree
    flat_p = jax.tree.leaves(params)
    from repro import partition
    flat_a = jax.tree.leaves(axes, is_leaf=partition.is_axes)
    assert len(flat_p) == len(flat_a)
    for p, a in zip(flat_p, flat_a):
        assert p.ndim == len(a), (p.shape, a)


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    opt = AdamW(learning_rate=1e-3)
    state = init_state(model, opt, jax.random.key(1))
    step = make_train_step(model, opt, param_axes=model.param_axes())
    batch = make_batch(cfg, rng)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state.step) == 1
    # parameters actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        state.params, new_state.params)
    assert max(jax.tree.leaves(moved)) > 0
