"""The solver throughput layer: unique-row dedup + LRU solve cache
(bit-equality end to end), hierarchical kernel refinement monotonicity,
benign pad rows, and sharded dispatch."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import dvfs, online, scheduling, single_task, solver_cache, tasks
from repro.core.solver_cache import SolveCache, build_keys, solve_rows

SEED = 7


def _dup_task_set(n_base: int, n_total: int, seed: int):
    """A task set with a random duplication pattern over ``n_base`` unique
    tasks (recurring-jobs shape; ``subset`` keeps repeated indices)."""
    rng = np.random.default_rng(seed)
    base = tasks.generate_offline_n(n_base, seed=seed,
                                    library=tasks.app_library())
    return base.subset(rng.integers(0, len(base), size=n_total))


def _assert_configs_equal(a, b):
    for fa, fb in zip(a, b):
        if isinstance(fa, int):
            assert fa == fb
        else:
            assert np.array_equal(np.asarray(fa), np.asarray(fb))


# ---------------------------------------------------------------------------
# Bit-equality of the dedup path (the layer's core contract).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_kernel", [False, True])
def test_configure_tasks_dedup_bit_identical(use_kernel):
    ts = _dup_task_set(24, 300, SEED)
    allowed = ts.deadline - ts.arrival
    solver_cache.GLOBAL_CACHE.clear()
    c0 = single_task.configure_tasks(ts.params, allowed,
                                     use_kernel=use_kernel, dedup=False)
    c1 = single_task.configure_tasks(ts.params, allowed,
                                     use_kernel=use_kernel, dedup=True)
    _assert_configs_equal(c0, c1)


@pytest.mark.parametrize("alg", ["edl", "edf-wf", "edf-bf", "lpt-ff"])
def test_offline_scheduler_dedup_bit_identical(alg):
    """All four offline policies: e_total and every per-assignment field
    must be bit-identical with and without the dedup layer."""
    ts = _dup_task_set(20, 240, SEED + 1)
    r0 = scheduling.schedule_offline(ts, l=2, theta=0.9, algorithm=alg,
                                     dedup=False)
    r1 = scheduling.schedule_offline(ts, l=2, theta=0.9, algorithm=alg,
                                     dedup=True)
    assert r1.e_total == r0.e_total
    assert r1.e_idle == r0.e_idle
    assert (r1.n_pairs, r1.n_servers, r1.violations) == \
        (r0.n_pairs, r0.n_servers, r0.violations)
    assert r1.assignments == r0.assignments


@pytest.mark.parametrize("alg", ["edl", "bin"])
def test_online_scheduler_dedup_bit_identical(alg):
    ts = tasks.generate_online(offline_util=0.02, online_util=0.05,
                               seed=1, horizon=120)
    r0 = online.schedule_online(ts, l=2, theta=0.9, algorithm=alg,
                                dedup=False)
    r1 = online.schedule_online(ts, l=2, theta=0.9, algorithm=alg,
                                dedup=True)
    assert r1.e_total == r0.e_total
    assert r1.assignments == r0.assignments


def test_kernel_classes_dedup_bit_identical():
    """The stacked heterogeneous-class kernel dispatch through the dedup
    layer (per-row interval bounds are part of the cache key)."""
    ts = _dup_task_set(16, 200, SEED + 2)
    kw = dict(l=2, theta=0.9, algorithm="edl",
              classes=("gtx-1080ti", "tpu-v5e"), use_kernel=True)
    r0 = scheduling.schedule_offline(ts, dedup=False, **kw)
    r1 = scheduling.schedule_offline(ts, dedup=True, **kw)
    assert r1.e_total == r0.e_total
    assert r1.assignments == r0.assignments


def test_cache_serves_repeat_calls():
    """A second identical call is answered from the cache (zero misses)
    with bit-identical output."""
    ts = _dup_task_set(16, 100, SEED + 3)
    allowed = ts.deadline - ts.arrival
    solver_cache.GLOBAL_CACHE.clear()
    c0 = single_task.configure_tasks(ts.params, allowed, dedup=True)
    solver_cache.GLOBAL_CACHE.reset_stats()
    c1 = single_task.configure_tasks(ts.params, allowed, dedup=True)
    assert solver_cache.GLOBAL_CACHE.misses == 0
    assert solver_cache.GLOBAL_CACHE.hits > 0
    _assert_configs_equal(c0, c1)


def test_theoretical_bound_dedup_bit_identical():
    ts = _dup_task_set(16, 150, SEED + 4)
    b0 = scheduling.bounds.theoretical_bound(ts, dedup=False)
    b1 = scheduling.bounds.theoretical_bound(ts, dedup=True)
    assert b0 == b1


# ---------------------------------------------------------------------------
# The cache data structure itself.
# ---------------------------------------------------------------------------


def test_lru_eviction_and_refresh():
    c = SolveCache(maxsize=3)
    rows = [np.full(8, float(i), np.float32) for i in range(5)]
    keys = [bytes([i]) for i in range(5)]
    for i in range(3):
        c.put("t", keys[i], rows[i])
    assert len(c) == 3
    # touching key 0 refreshes it; inserting key 3 must evict key 1 (LRU)
    assert c.get("t", keys[0]) is not None
    c.put("t", keys[3], rows[3])
    assert len(c) == 3
    assert c.get("t", keys[1]) is None          # evicted
    assert c.get("t", keys[0]) is not None      # refreshed, survived
    assert c.get("t", keys[3]) is not None
    # over-filling keeps the size bounded
    c.put("t", keys[4], rows[4])
    assert len(c) == 3


def test_cache_tags_namespace_entries():
    c = SolveCache(maxsize=8)
    c.put("a", b"k", np.zeros(8, np.float32))
    assert c.get("b", b"k") is None
    assert c.get("a", b"k") is not None


def test_solve_rows_dedups_within_call(rng):
    """solver_fn sees each unique row exactly once, scatter restores order;
    cache=None still dedups but persists nothing."""
    base = rng.random((6, solver_cache.KEY_COLS)).astype(np.float32)
    keys = base[rng.integers(0, 6, size=64)]
    calls = []

    def fn(km):
        calls.append(km.shape[0])
        return km[:, :8] * 2.0

    out = solve_rows(keys, fn, tag="test", cache=None)
    assert np.array_equal(out, keys[:, :8] * 2.0)
    assert len(calls) == 1 and calls[0] == 8    # 6 unique, pow-2 padded


# ---------------------------------------------------------------------------
# Kernel refinement + pad rows + sharding.
# ---------------------------------------------------------------------------


def test_kernel_refinement_monotone():
    """A finer (G0, G1) grid never yields MORE energy than the coarse grid
    on the golden task set (the fine winner is guarded against the coarse
    winner inside the kernel)."""
    from repro.kernels import ops

    lib = tasks.generate_offline(0.08, seed=9)
    allowed = np.asarray(lib.deadline - lib.arrival)
    keys = build_keys(lib.params.astuple(), allowed, False,
                      np.asarray(dvfs.WIDE.bounds(), np.float32))
    coarse = ops.dvfs_solve_matrix(keys, grid=(64, 2))
    fine = ops.dvfs_solve_matrix(keys, grid=(64, 64))
    feas = coarse[:, 7] > 0.5
    assert np.all(fine[feas, 5] <= coarse[feas, 5] * (1 + 1e-6))


def test_kernel_pad_rows_are_benign():
    """Pad rows (batch not a block multiple) cannot poison the block: a
    task's solution is identical whether it shares a block with pad rows
    or with other real tasks, and pads never produce inf/nan."""
    from repro.kernels import ops

    lib = tasks.generate_offline_n(5, seed=4, library=tasks.app_library())
    allowed = np.asarray(lib.deadline - lib.arrival)
    keys5 = build_keys(lib.params.astuple(), allowed, False,
                       np.asarray(dvfs.WIDE.bounds(), np.float32))
    out5 = ops.dvfs_solve_matrix(keys5, shard=False)      # 123 pad rows
    big = np.broadcast_to(keys5[-1], (256 - 5, keys5.shape[1]))
    out256 = ops.dvfs_solve_matrix(np.concatenate([keys5, big]), shard=False)
    assert np.array_equal(out5, out256[:5])
    assert np.all(np.isfinite(out5))


def test_sharded_dispatch_matches_single_device():
    """dvfs_solve_matrix(shard=True) is bitwise identical to the
    single-device path — proven on 2 forced host devices in a subprocess
    (device count is fixed at jax import time)."""
    code = """
import numpy as np
from repro.core import dvfs, tasks
from repro.core.solver_cache import build_keys
from repro.kernels import ops
import jax
assert len(jax.local_devices()) == 2, jax.local_devices()
ts = tasks.generate_offline_n(5000, seed=5, library=tasks.app_library())
keys = build_keys(ts.params.astuple(),
                  np.asarray(ts.deadline - ts.arrival), False,
                  np.asarray(dvfs.WIDE.bounds(), np.float32))
a = ops.dvfs_solve_matrix(keys, shard=True)
b = ops.dvfs_solve_matrix(keys, shard=False)
assert a.shape == (5000, 8)
assert np.array_equal(a, b)
print("OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + sys.path))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr[-2000:]
