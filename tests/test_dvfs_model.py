"""Unit tests for the paper's DVFS power/performance/energy models (Eq 1-4)."""

import numpy as np
import pytest

from repro.core import dvfs
from repro.core.dvfs import DvfsParams, WIDE, NARROW


def mk(p0=100.0, gamma=50.0, c=150.0, big_d=25.0, delta=0.5, t0=5.0):
    return DvfsParams(p0=p0, gamma=gamma, c=c, big_d=big_d, delta=delta, t0=t0)


def test_g1_sublinear_and_inverse():
    v = np.linspace(0.5, 1.24, 40)
    f = np.asarray(dvfs.g1(v))
    assert np.all(np.diff(f) > 0), "g1 must be increasing"
    # sublinear: slope decreasing
    slopes = np.diff(f) / np.diff(v)
    assert np.all(np.diff(slopes) < 1e-9)
    # inverse identity on the feasible branch
    vv = np.asarray(dvfs.g1_inv(f))
    np.testing.assert_allclose(vv, v, atol=1e-6)


def test_power_time_energy_identities():
    p = mk()
    pw = float(dvfs.power(p, 1.0, 1.0, 1.0))
    assert pw == pytest.approx(100 + 50 + 150)
    t = float(dvfs.exec_time(p, 1.0, 1.0))
    assert t == pytest.approx(25.0 + 5.0)
    e = float(dvfs.energy(p, 1.0, 1.0, 1.0))
    assert e == pytest.approx(pw * t)
    # default helpers agree
    assert float(p.default_power()) == pytest.approx(pw)
    assert float(p.default_time()) == pytest.approx(t)


def test_time_nonlinear_in_frequencies():
    """The paper's central modeling point: t is NOT ~ 1/f alone; it splits
    between core and memory sensitivity via delta."""
    p_core = mk(delta=1.0)
    p_mem = mk(delta=0.0)
    # core-bound: memory frequency has no effect
    t1 = float(dvfs.exec_time(p_core, 0.8, 0.5))
    t2 = float(dvfs.exec_time(p_core, 0.8, 1.2))
    assert t1 == pytest.approx(t2)
    # memory-bound: core frequency has no effect
    t1 = float(dvfs.exec_time(p_mem, 0.5, 0.8))
    t2 = float(dvfs.exec_time(p_mem, 1.0, 0.8))
    assert t1 == pytest.approx(t2)


def test_energy_nonmonotonic_in_fm():
    """E(fm) decreases then increases for a memory-sensitive task => a
    strictly interior optimum exists (what distinguishes the paper's model
    from monotonic CPU models)."""
    p = mk(gamma=150.0, c=50.0, delta=0.5, t0=10.0)
    fms = np.linspace(WIDE.fm_min, WIDE.fm_max, 101)
    e = np.asarray(dvfs.energy(p, 1.0, 1.0, fms))
    imin = int(np.argmin(e))
    assert 0 < imin < 100, "optimum should be interior"
    assert e[0] > e[imin] and e[-1] > e[imin]


def test_optimal_fm_closed_form_matches_grid():
    p = mk(delta=0.3)
    f_star = float(dvfs.optimal_fm(p, 1.0, 1.0, WIDE))
    fms = np.linspace(WIDE.fm_min, WIDE.fm_max, 20001)
    e = np.asarray(dvfs.energy(p, 1.0, 1.0, fms))
    f_grid = float(fms[np.argmin(e)])
    assert f_star == pytest.approx(f_grid, abs=2e-4)


def test_optimal_fm_gamma_zero_prefers_max():
    p = mk(gamma=0.0, delta=0.3)
    assert float(dvfs.optimal_fm(p, 1.0, 1.0, WIDE)) == pytest.approx(
        WIDE.fm_max)


def test_theorem1_energy_increasing_in_voltage():
    """dE/dV > 0 for fixed (fc, fm) — the optimum sits on fc = g1(V)."""
    p = mk()
    vs = np.linspace(0.6, 1.2, 50)
    e = np.asarray(dvfs.energy(p, vs, 0.7, 1.0))
    assert np.all(np.diff(e) > 0)


def test_interval_clamp():
    v, fc, fm = WIDE.clamp(2.0, 2.0, 2.0)
    assert float(v) == pytest.approx(WIDE.v_max)
    assert float(fc) == pytest.approx(dvfs.g1_float(WIDE.v_max))
    assert float(fm) == pytest.approx(WIDE.fm_max)
    # the narrow interval is a subset on the low side (fc_min/fm_min higher)
    assert NARROW.fc_min > WIDE.fc_min and NARROW.fm_min > WIDE.fm_min


def test_tpu_task_params_roundtrip():
    p = dvfs.tpu_task_params(duration_s=120.0, delta=0.7, t0_frac=0.1)
    assert float(p.default_time()) == pytest.approx(120.0)
    assert float(p.delta) == pytest.approx(0.7)
    # power split sums to the chip envelope at the default point
    assert float(p.default_power()) == pytest.approx(
        dvfs.TPU_V5E_CHIP["p_peak"])
