"""ClusterEngine: seed-equivalence goldens and state-machine invariants.

The golden numbers below were produced by the pre-engine (seed) scheduler
implementation — the object-graph ``_PairState``/``_ServerState`` simulator
and the heap-based offline packer — at commit 025555f, on fixed-seed task
sets.  The vectorized ``ClusterEngine`` rewrite must reproduce them to
1e-6 relative tolerance (it actually agrees to ~1e-10; the only divergence
source is the batched theta-readjustment boundary solve).

The event-driven engine (exact DRS power-off events) keeps every value
below bit-for-bit — see the ONLINE_GOLDEN comment for why these scenarios
never hit the old sweep's arrival-gap overcharge — and adds SPARSE_GOLDEN,
pinned on a scenario where the removed overcharge dominates, with the
per-config derivation in its comment.
"""

import numpy as np
import pytest

from repro.core import online, scheduling, tasks
from repro.core.engine import ClusterEngine

# (algorithm kwargs) -> (e_total, e_idle, n_pairs, n_servers, violations)
# from the seed implementation on generate_offline(0.1, seed=3), l=2, θ=0.9.
OFFLINE_GOLDEN = {
    "edl":    (3678787.8404366914, 6735.9927449506595, 84, 42, 0),
    "edf-wf": (3669301.5104696816, 18451.40813414862, 91, 46, 0),
    "edf-bf": (3725938.3543846672, 75088.25204913408, 78, 39, 0),
    "lpt-ff": (3708240.1715263743, 57390.069190841314, 114, 57, 0),
}

# from the seed implementation on generate_online(0.02, 0.05, seed=1,
# horizon=200): (e_total, e_overhead, n_pairs, n_servers, violations).
#
# Re-pinned for the event-driven engine (exact DRS power-off accounting):
# the deltas are ZERO.  On this workload every task outlives the 200-slot
# arrival horizon, so no server ever satisfies the idle >= rho condition at
# an arrival-slot sweep — every power-off is booked by `finalize`, which
# already billed the exact `mu + rho - on_since`.  The arrival-gap
# overcharge the old `drs_sweep` could add (`t_sweep - (mu + rho)` per
# mid-run power-off) is therefore 0 here; SPARSE_GOLDEN below pins a
# scenario where it is the dominant error term.
ONLINE_GOLDEN = {
    ("edl", 2, 0.9): (2731797.7952474374, 6660.0, 74, 37, 0),
    ("bin", 2, 0.9): (2736802.4581569973, 4500.0, 50, 25, 0),
    ("edl", 4, 1.0): (2958601.729300437, 7920.0, 88, 22, 0),
}

# Exact-DRS goldens on the sparse short-task scenario of
# tests/test_event_engine.py::sparse_ts (40 tasks, arrival gap 37 slots,
# service ~2-9 slots, so every visit powers the server off between
# arrivals): (e_total, e_idle, n_pairs, n_servers, violations) from the
# event-driven engine.  Derivation of each delta vs the sweep-based seed
# accounting (values measured at commit f05ce34):
#
#   (edl, 2, 0.9): e_idle 98553.5066788198  -> 15026.052420377584
#   (bin, 2, 0.9): e_idle 98553.5066788198  -> 15026.052420377584
#   (edl, 1, 1.0): e_idle 44723.72712922111 ->  2960.0
#
# Each removed delta is exactly the accumulated arrival-gap overcharge
# P_idle * sum(t_sweep - (mu_srv + rho)) over the mid-run power-offs: the
# old sweep billed the server up to the *next arrival slot* instead of to
# its power-off event.  For (edl, 1, 1.0) the corrected value is the
# analytic P_IDLE * RHO * n_tasks = 37 * 2 * 40 = 2960 exactly (each visit
# idles precisely rho);
# test_event_engine.py::test_removed_overcharge_matches_arrival_gap_derivation
# proves the identity in closed form on the no-DVFS variant.
SPARSE_GOLDEN = {
    ("edl", 2, 0.9): (47676.02078567312, 15026.052420377584, 2, 1, 0),
    ("bin", 2, 0.9): (47676.02078567312, 15026.052420377584, 2, 1, 0),
    ("edl", 1, 1.0): (32009.96836529554, 2960.0, 1, 1, 0),
}


@pytest.fixture(scope="module")
def library():
    return tasks.app_library()


@pytest.mark.parametrize("alg", sorted(OFFLINE_GOLDEN))
def test_offline_matches_seed_implementation(alg, library):
    ts = tasks.generate_offline(0.1, seed=3, library=library)
    r = scheduling.schedule_offline(ts, l=2, theta=0.9, algorithm=alg)
    e_total, e_idle, n_pairs, n_servers, violations = OFFLINE_GOLDEN[alg]
    assert r.e_total == pytest.approx(e_total, rel=1e-6)
    assert r.e_idle == pytest.approx(e_idle, rel=1e-6)
    assert r.n_pairs == n_pairs
    assert r.n_servers == n_servers
    assert r.violations == violations


@pytest.mark.parametrize("alg,l,theta", sorted(ONLINE_GOLDEN))
def test_online_matches_seed_implementation(alg, l, theta, library):
    ts = tasks.generate_online(offline_util=0.02, online_util=0.05, seed=1,
                               horizon=200, library=library)
    r = online.schedule_online(ts, l=l, theta=theta, algorithm=alg)
    e_total, e_overhead, n_pairs, n_servers, violations = \
        ONLINE_GOLDEN[(alg, l, theta)]
    assert r.e_total == pytest.approx(e_total, rel=1e-6)
    assert r.e_overhead == pytest.approx(e_overhead, rel=1e-6)
    assert r.n_pairs == n_pairs
    assert r.n_servers == n_servers
    assert r.violations == violations


@pytest.mark.parametrize("alg,l,theta", sorted(SPARSE_GOLDEN))
def test_online_sparse_exact_drs_goldens(alg, l, theta, library):
    from test_event_engine import sparse_ts  # resolves via pytest's
    # test-dir sys.path insertion, independent of the invocation cwd
    ts = sparse_ts(library=library)
    r = online.schedule_online(ts, l=l, theta=theta, algorithm=alg)
    e_total, e_idle, n_pairs, n_servers, violations = \
        SPARSE_GOLDEN[(alg, l, theta)]
    assert r.e_total == pytest.approx(e_total, rel=1e-9)
    assert r.e_idle == pytest.approx(e_idle, rel=1e-9)
    assert (r.n_pairs, r.n_servers, r.violations) == \
        (n_pairs, n_servers, violations)


def test_kernel_path_matches_jnp_path_online():
    """use_kernel=True routes Algorithm 1 AND the readjustment batch through
    the Pallas kernel; schedule shape must agree with the jnp solver path."""
    ts = tasks.generate_online(offline_util=0.02, online_util=0.04, seed=7,
                               horizon=120)
    r_j = online.schedule_online(ts, l=2, theta=0.9, algorithm="edl")
    r_k = online.schedule_online(ts, l=2, theta=0.9, algorithm="edl",
                                 use_kernel=True)
    assert r_k.violations == 0
    assert r_k.e_total == pytest.approx(r_j.e_total, rel=2e-3)


# ---------------------------------------------------------------------------
# State-machine invariants.
# ---------------------------------------------------------------------------


def test_engine_drs_and_finalize():
    eng = ClusterEngine(l=2, rho=2, p_idle=10.0, delta_on=5.0)
    sid = eng.new_server(0.0)
    assert eng.n_pairs == 2 and eng.n_on_servers() == 1
    eng.assign(sid * 2, 0.0, 1.0)          # pair 0 busy on [0, 1]
    eng.drs_sweep(2.0)                      # idle 1 < rho: stays on
    assert eng.n_on_servers() == 1
    eng.drs_sweep(3.0)                      # idle 2 >= rho: powers off
    assert eng.n_on_servers() == 0
    e_idle, e_over, n_srv = eng.finalize()
    # on [0, 3] with l=2: 6 pair-slots, 1 busy -> 5 idle; 2 turn-ons
    assert e_idle == pytest.approx(10.0 * 5.0)
    assert e_over == pytest.approx(5.0 * 2)
    assert n_srv == 1


def test_engine_acquire_prefers_waking_off_server():
    eng = ClusterEngine(l=2)
    eng.new_server(0.0)
    eng.drs_sweep(10.0)                     # server powers off
    pid = eng.acquire_pair(10.0)            # re-wakes it instead of building
    assert pid == 0
    assert eng.n_servers == 1
    assert eng.mu[0] == 10.0                # an awakened pair is free *now*


def test_engine_offline_finalize_is_algorithm3():
    from repro.core import cluster as cl
    eng = ClusterEngine(l=2, servers=False, p_idle=37.0)
    for mu in (5.0, 3.0, 8.0):
        pid = eng.open_pair()
        eng.assign(pid, 0.0, mu)
    e_idle, e_over, n_srv = eng.finalize()
    exp_idle, exp_srv = cl.offline_idle_energy(np.asarray([5.0, 3.0, 8.0]), 2)
    assert e_idle == pytest.approx(exp_idle)
    assert e_over == 0.0
    assert n_srv == exp_srv


def test_engine_selectors_tie_break_to_lowest_id():
    eng = ClusterEngine(l=1)
    for _ in range(3):
        eng.new_server(0.0)
    assert eng.worst_fit() == 0             # all mu equal -> lowest id
    eng.assign(0, 0.0, 4.0)
    eng.assign(1, 0.0, 2.0)
    assert eng.worst_fit() == 2             # mu: [4, 2, 0]
    assert eng.best_fit(0.0, 10.0, 1.0) == 0
    assert eng.first_fit(0.0, 10.0, 7.0) == 1   # pair 0 does not fit
    assert eng.first_fit(0.0, 3.0, 2.0) == 2
