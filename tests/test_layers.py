"""Layer-level unit tests: MoE dispatch, SSD scan, RG-LRU, attention
blockwise vs dense, chunked CE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn_lib, moe as moe_lib, rglru as rglru_lib, ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.models.layers import ParamBuilder
from repro.models.model import chunked_cross_entropy

KEY = jax.random.key(7)


def moe_cfg(E=8, k=2, cf=8.0):
    return ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=48, vocab_size=64,
                       n_experts=E, top_k=k, capacity_factor=cf)


def test_moe_matches_dense_reference_with_big_capacity():
    """With capacity >> needed, the einsum dispatch must equal dense top-k
    routing exactly (no drops)."""
    cfg = moe_cfg(cf=16.0)
    b = ParamBuilder(KEY)
    params = moe_lib.init_moe(b, cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y, aux = moe_lib.moe_mlp(params, x, cfg, group=32)
    y_ref = moe_lib.moe_mlp_dense_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=2e-2)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens_not_nans():
    cfg = moe_cfg(cf=0.25)   # aggressively tight capacity
    b = ParamBuilder(KEY)
    params = moe_lib.init_moe(b, cfg)
    x = jax.random.normal(jax.random.key(2), (2, 16, cfg.d_model))
    y, aux = moe_lib.moe_mlp(params, x, cfg, group=32)
    assert bool(jnp.all(jnp.isfinite(y)))
    # dropped tokens produce smaller outputs on average, never garbage
    assert float(jnp.max(jnp.abs(y))) < 1e3


def test_ssd_chunked_vs_reference_and_chunk_invariance():
    B, S, H, P, N = 2, 128, 2, 32, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    bmat = jax.random.normal(ks[3], (B, S, N)) * 0.3
    cmat = jax.random.normal(ks[4], (B, S, N)) * 0.3
    y_ref, s_ref = ssm_lib.ssd_reference(x, dt, a, bmat, cmat)
    for chunk in (16, 32, 128):
        y, s = ssm_lib.ssd_chunked(x, dt, a, bmat, cmat, chunk)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(y_ref, np.float32), atol=5e-3)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                                   rtol=2e-3, atol=2e-3)


def test_ssd_init_state_continuation():
    """Splitting a sequence in two with state carry == one pass."""
    B, S, H, P, N = 1, 64, 2, 16, 16
    ks = jax.random.split(jax.random.key(3), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    bmat = jax.random.normal(ks[3], (B, S, N)) * 0.3
    cmat = jax.random.normal(ks[4], (B, S, N)) * 0.3
    y_full, s_full = ssm_lib.ssd_chunked(x, dt, a, bmat, cmat, 16)
    h = S // 2
    y1, s1 = ssm_lib.ssd_chunked(x[:, :h], dt[:, :h], a, bmat[:, :h],
                                 cmat[:, :h], 16)
    y2, s2 = ssm_lib.ssd_chunked(x[:, h:], dt[:, h:], a, bmat[:, h:],
                                 cmat[:, h:], 16, init_state=s1)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, h:]),
                               atol=5e-3)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=2e-3, atol=2e-3)


def test_rglru_scan_vs_sequential():
    cfg = ModelConfig(name="t", family="hybrid", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=1, d_ff=48, vocab_size=64,
                      rnn_width=32)
    b = ParamBuilder(KEY)
    params = rglru_lib.init_rglru_block(b, cfg)
    x = jax.random.normal(jax.random.key(4), (2, 32, 32)) * 0.5
    h, h_last = rglru_lib.rglru_scan(params, x)
    h_ref = rglru_lib.rglru_reference(params, x)
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(h_ref, np.float32), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_last),
                               np.asarray(h_ref[:, -1], np.float32),
                               atol=1e-4)
    # stability: |a| < 1 keeps the state bounded
    assert float(jnp.max(jnp.abs(h_last))) < 1e2


def test_rglru_step_matches_scan():
    cfg = ModelConfig(name="t", family="hybrid", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=1, d_ff=32, vocab_size=64,
                      rnn_width=16)
    b = ParamBuilder(jax.random.key(5))
    params = rglru_lib.init_rglru_block(b, cfg)
    x = jax.random.normal(jax.random.key(6), (1, 8, 16)) * 0.5
    h_seq, _ = rglru_lib.rglru_scan(params, x)
    h = jnp.zeros((1, 16))
    for t in range(8):
        h = rglru_lib.rglru_step(params, x[:, t], h)
        np.testing.assert_allclose(np.asarray(h),
                                   np.asarray(h_seq[:, t], np.float32),
                                   atol=1e-4)


def test_blockwise_attention_vs_dense_chunking():
    """Blockwise (flash-style) attention must be chunk-size invariant and
    match the dense oracle, including non-divisible lengths (padding)."""
    from repro.kernels import ref as kref
    B, H, KV, S, dh = 1, 4, 2, 150, 32   # 150: exercises the pad path
    ks = jax.random.split(jax.random.key(8), 3)
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, KV, dh))
    v = jax.random.normal(ks[2], (B, S, KV, dh))
    exp = kref.attention_ref(q.transpose(0, 2, 1, 3),
                             k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), causal=True)
    for chunk in (37, 64, 256):
        out = attn_lib.blockwise_attention(q, k, v, causal=True, chunk=chunk)
        np.testing.assert_allclose(
            np.asarray(out.transpose(0, 2, 1, 3), np.float32),
            np.asarray(exp, np.float32), atol=3e-2)


def test_chunked_ce_matches_direct():
    B, S, d, V = 2, 64, 16, 50
    ks = jax.random.split(jax.random.key(9), 3)
    x = jax.random.normal(ks[0], (B, S, d))
    head = jax.random.normal(ks[1], (d, V)) * 0.1
    labels = jax.random.randint(ks[2], (B, S), 0, V)
    ce = chunked_cross_entropy(x, head, labels, chunk=16)
    logits = (x @ head).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    expect = jnp.mean(lse - gold)
    assert float(ce) == pytest.approx(float(expect), rel=2e-3)
    # padded-vocab masking: padding columns must not change the loss
    headp = jnp.pad(head, ((0, 0), (0, 14)))
    cep = chunked_cross_entropy(x, headp, labels, chunk=16, valid_vocab=V)
    assert float(cep) == pytest.approx(float(ce), rel=2e-3)
