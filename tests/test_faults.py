"""Fault-injection subsystem: failure traces, exact energy settlement at
the crash instant, deadline-aware re-placement, and graceful degradation.

Layers under test (see docs/ARCHITECTURE.md, fault-injection layer):

* :class:`repro.core.faults.FaultTrace` — deterministic trace construction;
* :class:`repro.core.engine.ClusterEngine.fail_pairs` /
  ``revive_pairs`` — engine-level goldens with hand-derived energies;
* :func:`repro.core.online.schedule_online(faults=...)` — end-to-end
  goldens (hand-derived), scalar/vector bit-identity under injection, and
  the graceful-degradation violation accounting;
* property invariants under arbitrary random traces (seeded sweep always;
  the same properties run under ``hypothesis`` when it is installed).

Golden derivations are written out next to each golden test.
"""

import numpy as np
import pytest

from repro.core import online, tasks
from repro.core.dvfs import DvfsParams
from repro.core.engine import ClusterEngine
from repro.core.faults import FaultEvent, FaultTrace
from repro.core.tasks import TaskSet

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # container ships without hypothesis; CI installs it
    HAVE_HYPOTHESIS = False

# Paper constants used by the hand derivations below.
P_IDLE, DELTA_ON, RHO = 37.0, 90.0, 2


# ---------------------------------------------------------------------------
# FaultTrace construction.
# ---------------------------------------------------------------------------

def test_trace_sorts_deterministically_fail_before_revive():
    tr = FaultTrace.from_events(
        [(5.0, 1, "revive"), (5.0, 0, "fail"), (2.0, 3, "fail"),
         (5.0, 0, "revive")])
    assert [(e.t, e.server, e.kind) for e in tr.events] == [
        (2.0, 3, "fail"), (5.0, 0, "fail"),
        (5.0, 0, "revive"), (5.0, 1, "revive")]
    assert tr.n_failures == 2 and len(tr) == 4


def test_trace_validation():
    with pytest.raises(ValueError):
        FaultEvent(1.0, 0, "explode")
    with pytest.raises(ValueError):
        FaultEvent(-1.0, 0, "fail")
    with pytest.raises(ValueError):
        FaultEvent(1.0, -2, "fail")
    with pytest.raises(ValueError):
        FaultTrace.sample(4, 10.0, mtbf=0.0)


def test_trace_sample_replays_from_seed():
    a = FaultTrace.sample(16, 200.0, mtbf=50.0, mttr=5.0, seed=9)
    b = FaultTrace.sample(16, 200.0, mtbf=50.0, mttr=5.0, seed=9)
    assert a.events == b.events
    assert a.events != FaultTrace.sample(16, 200.0, mtbf=50.0, mttr=5.0,
                                         seed=10).events
    # alternation per server: fail/revive strictly interleave in time
    by_srv = {}
    for e in a.events:
        by_srv.setdefault(e.server, []).append(e.kind)
    for kinds in by_srv.values():
        assert kinds[0] == "fail"
        assert all(k1 != k2 for k1, k2 in zip(kinds, kinds[1:]))


def test_trace_sample_per_class_mtbf():
    """Per-slot mtbf array: a crash-happy slot fails more often."""
    mtbf = np.array([5.0, 5000.0])
    tr = FaultTrace.sample(2, 500.0, mtbf=mtbf, mttr=1.0, seed=0)
    n0 = sum(1 for e in tr.events if e.server == 0 and e.kind == "fail")
    n1 = sum(1 for e in tr.events if e.server == 1 and e.kind == "fail")
    assert n0 > n1


def test_trace_fraction_counts_and_repair():
    tr = FaultTrace.fraction(200, 0.05, horizon=100.0, seed=1, repair=7.0)
    assert tr.n_failures == 10
    assert len(tr) == 20
    fails = {e.server: e.t for e in tr.events if e.kind == "fail"}
    for e in tr.events:
        if e.kind == "revive":
            assert e.t == pytest.approx(fails[e.server] + 7.0)


# ---------------------------------------------------------------------------
# Engine-level goldens (hand-derived).
# ---------------------------------------------------------------------------

def test_engine_fail_books_energy_exactly_at_crash_l1():
    """l=1: acquire at t=0, task [0, 10], crash at t=4.

    on-span = 4 - 0 (hard crash: no rho drain tail), busy = 10 - 6 rollback
    = 4, so e_idle = 37*(4 - 4) = 0 and e_overhead = 90*1.
    """
    eng = ClusterEngine(1, servers=True, rho=RHO)
    pid = eng.acquire_pair(0.0)
    eng.assign(pid, 0.0, 10.0)
    eng.settle(4.0)
    done = eng.fail_pairs(4.0, [pid], busy_rollback=[6.0])
    assert done.tolist() == [pid]
    assert eng.pair_failed[pid]
    assert float(eng.busy[pid]) == 4.0
    assert float(eng.mu[pid]) == 4.0
    e_idle, e_overhead, n_servers = eng.finalize()
    assert e_idle == 0.0
    assert e_overhead == DELTA_ON
    # repeated fail is a no-op
    eng2 = ClusterEngine(1, servers=True, rho=RHO)
    p2 = eng2.acquire_pair(0.0)
    eng2.settle(4.0)
    eng2.fail_pairs(4.0, [p2])
    assert eng2.fail_pairs(5.0, [p2]).size == 0


def test_engine_fail_books_energy_exactly_at_crash_l2():
    """l=2: tasks [0,10] and [0,3] on the two pairs, crash at t=4.

    busy = [10-6, 3-0] = [4, 3]; on-span = 4 for both pair slots, so
    e_idle = 37*(4*2 - 7) = 37 and e_overhead = 90*2 (both pairs of the
    one powered server).
    """
    eng = ClusterEngine(2, servers=True, rho=RHO)
    base = eng.acquire_pair(0.0)
    eng.assign(base, 0.0, 10.0)
    eng.assign(base + 1, 0.0, 3.0)
    eng.settle(4.0)
    eng.fail_pairs(4.0, [base, base + 1], busy_rollback=[6.0, 0.0])
    assert eng.busy.tolist() == [4.0, 3.0]
    e_idle, e_overhead, _ = eng.finalize()
    assert e_idle == pytest.approx(P_IDLE * 1.0)
    assert e_overhead == pytest.approx(DELTA_ON * 2)


def test_engine_failed_pairs_leave_every_selector_pool():
    eng = ClusterEngine(1, servers=True, rho=RHO)
    p0 = eng.acquire_pair(0.0)
    p1 = eng.acquire_pair(0.0)
    eng.fail_pairs(0.0, [p0])
    assert not eng.eligible_mask()[p0]
    assert eng.worst_fit() == p1
    assert eng.first_fit(0.0, 100.0, 1.0) == p1
    assert eng.best_fit(0.0, 100.0, 1.0) == p1
    assert eng.pool_ids().tolist() == [p1]
    # a failed-while-off server is not re-powered by acquire_pair
    eng.settle(50.0)          # both servers power off
    p2 = eng.acquire_pair(50.0)
    assert p2 // eng.l != p0 // eng.l


def test_engine_revive_floors_mu_and_rejoins_wake_pool():
    eng = ClusterEngine(1, servers=True, rho=RHO)
    p0 = eng.acquire_pair(0.0)
    eng.assign(p0, 0.0, 10.0)
    eng.settle(4.0)
    eng.fail_pairs(4.0, [p0], busy_rollback=[6.0])
    # revive while everything else is off: server rejoins the wake pool
    # (no on-span is booked until a task actually wakes it)
    assert eng.revive_pairs(20.0, [p0]).tolist() == [p0]
    assert not eng.pair_failed[p0]
    assert eng.revive_pairs(21.0, [p0]).size == 0      # no-op when healthy
    p1 = eng.acquire_pair(25.0)
    assert p1 == p0                                    # re-powered, not built
    assert float(eng.mu[p0]) == 25.0


def test_engine_settle_idempotent_around_failures():
    eng = ClusterEngine(2, servers=True, rho=RHO)
    base = eng.acquire_pair(0.0)
    eng.assign(base, 0.0, 3.0)
    eng.settle(4.0)
    snap = (eng._on_time[: eng.n_servers].copy(),
            eng._on[: eng.n_servers].copy())
    eng.settle(4.0)
    assert np.array_equal(snap[0], eng._on_time[: eng.n_servers])
    eng.fail_pairs(4.0, [base, base + 1], busy_rollback=[0.0, 0.0])
    snap = eng._on_time[: eng.n_servers].copy()
    eng.settle(4.0)
    eng.settle(4.0)
    assert np.array_equal(snap, eng._on_time[: eng.n_servers])


def test_engine_crash_at_drs_boundary_no_double_booking():
    """Crash at EXACTLY mu_srv + rho, the DRS power-off instant: settle
    books the off event first (span mu+rho), the crash then sees an OFF
    server and books nothing more."""
    eng = ClusterEngine(1, servers=True, rho=RHO)
    pid = eng.acquire_pair(0.0)
    eng.assign(pid, 0.0, 5.0)
    eng.settle(5.0 + RHO)
    eng.fail_pairs(5.0 + RHO, [pid], busy_rollback=[0.0])
    e_idle, e_overhead, _ = eng.finalize()
    assert e_idle == pytest.approx(P_IDLE * RHO)       # the rho drain tail
    assert e_overhead == pytest.approx(DELTA_ON)


# ---------------------------------------------------------------------------
# End-to-end goldens (hand-derived; no DVFS so every number is exact).
# ---------------------------------------------------------------------------

def _golden_task_set(n=2):
    """Tasks at (1,1,1): t* = t0 + D, p* = p0 + gamma + c.

    A: a=0, t*=10, p*=100, d=30;  B: a=1, t*=5, p*=200, d=40;
    C (revive golden): a=25, t*=2, p*=100, d=30.
    """
    params = DvfsParams(
        p0=np.array([30.0, 60.0, 30.0][:n]),
        gamma=np.array([20.0, 40.0, 20.0][:n]),
        c=np.array([50.0, 100.0, 50.0][:n]),
        big_d=np.array([9.0, 4.0, 1.0][:n]),
        delta=np.ones(n), t0=np.ones(n))
    return TaskSet(arrival=np.array([0.0, 1.0, 25.0][:n]),
                   deadline=np.array([30.0, 40.0, 30.0][:n]),
                   params=params, utilization=np.full(n, 0.5))


@pytest.mark.parametrize("placement", ["scalar", "vector"])
def test_e2e_crash_golden(placement):
    """l=1, EDL, no DVFS, fail server 0 at t=4.

    Failure-free: A -> pair0 [0,10], B -> pair0 [10,15].  Crash at 4:
    A truncated [0,4] (400 J wasted, billed), B tombstoned (0 J); both
    re-place EDF onto fresh server 1: A [4,14], B [14,19].
      e_run      = 100*4 + 100*10 + 200*5           = 2400
      on-spans   = srv0: 4 (hard crash), srv1: 19+2-4 = 17
      e_idle     = 37 * (21 - (4+10+5))             = 74
      e_overhead = 90 * 2                           = 180
      e_total                                       = 2654, 0 violations
    """
    r = online.schedule_online(
        _golden_task_set(), l=1, algorithm="edl", use_dvfs=False,
        placement=placement, bound=False,
        faults=FaultTrace.from_events([(4.0, 0, "fail")]))
    assert r.e_run == pytest.approx(2400.0)
    assert r.e_idle == pytest.approx(74.0)
    assert r.e_overhead == pytest.approx(180.0)
    assert r.e_total == pytest.approx(2654.0)
    assert r.violations == 0
    assert r.fault_stats == {"failures": 1, "revivals": 0, "skipped": 0,
                             "orphans": 2, "restarted": 2, "degraded": 0}
    rows = [(a.task, a.pair, a.start, a.finish, a.energy, a.failed)
            for a in r.assignments]
    assert rows == [(0, 0, 0.0, 4.0, 400.0, True),     # truncated at crash
                    (1, 0, 10.0, 10.0, 0.0, True),     # queued: tombstone
                    (0, 1, 4.0, 14.0, 1000.0, False),
                    (1, 1, 14.0, 19.0, 1000.0, False)]


@pytest.mark.parametrize("placement", ["scalar", "vector"])
def test_e2e_revive_golden(placement):
    """Extends the crash golden: server 0 revives at t=20; task C arrives
    at t=25 and must land on the REVIVED server 0 (server 1 powered off at
    21 = 19 + rho).

      e_run      = 2400 + 100*2                       = 2600
      on-spans   = srv0: 4 + (27+2-25) = 8, srv1: 17  -> sum 25
      e_idle     = 37 * (25 - (4+10+5+2))             = 148
      e_overhead = 90 * 3   (srv0 on twice, srv1 once) = 270
      e_total                                          = 3018, 0 violations
    """
    r = online.schedule_online(
        _golden_task_set(3), l=1, algorithm="edl", use_dvfs=False,
        placement=placement, bound=False,
        faults=FaultTrace.from_events([(4.0, 0, "fail"), (20.0, 0,
                                                          "revive")]))
    assert r.e_run == pytest.approx(2600.0)
    assert r.e_idle == pytest.approx(148.0)
    assert r.e_overhead == pytest.approx(270.0)
    assert r.e_total == pytest.approx(3018.0)
    assert r.violations == 0
    assert r.fault_stats["revivals"] == 1
    c_rec = [a for a in r.assignments if a.task == 2]
    assert len(c_rec) == 1 and c_rec[0].pair == 0      # revived server 0
    assert (c_rec[0].start, c_rec[0].finish) == (25.0, 27.0)


def test_e2e_degradation_counts_violation_never_crashes():
    """Crash just before a long task finishes, deadline too close: no pair
    (not even a fresh one) can rerun it in time, so the graceful-degradation
    step books it at max speed and the miss is ONE violation.  (A crash at
    EXACTLY the finish time would not orphan the task — a record with
    ``finish <= t`` has completed; ``test_crash_exactly_at_arrival_slot_
    boundary`` pins the other boundary.)"""
    params = DvfsParams(p0=np.array([30.0]), gamma=np.array([20.0]),
                        c=np.array([50.0]), big_d=np.array([9.0]),
                        delta=np.array([1.0]), t0=np.array([1.0]))
    ts = TaskSet(arrival=np.array([0.0]), deadline=np.array([11.0]),
                 params=params, utilization=np.array([0.9]))
    for placement in ("scalar", "vector"):
        r = online.schedule_online(
            ts, l=1, algorithm="edl", use_dvfs=False, placement=placement,
            bound=False, faults=FaultTrace.from_events([(9.5, 0, "fail")]))
        assert r.violations == 1
        assert r.fault_stats["degraded"] == 1
        live = [a for a in r.assignments if not a.failed]
        assert len(live) == 1 and live[0].finish > 11.0


def test_events_for_unbuilt_servers_are_skipped():
    r = online.schedule_online(
        _golden_task_set(), l=1, algorithm="edl", use_dvfs=False,
        bound=False,
        faults=FaultTrace.from_events([(4.0, 500, "fail"),
                                       (6.0, 501, "revive")]))
    assert r.fault_stats == {"failures": 0, "revivals": 0, "skipped": 2,
                             "orphans": 0, "restarted": 0, "degraded": 0}
    assert r.e_total == pytest.approx(
        online.schedule_online(_golden_task_set(), l=1, algorithm="edl",
                               use_dvfs=False, bound=False).e_total)


def test_empty_trace_is_bit_identical_to_no_faults():
    ts = tasks.generate_online(0.4, 1.6, seed=5, horizon=60)
    r0 = online.schedule_online(ts, l=2, theta=0.9, bound=False)
    r1 = online.schedule_online(ts, l=2, theta=0.9, bound=False,
                                faults=FaultTrace.from_events([]))
    assert r0.e_run == r1.e_run and r0.e_idle == r1.e_idle
    assert r0.e_overhead == r1.e_overhead
    assert r0.violations == r1.violations
    assert r0.fault_stats is None
    assert r1.fault_stats == {"failures": 0, "revivals": 0, "skipped": 0,
                              "orphans": 0, "restarted": 0, "degraded": 0}
    assert r1.assignments == r0.assignments


# ---------------------------------------------------------------------------
# Properties under arbitrary random traces.  Seeded sweep always runs; the
# same checker runs under hypothesis when installed (CI installs it).
# ---------------------------------------------------------------------------

def check_fault_invariants(seed: int, algorithm: str = "edl",
                           l: int = 2, classes=None):
    """Energy-conservation and record invariants under a random trace, plus
    scalar/vector bit-identity."""
    rng = np.random.default_rng(seed)
    ts = tasks.generate_online(0.3, float(rng.uniform(0.5, 1.5)),
                               seed=seed, horizon=60)
    trace = FaultTrace.sample(
        int(rng.integers(4, 40)), 70.0,
        mtbf=float(rng.uniform(10.0, 80.0)),
        mttr=float(rng.uniform(2.0, 20.0)) if rng.random() < 0.7 else None,
        seed=seed + 1)
    theta = float(rng.choice([0.8, 0.9, 1.0]))
    results = {}
    for placement in ("scalar", "vector"):
        r = online.schedule_online(
            ts, l=l, theta=theta, algorithm=algorithm, placement=placement,
            bound=False, classes=classes, faults=trace)
        results[placement] = r
        # Eq. 7 decomposition holds and every term is sane
        assert r.e_idle >= -1e-9
        assert r.e_overhead >= 0.0
        assert r.e_total == pytest.approx(r.e_run + r.e_idle + r.e_overhead)
        assert r.e_run == pytest.approx(
            sum(a.energy for a in r.assignments))
        live = {}
        for a in r.assignments:
            assert a.finish >= a.start - 1e-9          # no negative spans
            assert a.energy >= -1e-9
            if a.failed:
                assert a.energy == pytest.approx(
                    a.power * (a.finish - a.start))
            else:
                live[a.task] = live.get(a.task, 0) + 1
        # every task keeps exactly one live record, however often it failed
        assert len(live) == len(ts) and set(live.values()) == {1}
    a, b = results["scalar"], results["vector"]
    assert (a.e_run, a.e_idle, a.e_overhead, a.violations, a.n_pairs) == \
           (b.e_run, b.e_idle, b.e_overhead, b.violations, b.n_pairs)
    assert a.fault_stats == b.fault_stats

    def key(z):
        return (z.task, z.start, z.pair)

    assert sorted(a.assignments, key=key) == sorted(b.assignments, key=key)


@pytest.mark.parametrize("seed", range(8))
def test_fault_invariants_edl(seed):
    check_fault_invariants(seed, "edl")


@pytest.mark.parametrize("seed", range(4))
def test_fault_invariants_bin(seed):
    check_fault_invariants(100 + seed, "bin")


@pytest.mark.parametrize("seed", range(3))
def test_fault_invariants_mixed_classes(seed):
    check_fault_invariants(200 + seed, "edl",
                           classes=("gtx-1080ti", "tpu-v5e"))


def test_crash_exactly_at_arrival_slot_boundary():
    """Events AT a slot time apply before the slot's group is placed: the
    group can never land on the just-crashed server."""
    ts = _golden_task_set()
    r = online.schedule_online(
        ts, l=1, algorithm="edl", use_dvfs=False, bound=False,
        faults=FaultTrace.from_events([(1.0, 0, "fail")]))
    srv0_live = [a for a in r.assignments
                 if a.pair == 0 and not a.failed and a.start >= 1.0]
    assert not srv0_live
    assert r.violations == 0


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           algorithm=st.sampled_from(["edl", "bin"]),
           l=st.sampled_from([1, 2, 4]))
    def test_fault_invariants_hypothesis(seed, algorithm, l):
        check_fault_invariants(seed, algorithm, l=l)
