"""Trainer (grad accumulation, compression) and the accelerator-job adapter
that feeds roofline-derived LM jobs into the paper's scheduler."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.jobs import (AcceleratorJob, RooflineTerms, jobs_to_task_set,
                             synth_job_stream)
from repro.core.scheduling import schedule_offline
from repro.data.pipeline import SyntheticLMData
from repro.models.model import Model
from repro.optim.adamw import AdamW
from repro.train.trainer import init_state, make_train_step


def small_model():
    return Model(get_config("stablelm-12b").reduced())


def batch_of(model, B=8, S=32, seed=0, mode="succ"):
    d = SyntheticLMData.for_config(model.cfg, S, B, seed=seed, mode=mode)
    return {k: jnp.asarray(v) for k, v in d.batch(0).items()}


def test_grad_accumulation_matches_single_batch():
    model = small_model()
    opt = AdamW(learning_rate=1e-3)
    state = init_state(model, opt, jax.random.key(0))
    batch = batch_of(model)
    s1 = make_train_step(model, opt, microbatches=1,
                         param_axes=model.param_axes())
    s4 = make_train_step(model, opt, microbatches=4,
                         param_axes=model.param_axes())
    n1, m1 = s1(state, batch)
    n4, m4 = s4(state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-3)
    assert float(m1["grad_norm"]) == pytest.approx(float(m4["grad_norm"]),
                                                   rel=1e-3)
    # Adam normalizes per-element, so bf16 grad noise near zero can flip an
    # update's sign: param diffs are bounded by ~2 * lr, not by grad error.
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        n1.params, n4.params)
    assert max(jax.tree.leaves(diffs)) < 3.0 * 1e-3


def test_training_reduces_loss_on_copy_task():
    model = small_model()
    opt = AdamW(learning_rate=3e-3)
    state = init_state(model, opt, jax.random.key(0))
    step = jax.jit(make_train_step(model, opt,
                                   param_axes=model.param_axes()),
                   donate_argnums=0)
    data = SyntheticLMData.for_config(model.cfg, 64, 8, mode="succ")
    first = last = None
    for i in range(30):
        state, m = step(state, {k: jnp.asarray(v)
                                for k, v in data.batch(i).items()})
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.5, (first, last)


def test_compressed_grads_still_learn():
    model = small_model()
    opt = AdamW(learning_rate=3e-3)
    state = init_state(model, opt, jax.random.key(0))
    step = jax.jit(make_train_step(model, opt, compress_grads=True,
                                   param_axes=model.param_axes()),
                   donate_argnums=0)
    data = SyntheticLMData.for_config(model.cfg, 64, 8, mode="succ")
    first = last = None
    for i in range(25):
        state, m = step(state, {k: jnp.asarray(v)
                                for k, v in data.batch(i).items()})
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.3
    assert "quant_err" in m


# -- accelerator-job adapter (paper technique as framework feature) -------------


def test_roofline_terms_delta():
    t = RooflineTerms("a", "s", compute_s=3.0, memory_s=1.0,
                      collective_s=0.5)
    assert t.delta == pytest.approx(0.75)
    assert t.bottleneck == "compute"
    assert t.step_time == 3.0


def test_job_params_collective_share_joins_t0():
    t = RooflineTerms("a", "s", compute_s=1.0, memory_s=0.5,
                      collective_s=0.8)
    job = AcceleratorJob(arch="a", shape="s", steps=100, arrival=0.0,
                         deadline_slack=2.0, terms=t)
    p = job.to_params()
    # t0 fraction >= collective fraction of the step
    assert float(p.t0) / float(p.default_time()) >= 0.8 / 1.0 - 1e-6


def test_jobs_schedule_end_to_end():
    terms = {
        "qwen2-72b/train_4k": RooflineTerms("qwen2-72b", "train_4k",
                                            3.0, 1.0, 0.4),
        "mamba2-370m/decode_32k": RooflineTerms("mamba2-370m", "decode_32k",
                                                0.1, 0.9, 0.05),
    }
    jobs = synth_job_stream(terms, n_jobs=40, seed=1)
    ts = jobs_to_task_set(jobs)
    assert len(ts) == 40
    r = schedule_offline(ts.subset(ts.arrival == 0.0), l=2, theta=0.9,
                         algorithm="edl")
    assert r.violations == 0
    # compute-bound jobs should get delta close to 0.75, memory-bound low
    deltas = np.asarray(ts.params.delta)
    assert deltas.min() < 0.3 and deltas.max() > 0.6
