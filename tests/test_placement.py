"""The shared placement subsystem (``core/placement.py``) and the §5
theoretical bound (``core/bounds.py``).

``schedule_offline`` is now a thin driver over the same placement core the
online simulator uses.  These tests pin

* scalar/vector bit-identity for all four offline policies across
  {homogeneous, mixed-class} x theta in {1.0, 0.7};
* the PR-1 offline golden energies, unchanged to 1e-9 rel (exact values
  re-recorded from the pre-refactor implementation at commit 2b52443,
  which reproduced the seed goldens of ``tests/test_engine.py`` to 1e-6);
* the §5 wide-interval ~36% savings ceiling from ``theoretical_bound``
  and the e_bound reporting contract of both schedulers.
"""

import numpy as np
import pytest

from repro.core import bounds, cluster as cl, machines, online, scheduling, tasks


@pytest.fixture(scope="module")
def library():
    return tasks.app_library()


MIXES = {"homogeneous": None, "mixed": ("gtx-1080ti", "tpu-v5e")}

# Exact e_total/e_idle of the pre-refactor schedule_offline (commit
# 2b52443) on generate_offline(0.1, seed=3), l=2, theta=0.9 — the same
# scenario whose seed goldens tests/test_engine.py pins at 1e-6.  The
# placement-subsystem driver must reproduce them to 1e-9 rel (it matches
# bit-for-bit).
OFFLINE_GOLDEN_EXACT = {
    "edl":    (3678787.8401555126, 6735.992463771603, 84, 42, 0),
    "edf-wf": (3669301.5104696816, 18451.408134148674, 91, 46, 0),
    "edf-bf": (3725938.3543846672, 75088.25204913388, 78, 39, 0),
    "lpt-ff": (3708240.1715263743, 57390.06919084124, 114, 57, 0),
}


def _fields(a):
    return (a.task, a.pair, a.start, a.finish, a.v, a.fc, a.fm, a.power,
            a.energy, a.readjusted, a.class_id)


# ---------------------------------------------------------------------------
# Scalar vs vectorized offline placement: bit-identical.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("theta", [1.0, 0.7])
@pytest.mark.parametrize("mix", sorted(MIXES))
@pytest.mark.parametrize("alg", ["edl", "edf-wf", "edf-bf", "lpt-ff"])
def test_offline_vector_bit_identical(alg, mix, theta, library):
    ts = tasks.generate_offline(0.08, seed=13, library=library)
    kw = dict(l=3, theta=theta, algorithm=alg, classes=MIXES[mix],
              bound=False)
    r_s = scheduling.schedule_offline(ts, placement="scalar", **kw)
    r_v = scheduling.schedule_offline(ts, placement="vector", **kw)
    assert r_v.e_total == r_s.e_total           # bit-for-bit
    assert r_v.e_idle == r_s.e_idle
    assert (r_v.n_pairs, r_v.n_servers, r_v.violations) == \
        (r_s.n_pairs, r_s.n_servers, r_s.violations)
    assert len(r_v.assignments) == len(r_s.assignments)
    for a, b in zip(r_s.assignments, r_v.assignments):
        assert _fields(a) == _fields(b)


def test_offline_vector_bit_identical_wide_batch(library):
    """A batch large enough (~2k tasks) to exercise the bulk fresh-open
    heap path of the vectorized offline EDL placement."""
    ts = tasks.generate_offline_n(2000, seed=1, library=library)
    kw = dict(l=4, theta=0.9, algorithm="edl", bound=False)
    r_s = scheduling.schedule_offline(ts, placement="scalar", **kw)
    r_v = scheduling.schedule_offline(ts, placement="vector", **kw)
    assert r_v.e_total == r_s.e_total
    for a, b in zip(r_s.assignments, r_v.assignments):
        assert _fields(a) == _fields(b)


def test_unknown_offline_placement_rejected(library):
    ts = tasks.generate_offline(0.02, seed=0, library=library)
    with pytest.raises(ValueError):
        scheduling.schedule_offline(ts, placement="warp")


# ---------------------------------------------------------------------------
# PR-1 golden energies: unchanged through the refactor.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("alg", sorted(OFFLINE_GOLDEN_EXACT))
def test_offline_energies_unchanged_to_1e9(alg, library):
    ts = tasks.generate_offline(0.1, seed=3, library=library)
    r = scheduling.schedule_offline(ts, l=2, theta=0.9, algorithm=alg)
    e_total, e_idle, n_pairs, n_servers, violations = \
        OFFLINE_GOLDEN_EXACT[alg]
    assert r.e_total == pytest.approx(e_total, rel=1e-9)
    assert r.e_idle == pytest.approx(e_idle, rel=1e-9)
    assert (r.n_pairs, r.n_servers, r.violations) == \
        (n_pairs, n_servers, violations)
    # ... and the seed goldens of tests/test_engine.py still hold at their
    # original 1e-6 through this exact chain.
    from test_engine import OFFLINE_GOLDEN
    assert r.e_total == pytest.approx(OFFLINE_GOLDEN[alg][0], rel=1e-6)


# The old hasattr-based meta test ("online.py owns no placement internals")
# is retired: the layer-contract lint rule (tools/lint, backed by
# tools/lint/layer_dag.py) now enforces the import DAG for every module,
# not just this one edge — tests/test_lint.py covers it.


# ---------------------------------------------------------------------------
# The §5 theoretical bound.
# ---------------------------------------------------------------------------


def test_theoretical_bound_reproduces_wide_ceiling(library):
    """Paper §5: with the wide (analytic) scaling interval at most ~36% of
    energy can be saved; the generated library is calibrated to the 36.4%
    Fig. 4 anchor and the aggregate ceiling lands right there."""
    ts = tasks.generate_offline(0.3, seed=0, library=library)
    b = bounds.theoretical_bound(ts)
    assert b.savings_ceiling == pytest.approx(0.3646, abs=0.01)
    assert b.e_idle == 0.0 and b.e_overhead == 0.0   # exact-fit floor
    assert b.e_baseline == pytest.approx(cl.baseline_energy(ts))


def test_achieved_savings_stay_below_ceiling(library):
    """The schedulers' achieved savings (paper: 33-35%) must sit below the
    analytical ceiling, and every reported e_total above its e_bound."""
    ts = tasks.generate_offline(0.3, seed=0, library=library)
    base = cl.baseline_energy(ts)
    r = scheduling.schedule_offline(ts, l=1, algorithm="edl")
    assert r.e_bound > 0.0
    assert r.e_total >= r.e_bound
    achieved = 1.0 - r.e_total / base
    ceiling = bounds.theoretical_bound(ts).savings_ceiling
    assert 0.30 <= achieved <= ceiling


def test_bound_floor_per_task(library):
    """Per-task check: no assignment's energy beats its unconstrained
    optimum (the bound's run floor is truly per-task)."""
    ts = tasks.generate_offline(0.05, seed=21, library=library)
    from repro.core import dvfs, single_task
    mcs = machines.resolve_classes(None)
    params, _, _, _ = single_task.pad_pow2(ts.params, np.zeros(len(ts)))
    e_unc = bounds.unconstrained_energies(params, mcs, dvfs.WIDE, len(ts))
    r = scheduling.schedule_offline(ts, l=2, theta=0.9, algorithm="edl")
    for a in r.assignments:
        assert a.energy >= e_unc[0, a.task] - 1e-6 * abs(e_unc[0, a.task])


def test_online_bound_includes_drs_floors(library):
    """rho > 0 adds the exact online floors: one power-on of l pairs
    (Delta each) and rho idle slots per powered pair."""
    ts = tasks.generate_online(0.02, 0.05, seed=1, horizon=200,
                               library=library)
    b_off = bounds.theoretical_bound(ts)
    b_on = bounds.theoretical_bound(ts, l=4, rho=2)
    assert b_on.e_run == b_off.e_run
    assert b_on.e_idle == pytest.approx(cl.P_IDLE * 2 * 4)
    assert b_on.e_overhead == pytest.approx(cl.DELTA_ON * 4)
    r = online.schedule_online(ts, l=4, theta=1.0, algorithm="edl")
    assert r.e_bound == pytest.approx(b_on.e_bound)
    assert r.e_total >= r.e_bound


def test_bound_flag_and_summary(library):
    ts = tasks.generate_offline(0.02, seed=2, library=library)
    r0 = scheduling.schedule_offline(ts, bound=False)
    assert r0.e_bound == 0.0 and r0.bound_gap == 0.0
    r1 = scheduling.schedule_offline(ts)
    assert r1.e_bound > 0.0
    assert r1.summary()["e_bound"] == r1.e_bound
    assert r1.bound_gap == pytest.approx(r1.e_total / r1.e_bound - 1.0)


def test_bound_empty_task_set():
    empty = tasks.TaskSet(np.zeros(0), np.zeros(0),
                          tasks.app_library()[np.zeros(0, dtype=np.int64)],
                          np.zeros(0))
    b = bounds.theoretical_bound(empty)
    assert b.e_bound == 0.0 and b.savings_ceiling == 0.0


# ---------------------------------------------------------------------------
# Engine bulk accessors backing the subsystem.
# ---------------------------------------------------------------------------


def test_engine_open_pairs_matches_scalar_loop():
    from repro.core.engine import ClusterEngine
    a = ClusterEngine(l=2, servers=False,
                      classes=machines.get_classes(("gtx-1080ti",
                                                    "tpu-v5e")))
    b = ClusterEngine(l=2, servers=False,
                      classes=machines.get_classes(("gtx-1080ti",
                                                    "tpu-v5e")))
    cls = np.asarray([0, 1, 1, 0, 1], dtype=np.int64)
    base = a.open_pairs(cls)
    assert base == 0 and a.n_pairs == 5
    for c in cls:
        b.open_pair(class_id=int(c))
    np.testing.assert_array_equal(a.pair_class, b.pair_class)
    np.testing.assert_array_equal(a.mu, b.mu)


def test_engine_pool_ids_offline_and_online():
    from repro.core.engine import ClusterEngine
    mcs = machines.get_classes(("gtx-1080ti", "tpu-v5e"))
    off = ClusterEngine(l=2, servers=False, classes=mcs)
    off.open_pairs(np.asarray([0, 1, 0], dtype=np.int64))
    np.testing.assert_array_equal(off.pool_ids(0), [0, 2])
    np.testing.assert_array_equal(off.pool_ids(1), [1])
    on = ClusterEngine(l=2, servers=True, classes=mcs)
    on.acquire_pair(0.0, class_id=1)
    on.acquire_pair(0.0, class_id=0)
    on.drs_sweep(100.0)                    # both servers power off
    assert on.pool_ids(0).size == 0 and on.pool_ids(1).size == 0
    on.acquire_pair(100.0, class_id=1)     # re-wakes the class-1 server
    np.testing.assert_array_equal(on.pool_ids(1), [0, 1])
