"""Per-kernel shape/dtype sweeps, assert_allclose vs the ref.py oracles
(interpret mode executes the kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tasks as tasklib
from repro.kernels import ops, ref

KEY = jax.random.key(42)


@pytest.mark.parametrize("B,H,KV,S,dh", [
    (1, 2, 2, 128, 64),
    (2, 4, 2, 256, 64),
    (1, 8, 8, 384, 128),
    (2, 4, 1, 256, 80),     # MQA + non-128 head_dim (pad path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(B, H, KV, S, dh, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, hash((B, H, S, dh)) %
                                             2**31), 3)
    q = jax.random.normal(ks[0], (B, H, S, dh), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, dh), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, dh), dtype)
    out = ops.flash_attention(q, k, v, causal=True)
    exp = ref.attention_ref(q, k, v, causal=True)
    tol = 2e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol)


@pytest.mark.parametrize("window", [64, 128])
def test_flash_attention_sliding_window(window):
    B, H, KV, S, dh = 1, 4, 2, 256, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, S, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, S, dh), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    exp = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-3)


def test_flash_attention_noncausal():
    B, H, KV, S, dh = 2, 2, 2, 128, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, S, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, S, dh), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False)
    exp = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-3)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 128, 2, 64, 128, 64),
    (2, 256, 4, 64, 128, 128),
    (1, 256, 2, 128, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan(B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, S + P), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    b = (jax.random.normal(ks[3], (B, S, N)) * 0.3).astype(dtype)
    c = (jax.random.normal(ks[4], (B, S, N)) * 0.3).astype(dtype)
    y = ops.ssd_scan(x, dt, a, b, c, chunk=chunk)
    exp = ref.ssd_ref(x, dt, a, b, c)
    scale = float(jnp.max(jnp.abs(exp.astype(jnp.float32)))) + 1e-6
    tol = 2e-3 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(y, np.float32) / scale,
                               np.asarray(exp, np.float32) / scale,
                               atol=tol)


def test_ssd_matches_model_chunked_path():
    """Kernel vs the model's production jnp chunked implementation."""
    from repro.models.ssm import ssd_chunked
    B, S, H, P, N = 2, 256, 4, 64, 64
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    b = jax.random.normal(ks[3], (B, S, N)) * 0.3
    c = jax.random.normal(ks[4], (B, S, N)) * 0.3
    y_kernel = ops.ssd_scan(x, dt, a, b, c, chunk=128)
    y_model, _ = ssd_chunked(x, dt, a, b, c, chunk=128)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               atol=5e-3)


def test_dvfs_kernel_full_library():
    lib = tasklib.generate_offline(0.08, seed=9)
    allowed = lib.deadline - lib.arrival
    sol = ops.dvfs_solve(lib.params, allowed)
    tasks_mat = np.stack(
        [np.asarray(f, np.float32) for f in lib.params.astuple()]
        + [np.asarray(allowed, np.float32),
           np.zeros(len(lib), np.float32)], axis=1)
    expect = ref.dvfs_solve_ref(tasks_mat)
    rel = np.abs(sol.energy - expect[:, 5]) / expect[:, 5]
    # hierarchical (G0, G1) refinement: ~1e-7 typical, vs ~1e-5 flat-128
    assert float(np.max(rel)) < 1e-5
    assert float(np.mean(sol.deadline_prior == (expect[:, 6] > .5))) > 0.99
    # feasible solutions respect the deadline
    ok = sol.feasible
    assert np.all(sol.time[ok] <= np.asarray(allowed)[ok] * (1 + 1e-4))


def test_dvfs_kernel_narrow_interval():
    """Kernel/oracle parity on the realistic NARROW (GTX-1080Ti) interval."""
    from repro.core import dvfs

    lib = tasklib.generate_offline(0.06, seed=21)
    allowed = lib.deadline - lib.arrival
    sol = ops.dvfs_solve(lib.params, allowed, interval=dvfs.NARROW)
    tasks_mat = np.stack(
        [np.asarray(f, np.float32) for f in lib.params.astuple()]
        + [np.asarray(allowed, np.float32),
           np.zeros(len(lib), np.float32)], axis=1)
    expect = ref.dvfs_solve_ref(tasks_mat, interval=dvfs.NARROW)
    rel = np.abs(sol.energy - expect[:, 5]) / expect[:, 5]
    assert float(np.max(rel)) < 1e-5
    assert float(np.mean(sol.deadline_prior == (expect[:, 6] > .5))) > 0.99
    # solutions stay inside the NARROW box
    assert np.all(sol.fm >= dvfs.NARROW.fm_min - 1e-5)
    assert np.all(sol.fm <= dvfs.NARROW.fm_max + 1e-5)
    assert np.all(sol.fc <= dvfs.NARROW.fc_max + 1e-4)


def test_dvfs_kernel_readjust_path():
    """The kernel's theta-readjustment sweep (column-7 flag) matches the
    scalar ``single_task.readjust`` decisions within grid tolerance."""
    from repro.core.dvfs import DvfsParams

    from repro.core import dvfs

    lib = tasklib.app_library()
    rows = [lib[i] for i in range(8)]
    params = DvfsParams.stack(rows)
    tstar = np.asarray(params.default_time())
    tmin = np.asarray(dvfs.min_time(params, dvfs.WIDE))
    # feasible windows strictly below the default execution time (and hence
    # below the optimal DVFS time): the theta-readjustment regime
    windows = tmin + (tstar - tmin) * np.linspace(0.15, 0.9, 8)
    sol = ops.dvfs_solve(params, windows, readjust=True)
    for i in range(8):
        v, fc, fm, t, p, e = ref.dvfs_solve_ref(
            np.asarray([[*np.asarray(params[i].astuple(), np.float32),
                         np.float32(windows[i]), 1.0]], np.float32))[0][:6]
        assert abs(sol.energy[i] - e) / e < 1e-2
        # both respect the shrunken window
        assert sol.time[i] <= windows[i] * (1 + 1e-4)
        assert t <= windows[i] * (1 + 1e-4)
    # and the batched production path agrees with the scalar readjust
    from repro.core import single_task
    vb, fcb, fmb, tb, pb, eb = single_task.readjust_batch(
        params, windows, use_kernel=True)
    for i in range(8):
        vs, fcs, fms, ts_, ps, es = single_task.readjust(
            params[i], float(windows[i]))
        assert abs(eb[i] - es) / es < 1e-2
        assert tb[i] == pytest.approx(min(float(windows[i]), ts_), rel=1e-4)


def test_dvfs_kernel_through_scheduler():
    """configure_tasks(use_kernel=True) plugs the Pallas solver into
    Algorithm 1 and must produce a near-identical schedule."""
    from repro.core import scheduling
    ts = tasklib.generate_offline(0.05, seed=13)
    r_ref = scheduling.schedule_offline(ts, l=2, algorithm="edl",
                                        use_kernel=False)
    r_ker = scheduling.schedule_offline(ts, l=2, algorithm="edl",
                                        use_kernel=True)
    assert r_ker.violations == 0
    assert r_ker.e_total == pytest.approx(r_ref.e_total, rel=2e-3)


# ---------------------------------------------------------------------------
# Differential fuzz: the hierarchical kernel vs the kernels/ref.py oracle on
# random widened [n, 16] matrices — random params, random windows, random
# readjust flags, and MIXED per-row interval boxes including a degenerate
# (single-point) box.  The seeded sweep always runs; the same checker runs
# under hypothesis when installed (CI installs it).
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _fuzz_boxes(rng):
    """A few random scaling boxes plus one degenerate single-point box
    (v_min == v_max, fm_min == fm_max, fc pinned at g1(v_max))."""
    from repro.core import dvfs

    boxes = [dvfs.WIDE.bounds(), dvfs.NARROW.bounds()]
    for _ in range(2):
        v_min = float(rng.uniform(0.5, 0.9))
        v_max = float(rng.uniform(v_min + 0.05, 1.24))
        fm_min = float(rng.uniform(0.5, 0.9))
        boxes.append((v_min, v_max, float(rng.uniform(0.5, 0.8)),
                      fm_min, float(rng.uniform(fm_min + 0.05, 1.2))))
    v = float(rng.uniform(0.7, 1.2))
    fc = dvfs.g1_float(v)
    boxes.append((v, v, fc, 1.0, 1.0))        # degenerate: one point
    return boxes


def check_kernel_matches_oracle_fuzz(seed: int, n: int = 64):
    from repro.core import dvfs
    from repro.core.dvfs import DvfsParams

    rng = np.random.default_rng(seed)
    p_star = rng.uniform(120, 260, n)
    gamma = p_star * rng.uniform(0.05, 0.25, n)
    p0 = p_star * rng.uniform(0.1, 0.5, n)
    params = DvfsParams(p0=p0, gamma=gamma, c=p_star - gamma - p0,
                        big_d=rng.uniform(1.0, 50.0, n),
                        delta=rng.uniform(0.0, 1.0, n),
                        t0=rng.uniform(0.05, 5.0, n))
    boxes = _fuzz_boxes(rng)
    bounds = np.asarray([boxes[i] for i in rng.integers(0, len(boxes), n)],
                        np.float32)
    tstar = np.asarray(params.default_time())
    tmin = np.asarray([float(dvfs.min_time(params[i],
                                           dvfs.ScalingInterval(*bounds[i])))
                       for i in range(n)])
    readj = (rng.random(n) < 0.3).astype(np.float32)
    # windows span infeasible (below t_min) through slack (2 t*); readjust
    # rows stay >= t_min (the boundary solve's contract: a bookable window)
    lo = np.where(readj > 0.5, tmin, 0.5 * tmin)
    allowed = lo + (2.0 * tstar - lo) * rng.random(n)
    mat = np.stack([np.asarray(f, np.float32) for f in params.astuple()]
                   + [allowed.astype(np.float32), readj], axis=1)
    mat = np.concatenate([mat, bounds, np.zeros((n, 3), np.float32)], axis=1)
    assert mat.shape == (n, 16)

    got = ops.dvfs_solve_matrix(mat)
    expect = ref.dvfs_solve_ref(mat)

    e_got, e_exp = got[:, 5], expect[:, 5]
    rel = np.abs(e_got - e_exp) / np.maximum(e_exp, 1e-9)
    assert float(np.median(rel)) < 2e-3
    assert float(np.mean(rel)) < 1e-2
    assert float(np.mean((got[:, 6] > .5) == (expect[:, 6] > .5))) >= 0.9
    # solutions stay inside their per-row box
    for j, (lo_c, hi_c) in ((0, (8, 9)), (2, (11, 12))):   # v, fm
        assert np.all(got[:, j] >= mat[:, lo_c] - 1e-4)
        assert np.all(got[:, j] <= mat[:, hi_c] + 1e-4)
    assert np.all(got[:, 1] >= mat[:, 10] - 1e-4)          # fc >= fc_min
    # feasible deadline-prior rows respect their window (both sides)
    for out in (got, expect):
        ok = (out[:, 7] > .5) & (out[:, 6] > .5)
        assert np.all(out[ok, 3] <= allowed[ok] * (1 + 1e-3))


@pytest.mark.parametrize("seed", range(5))
def test_dvfs_kernel_fuzz_vs_oracle(seed):
    check_kernel_matches_oracle_fuzz(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_dvfs_kernel_fuzz_vs_oracle_hypothesis(seed):
        check_kernel_matches_oracle_fuzz(seed, n=32)
