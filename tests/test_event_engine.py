"""Event-driven online engine: exact DRS power-off accounting and the
vectorized placement path.

The old ``drs_sweep`` booked ``t_sweep - on_since`` at whatever arrival
slot the sweep happened to land on; the event engine books every power-off
at its exact event time ``mu + rho``.  These tests pin the analytic
consequences (sparse-arrival idle energy, gap invariance, span exactness)
and the bit-identity of the scalar and vectorized placement paths.
"""

import numpy as np
import pytest

from repro.core import cluster as cl, machines, online, scheduling, single_task, tasks
from repro.core.dvfs import DvfsParams
from repro.core.engine import ClusterEngine


@pytest.fixture(scope="module")
def library():
    return tasks.app_library()


def sparse_ts(n=40, gap=37, seed=5, scale=1, library=None):
    """Short tasks at arrival slots ``gap`` apart: service << gap, so every
    server powers off between arrivals (the regime the sweep overbilled)."""
    rng = np.random.default_rng(seed)
    lib = library if library is not None else tasks.app_library()
    rows, us = [], []
    for _ in range(n):
        app = lib[int(rng.integers(20))]
        rows.append(DvfsParams(app.p0, app.gamma, app.c, app.big_d * scale,
                               app.delta, app.t0 * scale))
        us.append(float(rng.uniform(0.3, 0.9)))
    params = DvfsParams.stack(rows)
    arrival = (1.0 + gap * np.arange(n)).astype(np.float64)
    t_star = np.asarray(params.default_time())
    deadline = arrival + t_star / np.asarray(us)
    return tasks.TaskSet(arrival, deadline, params, np.asarray(us))


# ---------------------------------------------------------------------------
# Exact power-off accounting (the drs_sweep overbilling fix).
# ---------------------------------------------------------------------------


def test_sparse_idle_is_analytic(library):
    """l=1, one task per visit, gaps >> rho: every cycle idles exactly rho,
    so E_idle == P_idle * rho * n to 1e-9 rel (Eq. 7 with exact events)."""
    ts = sparse_ts(library=library)
    r = online.schedule_online(ts, l=1, theta=1.0, algorithm="edl",
                               use_dvfs=False)
    assert r.violations == 0
    assert r.e_idle == pytest.approx(cl.P_IDLE * cl.RHO * len(ts), rel=1e-9)
    # every task re-wakes the single server: overhead is exact too
    assert r.e_overhead == pytest.approx(cl.DELTA_ON * len(ts), rel=1e-9)


def test_idle_invariant_to_arrival_free_gaps(library):
    """Dilating the arrival gaps (inserting arrival-free slots) must not
    change E_idle: power-off events bill mu + rho - on_since regardless of
    when the next arrival lands.  (The old sweep billed the full gap.)"""
    base = sparse_ts(gap=37, library=library)
    for gap in (101, 370, 97911):
        dilated = sparse_ts(gap=gap, library=library)
        for l in (1, 2):
            r0 = online.schedule_online(base, l=l, theta=1.0,
                                        algorithm="edl", use_dvfs=False)
            r1 = online.schedule_online(dilated, l=l, theta=1.0,
                                        algorithm="edl", use_dvfs=False)
            assert r1.e_idle == pytest.approx(r0.e_idle, rel=1e-9), \
                (gap, l)
            assert r1.e_overhead == pytest.approx(r0.e_overhead, rel=1e-9)


def test_removed_overcharge_matches_arrival_gap_derivation(library):
    """The delta vs the old sweep accounting is exactly the accumulated
    arrival-gap overcharge.  With l=1, one task per gap, service w_i and
    integer arrivals every ``gap`` slots, the old sweep billed the full
    ``gap`` for each of the first n-1 cycles (power-off observed only at
    the next arrival) and the exact ``w_last + rho`` at finalize; the event
    engine bills ``w_i + rho`` everywhere.  So

        e_idle_old - e_idle_new = P_idle * sum_{i<n-1} (gap - w_i - rho).
    """
    gap = 37
    ts = sparse_ts(gap=gap, library=library)
    r = online.schedule_online(ts, l=1, theta=1.0, algorithm="edl",
                               use_dvfs=False)
    w = np.asarray(ts.params.default_time())
    assert np.all(w + cl.RHO < gap)  # the sweep regime the test targets
    overcharge = cl.P_IDLE * float(np.sum(gap - w[:-1] - cl.RHO))
    # old booking: first n-1 cycles billed `gap - w_i` idle each (span gap,
    # busy w_i), the last cycle billed exactly rho at finalize.
    e_idle_old = cl.P_IDLE * (float(np.sum(gap - w[:-1])) + cl.RHO)
    assert e_idle_old - r.e_idle == pytest.approx(overcharge, rel=1e-9)


def test_append_late_noop_arrival_adds_only_own_cycle(library):
    """Regression for the sweep overbilling: appending one arbitrarily late
    arrival must add exactly that task's own cycle (rho idle + one turn-on)
    — under the old sweep it also re-billed every still-off server's gap."""
    base = sparse_ts(n=20, library=library)
    extra_at = float(base.arrival[-1]) + 1.0e6
    extra = tasks.TaskSet(
        np.asarray([extra_at]),
        np.asarray([extra_at + float(base.t_star[0]) / 0.5]),
        base.params[np.asarray([0])], np.asarray([0.5]))
    r0 = online.schedule_online(base, l=1, theta=1.0, algorithm="edl",
                                use_dvfs=False)
    r1 = online.schedule_online(base.concat(extra), l=1, theta=1.0,
                                algorithm="edl", use_dvfs=False)
    assert r1.e_idle == pytest.approx(r0.e_idle + cl.P_IDLE * cl.RHO,
                                      rel=1e-9)
    assert r1.e_overhead == pytest.approx(r0.e_overhead + cl.DELTA_ON,
                                          rel=1e-9)


@pytest.mark.parametrize("seed", range(8))
def test_power_off_span_is_exact_for_any_settle_times(seed):
    """Engine property: however sparse or irregular the settle times, every
    power-off books exactly mu_srv + rho - on_since."""
    rng = np.random.default_rng(seed)
    eng = ClusterEngine(l=2, rho=3, p_idle=10.0, delta_on=5.0)
    expected_on_time = 0.0
    t = 0.0
    on_since = {}
    mu_srv = {}
    for _ in range(40):
        t += float(rng.uniform(0.1, 50.0))           # arbitrary gaps
        eng.settle(t)
        # replicate the event rule on the shadow state
        for sid in list(on_since):
            if mu_srv[sid] + eng.rho <= t + 1e-9:
                expected_on_time += mu_srv[sid] + eng.rho - on_since[sid]
                del on_since[sid]
        booked = float(eng._on_time[: eng.n_servers].sum())
        assert booked == pytest.approx(expected_on_time, rel=1e-12,
                                       abs=1e-9)
        if rng.uniform() < 0.7:
            pid = eng.acquire_pair(t)
            sid = pid // eng.l
            if sid not in on_since:
                on_since[sid] = t
                mu_srv[sid] = t
            dur = float(rng.uniform(0.1, 8.0))
            eng.assign(pid, t, dur)
            mu_srv[sid] = max(mu_srv[sid], t + dur)
    eng.finalize()
    for sid, since in on_since.items():
        expected_on_time += mu_srv[sid] + eng.rho - since
    assert float(eng._on_time[: eng.n_servers].sum()) == \
        pytest.approx(expected_on_time, rel=1e-12, abs=1e-9)


@pytest.mark.parametrize("seed", range(6))
def test_e_idle_nonnegative_any_pattern(seed, library):
    """e_idle >= 0 and the Eq. 7 identity holds for every arrival pattern
    (including fractional arrivals)."""
    rng = np.random.default_rng(100 + seed)
    pattern = tasks.TRACE_PATTERNS[seed % len(tasks.TRACE_PATTERNS)]
    ts = tasks.generate_trace(200, pattern=pattern, horizon=300,
                              seed=seed, library=library)
    if seed % 2:  # perturb to fractional arrivals
        frac = rng.uniform(0.0, 0.999, len(ts))
        ts = tasks.TaskSet(ts.arrival - frac, ts.deadline, ts.params,
                           ts.utilization)
    l = int(rng.choice([1, 2, 4]))
    r = online.schedule_online(ts, l=l, theta=0.9, algorithm="edl")
    assert r.e_idle >= 0.0
    assert r.e_overhead >= 0.0
    assert r.e_total == pytest.approx(r.e_run + r.e_idle + r.e_overhead)


def test_settle_time_does_not_change_booking():
    """settle(t) and settle(t + huge) book the same span for an event that
    already occurred (the sweep used to bill up to its own call time)."""
    spans = []
    for late in (5.0, 5.0e7):
        eng = ClusterEngine(l=1, rho=2, p_idle=1.0, delta_on=0.0)
        pid = eng.acquire_pair(0.0)
        eng.assign(pid, 0.0, 1.5)        # off event at 3.5
        eng.settle(late)
        spans.append(float(eng._on_time[0]))
    assert spans[0] == spans[1] == pytest.approx(3.5)


# ---------------------------------------------------------------------------
# Fractional arrivals (ceil semantics).
# ---------------------------------------------------------------------------


def test_fractional_arrivals_never_start_early(library, rng):
    """A task arriving at 3.7 is grouped at slot 4, not slot 3: no
    assignment may start before its arrival, and its DVFS window is
    d - ceil(a), not the wider d - floor(a)."""
    ts0 = tasks.generate_trace(60, pattern="uniform", horizon=50, seed=3,
                               library=library)
    frac = rng.uniform(0.01, 0.99, len(ts0))
    ts = tasks.TaskSet(ts0.arrival - frac, ts0.deadline, ts0.params,
                       ts0.utilization)
    for placement in ("scalar", "vector"):
        r = online.schedule_online(ts, l=2, theta=0.9, algorithm="edl",
                                   placement=placement)
        for a in r.assignments:
            assert a.start >= ts.arrival[a.task] - 1e-9, \
                (a.task, a.start, ts.arrival[a.task])


def test_online_window_uses_ceil(library):
    mcs = machines.reference_classes()
    arrival = np.asarray([2.3])
    ts = tasks.TaskSet(arrival, np.asarray([50.0]),
                       library[np.asarray([0])], np.asarray([0.5]))
    assert online.arrival_slots(ts)[0] == 3.0
    cfgs = online.online_configs(ts, mcs, use_dvfs=False)
    # window is d - ceil(a) = 47, not d - floor(a) = 48
    assert bool(cfgs[0].feasible[0]) == (float(ts.t_star[0]) <= 47.0 + 1e-9)


# ---------------------------------------------------------------------------
# Scalar vs vectorized placement: bit-identical.
# ---------------------------------------------------------------------------


def _fields(a):
    return (a.task, a.pair, a.start, a.finish, a.v, a.fc, a.fm, a.power,
            a.energy, a.readjusted, a.class_id)


@pytest.mark.parametrize("alg", ["edl", "bin"])
def test_vector_placement_bit_identical_mixed_classes(alg, library):
    """EDL/bin online results are bit-identical between the scalar and
    vectorized placement paths on a ~1k-task mixed-class horizon."""
    ts = tasks.generate_online(0.05, 0.45, seed=11, horizon=300,
                               library=library)
    assert len(ts) > 900
    kw = dict(l=2, theta=0.9, algorithm=alg,
              classes=("gtx-1080ti", "tpu-v5e"))
    r_s = online.schedule_online(ts, placement="scalar", **kw)
    r_v = online.schedule_online(ts, placement="vector", **kw)
    assert r_v.e_total == r_s.e_total           # bit-for-bit
    assert r_v.e_idle == r_s.e_idle
    assert r_v.e_overhead == r_s.e_overhead
    assert (r_v.n_pairs, r_v.n_servers, r_v.violations) == \
        (r_s.n_pairs, r_s.n_servers, r_s.violations)
    assert len(r_v.assignments) == len(r_s.assignments)
    for a, b in zip(r_s.assignments, r_v.assignments):
        assert _fields(a) == _fields(b)


@pytest.mark.parametrize("l,theta", [(1, 0.8), (4, 1.0), (16, 0.9)])
def test_vector_placement_bit_identical_homogeneous(l, theta, library):
    ts = tasks.generate_online(0.05, 0.3, seed=7, horizon=200,
                               library=library)
    r_s = online.schedule_online(ts, l=l, theta=theta, placement="scalar",
                                 algorithm="edl")
    r_v = online.schedule_online(ts, l=l, theta=theta, placement="vector",
                                 algorithm="edl")
    assert r_v.e_total == r_s.e_total
    for a, b in zip(r_s.assignments, r_v.assignments):
        assert _fields(a) == _fields(b)


def test_unknown_placement_rejected(library):
    with pytest.raises(ValueError):
        online.schedule_online(sparse_ts(n=2, library=library),
                               placement="warp")


# ---------------------------------------------------------------------------
# Shared no-DVFS config builder (the deduped (1,1,1) fallback).
# ---------------------------------------------------------------------------


def test_default_config_builders_are_one_implementation(library):
    """scheduling.default_config and machines.default_configs must both be
    the shared single_task.no_dvfs_config, bit-for-bit."""
    ts = tasks.generate_offline(0.05, seed=9, library=library)
    ref = scheduling.default_config(ts)
    via_classes = machines.default_configs(
        ts, machines.reference_classes())[0]
    direct = single_task.no_dvfs_config(ts.params,
                                        ts.deadline - ts.arrival)
    for a, b, c in zip(ref, via_classes, direct):
        if isinstance(a, np.ndarray):
            np.testing.assert_array_equal(a, b)
            np.testing.assert_array_equal(a, c)
        else:
            assert a == b == c


def test_no_dvfs_flags_consistent(library):
    ts = tasks.generate_offline(0.05, seed=4, library=library)
    cfg = single_task.no_dvfs_config(ts.params, ts.deadline - ts.arrival)
    np.testing.assert_array_equal(np.asarray(cfg.feasible),
                                  ~np.asarray(cfg.deadline_prior))
    np.testing.assert_array_equal(cfg.t_hat, cfg.t_min)
    assert cfg.n_deadline_prior == int(np.sum(cfg.deadline_prior))
