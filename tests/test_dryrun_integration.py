"""Integration tests for the dry-run harness and elastic restore, run in
subprocesses with forced host-device counts (so this pytest process keeps
its single default device)."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


@pytest.mark.slow
def test_dryrun_cell_on_mini_mesh():
    """The dry-run harness end to end (build/lower/compile/capture/correct)
    on a 4x4 mini-mesh with a small arch — the same code path the 512-device
    production run uses."""
    code = """
    import json
    import jax
    from repro import partition
    from repro.launch import dryrun as dr
    from repro.launch.mesh import _mesh

    mesh = _mesh((4, 4), ("data", "model"))
    fn, args, sh, don, rules, mb = dr.build_cell(
        "whisper-base", "train_4k", mesh, batch_rows=16, microbatches=1)
    with partition.use_rules(rules), mesh:
        comp = jax.jit(fn, in_shardings=sh,
                       donate_argnums=don or None).lower(*args).compile()
    cap = dr.capture(comp)
    assert cap["cost"]["flops"] > 0
    assert cap["collectives"]["n_collectives"] > 0
    assert cap["memory"]["live_bytes"] > 0
    print("MINI_MESH_OK", json.dumps(
        {"flops": cap["cost"]["flops"],
         "colls": cap["collectives"]["n_collectives"]}))
    """
    r = run_py(code, devices=16)
    assert "MINI_MESH_OK" in r.stdout, r.stderr[-3000:]


@pytest.mark.slow
def test_elastic_restore_across_topologies(tmp_path):
    """Save a TrainState on a (2,2) mesh, restore it onto a (4,1) mesh —
    the 'restart on a different pod count' path."""
    ckdir = str(tmp_path / "ck")
    save_code = f"""
    import jax
    from repro import partition
    from repro.checkpoint.store import CheckpointStore
    from repro.configs import get_config
    from repro.launch.mesh import _mesh
    from repro.models.model import Model
    from repro.optim.adamw import AdamW
    from repro.train.trainer import init_state
    mesh = _mesh((2, 2), ("data", "model"))
    cfg = get_config("h2o-danube-1.8b").reduced()
    model = Model(cfg)
    opt = AdamW()
    with partition.use_rules(partition.fsdp_rules(mesh, 8)), mesh:
        state = init_state(model, opt, jax.random.key(7))
    CheckpointStore({ckdir!r}).save(3, state, blocking=True)
    print("SAVED", float(jax.tree.leaves(state.params)[0].sum()))
    """
    r1 = run_py(save_code, devices=4)
    assert "SAVED" in r1.stdout, r1.stderr[-3000:]
    saved_sum = float(r1.stdout.split("SAVED")[1].strip())

    restore_code = f"""
    import jax
    from repro import partition
    from repro.checkpoint.store import CheckpointStore
    from repro.configs import get_config
    from repro.launch.mesh import _mesh
    from repro.models.model import Model
    from repro.optim.adamw import AdamW
    from repro.train.trainer import init_state, make_state_axes
    mesh = _mesh((4, 1), ("data", "model"))   # NEW topology
    cfg = get_config("h2o-danube-1.8b").reduced()
    model = Model(cfg)
    opt = AdamW()
    rules = partition.fsdp_rules(mesh, 8)
    with partition.use_rules(rules), mesh:
        like = init_state(model, opt, jax.random.key(0))
        sh = jax.tree.map(rules.sharding, make_state_axes(model.param_axes()),
                          is_leaf=partition.is_axes)
        state = CheckpointStore({ckdir!r}).restore(like, shardings=sh)
    leaf = jax.tree.leaves(state.params)[0]
    assert "data" in str(leaf.sharding.spec) or True
    print("RESTORED", float(leaf.sum()))
    """
    r2 = run_py(restore_code, devices=4)
    assert "RESTORED" in r2.stdout, r2.stderr[-3000:]
    restored_sum = float(r2.stdout.split("RESTORED")[1].strip())
    assert abs(saved_sum - restored_sum) < 1e-3 * max(abs(saved_sum), 1.0)
