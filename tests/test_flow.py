"""Units for the CFG + forward-dataflow framework behind the flow-sensitive
lint families (tools/lint/flow.py): CFG construction for every statement
shape the rules must traverse, reaching-definitions fixpoint convergence,
and the layout.py symbolic slice-bound resolver."""

import ast

from tools.lint.flow import (
    build_cfg, layout_env, reaching_definitions, resolve_col_expr,
    run_forward, statement_states, stmt_exprs,
)


def _cfg_of(src: str):
    fn = ast.parse(src).body[0]
    assert isinstance(fn, ast.FunctionDef)
    return build_cfg(fn)


def _stmt_lines(cfg):
    """block id -> line numbers of its statements (reachable blocks)."""
    return {b.id: [s.lineno for s in b.stmts]
            for b in cfg.blocks if b.id in cfg.reachable() and b.stmts}


# ---------------------------------------------------------------------------
# CFG construction
# ---------------------------------------------------------------------------

def test_linear_body_single_path():
    cfg = _cfg_of("def f():\n    a = 1\n    b = a\n    return b\n")
    # Entry flows through one statement-bearing chain into exit.
    assert cfg.exit in {s for b in cfg.blocks for s in b.succs}
    reach = cfg.reachable()
    assert cfg.entry in reach and cfg.exit in reach
    stmts = [s for b in cfg.blocks for s in b.stmts]
    assert len(stmts) == 3


def test_if_elif_else_all_paths_reach_exit():
    cfg = _cfg_of(
        "def f(x):\n"
        "    if x == 1:\n"
        "        a = 1\n"
        "    elif x == 2:\n"
        "        a = 2\n"
        "    else:\n"
        "        a = 3\n"
        "    return a\n")
    reach = cfg.reachable()
    assert cfg.exit in reach
    # All three assignment statements live in distinct reachable blocks.
    assign_blocks = {b.id for b in cfg.blocks
                     if any(isinstance(s, ast.Assign) for s in b.stmts)}
    assert len(assign_blocks) == 3
    assert assign_blocks <= reach


def test_while_loop_has_back_edge():
    cfg = _cfg_of(
        "def f(n):\n"
        "    i = 0\n"
        "    while i < n:\n"
        "        i = i + 1\n"
        "    return i\n")
    header = next(b.id for b in cfg.blocks
                  if any(isinstance(s, ast.While) for s in b.stmts))
    body = next(b.id for b in cfg.blocks
                if any(isinstance(s, ast.Assign) and s.lineno == 4
                       for s in b.stmts))
    assert header in cfg.blocks[body].succs      # back edge
    assert body in cfg.blocks[header].succs      # loop entry


def test_for_break_continue_edges():
    cfg = _cfg_of(
        "def f(xs):\n"
        "    for x in xs:\n"
        "        if x < 0:\n"
        "            break\n"
        "        if x == 0:\n"
        "            continue\n"
        "        y = x\n"
        "    return 1\n")
    header = next(b.id for b in cfg.blocks
                  if any(isinstance(s, ast.For) for s in b.stmts))
    brk = next(b.id for b in cfg.blocks
               if any(isinstance(s, ast.Break) for s in b.stmts))
    cnt = next(b.id for b in cfg.blocks
               if any(isinstance(s, ast.Continue) for s in b.stmts))
    # continue jumps to the loop header; break jumps past it (to the block
    # holding the return, directly or transitively).
    assert header in cfg.blocks[cnt].succs
    assert header not in cfg.blocks[brk].succs
    assert cfg.blocks[brk].succs  # lands on the after-loop path


def test_early_return_terminates_path():
    cfg = _cfg_of(
        "def f(x):\n"
        "    if x:\n"
        "        return 1\n"
        "    y = 2\n"
        "    return y\n")
    ret_block = next(b for b in cfg.blocks
                     if any(isinstance(s, ast.Return) and s.lineno == 3
                            for s in b.stmts))
    assert ret_block.succs == [cfg.exit]


def test_try_except_handler_reachable():
    cfg = _cfg_of(
        "def f(x):\n"
        "    try:\n"
        "        a = risky(x)\n"
        "    except ValueError:\n"
        "        a = 0\n"
        "    return a\n")
    reach = cfg.reachable()
    handler = next(b.id for b in cfg.blocks
                   if any(isinstance(s, ast.Assign) and s.lineno == 5
                          for s in b.stmts))
    assert handler in reach
    assert cfg.exit in reach


def test_nested_function_is_opaque():
    cfg = _cfg_of(
        "def f():\n"
        "    def g():\n"
        "        return 1\n"
        "    return g\n")
    # The nested def is one opaque statement; its body contributes no
    # blocks and no owned expressions.
    defs = [s for b in cfg.blocks for s in b.stmts
            if isinstance(s, ast.FunctionDef)]
    assert len(defs) == 1
    assert stmt_exprs(defs[0]) == []


# ---------------------------------------------------------------------------
# Fixpoint / reaching definitions
# ---------------------------------------------------------------------------

def test_reaching_definitions_diamond_merges_both_arms():
    cfg = _cfg_of(
        "def f(c):\n"
        "    if c:\n"
        "        x = 1\n"
        "    else:\n"
        "        x = 2\n"
        "    return x\n")
    entry = reaching_definitions(cfg)
    ret_bid = next(b.id for b in cfg.blocks
                   if any(isinstance(s, ast.Return) for s in b.stmts))
    xdefs = {line for name, line in entry[ret_bid] if name == "x"}
    assert xdefs == {3, 5}


def test_reaching_definitions_redefinition_kills():
    cfg = _cfg_of(
        "def f():\n"
        "    x = 1\n"
        "    x = 2\n"
        "    return x\n")
    states = {}
    for state, stmt in statement_states(
            cfg, {cfg.entry: frozenset()},
            lambda s, st: s):  # identity transfer just to walk
        states[stmt.lineno] = state
    entry = reaching_definitions(cfg)
    # At exit, only the later definition survives.
    exit_preds = cfg.preds(cfg.exit)
    assert exit_preds
    # Walk the defining block manually: the kill happens inside one block,
    # so check the function-level result via a loop-carried variant below.
    cfg2 = _cfg_of(
        "def f(n):\n"
        "    x = 1\n"
        "    while n:\n"
        "        x = 2\n"
        "    return x\n")
    entry2 = reaching_definitions(cfg2)
    ret_bid = next(b.id for b in cfg2.blocks
                   if any(isinstance(s, ast.Return) for s in b.stmts))
    xdefs = {line for name, line in entry2[ret_bid] if name == "x"}
    assert xdefs == {2, 4}  # zero-iteration path keeps line 2 alive


def test_fixpoint_converges_on_nested_loops():
    cfg = _cfg_of(
        "def f(n):\n"
        "    s = 0\n"
        "    for i in range(n):\n"
        "        for j in range(i):\n"
        "            s = s + j\n"
        "    return s\n")
    entry = reaching_definitions(cfg)  # must terminate
    ret_bid = next(b.id for b in cfg.blocks
                   if any(isinstance(s, ast.Return) for s in b.stmts))
    sdefs = {line for name, line in entry[ret_bid] if name == "s"}
    assert sdefs == {2, 5}


def test_run_forward_must_join_loop():
    # A must-analysis (all-paths) over a loop converges and the
    # conditional arm does not leak into the join.
    src = ("def f(c):\n"
           "    mark()\n"
           "    if c:\n"
           "        clear()\n"
           "    tail()\n")
    cfg = _cfg_of(src)

    def transfer(state, stmt):
        calls = [n.func.id for e in stmt_exprs(stmt)
                 for n in ast.walk(e)
                 if isinstance(n, ast.Call) and isinstance(n.func, ast.Name)]
        if "mark" in calls:
            return True
        if "clear" in calls:
            return False
        return state

    entry = run_forward(cfg, False, transfer, lambda xs: all(xs))
    tail_state = None
    for state, stmt in statement_states(cfg, entry, transfer):
        if isinstance(stmt, ast.Expr) and stmt.lineno == 5:
            tail_state = state
    assert tail_state is False  # cleared on one path => not "must" marked


# ---------------------------------------------------------------------------
# layout.py slice-bound resolution
# ---------------------------------------------------------------------------

def test_layout_env_exposes_schema_constants():
    env = layout_env()
    assert env["NCOL"] == 16 and env["KEY_COLS"] == 13
    assert env["PARAMS_SLICE"] == slice(0, env["N_PARAMS"])


def _span(src: str, width=None):
    expr = ast.parse(src, mode="eval").body
    return resolve_col_expr(expr, layout_env(), width)


def test_resolve_col_expr_forms():
    env = layout_env()
    assert _span("3") == (3, 4)
    assert _span("ALLOWED") == (env["ALLOWED"], env["ALLOWED"] + 1)
    assert _span("layout.READJUST") == (env["READJUST"],
                                        env["READJUST"] + 1)
    assert _span("col(T0)") == (env["T0"], env["T0"] + 1)
    assert _span("PARAMS_SLICE") == (0, env["N_PARAMS"])
    assert _span("NCOL - KEY_COLS") == (3, 4)
    assert _span("unknown_name") is None
    # Slices resolve through names; open ends use 0 / the given width.
    sl = ast.parse("x[V_MIN:FM_MAX]", mode="eval").body.slice
    assert resolve_col_expr(sl, env) == (env["V_MIN"], env["FM_MAX"])
    sl_open = ast.parse("x[:KEY_COLS]", mode="eval").body.slice
    assert resolve_col_expr(sl_open, env, 16) == (0, env["KEY_COLS"])
