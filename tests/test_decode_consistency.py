"""Prefill + decode must reproduce the full-forward logits for every
architecture family, including ring-buffer (sliding-window) wraparound."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models.layers import COMPUTE_DTYPE
from repro.models.model import Model


def setup(arch, S):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params, _ = model.init(jax.random.key(0))
    B = 2
    tok = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok}
    if cfg.family == "vlm":
        batch["patch_embeds"] = 0.1 * jnp.ones(
            (B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = 0.1 * jnp.ones((B, cfg.n_frames, cfg.d_model),
                                         jnp.bfloat16)
    return cfg, model, params, tok, batch


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    S, S0 = 24, 16
    cfg, model, params, tok, batch = setup(arch, S)
    x, _ = model.forward(params, dict(batch, labels=tok), remat=False)
    head = model.head_matrix(params)
    full = model._mask_pad_logits(
        (x @ head.astype(COMPUTE_DTYPE)).astype(jnp.float32))

    pb = dict(batch)
    pb["tokens"] = tok[:, :S0]
    logits, cache = model.prefill(params, pb, max_seq=32)
    errs = [float(jnp.max(jnp.abs(logits - full[:, S0 - 1])))]
    for t in range(S0, S):
        logits, cache = model.decode_step(params, cache, tok[:, t],
                                          jnp.asarray(t))
        errs.append(float(jnp.max(jnp.abs(logits - full[:, t]))))
    tol = 0.15 if cfg.n_experts else 0.05  # MoE: capacity-routing jitter
    assert max(errs) < tol, (arch, errs)


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "recurrentgemma-2b"])
def test_ring_buffer_wraparound(arch):
    """Decode far past the sliding window: the ring cache must keep exactly
    the last W tokens' keys (greedy continuations stay finite + stable)."""
    S0 = 8
    cfg, model, params, tok, batch = setup(arch, S0)
    W = cfg.sliding_window or cfg.local_window  # reduced: 32
    pb = dict(batch)
    logits, cache = model.prefill(params, pb, max_seq=W)
    steps = W + 12   # wrap well past the ring
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    for t in range(S0, S0 + steps):
        logits, cache = model.decode_step(params, cache, cur,
                                          jnp.asarray(t))
        assert bool(jnp.all(jnp.isfinite(logits)))
        cur = jnp.argmax(logits, -1).astype(jnp.int32)


def test_swa_ring_matches_dense_window():
    """Sliding-window decode against the blockwise oracle: build a sequence
    longer than the window and compare decode logits computed through the
    ring cache vs a full forward pass with the same window mask."""
    arch = "h2o-danube-1.8b"
    S = 48  # window is 32 in the reduced config
    cfg, model, params, tok, batch = setup(arch, S)
    x, _ = model.forward(params, dict(batch, labels=tok), remat=False)
    head = model.head_matrix(params)
    full = model._mask_pad_logits(
        (x @ head.astype(COMPUTE_DTYPE)).astype(jnp.float32))

    W = cfg.sliding_window
    pb = dict(batch)
    pb["tokens"] = tok[:, :W]
    logits, cache = model.prefill(params, pb, max_seq=W)
    errs = [float(jnp.max(jnp.abs(logits - full[:, W - 1])))]
    for t in range(W, S):
        logits, cache = model.decode_step(params, cache, tok[:, t],
                                          jnp.asarray(t))
        errs.append(float(jnp.max(jnp.abs(logits - full[:, t]))))
    assert max(errs) < 0.05, errs
