"""Heterogeneous machine classes: degenerate-case goldens, kernel parity
for the class-extended task matrix, and class-aware scheduling behavior."""

import numpy as np
import pytest

from repro.core import dvfs, machines, online, scheduling, tasks
from repro.core.engine import ClusterEngine
from repro.core.machines import MachineClass
from repro.kernels import ops, ref

from tests.test_engine import OFFLINE_GOLDEN, ONLINE_GOLDEN


@pytest.fixture(scope="module")
def library():
    return tasks.app_library()


# ---------------------------------------------------------------------------
# Degenerate case: one reference class == the homogeneous code path.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("alg", sorted(OFFLINE_GOLDEN))
def test_single_class_offline_matches_goldens(alg, library):
    """A one-reference-class heterogeneous run reproduces the seed goldens
    (1e-9 rel on e_total) and is bit-for-bit the homogeneous path."""
    ts = tasks.generate_offline(0.1, seed=3, library=library)
    r_homo = scheduling.schedule_offline(ts, l=2, theta=0.9, algorithm=alg)
    r_het = scheduling.schedule_offline(ts, l=2, theta=0.9, algorithm=alg,
                                        classes=("gtx-1080ti",))
    assert r_het.e_total == r_homo.e_total          # bit-for-bit
    assert r_het.e_idle == r_homo.e_idle
    assert r_het.n_pairs == r_homo.n_pairs
    assert r_het.n_servers == r_homo.n_servers
    assert r_het.violations == r_homo.violations
    e_total, e_idle, n_pairs, n_servers, violations = OFFLINE_GOLDEN[alg]
    assert r_het.e_total == pytest.approx(e_total, rel=1e-9)
    assert r_het.e_idle == pytest.approx(e_idle, rel=1e-6)
    assert (r_het.n_pairs, r_het.n_servers, r_het.violations) == \
        (n_pairs, n_servers, violations)


@pytest.mark.parametrize("alg,l,theta", sorted(ONLINE_GOLDEN))
def test_single_class_online_matches_goldens(alg, l, theta, library):
    ts = tasks.generate_online(offline_util=0.02, online_util=0.05, seed=1,
                               horizon=200, library=library)
    r_homo = online.schedule_online(ts, l=l, theta=theta, algorithm=alg)
    r_het = online.schedule_online(ts, l=l, theta=theta, algorithm=alg,
                                   classes=("gtx-1080ti",))
    assert r_het.e_total == r_homo.e_total          # bit-for-bit
    assert r_het.e_overhead == r_homo.e_overhead
    assert r_het.n_pairs == r_homo.n_pairs
    e_total, e_overhead, n_pairs, n_servers, violations = \
        ONLINE_GOLDEN[(alg, l, theta)]
    assert r_het.e_total == pytest.approx(e_total, rel=1e-9)
    assert r_het.e_overhead == pytest.approx(e_overhead, rel=1e-6)
    assert (r_het.n_pairs, r_het.n_servers, r_het.violations) == \
        (n_pairs, n_servers, violations)


# ---------------------------------------------------------------------------
# Class-extended kernel task matrix vs the oracle.
# ---------------------------------------------------------------------------


def _class_matrix(ts, mcs, interval=dvfs.WIDE, readjust=False):
    """Build the stacked [C*n, 16] matrix the widened kernel consumes."""
    n = len(ts)
    allowed = np.asarray(ts.deadline - ts.arrival, np.float32)
    blocks = []
    for mc in mcs:
        a = mc.adapt(ts.params)
        iv = mc.effective_interval(interval)
        cols = [np.asarray(f, np.float32) for f in a.astuple()]
        flag = np.full(n, 1.0 if readjust else 0.0, np.float32)
        m = np.stack(cols + [allowed, flag], axis=1)
        b = np.broadcast_to(np.asarray(iv.bounds(), np.float32), (n, 5))
        blocks.append(np.concatenate([m, b, np.zeros((n, 3), np.float32)],
                                     axis=1))
    return np.concatenate(blocks, axis=0)


def test_kernel_oracle_parity_class_matrix(library):
    """One widened pallas_call over a class-stacked matrix (three different
    scaling boxes) matches the per-interval production solver."""
    from repro.kernels.dvfs_opt import dvfs_solve_kernel
    import jax.numpy as jnp

    ts = tasks.generate_offline(0.05, seed=17, library=library)
    mcs = machines.get_classes(("gtx-1080ti", "tpu-v5e", "v100-sxm2"))
    mat = _class_matrix(ts, mcs)
    out = np.asarray(dvfs_solve_kernel(jnp.asarray(mat), interpret=True))
    exp = ref.dvfs_solve_ref(mat)
    rel = np.abs(out[:, 5] - exp[:, 5]) / np.maximum(exp[:, 5], 1e-9)
    assert float(np.max(rel)) < 1e-2
    assert float(np.mean((out[:, 6] > .5) == (exp[:, 6] > .5))) > 0.97
    # solutions stay inside each class's own box
    n = len(ts)
    for c, mc in enumerate(mcs):
        iv = mc.effective_interval(dvfs.WIDE)
        sl = slice(c * n, (c + 1) * n)
        assert np.all(out[sl, 2] >= iv.fm_min - 1e-5)
        assert np.all(out[sl, 2] <= iv.fm_max + 1e-5)
        assert np.all(out[sl, 1] <= iv.fc_max + 1e-4)


def test_legacy_8col_matrix_still_supported(library):
    """The homogeneous [n, 8] layout is widened from the static interval."""
    ts = tasks.generate_offline(0.05, seed=9, library=library)
    allowed = ts.deadline - ts.arrival
    sol8 = ops.dvfs_solve(ts.params, allowed, interval=dvfs.NARROW)
    rows = np.broadcast_to(np.asarray(dvfs.NARROW.bounds(), np.float64),
                           (len(ts), 5))
    sol16 = ops.dvfs_solve(ts.params, allowed, interval_rows=rows)
    np.testing.assert_allclose(sol8.energy, sol16.energy, rtol=1e-6)


def test_configure_classes_kernel_matches_jnp(library):
    ts = tasks.generate_offline(0.05, seed=23, library=library)
    mcs = machines.get_classes(("gtx-1080ti", "tpu-v5e"))
    allowed = ts.deadline - ts.arrival
    cfg_j = machines.configure_classes(ts.params, allowed, mcs, dvfs.WIDE)
    cfg_k = machines.configure_classes(ts.params, allowed, mcs, dvfs.WIDE,
                                       use_kernel=True)
    for j, k in zip(cfg_j, cfg_k):
        ok = np.asarray(j.feasible) & np.asarray(k.feasible)
        rel = np.abs(k.e_hat[ok] - j.e_hat[ok]) / np.maximum(j.e_hat[ok], 1e-9)
        assert float(np.max(rel)) < 1e-2
        assert float(np.mean(j.deadline_prior == k.deadline_prior)) > 0.97


# ---------------------------------------------------------------------------
# Class-aware scheduling behavior.
# ---------------------------------------------------------------------------


def test_scheduler_prefers_min_energy_class(library):
    """An identical-but-half-power class should host every task."""
    cheap = MachineClass("half-power", power_scale=0.5)
    ts = tasks.generate_offline(0.05, seed=3, library=library)
    r = scheduling.schedule_offline(ts, l=2, theta=0.9, algorithm="edl",
                                    classes=(machines.GTX_1080TI, cheap))
    assert r.violations == 0
    assert all(a.class_id == 1 for a in r.assignments)
    r_ref = scheduling.schedule_offline(ts, l=2, theta=0.9, algorithm="edl")
    assert r.e_total < r_ref.e_total


def test_heterogeneous_online_decomposition_and_overheads(library):
    """Per-class Δ accounting: total overhead is a nonneg combination of the
    class Δs, and the energy identity holds."""
    ts = tasks.generate_online(0.02, 0.05, seed=5, horizon=200,
                               library=library)
    mcs = machines.get_classes(("gtx-1080ti", "v100-sxm2"))
    r = online.schedule_online(ts, l=2, theta=0.9, algorithm="edl",
                               classes=mcs)
    assert r.violations == 0
    assert r.e_total == pytest.approx(r.e_run + r.e_idle + r.e_overhead)
    assert r.e_run == pytest.approx(sum(a.energy for a in r.assignments))
    # overhead decomposes into integer pair turn-ons per class Δ
    d0, d1 = mcs[0].delta_on, mcs[1].delta_on
    found = any(
        abs(r.e_overhead - (d0 * i + d1 * round((r.e_overhead - d0 * i) / d1)))
        < 1e-6 and round((r.e_overhead - d0 * i) / d1) >= 0
        for i in range(2000))
    assert found, r.e_overhead


def test_all_algorithms_run_heterogeneous(library):
    ts = tasks.generate_offline(0.04, seed=2, library=library)
    for alg in ("edl", "edf-wf", "edf-bf", "lpt-ff"):
        r = scheduling.schedule_offline(ts, l=2, theta=0.9, algorithm=alg,
                                        classes=("gtx-1080ti", "tpu-v5e"))
        assert r.violations == 0, alg
        assert len(r.assignments) == len(ts)
    ts2 = tasks.generate_online(0.02, 0.04, seed=3, horizon=120,
                                library=library)
    for alg in ("edl", "bin"):
        r = online.schedule_online(ts2, l=2, theta=0.9, algorithm=alg,
                                   classes=("gtx-1080ti", "tpu-v5e"))
        assert r.violations == 0, alg
        assert len(r.assignments) == len(ts2)


def test_adapt_identity_and_transforms(library):
    ref_cls = machines.GTX_1080TI
    assert ref_cls.is_reference
    a = ref_cls.adapt(library)
    for x, y in zip(a.astuple(), library.astuple()):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    fast = MachineClass("fast", speed=2.0)
    f = fast.adapt(library)
    np.testing.assert_allclose(np.asarray(f.big_d),
                               np.asarray(library.big_d) / 2.0)
    np.testing.assert_allclose(np.asarray(f.default_time()),
                               np.asarray(library.default_time()) / 2.0)
    tpu = machines.TPU_V5E
    t = tpu.adapt(library)
    p_star = np.asarray(library.default_power())  # f32 jnp eval
    np.testing.assert_allclose(np.asarray(t.p0),
                               p_star * tpu.power_scale * tpu.p0_frac,
                               rtol=1e-5)
    # power split sums back to the scaled envelope (f32 jnp eval)
    np.testing.assert_allclose(np.asarray(t.default_power()),
                               p_star * tpu.power_scale, rtol=1e-5)


def test_engine_class_selectors_and_acquire():
    mcs = machines.get_classes(("gtx-1080ti", "tpu-v5e"))
    eng = ClusterEngine(l=2, classes=mcs)
    eng.new_server(0.0, class_id=0)
    eng.new_server(0.0, class_id=1)
    assert eng.worst_fit(class_id=1) == 2     # first pair of the class-1 server
    eng.assign(2, 0.0, 5.0)
    assert eng.worst_fit(class_id=1) == 3
    assert eng.worst_fit(class_id=0) == 0
    # DRS powers both off; acquire wakes the server of the requested class
    eng.drs_sweep(10.0)
    assert eng.n_on_servers() == 0
    pid = eng.acquire_pair(10.0, class_id=1)
    assert pid == 2 and eng.n_servers == 2
    np.testing.assert_array_equal(eng.pair_class, [0, 0, 1, 1])


def test_engine_offline_finalize_groups_per_class():
    """Virtual servers never mix classes: idle energy is the per-class sum."""
    from repro.core import cluster as cl
    mcs = (MachineClass("a", p_idle=10.0), MachineClass("b", p_idle=100.0))
    eng = ClusterEngine(l=2, servers=False, classes=mcs)
    for mu, cid in ((5.0, 0), (3.0, 0), (8.0, 1)):
        pid = eng.open_pair(class_id=cid)
        eng.assign(pid, 0.0, mu)
    e_idle, e_over, n_srv = eng.finalize()
    exp_a, n_a = cl.offline_idle_energy(np.asarray([5.0, 3.0]), 2,
                                        p_idle=10.0)
    exp_b, n_b = cl.offline_idle_energy(np.asarray([8.0]), 2, p_idle=100.0)
    assert e_idle == pytest.approx(exp_a + exp_b)
    assert n_srv == n_a + n_b
    assert e_over == 0.0


def test_registry_lookup_and_errors():
    assert machines.get_classes(("gtx-1080ti",))[0] is machines.GTX_1080TI
    with pytest.raises(KeyError):
        machines.get_classes(("no-such-class",))
    with pytest.raises(ValueError):
        machines.get_classes(())
    with pytest.raises(ValueError):
        scheduling.schedule_offline(
            tasks.generate_offline(0.01, seed=0), algorithm="edl",
            cfg=scheduling.default_config(tasks.generate_offline(0.01, seed=0)),
            classes=("gtx-1080ti", "tpu-v5e"))
