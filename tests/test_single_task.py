"""Single-task DVFS optimization (paper §4.1, Algorithm 1)."""

import numpy as np
import pytest

from repro.core import dvfs, single_task, tasks
from repro.core.dvfs import DvfsParams, WIDE, NARROW


def batched(p: DvfsParams) -> DvfsParams:
    return DvfsParams(*(np.asarray([f], np.float64) for f in p.astuple()))


@pytest.mark.parametrize("i", [0, 3, 7, 12, 19])
def test_unconstrained_matches_brute_force(i):
    lib = tasks.app_library()
    p = lib[i]
    sol = single_task.solve_unconstrained(batched(p))
    bf_e, _ = single_task.brute_force_optimum(p, n=200)
    assert float(np.asarray(sol.energy)[0]) == pytest.approx(bf_e, rel=2e-3)


@pytest.mark.parametrize("frac", [0.9, 0.95, 0.99])
def test_deadline_constrained_matches_brute_force(frac):
    lib = tasks.app_library()
    p = lib[5]
    tmin = float(dvfs.min_time(p, WIDE))
    tstar = float(p.default_time())
    allowed = tmin + frac * 0.3 * (tstar - tmin)
    sol = single_task.solve_with_deadline(batched(p), np.asarray([allowed]))
    bf_e, _ = single_task.brute_force_optimum(p, allowed=allowed, n=220)
    assert float(np.asarray(sol.energy)[0]) == pytest.approx(bf_e, rel=6e-3)
    assert float(np.asarray(sol.time)[0]) <= allowed + 1e-5


def test_deadline_infeasible_runs_max_speed():
    lib = tasks.app_library()
    p = lib[2]
    tmin = float(dvfs.min_time(p, WIDE))
    sol = single_task.solve_with_deadline(batched(p),
                                          np.asarray([0.5 * tmin]))
    assert not bool(np.asarray(sol.feasible)[0])
    assert float(np.asarray(sol.fc)[0]) == pytest.approx(WIDE.fc_max, rel=1e-5)
    assert float(np.asarray(sol.fm)[0]) == pytest.approx(WIDE.fm_max, rel=1e-5)


def test_energy_prior_keeps_unconstrained_optimum():
    lib = tasks.app_library()
    p = lib[4]
    unc = single_task.solve_unconstrained(batched(p))
    loose = float(np.asarray(unc.time)[0]) * 2.0
    sol = single_task.solve_with_deadline(batched(p), np.asarray([loose]))
    assert not bool(np.asarray(sol.deadline_prior)[0])
    assert float(np.asarray(sol.energy)[0]) == pytest.approx(
        float(np.asarray(unc.energy)[0]), rel=1e-5)


def test_library_wide_saving_anchor():
    """Paper Fig. 4: mean single-task energy saving ~= 36.4% on the wide
    interval (the calibrated library anchor all scheduling numbers hang
    off)."""
    lib = tasks.app_library()
    sol = single_task.solve_unconstrained(lib)
    saving = 1.0 - np.asarray(sol.energy) / np.asarray(lib.default_energy())
    assert float(np.mean(saving)) == pytest.approx(0.364, abs=0.01)
    # narrow interval saves much less (paper §5.2 direction)
    soln = single_task.solve_unconstrained(lib, NARROW)
    saving_n = 1 - np.asarray(soln.energy) / np.asarray(lib.default_energy())
    assert float(np.mean(saving_n)) < float(np.mean(saving)) * 0.7


def test_configure_tasks_algorithm1():
    ts = tasks.generate_offline(0.05, seed=7)
    cfg = single_task.configure_tasks(ts.params, ts.deadline - ts.arrival)
    assert cfg.n_deadline_prior == int(np.sum(cfg.deadline_prior))
    # deadline-prior tasks sit exactly on their deadline window
    dp = cfg.deadline_prior & cfg.feasible
    win = (ts.deadline - ts.arrival)[dp]
    np.testing.assert_allclose(cfg.t_hat[dp], win, rtol=1e-5)
    # energy-prior tasks fit within their window
    ep = ~cfg.deadline_prior
    assert np.all(cfg.t_hat[ep] <= (ts.deadline - ts.arrival)[ep] + 1e-6)
    # DVFS never increases energy vs default for feasible tasks
    e_def = np.asarray(ts.params.default_energy())
    assert np.all(cfg.e_hat[cfg.feasible] <= e_def[cfg.feasible] * 1.0001)


def test_readjustment_hits_window():
    lib = tasks.app_library()
    p = lib[8]
    tstar = float(p.default_time())
    window = 0.95 * tstar
    v, fc, fm, t, pw, e = single_task.readjust(p, window)
    assert t <= window + 1e-6
    # readjusted energy >= unconstrained optimum (giving up optimality)
    unc = single_task.solve_unconstrained(batched(p))
    assert e >= float(np.asarray(unc.energy)[0]) - 1e-3
