import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Initialize the jax backend BEFORE any test module import: importing
# repro.launch.dryrun sets XLA_FLAGS=--xla_force_host_platform_device_count
# =512 (by design — its first two lines), which must not leak into the
# test process's backend.  Backend flags are read exactly once, here.
import jax  # noqa: E402

jax.devices()

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(autouse=True)
def _seed_global_rngs():
    """Pin every global RNG before each test, so legacy ``np.random.*``
    calls anywhere down the stack draw the same stream regardless of test
    order or selection.  (JAX has no global RNG — ``jax.random`` takes
    explicit keys, which tests construct from literal seeds.)"""
    np.random.seed(0)
    yield


@pytest.fixture
def rng() -> np.random.Generator:
    """The per-test random source.  Tests take this fixture instead of
    constructing ad-hoc ``np.random.default_rng(...)`` inline, so all
    random test inputs are seeded in exactly one place."""
    return np.random.default_rng(0)
