import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Initialize the jax backend BEFORE any test module import: importing
# repro.launch.dryrun sets XLA_FLAGS=--xla_force_host_platform_device_count
# =512 (by design — its first two lines), which must not leak into the
# test process's backend.  Backend flags are read exactly once, here.
import jax  # noqa: E402

jax.devices()
