"""End-to-end behaviour: the paper's full pipeline (Algorithm 1 -> EDL ->
server grouping) reproduces the headline numbers, and the LM framework
trains/serves through the same public API the examples use."""

import numpy as np

from repro.core import cluster as cl, online, scheduling, tasks


def test_offline_pipeline_headline_savings():
    """Offline l=1: DVFS EDL saves ~33.5% vs the no-DVFS baseline while the
    theoretical per-task bound is ~36.4% (paper §5.3.2) — the scheduler
    must land between deadline losses and the bound."""
    lib = tasks.app_library()
    ts = tasks.generate_offline(0.4, seed=0, library=lib)
    base = cl.baseline_energy(ts)
    r = scheduling.schedule_offline(ts, l=1, algorithm="edl", use_dvfs=True)
    saving = 1 - r.e_total / base
    assert r.violations == 0
    assert 0.29 <= saving <= 0.365


def test_online_pipeline_headline_savings():
    """Online: runtime-energy saving ~34.7% (paper §5.4.2 direction) and the
    total saving stays within a few points of it at l=1."""
    ts = tasks.generate_online(offline_util=0.05, online_util=0.1, seed=0,
                               horizon=400)
    r_d = online.schedule_online(ts, l=1, theta=0.9, algorithm="edl",
                                 use_dvfs=True)
    r_n = online.schedule_online(ts, l=1, theta=1.0, algorithm="edl",
                                 use_dvfs=False)
    assert r_d.violations == 0
    run_saving = 1 - r_d.e_run / r_n.e_run
    assert 0.28 <= run_saving <= 0.40
    tot_saving = 1 - r_d.e_total / r_n.e_total
    assert tot_saving > 0.25


def test_end_to_end_train_and_serve_api():
    """The examples' public path: launch.train + launch.serve round trip."""
    from repro.launch.train import main as train_main
    from repro.launch.serve import main as serve_main
    out = train_main(["--arch", "recurrentgemma-2b", "--preset", "smoke",
                      "--steps", "6", "--batch", "2", "--seq", "48"])
    assert out["final_step"] == 6
    assert np.isfinite(out["losses"]).all()
    stats = serve_main(["--arch", "recurrentgemma-2b", "--preset", "smoke",
                        "--requests", "2", "--prompt-len", "8",
                        "--gen", "4"])
    assert stats["new_tokens"] == 8
