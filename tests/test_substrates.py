"""Data pipeline, checkpointing, fault-tolerant loop, optimizer, and
gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.data.pipeline import SyntheticLMData
from repro.optim.adamw import AdamW, cosine_schedule
from repro.optim.compression import compress_int8, decompress_int8
from repro.train.loop import LoopConfig, run_loop


# -- data ---------------------------------------------------------------------


def test_data_deterministic_across_restarts():
    d = SyntheticLMData(vocab_size=100, seq_len=16, global_batch=8, seed=3)
    b1 = d.batch(step=5)
    b2 = SyntheticLMData(vocab_size=100, seq_len=16, global_batch=8,
                         seed=3).batch(step=5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # different steps differ
    assert not np.array_equal(b1["tokens"], d.batch(step=6)["tokens"])


def test_data_sharding_consistency():
    """Concatenated per-shard batches == the global batch (multi-host
    correctness)."""
    d = SyntheticLMData(vocab_size=100, seq_len=16, global_batch=8, seed=1)
    full = d.batch(step=2)
    parts = [d.batch(step=2, shard=s, n_shards=4) for s in range(4)]
    np.testing.assert_array_equal(
        full["tokens"], np.concatenate([p["tokens"] for p in parts]))


def test_data_labels_are_shifted_tokens():
    d = SyntheticLMData(vocab_size=50, seq_len=12, global_batch=2, seed=0)
    b = d.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# -- optimizer ------------------------------------------------------------------


def test_adamw_descends_quadratic():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.5


def test_adamw_clipping_and_schedule():
    sched = cosine_schedule(1.0, warmup=10, total=100)
    assert float(sched(0)) == pytest.approx(0.0)
    assert float(sched(10)) == pytest.approx(1.0, rel=1e-2)
    assert float(sched(100)) == pytest.approx(0.1, rel=1e-2)
    opt = AdamW(learning_rate=1e-2, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    huge = {"w": jnp.full(3, 1e6)}
    new, state, m = opt.update(huge, state, params)
    assert float(m["grad_norm"]) > 1e5
    assert float(jnp.max(jnp.abs(new["w"]))) < 1.0  # clipped step


def test_int8_compression_roundtrip_error_bounded(rng):
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, s = compress_int8(x)
    assert q.dtype == jnp.int8
    y = decompress_int8(q, s)
    assert float(jnp.max(jnp.abs(x - y))) <= float(s) * 0.5 + 1e-7


# -- checkpointing ---------------------------------------------------------------


def tree_example():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32),
                  "d": (jnp.zeros(4), jnp.ones((2, 2)))}}


def test_checkpoint_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    tree = tree_example()
    store.save(3, tree, blocking=True)
    out = store.restore(jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    tree = tree_example()
    for s in (1, 5, 9):
        store.save(s, tree, blocking=True)
    assert store.steps() == [5, 9]
    assert store.latest_step() == 9


def test_checkpoint_atomicity(tmp_path):
    """A stale .tmp dir (crashed writer) must not be visible as a
    checkpoint."""
    store = CheckpointStore(str(tmp_path), keep=3)
    os.makedirs(os.path.join(str(tmp_path), "step_000777.tmp"))
    assert store.latest_step() is None
    store.save(1, tree_example(), blocking=True)
    assert store.latest_step() == 1


def test_checkpoint_elastic_restore_new_sharding(tmp_path):
    """Restore with explicit (new-mesh) shardings — the elastic path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    store = CheckpointStore(str(tmp_path))
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    store.save(0, tree, blocking=True)
    mesh = make_host_mesh(1, 1)
    sh = {"w": NamedSharding(mesh, P())}
    out = store.restore(tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    assert out["w"].sharding == sh["w"]


# -- fault-tolerant loop ----------------------------------------------------------


class ToyData:
    def batch(self, step):
        return {"x": jnp.asarray([float(step)])}


def toy_step(state, batch):
    new = state + batch["x"][0]
    return new, {"loss": new}


def test_loop_checkpoint_restart(tmp_path):
    ckdir = str(tmp_path / "ck")
    cfg = LoopConfig(total_steps=10, checkpoint_every=3, checkpoint_dir=ckdir,
                     log_every=0)
    out = run_loop(toy_step, jnp.asarray(0.0), ToyData(), cfg,
                   log=lambda *_: None)
    assert out["final_step"] == 10
    # a fresh loop restores and does nothing more
    out2 = run_loop(toy_step, jnp.asarray(0.0), ToyData(),
                    LoopConfig(total_steps=10, checkpoint_dir=ckdir,
                               log_every=0), log=lambda *_: None)
    assert float(out2["state"]) == float(out["state"])


def test_loop_failure_recovery(tmp_path):
    """A simulated node failure mid-run: the loop restores the latest
    checkpoint and converges to the same final state."""
    ckdir = str(tmp_path / "ck")
    fail_at = {"armed": True}

    def failure_hook(step):
        if step == 7 and fail_at["armed"]:
            fail_at["armed"] = False
            raise RuntimeError("simulated device loss")

    cfg = LoopConfig(total_steps=10, checkpoint_every=2, checkpoint_dir=ckdir,
                     log_every=0)
    out = run_loop(toy_step, jnp.asarray(0.0), ToyData(), cfg,
                   failure_hook=failure_hook, log=lambda *_: None)
    assert out["recoveries"] == 1
    assert out["final_step"] == 10
    # deterministic data + restore-from-step => exact same sum 0..9
    assert float(out["state"]) == pytest.approx(sum(range(10)))


def test_loop_straggler_watchdog():
    import time as _t

    class SlowData(ToyData):
        pass

    def slow_step(state, batch):
        if int(batch["x"][0]) == 8:
            _t.sleep(0.35)
        else:
            _t.sleep(0.01)
        return state + 1, {"loss": state}

    out = run_loop(slow_step, jnp.asarray(0.0), SlowData(),
                   LoopConfig(total_steps=10, log_every=0),
                   log=lambda *_: None)
    assert out["stragglers"] >= 1
