"""Partitioning rules, collective parsing, and dry-run unit logic (the
512-device compiles themselves run via launch/dryrun.py, not pytest)."""

import jax
import jax.numpy as jnp
import pytest

from repro import partition
from repro.configs import registry
from repro.launch import dryrun


# -- partition -------------------------------------------------------------------


def test_is_axes_leaf_predicate():
    assert partition.is_axes(("embed", "vocab"))
    assert partition.is_axes((None, "model"))
    assert partition.is_axes(())
    assert not partition.is_axes(({"a": 1},))
    from repro.train.trainer import TrainState
    assert not partition.is_axes(TrainState(params=1, opt=2, step=3))


def test_batch_axes_divisibility():
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    # with a (1,1) mesh everything divides
    assert partition.batch_axes_for(mesh, 8) == "data"

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    assert partition.batch_axes_for(FakeMesh(), 256) == ("pod", "data")
    assert partition.batch_axes_for(FakeMesh(), 16) == "pod"  # 16 % 32 != 0
    assert partition.batch_axes_for(FakeMesh(), 1) is None


def test_constrain_noop_without_rules():
    x = jnp.zeros((2, 3))
    y = partition.constrain(x, ("batch", None))
    assert y is x


def test_rules_spec_lookup():
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    rules = partition.fsdp_rules(mesh, 8)
    spec = rules.spec(("embed", "ff"))
    assert spec == jax.sharding.PartitionSpec("data", "model")
    assert rules.spec(()) == jax.sharding.PartitionSpec()


# -- registry / cells --------------------------------------------------------------


def test_cell_enumeration_counts():
    cells = registry.list_cells()
    # 10 archs x 4 shapes - 7 long_500k skips = 33
    assert len(cells) == 33
    skipped = [(a, s) for a in registry.ARCHS for s in registry.SHAPES
               if registry.cell_skip_reason(a, s)]
    assert len(skipped) == 7
    for a, s in skipped:
        assert s == "long_500k"


def test_input_specs_shapes():
    s = registry.input_specs("qwen2-72b", "train_4k")
    assert s["tokens"].shape == (256, 4096)
    s = registry.input_specs("internvl2-2b", "prefill_32k")
    assert s["patch_embeds"].shape == (32, 256, 2048)
    s = registry.input_specs("whisper-base", "train_4k")
    assert s["frames"].shape == (256, 1500, 512)
    s = registry.input_specs("mamba2-370m", "decode_32k")
    assert s["token"].shape == (128,)


def test_padded_vocab():
    assert registry.get_config("whisper-base").padded_vocab % 256 == 0
    assert registry.get_config("qwen2-72b").padded_vocab == 152064  # exact


# -- collective parsing -------------------------------------------------------------


HLO_SAMPLE = """
  %ar = f32[1024,512]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256], to_apply=%sum
  %ag = bf16[64,4096]{1,0} all-gather(%y), replica_groups={{0,1,2,3}}, dimensions={0}
  %rs = f32[8,128]{1,0} reduce-scatter(%z), replica_groups=[2,8]<=[16], dimensions={0}
  %cp = bf16[32,32]{1,0} collective-permute(%w), source_target_pairs={{0,1},{1,0}}
  %aa = f32[16,16]{1,0} all-to-all(%v), replica_groups=[4,4]<=[16]
  %done = f32[4,4]{1,0} add(%a, %b)
"""


def test_parse_collectives_ring_model():
    out = dryrun.parse_collectives(HLO_SAMPLE)
    assert out["n_collectives"] == 5
    per = out["per_op_operand_bytes"]
    assert per["all-reduce"] == 1024 * 512 * 4
    assert per["all-gather"] == 64 * 4096 * 2 / 4        # operand = shard
    assert per["reduce-scatter"] == 8 * 128 * 4 * 8      # operand = full
    assert per["collective-permute"] == 32 * 32 * 2
    assert per["all-to-all"] == 16 * 16 * 4
    # ring wire bytes: all-reduce 2X(n-1)/n with n=16
    expect_ar = 2 * 1024 * 512 * 4 * 15 / 16
    assert out["ring_wire_bytes"] >= expect_ar


def test_choose_microbatches_policy():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    cfg = registry.get_config("qwen2-72b")
    spec = registry.SHAPES["train_4k"]
    m = dryrun.choose_microbatches(cfg, spec, FakeMesh())
    assert m >= 8  # the 80-layer residual stash needs accumulation
    small = registry.get_config("whisper-base")
    assert dryrun.choose_microbatches(small, spec, FakeMesh()) == 1
    assert dryrun.choose_microbatches(cfg, registry.SHAPES["decode_32k"],
                                      FakeMesh()) == 1


def test_probe_correction_arithmetic():
    cfg = registry.get_config("stablelm-12b")
    rec = {
        "microbatches": 4,
        "probes": {
            "u1": {"cost": {"flops": 110.0, "bytes_accessed": 60.0},
                   "collectives": {"operand_bytes": 12.0,
                                   "ring_wire_bytes": 24.0}},
            "u2": {"cost": {"flops": 210.0, "bytes_accessed": 110.0},
                   "collectives": {"operand_bytes": 22.0,
                                   "ring_wire_bytes": 44.0}},
        },
    }
    out = dryrun.correct(rec, cfg)
    # B = 100, F = 10, L = 40, M = 4 => 4 * (10 + 40*100) = 16040
    assert out["flops"] == pytest.approx(4 * (10 + 40 * 100))
    assert out["flops_per_unit"] == pytest.approx(100)
    assert out["collective_operand_bytes"] == pytest.approx(
        4 * (2 + 40 * 10))


def test_n_units_families():
    assert dryrun.n_units(registry.get_config("qwen2-72b")) == 80
    assert dryrun.n_units(registry.get_config("recurrentgemma-2b")) == \
        pytest.approx(26 / 3)
    assert dryrun.n_units(registry.get_config("whisper-base")) == 6


def test_hbm_napkin_fields():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    cfg = registry.get_config("qwen2-72b")
    nap = dryrun.hbm_napkin(cfg, registry.SHAPES["train_4k"], FakeMesh(), 16)
    assert nap["params"] == pytest.approx(cfg.param_count() * 4 / 256,
                                          rel=1e-6)
    assert nap["total"] < 16 * 2**30  # fits v5e HBM
    napd = dryrun.hbm_napkin(cfg, registry.SHAPES["decode_32k"],
                             FakeMesh(), 1)
    assert "kv_cache" in napd and napd["total"] < 16 * 2**30
