"""Repo tooling: the repro-lint static-analysis pass (``tools.lint``) and
the docs link checker (``tools.check_docs``)."""
