"""The scheduler stack's layer DAG, as data.

This is the machine-readable form of the eight-layer diagram in
``docs/ARCHITECTURE.md`` (kept in sync by hand; the diagram is prose, this
is the contract the ``layer-contract`` lint rule enforces).  Layers are
listed top to bottom; a module may import modules of its own layer or any
layer *below* it, plus the shared leaf modules, plus any explicitly
documented extra edge.

Shared leaves (``SHARED``) are pure vocabulary/model modules with no
scheduler state — any layer may import them, and they may only import each
other:

* ``repro.kernels.layout``  — the declared solver-matrix column schema,
* ``repro.core.dvfs``       — the Eq. 1-4 power/time/energy model,
* ``repro.core.cluster``    — state-free result records + Algorithm-3 helper,
* ``repro.core.tasks``      — task-set synthesis,
* ``repro.core.jobs``       — trace/job synthesis on top of tasks.

``EXTRA_EDGES`` documents the deliberate exceptions: the SSD-scan oracle in
``kernels/ref.py`` reuses the reference recurrence from ``models/ssm.py``
rather than duplicating it.
"""

from __future__ import annotations

from typing import Dict, FrozenSet, Optional, Tuple

#: Top-to-bottom layers of docs/ARCHITECTURE.md.  Lower index = higher
#: layer; importing a HIGHER layer (smaller index) is a violation.
LAYERS: Tuple[Tuple[str, Tuple[str, ...]], ...] = (
    ("policies", ("repro.core.scheduling", "repro.core.online",
                  "repro.core.bounds")),
    ("faults", ("repro.core.faults",)),
    ("placement", ("repro.core.placement",)),
    ("machines", ("repro.core.machines",)),
    ("engine", ("repro.core.engine",)),
    ("solvers", ("repro.core.single_task", "repro.kernels.ref")),
    ("solver-throughput", ("repro.core.solver_cache", "repro.kernels.ops")),
    ("kernel", ("repro.kernels.dvfs_opt", "repro.kernels.flash_attention",
                "repro.kernels.ssd_scan")),
)

#: Shared leaf modules: importable from every layer, may only import each
#: other (checked).
SHARED: FrozenSet[str] = frozenset({
    "repro.kernels.layout",
    "repro.core.dvfs",
    "repro.core.cluster",
    "repro.core.tasks",
    "repro.core.jobs",
})

#: Documented exceptions to the layer rule: importer -> allowed extra
#: targets (modules outside the DAG or above the importer).
EXTRA_EDGES: Dict[str, FrozenSet[str]] = {
    # The SSD oracle reuses the reference recurrence instead of forking it.
    "repro.kernels.ref": frozenset({"repro.models.ssm"}),
}

#: Module -> layer index (position in LAYERS).
RANK: Dict[str, int] = {
    mod: i for i, (_, mods) in enumerate(LAYERS) for mod in mods
}

#: Module -> layer name.
LAYER_OF: Dict[str, str] = {
    mod: name for name, mods in LAYERS for mod in mods
}


def rank_of(module: str) -> Optional[int]:
    """Layer index of ``module``, or None if it is not a ranked DAG node."""
    return RANK.get(module)


def in_dag(module: str) -> bool:
    """True if ``module`` participates in the layer contract at all."""
    return module in RANK or module in SHARED
