"""Per-function CFG + forward-dataflow framework for the flow-sensitive rules.

The PR-7 rules are per-line AST matchers; the flow-sensitive families
(``pallas-hazard``, ``async-protocol``, ``shape-flow``) need to reason about
*order* — a store reaching a later load, a handle that is dispatched on one
path and never consumed on another.  This module provides the shared
machinery, stdlib ``ast`` only (zero installs, same constraint as the rest
of ``tools/lint``):

* :func:`build_cfg` — a per-function control-flow graph.  Every statement of
  the function body lives in exactly ONE basic block, including compound
  statements (``if``/``while``/``for``/``try``/``with`` headers appear as the
  last statement of the block that branches on them; their bodies live in
  successor blocks).  Transfer functions must therefore only look at the
  expressions a statement *directly owns* — use :func:`stmt_exprs`.
* :func:`run_forward` — a worklist fixpoint engine for forward analyses,
  parameterised by ``init``/``transfer``/``join``.  Works for both may-
  (union-join) and must- (intersection-join) analyses: blocks whose input is
  still unknown are skipped during joins, the classic initialisation.
* :func:`reaching_definitions` — the textbook client, used by the framework
  tests and as the template for the rule-side analyses.
* :func:`layout_env` / :func:`resolve_cols` — the symbolic slice-bound
  resolver: column expressions (``col(P0)``, ``layout.BOUNDS_SLICE``,
  ``NCOL - KEY_COLS``, literal ints/slices) are evaluated against the
  *actual* constants of ``src/repro/kernels/layout.py`` so the rules never
  hard-code a second copy of the schema.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import (
    Any, Callable, Dict, Iterator, List, Optional, Sequence, Set, Tuple,
)

__all__ = [
    "Block", "CFG", "build_cfg", "run_forward", "statement_states",
    "reaching_definitions", "stmt_exprs", "attr_chain", "walk_calls",
    "layout_env", "resolve_col_expr", "Span",
]


# ---------------------------------------------------------------------------
# CFG construction
# ---------------------------------------------------------------------------

class Block:
    """A basic block: a straight-line run of statements plus successor ids."""

    __slots__ = ("id", "stmts", "succs")

    def __init__(self, bid: int) -> None:
        self.id = bid
        self.stmts: List[ast.stmt] = []
        self.succs: List[int] = []

    def add_succ(self, bid: int) -> None:
        if bid not in self.succs:
            self.succs.append(bid)


class CFG:
    """Control-flow graph of one function body.

    ``entry`` and ``exit`` are block ids; ``exit`` is always empty and
    collects every path out of the function (returns, raises, fallthrough).
    """

    def __init__(self) -> None:
        self.blocks: List[Block] = []
        self.entry = self._new().id
        self.exit = self._new().id

    def _new(self) -> Block:
        b = Block(len(self.blocks))
        self.blocks.append(b)
        return b

    def preds(self, bid: int) -> List[int]:
        return [b.id for b in self.blocks if bid in b.succs]

    def reachable(self) -> Set[int]:
        seen: Set[int] = set()
        work = [self.entry]
        while work:
            b = work.pop()
            if b in seen:
                continue
            seen.add(b)
            work.extend(self.blocks[b].succs)
        return seen


class _Builder:
    """Recursive-descent CFG builder over a statement list."""

    def __init__(self, cfg: CFG) -> None:
        self.cfg = cfg
        # (break-target, continue-target) stack for loops.
        self.loop_stack: List[Tuple[int, int]] = []

    def build(self, body: Sequence[ast.stmt], cur: int) -> int:
        """Lay out ``body`` starting in block ``cur``; return the block that
        falls through (possibly a fresh dead block after a jump)."""
        for stmt in body:
            cur = self._stmt(stmt, cur)
        return cur

    # -- helpers ----------------------------------------------------------
    def _seal(self, cur: int) -> int:
        """Terminate ``cur`` (it just jumped); continue in a dead block."""
        return self.cfg._new().id

    def _stmt(self, stmt: ast.stmt, cur: int) -> int:
        cfg = self.cfg
        blocks = cfg.blocks
        if isinstance(stmt, (ast.Return, ast.Raise)):
            blocks[cur].stmts.append(stmt)
            blocks[cur].add_succ(cfg.exit)
            return self._seal(cur)
        if isinstance(stmt, ast.Break):
            blocks[cur].stmts.append(stmt)
            if self.loop_stack:
                blocks[cur].add_succ(self.loop_stack[-1][0])
            return self._seal(cur)
        if isinstance(stmt, ast.Continue):
            blocks[cur].stmts.append(stmt)
            if self.loop_stack:
                blocks[cur].add_succ(self.loop_stack[-1][1])
            return self._seal(cur)
        if isinstance(stmt, ast.If):
            blocks[cur].stmts.append(stmt)
            after = cfg._new().id
            then_b = cfg._new().id
            blocks[cur].add_succ(then_b)
            then_end = self.build(stmt.body, then_b)
            blocks[then_end].add_succ(after)
            if stmt.orelse:
                else_b = cfg._new().id
                blocks[cur].add_succ(else_b)
                else_end = self.build(stmt.orelse, else_b)
                blocks[else_end].add_succ(after)
            else:
                blocks[cur].add_succ(after)
            return after
        if isinstance(stmt, (ast.While, ast.For, ast.AsyncFor)):
            # Header gets its own block so the back edge re-evaluates the
            # test / iterator expression.
            header = cfg._new().id
            blocks[cur].add_succ(header)
            blocks[header].stmts.append(stmt)
            after = cfg._new().id
            body_b = cfg._new().id
            blocks[header].add_succ(body_b)
            self.loop_stack.append((after, header))
            body_end = self.build(stmt.body, body_b)
            self.loop_stack.pop()
            blocks[body_end].add_succ(header)
            if stmt.orelse:
                else_b = cfg._new().id
                blocks[header].add_succ(else_b)
                else_end = self.build(stmt.orelse, else_b)
                blocks[else_end].add_succ(after)
            else:
                blocks[header].add_succ(after)
            return after
        if isinstance(stmt, ast.Try):
            # Conservative: any statement of the try body may raise, so each
            # handler is reachable both from the block *entering* the try and
            # from its end.  finally is laid out on the join path.
            body_b = cfg._new().id
            blocks[cur].add_succ(body_b)
            body_end = self.build(stmt.body, body_b)
            join = cfg._new().id
            else_end = self.build(stmt.orelse, body_end) if stmt.orelse \
                else body_end
            blocks[else_end].add_succ(join)
            for handler in stmt.handlers:
                h_b = cfg._new().id
                blocks[cur].add_succ(h_b)
                blocks[body_end].add_succ(h_b)
                h_end = self.build(handler.body, h_b)
                blocks[h_end].add_succ(join)
            if stmt.finalbody:
                return self.build(stmt.finalbody, join)
            return join
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            # The With node carries its context-manager expressions; the body
            # executes linearly after it.
            blocks[cur].stmts.append(stmt)
            return self.build(stmt.body, cur)
        # Simple statement (incl. nested def/class, treated as opaque).
        blocks[cur].stmts.append(stmt)
        return cur


def build_cfg(fn_or_body: Any) -> CFG:
    """Build the CFG of a function (or a raw statement list)."""
    body = fn_or_body.body if isinstance(
        fn_or_body, (ast.FunctionDef, ast.AsyncFunctionDef)) else fn_or_body
    cfg = CFG()
    end = _Builder(cfg).build(body, cfg.entry)
    cfg.blocks[end].add_succ(cfg.exit)
    return cfg


# ---------------------------------------------------------------------------
# Forward fixpoint engine
# ---------------------------------------------------------------------------

def run_forward(
    cfg: CFG,
    init: Any,
    transfer: Callable[[Any, ast.stmt], Any],
    join: Callable[[List[Any]], Any],
) -> Dict[int, Any]:
    """Iterate ``transfer`` over ``cfg`` to a fixpoint; return block-entry
    states.  Blocks not yet reached contribute nothing to joins (their state
    is ``None`` = unknown), which makes the same engine correct for both
    may- and must-analyses.  States must support ``==``.
    """
    entry_state: Dict[int, Any] = {cfg.entry: init}
    work = [cfg.entry]
    while work:
        bid = work.pop(0)
        state = entry_state.get(bid)
        if state is None:
            continue
        for stmt in cfg.blocks[bid].stmts:
            state = transfer(state, stmt)
        for succ in cfg.blocks[bid].succs:
            # The successor's entry is the join over the exit states of all
            # predecessors whose entry is already known (this block's fresh
            # exit state included).
            ins = []
            for p in cfg.preds(succ):
                out = state if p == bid else _block_exit(
                    cfg, p, entry_state, transfer)
                if out is not None:
                    ins.append(out)
            new = join(ins) if ins else None
            if new is not None and new != entry_state.get(succ):
                entry_state[succ] = new
                if succ not in work:
                    work.append(succ)
    return entry_state


def _block_exit(
    cfg: CFG,
    bid: int,
    entry_state: Dict[int, Any],
    transfer: Callable[[Any, ast.stmt], Any],
) -> Any:
    state = entry_state.get(bid)
    if state is None:
        return None
    for stmt in cfg.blocks[bid].stmts:
        state = transfer(state, stmt)
    return state


def statement_states(
    cfg: CFG,
    entry_state: Dict[int, Any],
    transfer: Callable[[Any, ast.stmt], Any],
) -> Iterator[Tuple[Any, ast.stmt]]:
    """After :func:`run_forward`, re-walk every reachable block yielding the
    state *before* each statement — the pass where rules emit findings (the
    fixpoint loop itself may visit a statement many times).
    """
    for bid in sorted(cfg.reachable()):
        state = entry_state.get(bid)
        if state is None:
            continue
        for stmt in cfg.blocks[bid].stmts:
            yield state, stmt
            state = transfer(state, stmt)


# ---------------------------------------------------------------------------
# Reaching definitions (framework test client + template)
# ---------------------------------------------------------------------------

def _assigned_names(stmt: ast.stmt) -> List[str]:
    out: List[str] = []
    targets: List[ast.expr] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        targets = [stmt.target]
    for t in targets:
        for node in ast.walk(t):
            if isinstance(node, ast.Name) and isinstance(
                    node.ctx, (ast.Store,)):
                out.append(node.id)
    return out


def reaching_definitions(
    cfg: CFG,
) -> Dict[int, Set[Tuple[str, int]]]:
    """Classic reaching definitions: block-entry sets of ``(name, lineno)``
    pairs, one per definition site that may reach the block."""

    def transfer(state: Set[Tuple[str, int]],
                 stmt: ast.stmt) -> Set[Tuple[str, int]]:
        names = _assigned_names(stmt)
        if not names:
            return state
        gen = {(n, stmt.lineno) for n in names}
        killed = set(names)
        if isinstance(stmt, (ast.For, ast.AsyncFor, ast.AugAssign)):
            # Loop targets / augmented assigns merge rather than kill: the
            # old value may still reach (zero-iteration loop, RMW).
            return state | gen
        return {d for d in state if d[0] not in killed} | gen

    def join(states: List[Set[Tuple[str, int]]]) -> Set[Tuple[str, int]]:
        out: Set[Tuple[str, int]] = set()
        for s in states:
            out |= s
        return out

    entry = run_forward(cfg, frozenset(), lambda s, st: frozenset(
        transfer(set(s), st)), lambda xs: frozenset(join(
            [set(x) for x in xs])))
    return {bid: set(s) for bid, s in entry.items()}


# ---------------------------------------------------------------------------
# Expression helpers shared by the rule families
# ---------------------------------------------------------------------------

def stmt_exprs(stmt: ast.stmt) -> List[ast.expr]:
    """The expressions a statement *directly owns* — its own test/value/
    targets, but never the bodies of compound statements (those live in other
    CFG blocks) and never the bodies of nested function/class definitions.
    """
    if isinstance(stmt, ast.Assign):
        return [stmt.value] + list(stmt.targets)
    if isinstance(stmt, ast.AugAssign):
        return [stmt.value, stmt.target]
    if isinstance(stmt, ast.AnnAssign):
        return ([stmt.value] if stmt.value else []) + [stmt.target]
    if isinstance(stmt, ast.Expr):
        return [stmt.value]
    if isinstance(stmt, ast.Return):
        return [stmt.value] if stmt.value else []
    if isinstance(stmt, (ast.If, ast.While)):
        return [stmt.test]
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        return [stmt.iter, stmt.target]
    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        out: List[ast.expr] = []
        for item in stmt.items:
            out.append(item.context_expr)
            if item.optional_vars is not None:
                out.append(item.optional_vars)
        return out
    if isinstance(stmt, ast.Raise):
        return [e for e in (stmt.exc, stmt.cause) if e is not None]
    if isinstance(stmt, ast.Assert):
        return [stmt.test] + ([stmt.msg] if stmt.msg else [])
    if isinstance(stmt, ast.Delete):
        return list(stmt.targets)
    if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                         ast.ClassDef)):
        return []  # opaque: nested scopes are separate regions
    return []


def attr_chain(node: ast.expr) -> Optional[str]:
    """Dotted-name string of a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def walk_calls(expr: ast.expr) -> Iterator[ast.Call]:
    """Every Call node within ``expr`` (including nested ones)."""
    for node in ast.walk(expr):
        if isinstance(node, ast.Call):
            yield node


# ---------------------------------------------------------------------------
# layout.py constant resolution
# ---------------------------------------------------------------------------

_LAYOUT_ENV: Optional[Dict[str, Any]] = None


def layout_env() -> Dict[str, Any]:
    """Execute ``src/repro/kernels/layout.py`` (stdlib-only by design — the
    layer DAG pins it as a shared leaf) and return its namespace, so slice
    bounds resolve against the *declared* schema rather than a copy.  Returns
    an empty dict if the file is missing (rules then degrade to silence).
    """
    global _LAYOUT_ENV
    if _LAYOUT_ENV is None:
        path = Path(__file__).resolve().parents[2] / "src" / "repro" / \
            "kernels" / "layout.py"
        env: Dict[str, Any] = {}
        try:
            exec(compile(path.read_text(), str(path), "exec"), env)
        except OSError:
            env = {}
        _LAYOUT_ENV = env
    return _LAYOUT_ENV


#: Resolved column span: ``(lo, hi)`` half-open, or None when symbolic.
Span = Tuple[int, int]


def _resolve_int(node: ast.expr, env: Dict[str, Any]) -> Optional[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        inner = _resolve_int(node.operand, env)
        return -inner if inner is not None else None
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.Add, ast.Sub)):
        left = _resolve_int(node.left, env)
        right = _resolve_int(node.right, env)
        if left is None or right is None:
            return None
        return left + right if isinstance(node.op, ast.Add) else left - right
    chain = attr_chain(node)
    if chain is not None:
        val = env.get(chain.rsplit(".", 1)[-1])
        if isinstance(val, int) and not isinstance(val, bool):
            return val
    return None


def resolve_col_expr(
    node: ast.expr, env: Dict[str, Any], width: Optional[int] = None,
) -> Optional[Span]:
    """Resolve a column subscript expression to a half-open ``(lo, hi)`` span.

    Handles literal ints, layout column names (bare or attribute-qualified),
    ``col(i)`` calls, ``slice``-valued layout constants (``PARAMS_SLICE``),
    and explicit ``lo:hi`` slices whose endpoints resolve (``None`` endpoints
    use 0 / ``width`` when the ref width is known).  Returns None when the
    expression stays symbolic — callers must treat that conservatively.
    """
    i = _resolve_int(node, env)
    if i is not None:
        return (i, i + 1)
    chain = attr_chain(node)
    if chain is not None:
        val = env.get(chain.rsplit(".", 1)[-1])
        if isinstance(val, slice) and isinstance(val.start, int) \
                and isinstance(val.stop, int):
            return (val.start, val.stop)
    if isinstance(node, ast.Call):
        fn = attr_chain(node.func)
        if fn is not None and fn.rsplit(".", 1)[-1] == "col" \
                and len(node.args) == 1 and not node.keywords:
            i = _resolve_int(node.args[0], env)
            if i is not None:
                return (i, i + 1)
        return None
    if isinstance(node, ast.Slice):
        if node.step is not None:
            return None
        lo = 0 if node.lower is None else _resolve_int(node.lower, env)
        if node.upper is None:
            hi: Optional[int] = width
        else:
            hi = _resolve_int(node.upper, env)
        if lo is None or hi is None:
            return None
        return (lo, hi)
    return None
