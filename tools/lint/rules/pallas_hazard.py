"""pallas-hazard: ref load/store hazards inside Pallas kernel bodies.

A Pallas kernel body is straight-line traced code over mutable refs; the
compiler will happily reorder nothing for you, so a read-after-write on an
overlapping slice, a store into an input ref, or a column slice that drifts
across a ``layout.py`` group boundary silently corrupts ``e_total`` instead
of crashing.  This family abstractly interprets every function with
``*_ref`` parameters in ``repro.kernels``:

* **Ref classification** — the module's ``pl.pallas_call`` site is cross-
  referenced (``in_specs``/``out_specs``/``scratch_shapes`` map positionally
  onto the kernel's ref parameters) to split refs into input / output /
  scratch; any store to an *input* ref is flagged.
* **Symbolic slice bounds** — block widths come from the BlockSpec shapes,
  resolved through the constants of ``kernels/layout.py`` (``NCOL``,
  ``SOL_COLS``, ``col(i)``, ``PARAMS_SLICE``, ...).  Loads of a full ref
  (``t = tasks_ref[...]``) taint the target, so later column subscripts on
  ``t`` are checked against the ref's declared width: out-of-bounds columns
  and multi-column slices that cross a column-group boundary (PARAMS /
  ALLOWED / READJUST / BOUNDS / padding) are flagged.  Symbolically
  unresolvable bounds stay silent — the rule never guesses.
* **RAW / WAR hazards** — a forward may-analysis per *region* (the kernel's
  top-level body and each nested ``@pl.when`` function are separate regions,
  predicated off each other): a load overlapping a reaching store to the
  same ref (read-after-write), or a store *partially* overlapping a prior
  load (write-after-read on a strict sub-slice — mixed-staleness lanes) is
  flagged unless a barrier call intervenes.  Same-statement RMW
  (``acc_ref[...] = acc_ref[...] * c + u``) is idiomatic and exempt: the
  right-hand load completes before the store.
"""

from __future__ import annotations

import ast
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple, Union

from tools.lint import Context, Finding
from tools.lint.flow import (
    CFG, _resolve_int, attr_chain, build_cfg, layout_env, resolve_col_expr,
    run_forward, statement_states, stmt_exprs,
)

NAME = "pallas-hazard"

#: Access span over a ref's last axis: concrete half-open bounds, the whole
#: ref, or symbolically unknown.
Span = Union[Tuple[int, int], str, None]
FULL = "full"

#: Hazard-state element: ("L"|"S", ref name, span, lineno).
_Access = Tuple[str, str, Span, int]
_State = FrozenSet[_Access]


# ---------------------------------------------------------------------------
# pallas_call cross-referencing: ref name -> role + width
# ---------------------------------------------------------------------------

class _RefInfo:
    __slots__ = ("role", "width")

    def __init__(self, role: str, width: Optional[int]) -> None:
        self.role = role      # "in" | "out" | "scratch" | "unknown"
        self.width = width    # last-axis block width, when resolvable


def _shape_last(call: ast.expr, env: Dict[str, object]) -> Optional[int]:
    """Last-axis width of a ``pl.BlockSpec((.., W), ..)`` / VMEM shape."""
    if not isinstance(call, ast.Call) or not call.args:
        return None
    shape = call.args[0]
    if isinstance(shape, ast.Tuple) and shape.elts:
        return _resolve_int(shape.elts[-1], env)
    return None


def _spec_list(node: Optional[ast.expr]) -> List[ast.expr]:
    if node is None:
        return []
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    return [node]


def _kernel_specs(tree: ast.AST) -> Dict[str, Tuple[int, int, int,
                                                    List[Optional[int]],
                                                    List[Optional[int]]]]:
    """kernel function name -> (n_in, n_out, n_scratch, in_widths,
    out_widths), from the module's ``pl.pallas_call`` sites.  The kernel may
    be passed directly, via ``functools.partial(fn, ...)``, or via a local
    variable assigned from such a partial."""
    env = layout_env()
    # Local aliases: name -> underlying function name (through partial).
    alias: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Call):
            chain = attr_chain(node.value.func) or ""
            if chain.rsplit(".", 1)[-1] == "partial" and node.value.args:
                inner = node.value.args[0]
                if isinstance(inner, ast.Name):
                    alias[node.targets[0].id] = inner.id

    out: Dict[str, Tuple[int, int, int, List[Optional[int]],
                         List[Optional[int]]]] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = attr_chain(node.func) or ""
        if chain.rsplit(".", 1)[-1] != "pallas_call" or not node.args:
            continue
        target = node.args[0]
        name: Optional[str] = None
        if isinstance(target, ast.Call):  # functools.partial(fn, ...)
            if target.args and isinstance(target.args[0], ast.Name):
                name = target.args[0].id
        elif isinstance(target, ast.Name):
            name = alias.get(target.id, target.id)
        if name is None:
            continue
        kw = {k.arg: k.value for k in node.keywords if k.arg}
        in_specs = _spec_list(kw.get("in_specs"))
        out_specs = _spec_list(kw.get("out_specs"))
        scratch = _spec_list(kw.get("scratch_shapes"))
        out[name] = (
            len(in_specs), len(out_specs), len(scratch),
            [_shape_last(s, env) for s in in_specs],
            [_shape_last(s, env) for s in out_specs],
        )
    return out


def _classify_refs(
    fn: ast.FunctionDef,
    specs: Dict[str, Tuple[int, int, int, List[Optional[int]],
                           List[Optional[int]]]],
) -> Dict[str, _RefInfo]:
    params = [a.arg for a in fn.args.args]
    refs = [p for p in params if p.endswith("_ref")]
    info = {r: _RefInfo("unknown", None) for r in refs}
    spec = specs.get(fn.name)
    if spec is None:
        return info
    n_in, n_out, n_scratch, in_w, out_w = spec
    if n_in + n_out + n_scratch != len(params):
        return info
    for i, p in enumerate(params):
        if p not in info:
            continue
        if i < n_in:
            info[p] = _RefInfo("in", in_w[i])
        elif i < n_in + n_out:
            info[p] = _RefInfo("out", out_w[i - n_in])
        else:
            info[p] = _RefInfo("scratch", None)
    return info


# ---------------------------------------------------------------------------
# Subscript access extraction
# ---------------------------------------------------------------------------

def _is_full_slice(node: ast.expr) -> bool:
    return isinstance(node, ast.Slice) and node.lower is None \
        and node.upper is None and node.step is None


def _access_span(sub: ast.Subscript, env: Dict[str, object],
                 width: Optional[int]) -> Span:
    """Span of a ref/tainted-matrix subscript over the *last* axis."""
    sl = sub.slice
    if isinstance(sl, ast.Constant) and sl.value is Ellipsis:
        return FULL
    if _is_full_slice(sl):
        return FULL
    if isinstance(sl, ast.Tuple):
        if all(_is_full_slice(e) or (
                isinstance(e, ast.Constant) and e.value is Ellipsis)
                for e in sl.elts):
            return FULL
        lead, last = sl.elts[:-1], sl.elts[-1]
        if lead and all(_is_full_slice(e) for e in lead):
            span = resolve_col_expr(last, env, width)
            return span
        return None
    # 1-D subscript with a resolvable index / slice.
    return resolve_col_expr(sl, env, width)


def _ref_accesses(stmt: ast.stmt, names: Sequence[str],
                  env: Dict[str, object],
                  widths: Dict[str, Optional[int]],
                  ) -> Tuple[List[Tuple[str, Span, ast.Subscript]],
                             List[Tuple[str, Span, ast.Subscript]]]:
    """(loads, stores) of tracked names in the statement's own expressions."""
    loads: List[Tuple[str, Span, ast.Subscript]] = []
    stores: List[Tuple[str, Span, ast.Subscript]] = []
    for expr in stmt_exprs(stmt):
        for node in ast.walk(expr):
            if not isinstance(node, ast.Subscript):
                continue
            if not isinstance(node.value, ast.Name):
                continue
            name = node.value.id
            if name not in names:
                continue
            span = _access_span(node, env, widths.get(name))
            if isinstance(node.ctx, ast.Store):
                stores.append((name, span, node))
            else:
                loads.append((name, span, node))
    return loads, stores


def _concrete(span: Span, width: Optional[int]) -> Optional[Tuple[int, int]]:
    if span == FULL:
        return (0, width) if width is not None else None
    if isinstance(span, tuple):
        return span
    return None


def _may_overlap(a: Span, b: Span, width: Optional[int]) -> bool:
    """Conservative overlap: unknown spans are assumed to overlap."""
    ca, cb = _concrete(a, width), _concrete(b, width)
    if ca is None or cb is None:
        return True
    return ca[0] < cb[1] and cb[0] < ca[1]


def _definitely_partial(store: Span, load: Span,
                        width: Optional[int]) -> bool:
    """True only when both spans concretize and overlap without being
    equal — the provable mixed-staleness case."""
    cs, cl = _concrete(store, width), _concrete(load, width)
    if cs is None or cl is None:
        return False
    return cs != cl and cs[0] < cl[1] and cl[0] < cs[1]


# ---------------------------------------------------------------------------
# Column-group (schema-drift) checks
# ---------------------------------------------------------------------------

def _groups_for(width: int, env: Dict[str, object]
                ) -> List[Tuple[int, int, str]]:
    ncol = env.get("NCOL")
    key_cols = env.get("KEY_COLS")
    n_params = env.get("N_PARAMS")
    if not isinstance(ncol, int) or not isinstance(key_cols, int) \
            or not isinstance(n_params, int):
        return [(0, width, "matrix")]
    if width in (ncol, key_cols):
        allowed = env.get("ALLOWED")
        readjust = env.get("READJUST")
        v_min = env.get("V_MIN")
        if not isinstance(allowed, int) or not isinstance(readjust, int) \
                or not isinstance(v_min, int):
            return [(0, width, "matrix")]
        groups = [(0, n_params, "PARAMS"),
                  (allowed, allowed + 1, "ALLOWED"),
                  (readjust, readjust + 1, "READJUST"),
                  (v_min, key_cols, "BOUNDS")]
        if width > key_cols:
            groups.append((key_cols, width, "padding"))
        return groups
    return [(0, width, "matrix")]


def _check_span(ctx: Context, node: ast.Subscript, span: Span,
                width: Optional[int], env: Dict[str, object],
                what: str) -> List[Finding]:
    if width is None or not isinstance(span, tuple):
        return []
    lo, hi = span
    if lo < 0 or hi > width:
        return [ctx.finding(
            node, NAME, f"column access [{lo}:{hi}] out of bounds for the "
            f"[*, {width}] {what}")]
    if hi - lo > 1 and (lo, hi) != (0, width):
        for g_lo, g_hi, g_name in _groups_for(width, env):
            if g_lo <= lo and hi <= g_hi:
                return []
        return [ctx.finding(
            node, NAME, f"slice [{lo}:{hi}] crosses a layout.py column-group "
            f"boundary of the [*, {width}] {what} (PARAMS/ALLOWED/READJUST/"
            "BOUNDS must be addressed as whole groups — schema drift)")]
    return []


# ---------------------------------------------------------------------------
# Per-region hazard dataflow
# ---------------------------------------------------------------------------

def _is_barrier(stmt: ast.stmt) -> bool:
    for expr in stmt_exprs(stmt):
        for node in ast.walk(expr):
            if isinstance(node, ast.Call):
                chain = attr_chain(node.func) or ""
                if "barrier" in chain.rsplit(".", 1)[-1]:
                    return True
    return False


def _region_findings(
    ctx: Context, body: Sequence[ast.stmt], refs: Dict[str, _RefInfo],
    env: Dict[str, object],
) -> List[Finding]:
    names = list(refs)
    widths = {r: info.width for r, info in refs.items()}

    def transfer(state: _State, stmt: ast.stmt) -> _State:
        if _is_barrier(stmt):
            return frozenset()
        loads, stores = _ref_accesses(stmt, names, env, widths)
        acc = set(state)
        acc |= {("L", n, s, stmt.lineno) for n, s, _ in loads}
        acc |= {("S", n, s, stmt.lineno) for n, s, _ in stores}
        return frozenset(acc)

    def join(states: List[_State]) -> _State:
        out: set = set()
        for s in states:
            out |= s
        return frozenset(out)

    cfg: CFG = build_cfg(list(body))
    entry = run_forward(cfg, frozenset(), transfer, join)
    findings: List[Finding] = []
    seen: set = set()
    for state, stmt in statement_states(cfg, entry, transfer):
        loads, stores = _ref_accesses(stmt, names, env, widths)
        for n, span, node in loads:
            w = widths.get(n)
            for kind, rn, rspan, rline in state:
                if kind == "S" and rn == n and _may_overlap(
                        span, rspan, w):
                    key = ("raw", n, node.lineno, node.col_offset)
                    if key not in seen:
                        seen.add(key)
                        findings.append(ctx.finding(
                            node, NAME, f"read of {n} may observe the store "
                            f"at line {rline} (read-after-write on "
                            "overlapping slices with no intervening "
                            "barrier)"))
                    break
        for n, span, node in stores:
            w = widths.get(n)
            for kind, rn, rspan, rline in state:
                if kind == "L" and rn == n and _definitely_partial(
                        span, rspan, w):
                    key = ("war", n, node.lineno, node.col_offset)
                    if key not in seen:
                        seen.add(key)
                        findings.append(ctx.finding(
                            node, NAME, f"store to {n} partially overlaps "
                            f"the slice read at line {rline} (write-after-"
                            "read on a strict sub-slice leaves mixed-"
                            "staleness lanes)"))
                    break
    return findings


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def _kernel_findings(ctx: Context, fn: ast.FunctionDef,
                     refs: Dict[str, _RefInfo],
                     env: Dict[str, object]) -> List[Finding]:
    findings: List[Finding] = []
    widths = {r: info.width for r, info in refs.items()}

    # Taint: vars assigned from a full-ref load inherit the ref's width, so
    # later column subscripts on them are schema-checked too.  Peel width-
    # preserving .astype(...) wrappers (`t = tasks_ref[...].astype(f32)`).
    def _peel(expr: ast.expr) -> ast.expr:
        while isinstance(expr, ast.Call) \
                and isinstance(expr.func, ast.Attribute) \
                and expr.func.attr == "astype":
            expr = expr.func.value
        return expr

    tainted: Dict[str, Optional[int]] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            value = _peel(node.value)
            if isinstance(value, ast.Subscript) \
                    and isinstance(value.value, ast.Name) \
                    and value.value.id in refs:
                span = _access_span(value, env, widths.get(value.value.id))
                if span == FULL:
                    tainted[node.targets[0].id] = widths.get(value.value.id)

    # Store-to-input + per-access schema checks (flow-insensitive).
    for node in ast.walk(fn):
        if not isinstance(node, ast.Subscript) \
                or not isinstance(node.value, ast.Name):
            continue
        name = node.value.id
        if name in refs:
            if isinstance(node.ctx, ast.Store) and refs[name].role == "in":
                findings.append(ctx.finding(
                    node, NAME, f"store to input ref {name}: the "
                    "pallas_call in_specs declare it read-only; writing it "
                    "aliases the caller's task matrix"))
            findings += _check_span(
                ctx, node, _access_span(node, env, widths.get(name)),
                widths.get(name), env, f"ref {name}")
        elif name in tainted:
            findings += _check_span(
                ctx, node, _access_span(node, env, tainted[name]),
                tainted[name], env, f"matrix {name} (loaded from a ref)")

    # Hazard dataflow per region: the top-level body, then each nested
    # function (predicated @pl.when regions execute independently).
    findings += _region_findings(ctx, fn.body, refs, env)
    for node in ast.walk(fn):
        if isinstance(node, ast.FunctionDef) and node is not fn:
            findings += _region_findings(ctx, node.body, refs, env)
    return findings


def check(ctx: Context) -> List[Finding]:
    mod = ctx.module or ""
    if not mod.startswith("repro.kernels"):
        return []
    env = layout_env()
    specs = _kernel_specs(ctx.tree)
    findings: List[Finding] = []
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, ast.FunctionDef):
            continue
        if not any(a.arg.endswith("_ref") for a in fn.args.args):
            continue
        refs = _classify_refs(fn, specs)
        if refs:
            findings += _kernel_findings(ctx, fn, refs, env)
    return findings
