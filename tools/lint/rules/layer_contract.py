"""layer-contract: enforce the docs/ARCHITECTURE.md import DAG.

Two checks over every ``repro.*`` module (``__init__.py`` package facades
are exempt — they exist to re-export):

* **Layer direction.**  A ranked module (:data:`tools.lint.layer_dag.RANK`)
  may import modules of its own layer or deeper, the SHARED leaves, and
  its documented EXTRA_EDGES — nothing else inside ``repro``.  A SHARED
  leaf may only import other SHARED leaves.  Function-level (lazy) imports
  are held to the same contract: laziness breaks import cycles, not the
  architecture.

* **Private names.**  ``from repro.x import _name`` reaches into another
  module's implementation; private names are module-local by convention.
"""

from __future__ import annotations

import ast
from typing import List, Optional, Tuple

from tools.lint import Context, Finding
from tools.lint.layer_dag import EXTRA_EDGES, LAYER_OF, RANK, SHARED

NAME = "layer-contract"


def _import_targets(tree: ast.Module) -> List[Tuple[ast.AST, str, Tuple[str, ...]]]:
    """All ``repro.*`` imports as ``(node, base_module, imported_names)``."""
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.startswith("repro."):
                    out.append((node, alias.name, ()))
        elif isinstance(node, ast.ImportFrom):
            if node.module and node.module.startswith("repro") and not node.level:
                out.append((node, node.module,
                            tuple(a.name for a in node.names)))
    return out


def _resolve(base: str, names: Tuple[str, ...]) -> List[str]:
    """Concrete target modules of one import statement.

    ``from repro.core import solver_cache`` names the *submodule*
    ``repro.core.solver_cache``; ``from repro.core.dvfs import DvfsParams``
    names the module ``repro.core.dvfs`` itself.  A dotted name is a known
    module iff it appears in the DAG tables; otherwise the base module is
    the target.
    """
    if not names:
        return [base]
    targets = []
    for n in names:
        cand = f"{base}.{n}"
        if cand in RANK or cand in SHARED or any(
                cand in extras for extras in EXTRA_EDGES.values()):
            targets.append(cand)
        else:
            targets.append(base)
    return sorted(set(targets))


def _violation(importer: str, target: str) -> Optional[str]:
    """Reason ``importer -> target`` breaks the contract, or None if legal."""
    if target in SHARED or target == importer:
        return None
    if target in EXTRA_EDGES.get(importer, ()):
        return None
    # Importing a package facade (repro, repro.core, repro.kernels) pulls
    # in an unscoped surface; treat it like an unknown module below.
    if importer in SHARED:
        return (f"shared leaf module imports {target}; shared leaves may "
                "only import other shared leaves")
    r_imp = RANK.get(importer)
    if r_imp is None:
        return None  # importer outside the DAG: no contract to enforce
    r_tgt = RANK.get(target)
    if r_tgt is None:
        return (f"imports {target}, which is outside the scheduler-stack "
                "DAG (docs/ARCHITECTURE.md); add an EXTRA_EDGES entry in "
                "tools/lint/layer_dag.py if this edge is deliberate")
    if r_tgt < r_imp:
        return (f"layer '{LAYER_OF[importer]}' imports UP-layer "
                f"'{LAYER_OF[target]}' module {target}")
    return None


def check(ctx: Context) -> List[Finding]:
    if ctx.module is None or not ctx.module.startswith("repro"):
        return []
    findings: List[Finding] = []
    is_facade = ctx.path.endswith("__init__.py")
    for node, base, names in _import_targets(ctx.tree):
        # Private-name reach-through (checked even for facades).
        for n in names:
            if n.startswith("_") and not n.startswith("__"):
                findings.append(ctx.finding(
                    node, NAME,
                    f"imports private name '{n}' from {base}; private "
                    "names are module-local — export a public alias"))
        if is_facade:
            continue
        for target in _resolve(base, names):
            reason = _violation(ctx.module, target)
            if reason:
                findings.append(ctx.finding(node, NAME, reason))
    return findings
