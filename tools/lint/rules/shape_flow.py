"""shape-flow: symbolic matrix shape/dtype inference at solver boundaries.

The solver stack passes task/key/solution matrices between nine modules as
bare float32 ndarrays; nothing but convention says a ``solve_rows`` call is
fed ``[n, KEY_COLS]`` and a kernel path ``[n, NCOL]``.  The golden tests
catch drift only after the fact.  This family runs a forward symbolic
inference over every function in the solver-facing modules (the same scope
as ``matrix-schema``), tracking per-variable ``(width, dtype)`` facts:

* widths are *produced* by the known constructors — ``build_keys`` is
  ``(KEY_COLS, f32)`` by its own contract, ``solution_to_rows`` is
  ``SOL_COLS``, ``np.zeros/empty/ones/full((n, W))`` resolve ``W`` through
  ``layout.py``, ``np.stack([..k items..], axis=1)`` is ``k``,
  ``np.concatenate(.., axis=1)`` sums known widths, ``np.broadcast_to``
  reads its target shape, row slices and ``_pad_rows`` preserve width,
  column slices re-resolve through the layout constants;
* and *consumed* at the contract sites — the key matrix of
  ``solver_cache.solve_rows(_async)`` must be ``[n, KEY_COLS]`` float32,
  and the kernel entries ``dvfs_solve_matrix`` (``KEY_COLS`` or ``NCOL``)
  and ``dvfs_solve_kernel`` (``NCOL`` or ``LEGACY_NCOL``) must be fed a
  task matrix of a declared width.

Unknown widths stay silent — the rule flags only *provable* mismatches, so
parameter passthroughs (already guarded by runtime asserts) never false-
positive.  ``single_task.solve_rows_async`` takes per-task params, not a
key matrix, and is excluded by its qualifier.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from tools.lint import Context, Finding
from tools.lint.flow import (
    CFG, _resolve_int, attr_chain, build_cfg, layout_env, resolve_col_expr,
    run_forward, statement_states, stmt_exprs, walk_calls,
)
from tools.lint.rules.matrix_schema import SCHEMA_SCOPE

NAME = "shape-flow"

#: (width, dtype) with None = unknown; dtype in {"f32", "f64"}.
_Fact = Tuple[Optional[int], Optional[str]]
_Env = Tuple[Tuple[str, _Fact], ...]  # sorted, hashable var environment

_F32 = {"np.float32", "numpy.float32", "jnp.float32", "jax.numpy.float32"}
_F64 = {"np.float64", "numpy.float64", "jnp.float64", "jax.numpy.float64"}

#: Width-preserving single-matrix wrappers.
_PASSTHROUGH = {"ascontiguousarray", "asarray", "array", "copy",
                "device_put", "_pad_rows", "pad_rows", "abs", "where"}


def _final(chain: Optional[str]) -> str:
    return (chain or "").rsplit(".", 1)[-1]


def _dtype_of(node: ast.expr) -> Optional[str]:
    chain = attr_chain(node)
    if chain in _F32:
        return "f32"
    if chain in _F64:
        return "f64"
    return None


def _env_get(env: _Env, var: str) -> _Fact:
    for v, fact in env:
        if v == var:
            return fact
    return (None, None)


def _env_set(env: _Env, var: str, fact: _Fact) -> _Env:
    items = [(v, f) for v, f in env if v != var]
    if fact != (None, None):
        items.append((var, fact))
    return tuple(sorted(items))


def _shape_width(node: ast.expr, layout: Dict[str, object]) -> \
        Optional[int]:
    """Second element of an explicit ``(rows, cols)`` shape tuple."""
    if isinstance(node, ast.Tuple) and len(node.elts) == 2:
        return _resolve_int(node.elts[1], layout)
    return None


def _infer(expr: ast.expr, env: _Env,
           layout: Dict[str, object]) -> _Fact:
    """Symbolic (width, dtype) of an expression, or (None, None)."""
    if isinstance(expr, ast.Name):
        return _env_get(env, expr.id)
    if isinstance(expr, ast.IfExp):
        a, b = _infer(expr.body, env, layout), _infer(
            expr.orelse, env, layout)
        return (a[0] if a[0] == b[0] else None,
                a[1] if a[1] == b[1] else None)
    if isinstance(expr, ast.Subscript):
        base_w, base_d = _infer(expr.value, env, layout)
        sl = expr.slice
        if isinstance(sl, ast.Tuple) and len(sl.elts) == 2:
            lead, last = sl.elts
            if isinstance(lead, ast.Slice):  # [rows, cols] selection
                span = resolve_col_expr(last, layout, base_w)
                if span is not None:
                    return (span[1] - span[0], base_d)
                return (None, base_d)
            return (None, None)
        if isinstance(sl, ast.Slice):  # row slice keeps the width
            return (base_w, base_d)
        return (None, None)
    if not isinstance(expr, ast.Call):
        return (None, None)

    call = expr
    name = _final(attr_chain(call.func))
    kw = {k.arg: k.value for k in call.keywords if k.arg}

    if name == "build_keys":
        key_cols = layout.get("KEY_COLS")
        return (key_cols if isinstance(key_cols, int) else None, "f32")
    if name == "solution_to_rows":
        sol = layout.get("SOL_COLS")
        return (sol if isinstance(sol, int) else None, "f32")
    if name in _PASSTHROUGH:
        if not call.args:
            return (None, None)
        w, d = _infer(call.args[0], env, layout)
        if len(call.args) >= 2:
            d = _dtype_of(call.args[1]) or d
        if "dtype" in kw:
            d = _dtype_of(kw["dtype"]) or d
        return (w, d)
    if name in {"zeros", "empty", "ones", "full", "zeros_like",
                "empty_like", "full_like"}:
        if name.endswith("_like"):
            return _infer(call.args[0], env, layout) if call.args \
                else (None, None)
        w = _shape_width(call.args[0], layout) if call.args else None
        d = None
        for cand in list(call.args[1:]) + \
                ([kw["dtype"]] if "dtype" in kw else []):
            d = _dtype_of(cand) or d
        return (w, d)
    if name == "broadcast_to" and len(call.args) >= 2:
        _, d = _infer(call.args[0], env, layout)
        return (_shape_width(call.args[1], layout), d)
    if name == "stack" and call.args \
            and isinstance(call.args[0], (ast.List, ast.Tuple)):
        axis = kw.get("axis")
        if axis is not None and _resolve_int(axis, layout) == 1:
            elts = call.args[0].elts
            dtypes = {_infer(e, env, layout)[1] for e in elts}
            d = dtypes.pop() if len(dtypes) == 1 else None
            return (len(elts), d)
        return (None, None)
    if name == "concatenate" and call.args \
            and isinstance(call.args[0], (ast.List, ast.Tuple)):
        axis_node = kw.get("axis") or (
            call.args[1] if len(call.args) > 1 else None)
        axis = _resolve_int(axis_node, layout) if axis_node is not None \
            else 0
        facts = [_infer(e, env, layout) for e in call.args[0].elts]
        dtypes = {d for _, d in facts}
        d = dtypes.pop() if len(dtypes) == 1 else None
        widths = [w for w, _ in facts]
        if axis == 1:
            if all(w is not None for w in widths):
                return (sum(widths), d)  # type: ignore[arg-type]
            return (None, d)
        if axis == 0:
            known = {w for w in widths if w is not None}
            if len(known) == 1 and all(w is not None for w in widths):
                return (known.pop(), d)
            return (None, d)
        return (None, d)
    return (None, None)


# ---------------------------------------------------------------------------
# Contract sites
# ---------------------------------------------------------------------------

def _key_contract_site(call: ast.Call) -> bool:
    """True for ``solve_rows``/``solve_rows_async`` calls that take a key
    matrix — i.e. the solver_cache entry points, not the per-task wrapper
    ``single_task.solve_rows_async(params, ...)``."""
    chain = attr_chain(call.func) or ""
    name = _final(chain)
    if name not in {"solve_rows", "solve_rows_async"}:
        return False
    qualifier = chain[: -len(name)].rstrip(".")
    return qualifier in {"", "solver_cache"} and bool(call.args)


def _contract_findings(ctx: Context, fn: ast.FunctionDef,
                       layout: Dict[str, object]) -> List[Finding]:
    key_cols = layout.get("KEY_COLS")
    ncol = layout.get("NCOL")
    legacy = layout.get("LEGACY_NCOL")
    if not isinstance(key_cols, int) or not isinstance(ncol, int):
        return []

    def transfer(env: _Env, stmt: ast.stmt) -> _Env:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            return _env_set(env, stmt.targets[0].id,
                            _infer(stmt.value, env, layout))
        if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.For)):
            # Any other assignment form degrades its targets to unknown.
            targets = stmt.targets if isinstance(stmt, ast.Assign) \
                else [stmt.target]
            for t in targets:
                for node in ast.walk(t):
                    if isinstance(node, ast.Name) \
                            and isinstance(node.ctx, ast.Store):
                        env = _env_set(env, node.id, (None, None))
        return env

    def join(envs: List[_Env]) -> _Env:
        if not envs:
            return tuple()
        merged: Dict[str, _Fact] = {}
        all_vars = {v for e in envs for v, _ in e}
        for v in all_vars:
            facts = [_env_get(e, v) for e in envs]
            w = facts[0][0] if all(f[0] == facts[0][0] for f in facts) \
                else None
            d = facts[0][1] if all(f[1] == facts[0][1] for f in facts) \
                else None
            merged[v] = (w, d)
        return tuple(sorted(
            (v, f) for v, f in merged.items() if f != (None, None)))

    cfg: CFG = build_cfg(fn)
    entry = run_forward(cfg, tuple(), transfer, join)
    findings: List[Finding] = []
    seen: set = set()
    for env, stmt in statement_states(cfg, entry, transfer):
        for expr in stmt_exprs(stmt):
            for call in walk_calls(expr):
                name = _final(attr_chain(call.func))
                if _key_contract_site(call):
                    w, d = _infer(call.args[0], env, layout)
                    key = (call.lineno, call.col_offset)
                    if w is not None and w != key_cols \
                            and key not in seen:
                        seen.add(key)
                        findings.append(ctx.finding(
                            call, NAME, f"{name}() is fed a [n, {w}] "
                            f"matrix; the key-matrix contract is "
                            f"[n, {key_cols}] (layout.KEY_COLS)"))
                    elif d == "f64" and key not in seen:
                        seen.add(key)
                        findings.append(ctx.finding(
                            call, NAME, f"{name}() key matrix must be "
                            "float32 (cache keys hash raw f32 bytes); "
                            "inferred float64"))
                elif name == "dvfs_solve_matrix" and call.args:
                    w, _d = _infer(call.args[0], env, layout)
                    ok = {key_cols, ncol}
                    if w is not None and w not in ok:
                        key = (call.lineno, call.col_offset)
                        if key not in seen:
                            seen.add(key)
                            findings.append(ctx.finding(
                                call, NAME, f"dvfs_solve_matrix() is fed a "
                                f"[n, {w}] matrix; it accepts "
                                f"[n, {key_cols}] keys or [n, {ncol}] "
                                "task rows"))
                elif name == "dvfs_solve_kernel" and call.args:
                    w, _d = _infer(call.args[0], env, layout)
                    ok = {ncol} | ({legacy} if isinstance(legacy, int)
                                   else set())
                    if w is not None and w not in ok:
                        key = (call.lineno, call.col_offset)
                        if key not in seen:
                            seen.add(key)
                            findings.append(ctx.finding(
                                call, NAME, f"dvfs_solve_kernel() is fed a "
                                f"[n, {w}] matrix; it accepts "
                                f"[n, {ncol}] (or legacy [n, {legacy}]) "
                                "task rows"))
    return findings


def check(ctx: Context) -> List[Finding]:
    mod = ctx.module or ""
    if mod not in SCHEMA_SCOPE:
        return []
    layout = layout_env()
    if not layout:
        return []
    findings: List[Finding] = []
    for fn in ast.walk(ctx.tree):
        if isinstance(fn, ast.FunctionDef):
            findings += _contract_findings(ctx, fn, layout)
    return findings
