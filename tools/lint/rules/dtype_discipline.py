"""dtype-discipline: kernel code states its dtypes.

The solver stack's bit-identity contracts (scalar/vector placement, dedup
transparency, the PR-1/4/5 energy goldens) all rest on every array in the
kernel path being f32 *on purpose*.  A dtype-less constructor silently
follows the jax x64 flag; an f64 literal upcasts a whole expression.  In
``repro.kernels`` (the schema module excepted — it holds no arrays):

* ``jnp/np.zeros|ones|full|empty(...)`` must pass a dtype (positionally or
  by keyword).  ``*_like`` constructors inherit and are fine.
* ``jnp/np.array|asarray([literal, ...])`` of a list/tuple literal must
  pass a dtype.
* ``float64``/``f64`` dtypes are flagged outright.
"""

from __future__ import annotations

import ast
from typing import List

from tools.lint import Context, Finding

NAME = "dtype-discipline"

#: constructor name -> index of its positional dtype argument.
_DTYPE_POS = {"zeros": 1, "ones": 1, "empty": 1, "full": 2,
              "array": 1, "asarray": 1}


def _attr_chain(node: ast.AST) -> str:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def check(ctx: Context) -> List[Finding]:
    mod = ctx.module or ""
    if not mod.startswith("repro.kernels") or mod == "repro.kernels.layout":
        return []
    findings: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Attribute) and node.attr == "float64":
            findings.append(ctx.finding(
                node, NAME, "float64 dtype in kernel code; the solver "
                "stack is f32 end to end"))
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if "." not in chain:
            continue
        base, fn = chain.rsplit(".", 1)
        if base not in {"jnp", "np", "numpy", "jax.numpy"}:
            continue
        if fn not in _DTYPE_POS:
            continue
        has_kw_dtype = any(kw.arg == "dtype" for kw in node.keywords)
        has_pos_dtype = len(node.args) > _DTYPE_POS[fn]
        if fn in {"array", "asarray"}:
            # Only literal payloads are in scope: converting an existing
            # array keeps its dtype, which is fine.
            if not (node.args
                    and isinstance(node.args[0], (ast.List, ast.Tuple))):
                continue
        if not (has_kw_dtype or has_pos_dtype):
            findings.append(ctx.finding(
                node, NAME, f"{chain}() without an explicit dtype in "
                "kernel code; state the dtype (f32 unless proven "
                "otherwise)"))
    return findings
