"""Rule registry: family name -> ``check(ctx) -> list[Finding]``.

Adding a rule family = writing a module with a ``NAME`` string and a
``check(ctx)`` function, then registering it here (see docs/LINTING.md).
The flow-sensitive families (pallas-hazard, async-protocol, shape-flow)
build on the CFG/dataflow framework in :mod:`tools.lint.flow`.
"""

from __future__ import annotations

from tools.lint.rules import (async_protocol, determinism, dtype_discipline,
                              layer_contract, matrix_schema, pallas_hazard,
                              shape_flow)

ALL_RULES = {
    layer_contract.NAME: layer_contract.check,
    matrix_schema.NAME: matrix_schema.check,
    determinism.NAME: determinism.check,
    dtype_discipline.NAME: dtype_discipline.check,
    pallas_hazard.NAME: pallas_hazard.check,
    async_protocol.NAME: async_protocol.check,
    shape_flow.NAME: shape_flow.check,
}
