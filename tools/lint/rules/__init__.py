"""Rule registry: family name -> ``check(ctx) -> list[Finding]``.

Adding a rule family = writing a module with a ``NAME`` string and a
``check(ctx)`` function, then registering it here (see docs/LINTING.md).
"""

from __future__ import annotations

from tools.lint.rules import (determinism, dtype_discipline, layer_contract,
                              matrix_schema)

ALL_RULES = {
    layer_contract.NAME: layer_contract.check,
    matrix_schema.NAME: matrix_schema.check,
    determinism.NAME: determinism.check,
    dtype_discipline.NAME: dtype_discipline.check,
}
