"""async-protocol: AsyncSolve handle lifecycle + prefetch-window discipline.

The pipelined online driver's bit-identity guarantee rests on a protocol
that used to be enforced by ``# lint: prefetch-region`` comment markers.
This family retires the markers and proves the same contracts by dataflow
over the CFG of every function in ``repro.core.online`` /
``repro.core.solver_cache`` / ``repro.core.machines`` /
``repro.core.single_task``:

* **Handle lifecycle** — a variable assigned from a dispatcher call
  (``solve_rows_async`` / ``configure_classes_async``) must reach exactly
  one consumption on every path.  States per variable (a may-set, union
  join): LIVE (dispatched), NONE (the ``... if cond else None`` arm),
  CONSUMED (``.result()`` called, or passed to a ``*_sync`` call), ESCAPED
  (stored into a container/attribute, returned, or passed to a non-sync
  call — ownership transferred, tracking stops).  Flagged: a handle that
  can only be LIVE at function exit (dropped — its solve result is
  discarded and the cache never filled), a second consumption of a
  possibly-CONSUMED handle, and rebinding a name while a LIVE handle may
  still be in it.
* **Blocking calls in the prefetch window** — from any dispatch point
  (a dispatcher call, or a value-discarded ``.dispatch(...)`` method call)
  to the end of the function, work may be in flight (may-analysis, no
  kill: consuming one handle proves nothing about the others).  Blocking
  host<->device calls there (``np.asarray`` / ``jnp.asarray`` /
  ``jax.device_get`` / ``.block_until_ready()``) stall the overlap and are
  flagged — except inside ``*_sync``-named functions, whose suffix is the
  documented license to materialize.
* **Stale full-horizon view reads** — between a handle-producing dispatch
  (``h = state.dispatch(...)``) and its sync point, the full-horizon views
  (``.cfgs`` / ``.order_cls``, and the chunk-context readers
  ``update_tasks`` / ``prepare_chunk``) are stale for the dispatched span.
  A must-analysis (intersection join — flagged only when it holds on every
  path) marks the window dirty at an unconditional handle-producing
  assignment and clean at a sync call (``.result()`` / any ``*_sync``
  call); view reads in a dirty window are flagged.
* **Retired markers** — any surviving ``prefetch-region-begin/-end``
  comment is itself an error: the guarantee is derived from the code now,
  and a marker would suggest otherwise.
"""

from __future__ import annotations

import ast
from typing import Dict, FrozenSet, List, Optional, Tuple

from tools.lint import Context, Finding
from tools.lint.flow import (
    CFG, attr_chain, build_cfg, run_forward, statement_states, stmt_exprs,
    walk_calls,
)

NAME = "async-protocol"

_SCOPE = (
    "repro.core.online",
    "repro.core.solver_cache",
    "repro.core.machines",
    "repro.core.single_task",
)

#: Calls that create an AsyncSolve-protocol handle (matched on the final
#: attribute, so both ``solver_cache.solve_rows_async`` and a bare
#: ``solve_rows_async`` count).
_DISPATCHERS = {"solve_rows_async", "configure_classes_async"}

_BLOCKING_CALLS = {"np.asarray", "numpy.asarray", "jnp.asarray",
                   "jax.numpy.asarray", "jax.device_get"}

#: Full-horizon view attributes and chunk-context reader methods that must
#: not be read while a dispatched span is unconsumed.
_VIEW_ATTRS = {"cfgs", "order_cls"}
_VIEW_READERS = {"update_tasks", "prepare_chunk"}

# Lifecycle lattice elements (per-variable may-sets of these).
LIVE, NONE, CONSUMED, ESCAPED = "live", "none", "consumed", "escaped"

_LifeState = FrozenSet[Tuple[str, str]]  # {(var, element)}


def _final_name(func: ast.expr) -> str:
    chain = attr_chain(func) or ""
    return chain.rsplit(".", 1)[-1]


def _dispatch_kind(value: ast.expr) -> Optional[str]:
    """LIVE for a direct dispatcher call, NONE-able LIVE for the
    ``dispatch() if cond else None`` idiom, else None."""
    if isinstance(value, ast.Call) and _final_name(value.func) \
            in _DISPATCHERS:
        return LIVE
    if isinstance(value, ast.IfExp):
        a = _dispatch_kind(value.body)
        b = _dispatch_kind(value.orelse)
        none_arm = (isinstance(value.body, ast.Constant)
                    and value.body.value is None) or \
                   (isinstance(value.orelse, ast.Constant)
                    and value.orelse.value is None)
        if (a or b) and none_arm:
            return "maybe"
    return None


# ---------------------------------------------------------------------------
# Handle lifecycle
# ---------------------------------------------------------------------------

def _var_states(state: _LifeState, var: str) -> FrozenSet[str]:
    return frozenset(e for v, e in state if v == var)


def _set_var(state: _LifeState, var: str,
             elems: FrozenSet[str]) -> _LifeState:
    return frozenset({(v, e) for v, e in state if v != var}
                     | {(var, e) for e in elems})


def _map_var(state: _LifeState, var: str, frm: str, to: str) -> _LifeState:
    cur = _var_states(state, var)
    if frm not in cur:
        return state
    return _set_var(state, var, (cur - {frm}) | {to})


def _consumes(call: ast.Call, var: str) -> bool:
    """Does this call consume ``var``? — ``var.result()`` or ``var`` passed
    to a ``*_sync``-named callable."""
    if isinstance(call.func, ast.Attribute) and call.func.attr == "result" \
            and isinstance(call.func.value, ast.Name) \
            and call.func.value.id == var:
        return True
    if _final_name(call.func).endswith("_sync"):
        for arg in call.args + [k.value for k in call.keywords]:
            if isinstance(arg, ast.Name) and arg.id == var:
                return True
    return False


def _escapes(stmt: ast.stmt, var: str) -> bool:
    """Ownership transfer: ``var`` returned/yielded, stored into a
    container/tuple/attribute/subscript, or passed to a call that is not a
    sync point (e.g. ``batches.append((.., var))``, ``ClassSolves(
    stacked=var)``)."""
    for expr in stmt_exprs(stmt):
        for node in ast.walk(expr):
            if isinstance(node, (ast.Tuple, ast.List, ast.Set, ast.Dict)):
                for elt in ast.walk(node):
                    if isinstance(elt, ast.Name) and elt.id == var \
                            and elt is not node:
                        return True
            if isinstance(node, ast.Call) and not _consumes(node, var):
                for arg in node.args + [k.value for k in node.keywords]:
                    if isinstance(arg, ast.Name) and arg.id == var:
                        return True
    if isinstance(stmt, (ast.Return,)) and stmt.value is not None:
        for node in ast.walk(stmt.value):
            if isinstance(node, ast.Name) and node.id == var:
                return True
    if isinstance(stmt, ast.Assign):
        for tgt in stmt.targets:
            if not isinstance(tgt, ast.Name):
                for node in ast.walk(tgt):
                    if isinstance(node, ast.Name) and node.id == var:
                        pass  # var as a *target* base is a write, not escape
        if isinstance(stmt.value, ast.Name) and stmt.value.id == var:
            return True  # aliased into another name: stop tracking
    return False


def _lifecycle_findings(ctx: Context, fn: ast.FunctionDef) -> List[Finding]:
    # Only analyse functions that dispatch at least once.
    creation: Dict[str, ast.stmt] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and _dispatch_kind(node.value) is not None:
            creation[node.targets[0].id] = node
    if not creation:
        return []
    tracked = set(creation)

    def transfer(state: _LifeState, stmt: ast.stmt) -> _LifeState:
        # Consumption / escape first (RHS evaluates before rebinding).
        for expr in stmt_exprs(stmt):
            for call in walk_calls(expr):
                for var in tracked:
                    if _consumes(call, var):
                        state = _map_var(state, var, LIVE, CONSUMED)
                        state = _map_var(state, var, "maybe", CONSUMED)
        for var in tracked:
            if _escapes(stmt, var):
                cur = _var_states(state, var)
                if cur & {LIVE, "maybe"}:
                    state = _set_var(
                        state, var, (cur - {LIVE, "maybe"}) | {ESCAPED})
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name) \
                and stmt.targets[0].id in tracked:
            var = stmt.targets[0].id
            kind = _dispatch_kind(stmt.value)
            if kind == LIVE:
                state = _set_var(state, var, frozenset({LIVE}))
            elif kind == "maybe":
                state = _set_var(state, var, frozenset({LIVE, NONE}))
            else:
                state = _set_var(state, var, frozenset())
        return state

    def join(states: List[_LifeState]) -> _LifeState:
        out: set = set()
        for s in states:
            out |= s
        return frozenset(out)

    cfg: CFG = build_cfg(fn)
    entry = run_forward(cfg, frozenset(), transfer, join)

    findings: List[Finding] = []
    seen: set = set()

    def flag(node: ast.AST, key: tuple, msg: str) -> None:
        if key not in seen:
            seen.add(key)
            findings.append(ctx.finding(node, NAME, msg))

    for state, stmt in statement_states(cfg, entry, transfer):
        # Double-consume: consuming a possibly-already-consumed handle.
        for expr in stmt_exprs(stmt):
            for call in walk_calls(expr):
                for var in tracked:
                    if _consumes(call, var):
                        cur = _var_states(state, var)
                        if CONSUMED in cur and ESCAPED not in cur:
                            flag(call, ("dbl", var, call.lineno),
                                 f"handle {var} may already be consumed "
                                 "here (result() memoizes, but a second "
                                 "sync point hides a protocol bug)")
        # Rebinding a name that may still hold a live handle.
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name) \
                and stmt.targets[0].id in tracked:
            var = stmt.targets[0].id
            cur = _var_states(state, var)
            consumed_here = any(
                _consumes(call, var)
                for expr in stmt_exprs(stmt) for call in walk_calls(expr))
            if LIVE in cur and not consumed_here \
                    and not _escapes(stmt, var):
                flag(stmt, ("over", var, stmt.lineno),
                     f"{var} is rebound while it may still hold a live "
                     "unconsumed handle — the in-flight solve is dropped")

    # Dropped handles: only-LIVE (never consumed, never escaped) at exit.
    exit_states = [
        _block_exit_state(cfg, bid, entry, transfer)
        for bid in cfg.preds(cfg.exit)]
    merged: Dict[str, set] = {v: set() for v in tracked}
    for st in exit_states:
        if st is None:
            continue
        for v, e in st:
            if v in merged:
                merged[v].add(e)
    for var, elems in merged.items():
        if LIVE in elems and CONSUMED not in elems and ESCAPED not in elems:
            flag(creation[var], ("drop", var),
                 f"handle {var} is dispatched but never reaches result()/"
                 "a *_sync consumer on any path — the solve result is "
                 "dropped and the cache is never filled")
    return findings


def _block_exit_state(cfg: CFG, bid: int, entry: Dict[int, object],
                      transfer) -> Optional[_LifeState]:
    state = entry.get(bid)
    if state is None:
        return None
    for stmt in cfg.blocks[bid].stmts:
        state = transfer(state, stmt)
    return state  # type: ignore[return-value]


# ---------------------------------------------------------------------------
# Blocking calls in the prefetch window (may-analysis)
# ---------------------------------------------------------------------------

def _opens_window(stmt: ast.stmt) -> bool:
    for expr in stmt_exprs(stmt):
        for call in walk_calls(expr):
            if _final_name(call.func) in _DISPATCHERS:
                return True
            if isinstance(call.func, ast.Attribute) \
                    and call.func.attr == "dispatch":
                return True
    return False


def _blocking_calls(stmt: ast.stmt) -> List[Tuple[ast.Call, str]]:
    out = []
    for expr in stmt_exprs(stmt):
        for call in walk_calls(expr):
            chain = attr_chain(call.func) or ""
            if chain in _BLOCKING_CALLS:
                out.append((call, f"{chain}()"))
            elif isinstance(call.func, ast.Attribute) \
                    and call.func.attr == "block_until_ready":
                out.append((call, ".block_until_ready()"))
    return out


def _window_findings(ctx: Context, fn: ast.FunctionDef) -> List[Finding]:
    if fn.name.endswith("_sync"):
        return []

    def transfer(state: bool, stmt: ast.stmt) -> bool:
        return state or _opens_window(stmt)

    cfg: CFG = build_cfg(fn)
    entry = run_forward(cfg, False, transfer, lambda xs: any(xs))
    findings: List[Finding] = []
    seen: set = set()
    for state, stmt in statement_states(cfg, entry, transfer):
        if not state:
            continue
        for call, label in _blocking_calls(stmt):
            key = (call.lineno, call.col_offset)
            if key in seen:
                continue
            seen.add(key)
            findings.append(ctx.finding(
                call, NAME, f"{label} blocks on device results while a "
                "dispatched solve batch may be in flight; materialize only "
                "inside a *_sync method so the prefetch keeps overlapping "
                "placement"))
    return findings


# ---------------------------------------------------------------------------
# Stale full-horizon view reads (must-analysis)
# ---------------------------------------------------------------------------

def _is_handle_call(expr: ast.expr) -> bool:
    if not isinstance(expr, ast.Call):
        return False
    if _final_name(expr.func) in _DISPATCHERS:
        return True
    return isinstance(expr.func, ast.Attribute) \
        and expr.func.attr == "dispatch"


def _produces_handle(stmt: ast.stmt) -> bool:
    """Handle-producing assignment: ``h = state.dispatch(..)``, a direct
    dispatcher call, or either arm of the ``dispatch(..) if c else None``
    idiom.  A bare ``obj.dispatch(..)`` expression statement does NOT count
    — it returns no handle, so no view depends on consuming it (the
    deferred-readjust queue)."""
    if not isinstance(stmt, ast.Assign):
        return False
    value = stmt.value
    if isinstance(value, ast.IfExp):
        return _is_handle_call(value.body) or _is_handle_call(value.orelse)
    return _is_handle_call(value)


def _syncs(stmt: ast.stmt) -> bool:
    for expr in stmt_exprs(stmt):
        for call in walk_calls(expr):
            name = _final_name(call.func)
            if name == "result" or name.endswith("_sync"):
                return True
    return False


def _view_reads(stmt: ast.stmt) -> List[Tuple[ast.AST, str]]:
    out: List[Tuple[ast.AST, str]] = []
    for expr in stmt_exprs(stmt):
        for node in ast.walk(expr):
            if isinstance(node, ast.Attribute) \
                    and isinstance(node.ctx, ast.Load) \
                    and node.attr in _VIEW_ATTRS:
                out.append((node, f".{node.attr}"))
            elif isinstance(node, ast.Call) \
                    and _final_name(node.func) in _VIEW_READERS:
                out.append((node, f"{_final_name(node.func)}()"))
    return out


def _view_findings(ctx: Context, fn: ast.FunctionDef) -> List[Finding]:
    if fn.name.endswith("_sync"):
        return []

    def transfer(state: bool, stmt: ast.stmt) -> bool:
        if _syncs(stmt):
            return False
        if _produces_handle(stmt):
            return True
        return state

    def join(states: List[bool]) -> bool:
        return all(states)  # must: dirty only if dirty on every path

    cfg: CFG = build_cfg(fn)
    entry = run_forward(cfg, False, transfer, join)
    findings: List[Finding] = []
    seen: set = set()
    for state, stmt in statement_states(cfg, entry, transfer):
        if not state or _syncs(stmt):
            continue
        for node, label in _view_reads(stmt):
            key = (node.lineno, node.col_offset)
            if key in seen:
                continue
            seen.add(key)
            findings.append(ctx.finding(
                node, NAME, f"{label} reads a full-horizon view between a "
                "dispatch and its sync point — the dispatched span is "
                "stale until consume_sync/result() lands it"))
    return findings


# ---------------------------------------------------------------------------
# Retired markers
# ---------------------------------------------------------------------------

def _marker_findings(ctx: Context) -> List[Finding]:
    findings = []
    for i, line in enumerate(ctx.lines, start=1):
        if "prefetch-region-begin" in line or "prefetch-region-end" in line:
            findings.append(Finding(
                path=ctx.path, line=i, col=0, rule=NAME,
                message="retired prefetch-region marker: the window is "
                        "derived by async-protocol dataflow now — delete "
                        "the comment"))
    return findings


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def check(ctx: Context) -> List[Finding]:
    mod = ctx.module or ""
    if not mod.startswith(_SCOPE):
        return []
    findings = _marker_findings(ctx)
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, (ast.FunctionDef,)):
            continue
        findings += _lifecycle_findings(ctx, fn)
        findings += _window_findings(ctx, fn)
        findings += _view_findings(ctx, fn)
    return findings
