"""matrix-schema: no raw integer column indices into the solver matrices.

Three hand-synchronized layouts flow through the solver stack — the
``[n, NCOL]`` task matrix, the ``[n, KEY_COLS]`` cache-key matrix and the
``[n, SOL_COLS]`` solution matrix.  :mod:`repro.kernels.layout` is the one
place their columns are declared; everywhere else a literal column number
(``rows[:, 5]``, ``mat[:, 8:13]``, ``(n, 5)`` widths are NOT flagged —
only subscripts) is a silent-drift hazard: the layouts once disagreed
between ``core/single_task.py`` and the kernel until PR 8 unified them.

Scope: the solver-stack modules that actually touch these matrices
(:data:`SCHEMA_SCOPE`).  Flagged: a 2-D subscript whose column position
(second tuple element) is a non-negative integer literal or a slice with
integer-literal endpoints.  Column reads through ``layout.*`` names,
variables, or ``None``/negative indices are fine.  A genuinely non-schema
2-D read in scope (e.g. the span grouping in ``core/cluster.py``) carries
an inline ``# lint: disable=matrix-schema`` with a why-comment.
"""

from __future__ import annotations

import ast
from typing import List

from tools.lint import Context, Finding

NAME = "matrix-schema"

#: Modules whose 2-D subscripts are solver-matrix column reads.
SCHEMA_SCOPE = frozenset({
    "repro.kernels.dvfs_opt",
    "repro.kernels.ops",
    "repro.kernels.ref",
    "repro.core.solver_cache",
    "repro.core.single_task",
    "repro.core.machines",
    "repro.core.bounds",
    "repro.core.cluster",
    "repro.core.dvfs",
})


def _is_int_literal(node: ast.AST) -> bool:
    return (isinstance(node, ast.Constant) and isinstance(node.value, int)
            and not isinstance(node.value, bool) and node.value >= 0)


def _column_literal(node: ast.AST) -> bool:
    """True if a subscript tuple's column slot is a literal column index."""
    if _is_int_literal(node):
        return True
    if isinstance(node, ast.Slice):
        return any(_is_int_literal(p) for p in (node.lower, node.upper))
    return False


def check(ctx: Context) -> List[Finding]:
    if ctx.module not in SCHEMA_SCOPE:
        return []
    findings: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Subscript):
            continue
        sl = node.slice
        if isinstance(sl, ast.Tuple) and len(sl.elts) >= 2:
            colslot = sl.elts[1]
            if _column_literal(colslot):
                findings.append(ctx.finding(
                    node, NAME,
                    "raw integer column index into a solver matrix; use "
                    "the named columns in repro.kernels.layout"))
    return findings
