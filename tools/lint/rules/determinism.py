"""determinism: library code must be replayable bit-for-bit.

Every schedule, fault trace and benchmark in this repo is pinned by golden
values, so library code may not consult ambient entropy or wall clocks,
and Pallas kernel bodies may not collapse traced values to Python scalars
(that either crashes under tracing or silently freezes a traced value at
trace time).  Four checks:

* **Unseeded / global-state RNG** (all of ``repro``): calls to the legacy
  ``np.random.<fn>`` global API, to ``np.random.default_rng()`` with no
  seed, or to stdlib ``random.<fn>`` (except ``random.Random(seed)``).
* **Wall-clock reads** (``repro.core`` + ``repro.kernels``): ``time.time``
  / ``time.time_ns`` / ``datetime.now`` — scheduler math must never read
  the host clock (``time.perf_counter`` in benchmarks/launch is out of
  scope by construction).
* **Mutable default arguments** (``repro.core``): a ``def f(x=[])`` default
  is shared across calls — state that survives between scheduler runs.
* **Traced-value misuse in kernel bodies** (``repro.kernels``): inside a
  Pallas kernel (a function with ``*_ref`` parameters), values read from
  the refs are traced; ``float()``/``int()``/``bool()``/``.item()`` on
  them, or ``if``/``while`` on a condition derived from them, is flagged.
  Static Python conditionals on non-traced closure values (e.g.
  ``if causal:``) are fine — taint starts at the ref reads only.

The blocking-sync-inside-the-prefetch-region check that used to live here
(driven by ``# lint: prefetch-region-begin/-end`` comment markers) is
retired: the ``async-protocol`` family now derives the prefetch window by
dataflow from the dispatch sites themselves, and flags any surviving
marker as an error.
"""

from __future__ import annotations

import ast
from typing import List, Set

from tools.lint import Context, Finding

NAME = "determinism"

_LEGACY_OK = {"default_rng", "Generator", "SeedSequence", "BitGenerator",
              "Philox", "PCG64"}


def _attr_chain(node: ast.AST) -> str:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _check_rng(ctx: Context) -> List[Finding]:
    findings = []
    has_stdlib_random = any(
        isinstance(n, ast.Import) and any(a.name == "random"
                                          for a in n.names)
        for n in ast.walk(ctx.tree))
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if chain.startswith(("np.random.", "numpy.random.")):
            fn = chain.rsplit(".", 1)[1]
            if fn == "default_rng" and not node.args and not node.keywords:
                findings.append(ctx.finding(
                    node, NAME, "np.random.default_rng() without a seed: "
                    "results are not replayable — pass an explicit seed"))
            elif fn not in _LEGACY_OK:
                findings.append(ctx.finding(
                    node, NAME, f"legacy global-state RNG {chain}(); use a "
                    "seeded np.random.default_rng(seed) Generator"))
        elif (has_stdlib_random and chain.startswith("random.")
              and chain != "random.Random"):
            findings.append(ctx.finding(
                node, NAME, f"stdlib {chain}() draws from the global RNG; "
                "use a seeded np.random.default_rng(seed)"))
    return findings


_CLOCKS = {"time.time", "time.time_ns", "datetime.now",
           "datetime.datetime.now", "datetime.utcnow",
           "datetime.datetime.utcnow"}


def _check_clock(ctx: Context) -> List[Finding]:
    findings = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call) and _attr_chain(node.func) in _CLOCKS:
            findings.append(ctx.finding(
                node, NAME, f"{_attr_chain(node.func)}() reads the host "
                "wall clock inside scheduler library code"))
    return findings


_MUTABLE_CALLS = {"list", "dict", "set"}


def _check_mutable_defaults(ctx: Context) -> List[Finding]:
    findings = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        defaults = list(node.args.defaults) + [
            d for d in node.args.kw_defaults if d is not None]
        for d in defaults:
            mutable = isinstance(d, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(d, ast.Call) and isinstance(d.func, ast.Name)
                and d.func.id in _MUTABLE_CALLS)
            if mutable:
                findings.append(ctx.finding(
                    d, NAME, f"mutable default argument in {node.name}(); "
                    "defaults are evaluated once and shared across calls"))
    return findings


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _check_kernel_bodies(ctx: Context) -> List[Finding]:
    findings = []
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, ast.FunctionDef):
            continue
        refs = {a.arg for a in fn.args.args + fn.args.kwonlyargs
                if a.arg.endswith("_ref")}
        if not refs:
            continue
        tainted = set(refs)
        for stmt in ast.walk(fn):
            # Propagate taint through assignments, in source order (ast.walk
            # is BFS over the function, close enough for straight-line
            # kernel bodies where defs precede uses).
            if isinstance(stmt, ast.Assign):
                if _names_in(stmt.value) & tainted:
                    for tgt in stmt.targets:
                        tainted |= _names_in(tgt)
            elif isinstance(stmt, ast.AugAssign):
                if _names_in(stmt.value) & tainted:
                    tainted |= _names_in(stmt.target)
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                chain = _attr_chain(node.func)
                if (chain in {"float", "int", "bool"} and node.args
                        and _names_in(node.args[0]) & tainted):
                    findings.append(ctx.finding(
                        node, NAME, f"{chain}() on a traced value inside a "
                        "Pallas kernel body freezes/crashes under tracing"))
                elif (isinstance(node.func, ast.Attribute)
                      and node.func.attr == "item"
                      and _names_in(node.func.value) & tainted):
                    findings.append(ctx.finding(
                        node, NAME, ".item() on a traced value inside a "
                        "Pallas kernel body"))
            elif isinstance(node, (ast.If, ast.While)):
                if _names_in(node.test) & tainted:
                    findings.append(ctx.finding(
                        node, NAME, "Python control flow on a traced value "
                        "inside a Pallas kernel body; use jnp.where / "
                        "jax.lax primitives"))
            elif isinstance(node, ast.Assert):
                if _names_in(node.test) & tainted:
                    findings.append(ctx.finding(
                        node, NAME, "assert on a traced value inside a "
                        "Pallas kernel body"))
    return findings


def check(ctx: Context) -> List[Finding]:
    mod = ctx.module or ""
    if not mod.startswith("repro"):
        return []
    findings = _check_rng(ctx)
    if mod.startswith(("repro.core", "repro.kernels")):
        findings += _check_clock(ctx)
    if mod.startswith("repro.core"):
        findings += _check_mutable_defaults(ctx)
    if mod.startswith("repro.kernels"):
        findings += _check_kernel_bodies(ctx)
    return findings
