"""``python -m tools.lint`` entry point."""

import sys

from tools.lint import main

sys.exit(main())
