"""Differential mutation corpus: prove the flow-sensitive rules catch bugs.

A linter that never fires is indistinguishable from one that cannot fire.
This module seeds ~a dozen realistic hazard/protocol/shape mutations into
*copies* of the real kernel and scheduler sources (the files the rules
exist to protect), lints each mutant in-memory, and asserts that exactly
the expected rule family flags it — and that the pristine file is clean,
so the mutation is provably what trips the rule.

Run directly (``python -m tools.lint.selfcheck``; exit 0 = every mutant
caught) or via the parametrized test in ``tests/test_lint.py``.  CI runs
both.  When a rule is refactored, a mutant going silently uncaught fails
the gate — the corpus is the rule suite's own regression harness.
"""

from __future__ import annotations

import dataclasses
import sys
from pathlib import Path
from typing import List, Optional, Tuple

from tools.lint import Finding, lint_source, module_name_for

REPO_ROOT = Path(__file__).resolve().parents[2]


@dataclasses.dataclass(frozen=True)
class Mutation:
    """One seeded bug: replace ``old`` (must occur exactly once) with
    ``new`` in ``path``; the lint must report ``rule`` with a message
    containing ``expect`` (a stable substring identifying the check)."""

    name: str
    path: str           # repo-relative source file to mutate
    old: str
    new: str
    rule: str
    expect: str


MUTATIONS: Tuple[Mutation, ...] = (
    # ---- pallas-hazard ---------------------------------------------------
    Mutation(
        name="ssd-load-after-store",
        path="src/repro/kernels/ssd_scan.py",
        old=("    cs = jax.lax.dot_general(cm, state_ref[...], "
             "(((1,), (1,)), ((), ())),"),
        new=("    state_ref[...] = state_ref[...] * 2.0\n"
             "    cs = jax.lax.dot_general(cm, state_ref[...], "
             "(((1,), (1,)), ((), ())),"),
        rule="pallas-hazard",
        expect="read-after-write",
    ),
    Mutation(
        name="dvfs-partial-store-after-load",
        path="src/repro/kernels/dvfs_opt.py",
        old="    out_ref[...] = out.astype(out_ref.dtype)",
        new=("    tasks_ref[:, col(READJUST)] = t[:, col(READJUST)]\n"
             "    out_ref[...] = out.astype(out_ref.dtype)"),
        rule="pallas-hazard",
        expect="write-after-read",
    ),
    Mutation(
        name="dvfs-store-to-input-ref",
        path="src/repro/kernels/dvfs_opt.py",
        old="    out_ref[...] = out.astype(out_ref.dtype)",
        new="    tasks_ref[...] = out.astype(out_ref.dtype)",
        rule="pallas-hazard",
        expect="store to input ref",
    ),
    Mutation(
        name="dvfs-widen-column-slice",
        path="src/repro/kernels/dvfs_opt.py",
        old="    allowed = t[:, col(ALLOWED)]",
        new="    allowed = t[:, ALLOWED:FM_MIN]",
        rule="pallas-hazard",
        expect="crosses a layout.py column-group boundary",
    ),
    Mutation(
        name="dvfs-out-of-bounds-column",
        path="src/repro/kernels/dvfs_opt.py",
        old="                              t[:, col(FM_MAX)])",
        new="                              t[:, col(NCOL)])",
        rule="pallas-hazard",
        expect="out of bounds",
    ),
    # ---- async-protocol --------------------------------------------------
    Mutation(
        name="cache-drop-result",
        path="src/repro/core/solver_cache.py",
        old=("    return solve_rows_async(keys, solver_fn, tag=tag, "
             "cache=cache).result()"),
        new=("    handle = solve_rows_async(keys, solver_fn, tag=tag, "
             "cache=cache)\n"
             "    return None"),
        rule="async-protocol",
        expect="never reaches result()",
    ),
    Mutation(
        name="cache-double-consume",
        path="src/repro/core/solver_cache.py",
        old=("    return solve_rows_async(keys, solver_fn, tag=tag, "
             "cache=cache).result()"),
        new=("    handle = solve_rows_async(keys, solver_fn, tag=tag, "
             "cache=cache)\n"
             "    handle.result()\n"
             "    return handle.result()"),
        rule="async-protocol",
        expect="already be consumed",
    ),
    Mutation(
        name="online-blocking-in-window",
        path="src/repro/core/online.py",
        old="        readj.dispatch(pending)",
        new=("        readj.dispatch(pending)\n"
             "        _probe = np.asarray(pending)"),
        rule="async-protocol",
        expect="blocks on device results",
    ),
    Mutation(
        name="online-view-read-before-sync",
        path="src/repro/core/online.py",
        old=("            state.consume_sync(handle, spans[j])\n"
             "            if vector:\n"
             "                ctx.update_tasks(spans[j])"),
        new=("            if vector:\n"
             "                ctx.update_tasks(spans[j])\n"
             "            state.consume_sync(handle, spans[j])"),
        rule="async-protocol",
        expect="full-horizon view",
    ),
    # ---- shape-flow ------------------------------------------------------
    Mutation(
        name="machines-truncated-key-matrix",
        path="src/repro/core/machines.py",
        old=("    handle = solver_cache.solve_rows_async(\n"
             "        keys, lambda km: kernel_ops.dvfs_solve_matrix(km, "
             "block=False),"),
        new=("    handle = solver_cache.solve_rows_async(\n"
             "        keys[:, :layout.LEGACY_NCOL],\n"
             "        lambda km: kernel_ops.dvfs_solve_matrix(km, "
             "block=False),"),
        rule="shape-flow",
        expect="key-matrix contract",
    ),
    Mutation(
        name="single-task-params-only-keys",
        path="src/repro/core/single_task.py",
        old=("    return solver_cache.solve_rows_async(keys, solve, "
             "tag=tag, cache=cache,\n"
             "                                         unique=False)"),
        new=("    return solver_cache.solve_rows_async(\n"
             "        keys[:, layout.PARAMS_SLICE], solve, tag=tag, "
             "cache=cache, unique=False)"),
        rule="shape-flow",
        expect="key-matrix contract",
    ),
    # ---- unused-suppression ----------------------------------------------
    Mutation(
        name="cluster-stale-suppression",
        path="src/repro/core/cluster.py",
        old="# lint: disable=matrix-schema",
        new="# lint: disable=dtype-discipline",
        rule="unused-suppression",
        expect="does not suppress any finding",
    ),
)


def apply(mutation: Mutation, root: Path = REPO_ROOT) -> str:
    """Mutated source text; raises if the anchor is missing/ambiguous."""
    source = (root / mutation.path).read_text()
    n = source.count(mutation.old)
    if n != 1:
        raise AssertionError(
            f"{mutation.name}: anchor occurs {n} times in {mutation.path} "
            "(expected exactly 1) — the corpus drifted from the source; "
            "re-anchor it")
    return source.replace(mutation.old, mutation.new, 1)


def run_one(mutation: Mutation,
            root: Path = REPO_ROOT) -> Tuple[bool, List[Finding]]:
    """(caught, findings-of-the-expected-rule) for one mutant."""
    path = mutation.path
    mutated = apply(mutation, root)
    findings = lint_source(mutated, path,
                           module=module_name_for(Path(path)))
    hits = [f for f in findings
            if f.rule == mutation.rule and mutation.expect in f.message]
    return bool(hits), findings


def baseline_clean(mutation: Mutation, root: Path = REPO_ROOT) -> bool:
    """The pristine file produces no finding matching the expectation, so
    the mutation is what trips the rule."""
    path = mutation.path
    source = (root / path).read_text()
    findings = lint_source(source, path,
                           module=module_name_for(Path(path)))
    return not any(f.rule == mutation.rule and mutation.expect in f.message
                   for f in findings)


def main(argv: Optional[List[str]] = None) -> int:
    failures = 0
    for m in MUTATIONS:
        if not baseline_clean(m):
            print(f"FAIL {m.name}: pristine {m.path} already matches "
                  f"[{m.rule}] {m.expect!r}")
            failures += 1
            continue
        caught, findings = run_one(m)
        if caught:
            print(f"ok   {m.name}: caught by [{m.rule}]")
        else:
            print(f"FAIL {m.name}: mutation NOT caught; findings were:")
            for f in findings:
                print(f"     {f.render()}")
            failures += 1
    total = len(MUTATIONS)
    print(f"{total - failures}/{total} mutations caught")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
