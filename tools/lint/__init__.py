"""repro-lint: repo-specific static analysis for the scheduler stack.

Seven AST-based rule families (stdlib ``ast`` only, no third-party deps).
The first four are per-line matchers; the last three are flow-sensitive —
they run on the per-function CFG + forward-dataflow framework in
:mod:`tools.lint.flow`:

* ``layer-contract``    — enforce the docs/ARCHITECTURE.md import DAG
                          (:mod:`tools.lint.layer_dag`) and forbid
                          cross-module imports of ``_private`` names;
* ``matrix-schema``     — forbid raw integer column indices into the
                          solver matrices outside
                          :mod:`repro.kernels.layout`;
* ``determinism``       — forbid unseeded RNG and wall-clock reads in
                          library code, mutable default arguments in
                          ``repro.core``, and Python control flow /
                          scalarization on traced values inside Pallas
                          kernel bodies;
* ``dtype-discipline``  — forbid dtype-less array constructors and
                          non-f32 dtypes in kernel code;
* ``pallas-hazard``     — ref load/store hazard analysis of Pallas kernel
                          bodies (RAW/WAR on overlapping slices, stores to
                          input refs, out-of-bounds / group-crossing
                          column slices resolved through ``layout.py``);
* ``async-protocol``    — AsyncSolve handle lifecycle (consumed exactly
                          once on every path), blocking calls inside the
                          dataflow-derived prefetch window, stale
                          full-horizon view reads before the sync point;
* ``shape-flow``        — symbolic [n, width]/dtype inference proving
                          every key matrix fed to ``solve_rows`` is
                          ``[n, KEY_COLS]`` f32 and kernel entries get
                          declared task-matrix widths.

Run with ``python -m tools.lint`` (see ``--help``).  A finding on a line
carrying ``# lint: disable=<rule>[,<rule>...]`` (or ``disable=all``) is
suppressed; every suppression should say why on the same or previous line.
Suppressions are read from real comment tokens only, and a suppression
that suppresses nothing is itself an error (``unused-suppression``,
checked on full runs — i.e. when ``--select`` is not narrowing the rule
set).
"""

from __future__ import annotations

import ast
import dataclasses
import io
import json
import re
import sys
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set

__all__ = ["Finding", "Context", "lint_source", "lint_paths", "main",
           "ALL_RULES"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint violation, pointing at ``path:line:col``."""

    path: str
    line: int
    col: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Context:
    """Everything a rule's ``check`` receives for one file."""

    path: str            # as given (display + suppression lookup)
    module: Optional[str]  # dotted module name, e.g. "repro.core.engine"
    source: str
    tree: ast.Module
    lines: List[str]

    def finding(self, node: ast.AST, rule: str, message: str) -> Finding:
        return Finding(self.path, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), rule, message)


def module_name_for(path: Path) -> Optional[str]:
    """Dotted module name for a repo file: ``src/repro/core/engine.py`` ->
    ``repro.core.engine``; files outside a known package root -> None."""
    parts = list(path.parts)
    for root in ("repro", "tools"):
        if root in parts:
            rel = parts[parts.index(root):]
            if rel[-1] == "__init__.py":
                rel = rel[:-1]
            elif rel[-1].endswith(".py"):
                rel[-1] = rel[-1][:-3]
            return ".".join(rel)
    return None


_SUPPRESS_RE = re.compile(r"#\s*lint:\s*disable=([A-Za-z0-9_,\- ]+)")

#: Meta-rule id for suppressions that suppress nothing (see lint_source).
UNUSED_SUPPRESSION = "unused-suppression"


def _suppressions(source: str,
                  lines: Sequence[str]) -> Dict[int, Set[str]]:
    """1-based line -> set of suppressed rule names (or {"all"}).

    Only real COMMENT tokens count — a ``# lint: disable=...`` inside a
    docstring or string literal is prose, not a suppression (and must not
    trip the unused-suppression check).  Falls back to a line scan if the
    source does not tokenize (lint_source already survived ast.parse, so
    this is belt-and-braces).
    """
    out: Dict[int, Set[str]] = {}
    try:
        tokens = list(tokenize.generate_tokens(
            io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        for i, line in enumerate(lines, start=1):
            m = _SUPPRESS_RE.search(line)
            if m:
                out[i] = {r.strip() for r in m.group(1).split(",")
                          if r.strip()}
        return out
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _SUPPRESS_RE.search(tok.string)
        if m:
            out[tok.start[0]] = {r.strip() for r in m.group(1).split(",")
                                 if r.strip()}
    return out


def lint_source(source: str, path: str = "<string>", *,
                module: Optional[str] = None,
                select: Optional[Iterable[str]] = None) -> List[Finding]:
    """Lint one source string; the programmatic entry point (tests use it).

    ``module`` overrides the dotted-module inference from ``path`` —
    fixtures pass e.g. ``module="repro.core.engine"`` to put a synthetic
    snippet in scope of the module-scoped rules.
    """
    if module is None:
        module = module_name_for(Path(path))
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [Finding(path, exc.lineno or 1, exc.offset or 0, "parse",
                        f"syntax error: {exc.msg}")]
    lines = source.splitlines()
    ctx = Context(path=path, module=module, source=source, tree=tree,
                  lines=lines)
    wanted = set(select) if select is not None else None
    findings: List[Finding] = []
    for name, check in ALL_RULES.items():
        if wanted is not None and name not in wanted:
            continue
        findings.extend(check(ctx))
    sup = _suppressions(source, lines)
    kept = [f for f in findings
            if not (sup.get(f.line) and
                    ("all" in sup[f.line] or f.rule in sup[f.line]))]
    if wanted is None:
        # Full runs validate the suppressions themselves: a disable that
        # filtered no finding is stale (or a typo'd rule name) and keeping
        # it would silently shadow future findings on that line.
        for line, rules in sorted(sup.items()):
            used = any(f.line == line and
                       ("all" in rules or f.rule in rules)
                       for f in findings)
            if not used:
                kept.append(Finding(
                    path, line, 0, UNUSED_SUPPRESSION,
                    f"suppression 'lint: disable={','.join(sorted(rules))}'"
                    " does not suppress any finding — stale or typo'd; "
                    "delete it"))
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return kept


DEFAULT_TARGETS = ("src/repro", "tools")
_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache"}


def iter_py_files(targets: Sequence[str], root: Path) -> List[Path]:
    files: List[Path] = []
    for t in targets:
        p = (root / t) if not Path(t).is_absolute() else Path(t)
        if p.is_file() and p.suffix == ".py":
            files.append(p)
        elif p.is_dir():
            files.extend(sorted(
                f for f in p.rglob("*.py")
                if not (_SKIP_DIRS & set(f.parts))))
    return files


def lint_paths(targets: Sequence[str], *, root: Optional[Path] = None,
               select: Optional[Iterable[str]] = None) -> List[Finding]:
    root = root or Path(__file__).resolve().parents[2]
    findings: List[Finding] = []
    for f in iter_py_files(targets, root):
        rel = f.relative_to(root) if f.is_relative_to(root) else f
        findings.extend(lint_source(f.read_text(), str(rel), select=select))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    parser = argparse.ArgumentParser(
        prog="python -m tools.lint",
        description="repro-lint: layer contracts, matrix schema, "
                    "determinism and dtype discipline.")
    parser.add_argument("targets", nargs="*", default=list(DEFAULT_TARGETS),
                        help="files or directories relative to the repo "
                             f"root (default: {' '.join(DEFAULT_TARGETS)})")
    parser.add_argument("--select", default=None, metavar="RULE[,RULE]",
                        help="run only these rule families")
    parser.add_argument("--json", action="store_true",
                        help="emit findings as a JSON array")
    parser.add_argument("--list-rules", action="store_true",
                        help="list rule families and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for name in ALL_RULES:
            print(name)
        print(UNUSED_SUPPRESSION)  # meta-check, active on full runs
        return 0
    select = ([r.strip() for r in args.select.split(",") if r.strip()]
              if args.select else None)
    if select:
        unknown = set(select) - set(ALL_RULES) - {UNUSED_SUPPRESSION}
        if unknown:
            print(f"unknown rule(s): {', '.join(sorted(unknown))}; "
                  f"known: {', '.join(ALL_RULES)}", file=sys.stderr)
            return 2
    findings = lint_paths(args.targets, select=select)
    if args.json:
        print(json.dumps([f.as_dict() for f in findings], indent=2))
    else:
        for f in findings:
            print(f.render())
        if findings:
            print(f"{len(findings)} finding(s)", file=sys.stderr)
    return 1 if findings else 0


# Imported at the bottom: rule modules import Finding/Context from here.
from tools.lint.rules import ALL_RULES  # noqa: E402
