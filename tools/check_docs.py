"""Docs CI gate: intra-repo markdown links resolve and README quickstart
commands run ``--help`` cleanly.

    PYTHONPATH=src python tools/check_docs.py [--no-commands]

Checks every tracked ``*.md`` file for relative links whose target file is
missing, then extracts ``PYTHONPATH=src python ...`` command lines from
README.md bash blocks and runs each with ``--help`` appended (argparse
surfaces import errors and CLI drift without paying for a real run).
"""

from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def md_files():
    out = subprocess.run(["git", "ls-files", "*.md"], cwd=REPO,
                         capture_output=True, text=True, check=True)
    return [p for p in out.stdout.splitlines() if p]


def check_links() -> list:
    errors = []
    for md in md_files():
        base = os.path.dirname(os.path.join(REPO, md))
        text = open(os.path.join(REPO, md)).read()
        for target in LINK_RE.findall(text):
            if target.startswith(SKIP_PREFIXES):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = os.path.normpath(os.path.join(base, path))
            if not os.path.exists(resolved):
                errors.append(f"{md}: broken link -> {target}")
    return errors


def readme_commands() -> list:
    """``PYTHONPATH=src python ...`` lines from README bash blocks, with
    backslash continuations joined."""
    text = open(os.path.join(REPO, "README.md")).read()
    cmds = []
    for block in re.findall(r"```bash\n(.*?)```", text, re.S):
        joined = block.replace("\\\n", " ")
        for line in joined.splitlines():
            line = line.split("#", 1)[0].strip()
            if line.startswith("PYTHONPATH=src python") and "pytest" not in line:
                cmds.append(line)
    return cmds


def check_commands() -> list:
    errors = []
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    for cmd in readme_commands():
        argv = cmd.split()[1:] + ["--help"]  # drop the PYTHONPATH=src prefix
        r = subprocess.run(argv, cwd=REPO, env=env, capture_output=True,
                           text=True, timeout=300)
        status = "ok" if r.returncode == 0 else f"exit {r.returncode}"
        print(f"[check-docs] {' '.join(argv)}: {status}")
        if r.returncode != 0:
            errors.append(f"{cmd!r} --help failed:\n{r.stderr[-2000:]}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-commands", action="store_true",
                    help="only check markdown links")
    args = ap.parse_args(argv)
    errors = check_links()
    print(f"[check-docs] {len(md_files())} markdown files, "
          f"{len(errors)} broken links")
    if not args.no_commands:
        errors += check_commands()
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
